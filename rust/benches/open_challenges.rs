//! Bench: the paper's §4.6 open challenges, explored as extensions,
//! plus DRAM controller design-choice ablations (DESIGN.md §5(3)).
//!
//! (b) "investigate schemes to improve utilization of bank-level
//!     parallelism in modern memories" — bank-group-interleaved
//!     address mapping vs the Ramulator default.
//! (c) "enabling the immediate update propagation scheme for
//!     multi-channel" — AccuGraph/ForeGraph with their data structures
//!     striped line-interleaved across channels.
//! Ablation: FR-FCFS vs FCFS scheduling, open- vs closed-page rows.

use graphmem::accel::{build, AcceleratorConfig, AcceleratorKind};
use graphmem::algo::problem::{GraphProblem, ProblemKind};
use graphmem::dram::{
    AddrMap, ChannelMode, DramPolicy, DramSpec, MemorySystem, RowPolicy, SchedPolicy,
};
use graphmem::graph::datasets;
use graphmem::report::Table;

fn run_with(
    kind: AcceleratorKind,
    graph: &str,
    channels: usize,
    policy: DramPolicy,
) -> graphmem::sim::SimReport {
    let g = datasets::dataset(graph).expect("dataset");
    let p = GraphProblem::new(ProblemKind::Bfs, &g);
    let mut cfg = AcceleratorConfig::all_optimizations().with_channels(channels);
    cfg.experimental_multichannel = true;
    let mode = if kind.multi_channel() {
        ChannelMode::Region
    } else {
        ChannelMode::InterleaveLine
    };
    let mut accel = build(kind, &g, &cfg);
    let mut mem = MemorySystem::with_mode_and_policy(DramSpec::ddr4_2400(channels), mode, policy);
    accel.run(&p, &mut mem)
}

fn main() {
    let t0 = std::time::Instant::now();

    // ---- open challenge (b): address mapping ----
    let mut t = Table::new(
        "Open challenge (b) — bank-group-interleaved mapping vs default (BFS, DDR4 1ch)",
        &["accel", "graph", "default (s)", "util%", "interleaved (s)", "util%", "speedup"],
    );
    for (kind, g) in [
        (AcceleratorKind::AccuGraph, "sd"),
        (AcceleratorKind::AccuGraph, "pk"),
        (AcceleratorKind::HitGraph, "sd"),
        (AcceleratorKind::ThunderGp, "pk"),
    ] {
        let base = run_with(kind, g, 1, DramPolicy::default());
        let inter = run_with(
            kind,
            g,
            1,
            DramPolicy {
                addr_map: AddrMap::BankInterleaved,
                ..Default::default()
            },
        );
        t.row(vec![
            kind.name().into(),
            g.into(),
            format!("{:.5}", base.seconds),
            format!("{:.1}", 100.0 * base.bus_utilization),
            format!("{:.5}", inter.seconds),
            format!("{:.1}", 100.0 * inter.bus_utilization),
            format!("{:.2}x", base.seconds / inter.seconds),
        ]);
    }
    println!("{}", t.render());

    // ---- open challenge (c): multi-channel immediate propagation ----
    let mut t = Table::new(
        "Open challenge (c) — immediate-propagation systems, striped across channels (BFS)",
        &["accel", "graph", "1ch (s)", "2ch speedup", "4ch speedup"],
    );
    for (kind, g) in [
        (AcceleratorKind::AccuGraph, "pk"),
        (AcceleratorKind::AccuGraph, "lj"),
        (AcceleratorKind::ForeGraph, "pk"),
        (AcceleratorKind::ForeGraph, "lj"),
    ] {
        let base = run_with(kind, g, 1, DramPolicy::default());
        let two = run_with(kind, g, 2, DramPolicy::default());
        let four = run_with(kind, g, 4, DramPolicy::default());
        t.row(vec![
            kind.name().into(),
            g.into(),
            format!("{:.5}", base.seconds),
            format!("{:.2}x", base.seconds / two.seconds),
            format!("{:.2}x", base.seconds / four.seconds),
        ]);
    }
    println!("{}", t.render());

    // ---- controller policy ablation ----
    let mut t = Table::new(
        "DRAM controller ablation (BFS, DDR4 1ch): scheduling x row policy",
        &["accel", "graph", "FR-FCFS/open (s)", "FCFS", "closed-page"],
    );
    for (kind, g) in [
        (AcceleratorKind::AccuGraph, "sd"),
        (AcceleratorKind::HitGraph, "wt"),
        (AcceleratorKind::ThunderGp, "yt"),
    ] {
        let base = run_with(kind, g, 1, DramPolicy::default());
        let fcfs = run_with(
            kind,
            g,
            1,
            DramPolicy {
                sched: SchedPolicy::Fcfs,
                ..Default::default()
            },
        );
        let closed = run_with(
            kind,
            g,
            1,
            DramPolicy {
                row: RowPolicy::ClosedPage,
                ..Default::default()
            },
        );
        t.row(vec![
            kind.name().into(),
            g.into(),
            format!("{:.5}", base.seconds),
            format!("{:.2}x", base.seconds / fcfs.seconds),
            format!("{:.2}x", base.seconds / closed.seconds),
        ]);
    }
    println!("{}", t.render());
    println!(
        "bench open_challenges: done in {:.2}s",
        t0.elapsed().as_secs_f64()
    );
}
