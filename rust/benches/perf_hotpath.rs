//! Perf microbenches for the hot paths (EXPERIMENTS.md §Perf):
//!
//! * DRAM channel service throughput — sequential / random streams
//!   (requests per wall-second).
//! * Phase-driver throughput (merge tree + window + chaining on top of
//!   the DRAM model).
//! * End-to-end simulation throughput (HitGraph BFS on a mid-size
//!   graph, simulated requests per wall-second).
//! * Golden engines: native vs XLA/PJRT per-iteration latency.

use graphmem::accel::stream::{seq_lines, Phase, StreamClass};
use graphmem::accel::{build, AcceleratorConfig, AcceleratorKind};
use graphmem::algo::problem::{GraphProblem, ProblemKind};
use graphmem::dram::{ChannelMode, DramSpec, MemKind, MemRequest, MemorySystem};
use graphmem::engine::{AlgorithmEngine, NativeEngine, XlaEngine};
use graphmem::graph::rmat::{generate, RmatParams};
use graphmem::sim::run_phase;
use graphmem::util::rng::Rng;

fn time<F: FnMut()>(mut f: F) -> f64 {
    let t0 = std::time::Instant::now();
    f();
    t0.elapsed().as_secs_f64()
}

fn bench_dram_channel() {
    let spec = DramSpec::ddr4_2400(1);
    const N: u64 = 2_000_000;

    // sequential
    let mut mem = MemorySystem::new(spec);
    let dt = time(|| {
        for i in 0..N {
            mem.enqueue(
                MemRequest {
                    addr: i * 64,
                    kind: MemKind::Read,
                    tag: i,
                    region: graphmem::trace::Region::Edges,
                },
                0,
            );
            if i % 64 == 63 {
                while mem.service_one().is_some() {}
            }
        }
        while mem.service_one().is_some() {}
    });
    println!(
        "dram.sequential: {:.2} M req/s ({} requests in {:.3}s)",
        N as f64 / dt / 1e6,
        N,
        dt
    );

    // random
    let mut mem = MemorySystem::new(spec);
    let mut rng = Rng::new(1);
    let span = spec.channel_bytes / 64;
    let dt = time(|| {
        for i in 0..N {
            mem.enqueue(
                MemRequest {
                    addr: rng.next_below(span) * 64,
                    kind: MemKind::Read,
                    tag: i,
                    region: graphmem::trace::Region::Vertices,
                },
                0,
            );
            if i % 64 == 63 {
                while mem.service_one().is_some() {}
            }
        }
        while mem.service_one().is_some() {}
    });
    println!("dram.random:     {:.2} M req/s", N as f64 / dt / 1e6);
}

fn bench_phase_driver() {
    let spec = DramSpec::ddr4_2400(1);
    const LINES: u64 = 1_000_000;
    let mut mem = MemorySystem::new(spec);
    let phase = Phase::single(
        StreamClass::Edges,
        MemKind::Read,
        seq_lines(0, LINES * 64),
        32,
    );
    let dt = time(|| {
        run_phase(&mut mem, &phase, 0);
    });
    println!(
        "driver.seq_phase: {:.2} M req/s ({} lines in {:.3}s)",
        LINES as f64 / dt / 1e6,
        LINES,
        dt
    );
}

fn bench_end_to_end_sim() {
    let g = generate(RmatParams::graph500(14, 16, 7)); // 16k x 262k
    let p = GraphProblem::new(ProblemKind::Bfs, &g);
    let cfg = AcceleratorConfig::all_optimizations();
    let mut accel = build(AcceleratorKind::HitGraph, &g, &cfg);
    let mut mem = MemorySystem::with_mode(DramSpec::ddr4_2400(1), ChannelMode::Region);
    let mut report = None;
    let dt = time(|| {
        report = Some(accel.run(&p, &mut mem));
    });
    let r = report.unwrap();
    println!(
        "sim.hitgraph_bfs_r14: {:.2} M req/s wall ({} DRAM requests, sim {:.4}s, wall {:.3}s, slowdown {:.0}x)",
        r.dram.requests() as f64 / dt / 1e6,
        r.dram.requests(),
        r.seconds,
        dt,
        dt / r.seconds
    );
}

fn bench_engines() {
    let g = generate(RmatParams::graph500(11, 12, 42));
    let p = GraphProblem::new(ProblemKind::PageRank, &g);
    let mut native = NativeEngine::new();
    let dt_native = time(|| {
        native.run(&p, &g, 1).unwrap();
    });
    println!("engine.native_pr_step: {:.3} ms", dt_native * 1e3);
    match XlaEngine::from_repo_root() {
        Ok(mut xla) => {
            // warm-up compiles the executable
            xla.run(&p, &g, 1).unwrap();
            let dt_x = time(|| {
                xla.run(&p, &g, 1).unwrap();
            });
            println!(
                "engine.xla_pr_step:    {:.3} ms ({:.1}x native; interpret-mode Pallas scatter is O(N*M))",
                dt_x * 1e3,
                dt_x / dt_native
            );
        }
        Err(e) => println!("engine.xla: skipped ({e})"),
    }
}

fn main() {
    println!("perf_hotpath — simulator throughput microbenches");
    bench_dram_channel();
    bench_phase_driver();
    bench_end_to_end_sim();
    bench_engines();
}
