//! Perf microbenches for the hot paths (EXPERIMENTS.md §Perf):
//!
//! * DRAM channel service throughput — sequential / random streams
//!   (requests per wall-second) through the event-driven completion
//!   heap.
//! * Phase-driver throughput (merge tree + window + chaining on top of
//!   the DRAM model), descriptor streams vs the materialized escape
//!   hatch — the zero-materialization refactor's headline numbers —
//!   plus per-call vs arena-reused scratch (`driver.scratch_fresh` /
//!   `driver.scratch_reuse`).
//! * End-to-end simulation throughput (HitGraph BFS on a mid-size
//!   graph, simulated requests per wall-second).
//! * Program-cache amortization (`sweep.mem_axis_amortized.*`): one
//!   workload across a memory-technology × channel-count sweep,
//!   fresh-compile vs the session's shared program cache side by side
//!   — reports asserted bit-identical in-run, and the cached pass
//!   must run ≥2× fewer compile passes.
//! * On-chip vertex buffer (`onchip.{off,vertex_cache}`): AccuGraph ×
//!   lj streaming-only vs with the paper's vertex array modelled —
//!   the cached row is asserted in-run to issue strictly fewer DRAM
//!   requests and to report ≥1 hit (`onchip_hits` JSON extra).
//! * Advisor probe vs full sweep (`advisor.probe_vs_full`): one
//!   sampled probe producing a full recommendation vs the 12-point
//!   on-chip grid search it replaces — the probe is asserted in-run
//!   to be ≥10× cheaper, and CI greps `advisor_probe_runs`.
//! * ReGraph event-heap servicing at 32 HBM2 pseudo-channels
//!   (`regraph.c32_heap`): asserted in-run bit-identical to the
//!   retained linear-scan reference selector — CI's bench-smoke greps
//!   `heap_scan_agree` and the request count.
//! * Fault-injector overhead (`robust.faulted_vs_clean`): the same
//!   HitGraph BFS run clean and under `FaultPlan::mixed`, both through
//!   `run_checked` — asserted in-run that neither surfaces a
//!   `SimError`, that faults actually fired, and that injection moves
//!   cycles upward without touching results. CI's bench-smoke greps
//!   `sim_errors` and `faults_injected`.
//! * Durable-cache restart (`serve.cold_vs_warm.{cold,warm}`): one
//!   figure-grade spec set simulated and written through a disk-backed
//!   session, then re-served by a fresh session over the same cache
//!   directory — the warm pass is asserted bit-identical with zero
//!   executed simulations. CI's bench-smoke greps `disk_cache_hits`.
//! * Static-verifier overhead (`verify.overhead`): figure-grade
//!   programs compiled vs verified side by side — verification is
//!   asserted in-run to cost <10% of compilation and to find zero
//!   violations. CI's bench-smoke greps `verify_violations`.
//! * Golden engines: native vs XLA/PJRT per-iteration latency.
//!
//! Output: human-readable lines on stdout, plus machine-readable JSON
//! lines (one object per bench: name, requests, wall seconds,
//! requests/s, peak stream bytes, optional per-bench extras like
//! `programs_compiled`/`programs_reused`) written to the file named by
//! `GRAPHMEM_BENCH_JSON` or `--json <path>` (replacing its contents). `GRAPHMEM_SCOPE=quick`
//! shrinks every size so CI can smoke-run the whole file in seconds;
//! the committed `BENCH_hotpath.json` at the repo root records the
//! full-scope baseline schema (refresh it with
//! `cargo bench --bench perf_hotpath` on a quiet machine).

use graphmem::accel::stream::{Fanout, LineSource, LineStream, Merge, Phase, StreamClass};
use graphmem::accel::{build, AcceleratorConfig, AcceleratorKind};
use graphmem::advisor::Advisor;
use graphmem::algo::problem::{GraphProblem, ProblemKind};
use graphmem::dram::{ChannelMode, DramSpec, FaultPlan, MemKind, MemRequest, MemTech, MemorySystem};
use graphmem::engine::{AlgorithmEngine, NativeEngine, XlaEngine};
use graphmem::graph::rmat::{generate, RmatParams};
use graphmem::graph::DatasetId;
use graphmem::onchip::OnChipConfig;
use graphmem::sim::{run_phase, run_phase_with, PhaseScratch, Session, SimSpec, Sweep, Workload};
use graphmem::util::rng::Rng;
use std::io::Write;

fn time<F: FnMut()>(mut f: F) -> f64 {
    let t0 = std::time::Instant::now();
    f();
    t0.elapsed().as_secs_f64()
}

/// One machine-readable result row.
struct BenchRow {
    name: String,
    requests: u64,
    wall_s: f64,
    peak_stream_bytes: u64,
    /// Additional per-bench counters, appended verbatim to the JSON
    /// object (e.g. program-cache compile/reuse counts).
    extras: Vec<(&'static str, u64)>,
}

impl BenchRow {
    fn req_per_s(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.requests as f64 / self.wall_s
        } else {
            0.0
        }
    }

    /// Hand-rolled JSON (the offline registry has no serde).
    fn json(&self) -> String {
        let mut s = format!(
            "{{\"bench\":\"{}\",\"requests\":{},\"wall_s\":{:.6},\"req_per_s\":{:.1},\"peak_stream_bytes\":{}",
            self.name, self.requests, self.wall_s, self.req_per_s(), self.peak_stream_bytes
        );
        for (k, v) in &self.extras {
            s.push_str(&format!(",\"{k}\":{v}"));
        }
        s.push('}');
        s
    }
}

struct Reporter {
    rows: Vec<BenchRow>,
}

impl Reporter {
    fn record(&mut self, name: &str, requests: u64, wall_s: f64, peak_stream_bytes: u64) {
        self.record_with(name, requests, wall_s, peak_stream_bytes, Vec::new());
    }

    fn record_with(
        &mut self,
        name: &str,
        requests: u64,
        wall_s: f64,
        peak_stream_bytes: u64,
        extras: Vec<(&'static str, u64)>,
    ) {
        print!(
            "{name}: {:.2} M req/s ({requests} requests in {wall_s:.3}s, stream bytes {peak_stream_bytes}",
            requests as f64 / wall_s.max(1e-12) / 1e6,
        );
        for (k, v) in &extras {
            print!(", {k} {v}");
        }
        println!(")");
        self.rows.push(BenchRow {
            name: name.to_string(),
            requests,
            wall_s,
            peak_stream_bytes,
            extras,
        });
    }

    fn flush(&self, path: Option<&str>) {
        let Some(path) = path else { return };
        let mut out = String::new();
        let scope = if quick_scope() { "quick" } else { "full" };
        out.push_str(&format!(
            "{{\"meta\":\"graphmem perf_hotpath\",\"scope\":\"{scope}\"}}\n"
        ));
        for r in &self.rows {
            out.push_str(&r.json());
            out.push('\n');
        }
        match std::fs::File::create(path).and_then(|mut f| f.write_all(out.as_bytes())) {
            Ok(()) => println!("wrote {} JSON rows to {path}", self.rows.len()),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
    }
}

fn quick_scope() -> bool {
    std::env::var("GRAPHMEM_SCOPE").map(|s| s == "quick").unwrap_or(false)
}

fn bench_dram_channel(rep: &mut Reporter) {
    let spec = DramSpec::ddr4_2400(1);
    let n: u64 = if quick_scope() { 100_000 } else { 2_000_000 };

    // sequential
    let mut mem = MemorySystem::new(spec);
    let dt = time(|| {
        for i in 0..n {
            mem.enqueue(
                MemRequest {
                    addr: i * 64,
                    kind: MemKind::Read,
                    tag: i,
                    region: graphmem::trace::Region::Edges,
                },
                0,
            );
            if i % 64 == 63 {
                while mem.service_one().is_some() {}
            }
        }
        while mem.service_one().is_some() {}
    });
    rep.record("dram.sequential", n, dt, 0);

    // random
    let mut mem = MemorySystem::new(spec);
    let mut rng = Rng::new(1);
    let span = spec.channel_bytes / 64;
    let dt = time(|| {
        for i in 0..n {
            mem.enqueue(
                MemRequest {
                    addr: rng.next_below(span) * 64,
                    kind: MemKind::Read,
                    tag: i,
                    region: graphmem::trace::Region::Vertices,
                },
                0,
            );
            if i % 64 == 63 {
                while mem.service_one().is_some() {}
            }
        }
        while mem.service_one().is_some() {}
    });
    rep.record("dram.random", n, dt, 0);

    // multi-channel servicing: the event-driven heap's O(log C) pick
    // vs the pre-refactor per-request scan of every channel queue
    // (service_one_scan is the seed's selection algorithm, kept as a
    // verified-identical reference) — this pair is the dram-layer
    // before/after measurement.
    let spec8 = DramSpec::hbm_1000(8);
    for (name, use_scan) in [("dram.sequential_8ch", false), ("dram.sequential_8ch_scan", true)] {
        let mut mem = MemorySystem::new(spec8);
        let service = |m: &mut MemorySystem| {
            if use_scan {
                m.service_one_scan().is_some()
            } else {
                m.service_one().is_some()
            }
        };
        let dt = time(|| {
            for i in 0..n {
                mem.enqueue(
                    MemRequest {
                        addr: i * 64,
                        kind: MemKind::Read,
                        tag: i,
                        region: graphmem::trace::Region::Edges,
                    },
                    0,
                );
                if i % 512 == 511 {
                    while service(&mut mem) {}
                }
            }
            while service(&mut mem) {}
        });
        rep.record(name, n, dt, 0);
    }
}

/// The seed's phase-driver algorithm for a single independent stream:
/// materialized address vector, per-pick `channel_of` on the vector,
/// one scan-selected completion per fill attempt. Used as the honest
/// pre-refactor baseline for `driver.seq_phase`; its end cycle must
/// equal the descriptor run's (asserted in `bench_phase_driver`).
fn run_phase_reference(mem: &mut MemorySystem, lines: &[u64], window: usize, start: u64) -> u64 {
    let nch = mem.num_channels();
    let mut in_flight = vec![0usize; nch];
    let mut slot_free_at = vec![start; nch];
    let mut issued = 0usize;
    let mut total_in_flight = 0usize;
    let mut end = start;
    loop {
        loop {
            if issued >= lines.len() {
                break;
            }
            let ch = mem.channel_of(lines[issued]);
            if in_flight[ch] >= window {
                break;
            }
            let arrival = if in_flight[ch] + 1 == window {
                slot_free_at[ch]
            } else {
                start
            };
            mem.enqueue(
                MemRequest {
                    addr: lines[issued],
                    kind: MemKind::Read,
                    tag: issued as u64,
                    region: graphmem::trace::Region::Edges,
                },
                arrival,
            );
            issued += 1;
            in_flight[ch] += 1;
            total_in_flight += 1;
        }
        if total_in_flight == 0 {
            break;
        }
        let tok = mem.service_one_scan().expect("in-flight implies serviceable");
        in_flight[tok.channel] -= 1;
        total_in_flight -= 1;
        slot_free_at[tok.channel] = tok.done_at;
        end = end.max(tok.done_at);
    }
    end
}

fn bench_phase_driver(rep: &mut Reporter) {
    let spec = DramSpec::ddr4_2400(1);
    let lines: u64 = if quick_scope() { 100_000 } else { 1_000_000 };

    // Descriptor path: zero stream bytes regardless of length.
    let mut mem = MemorySystem::new(spec);
    let phase = Phase::single(
        StreamClass::Edges,
        MemKind::Read,
        LineSource::seq(0, lines * 64),
        32,
    );
    let peak = phase.stream_bytes();
    let mut end_desc = 0;
    let dt = time(|| {
        end_desc = run_phase(&mut mem, &phase, 0).end_cycle;
    });
    rep.record("driver.seq_phase", lines, dt, peak);

    // Materialized escape hatch: same simulation through the new
    // driver, O(lines) address memory.
    let mut mem = MemorySystem::new(spec);
    let mat = phase.materialized();
    let peak = mat.stream_bytes();
    let dt = time(|| {
        run_phase(&mut mem, &mat, 0);
    });
    rep.record("driver.seq_phase_materialized", lines, dt, peak);

    // Pre-refactor baseline: the seed's algorithm end to end —
    // materialized vector, per-pick channel_of, scan-selected
    // completions, no batching. The >= 2x acceptance criterion is
    // driver.seq_phase vs this row; the end-cycle assert keeps the
    // comparison honest (identical simulation, different engine).
    let mut mem = MemorySystem::new(spec);
    let addr_vec = LineSource::seq(0, lines * 64).materialize();
    let peak = addr_vec.len() as u64 * 8;
    let mut end_ref = 0;
    let dt = time(|| {
        end_ref = run_phase_reference(&mut mem, &addr_vec, 32, 0);
    });
    assert_eq!(end_desc, end_ref, "reference driver must be bit-identical");
    rep.record("driver.seq_phase_seed_reference", lines, dt, peak);

    // Chained pair (parent releases child lines), descriptor form.
    let mut mem = MemorySystem::new(spec);
    let half = lines / 2;
    let phase = Phase {
        streams: vec![
            graphmem::accel::stream::LineStream::independent(
                StreamClass::Edges,
                MemKind::Read,
                LineSource::seq(0, half * 64),
            ),
            graphmem::accel::stream::LineStream::chained(
                StreamClass::Writes,
                MemKind::Write,
                LineSource::seq(1 << 34, half * 64),
                0,
                graphmem::accel::stream::Fanout::Uniform(1),
            ),
        ],
        merge: graphmem::accel::stream::Merge::prio([1, 0]).into(),
        window: 32,
    };
    let peak = phase.stream_bytes();
    let dt = time(|| {
        run_phase(&mut mem, &phase, 0);
    });
    rep.record("driver.chained_phase", lines, dt, peak);
}

fn bench_end_to_end_sim(rep: &mut Reporter) {
    let scale = if quick_scope() { 10 } else { 14 };
    let g = generate(RmatParams::graph500(scale, 16, 7));
    let p = GraphProblem::new(ProblemKind::Bfs, &g);
    let cfg = AcceleratorConfig::all_optimizations();
    let mut accel = build(AcceleratorKind::HitGraph, &g, &cfg);
    let mut mem = MemorySystem::with_mode(DramSpec::ddr4_2400(1), ChannelMode::Region);
    let mut report = None;
    let dt = time(|| {
        report = Some(accel.run(&p, &mut mem));
    });
    let r = report.unwrap();
    println!(
        "sim.hitgraph_bfs: sim {:.4}s, wall {:.3}s, slowdown {:.0}x",
        r.seconds,
        dt,
        dt / r.seconds.max(1e-12)
    );
    rep.record(
        &format!("sim.hitgraph_bfs_r{scale}"),
        r.dram.requests(),
        dt,
        0,
    );
}

/// Arena-reused scratch vs per-call allocation across many small
/// phases — the shape accelerator runs actually produce (one phase
/// per partition per iteration). End cycles are asserted identical.
fn bench_driver_scratch(rep: &mut Reporter) {
    let spec = DramSpec::ddr4_2400(2);
    let phases_n: usize = if quick_scope() { 512 } else { 4096 };
    let phases: Vec<Phase> = (0..phases_n)
        .map(|i| {
            let base = (i as u64) << 20;
            let parent = LineStream::independent(
                StreamClass::Edges,
                MemKind::Read,
                LineSource::seq(base, 48 * 64),
            );
            let gather =
                LineSource::gather(1 << 34, 4, (0..24u64).map(|j| (j * 37 + i as u64) % 4096));
            let released = gather.len() as u32;
            let child = LineStream::chained(
                StreamClass::Writes,
                MemKind::Write,
                gather,
                0,
                Fanout::AfterLast(released),
            );
            Phase {
                streams: vec![parent, child],
                merge: Merge::prio([1, 0]).into(),
                window: 16,
            }
        })
        .collect();
    let requests: u64 = phases.iter().map(|p| p.total_requests() as u64).sum();

    let mut mem = MemorySystem::new(spec);
    let mut end_fresh = 0u64;
    let dt_fresh = time(|| {
        let mut c = 0;
        for ph in &phases {
            c = run_phase(&mut mem, ph, c).end_cycle;
        }
        end_fresh = c;
    });
    rep.record("driver.scratch_fresh", requests, dt_fresh, 0);

    let mut mem = MemorySystem::new(spec);
    let mut scratch = PhaseScratch::new();
    let mut end_shared = 0u64;
    let dt_shared = time(|| {
        let mut c = 0;
        for ph in &phases {
            c = run_phase_with(&mut mem, ph, c, &mut scratch).end_cycle;
        }
        end_shared = c;
    });
    assert_eq!(end_fresh, end_shared, "scratch reuse must be bit-identical");
    rep.record("driver.scratch_reuse", requests, dt_shared, 0);
}

/// The paper's sweep shape: one workload across memory technologies ×
/// channel counts. Fresh-compile (one program compile per point, the
/// pre-refactor behavior) vs a session's shared program cache (one
/// compile per channel count), side by side on the same spec list.
/// Reports must be bit-identical; the cached pass must compile ≥2×
/// fewer programs.
fn bench_sweep_mem_axis(rep: &mut Reporter) {
    let scale = if quick_scope() { 9 } else { 12 };
    let g = generate(RmatParams::graph500(scale, 8, 0xA5));
    let sweep = Sweep::new()
        .accelerators([AcceleratorKind::ThunderGp])
        .workloads([Workload::custom("mem-axis", g)])
        .problems([ProblemKind::Bfs])
        .mem_techs([MemTech::Ddr3, MemTech::Ddr4, MemTech::Hbm])
        .channels([1, 2, 4, 8])
        .configs([AcceleratorConfig::all_optimizations()])
        .skip_unsupported(); // DDR3/DDR4 cap at 4 channels
    let specs = sweep.specs().expect("sweep axes are non-empty");

    // Fresh: every point compiles its own program (SimSpec::run).
    let mut fresh = Vec::with_capacity(specs.len());
    let dt_fresh = time(|| {
        for s in &specs {
            fresh.push(s.run());
        }
    });
    let requests: u64 = fresh.iter().map(|r| r.dram.requests()).sum();
    rep.record_with(
        "sweep.mem_axis_amortized.fresh",
        requests,
        dt_fresh,
        0,
        vec![("compile_passes", specs.len() as u64)],
    );

    // Cached: one serial session; programs shared across the mem axis.
    let session = Session::new();
    let mut cached = Vec::with_capacity(specs.len());
    let dt_cached = time(|| {
        for s in &specs {
            cached.push(session.run(s));
        }
    });
    assert_eq!(fresh, cached, "program cache must be bit-identical");
    let st = session.stats();
    assert!(
        st.programs_compiled * 2 <= specs.len(),
        "expected >=2x fewer compile passes: {} compiles for {} points",
        st.programs_compiled,
        specs.len()
    );
    assert!(st.programs_reused >= 1, "cache must see reuse");
    rep.record_with(
        "sweep.mem_axis_amortized.cached",
        requests,
        dt_cached,
        0,
        vec![
            ("compile_passes", st.programs_compiled as u64),
            ("programs_reused", st.programs_reused as u64),
        ],
    );
}

/// On-chip vertex buffer (the PR-5 tentpole): AccuGraph × lj with the
/// paper's vertex array modelled vs streaming-only, side by side. The
/// cached row must issue strictly fewer DRAM requests and report at
/// least one hit (CI's bench-smoke greps `onchip_hits` so the buffer
/// cannot silently regress to always-miss).
fn bench_onchip(rep: &mut Reporter) {
    let problem = if quick_scope() { ProblemKind::PageRank } else { ProblemKind::Bfs };
    let mk = |onchip: Option<OnChipConfig>| {
        SimSpec::builder()
            .accelerator(AcceleratorKind::AccuGraph)
            .graph(DatasetId::Lj)
            .problem(problem)
            .config(AcceleratorConfig::all_optimizations())
            .onchip(onchip)
            .build()
            .expect("AccuGraph x lj is a valid spec")
    };
    let off_spec = mk(None);
    let mut off = None;
    let dt_off = time(|| off = Some(off_spec.run()));
    let off = off.unwrap();
    rep.record_with(
        "onchip.off",
        off.dram.requests(),
        dt_off,
        0,
        vec![("dram_requests", off.dram.requests())],
    );

    let cache = OnChipConfig::default_for(
        AcceleratorKind::AccuGraph,
        off_spec.config(),
    )
    .expect("AccuGraph has a default vertex array");
    let on_spec = mk(Some(cache));
    let mut on = None;
    let dt_on = time(|| on = Some(on_spec.run()));
    let on = on.unwrap();
    let stats = on.onchip.as_ref().expect("onchip specs attach counters");
    assert!(
        on.dram.requests() < off.dram.requests(),
        "vertex cache must issue strictly fewer DRAM requests: {} !< {}",
        on.dram.requests(),
        off.dram.requests()
    );
    assert!(stats.hits_total() >= 1, "the vertex array must hit at least once");
    rep.record_with(
        "onchip.vertex_cache",
        on.dram.requests(),
        dt_on,
        0,
        vec![
            ("dram_requests", on.dram.requests()),
            ("onchip_hits", stats.hits_total()),
            ("onchip_misses", stats.misses_total()),
            ("onchip_fills", stats.fills_total()),
        ],
    );
}

/// Advisor probe vs the sweep it replaces (`advisor.probe_vs_full`):
/// one sampled probe run producing a full recommendation vs the
/// 12-point on-chip sweep a user would otherwise grid-search. The
/// probe must be ≥10× cheaper (asserted in-run); CI's bench-smoke
/// greps `advisor_probe_runs` so the probe path cannot silently stop
/// executing.
fn bench_advisor(rep: &mut Reporter) {
    let scale = if quick_scope() { 9 } else { 12 };
    let g = generate(RmatParams::graph500(scale, 8, 0x5EED));
    let spec = SimSpec::builder()
        .accelerator(AcceleratorKind::AccuGraph)
        .custom_graph("advisor-bench", g.clone())
        .problem(ProblemKind::PageRank)
        .config(AcceleratorConfig::all_optimizations())
        .build()
        .expect("AccuGraph x rmat is a valid spec");

    // Probe: force sampling (1/8 of the edges) so the row measures
    // the cheap path the advisor actually takes on big graphs.
    let advisor = Advisor::new().with_probe_max_edges(g.num_edges() / 8);
    let mut rec = None;
    let dt_probe = time(|| rec = Some(advisor.recommend(&spec).expect("probe runs")));
    let rec = rec.unwrap();
    assert!(rec.probe_sampled, "probe cutoff must force sampling");

    // The grid search the probe replaces: a 12-point on-chip sweep at
    // full graph size through a fresh session.
    let budgets: Vec<Option<OnChipConfig>> = std::iter::once(None)
        .chain((0..11).map(|i| Some(OnChipConfig::vertex_cache(1024u64 << i))))
        .collect();
    let sweep_points = budgets.len() as u64;
    let sweep = Sweep::new()
        .accelerators([AcceleratorKind::AccuGraph])
        .workloads([Workload::custom("advisor-bench", g)])
        .problems([ProblemKind::PageRank])
        .configs([AcceleratorConfig::all_optimizations()])
        .onchip_configs(budgets);
    let session = Session::new();
    let mut runs = Vec::new();
    let dt_sweep = time(|| runs = sweep.run_with(&session).expect("sweep axes are non-empty"));
    let requests: u64 = runs.iter().map(|r| r.report.dram.requests()).sum();
    assert!(
        dt_sweep >= 10.0 * dt_probe,
        "probe must be >=10x cheaper than the sweep it replaces: probe {dt_probe:.4}s vs sweep {dt_sweep:.4}s"
    );
    rep.record_with(
        "advisor.probe_vs_full",
        rec.probe_requests,
        dt_probe,
        0,
        vec![
            ("advisor_probe_runs", 1),
            ("probe_sampled", 1),
            ("sweep_points", sweep_points),
            ("sweep_requests", requests),
            ("speedup_x", (dt_sweep / dt_probe.max(1e-12)) as u64),
        ],
    );
}

/// ReGraph at full HBM2 pseudo-channel fan-out (`regraph.c32_heap`):
/// one 32-channel heterogeneous (little/big pipeline) BFS serviced by
/// the event heap, asserted in-run to be bit-identical to the same
/// simulation replayed under the retained `service_one_scan`
/// reference selector. CI's bench-smoke greps `heap_scan_agree` and
/// the request count so the 32-channel path cannot silently stop
/// simulating.
fn bench_regraph_c32(rep: &mut Reporter) {
    let scale = if quick_scope() { 9 } else { 13 };
    let g = generate(RmatParams::graph500(scale, 12, 0xC32));
    let spec = SimSpec::builder()
        .accelerator(AcceleratorKind::ReGraph)
        .custom_graph("regraph-c32", g)
        .problem(ProblemKind::Bfs)
        .mem(MemTech::Hbm2)
        .channels(32)
        .config(AcceleratorConfig::all_optimizations())
        .build()
        .expect("ReGraph x hbm2 x32 is a valid spec");
    let mut heap = None;
    let dt_heap = time(|| heap = Some(spec.run()));
    let heap = heap.unwrap();
    let (scan, _) = spec.run_traced_scan();
    assert_eq!(
        heap, scan,
        "heap and scan servicing must be bit-identical at C=32"
    );
    assert_eq!(heap.channels, 32);
    assert!(heap.dram.requests() > 0, "C=32 run must issue DRAM traffic");
    rep.record_with(
        "regraph.c32_heap",
        heap.dram.requests(),
        dt_heap,
        0,
        vec![
            ("heap_scan_agree", 1),
            ("dram_requests", heap.dram.requests()),
            ("channels", 32),
        ],
    );
}

/// Fault-injector overhead (`robust.faulted_vs_clean`): one HitGraph
/// BFS simulated clean and again under `FaultPlan::mixed`, both via
/// the typed-error path (`run_checked`). The injector must be free
/// when absent (no plan installed → zero checks beyond an `Option`
/// test) and deterministic when present, so the interesting number is
/// the faulted/clean wall ratio at identical request counts. In-run
/// asserts guarantee the row can't go stale: zero `SimError`s, faults
/// actually injected, results untouched, cycles only ever up.
fn bench_robust_faults(rep: &mut Reporter) {
    let scale = if quick_scope() { 9 } else { 12 };
    let g = generate(RmatParams::graph500(scale, 12, 0xFA17));
    let clean_spec = SimSpec::builder()
        .accelerator(AcceleratorKind::HitGraph)
        .custom_graph("robust-fvc", g)
        .problem(ProblemKind::Bfs)
        .mem(MemTech::Hbm)
        .channels(4)
        .config(AcceleratorConfig::all_optimizations())
        .build()
        .expect("HitGraph x hbm x4 is a valid spec");
    let faulted_spec = clean_spec.clone().with_faults(Some(FaultPlan::mixed(0xFA17)));
    let mut sim_errors = 0u64;
    let mut clean = None;
    let dt_clean = time(|| match clean_spec.run_checked() {
        Ok(r) => clean = Some(r),
        Err(_) => sim_errors += 1,
    });
    let mut faulted = None;
    let dt_faulted = time(|| match faulted_spec.run_checked() {
        Ok(r) => faulted = Some(r),
        Err(_) => sim_errors += 1,
    });
    assert_eq!(sim_errors, 0, "neither run may surface a SimError");
    let (clean, faulted) = (clean.unwrap(), faulted.unwrap());
    assert!(faulted.dram.faults_injected > 0, "mixed plan must fire");
    assert_eq!(
        clean.metrics, faulted.metrics,
        "fault injection must never change algorithm results"
    );
    assert_eq!(clean.dram.requests(), faulted.dram.requests());
    assert!(faulted.cycles >= clean.cycles, "faults only ever add cycles");
    println!(
        "robust.faulted_vs_clean: clean {:.3} ms, faulted {:.3} ms ({} faults, +{} cycles)",
        dt_clean * 1e3,
        dt_faulted * 1e3,
        faulted.dram.faults_injected,
        faulted.cycles - clean.cycles
    );
    rep.record_with(
        "robust.faulted_vs_clean",
        clean.dram.requests() + faulted.dram.requests(),
        dt_clean + dt_faulted,
        0,
        vec![
            ("sim_errors", sim_errors),
            ("faults_injected", faulted.dram.faults_injected),
            ("fault_delay_cycles", faulted.dram.fault_delay_cycles),
            ("clean_cycles", clean.cycles),
            ("faulted_cycles", faulted.cycles),
        ],
    );
}

/// Durable-cache restart latency (`serve.cold_vs_warm`, the PR-9
/// tentpole's headline number): the same figure-grade spec set through
/// a cold session (simulate + write-through) and then a fresh session
/// over the same cache directory, as a daemon restart would see it.
/// In-run asserts pin the contract: the warm pass adopts every report
/// from disk bit-identically and executes zero simulations
/// (`sim_runs == disk_hits`). CI's bench-smoke greps
/// `disk_cache_hits` so the disk layer cannot silently stop hitting.
fn bench_serve_cold_vs_warm(rep: &mut Reporter) {
    use graphmem::persist::CacheDir;
    use std::sync::Arc;

    let pid = std::process::id();
    let root = std::env::temp_dir().join(format!("graphmem-bench-serve-{pid}"));
    let _ = std::fs::remove_dir_all(&root);
    let scale = if quick_scope() { 9 } else { 12 };
    let g = generate(RmatParams::graph500(scale, 8, 0x5E12));
    let specs: Vec<SimSpec> = [AcceleratorKind::HitGraph, AcceleratorKind::ThunderGp]
        .into_iter()
        .flat_map(|k| {
            [ProblemKind::Bfs, ProblemKind::PageRank].into_iter().map(move |p| (k, p))
        })
        .map(|(k, p)| {
            SimSpec::builder()
                .accelerator(k)
                .workload(Workload::custom("serve-bench", g.clone()))
                .problem(p)
                .config(AcceleratorConfig::all_optimizations())
                .build()
                .expect("bench specs are valid")
        })
        .collect();

    let cold = Session::new()
        .with_disk_cache(Arc::new(CacheDir::new(&root).expect("temp cache dir")));
    let mut cold_reports = Vec::with_capacity(specs.len());
    let dt_cold = time(|| {
        for s in &specs {
            cold_reports.push(cold.run(s));
        }
    });
    let st = cold.stats();
    assert_eq!(st.disk_writes, specs.len(), "cold pass writes every entry through");
    let requests: u64 = cold_reports.iter().map(|r| r.dram.requests()).sum();
    rep.record_with(
        "serve.cold_vs_warm.cold",
        requests,
        dt_cold,
        0,
        vec![
            ("disk_cache_hits", st.disk_hits as u64),
            ("disk_cache_writes", st.disk_writes as u64),
            ("executed_sims", (st.sim_runs - st.disk_hits) as u64),
        ],
    );

    // The restart: a fresh session (empty memo) over the same files.
    let warm = Session::new()
        .with_disk_cache(Arc::new(CacheDir::new(&root).expect("temp cache dir")));
    let mut warm_reports = Vec::with_capacity(specs.len());
    let dt_warm = time(|| {
        for s in &specs {
            warm_reports.push(warm.run(s));
        }
    });
    assert_eq!(warm_reports, cold_reports, "disk answers are bit-identical");
    let st = warm.stats();
    assert_eq!(
        st.sim_runs, st.disk_hits,
        "warm identity: the restarted session executed zero simulations"
    );
    assert!(st.disk_hits >= 1, "the disk cache must actually hit");
    rep.record_with(
        "serve.cold_vs_warm.warm",
        requests,
        dt_warm,
        0,
        vec![
            ("disk_cache_hits", st.disk_hits as u64),
            ("disk_cache_writes", st.disk_writes as u64),
            ("executed_sims", (st.sim_runs - st.disk_hits) as u64),
        ],
    );
    println!(
        "serve.cold_vs_warm: cold {:.3}s, warm {:.3}s ({:.0}x) over {} specs",
        dt_cold,
        dt_warm,
        dt_cold / dt_warm.max(1e-12),
        specs.len()
    );
    let _ = std::fs::remove_dir_all(&root);
}

/// Static verification overhead (`verify.overhead`): a figure-grade
/// spec set compiled repeatedly, then the compiled artifacts verified
/// repeatedly, side by side. Verification walks descriptor facts
/// (extremal lines for closed forms, full scans for gathers) and must
/// stay under 10% of compile wall time — asserted in-run, so the
/// checker cannot quietly grow a hot loop. CI's bench-smoke greps
/// `verify_violations` so the figure-grade programs stay clean.
fn bench_verify_overhead(rep: &mut Reporter) {
    let scale = if quick_scope() { 9 } else { 12 };
    let g = generate(RmatParams::graph500(scale, 8, 0x5EC5));
    // One spec per channel mode + the gather-heaviest design, so the
    // verify pass covers both the extremal-line and full-scan paths.
    let specs: Vec<SimSpec> = [
        (AcceleratorKind::AccuGraph, 1usize, MemTech::Ddr4),
        (AcceleratorKind::HitGraph, 8, MemTech::Hbm),
        (AcceleratorKind::ThunderGp, 8, MemTech::Hbm),
    ]
    .into_iter()
    .map(|(k, c, m)| {
        SimSpec::builder()
            .accelerator(k)
            .custom_graph("verify-bench", g.clone())
            .problem(ProblemKind::Bfs)
            .mem(m)
            .channels(c)
            .config(AcceleratorConfig::all_optimizations())
            .build()
            .expect("verify-bench specs are valid")
    })
    .collect();

    let reps = if quick_scope() { 20 } else { 40 };
    let mut programs = Vec::with_capacity(reps * specs.len());
    let dt_compile = time(|| {
        for _ in 0..reps {
            for s in &specs {
                programs.push(s.compile_program());
            }
        }
    });
    let mut violations = 0u64;
    let mut lines = 0u64;
    let dt_verify = time(|| {
        for (i, p) in programs.iter().enumerate() {
            let r = specs[i % specs.len()].verify_report(p);
            violations += r.violations.len() as u64;
            lines += r.lines;
        }
    });
    assert_eq!(violations, 0, "figure-grade programs must verify clean");
    assert!(
        dt_verify < 0.10 * dt_compile,
        "static verification must cost <10% of compilation: verify {:.4}s vs compile {:.4}s",
        dt_verify,
        dt_compile
    );
    println!(
        "verify.overhead: compile {:.3} ms, verify {:.3} ms ({:.1}% of compile) over {} programs",
        dt_compile * 1e3,
        dt_verify * 1e3,
        dt_verify / dt_compile.max(1e-12) * 100.0,
        programs.len()
    );
    rep.record_with(
        "verify.overhead",
        lines,
        dt_verify,
        0,
        vec![
            ("verify_violations", violations),
            ("programs_verified", programs.len() as u64),
            ("compile_wall_us", (dt_compile * 1e6) as u64),
            ("verify_wall_us", (dt_verify * 1e6) as u64),
        ],
    );
}

fn bench_engines(rep: &mut Reporter) {
    let scale = if quick_scope() { 9 } else { 11 };
    let g = generate(RmatParams::graph500(scale, 12, 42));
    let p = GraphProblem::new(ProblemKind::PageRank, &g);
    let mut native = NativeEngine::new();
    let dt_native = time(|| {
        native.run(&p, &g, 1).unwrap();
    });
    println!("engine.native_pr_step: {:.3} ms", dt_native * 1e3);
    rep.record("engine.native_pr_step", g.num_edges() as u64, dt_native, 0);
    match XlaEngine::from_repo_root() {
        Ok(mut xla) => {
            // warm-up compiles the executable
            xla.run(&p, &g, 1).unwrap();
            let dt_x = time(|| {
                xla.run(&p, &g, 1).unwrap();
            });
            println!(
                "engine.xla_pr_step:    {:.3} ms ({:.1}x native; interpret-mode Pallas scatter is O(N*M))",
                dt_x * 1e3,
                dt_x / dt_native
            );
            rep.record("engine.xla_pr_step", g.num_edges() as u64, dt_x, 0);
        }
        Err(e) => println!("engine.xla: skipped ({e})"),
    }
}

fn main() {
    // Args: cargo bench passes `--bench`; we also accept `--json <path>`.
    let mut json_path = std::env::var("GRAPHMEM_BENCH_JSON").ok();
    let args: Vec<String> = std::env::args().collect();
    let mut i = 1;
    while i < args.len() {
        if args[i] == "--json" && i + 1 < args.len() {
            json_path = Some(args[i + 1].clone());
            i += 1;
        }
        i += 1; // ignore everything else (e.g. `--bench` from cargo)
    }

    println!(
        "perf_hotpath — simulator throughput microbenches ({} scope)",
        if quick_scope() { "quick" } else { "full" }
    );
    let mut rep = Reporter { rows: Vec::new() };
    bench_dram_channel(&mut rep);
    bench_phase_driver(&mut rep);
    bench_driver_scratch(&mut rep);
    bench_end_to_end_sim(&mut rep);
    bench_sweep_mem_axis(&mut rep);
    bench_onchip(&mut rep);
    bench_advisor(&mut rep);
    bench_regraph_c32(&mut rep);
    bench_robust_faults(&mut rep);
    bench_serve_cold_vs_warm(&mut rep);
    bench_verify_overhead(&mut rep);
    bench_engines(&mut rep);
    rep.flush(json_path.as_deref());
}
