//! Bench: Fig. 2 — simulation (shape) error vs published numbers.
//!
//! Regenerates the paper's rows on the scaled workloads and times the
//! sweep. Scope via GRAPHMEM_SCOPE=quick|standard|full (default
//! standard).

use graphmem::coordinator::{experiment::bench_scope, run_experiment, Experiment};

fn main() {
    let scope = bench_scope();
    eprintln!("bench fig02_sim_error (scope {scope:?})");
    let t0 = std::time::Instant::now();
    let tables = run_experiment(Experiment::Fig02SimError, scope).expect("experiment");
    let dt = t0.elapsed();
    for t in &tables {
        println!("{}", t.render());
    }
    println!(
        "bench fig02_sim_error: {} table(s) in {:.2}s (scope {scope:?})",
        tables.len(),
        dt.as_secs_f64()
    );
}
