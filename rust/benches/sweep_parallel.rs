//! Bench: parallel sweep engine vs the serial path.
//!
//! Runs the Fig. 8-style product (4 accelerators x BFS/PR x scope
//! graphs, DDR4 x1, all optimizations) once serially and once through
//! the multi-threaded `Session`, reporting wall time and speedup.
//! Scope via GRAPHMEM_SCOPE=quick|standard|full (default standard).

use graphmem::accel::{AcceleratorConfig, AcceleratorKind};
use graphmem::algo::problem::ProblemKind;
use graphmem::coordinator::experiment::bench_scope;
use graphmem::sim::{Session, Sweep};

fn main() {
    let scope = bench_scope();
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(8);
    eprintln!("bench sweep_parallel (scope {scope:?}, {threads} threads)");

    let sweep = Sweep::new()
        .accelerators(AcceleratorKind::all())
        .graphs(scope.graphs())
        .problems([ProblemKind::Bfs, ProblemKind::PageRank])
        .configs([AcceleratorConfig::all_optimizations()]);
    let specs = sweep.specs().expect("specs");

    // Warm the process-wide dataset cache so generation cost doesn't
    // skew the serial-vs-parallel comparison.
    for g in scope.graphs() {
        let _ = g.load_shared();
    }

    let t0 = std::time::Instant::now();
    let serial: Vec<_> = specs.iter().map(|s| s.run()).collect();
    let t_serial = t0.elapsed().as_secs_f64();

    let session = Session::new();
    let t1 = std::time::Instant::now();
    let parallel = session.run_batch(&specs, threads);
    let t_parallel = t1.elapsed().as_secs_f64();

    assert_eq!(serial.len(), parallel.len());
    for (a, b) in serial.iter().zip(&parallel) {
        assert_eq!(a, b, "parallel sweep must match serial results");
    }

    println!(
        "bench sweep_parallel: {} specs  serial {t_serial:.2}s  parallel {t_parallel:.2}s  \
         speedup {:.2}x (scope {scope:?}, {threads} threads)",
        specs.len(),
        t_serial / t_parallel.max(1e-9),
    );
}
