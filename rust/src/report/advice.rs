//! Formatter for advisor output (`graphmem advise`): a compact
//! three-row choice table plus the full rationale lines. The
//! rationales name histogram evidence verbatim and run long, so they
//! are printed below the table rather than squeezed into cells.

use super::table::Table;
use crate::advisor::Recommendation;
use crate::dram::ChannelMode;

/// One row per decision axis: partitioning, placement, on-chip.
pub fn advice_table(rec: &Recommendation) -> Table {
    let mut t = Table::new(
        format!(
            "Advisor recommendation — {}/{}/{} (probe: {}{})",
            rec.accelerator,
            rec.workload_label,
            rec.problem,
            rec.probe_label,
            if rec.probe_sampled { ", sampled" } else { "" }
        ),
        &["choice", "recommendation", "predicted cost"],
    );
    t.row(vec![
        "partitioning".to_string(),
        format!(
            "{} (capacity {} values, {} partition(s))",
            rec.partitioning.scheme, rec.partitioning.capacity_values, rec.partitioning.partitions
        ),
        format!("{:.0} pass(es)", rec.partitioning.predicted_cost),
    ]);
    let mode = match rec.placement.mode {
        ChannelMode::Region => "region-placed",
        ChannelMode::InterleaveLine => "line-interleaved",
    };
    t.row(vec![
        "placement".to_string(),
        format!("{} channel(s), {mode}", rec.placement.channels),
        format!("{:.0} cycles", rec.placement.predicted_cost),
    ]);
    let onchip = match &rec.onchip.config {
        Some(cfg) => format!(
            "{} B scratchpad over {} region(s)",
            cfg.capacity_bytes(),
            cfg.regions().len()
        ),
        None => "none (streaming)".to_string(),
    };
    t.row(vec![
        "on-chip".to_string(),
        onchip,
        format!("{:.0} DRAM requests", rec.onchip.predicted_cost),
    ]);
    t
}

/// The per-choice rationales, one prefixed line each.
pub fn rationale_lines(rec: &Recommendation) -> Vec<String> {
    vec![
        format!("partitioning: {}", rec.partitioning.rationale),
        format!("placement: {}", rec.placement.rationale),
        format!("on-chip: {}", rec.onchip.rationale),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::AcceleratorKind;
    use crate::advisor::{OnChipChoice, PartitionChoice, PlacementChoice, RegionBudget};
    use crate::algo::problem::ProblemKind;
    use crate::onchip::OnChipConfig;
    use crate::partition::PartitionScheme;
    use crate::trace::Region;

    fn rec() -> Recommendation {
        Recommendation {
            accelerator: AcceleratorKind::AccuGraph,
            workload_label: "sd".to_string(),
            problem: ProblemKind::Bfs,
            probe_label: "AccuGraph/sd/BFS/ddr4x1".to_string(),
            probe_requests: 10_000,
            probe_sampled: true,
            partitioning: PartitionChoice {
                scheme: PartitionScheme::Horizontal,
                capacity_values: 2_048,
                partitions: 2,
                predicted_cost: 2.0,
                rationale: "edge region is 91.0% sequential".to_string(),
            },
            placement: PlacementChoice {
                channels: 1,
                mode: ChannelMode::InterleaveLine,
                predicted_cost: 123_456.0,
                rationale: "probe bus utilization 22.0%".to_string(),
            },
            onchip: OnChipChoice {
                config: Some(OnChipConfig::scratchpad(8_192, [Region::Vertices])),
                per_region: vec![RegionBudget {
                    region: Region::Vertices,
                    budget_bytes: 8_192,
                    predicted_hit_rate: 0.42,
                    predicted_saved_requests: 4_200,
                }],
                predicted_cost: 5_800.0,
                rationale: "reuse histogram places 4200 intervals within 128 lines".to_string(),
            },
        }
    }

    #[test]
    fn table_carries_all_three_choices() {
        let t = advice_table(&rec());
        assert_eq!(t.num_rows(), 3);
        let s = t.render();
        assert!(s.contains("horizontal"));
        assert!(s.contains("line-interleaved"));
        assert!(s.contains("8192 B scratchpad"));
        assert!(s.contains("sampled"));
        assert!(!t.to_csv().is_empty());
    }

    #[test]
    fn streaming_pick_renders_none() {
        let mut r = rec();
        r.onchip.config = None;
        r.onchip.per_region.clear();
        assert!(advice_table(&r).render().contains("none (streaming)"));
    }

    #[test]
    fn rationales_come_out_one_line_each() {
        let lines = rationale_lines(&rec());
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("partitioning: "));
        assert!(lines[2].contains("reuse histogram"));
    }
}
