//! Report generation: aligned text tables, CSV emit, and the figure
//! series formatters used by the bench harness and the CLI —
//! including the access-pattern tables of [`pattern`].

pub mod pattern;
pub mod table;

pub use pattern::{channel_table, onchip_table, pattern_tables, region_table, reuse_table};
pub use table::Table;
