//! Report generation: aligned text tables, CSV emit, and the figure
//! series formatters used by the bench harness and the CLI —
//! including the access-pattern tables of [`pattern`] and the advisor
//! recommendation formatter of [`advice`].

pub mod advice;
pub mod failure;
pub mod pattern;
pub mod table;

pub use advice::{advice_table, rationale_lines};
pub use failure::{failure_details, failure_table};
pub use pattern::{channel_table, onchip_table, pattern_tables, region_table, reuse_table};
pub use table::Table;
