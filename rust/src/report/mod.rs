//! Report generation: aligned text tables, CSV emit, and the figure
//! series formatters used by the bench harness and the CLI.

pub mod table;

pub use table::Table;
