//! Formatter for failed sweep points (`graphmem sweep --keep-going`):
//! one row per failure with the spec label, the error kind slug and
//! the full diagnostic. Stall diagnostics run long (per-stream
//! cursors, per-channel loads), so the table keeps a one-line digest
//! and [`failure_details`] carries the full rendering below it.

use super::table::Table;
use crate::sim::{SweepOutcome, SweepTrial};

/// One row per failed trial: `spec | kind | detail`. Returns `None`
/// when every trial succeeded (print nothing instead of an empty
/// table).
pub fn failure_table(trials: &[SweepTrial]) -> Option<Table> {
    let failed: Vec<_> = trials
        .iter()
        .filter_map(|t| t.outcome.error().map(|e| (t, e)))
        .collect();
    if failed.is_empty() {
        return None;
    }
    let mut t = Table::new(
        format!("Failed sweep points ({} of {})", failed.len(), trials.len()),
        &["spec", "kind", "detail"],
    );
    for (trial, err) in failed {
        // First line only: multi-line diagnostics go to
        // `failure_details`, not into a table cell.
        let digest = err.to_string();
        let digest = digest.lines().next().unwrap_or_default().to_string();
        t.row(vec![trial.spec.label(), err.kind().to_string(), digest]);
    }
    Some(t)
}

/// Full diagnostics for every failed trial, one block per failure —
/// stall reports include their per-stream / per-channel breakdown
/// here.
pub fn failure_details(trials: &[SweepTrial]) -> Vec<String> {
    trials
        .iter()
        .filter_map(|t| match &t.outcome {
            SweepOutcome::Failed(err) => Some(format!("{}: {err}", t.spec.label())),
            SweepOutcome::Ok(_) => None,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::robust::SimError;
    use crate::sim::{SimSpec, SweepOutcome, SweepTrial};
    use crate::accel::AcceleratorKind;
    use crate::algo::problem::ProblemKind;
    use crate::graph::DatasetId;

    fn spec() -> SimSpec {
        SimSpec::builder()
            .accelerator(AcceleratorKind::HitGraph)
            .graph(DatasetId::Sd)
            .problem(ProblemKind::Bfs)
            .build()
            .unwrap()
    }

    #[test]
    fn all_ok_renders_nothing() {
        let trials = vec![SweepTrial {
            spec: spec(),
            outcome: SweepOutcome::Ok(spec().run()),
        }];
        assert!(failure_table(&trials).is_none());
        assert!(failure_details(&trials).is_empty());
    }

    #[test]
    fn failures_render_label_kind_and_detail() {
        let trials = vec![
            SweepTrial {
                spec: spec(),
                outcome: SweepOutcome::Failed(SimError::BudgetExceeded {
                    resource: crate::robust::BudgetResource::Cycles,
                    limit: 100,
                    observed: 101,
                }),
            },
            SweepTrial {
                spec: spec(),
                outcome: SweepOutcome::Failed(SimError::Panicked {
                    message: "boom".to_string(),
                }),
            },
        ];
        let t = failure_table(&trials).expect("two failures, one table");
        assert_eq!(t.num_rows(), 2);
        let rendered = t.render();
        assert!(rendered.contains("HitGraph/sd/BFS/ddr4x1"));
        assert!(rendered.contains("budget-exceeded"));
        assert!(rendered.contains("panicked"));
        let details = failure_details(&trials);
        assert_eq!(details.len(), 2);
        assert!(details[1].contains("boom"));
    }
}
