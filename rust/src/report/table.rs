//! Minimal aligned-text table builder (the offline crate set has no
//! table crate). Also emits CSV.

/// A simple column-aligned table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Table {
        Table {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..cols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (c, cell) in cells.iter().enumerate() {
                if c > 0 {
                    line.push_str("  ");
                }
                // left-align first column, right-align the rest
                if c == 0 {
                    line.push_str(&format!("{:<width$}", cell, width = widths[c]));
                } else {
                    line.push_str(&format!("{:>width$}", cell, width = widths[c]));
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (cols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (comma-separated, quotes only when needed).
    pub fn to_csv(&self) -> String {
        let esc = |s: &String| -> String {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = String::new();
        out.push_str(&self.header.iter().map(esc).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["graph", "MTEPS"]);
        t.row(vec!["sd".into(), "123.4".into()]);
        t.row(vec!["longname".into(), "7.0".into()]);
        let s = t.render();
        assert!(s.contains("## Demo"));
        assert!(s.contains("graph"));
        let lines: Vec<&str> = s.lines().collect();
        // all data lines same width
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_wrong_width() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only one".into()]);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["with,comma".into(), "with\"quote".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"with,comma\""));
        assert!(csv.contains("\"with\"\"quote\""));
    }
}
