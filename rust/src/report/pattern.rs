//! Access-pattern table formatters: render an
//! [`AccessPatternSummary`] as the Fig. 8/10/11-style tables the
//! paper uses to compare accelerators (per-region traffic breakdown,
//! sequentiality classification, row-buffer locality, per-channel
//! reuse).

use super::table::Table;
use crate::onchip::OnChipStats;
use crate::trace::{AccessPatternSummary, ChannelSummary, Histogram, Region};

/// Widest reuse-interval table we render: channel counts beyond this
/// (HBM2 pseudo-channel stacks go to 32) are split into several
/// 8-column blocks so the table stays terminal-sized.
pub(crate) const REUSE_TABLE_CHANNELS: usize = 8;

/// Percentage table cell: `part / whole` to one decimal, `-` for an
/// empty denominator. Shared by every pattern table in the crate.
pub(crate) fn pct(part: u64, whole: u64) -> String {
    if whole == 0 {
        "-".to_string()
    } else {
        format!("{:.1}", 100.0 * part as f64 / whole as f64)
    }
}

/// Per-region traffic and pattern breakdown (one row per region that
/// saw traffic) — counts, bytes, sequential/strided/random mix, mean
/// sequential-run length, and the in-order row-buffer outcome mix.
pub fn region_table(label: &str, s: &AccessPatternSummary) -> Table {
    let mut t = Table::new(
        format!("Access patterns by region — {label}"),
        &[
            "region", "reads", "writes", "bytes", "share%", "seq%", "strided%", "random%",
            "run", "hit%", "miss%", "conf%", "lines", "reuse",
        ],
    );
    let total_bytes = s.total_bytes();
    for r in Region::all() {
        let reg = s.region(r);
        let n = reg.requests();
        if n == 0 {
            continue;
        }
        t.row(vec![
            r.name().to_string(),
            reg.reads.to_string(),
            reg.writes.to_string(),
            reg.bytes.to_string(),
            pct(reg.bytes, total_bytes),
            pct(reg.sequential, n),
            pct(reg.strided, n),
            pct(reg.random, n),
            format!("{:.1}", reg.mean_run_length()),
            pct(reg.row_hits, n),
            pct(reg.row_misses, n),
            pct(reg.row_conflicts, n),
            reg.distinct_lines.to_string(),
            reg.reuse.count().to_string(),
        ]);
    }
    t
}

/// On-chip buffer roll-up (see [`crate::onchip`]): per cached region,
/// how much traffic the BRAM retired (hits) vs passed to DRAM
/// (misses), plus fills. The companion of
/// [`crate::trace::RegionSummary::predicted_hit_rate`] — the CLI's
/// `analyze --onchip` prints both sides of the loop.
pub fn onchip_table(label: &str, s: &OnChipStats) -> Table {
    let mut t = Table::new(
        format!(
            "On-chip buffer ({} lines) — {label}",
            s.capacity_lines()
        ),
        &["region", "hits", "misses", "fills", "hit%"],
    );
    for r in Region::all() {
        let n = s.region_accesses(r);
        if n == 0 {
            continue;
        }
        t.row(vec![
            r.name().to_string(),
            s.region_hits(r).to_string(),
            s.region_misses(r).to_string(),
            s.region_fills(r).to_string(),
            pct(s.region_hits(r), n),
        ]);
    }
    t.row(vec![
        "total".to_string(),
        s.hits_total().to_string(),
        s.misses_total().to_string(),
        s.fills_total().to_string(),
        pct(s.hits_total(), s.hits_total() + s.misses_total()),
    ]);
    t
}

/// Per-channel roll-up: traffic balance, row locality and reuse
/// (Fig. 11(b) / Fig. 12 companion).
pub fn channel_table(label: &str, s: &AccessPatternSummary) -> Table {
    let mut t = Table::new(
        format!("Per-channel roll-up — {label}"),
        &[
            "channel", "reads", "writes", "hit%", "miss%", "conf%", "lines", "reuse",
            "mean gap",
        ],
    );
    for c in &s.channels {
        let n = c.requests();
        t.row(vec![
            c.channel.to_string(),
            c.reads.to_string(),
            c.writes.to_string(),
            pct(c.row_hits, n),
            pct(c.row_misses, n),
            pct(c.row_conflicts, n),
            c.distinct_lines.to_string(),
            c.reuse.count().to_string(),
            format!("{:.0}", c.reuse.mean()),
        ]);
    }
    t
}

/// Reuse-interval histogram, one column per channel: how many
/// same-channel accesses pass between two touches of the same cache
/// line (small intervals = cache-friendly reuse; huge intervals =
/// streaming re-reads).
pub fn reuse_table(label: &str, s: &AccessPatternSummary) -> Table {
    reuse_table_block(label, &s.channels)
}

fn reuse_table_block(label: &str, channels: &[ChannelSummary]) -> Table {
    let max_bucket = channels
        .iter()
        .map(|c| c.reuse.buckets().len())
        .max()
        .unwrap_or(0);
    let mut header: Vec<String> = vec!["reuse interval".to_string()];
    for c in channels {
        header.push(format!("ch{}", c.channel));
    }
    let header_refs: Vec<&str> = header.iter().map(|h| h.as_str()).collect();
    let mut t = Table::new(
        format!("Reuse-interval histogram — {label}"),
        &header_refs,
    );
    for k in 0..max_bucket {
        let mut row = vec![format!("< {}", Histogram::bucket_limit(k))];
        let mut any = false;
        for c in channels {
            let v = c.reuse.buckets().get(k).copied().unwrap_or(0);
            any |= v > 0;
            row.push(v.to_string());
        }
        if any {
            t.row(row);
        }
    }
    t
}

/// The full table set for one run. Wide channel configurations (HBM2
/// pseudo-channels, up to 32) get one reuse table per block of
/// [`REUSE_TABLE_CHANNELS`] channels instead of a 33-column monster.
pub fn pattern_tables(label: &str, s: &AccessPatternSummary) -> Vec<Table> {
    let mut tables = vec![region_table(label, s), channel_table(label, s)];
    if s.channels.len() <= REUSE_TABLE_CHANNELS {
        tables.push(reuse_table(label, s));
    } else {
        for block in s.channels.chunks(REUSE_TABLE_CHANNELS) {
            let first = block.first().map(|c| c.channel).unwrap_or(0);
            let last = block.last().map(|c| c.channel).unwrap_or(0);
            tables.push(reuse_table_block(
                &format!("{label} ch{first}-{last}"),
                block,
            ));
        }
    }
    tables
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::{ChannelMode, MemKind, MemTech};
    use crate::trace::{AccessPatternAnalyzer, TraceEvent};

    fn summary() -> AccessPatternSummary {
        let mut a = AccessPatternAnalyzer::new(MemTech::Ddr4.spec(2), ChannelMode::InterleaveLine);
        for i in 0..32u64 {
            a.observe(&TraceEvent {
                addr: i * 64,
                kind: MemKind::Read,
                region: Region::Edges,
                arrival: i,
                channel: (i % 2) as usize,
            });
        }
        // One reused vertex line on channel 0.
        for _ in 0..2 {
            a.observe(&TraceEvent {
                addr: 1 << 20,
                kind: MemKind::Write,
                region: Region::Vertices,
                arrival: 99,
                channel: 0,
            });
        }
        a.finish()
    }

    #[test]
    fn tables_render_nonzero_regions_only() {
        let s = summary();
        let t = region_table("test", &s);
        let txt = t.render();
        assert!(txt.contains("edges"));
        assert!(txt.contains("vertices"));
        assert!(!txt.contains("updates"), "{txt}");
        assert_eq!(t.num_rows(), 2);
    }

    #[test]
    fn channel_and_reuse_tables_cover_all_channels() {
        let s = summary();
        let ct = channel_table("test", &s);
        assert_eq!(ct.num_rows(), 2);
        let rt = reuse_table("test", &s);
        // the repeated vertex line produced exactly one reuse record
        assert!(rt.render().contains("ch0"));
        assert_eq!(pattern_tables("x", &s).len(), 3);
    }

    #[test]
    fn wide_channel_configs_split_the_reuse_table_into_blocks() {
        // 32 HBM2 pseudo-channels: the reuse histogram must come out
        // as four 8-channel blocks, not one 33-column table.
        let mut a = AccessPatternAnalyzer::new(MemTech::Hbm2.spec(32), ChannelMode::Region);
        for i in 0..64u64 {
            a.observe(&TraceEvent {
                addr: i * 64,
                kind: MemKind::Read,
                region: Region::Edges,
                arrival: i,
                channel: (i % 32) as usize,
            });
        }
        let s = a.finish();
        assert_eq!(s.channels.len(), 32);
        let tables = pattern_tables("wide", &s);
        assert_eq!(tables.len(), 2 + 32 / REUSE_TABLE_CHANNELS);
        let rendered: Vec<String> = tables.iter().map(|t| t.render()).collect();
        assert!(rendered[2].contains("ch0-7"), "{}", rendered[2]);
        assert!(rendered[5].contains("ch24-31"), "{}", rendered[5]);
        // The per-channel roll-up still carries every channel.
        assert!(rendered[1].contains("31"), "{}", rendered[1]);
    }

    #[test]
    fn pct_handles_zero_denominator() {
        assert_eq!(pct(5, 0), "-");
        assert_eq!(pct(1, 4), "25.0");
    }

    #[test]
    fn onchip_table_covers_cached_regions_plus_total() {
        use crate::onchip::{OnChipBuffer, OnChipConfig};
        let mut buf = OnChipBuffer::new(OnChipConfig::vertex_cache(4 * 64));
        for addr in [0u64, 0, 64, 0] {
            buf.access(addr, MemKind::Read, Region::Vertices, 0);
        }
        let t = onchip_table("test", buf.stats());
        let txt = t.render();
        assert!(txt.contains("vertices"), "{txt}");
        assert!(txt.contains("total"), "{txt}");
        assert!(!txt.contains("edges"), "uncached regions are omitted: {txt}");
        assert_eq!(t.num_rows(), 2);
    }
}
