//! The experiment registry: one entry per figure/table of the paper's
//! evaluation. Each experiment expresses its runs as typed
//! [`SimSpec`]s, prefetches the full set in parallel through a shared
//! [`Session`] (a declarative [`Sweep`] wherever the runs form a
//! cartesian product), then renders the same rows/series the paper
//! reports from the memoized results — plus (where meaningful) a shape
//! comparison against the embedded published numbers.

use super::paper;
use crate::accel::{AcceleratorConfig, AcceleratorKind, Optimization};
use crate::algo::problem::ProblemKind;
use crate::dram::MemTech;
use crate::trace::Region;
use crate::graph::datasets::DatasetId;
use crate::graph::properties::GraphProperties;
use crate::report::{failure_table, Table};
use crate::sim::{Session, SimReport, SimSpec, Sweep, SweepOutcome, SweepTrial};
use crate::util::stats;
use anyhow::{anyhow, Result};

/// Which graphs to sweep. The paper always uses all 12; `Quick` and
/// `Standard` keep CLI/bench turnaround sane.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scope {
    /// sd, db, yt, wt
    Quick,
    /// + pk, lj, bk, rd, r21
    Standard,
    /// all 12 graphs of Tab. 2
    Full,
}

impl Scope {
    pub fn parse(s: &str) -> Option<Scope> {
        match s {
            "quick" => Some(Scope::Quick),
            "standard" => Some(Scope::Standard),
            "full" => Some(Scope::Full),
            _ => None,
        }
    }

    pub fn graphs(self) -> Vec<DatasetId> {
        match self {
            Scope::Quick => vec![DatasetId::Sd, DatasetId::Db, DatasetId::Yt, DatasetId::Wt],
            Scope::Standard => vec![
                DatasetId::Sd,
                DatasetId::Db,
                DatasetId::Yt,
                DatasetId::Pk,
                DatasetId::Wt,
                DatasetId::Lj,
                DatasetId::Bk,
                DatasetId::Rd,
                DatasetId::R21,
            ],
            Scope::Full => paper::GRAPHS.to_vec(),
        }
    }

    /// The Fig. 12/13 deep-dive subset, restricted to this scope where
    /// possible (rd is essential for the skipping effects).
    pub fn ablation_graphs(self) -> Vec<DatasetId> {
        match self {
            Scope::Quick => vec![DatasetId::Db, DatasetId::Rd],
            _ => paper::ABLATION_GRAPHS.to_vec(),
        }
    }
}

/// All experiments (figures and tables of the evaluation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Experiment {
    Fig02SimError,
    Fig08Tab4Mteps,
    Fig09Metrics,
    Fig10Skewness,
    Fig11Tab6Dram,
    Fig12Tab7Channels,
    Fig13Tab8Opts,
    Fig14Degree,
    Tab5Weighted,
    /// Per-region access-pattern comparison (the trace-analysis
    /// subsystem run across accelerators; Figs. 8–11 companion).
    Patterns,
}

impl Experiment {
    pub fn parse(s: &str) -> Option<Experiment> {
        match s.to_ascii_lowercase().as_str() {
            "fig02" | "fig2" | "sim-error" => Some(Experiment::Fig02SimError),
            "fig08" | "fig8" | "tab4" | "mteps" => Some(Experiment::Fig08Tab4Mteps),
            "fig09" | "fig9" | "metrics" => Some(Experiment::Fig09Metrics),
            "fig10" | "skewness" => Some(Experiment::Fig10Skewness),
            "fig11" | "tab6" | "dram" => Some(Experiment::Fig11Tab6Dram),
            "fig12" | "tab7" | "channels" => Some(Experiment::Fig12Tab7Channels),
            "fig13" | "tab8" | "opts" => Some(Experiment::Fig13Tab8Opts),
            "fig14" | "degree" => Some(Experiment::Fig14Degree),
            "tab5" | "weighted" => Some(Experiment::Tab5Weighted),
            "patterns" | "pattern" | "access" => Some(Experiment::Patterns),
            _ => None,
        }
    }

    pub fn all() -> [Experiment; 10] {
        [
            Experiment::Fig02SimError,
            Experiment::Fig08Tab4Mteps,
            Experiment::Fig09Metrics,
            Experiment::Fig10Skewness,
            Experiment::Fig11Tab6Dram,
            Experiment::Fig12Tab7Channels,
            Experiment::Fig13Tab8Opts,
            Experiment::Fig14Degree,
            Experiment::Tab5Weighted,
            Experiment::Patterns,
        ]
    }

    pub fn id(self) -> &'static str {
        match self {
            Experiment::Fig02SimError => "fig02",
            Experiment::Fig08Tab4Mteps => "fig08",
            Experiment::Fig09Metrics => "fig09",
            Experiment::Fig10Skewness => "fig10",
            Experiment::Fig11Tab6Dram => "fig11",
            Experiment::Fig12Tab7Channels => "fig12",
            Experiment::Fig13Tab8Opts => "fig13",
            Experiment::Fig14Degree => "fig14",
            Experiment::Tab5Weighted => "tab5",
            Experiment::Patterns => "patterns",
        }
    }

    pub fn description(self) -> &'static str {
        match self {
            Experiment::Fig02SimError => "shape error vs the paper's published runtimes",
            Experiment::Fig08Tab4Mteps => "MTEPS comparison, 4 accelerators x BFS/PR/WCC (Tab. 4)",
            Experiment::Fig09Metrics => "critical performance metrics for BFS",
            Experiment::Fig10Skewness => "MREPS by degree-distribution skewness",
            Experiment::Fig11Tab6Dram => "DDR3/HBM speedup over DDR4 + row-buffer mix (Tab. 6)",
            Experiment::Fig12Tab7Channels => "channel scalability, HitGraph/ThunderGP (Tab. 7)",
            Experiment::Fig13Tab8Opts => "optimization ablation speedups (Tab. 8)",
            Experiment::Fig14Degree => "MREPS by average degree",
            Experiment::Tab5Weighted => "SSSP/SpMV runtimes, HitGraph/ThunderGP (Tab. 5)",
            Experiment::Patterns => "per-region access-pattern comparison (Figs. 8-11 companion)",
        }
    }
}

/// Scope for `cargo bench` runs: `GRAPHMEM_SCOPE=quick|standard|full`
/// (default `standard` — every figure's qualitative shape is visible
/// there; `full` adds the three heaviest graphs or/tw/r24).
pub fn bench_scope() -> Scope {
    std::env::var("GRAPHMEM_SCOPE")
        .ok()
        .and_then(|s| Scope::parse(&s))
        .unwrap_or(Scope::Standard)
}

/// Run one experiment; returns rendered tables. All simulations are
/// prefetched in parallel through a per-call [`Session`].
pub fn run_experiment(exp: Experiment, scope: Scope) -> Result<Vec<Table>> {
    let session = Session::new();
    run_experiment_with(&session, exp, scope)
}

/// Run one experiment against a caller-provided session, sharing its
/// memoized runs with other experiments (Fig. 8's BFS runs feed
/// Figs. 9, 10 and 14, for example).
pub fn run_experiment_with(
    session: &Session,
    exp: Experiment,
    scope: Scope,
) -> Result<Vec<Table>> {
    match exp {
        Experiment::Fig02SimError => fig02(session, scope),
        Experiment::Fig08Tab4Mteps => fig08(session, scope),
        Experiment::Fig09Metrics => fig09(session, scope),
        Experiment::Fig10Skewness => fig10(session, scope),
        Experiment::Fig11Tab6Dram => fig11(session, scope),
        Experiment::Fig12Tab7Channels => fig12(session, scope),
        Experiment::Fig13Tab8Opts => fig13(session, scope),
        Experiment::Fig14Degree => fig14(session, scope),
        Experiment::Tab5Weighted => tab5(session, scope),
        Experiment::Patterns => patterns_exp(session, scope),
    }
}

fn all_opt() -> AcceleratorConfig {
    AcceleratorConfig::all_optimizations()
}

/// Build one typed spec (experiment combinations are valid by
/// construction; errors here indicate a registry bug).
fn spec(
    kind: AcceleratorKind,
    g: DatasetId,
    problem: ProblemKind,
    mem: MemTech,
    channels: usize,
    cfg: &AcceleratorConfig,
) -> Result<SimSpec> {
    Ok(SimSpec::builder()
        .accelerator(kind)
        .graph(g)
        .problem(problem)
        .mem(mem)
        .channels(channels)
        .config(cfg.clone())
        .build()?)
}

/// Run (or fetch) one spec through the session.
fn sim(
    session: &Session,
    kind: AcceleratorKind,
    g: DatasetId,
    problem: ProblemKind,
    mem: MemTech,
    channels: usize,
    cfg: &AcceleratorConfig,
) -> Result<SimReport> {
    Ok(session.run(&spec(kind, g, problem, mem, channels, cfg)?))
}

/// Materialize a sweep's product in parallel into the session cache;
/// the serial table-building loops below then hit memoized results.
fn prefetch(session: &Session, sweep: &Sweep) -> Result<()> {
    let specs = sweep.specs()?;
    session.run_all(&specs);
    Ok(())
}

const PROBLEMS_FIG8: [ProblemKind; 3] =
    [ProblemKind::Bfs, ProblemKind::PageRank, ProblemKind::Wcc];

/// The paper's core figure matrix (Fig. 8 / Tab. 4, whose BFS column
/// also feeds Figs. 2, 9, 10 and 14): every accelerator × every graph
/// in `scope` × BFS/PR/WCC on DDR4 single-channel, all optimizations.
/// `graphmem serve --warm` precompiles exactly this set so a fresh
/// daemon answers figure-grade requests without first-touch latency.
pub fn figure_matrix_specs(scope: Scope) -> Result<Vec<SimSpec>> {
    Ok(Sweep::new()
        .accelerators(AcceleratorKind::all())
        .graphs(scope.graphs())
        .problems(PROBLEMS_FIG8)
        .configs([all_opt()])
        .specs()?)
}

// ---------------------------------------------------------------------------
// Fig. 8 / Tab. 4 — MTEPS (and runtimes) on DDR4 single-channel
// ---------------------------------------------------------------------------

fn fig08(session: &Session, scope: Scope) -> Result<Vec<Table>> {
    let cfg = all_opt();
    prefetch(
        session,
        &Sweep::new()
            .accelerators(AcceleratorKind::all())
            .graphs(scope.graphs())
            .problems(PROBLEMS_FIG8)
            .configs([cfg.clone()]),
    )?;
    let mut mteps = Table::new(
        "Fig. 8 — MTEPS by graph and problem (DDR4, single-channel)",
        &[
            "graph", "AG:BFS", "AG:PR", "AG:WCC", "FG:BFS", "FG:PR", "FG:WCC", "HG:BFS", "HG:PR",
            "HG:WCC", "TGP:BFS", "TGP:PR", "TGP:WCC",
        ],
    );
    let mut runtime = Table::new(
        "Tab. 4 — runtimes in seconds (scaled workloads)",
        &[
            "graph", "AG:BFS", "AG:PR", "AG:WCC", "FG:BFS", "FG:PR", "FG:WCC", "HG:BFS", "HG:PR",
            "HG:WCC", "TGP:BFS", "TGP:PR", "TGP:WCC",
        ],
    );
    for g in scope.graphs() {
        let mut mrow = vec![g.to_string()];
        let mut rrow = vec![g.to_string()];
        for kind in AcceleratorKind::all() {
            for problem in PROBLEMS_FIG8 {
                let r = sim(session, kind, g, problem, MemTech::Ddr4, 1, &cfg)?;
                mrow.push(format!("{:.1}", r.mteps()));
                rrow.push(format!("{:.5}", r.seconds));
            }
        }
        mteps.row(mrow);
        runtime.row(rrow);
    }
    Ok(vec![mteps, runtime])
}

// ---------------------------------------------------------------------------
// Fig. 2 — shape error vs the paper's published numbers
// ---------------------------------------------------------------------------

/// Because our workloads are ~1/64-scale stand-ins, absolute runtimes
/// are incomparable; instead we test the paper's central claim —
/// *comparability across accelerators*: within each (graph, problem),
/// every accelerator's runtime is divided by the four-system geometric
/// mean, and the percentage error of our share vs the paper's share is
/// reported. 0 % means "who wins, by what factor" matches the paper
/// exactly; graph-scale and diameter effects cancel because they hit
/// all four systems alike.
fn fig02(session: &Session, scope: Scope) -> Result<Vec<Table>> {
    let cfg = all_opt();
    let graphs = scope.graphs();
    // Only systems with published Tab. 4 rows can be shape-compared;
    // the rest (ReGraph) are excluded with a typed failure row instead
    // of aborting the whole experiment.
    let probe_graph = *graphs.first().ok_or_else(|| anyhow!("empty scope"))?;
    let mut kinds = Vec::new();
    let mut excluded = Vec::new();
    for kind in AcceleratorKind::all() {
        match paper::tab4_runtime_checked(kind, probe_graph, ProblemKind::Bfs) {
            Ok(_) => kinds.push(kind),
            Err(err) => excluded.push(SweepTrial {
                spec: spec(kind, probe_graph, ProblemKind::Bfs, MemTech::Ddr4, 1, &cfg)?,
                outcome: SweepOutcome::Failed(err),
            }),
        }
    }
    prefetch(
        session,
        &Sweep::new()
            .accelerators(kinds.iter().copied())
            .graphs(graphs.clone())
            .problems(PROBLEMS_FIG8)
            .configs([cfg.clone()]),
    )?;
    let mut t = Table::new(
        "Fig. 2 — accelerator-share error vs published runtimes (%)",
        &["accelerator", "BFS", "PR", "WCC", "mean"],
    );
    // errs[kind][problem] -> Vec of per-graph share errors
    let mut errs = vec![vec![Vec::new(); PROBLEMS_FIG8.len()]; kinds.len()];
    for g in &graphs {
        for (pi, problem) in PROBLEMS_FIG8.iter().enumerate() {
            let mut ours = Vec::new();
            let mut theirs = Vec::new();
            for &kind in &kinds {
                let r = sim(session, kind, *g, *problem, MemTech::Ddr4, 1, &cfg)?;
                let p = paper::tab4_runtime_checked(kind, *g, *problem)
                    .map_err(|e| anyhow!("{e}"))?;
                ours.push(r.seconds);
                theirs.push(p);
            }
            let go = stats::geo_mean(&ours);
            let gt = stats::geo_mean(&theirs);
            for (ki, _) in kinds.iter().enumerate() {
                errs[ki][pi].push(stats::pct_error(ours[ki] / go, theirs[ki] / gt));
            }
        }
    }
    let mut grand = Vec::new();
    for (ki, kind) in kinds.iter().enumerate() {
        let mut row = vec![kind.name().to_string()];
        let mut per_accel = Vec::new();
        for pi in 0..PROBLEMS_FIG8.len() {
            let mean = stats::mean(&errs[ki][pi]);
            row.push(format!("{mean:.1}"));
            per_accel.push(mean);
            grand.push(mean);
        }
        row.push(format!("{:.1}", stats::mean(&per_accel)));
        t.row(row);
    }
    t.row(vec![
        "MEAN".into(),
        String::new(),
        String::new(),
        String::new(),
        format!("{:.1}", stats::mean(&grand)),
    ]);
    let mut note = Table::new(
        "Reference: paper's own simulation-vs-hardware mean error",
        &["source", "mean error %"],
    );
    note.row(vec![
        "Dann et al. (Fig. 2)".into(),
        format!("{:.2}", paper::PAPER_MEAN_ERROR_PCT),
    ]);
    let mut tables = vec![t, note];
    // Excluded systems surface through the standard failure path, one
    // typed row each, instead of silently vanishing (or, before this,
    // aborting the whole figure with an anyhow error).
    if let Some(excl) = failure_table(&excluded) {
        tables.push(excl);
    }
    Ok(tables)
}

// ---------------------------------------------------------------------------
// Fig. 9 — critical performance metrics (BFS)
// ---------------------------------------------------------------------------

fn fig09(session: &Session, scope: Scope) -> Result<Vec<Table>> {
    let cfg = all_opt();
    prefetch(
        session,
        &Sweep::new()
            .accelerators(AcceleratorKind::all())
            .graphs(scope.graphs())
            .problems([ProblemKind::Bfs])
            .configs([cfg.clone()]),
    )?;
    let mut tables = Vec::new();
    let metrics: [(&str, fn(&SimReport) -> f64); 4] = [
        ("Fig. 9(a) — iterations", |r| r.metrics.iterations as f64),
        ("Fig. 9(b) — bytes per edge", |r| r.bytes_per_edge()),
        ("Fig. 9(c) — values read per iteration", |r| {
            r.values_read_per_iter()
        }),
        ("Fig. 9(d) — edges read per iteration", |r| {
            r.edges_read_per_iter()
        }),
    ];
    for (title, f) in metrics {
        let mut t = Table::new(
            format!("{title} (BFS, DDR4 single-channel)"),
            &["graph", "AccuGraph", "ForeGraph", "HitGraph", "ThunderGP"],
        );
        for g in scope.graphs() {
            let mut row = vec![g.to_string()];
            for kind in AcceleratorKind::all() {
                let r = sim(session, kind, g, ProblemKind::Bfs, MemTech::Ddr4, 1, &cfg)?;
                row.push(format!("{:.1}", f(&r)));
            }
            t.row(row);
        }
        tables.push(t);
    }
    Ok(tables)
}

// ---------------------------------------------------------------------------
// Fig. 10 / Fig. 14 — MREPS by skewness / average degree
// ---------------------------------------------------------------------------

fn mreps_by_property(
    session: &Session,
    scope: Scope,
    title: &str,
    prop: fn(&GraphProperties) -> f64,
    prop_name: &str,
) -> Result<Vec<Table>> {
    let cfg = all_opt();
    prefetch(
        session,
        &Sweep::new()
            .accelerators(AcceleratorKind::all())
            .graphs(scope.graphs())
            .problems([ProblemKind::Bfs])
            .configs([cfg.clone()]),
    )?;
    let mut entries: Vec<(f64, DatasetId)> = Vec::new();
    for g in scope.graphs() {
        let p = GraphProperties::compute(&g.load_shared());
        entries.push((prop(&p), g));
    }
    entries.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut t = Table::new(
        title,
        &[
            "graph", prop_name, "AccuGraph", "ForeGraph", "HitGraph", "ThunderGP",
        ],
    );
    for (val, g) in entries {
        let mut row = vec![g.to_string(), format!("{val:.2}")];
        for kind in AcceleratorKind::all() {
            let r = sim(session, kind, g, ProblemKind::Bfs, MemTech::Ddr4, 1, &cfg)?;
            row.push(format!("{:.1}", r.mreps()));
        }
        t.row(row);
    }
    Ok(vec![t])
}

fn fig10(session: &Session, scope: Scope) -> Result<Vec<Table>> {
    mreps_by_property(
        session,
        scope,
        "Fig. 10 — MREPS by skewness of degree distribution (BFS)",
        |p| p.degree_skewness,
        "skewness",
    )
}

fn fig14(session: &Session, scope: Scope) -> Result<Vec<Table>> {
    mreps_by_property(
        session,
        scope,
        "Fig. 14 — MREPS by average degree (BFS)",
        |p| p.avg_degree,
        "D_avg",
    )
}

// ---------------------------------------------------------------------------
// Fig. 11 / Tab. 6 — DRAM technology comparison
// ---------------------------------------------------------------------------

fn fig11(session: &Session, scope: Scope) -> Result<Vec<Table>> {
    let cfg = all_opt();
    prefetch(
        session,
        &Sweep::new()
            .accelerators(AcceleratorKind::all())
            .graphs(scope.graphs())
            .problems([ProblemKind::Bfs])
            .mem_techs(MemTech::all())
            .configs([cfg.clone()]),
    )?;
    let mut speedup = Table::new(
        "Fig. 11(a) — DDR3 and HBM speedup over DDR4 (BFS, single-channel)",
        &[
            "graph", "AG:DDR3", "AG:HBM", "FG:DDR3", "FG:HBM", "HG:DDR3", "HG:HBM", "TGP:DDR3",
            "TGP:HBM",
        ],
    );
    let mut util = Table::new(
        "Fig. 11(b) — bandwidth utilization % (hit/miss/conflict mix), DDR4 BFS",
        &["graph", "accel", "util%", "hit%", "miss%", "conflict%"],
    );
    for g in scope.graphs() {
        let mut row = vec![g.to_string()];
        for kind in AcceleratorKind::all() {
            let d4 = sim(session, kind, g, ProblemKind::Bfs, MemTech::Ddr4, 1, &cfg)?;
            let d3 = sim(session, kind, g, ProblemKind::Bfs, MemTech::Ddr3, 1, &cfg)?;
            let hb = sim(session, kind, g, ProblemKind::Bfs, MemTech::Hbm, 1, &cfg)?;
            row.push(format!("{:.2}", d4.seconds / d3.seconds));
            row.push(format!("{:.2}", d4.seconds / hb.seconds));
            let (h, m, c) = d4.row_mix();
            util.row(vec![
                g.to_string(),
                kind.name().to_string(),
                format!("{:.1}", 100.0 * d4.bus_utilization),
                format!("{:.1}", 100.0 * h),
                format!("{:.1}", 100.0 * m),
                format!("{:.1}", 100.0 * c),
            ]);
        }
        speedup.row(row);
    }
    Ok(vec![speedup, util])
}

// ---------------------------------------------------------------------------
// Fig. 12 / Tab. 7 — channel scalability
// ---------------------------------------------------------------------------

fn fig12(session: &Session, scope: Scope) -> Result<Vec<Table>> {
    let cfg = all_opt();
    let kinds = [AcceleratorKind::HitGraph, AcceleratorKind::ThunderGp];
    prefetch(
        session,
        &Sweep::new()
            .accelerators(kinds)
            .graphs(scope.ablation_graphs())
            .problems([ProblemKind::Bfs])
            .mem_techs(MemTech::all())
            .channels([1, 2, 4])
            .configs([cfg.clone()]),
    )?;
    prefetch(
        session,
        &Sweep::new()
            .accelerators(kinds)
            .graphs(scope.ablation_graphs())
            .problems([ProblemKind::Bfs])
            .mem_techs([MemTech::Hbm])
            .channels([8])
            .configs([cfg.clone()]),
    )?;
    let mut tables = Vec::new();
    for kind in kinds {
        let mut t = Table::new(
            format!("Fig. 12 — {} speedup over 1 channel (BFS)", kind.name()),
            &["dram", "channels", "db", "lj", "or", "rd"],
        );
        for mem in MemTech::all() {
            let chs: &[usize] = if mem == MemTech::Hbm { &[2, 4, 8] } else { &[2, 4] };
            // 1-channel baselines
            let mut base = std::collections::HashMap::new();
            for g in scope.ablation_graphs() {
                let r = sim(session, kind, g, ProblemKind::Bfs, mem, 1, &cfg)?;
                base.insert(g, r.seconds);
            }
            for &ch in chs {
                let mut row = vec![mem.name().to_uppercase(), ch.to_string()];
                for g in paper::ABLATION_GRAPHS {
                    if !scope.ablation_graphs().contains(&g) {
                        row.push("-".into());
                        continue;
                    }
                    let r = sim(session, kind, g, ProblemKind::Bfs, mem, ch, &cfg)?;
                    row.push(format!("{:.2}x", base[&g] / r.seconds));
                }
                t.row(row);
            }
        }
        tables.push(t);
    }
    Ok(tables)
}

// ---------------------------------------------------------------------------
// Fig. 13 / Tab. 8 — optimization ablations
// ---------------------------------------------------------------------------

fn fig13(session: &Session, scope: Scope) -> Result<Vec<Table>> {
    let graphs = scope.ablation_graphs();
    let mut tables = Vec::new();

    // (accelerator, label, configuration) rows, mirroring Tab. 8.
    let configs: Vec<(AcceleratorKind, &str, AcceleratorConfig)> = vec![
        (AcceleratorKind::AccuGraph, "none", AcceleratorConfig::baseline()),
        (
            AcceleratorKind::AccuGraph,
            "prefetch skip",
            AcceleratorConfig::baseline().with(Optimization::PrefetchSkipping),
        ),
        (
            AcceleratorKind::AccuGraph,
            "partition skip",
            AcceleratorConfig::baseline().with(Optimization::PartitionSkipping),
        ),
        (AcceleratorKind::AccuGraph, "all", all_opt()),
        (AcceleratorKind::ForeGraph, "none", AcceleratorConfig::baseline()),
        (
            AcceleratorKind::ForeGraph,
            "edge shuffle",
            AcceleratorConfig::baseline().with(Optimization::EdgeShuffling),
        ),
        (
            AcceleratorKind::ForeGraph,
            "shard skip",
            AcceleratorConfig::baseline().with(Optimization::ShardSkipping),
        ),
        (
            AcceleratorKind::ForeGraph,
            "stride map",
            AcceleratorConfig::baseline().with(Optimization::StrideMapping),
        ),
        (AcceleratorKind::ForeGraph, "all", all_opt()),
        (AcceleratorKind::HitGraph, "none", AcceleratorConfig::baseline()),
        (
            AcceleratorKind::HitGraph,
            "partition skip",
            AcceleratorConfig::baseline().with(Optimization::PartitionSkipping),
        ),
        (
            AcceleratorKind::HitGraph,
            "edge sort",
            AcceleratorConfig::baseline().with(Optimization::EdgeSorting),
        ),
        (
            AcceleratorKind::HitGraph,
            "update combine",
            AcceleratorConfig::baseline()
                .with(Optimization::EdgeSorting)
                .with(Optimization::UpdateCombining),
        ),
        (
            AcceleratorKind::HitGraph,
            "update filter",
            AcceleratorConfig::baseline().with(Optimization::UpdateFiltering),
        ),
        (AcceleratorKind::HitGraph, "all", all_opt()),
        (AcceleratorKind::ThunderGp, "none", AcceleratorConfig::baseline()),
        (
            AcceleratorKind::ThunderGp,
            "chunk schedule",
            AcceleratorConfig::baseline().with(Optimization::ChunkScheduling),
        ),
    ];

    // Not a cartesian product (each accelerator has its own config
    // list), so build the spec batch directly and fan it out.
    let mut batch = Vec::new();
    for (kind, _, cfg) in &configs {
        for &g in &graphs {
            batch.push(spec(*kind, g, ProblemKind::Bfs, MemTech::Ddr4, 1, cfg)?);
        }
    }
    session.run_all(&batch);

    let mut t = Table::new(
        "Fig. 13 / Tab. 8 — BFS runtime (s) and speedup over baseline by optimization",
        &{
            let mut h = vec!["accel", "optimization"];
            for g in &graphs {
                h.push(g.name());
            }
            h.push("geomean speedup");
            h
        },
    );
    // Baselines per accelerator.
    let mut base: std::collections::HashMap<AcceleratorKind, Vec<f64>> =
        std::collections::HashMap::new();
    for (kind, label, cfg) in &configs {
        let mut secs = Vec::new();
        for &g in &graphs {
            let r = sim(session, *kind, g, ProblemKind::Bfs, MemTech::Ddr4, 1, cfg)?;
            secs.push(r.seconds);
        }
        if *label == "none" {
            base.insert(*kind, secs.clone());
        }
        let b = &base[kind];
        let speedups: Vec<f64> = b.iter().zip(&secs).map(|(b, s)| b / s).collect();
        let mut row = vec![kind.name().to_string(), label.to_string()];
        for (i, s) in secs.iter().enumerate() {
            row.push(format!("{:.5} ({:.2}x)", s, speedups[i]));
        }
        row.push(format!("{:.2}x", stats::geo_mean(&speedups)));
        t.row(row);
    }
    tables.push(t);
    Ok(tables)
}

// ---------------------------------------------------------------------------
// Patterns — per-region access-pattern comparison (trace::analysis)
// ---------------------------------------------------------------------------

/// The paper's central analysis as an experiment: for every
/// accelerator × graph, break the DRAM traffic down by data-structure
/// region and report sequentiality and in-order row locality. The
/// summaries ride on the memoized [`SimReport`]s (no trace files).
fn patterns_exp(session: &Session, scope: Scope) -> Result<Vec<Table>> {
    let cfg = all_opt();
    prefetch(
        session,
        &Sweep::new()
            .accelerators(AcceleratorKind::all())
            .graphs(scope.graphs())
            .problems([ProblemKind::Bfs])
            .configs([cfg.clone()])
            .collect_patterns(),
    )?;
    let pct = crate::report::pattern::pct;
    let mut share = Table::new(
        "Patterns (a) — traffic share by region (%, BFS, DDR4 single-channel)",
        &["graph", "accel", "edges%", "vertices%", "updates%", "payload%", "total req"],
    );
    let mut locality = Table::new(
        "Patterns (b) — sequentiality and in-order row locality by region (BFS)",
        &["graph", "accel", "region", "seq%", "strided%", "random%", "hit%", "miss%", "conf%"],
    );
    for g in scope.graphs() {
        for kind in AcceleratorKind::all() {
            let spec = SimSpec::builder()
                .accelerator(kind)
                .graph(g)
                .problem(ProblemKind::Bfs)
                .mem(MemTech::Ddr4)
                .channels(1)
                .config(cfg.clone())
                .patterns(true)
                .build()?;
            let r = session.run(&spec);
            let s = r
                .patterns
                .as_ref()
                .expect("patterns(true) specs always attach a summary");
            let total = s.total_requests();
            let mut row = vec![g.to_string(), kind.name().to_string()];
            for region in Region::all() {
                row.push(pct(s.region(region).requests(), total));
            }
            row.push(total.to_string());
            share.row(row);
            for region in Region::all() {
                let reg = s.region(region);
                let n = reg.requests();
                if n == 0 {
                    continue;
                }
                locality.row(vec![
                    g.to_string(),
                    kind.name().to_string(),
                    region.name().to_string(),
                    pct(reg.sequential, n),
                    pct(reg.strided, n),
                    pct(reg.random, n),
                    pct(reg.row_hits, n),
                    pct(reg.row_misses, n),
                    pct(reg.row_conflicts, n),
                ]);
            }
        }
    }
    Ok(vec![share, locality])
}

// ---------------------------------------------------------------------------
// Tab. 5 — weighted problems
// ---------------------------------------------------------------------------

fn tab5(session: &Session, scope: Scope) -> Result<Vec<Table>> {
    let cfg = all_opt();
    let kinds = [AcceleratorKind::HitGraph, AcceleratorKind::ThunderGp];
    prefetch(
        session,
        &Sweep::new()
            .accelerators(kinds)
            .graphs(scope.graphs())
            .problems([ProblemKind::Sssp, ProblemKind::SpMV])
            .configs([cfg.clone()]),
    )?;
    let mut t = Table::new(
        "Tab. 5 — SSSP / SpMV runtimes (s), DDR4 single-channel",
        &["graph", "HG:SSSP", "HG:SpMV", "TGP:SSSP", "TGP:SpMV"],
    );
    for g in scope.graphs() {
        let mut row = vec![g.to_string()];
        for kind in kinds {
            for problem in [ProblemKind::Sssp, ProblemKind::SpMV] {
                let r = sim(session, kind, g, problem, MemTech::Ddr4, 1, &cfg)?;
                row.push(format!("{:.5}", r.seconds));
            }
        }
        t.row(row);
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_ids() {
        assert_eq!(Experiment::parse("fig08"), Some(Experiment::Fig08Tab4Mteps));
        assert_eq!(Experiment::parse("tab7"), Some(Experiment::Fig12Tab7Channels));
        assert_eq!(Experiment::parse("zzz"), None);
        for e in Experiment::all() {
            assert_eq!(Experiment::parse(e.id()), Some(e));
        }
    }

    #[test]
    fn scopes() {
        assert_eq!(Scope::parse("quick"), Some(Scope::Quick));
        assert_eq!(Scope::Full.graphs().len(), 12);
        assert!(Scope::Quick.graphs().len() < Scope::Standard.graphs().len());
    }

    #[test]
    fn quick_fig09_runs() {
        let tables = run_experiment(Experiment::Fig09Metrics, Scope::Quick).unwrap();
        assert_eq!(tables.len(), 4);
        for t in &tables {
            assert_eq!(t.num_rows(), 4); // 4 quick graphs
        }
    }

    #[test]
    fn quick_tab5_runs() {
        let tables = run_experiment(Experiment::Tab5Weighted, Scope::Quick).unwrap();
        assert_eq!(tables.len(), 1);
        assert!(tables[0].render().contains("HG:SSSP"));
    }

    #[test]
    fn quick_patterns_runs() {
        let tables = run_experiment(Experiment::Patterns, Scope::Quick).unwrap();
        assert_eq!(tables.len(), 2);
        assert_eq!(tables[0].num_rows(), 16); // 4 quick graphs x 4 accelerators
        let txt = tables[1].render();
        assert!(txt.contains("edges"), "{txt}");
        assert!(txt.contains("vertices"), "{txt}");
    }

    #[test]
    fn sessions_share_runs_across_experiments() {
        let session = Session::new();
        run_experiment_with(&session, Experiment::Fig10Skewness, Scope::Quick).unwrap();
        let after_fig10 = session.cached_runs();
        assert!(after_fig10 > 0);
        // Fig. 14 uses the same BFS runs — nothing new simulates.
        run_experiment_with(&session, Experiment::Fig14Degree, Scope::Quick).unwrap();
        assert_eq!(session.cached_runs(), after_fig10);
    }
}
