//! The paper's published measurements (Appendix A, Tabs. 4–8),
//! embedded for shape comparison: our workloads are scaled stand-ins
//! (DESIGN.md §6), so the harness compares *relative* behaviour
//! (rankings, ratios, crossovers) against these numbers, not absolute
//! runtimes.

use crate::accel::AcceleratorKind;
use crate::algo::problem::ProblemKind;
use crate::dram::MemTech;
use crate::graph::datasets::DatasetId;
use crate::robust::SimError;

/// Graph order used by all appendix tables (the row-index source of
/// truth for every table below — defined from `DatasetId` so the two
/// can never drift).
pub const GRAPHS: [DatasetId; 12] = DatasetId::all();

/// The Fig. 12/13 subset.
pub const ABLATION_GRAPHS: [DatasetId; 4] = DatasetId::ablation();

/// Tab. 4: DDR4 single-channel runtimes (seconds), all optimizations,
/// per graph: [BFS, PR, WCC].
pub fn tab4(accel: AcceleratorKind, graph: DatasetId) -> Option<[f64; 3]> {
    let idx = GRAPHS.iter().position(|&g| g == graph)?;
    let table: &[[f64; 3]; 12] = match accel {
        // ReGraph post-dates the paper: no published row exists.
        AcceleratorKind::ReGraph => return None,
        AcceleratorKind::AccuGraph => &[
            [0.0017, 0.0005, 0.0009],
            [0.0107, 0.0014, 0.0083],
            [0.0232, 0.0044, 0.0189],
            [0.1154, 0.0241, 0.0688],
            [0.0274, 0.0075, 0.0236],
            [0.4709, 0.0879, 0.1685],
            [0.2650, 0.0459, 0.2202],
            [10.3114, 1.9304, 10.4346],
            [1.6355, 0.0033, 1.6219],
            [1.3653, 0.0057, 0.9357],
            [0.3174, 0.0650, 0.3466],
            [1.9207, 0.2835, 1.8342],
        ],
        AcceleratorKind::ForeGraph => &[
            [0.0159, 0.0009, 0.0046],
            [0.0268, 0.0019, 0.0173],
            [0.0332, 0.0032, 0.0256],
            [0.1335, 0.0225, 0.1126],
            [0.0327, 0.0061, 0.0245],
            [0.4736, 0.0791, 0.2791],
            [0.4347, 0.0396, 0.2577],
            [21.7350, 2.7537, 63.8956],
            [5.0959, 0.0057, 3.2011],
            [8.0324, 0.0108, 2.7803],
            [0.4926, 0.0681, 0.3757],
            [1.3074, 0.2287, 1.5206],
        ],
        AcceleratorKind::HitGraph => &[
            [0.0081, 0.0009, 0.0077],
            [0.0344, 0.0023, 0.0348],
            [0.0659, 0.0076, 0.0706],
            [0.3465, 0.0484, 0.3310],
            [0.0601, 0.0094, 0.0653],
            [1.2344, 0.1831, 1.2852],
            [0.7591, 0.0725, 0.9049],
            [13.8804, 1.5886, 20.0293],
            [3.7714, 0.0068, 4.7490],
            [3.9504, 0.0086, 4.6874],
            [0.9812, 0.1282, 1.2820],
            [2.2484, 0.2198, 2.7620],
        ],
        AcceleratorKind::ThunderGp => &[
            [0.0087, 0.0009, 0.0078],
            [0.0345, 0.0022, 0.0323],
            [0.0940, 0.0063, 0.0879],
            [0.5225, 0.0523, 0.5239],
            [0.0529, 0.0066, 0.0464],
            [1.5718, 0.1967, 1.5754],
            [0.9538, 0.0637, 0.9555],
            [24.2738, 1.2539, 66.8212],
            [4.0371, 0.0070, 4.8985],
            [4.0059, 0.0067, 3.6763],
            [1.3596, 0.1512, 1.5147],
            [3.5936, 0.2401, 3.3590],
        ],
    };
    Some(table[idx])
}

/// Tab. 4 runtime for one problem.
pub fn tab4_runtime(
    accel: AcceleratorKind,
    graph: DatasetId,
    problem: ProblemKind,
) -> Option<f64> {
    let row = tab4(accel, graph)?;
    match problem {
        ProblemKind::Bfs => Some(row[0]),
        ProblemKind::PageRank => Some(row[1]),
        ProblemKind::Wcc => Some(row[2]),
        _ => None,
    }
}

/// [`tab4_runtime`] with a typed error instead of a bare `None`: a
/// missing published row (ReGraph, or a problem outside Tab. 4) is an
/// invalid *input* to a shape comparison, not a reason to panic or
/// abort a whole experiment — callers route it through the same
/// failure-table path as any other [`SimError`].
pub fn tab4_runtime_checked(
    accel: AcceleratorKind,
    graph: DatasetId,
    problem: ProblemKind,
) -> Result<f64, SimError> {
    tab4_runtime(accel, graph, problem).ok_or_else(|| {
        SimError::InvalidInput(format!(
            "no published Tab. 4 runtime for {}/{graph}/{problem} \
             (ReGraph post-dates the paper; Tab. 4 covers BFS/PR/WCC)",
            accel.name()
        ))
    })
}

/// Tab. 5: weighted-problem runtimes (seconds) on DDR4 single-channel,
/// per graph: [SSSP, SpMV]. Only HitGraph and ThunderGP.
pub fn tab5(accel: AcceleratorKind, graph: DatasetId) -> Option<[f64; 2]> {
    let idx = GRAPHS.iter().position(|&g| g == graph)?;
    let table: &[[f64; 2]; 12] = match accel {
        AcceleratorKind::HitGraph => &[
            [0.0114, 0.0012],
            [0.0459, 0.0030],
            [0.0848, 0.0096],
            [0.5014, 0.0695],
            [0.0740, 0.0111],
            [1.8002, 0.2639],
            [1.0300, 0.0964],
            [18.6132, 2.0955],
            [5.2940, 0.0094],
            [5.0307, 0.0105],
            [1.4582, 0.1904],
            [3.2229, 0.3124],
        ],
        AcceleratorKind::ThunderGp => &[
            [0.0122, 0.0012],
            [0.0469, 0.0029],
            [0.1271, 0.0084],
            [0.7501, 0.0747],
            [0.0680, 0.0085],
            [2.2647, 0.2821],
            [1.3311, 0.0884],
            [32.4852, 2.0255],
            [5.6896, 0.0098],
            [5.1446, 0.0085],
            [1.9629, 0.2173],
            [5.0438, 0.3355],
        ],
        _ => return None,
    };
    Some(table[idx])
}

/// Tab. 6: DDR3 and HBM single-channel BFS runtimes (seconds), per
/// graph: [DDR3, HBM].
pub fn tab6(accel: AcceleratorKind, graph: DatasetId) -> Option<[f64; 2]> {
    let idx = GRAPHS.iter().position(|&g| g == graph)?;
    let table: &[[f64; 2]; 12] = match accel {
        // ReGraph post-dates the paper: no published row exists.
        AcceleratorKind::ReGraph => return None,
        AcceleratorKind::AccuGraph => &[
            [0.0014, 0.0017],
            [0.0094, 0.0114],
            [0.0200, 0.0244],
            [0.0970, 0.1157],
            [0.0241, 0.0303],
            [0.3935, 0.4708],
            [0.2335, 0.2867],
            [9.0370, 11.2454],
            [1.3712, 1.6510],
            [1.1917, 1.4289],
            [0.2651, 0.3168],
            [1.6698, 2.2024],
        ],
        AcceleratorKind::ForeGraph => &[
            [0.0131, 0.0157],
            [0.0221, 0.0264],
            [0.0274, 0.0327],
            [0.1101, 0.1316],
            [0.0269, 0.0321],
            [0.3905, 0.4668],
            [0.3584, 0.4282],
            [17.9232, 21.4115],
            [4.2011, 5.0245],
            [6.6240, 7.9176],
            [0.4062, 0.4856],
            [1.0779, 1.2862],
        ],
        AcceleratorKind::HitGraph => &[
            [0.0064, 0.0090],
            [0.0273, 0.0382],
            [0.0526, 0.0736],
            [0.0275, 0.0389], // as printed in the paper (pk outlier)
            [0.0484, 0.0671],
            [0.9660, 1.3605],
            [0.6045, 0.8461],
            [11.4310, 16.3588],
            [2.9800, 4.1829],
            [3.1720, 4.4374],
            [0.7626, 1.0785],
            [1.7598, 2.4812],
        ],
        AcceleratorKind::ThunderGp => &[
            [0.0070, 0.0096],
            [0.0289, 0.0401],
            [0.0769, 0.1060],
            [0.4261, 0.5833],
            [0.0422, 0.0576],
            [1.2889, 1.7739],
            [0.7893, 1.1007],
            [20.8722, 30.9201],
            [3.3493, 4.5960],
            [3.3688, 4.7319],
            [1.1087, 1.5177],
            [3.0170, 4.1784],
        ],
    };
    Some(table[idx])
}

/// Tab. 7: multi-channel BFS runtimes (seconds) for HitGraph and
/// ThunderGP on db/lj/or/rd. Channels in {2, 4} (plus 8 for HBM).
pub fn tab7(
    accel: AcceleratorKind,
    mem: MemTech,
    channels: usize,
    graph: DatasetId,
) -> Option<f64> {
    let gi = ABLATION_GRAPHS.iter().position(|&g| g == graph)?;
    let hit = matches!(accel, AcceleratorKind::HitGraph);
    if !hit && !matches!(accel, AcceleratorKind::ThunderGp) {
        return None;
    }
    let row: [f64; 4] = match (mem, channels, hit) {
        (MemTech::Ddr3, 2, true) => [0.0174, 0.3640, 0.5433, 1.5002],
        (MemTech::Ddr3, 2, false) => [0.0169, 0.4143, 0.6355, 2.1135],
        (MemTech::Ddr3, 4, true) => [0.0105, 0.2221, 0.3151, 0.7443],
        (MemTech::Ddr3, 4, false) => [0.0109, 0.2336, 0.3222, 1.4887],
        (MemTech::Ddr4, 2, true) => [0.0192, 0.3998, 0.5966, 1.6494],
        (MemTech::Ddr4, 2, false) => [0.0185, 0.4557, 0.6978, 2.3198],
        (MemTech::Ddr4, 4, true) => [0.0127, 0.2682, 0.3798, 0.8968],
        (MemTech::Ddr4, 4, false) => [0.0131, 0.2807, 0.3865, 1.7867],
        (MemTech::Hbm, 2, true) => [0.0218, 0.4549, 0.6824, 1.8830],
        (MemTech::Hbm, 2, false) => [0.0211, 0.5236, 0.7753, 2.6404],
        (MemTech::Hbm, 4, true) => [0.0128, 0.2702, 0.3776, 0.8957],
        (MemTech::Hbm, 4, false) => [0.0128, 0.2772, 0.3735, 1.7533],
        (MemTech::Hbm, 8, true) => [0.0069, 0.1452, 0.1934, 0.3792],
        (MemTech::Hbm, 8, false) => [0.0108, 0.1926, 0.2400, 1.6126],
        _ => return None,
    };
    Some(row[gi])
}

/// Tab. 8: BFS runtimes (seconds) on DDR4 single-channel with a single
/// optimization enabled (or none), on db/lj/or/rd.
pub fn tab8(accel: AcceleratorKind, optimization: &str, graph: DatasetId) -> Option<f64> {
    let gi = ABLATION_GRAPHS.iter().position(|&g| g == graph)?;
    let row: [f64; 4] = match (accel, optimization) {
        (AcceleratorKind::AccuGraph, "none") => [0.0118, 0.3062, 0.5071, 1.3834],
        (AcceleratorKind::AccuGraph, "prefetch") => [0.0107, 0.3062, 0.5071, 1.3834],
        (AcceleratorKind::AccuGraph, "partition") => [0.0118, 0.2650, 0.4709, 1.3670],
        (AcceleratorKind::ForeGraph, "none") => [0.0263, 0.9428, 2.0590, 15.6424],
        (AcceleratorKind::ForeGraph, "shuffle") => [0.0936, 3.3837, 5.5188, 86.4302],
        (AcceleratorKind::ForeGraph, "shardskip") => [0.0191, 0.6594, 1.3149, 4.9896],
        (AcceleratorKind::ForeGraph, "stride") => [0.0268, 0.4347, 0.4736, 8.0324],
        (AcceleratorKind::HitGraph, "none") => [0.1594, 4.1306, 7.1937, 4.7238],
        (AcceleratorKind::HitGraph, "partition") => [0.1455, 2.7382, 5.8026, 4.3559],
        (AcceleratorKind::HitGraph, "sort") => [0.0284, 0.8422, 1.1732, 1.8639],
        (AcceleratorKind::HitGraph, "combine") => [0.0149, 0.4318, 0.4883, 1.1849],
        (AcceleratorKind::HitGraph, "filter") => [0.1081, 3.0243, 4.2361, 3.1239],
        (AcceleratorKind::ThunderGp, "none") => [0.0125, 0.2702, 0.3701, 1.7121],
        _ => return None,
    };
    Some(row[gi])
}

/// Mean simulation error the paper reports for the original
/// environment (Fig. 2): 22.63 %.
pub const PAPER_MEAN_ERROR_PCT: f64 = 22.63;

#[cfg(test)]
mod tests {
    use super::*;

    /// The four accelerators the paper measured; ReGraph post-dates it
    /// and deliberately has no appendix rows.
    fn published() -> impl Iterator<Item = AcceleratorKind> {
        AcceleratorKind::all()
            .into_iter()
            .filter(|k| *k != AcceleratorKind::ReGraph)
    }

    #[test]
    fn tab4_is_complete_for_published_systems() {
        for accel in published() {
            for g in GRAPHS {
                let row = tab4(accel, g).unwrap_or_else(|| panic!("{accel:?} {g}"));
                assert!(row.iter().all(|&v| v > 0.0));
            }
        }
    }

    #[test]
    fn missing_rows_are_typed_errors_not_panics() {
        assert!(tab4(AcceleratorKind::ReGraph, DatasetId::Sd).is_none());
        assert!(tab6(AcceleratorKind::ReGraph, DatasetId::Sd).is_none());
        let err =
            tab4_runtime_checked(AcceleratorKind::ReGraph, DatasetId::Sd, ProblemKind::Bfs)
                .unwrap_err();
        assert_eq!(err.kind(), "invalid-input");
        assert!(err.to_string().contains("ReGraph"), "{err}");
        // A problem outside Tab. 4 is the same class of failure.
        let err =
            tab4_runtime_checked(AcceleratorKind::HitGraph, DatasetId::Sd, ProblemKind::Sssp)
                .unwrap_err();
        assert_eq!(err.kind(), "invalid-input");
        // And the rows that do exist come back Ok.
        assert!(
            tab4_runtime_checked(AcceleratorKind::HitGraph, DatasetId::Sd, ProblemKind::Bfs)
                .is_ok()
        );
    }

    #[test]
    fn tab4_shape_facts_from_the_paper() {
        // PR fastest (1 iteration) on every accel/graph
        for accel in published() {
            for g in GRAPHS {
                let [bfs, pr, _wcc] = tab4(accel, g).unwrap();
                assert!(pr < bfs, "{accel:?} {g}");
            }
        }
        // AccuGraph & ForeGraph beat HitGraph & ThunderGP on or/lj BFS
        for g in [DatasetId::Or, DatasetId::Lj] {
            let ag = tab4(AcceleratorKind::AccuGraph, g).unwrap()[0];
            let hg = tab4(AcceleratorKind::HitGraph, g).unwrap()[0];
            assert!(ag < hg, "{g}");
        }
    }

    #[test]
    fn tab5_only_weighted_systems() {
        assert!(tab5(AcceleratorKind::AccuGraph, DatasetId::Sd).is_none());
        assert!(tab5(AcceleratorKind::HitGraph, DatasetId::Sd).is_some());
        assert!(tab5(AcceleratorKind::ThunderGp, DatasetId::R24).is_some());
    }

    #[test]
    fn tab6_hbm_slower_than_ddr3_everywhere() {
        // insight 6: HBM single-channel never beats DDR3 in Tab. 6
        for accel in published() {
            for g in GRAPHS {
                let [ddr3, hbm] = tab6(accel, g).unwrap();
                assert!(hbm > ddr3, "{accel:?} {g}");
            }
        }
    }

    #[test]
    fn tab7_scaling_facts() {
        // HitGraph near-linear on rd (super-linear per the paper)
        let one = tab4(AcceleratorKind::HitGraph, DatasetId::Rd).unwrap()[0];
        let four = tab7(AcceleratorKind::HitGraph, MemTech::Ddr4, 4, DatasetId::Rd).unwrap();
        assert!(one / four > 3.5);
        // ThunderGP sub-linear on rd
        let t1 = tab4(AcceleratorKind::ThunderGp, DatasetId::Rd).unwrap()[0];
        let t4 = tab7(AcceleratorKind::ThunderGp, MemTech::Ddr4, 4, DatasetId::Rd).unwrap();
        assert!(t1 / t4 < 3.0);
    }

    #[test]
    fn tab8_shuffle_alone_hurts() {
        for g in ABLATION_GRAPHS {
            let none = tab8(AcceleratorKind::ForeGraph, "none", g).unwrap();
            let shuf = tab8(AcceleratorKind::ForeGraph, "shuffle", g).unwrap();
            assert!(shuf > none, "{g}");
        }
    }
}
