//! Experiment coordination on top of the typed session API.
//!
//! The flow is: [`experiment`] declares every figure/table of the
//! paper's evaluation; each experiment expresses its runs as
//! [`crate::sim::SimSpec`]s (often via a [`crate::sim::Sweep`] over
//! typed axes), prefetches them in parallel through a shared
//! [`crate::sim::Session`], and formats the memoized reports into
//! tables. [`paper`] embeds the published numbers for shape
//! comparison.
//!
//! ```no_run
//! use graphmem::coordinator::{run_experiment, Experiment, Scope};
//!
//! let tables = run_experiment(Experiment::Fig08Tab4Mteps, Scope::Quick).unwrap();
//! for t in tables {
//!     println!("{}", t.render());
//! }
//! ```
//!
//! [`runner`] holds the deprecated string-keyed shims (`run_one`,
//! `Runner`, `dram_spec`) retained for one release; see its module
//! docs for the migration table.

pub mod experiment;
pub mod paper;
pub mod runner;

pub use experiment::{run_experiment, Experiment, Scope};
#[allow(deprecated)]
pub use runner::{run_one, Runner};
pub use crate::sim::{Session, SimSpec, Sweep};
