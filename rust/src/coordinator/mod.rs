//! Experiment coordination: the registry of every figure and table in
//! the paper's evaluation, the sweep runner that regenerates them on
//! the scaled workloads, and the embedded published numbers used for
//! shape comparison.

pub mod experiment;
pub mod paper;
pub mod runner;

pub use experiment::{run_experiment, Experiment, Scope};
pub use runner::{run_one, Runner};
