//! Experiment coordination on top of the typed session API.
//!
//! The flow is: [`experiment`] declares every figure/table of the
//! paper's evaluation; each experiment expresses its runs as
//! [`crate::sim::SimSpec`]s (often via a [`crate::sim::Sweep`] over
//! typed axes), prefetches them in parallel through a shared
//! [`crate::sim::Session`], and formats the memoized reports into
//! tables. [`paper`] embeds the published numbers for shape
//! comparison.
//!
//! ```no_run
//! use graphmem::coordinator::{run_experiment, Experiment, Scope};
//!
//! let tables = run_experiment(Experiment::Fig08Tab4Mteps, Scope::Quick).unwrap();
//! for t in tables {
//!     println!("{}", t.render());
//! }
//! ```
//!
//! The PR-1 string-keyed shims (`run_one`, `Runner`, `dram_spec`)
//! that lived in a `runner` module here were retained for one release
//! and have been removed; migrate to [`crate::sim::Session`] /
//! [`crate::sim::SimSpec`] (the README's "Typed session API" section
//! keeps the migration table).

pub mod experiment;
pub mod paper;

pub use experiment::{figure_matrix_specs, run_experiment, Experiment, Scope};
pub use crate::sim::{Session, SimSpec, Sweep};
