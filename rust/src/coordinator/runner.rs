//! Deprecated string-keyed entry points, kept for one release as thin
//! shims over the typed session API.
//!
//! Migration:
//!
//! * `run_one(kind, "lj", problem, "ddr4", ch, &cfg)` →
//!   `SimSpec::builder().accelerator(kind).graph(DatasetId::Lj)
//!    .problem(problem).mem(MemTech::Ddr4).channels(ch)
//!    .config(cfg).build()?.run()`
//! * `Runner` → [`crate::sim::Session`] (shared across threads, runs
//!   batches in parallel via [`crate::sim::Session::run_all`]).
//! * `dram_spec("hbm", ch)` → `MemTech::Hbm.spec(ch)`.
//!
//! The old `Runner` memoized on a hand-rolled format-string key that
//! omitted `cfg.window` and `cfg.experimental_multichannel`, so runs
//! differing only in those fields aliased to one cached report. The
//! typed [`crate::sim::SimSpec`] key derives `Hash`/`Eq` over every
//! field, making that class of bug structurally impossible (regression
//! test below).

use crate::accel::{AcceleratorConfig, AcceleratorKind};
use crate::algo::problem::ProblemKind;
use crate::dram::{DramSpec, MemTech};
use crate::sim::metrics::SimReport;
use crate::sim::{Session, SimSpec};
use anyhow::{anyhow, Result};

/// Resolve a DRAM type name ("ddr3" | "ddr4" | "hbm") to a spec.
#[deprecated(since = "0.2.0", note = "parse a `MemTech` and call `MemTech::spec` instead")]
pub fn dram_spec(dram: &str, channels: usize) -> Result<DramSpec> {
    let tech: MemTech = dram.parse().map_err(|e: String| anyhow!(e))?;
    Ok(tech.spec(channels))
}

/// Execute one simulation run.
#[deprecated(
    since = "0.2.0",
    note = "build a typed spec: `SimSpec::builder()...build()?.run()` (see `sim::spec`)"
)]
pub fn run_one(
    kind: AcceleratorKind,
    graph: &str,
    problem: ProblemKind,
    dram: &str,
    channels: usize,
    cfg: &AcceleratorConfig,
) -> Result<SimReport> {
    let spec = SimSpec::builder()
        .accelerator(kind)
        .graph_named(graph)
        .problem(problem)
        .mem_named(dram)
        .channels(channels)
        .config(cfg.clone())
        .build()?;
    Ok(spec.run())
}

/// Memoizing runner (deprecated shim over [`Session`]).
#[deprecated(since = "0.2.0", note = "use `sim::Session` (thread-safe, parallel batches)")]
pub struct Runner {
    session: Session,
}

#[allow(deprecated)]
impl Default for Runner {
    fn default() -> Runner {
        Runner {
            session: Session::new(),
        }
    }
}

#[allow(deprecated)]
impl Runner {
    pub fn new() -> Runner {
        Runner::default()
    }

    /// Run (or fetch from cache).
    pub fn run(
        &mut self,
        kind: AcceleratorKind,
        graph: &str,
        problem: ProblemKind,
        dram: &str,
        channels: usize,
        cfg: &AcceleratorConfig,
    ) -> Result<SimReport> {
        let spec = SimSpec::builder()
            .accelerator(kind)
            .graph_named(graph)
            .problem(problem)
            .mem_named(dram)
            .channels(channels)
            .config(cfg.clone())
            .build()?;
        Ok(self.session.run(&spec))
    }

    pub fn cached_runs(&self) -> usize {
        self.session.cached_runs()
    }
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::graph::datasets::DatasetId;

    #[test]
    fn rejects_invalid_combinations() {
        let cfg = AcceleratorConfig::default();
        assert!(run_one(
            AcceleratorKind::AccuGraph,
            "sd",
            ProblemKind::Sssp,
            "ddr4",
            1,
            &cfg
        )
        .is_err());
        assert!(run_one(
            AcceleratorKind::ForeGraph,
            "sd",
            ProblemKind::Bfs,
            "ddr4",
            4,
            &cfg
        )
        .is_err());
        assert!(
            run_one(AcceleratorKind::HitGraph, "sd", ProblemKind::Bfs, "dd5", 1, &cfg).is_err()
        );
        assert!(
            run_one(AcceleratorKind::HitGraph, "zz", ProblemKind::Bfs, "ddr4", 1, &cfg).is_err()
        );
    }

    #[test]
    fn runner_caches() {
        let mut r = Runner::new();
        let cfg = AcceleratorConfig::all_optimizations();
        let a = r
            .run(AcceleratorKind::AccuGraph, "sd", ProblemKind::PageRank, "ddr4", 1, &cfg)
            .unwrap();
        assert_eq!(r.cached_runs(), 1);
        let b = r
            .run(AcceleratorKind::AccuGraph, "sd", ProblemKind::PageRank, "ddr4", 1, &cfg)
            .unwrap();
        assert_eq!(r.cached_runs(), 1);
        assert_eq!(a.cycles, b.cycles);
    }

    #[test]
    fn dram_specs_resolve() {
        assert!(dram_spec("ddr3", 2).is_ok());
        assert!(dram_spec("hbm", 8).is_ok());
        assert!(dram_spec("lpddr", 1).is_err());
    }

    /// The retired `Runner::key` format string, verbatim — it omitted
    /// `cfg.window` and `cfg.experimental_multichannel`.
    fn old_key(
        kind: AcceleratorKind,
        graph: &str,
        problem: ProblemKind,
        dram: &str,
        channels: usize,
        cfg: &AcceleratorConfig,
    ) -> String {
        format!(
            "{}|{}|{}|{}|{}|{:?}|{}|{}|{}",
            kind.name(),
            graph,
            problem.name(),
            dram,
            channels,
            cfg.optimizations,
            cfg.bram_values,
            cfg.foregraph_interval,
            cfg.num_pes,
        )
    }

    /// Regression for the stale-cache bug: two configs differing only
    /// in `window` (or `experimental_multichannel`) collided under the
    /// old string key, so the second run silently returned the first
    /// run's report. The derived `SimSpec` key keeps them distinct.
    #[test]
    fn old_key_collision_is_structurally_impossible_now() {
        let wide = AcceleratorConfig::default().with_window(32);
        let narrow = AcceleratorConfig::default().with_window(1);
        assert_ne!(wide, narrow);
        // The old cache key cannot tell them apart...
        assert_eq!(
            old_key(AcceleratorKind::HitGraph, "sd", ProblemKind::Bfs, "ddr4", 1, &wide),
            old_key(AcceleratorKind::HitGraph, "sd", ProblemKind::Bfs, "ddr4", 1, &narrow),
        );
        // ...and the flag was dropped too.
        let flagged = AcceleratorConfig::default().with_experimental_multichannel(true);
        assert_eq!(
            old_key(AcceleratorKind::HitGraph, "sd", ProblemKind::Bfs, "ddr4", 1, &flagged),
            old_key(
                AcceleratorKind::HitGraph,
                "sd",
                ProblemKind::Bfs,
                "ddr4",
                1,
                &AcceleratorConfig::default()
            ),
        );
        // The typed key separates them: two cache entries, and the
        // window genuinely changes DRAM timing — the old cache was
        // returning a wrong report for one of the two.
        let build = |cfg: AcceleratorConfig| {
            SimSpec::builder()
                .accelerator(AcceleratorKind::HitGraph)
                .graph(DatasetId::Sd)
                .problem(ProblemKind::Bfs)
                .config(cfg)
                .build()
                .unwrap()
        };
        let (sa, sb) = (build(wide), build(narrow));
        assert_ne!(sa, sb);
        let session = Session::new();
        let ra = session.run(&sa);
        let rb = session.run(&sb);
        assert_eq!(session.cached_runs(), 2);
        assert_ne!(ra.cycles, rb.cycles, "window must affect timing");
    }
}
