//! Simulation sweep runner with memoization: several experiments share
//! the same underlying runs (e.g. Fig. 8's BFS runs feed Figs. 9, 10
//! and 14), so results are cached per configuration.

use crate::accel::{build, AcceleratorConfig, AcceleratorKind};
use crate::algo::problem::{GraphProblem, ProblemKind};
use crate::dram::{ChannelMode, DramSpec, MemorySystem};
use crate::graph::datasets;
use crate::sim::metrics::SimReport;
use anyhow::{anyhow, Result};
use std::collections::HashMap;

/// Resolve a DRAM type name ("ddr3" | "ddr4" | "hbm") to a spec.
pub fn dram_spec(dram: &str, channels: usize) -> Result<DramSpec> {
    let spec = match dram {
        "ddr4" => DramSpec::ddr4_2400(channels),
        "ddr3" => DramSpec::ddr3_2133(channels),
        "hbm" => DramSpec::hbm_1000(channels),
        other => return Err(anyhow!("unknown DRAM type {other:?} (ddr3|ddr4|hbm)")),
    };
    Ok(spec)
}

/// Execute one simulation run.
pub fn run_one(
    kind: AcceleratorKind,
    graph: &str,
    problem: ProblemKind,
    dram: &str,
    channels: usize,
    cfg: &AcceleratorConfig,
) -> Result<SimReport> {
    if problem.weighted() && !kind.supports_weighted() {
        return Err(anyhow!(
            "{} does not support weighted problems (Tab. 1)",
            kind.name()
        ));
    }
    if channels > 1 && !kind.multi_channel() && !cfg.experimental_multichannel {
        return Err(anyhow!(
            "{} is not enabled for multi-channel operation (Fig. 12); \
             set experimental_multichannel for the open-challenge-(c) extension",
            kind.name()
        ));
    }
    let g = if problem.weighted() {
        datasets::dataset_weighted(graph)
    } else {
        datasets::dataset(graph)
    }
    .ok_or_else(|| anyhow!("unknown dataset {graph:?}"))?;
    let spec = dram_spec(dram, channels)?;
    // HitGraph/ThunderGP place data per channel (region mode); the
    // single-channel accelerators see one region either way.
    let mode = if kind.multi_channel() {
        ChannelMode::Region
    } else {
        ChannelMode::InterleaveLine
    };
    let p = GraphProblem::new(problem, &g);
    let cfg = cfg.clone().with_channels(channels);
    let mut accel = build(kind, &g, &cfg);
    let mut mem = MemorySystem::with_mode(spec, mode);
    Ok(accel.run(&p, &mut mem))
}

/// Memoizing runner.
#[derive(Default)]
pub struct Runner {
    cache: HashMap<String, SimReport>,
}

impl Runner {
    pub fn new() -> Runner {
        Runner::default()
    }

    fn key(
        kind: AcceleratorKind,
        graph: &str,
        problem: ProblemKind,
        dram: &str,
        channels: usize,
        cfg: &AcceleratorConfig,
    ) -> String {
        format!(
            "{}|{}|{}|{}|{}|{:?}|{}|{}|{}",
            kind.name(),
            graph,
            problem.name(),
            dram,
            channels,
            cfg.optimizations,
            cfg.bram_values,
            cfg.foregraph_interval,
            cfg.num_pes,
        )
    }

    /// Run (or fetch from cache).
    pub fn run(
        &mut self,
        kind: AcceleratorKind,
        graph: &str,
        problem: ProblemKind,
        dram: &str,
        channels: usize,
        cfg: &AcceleratorConfig,
    ) -> Result<SimReport> {
        let key = Self::key(kind, graph, problem, dram, channels, cfg);
        if let Some(hit) = self.cache.get(&key) {
            return Ok(hit.clone());
        }
        let report = run_one(kind, graph, problem, dram, channels, cfg)?;
        self.cache.insert(key, report.clone());
        Ok(report)
    }

    pub fn cached_runs(&self) -> usize {
        self.cache.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_invalid_combinations() {
        let cfg = AcceleratorConfig::default();
        assert!(run_one(
            AcceleratorKind::AccuGraph,
            "sd",
            ProblemKind::Sssp,
            "ddr4",
            1,
            &cfg
        )
        .is_err());
        assert!(run_one(
            AcceleratorKind::ForeGraph,
            "sd",
            ProblemKind::Bfs,
            "ddr4",
            4,
            &cfg
        )
        .is_err());
        assert!(
            run_one(AcceleratorKind::HitGraph, "sd", ProblemKind::Bfs, "dd5", 1, &cfg).is_err()
        );
        assert!(
            run_one(AcceleratorKind::HitGraph, "zz", ProblemKind::Bfs, "ddr4", 1, &cfg).is_err()
        );
    }

    #[test]
    fn runner_caches() {
        let mut r = Runner::new();
        let cfg = AcceleratorConfig::all_optimizations();
        let a = r
            .run(AcceleratorKind::AccuGraph, "sd", ProblemKind::PageRank, "ddr4", 1, &cfg)
            .unwrap();
        assert_eq!(r.cached_runs(), 1);
        let b = r
            .run(AcceleratorKind::AccuGraph, "sd", ProblemKind::PageRank, "ddr4", 1, &cfg)
            .unwrap();
        assert_eq!(r.cached_runs(), 1);
        assert_eq!(a.cycles, b.cycles);
    }

    #[test]
    fn dram_specs_resolve() {
        assert!(dram_spec("ddr3", 2).is_ok());
        assert!(dram_spec("hbm", 8).is_ok());
        assert!(dram_spec("lpddr", 1).is_err());
    }
}
