//! Static analysis: prove structural invariants of compiled
//! [`PhaseProgram`](crate::accel::PhaseProgram)s *without executing
//! them*, plus a dependency-free repo source linter ([`srclint`]).
//!
//! The paper's premise is that accelerator memory behavior is decided
//! by *structure* — partitioning, descriptor layout, channel mapping —
//! yet until this module every structural invariant in the simulator
//! (Region clamping, fanout/merge token conservation, chain-deadlock
//! freedom) was only checked dynamically: by a `debug_assert!` firing
//! mid-run or by the PR-8 stall watchdog diagnosing a hang after the
//! fact. All of those properties are decidable from the compiled
//! artifact alone, and [`ProgramChecker`] decides them:
//!
//! 1. **Region bounds** — in [`ChannelMode::Region`] every descriptor
//!    is channel-local and rebased by `region_base(owner)` at execute
//!    time. The checker replays that rebase through the *same*
//!    [`ChannelMode::local_addr`] rewrite the memory system uses and
//!    rejects any line that would land outside its owner's
//!    `channel_bytes` region (the static form of
//!    `MemorySystem::enqueue`'s Region-mode `debug_assert!`). In
//!    [`ChannelMode::InterleaveLine`] addresses stripe over every
//!    channel and are never bound-checked by the memory system, so the
//!    check is vacuous there by design.
//! 2. **Fanout conservation** — a chained stream deadlocks if its
//!    parents release fewer tokens than it has requests, and leaks
//!    tokens if they release more. Statically: for every chained
//!    stream, `fanout.total(parent_len) == len`, and `PerParent`
//!    schedules must have exactly `parent_len` entries. This is the
//!    compile-time form of the PR-8 no-forward-progress watchdog.
//! 3. **Chain shape** — every `chained_to` parent exists, no stream
//!    chains to itself, and parent links are acyclic.
//! 4. **Merge coverage** — the arbiter tree references only real
//!    streams, references no stream twice, and covers every stream
//!    (an uncovered stream can never issue: a silent no-op; a
//!    duplicated one double-issues).
//! 5. **Gather domains** — every `Gather` index stays below its
//!    declared domain (graph vertex count, or interval length for
//!    interval-local gathers).
//! 6. **Footprints & on-chip capacity** — per-channel layout
//!    footprints fit in `channel_bytes`, and a declared
//!    [`OnChipConfig`] passes its own validation and can hold at
//!    least one cache line when given a non-zero budget.
//!
//! Each violation is a typed [`VerifyError`] naming the offending
//! phase/stream/descriptor. The checker runs on [`ProgramFacts`], a
//! public mirror of the compiled program's structure produced by
//! `PhaseProgram::facts()` — public so test suites can inject defects
//! field-by-field. Execute-time value-dependent streams (AccuGraph's
//! write-backs, HitGraph's update queues, …) appear as static
//! maximal-bounds stand-ins flagged [`StreamFacts::dynamic`].
//!
//! Wiring: [`crate::sim::SimSpec::compile_program`] verifies every
//! program in debug builds, and in release builds when the spec opted
//! in via `SimSpecBuilder::verify(true)` (the flag joins the memo
//! key); `graphmem serve` verifies at admission and answers
//! `ERR verify` without burning a run slot; `graphmem lint` exposes
//! both passes on the command line.
//!
//! Future per-accelerator structural rules (e.g. "ReGraph dense
//! partitions only ever gather interval-locally") belong here, as
//! extra passes over [`ProgramFacts`].

pub mod srclint;

use crate::accel::stream::{Fanout, LineSource, LineStream, Merge, Phase, StreamClass};
use crate::accel::AcceleratorKind;
use crate::dram::{ChannelMode, CACHE_LINE};
use crate::onchip::OnChipConfig;
use std::fmt;
use std::sync::Arc;

/// One stream of a compiled phase, in checkable form.
///
/// Addressing convention matches the compiled program: in
/// [`ChannelMode::Region`] the `source` is *channel-local* (the
/// program rebases it by `region_base(owner)` when assembling the
/// execute-time phase) and [`StreamFacts::owner`] names the owning
/// channel; in [`ChannelMode::InterleaveLine`] addresses are global
/// and `owner` is `None`.
#[derive(Clone, Debug)]
pub struct StreamFacts {
    pub class: StreamClass,
    pub source: LineSource,
    /// Index of the parent stream whose completions release this
    /// stream's requests; `None` for independent streams.
    pub chained_to: Option<usize>,
    pub fanout: Fanout,
    /// Owning channel in Region mode; `None` when interleaved.
    pub owner: Option<usize>,
    /// For [`LineSource::Gather`] sources: the exclusive upper bound
    /// every index must stay below (vertex count for global gathers,
    /// interval length for interval-local ones).
    pub gather_domain: Option<u64>,
    /// True when the execute-time stream is value-dependent and this
    /// entry is a static maximal-bounds stand-in built at compile
    /// time.
    pub dynamic: bool,
}

impl StreamFacts {
    /// Facts of a compiled stream, verbatim: a static stream with no
    /// gather domain, owned by `owner` in Region mode. Builders set
    /// [`StreamFacts::gather_domain`] / [`StreamFacts::dynamic`] on
    /// the result where they apply.
    pub fn of(stream: &LineStream, owner: Option<usize>) -> StreamFacts {
        StreamFacts {
            class: stream.class,
            source: stream.source.clone(),
            chained_to: stream.chained_to,
            fanout: stream.fanout.clone(),
            owner,
            gather_domain: None,
            dynamic: false,
        }
    }

    /// Exclusive end (last line address + line size) of this
    /// stream's descriptor span, or 0 when empty.
    fn extent(&self) -> u64 {
        let len = self.source.len();
        if len == 0 {
            return 0;
        }
        match &self.source {
            // Closed-form descriptors are monotone in `i`.
            LineSource::Seq { .. } | LineSource::Strided { .. } => {
                self.source.line(len - 1) + CACHE_LINE
            }
            LineSource::Gather { .. } | LineSource::Explicit(_) => (0..len)
                .map(|i| self.source.line(i) + CACHE_LINE)
                .max()
                .unwrap_or(0),
        }
    }
}

/// One phase of a compiled program, in checkable form.
#[derive(Clone, Debug)]
pub struct PhaseFacts {
    /// Human-readable origin, e.g. `"scatter[3]"` — quoted verbatim
    /// in diagnostics.
    pub label: String,
    pub streams: Vec<StreamFacts>,
    pub merge: Arc<Merge>,
    pub window: usize,
}

impl PhaseFacts {
    /// Facts of a compiled phase, verbatim: every stream via
    /// [`StreamFacts::of`] with a uniform `owner`, sharing the
    /// phase's merge tree by reference.
    pub fn of(label: impl Into<String>, phase: &Phase, owner: Option<usize>) -> PhaseFacts {
        PhaseFacts {
            label: label.into(),
            streams: phase.streams.iter().map(|s| StreamFacts::of(s, owner)).collect(),
            merge: Arc::clone(&phase.merge),
            window: phase.window,
        }
    }
}

/// The checkable mirror of a compiled [`crate::accel::PhaseProgram`]:
/// everything the static verifier needs, nothing it doesn't. Produced
/// by `PhaseProgram::facts()`; fully public so property suites can
/// hand-mutate a legitimate program into each defect class and assert
/// the checker rejects it.
#[derive(Clone, Debug)]
pub struct ProgramFacts {
    pub accelerator: AcceleratorKind,
    pub vertices: usize,
    pub edges: usize,
    pub channels: usize,
    pub mode: ChannelMode,
    /// Bytes the compile-time layout placed on each channel (indexed
    /// by channel in Region mode). In interleave mode a single entry
    /// holds the global layout extent; it stripes over all channels
    /// and is not capacity-checked (see module docs).
    pub footprint: Vec<u64>,
    pub phases: Vec<PhaseFacts>,
}

impl ProgramFacts {
    /// Assemble facts, deriving per-channel footprints from the
    /// extremal line of every stream. Maximal dynamic stand-ins make
    /// the stream extents cover the compile-time layout, so this is
    /// the layout footprint the capacity check needs. Region mode
    /// gets one slot per channel (unowned streams land on channel
    /// 0); interleave mode gets a single global slot.
    pub fn assemble(
        accelerator: AcceleratorKind,
        vertices: usize,
        edges: usize,
        channels: usize,
        mode: ChannelMode,
        phases: Vec<PhaseFacts>,
    ) -> ProgramFacts {
        let slots = match mode {
            ChannelMode::Region => channels.max(1),
            ChannelMode::InterleaveLine => 1,
        };
        let mut footprint = vec![0u64; slots];
        for phase in &phases {
            for s in &phase.streams {
                let slot = s.owner.unwrap_or(0).min(slots - 1);
                footprint[slot] = footprint[slot].max(s.extent());
            }
        }
        ProgramFacts { accelerator, vertices, edges, channels, mode, footprint, phases }
    }
}

/// Where a violation was found: phase index + label, and the stream
/// index within it when one is implicated.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Site {
    pub phase: usize,
    pub label: String,
    pub stream: Option<usize>,
}

impl Site {
    fn new(phase: usize, label: &str, stream: Option<usize>) -> Site {
        Site { phase, label: label.to_string(), stream }
    }
}

impl fmt::Display for Site {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "phase {} (`{}`)", self.phase, self.label)?;
        if let Some(s) = self.stream {
            write!(f, " stream {s}")?;
        }
        Ok(())
    }
}

/// A structural invariant violation in a compiled program. Every
/// variant names its [`Site`] (or channel), so a diagnostic always
/// points at the offending phase/stream/descriptor.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum VerifyError {
    /// A descriptor line, rebased onto its owner's region, lands
    /// outside that channel's `channel_bytes` (Region mode).
    RegionOverflow { at: Site, index: usize, local: u64, limit: u64, channel: usize },
    /// A stream's declared owner is not a valid channel index.
    ChannelOutOfRange { at: Site, channel: usize, channels: usize },
    /// A channel's compile-time layout exceeds its capacity.
    FootprintOverflow { channel: usize, bytes: u64, limit: u64 },
    /// A chained stream's release schedule does not conserve tokens:
    /// parents release `released` requests, the stream has `len`.
    FanoutMismatch { at: Site, len: usize, released: u64 },
    /// A `PerParent` schedule whose length differs from the parent
    /// stream's length.
    FanoutArity { at: Site, parent_len: usize, schedule_len: usize },
    /// `chained_to` names a stream that does not exist.
    BadParent { at: Site, parent: usize, streams: usize },
    /// Following `chained_to` links revisits a stream.
    ChainCycle { at: Site },
    /// A non-empty phase whose merge tree has no leaves.
    EmptyMerge { at: Site },
    /// A merge-tree leaf referencing a stream that does not exist.
    MergeUnknownStream { at: Site, leaf: usize },
    /// A merge-tree leaf referenced more than once (double-issue).
    MergeDuplicateStream { at: Site, leaf: usize },
    /// A stream no merge-tree leaf covers (it could never issue).
    OrphanStream { at: Site },
    /// A `Gather` index at position `index` with value `value`
    /// escaping its declared domain.
    GatherOutOfRange { at: Site, index: usize, value: u64, domain: u64 },
    /// A non-empty phase with a zero outstanding-request window.
    ZeroWindow { at: Site },
    /// A declared on-chip buffer that cannot work as configured.
    OnChipInconsistent { detail: String },
}

impl fmt::Display for VerifyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            VerifyError::RegionOverflow { at, index, local, limit, channel } => write!(
                f,
                "{at}: line {index} at channel-local address {local:#x} exceeds channel \
                 {channel}'s region of {limit} bytes"
            ),
            VerifyError::ChannelOutOfRange { at, channel, channels } => write!(
                f,
                "{at}: owning channel {channel} out of range for {channels} channels"
            ),
            VerifyError::FootprintOverflow { channel, bytes, limit } => write!(
                f,
                "layout places {bytes} bytes on channel {channel}, exceeding its {limit}-byte \
                 region"
            ),
            VerifyError::FanoutMismatch { at, len, released } => write!(
                f,
                "{at}: fanout releases {released} tokens for {len} requests — the stream would \
                 {}",
                if (*released as u128) < (*len as u128) { "deadlock" } else { "leak tokens" }
            ),
            VerifyError::FanoutArity { at, parent_len, schedule_len } => write!(
                f,
                "{at}: per-parent release schedule has {schedule_len} entries for a parent of \
                 length {parent_len}"
            ),
            VerifyError::BadParent { at, parent, streams } => write!(
                f,
                "{at}: chained to stream {parent}, but the phase has {streams} streams"
            ),
            VerifyError::ChainCycle { at } => {
                write!(f, "{at}: chained-release links form a cycle")
            }
            VerifyError::EmptyMerge { at } => {
                write!(f, "{at}: non-empty phase with an empty merge tree")
            }
            VerifyError::MergeUnknownStream { at, leaf } => {
                write!(f, "{at}: merge tree references unknown stream {leaf}")
            }
            VerifyError::MergeDuplicateStream { at, leaf } => {
                write!(f, "{at}: merge tree references stream {leaf} more than once")
            }
            VerifyError::OrphanStream { at } => {
                write!(f, "{at}: no merge-tree leaf covers this stream — it can never issue")
            }
            VerifyError::GatherOutOfRange { at, index, value, domain } => write!(
                f,
                "{at}: gather index [{index}] = {value} escapes its domain of {domain}"
            ),
            VerifyError::ZeroWindow { at } => {
                write!(f, "{at}: non-empty phase with a zero-request window")
            }
            VerifyError::OnChipInconsistent { detail } => {
                write!(f, "on-chip buffer config inconsistent: {detail}")
            }
        }
    }
}

impl std::error::Error for VerifyError {}

/// Outcome of one verification run: the violations (empty ⇒ the
/// program is structurally sound) plus coverage counters, so callers
/// can report *how much* was proven, not just that nothing failed.
#[derive(Clone, Debug, Default)]
pub struct VerifyReport {
    pub violations: Vec<VerifyError>,
    /// Phases examined.
    pub phases: usize,
    /// Streams examined across all phases.
    pub streams: usize,
    /// Descriptor lines bound-checked (closed-form descriptors are
    /// proven by their extremal lines and count 2).
    pub lines: u64,
}

impl VerifyReport {
    pub fn is_ok(&self) -> bool {
        self.violations.is_empty()
    }
}

impl fmt::Display for VerifyReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} violation(s) over {} phase(s), {} stream(s), {} line(s)",
            self.violations.len(),
            self.phases,
            self.streams,
            self.lines
        )
    }
}

/// The static program verifier. Holds the one piece of context a
/// compiled program does not know about itself: the per-channel
/// capacity of the memory technology it will run against.
#[derive(Clone, Copy, Debug)]
pub struct ProgramChecker {
    channel_bytes: u64,
}

impl ProgramChecker {
    /// A checker for a memory system with `channel_bytes` bytes per
    /// channel (see `DramSpec::channel_bytes`).
    pub fn new(channel_bytes: u64) -> ProgramChecker {
        ProgramChecker { channel_bytes }
    }

    /// Verify a program; `onchip` additionally checks a declared
    /// buffer configuration for consistency.
    pub fn check(&self, facts: &ProgramFacts, onchip: Option<&OnChipConfig>) -> VerifyReport {
        let mut rep = VerifyReport::default();
        for (pi, phase) in facts.phases.iter().enumerate() {
            rep.phases += 1;
            rep.streams += phase.streams.len();
            self.check_window(pi, phase, &mut rep);
            self.check_chains(pi, phase, &mut rep);
            self.check_merge(pi, phase, &mut rep);
            self.check_bounds(facts, pi, phase, &mut rep);
        }
        self.check_footprint(facts, &mut rep);
        if let Some(cfg) = onchip {
            self.check_onchip(cfg, &mut rep);
        }
        rep
    }

    fn check_window(&self, pi: usize, phase: &PhaseFacts, rep: &mut VerifyReport) {
        if phase.window == 0 && !phase.streams.is_empty() {
            rep.violations
                .push(VerifyError::ZeroWindow { at: Site::new(pi, &phase.label, None) });
        }
    }

    /// Chain shape + fanout token conservation (checks 2 and 3).
    fn check_chains(&self, pi: usize, phase: &PhaseFacts, rep: &mut VerifyReport) {
        let n = phase.streams.len();
        for (si, s) in phase.streams.iter().enumerate() {
            let Some(parent) = s.chained_to else { continue };
            let at = Site::new(pi, &phase.label, Some(si));
            if parent >= n || parent == si {
                rep.violations.push(VerifyError::BadParent { at, parent, streams: n });
                continue;
            }
            // Walk the parent links; more than `n` hops means a cycle
            // (each hop visits a distinct stream in an acyclic chain).
            let mut cursor = parent;
            let mut hops = 1usize;
            while let Some(next) = phase.streams[cursor].chained_to {
                if next >= n || next == cursor {
                    break; // reported at its own stream
                }
                cursor = next;
                hops += 1;
                if hops > n {
                    rep.violations.push(VerifyError::ChainCycle { at: at.clone() });
                    break;
                }
            }
            if hops > n {
                continue;
            }
            // Token conservation against the parent's length.
            let parent_len = phase.streams[parent].source.len();
            if let Fanout::PerParent(v) = &s.fanout {
                if v.len() != parent_len {
                    rep.violations.push(VerifyError::FanoutArity {
                        at: at.clone(),
                        parent_len,
                        schedule_len: v.len(),
                    });
                    continue;
                }
            }
            let released = s.fanout.total(parent_len);
            let len = s.source.len();
            if released != len as u64 {
                rep.violations.push(VerifyError::FanoutMismatch { at, len, released });
            }
        }
    }

    /// Merge-tree coverage (check 4): every stream exactly once.
    fn check_merge(&self, pi: usize, phase: &PhaseFacts, rep: &mut VerifyReport) {
        let n = phase.streams.len();
        let mut leaves = Vec::new();
        collect_leaves(&phase.merge, &mut leaves);
        if leaves.is_empty() {
            if n > 0 {
                rep.violations
                    .push(VerifyError::EmptyMerge { at: Site::new(pi, &phase.label, None) });
            }
            return;
        }
        let mut covered = vec![false; n];
        for &leaf in &leaves {
            if leaf >= n {
                rep.violations.push(VerifyError::MergeUnknownStream {
                    at: Site::new(pi, &phase.label, None),
                    leaf,
                });
            } else if covered[leaf] {
                rep.violations.push(VerifyError::MergeDuplicateStream {
                    at: Site::new(pi, &phase.label, Some(leaf)),
                    leaf,
                });
            } else {
                covered[leaf] = true;
            }
        }
        for (si, seen) in covered.iter().enumerate() {
            if !seen {
                rep.violations
                    .push(VerifyError::OrphanStream { at: Site::new(pi, &phase.label, Some(si)) });
            }
        }
    }

    /// Region bounds + gather domains (checks 1 and 5). Bounds are
    /// proven through the same [`ChannelMode::local_addr`] rewrite the
    /// memory system applies at enqueue, so static acceptance implies
    /// the Region-mode `debug_assert!` can never fire for this stream.
    fn check_bounds(
        &self,
        facts: &ProgramFacts,
        pi: usize,
        phase: &PhaseFacts,
        rep: &mut VerifyReport,
    ) {
        for (si, s) in phase.streams.iter().enumerate() {
            // Gather-domain check applies in every channel mode.
            if let (LineSource::Gather { indices, .. }, Some(domain)) =
                (&s.source, s.gather_domain)
            {
                for (i, &idx) in indices.iter().enumerate() {
                    rep.lines += 1;
                    if u64::from(idx) >= domain {
                        rep.violations.push(VerifyError::GatherOutOfRange {
                            at: Site::new(pi, &phase.label, Some(si)),
                            index: i,
                            value: u64::from(idx),
                            domain,
                        });
                        break; // one witness per stream is enough
                    }
                }
            }
            // Region bounds only bind in Region mode: interleaved
            // addresses stripe over all channels by construction.
            if facts.mode != ChannelMode::Region {
                continue;
            }
            let Some(owner) = s.owner else { continue };
            let at = Site::new(pi, &phase.label, Some(si));
            if owner >= facts.channels {
                rep.violations.push(VerifyError::ChannelOutOfRange {
                    at,
                    channel: owner,
                    channels: facts.channels,
                });
                continue;
            }
            let mut check_line = |i: usize, rep: &mut VerifyReport| -> bool {
                let local = s.source.line(i);
                rep.lines += 1;
                // Rebase exactly as the execute path does, then prove
                // the memory system's rewrite routes the line back to
                // its owner at the same local address.
                let global = owner as u64 * self.channel_bytes + local;
                let routed = (global / self.channel_bytes).min(facts.channels as u64 - 1);
                let rewritten =
                    facts.mode.local_addr(global, facts.channels, self.channel_bytes);
                if local + CACHE_LINE > self.channel_bytes
                    || routed != owner as u64
                    || rewritten != local
                {
                    rep.violations.push(VerifyError::RegionOverflow {
                        at: Site::new(pi, &phase.label, Some(si)),
                        index: i,
                        local,
                        limit: self.channel_bytes,
                        channel: owner,
                    });
                    return false;
                }
                true
            };
            let len = s.source.len();
            if len == 0 {
                continue;
            }
            match &s.source {
                // Closed-form descriptors are monotone in `i`: the
                // extremal lines prove the whole span.
                LineSource::Seq { .. } | LineSource::Strided { .. } => {
                    if check_line(0, rep) {
                        check_line(len - 1, rep);
                    }
                }
                LineSource::Gather { .. } | LineSource::Explicit(_) => {
                    for i in 0..len {
                        if !check_line(i, rep) {
                            break; // one witness per stream
                        }
                    }
                }
            }
        }
    }

    /// Per-channel layout capacity (check 6a).
    fn check_footprint(&self, facts: &ProgramFacts, rep: &mut VerifyReport) {
        if facts.mode != ChannelMode::Region {
            return;
        }
        for (channel, &bytes) in facts.footprint.iter().enumerate() {
            if bytes > self.channel_bytes {
                rep.violations.push(VerifyError::FootprintOverflow {
                    channel,
                    bytes,
                    limit: self.channel_bytes,
                });
            }
        }
    }

    /// Declared on-chip buffer consistency (check 6b).
    fn check_onchip(&self, cfg: &OnChipConfig, rep: &mut VerifyReport) {
        if let Err(detail) = cfg.validate() {
            rep.violations.push(VerifyError::OnChipInconsistent { detail: detail.to_string() });
            return;
        }
        if cfg.capacity_bytes() > 0 && cfg.capacity_lines() == 0 {
            rep.violations.push(VerifyError::OnChipInconsistent {
                detail: format!(
                    "a {}-byte budget holds zero {CACHE_LINE}-byte lines",
                    cfg.capacity_bytes()
                ),
            });
        }
    }
}

fn collect_leaves(m: &Merge, out: &mut Vec<usize>) {
    match m {
        Merge::Leaf(s) => out.push(*s),
        Merge::RoundRobin(children) | Merge::Priority(children) => {
            for c in children {
                collect_leaves(c, out);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::stream::LineStream;
    use crate::dram::MemKind;
    use crate::onchip::Geometry;

    const CB: u64 = 1 << 20; // 1 MiB channels keep the arithmetic readable

    fn phase(streams: Vec<StreamFacts>, merge: Merge) -> PhaseFacts {
        PhaseFacts { label: "t[0]".into(), streams, merge: Arc::new(merge), window: 16 }
    }

    fn stream(source: LineSource) -> StreamFacts {
        StreamFacts {
            class: StreamClass::Edges,
            source,
            chained_to: None,
            fanout: Fanout::Uniform(0),
            owner: Some(0),
            gather_domain: None,
            dynamic: false,
        }
    }

    fn facts(phases: Vec<PhaseFacts>) -> ProgramFacts {
        ProgramFacts {
            accelerator: AcceleratorKind::HitGraph,
            vertices: 64,
            edges: 256,
            channels: 4,
            mode: ChannelMode::Region,
            footprint: vec![0; 4],
            phases,
        }
    }

    fn check(f: &ProgramFacts) -> VerifyReport {
        ProgramChecker::new(CB).check(f, None)
    }

    #[test]
    fn a_well_formed_phase_passes() {
        let f = facts(vec![phase(
            vec![stream(LineSource::seq(0, 4096))],
            Merge::Leaf(0),
        )]);
        let rep = check(&f);
        assert!(rep.is_ok(), "{rep}: {:?}", rep.violations);
        assert_eq!(rep.phases, 1);
        assert_eq!(rep.streams, 1);
        assert!(rep.lines >= 2, "both extremal lines proven");
    }

    #[test]
    fn seq_straddling_its_region_is_rejected_via_the_shared_rewrite() {
        // Last line of the span lands at local CB → routed to owner+1.
        let f = facts(vec![phase(
            vec![stream(LineSource::seq(CB - 64, 128))],
            Merge::Leaf(0),
        )]);
        let rep = check(&f);
        assert!(matches!(
            rep.violations.as_slice(),
            [VerifyError::RegionOverflow { local, channel: 0, .. }] if *local == CB
        ));
    }

    #[test]
    fn last_channel_clamping_does_not_hide_overflow() {
        // Region routing clamps to the last channel, so an overflow on
        // channel C-1 still *routes* "correctly" — the rewrite check
        // alone would miss it; the explicit limit check must not.
        let mut f = facts(vec![phase(
            vec![stream(LineSource::seq(CB, 64))],
            Merge::Leaf(0),
        )]);
        f.phases[0].streams[0].owner = Some(3);
        let rep = check(&f);
        assert!(matches!(
            rep.violations.as_slice(),
            [VerifyError::RegionOverflow { channel: 3, .. }]
        ));
    }

    #[test]
    fn gather_index_outside_its_domain_is_rejected() {
        let mut s = stream(LineSource::gather(0, 4, [3u64, 64, 2]));
        s.gather_domain = Some(64);
        let f = facts(vec![phase(vec![s], Merge::Leaf(0))]);
        let rep = check(&f);
        assert!(matches!(
            rep.violations.as_slice(),
            [VerifyError::GatherOutOfRange { index: 1, value: 64, domain: 64, .. }]
        ));
    }

    #[test]
    fn fanout_over_and_under_release_are_both_rejected() {
        for (k, expect_ok) in [(1u32, true), (2, false), (0, false)] {
            let parent = stream(LineSource::seq(0, 4 * 64));
            let mut child = stream(LineSource::seq(4096, 4 * 64));
            child.chained_to = Some(0);
            child.fanout = Fanout::Uniform(k);
            let f = facts(vec![phase(vec![parent, child], Merge::prio([1, 0]))]);
            let rep = check(&f);
            assert_eq!(rep.is_ok(), expect_ok, "uniform fanout {k}");
            if !expect_ok {
                assert!(matches!(
                    rep.violations.as_slice(),
                    [VerifyError::FanoutMismatch { len: 4, .. }]
                ));
            }
        }
    }

    #[test]
    fn per_parent_arity_mismatch_is_rejected() {
        let parent = stream(LineSource::seq(0, 4 * 64));
        let mut child = stream(LineSource::seq(4096, 64));
        child.chained_to = Some(0);
        child.fanout = Fanout::PerParent(vec![1u32].into()); // parent has 4 lines
        let f = facts(vec![phase(vec![parent, child], Merge::prio([1, 0]))]);
        assert!(matches!(
            check(&f).violations.as_slice(),
            [VerifyError::FanoutArity { parent_len: 4, schedule_len: 1, .. }]
        ));
    }

    #[test]
    fn bad_parent_and_chain_cycle_are_rejected() {
        let mut a = stream(LineSource::seq(0, 64));
        a.chained_to = Some(7);
        let f = facts(vec![phase(vec![a], Merge::Leaf(0))]);
        assert!(matches!(
            check(&f).violations.as_slice(),
            [VerifyError::BadParent { parent: 7, streams: 1, .. }]
        ));

        let mut a = stream(LineSource::seq(0, 64));
        a.chained_to = Some(1);
        a.fanout = Fanout::Uniform(1);
        let mut b = stream(LineSource::seq(64, 64));
        b.chained_to = Some(0);
        b.fanout = Fanout::Uniform(1);
        let f = facts(vec![phase(vec![a, b], Merge::rr([0, 1]))]);
        assert!(
            check(&f)
                .violations
                .iter()
                .any(|v| matches!(v, VerifyError::ChainCycle { .. })),
            "mutual chain is a cycle"
        );
    }

    #[test]
    fn merge_orphan_duplicate_unknown_and_empty_are_rejected() {
        let two = || vec![stream(LineSource::seq(0, 64)), stream(LineSource::seq(64, 64))];
        let orphan = facts(vec![phase(two(), Merge::Leaf(0))]);
        assert!(matches!(
            check(&orphan).violations.as_slice(),
            [VerifyError::OrphanStream { at }] if at.stream == Some(1)
        ));

        let dup = facts(vec![phase(two(), Merge::rr([0, 1, 0]))]);
        assert!(matches!(
            check(&dup).violations.as_slice(),
            [VerifyError::MergeDuplicateStream { leaf: 0, .. }]
        ));

        let unknown = facts(vec![phase(two(), Merge::rr([0, 1, 9]))]);
        assert!(matches!(
            check(&unknown).violations.as_slice(),
            [VerifyError::MergeUnknownStream { leaf: 9, .. }]
        ));

        let empty = facts(vec![phase(two(), Merge::RoundRobin(Vec::new()))]);
        assert!(matches!(
            check(&empty).violations.as_slice(),
            [VerifyError::EmptyMerge { .. }]
        ));
    }

    #[test]
    fn footprint_overflow_and_zero_window_are_rejected() {
        let mut f = facts(vec![phase(vec![stream(LineSource::seq(0, 64))], Merge::Leaf(0))]);
        f.footprint[2] = CB + 1;
        f.phases[0].window = 0;
        let rep = check(&f);
        assert!(rep.violations.iter().any(
            |v| matches!(v, VerifyError::FootprintOverflow { channel: 2, .. })
        ));
        assert!(rep.violations.iter().any(|v| matches!(v, VerifyError::ZeroWindow { .. })));
    }

    #[test]
    fn interleave_mode_skips_region_checks_but_not_gather_domains() {
        let mut s = stream(LineSource::seq(100 * CB, 4096)); // far past one channel
        s.owner = None;
        let mut g = stream(LineSource::gather(0, 4, [999u64]));
        g.gather_domain = Some(10);
        let mut f = facts(vec![phase(vec![s, g], Merge::rr([0, 1]))]);
        f.mode = ChannelMode::InterleaveLine;
        f.footprint = vec![100 * CB];
        let rep = check(&f);
        assert!(matches!(
            rep.violations.as_slice(),
            [VerifyError::GatherOutOfRange { .. }]
        ));
    }

    #[test]
    fn onchip_inconsistencies_are_rejected() {
        let f = facts(Vec::new());
        let checker = ProgramChecker::new(CB);
        // Sub-line budget: validates, but holds zero lines.
        let tiny = OnChipConfig::vertex_cache(32);
        assert!(matches!(
            checker.check(&f, Some(&tiny)).violations.as_slice(),
            [VerifyError::OnChipInconsistent { .. }]
        ));
        // Zero-way set-associative geometry fails validate().
        let zero_ways = OnChipConfig::new(
            1 << 14,
            Geometry::SetAssociative { ways: 0 },
            [crate::trace::Region::Vertices],
        );
        assert!(matches!(
            checker.check(&f, Some(&zero_ways)).violations.as_slice(),
            [VerifyError::OnChipInconsistent { .. }]
        ));
        // A healthy buffer passes.
        assert!(checker.check(&f, Some(&OnChipConfig::vertex_cache(1 << 14))).is_ok());
    }

    #[test]
    fn real_streams_convert_to_facts_shape() {
        // The facts builders clone compiled LineStreams; mirror that
        // here to pin the field mapping.
        let ls = LineStream::chained(
            StreamClass::Values,
            MemKind::Read,
            LineSource::seq(0, 256),
            0,
            Fanout::AfterLast(4),
        );
        let sf = StreamFacts {
            class: ls.class,
            source: ls.source.clone(),
            chained_to: ls.chained_to,
            fanout: ls.fanout.clone(),
            owner: None,
            gather_domain: None,
            dynamic: false,
        };
        assert_eq!(sf.chained_to, Some(0));
        assert_eq!(sf.source.len(), 4);
    }
}
