//! Hand-rolled, dependency-free repo invariant linter
//! (`graphmem lint --src`).
//!
//! Three passes over `rust/src/`, all pure text — no syn, no regex
//! crate, nothing the container doesn't already have:
//!
//! 1. **Panic hygiene.** No `.unwrap()` / `.expect(` in library code.
//!    Test modules (everything after a `#[cfg(test)]`-attributed
//!    `mod`) are exempt, matching the crate-level
//!    `#![warn(clippy::unwrap_used, clippy::expect_used)]` gate.
//!    Grandfathered sites live in `lint-allowlist.txt` next to
//!    `Cargo.toml`; the recorded count is a **ratchet** — a file may
//!    only ever go down. Exceeding its entry (or appearing without
//!    one) fails the lint; dropping below it prints a tighten notice.
//! 2. **Memo-key coverage.** Every field of `sim::SimSpec` *is* the
//!    memo key (the struct derives `Hash`/`Eq`), and `persist`
//!    serializes it for the disk cache. PR 1 fixed a stale-cache bug
//!    caused by exactly this invariant rotting; this pass
//!    cross-references the `SimSpec` struct fields in `sim/spec.rs`
//!    against both the `spec_to_line` format keys and the
//!    `spec_from_line_with` parser keys in `persist/mod.rs`, through
//!    the field↔key table [`FIELD_KEYS`]. Adding a spec field without
//!    updating the table, the serializer, *and* the parser is a lint
//!    failure — in CI, not in a user's stale cache.
//! 3. **Determinism.** No `Instant::now` / `SystemTime` in the
//!    deterministic simulation paths (`sim/`, `dram/`, `accel/`):
//!    bit-identical replay (heap/scan equivalence, trace-vs-live,
//!    disk-cache round trips) forbids wall-clock reads there.
//!    Wall-clock use belongs in `robust/` (budget deadlines) and the
//!    CLI.

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// The `SimSpec` field ↔ serializer key table pass 2 checks both
/// sides against. A new `SimSpec` field must be added here *and* to
/// `persist`'s serializer + parser; a new serializer key must trace
/// back to a field. (`config` fans out into its per-field keys.)
pub const FIELD_KEYS: &[(&str, &[&str])] = &[
    ("accelerator", &["accel"]),
    ("workload", &["graph"]),
    ("problem", &["problem"]),
    ("mem", &["mem"]),
    ("channels", &["channels"]),
    ("patterns", &["patterns"]),
    ("config", &["opts", "bram", "interval", "pes", "window", "xmc"]),
    ("onchip", &["onchip"]),
    ("budget", &["budget"]),
    ("faults", &["faults"]),
    ("verify", &["verify"]),
];

/// Directories whose files must never read the wall clock.
pub const DETERMINISTIC_DIRS: &[&str] = &["sim", "dram", "accel"];

// Spelled via concat! so the linter does not flag (or mode-flip on)
// its own pattern literals when scanning this file.
const UNWRAP_PAT: &str = concat!(".unw", "rap()");
const EXPECT_PAT: &str = concat!(".exp", "ect(");
const CFG_TEST_PAT: &str = concat!("#[cfg(te", "st)]");
const INSTANT_PAT: &str = concat!("Instant::", "now");
const SYSTIME_PAT: &str = concat!("System", "Time");

/// One lint violation, with enough location to act on.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LintViolation {
    /// Path relative to the source root, forward slashes.
    pub file: String,
    /// 1-based line, or 0 for whole-file findings.
    pub line: usize,
    pub message: String,
}

impl fmt::Display for LintViolation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.line > 0 {
            write!(f, "{}:{}: {}", self.file, self.line, self.message)
        } else {
            write!(f, "{}: {}", self.file, self.message)
        }
    }
}

/// Outcome of a source lint run. `violations` empty ⇒ pass;
/// `notices` are non-fatal (ratchet-tightening opportunities).
#[derive(Clone, Debug, Default)]
pub struct SrcLintReport {
    pub violations: Vec<LintViolation>,
    pub notices: Vec<String>,
    /// `.rs` files scanned.
    pub files: usize,
    /// Non-test unwrap/expect sites found (allowlisted or not).
    pub unwrap_sites: usize,
}

impl SrcLintReport {
    pub fn is_ok(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Per-file scan result of pass 1 + pass 3 (pure text, unit-testable
/// without a filesystem).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FileScan {
    /// 1-based lines of non-test `.unwrap()` / `.expect(` sites
    /// (one entry per occurrence).
    pub unwraps: Vec<usize>,
    /// 1-based lines of wall-clock reads (reported only for files
    /// under [`DETERMINISTIC_DIRS`]).
    pub timing: Vec<usize>,
}

/// Scan one file's text. Comment text (`//` to end of line) is
/// ignored; everything after a `#[cfg(test)]`-attributed `mod` is
/// treated as test code and exempt from the unwrap pass (the repo
/// convention is one test module at the end of each file).
pub fn scan_file(text: &str) -> FileScan {
    let mut scan = FileScan::default();
    let mut pending_cfg_test = false;
    let mut in_test = false;
    for (i, raw) in text.lines().enumerate() {
        let line = match raw.find("//") {
            Some(p) => &raw[..p],
            None => raw,
        };
        if !in_test {
            if line.contains(CFG_TEST_PAT) {
                pending_cfg_test = true;
            } else if pending_cfg_test && contains_mod(line) {
                in_test = true;
            }
        }
        if !in_test {
            let hits = line.matches(UNWRAP_PAT).count() + line.matches(EXPECT_PAT).count();
            for _ in 0..hits {
                scan.unwraps.push(i + 1);
            }
        }
        if line.contains(INSTANT_PAT) || line.contains(SYSTIME_PAT) {
            scan.timing.push(i + 1);
        }
    }
    scan
}

fn contains_mod(line: &str) -> bool {
    line.split_whitespace().any(|w| w == "mod")
}

/// Parse an allowlist: one `path count` pair per line, `#` comments
/// and blank lines ignored. Malformed lines are reported as
/// violations (a corrupt ratchet must not silently allow anything).
pub fn parse_allowlist(text: &str) -> (Vec<(String, usize)>, Vec<LintViolation>) {
    let mut entries = Vec::new();
    let mut bad = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        match (it.next(), it.next().map(str::parse::<usize>), it.next()) {
            (Some(path), Some(Ok(count)), None) => entries.push((path.to_string(), count)),
            _ => bad.push(LintViolation {
                file: "lint-allowlist.txt".to_string(),
                line: i + 1,
                message: format!("malformed allowlist entry {line:?} (want `path count`)"),
            }),
        }
    }
    (entries, bad)
}

/// Pass 2: cross-reference the `SimSpec` struct fields (text of
/// `sim/spec.rs`) against `persist`'s serializer format keys and
/// parser keys (text of `persist/mod.rs`) through [`FIELD_KEYS`].
pub fn memo_key_coverage(spec_text: &str, persist_text: &str) -> Vec<LintViolation> {
    let mut out = Vec::new();
    let at = |file: &str, msg: String| LintViolation {
        file: file.to_string(),
        line: 0,
        message: msg,
    };

    let fields = struct_fields(spec_text, "pub struct SimSpec");
    if fields.is_empty() {
        out.push(at("sim/spec.rs", "could not locate `pub struct SimSpec` fields".into()));
        return out;
    }
    // Struct ↔ table, both directions.
    for f in &fields {
        if !FIELD_KEYS.iter().any(|(name, _)| name == f) {
            out.push(at(
                "sim/spec.rs",
                format!(
                    "SimSpec field `{f}` (part of the memo key) has no serializer keys in \
                     verify::srclint::FIELD_KEYS — add it there and to persist's \
                     spec_to_line/spec_from_line_with"
                ),
            ));
        }
    }
    for (name, _) in FIELD_KEYS {
        if !fields.iter().any(|f| f == name) {
            out.push(at(
                "sim/spec.rs",
                format!("FIELD_KEYS names `{name}`, which is not a SimSpec field"),
            ));
        }
    }

    // Table ↔ serializer format string ↔ parser takes, as sets.
    let ser = format_keys(body_of(persist_text, "fn spec_to_line"));
    let par = take_keys(body_of(persist_text, "fn spec_from_line_with"));
    if ser.is_empty() {
        out.push(at("persist/mod.rs", "could not locate spec_to_line format keys".into()));
        return out;
    }
    if par.is_empty() {
        out.push(at("persist/mod.rs", "could not locate spec_from_line_with keys".into()));
        return out;
    }
    for (field, keys) in FIELD_KEYS {
        for key in *keys {
            if !ser.iter().any(|k| k == key) {
                out.push(at(
                    "persist/mod.rs",
                    format!("field `{field}`: key `{key}` missing from spec_to_line"),
                ));
            }
            if !par.iter().any(|k| k == key) {
                out.push(at(
                    "persist/mod.rs",
                    format!("field `{field}`: key `{key}` missing from spec_from_line_with"),
                ));
            }
        }
    }
    let known = |k: &String| FIELD_KEYS.iter().any(|(_, keys)| keys.contains(&k.as_str()));
    for k in ser.iter().filter(|k| !known(k)) {
        out.push(at(
            "persist/mod.rs",
            format!("spec_to_line key `{k}` maps to no SimSpec field in FIELD_KEYS"),
        ));
    }
    for k in par.iter().filter(|k| !known(k)) {
        out.push(at(
            "persist/mod.rs",
            format!("spec_from_line_with key `{k}` maps to no SimSpec field in FIELD_KEYS"),
        ));
    }
    out
}

/// Field names of the struct declared by `decl` (e.g.
/// `"pub struct SimSpec"`): identifiers of `name: Type,` lines
/// between the opening brace and the first `}` at declaration depth.
fn struct_fields(text: &str, decl: &str) -> Vec<String> {
    let Some(start) = text.find(decl) else { return Vec::new() };
    let body = &text[start..];
    let Some(open) = body.find('{') else { return Vec::new() };
    let mut fields = Vec::new();
    for line in body[open + 1..].lines() {
        let line = match line.find("//") {
            Some(p) => &line[..p],
            None => line,
        };
        let t = line.trim();
        if t.starts_with('}') {
            break;
        }
        if t.starts_with('#') {
            continue; // attribute
        }
        let t = t.strip_prefix("pub ").unwrap_or(t);
        if let Some((name, _ty)) = t.split_once(':') {
            let name = name.trim();
            if !name.is_empty()
                && name.chars().all(|c| c.is_ascii_lowercase() || c == '_')
            {
                fields.push(name.to_string());
            }
        }
    }
    fields
}

/// The body of the function whose signature contains `sig`: text
/// from the match to the next top-level `fn` declaration (good
/// enough for key extraction; both persist functions are top-level).
fn body_of<'t>(text: &'t str, sig: &str) -> &'t str {
    let Some(start) = text.find(sig) else { return "" };
    let rest = &text[start + sig.len()..];
    let end = ["\npub fn ", "\nfn "]
        .iter()
        .filter_map(|pat| rest.find(pat))
        .min()
        .unwrap_or(rest.len());
    &rest[..end]
}

/// `key={}` tokens of a format string: for every `={}` occurrence,
/// the identifier immediately before it.
fn format_keys(body: &str) -> Vec<String> {
    let bytes = body.as_bytes();
    let mut keys = Vec::new();
    let mut from = 0;
    while let Some(p) = body[from..].find("={}") {
        let at = from + p;
        let mut s = at;
        while s > 0 && (bytes[s - 1].is_ascii_alphanumeric() || bytes[s - 1] == b'_') {
            s -= 1;
        }
        if s < at {
            keys.push(body[s..at].to_string());
        }
        from = at + 3;
    }
    keys
}

/// String arguments of `.take("…")` calls.
fn take_keys(body: &str) -> Vec<String> {
    let mut keys = Vec::new();
    let mut from = 0;
    while let Some(p) = body[from..].find(".take(\"") {
        let at = from + p + ".take(\"".len();
        if let Some(q) = body[at..].find('"') {
            keys.push(body[at..at + q].to_string());
            from = at + q;
        } else {
            break;
        }
    }
    keys
}

/// Walk `src_root` (a crate `src/` directory) and run all three
/// passes; `allowlist_text` is the content of `lint-allowlist.txt`
/// (empty string ⇒ nothing grandfathered). Only I/O errors are `Err`;
/// lint findings are data in the report.
pub fn lint_sources(src_root: &Path, allowlist_text: &str) -> io::Result<SrcLintReport> {
    let mut rep = SrcLintReport::default();
    let (allow, bad) = parse_allowlist(allowlist_text);
    rep.violations.extend(bad);

    let mut files = Vec::new();
    collect_rs_files(src_root, src_root, &mut files)?;
    files.sort();

    let mut spec_text = None;
    let mut persist_text = None;
    for rel in &files {
        rep.files += 1;
        let text = fs::read_to_string(src_root.join(rel))?;
        let scan = scan_file(&text);
        rep.unwrap_sites += scan.unwraps.len();

        let allowed = allow
            .iter()
            .find(|(p, _)| p == rel)
            .map(|&(_, n)| n)
            .unwrap_or(0);
        let found = scan.unwraps.len();
        if found > allowed {
            let first_new = scan.unwraps.get(allowed).copied().unwrap_or(0);
            rep.violations.push(LintViolation {
                file: rel.clone(),
                line: first_new,
                message: format!(
                    "{found} non-test unwrap/expect site(s), allowlist grants {allowed} — \
                     return a typed error instead (the allowlist only ratchets down)"
                ),
            });
        } else if found < allowed {
            rep.notices.push(format!(
                "{rel}: allowlist grants {allowed} unwrap/expect site(s) but only {found} \
                 remain — tighten lint-allowlist.txt"
            ));
        }

        if DETERMINISTIC_DIRS.iter().any(|d| rel.starts_with(&format!("{d}/"))) {
            for line in &scan.timing {
                rep.violations.push(LintViolation {
                    file: rel.clone(),
                    line: *line,
                    message: "wall-clock read in a deterministic sim path (move timing to \
                              robust/ or the CLI)"
                        .to_string(),
                });
            }
        }

        if rel == "sim/spec.rs" {
            spec_text = Some(text);
        } else if rel == "persist/mod.rs" {
            persist_text = Some(text);
        }
    }

    for (path, _) in &allow {
        if !files.iter().any(|f| f == path) {
            rep.violations.push(LintViolation {
                file: path.clone(),
                line: 0,
                message: "allowlisted file does not exist — remove its entry".to_string(),
            });
        }
    }

    match (spec_text, persist_text) {
        (Some(spec), Some(persist)) => {
            rep.violations.extend(memo_key_coverage(&spec, &persist));
        }
        _ => rep.violations.push(LintViolation {
            file: "sim/spec.rs".to_string(),
            line: 0,
            message: "memo-key coverage pass needs sim/spec.rs and persist/mod.rs under the \
                      source root"
                .to_string(),
        }),
    }

    Ok(rep)
}

fn collect_rs_files(root: &Path, dir: &Path, out: &mut Vec<String>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(root, &path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            if let Ok(rel) = path.strip_prefix(root) {
                let rel: Vec<String> = rel
                    .components()
                    .map(|c| c.as_os_str().to_string_lossy().into_owned())
                    .collect();
                out.push(rel.join("/"));
            }
        }
    }
    Ok(())
}

/// Locate the crate source root (`…/rust/src`) from a starting
/// directory: accepts the repo root, the crate root, or `src` itself.
pub fn find_src_root(start: &Path) -> Option<PathBuf> {
    for candidate in [start.join("rust/src"), start.join("src"), start.to_path_buf()] {
        if candidate.join("lib.rs").is_file() {
            return Some(candidate);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    // Assembled so this file's own scan never sees the patterns.
    fn uw(recv: &str) -> String {
        format!("let x = {recv}{};\n", concat!(".unw", "rap()"))
    }

    #[test]
    fn scan_counts_non_test_unwraps_and_skips_comments_and_tests() {
        let mut text = String::new();
        text.push_str(&uw("a")); // line 1: counted
        text.push_str(&format!("// {}", uw("c"))); // comment: skipped
        text.push_str("fn f() {}\n");
        text.push_str(concat!("#[cfg(te", "st)]\n"));
        text.push_str("mod tests {\n");
        text.push_str(&uw("b")); // in tests: skipped
        text.push_str("}\n");
        let scan = scan_file(&text);
        assert_eq!(scan.unwraps, vec![1]);
    }

    #[test]
    fn expect_calls_count_but_unwrap_or_variants_do_not() {
        let text = format!(
            "a{}\"m\");\nb.unwrap_or(0);\nc.unwrap_or_else(d);\n",
            concat!(".exp", "ect(")
        );
        assert_eq!(scan_file(&text).unwraps, vec![1]);
    }

    #[test]
    fn timing_reads_are_flagged_with_lines() {
        let text = format!("fn f() {{\nlet t = {};\n}}\n", concat!("Instant::", "now()"));
        assert_eq!(scan_file(&text).timing, vec![2]);
    }

    #[test]
    fn allowlist_parses_and_rejects_malformed_lines() {
        let (entries, bad) = parse_allowlist("# c\n\ngraph/io.rs 6\nbad line here\n");
        assert_eq!(entries, vec![("graph/io.rs".to_string(), 6)]);
        assert_eq!(bad.len(), 1);
        assert_eq!(bad[0].line, 4);
    }

    const SPEC_OK: &str = "
pub struct SimSpec {
    accelerator: AcceleratorKind,
    workload: Workload,
    problem: ProblemKind,
    mem: MemTech,
    channels: usize,
    patterns: bool,
    config: AcceleratorConfig,
    onchip: Option<OnChipConfig>,
    budget: Option<RunBudget>,
    faults: Option<FaultPlan>,
    verify: bool,
}
";

    fn persist_ok() -> String {
        let keys = "accel={} graph={} problem={} mem={} channels={} patterns={} opts={} \
                    bram={} interval={} pes={} window={} xmc={} onchip={} budget={} \
                    faults={} verify={}";
        let takes: String = [
            "accel", "graph", "problem", "mem", "channels", "patterns", "opts", "bram",
            "interval", "pes", "window", "xmc", "onchip", "budget", "faults", "verify",
        ]
        .iter()
        .map(|k| format!("    let _ = t.take(\"{k}\")?;\n"))
        .collect();
        format!("pub fn spec_to_line() {{ \"{keys}\" }}\npub fn spec_from_line_with() {{\n{takes}}}\n")
    }

    #[test]
    fn memo_key_coverage_accepts_a_consistent_pair() {
        let v = memo_key_coverage(SPEC_OK, &persist_ok());
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn a_new_spec_field_without_serializer_keys_fails() {
        let spec = SPEC_OK.replace("    verify: bool,", "    verify: bool,\n    shiny: u32,");
        let v = memo_key_coverage(&spec, &persist_ok());
        assert!(v.iter().any(|x| x.message.contains("`shiny`")), "{v:?}");
    }

    #[test]
    fn a_serializer_key_missing_from_the_parser_fails() {
        let persist = persist_ok().replace("    let _ = t.take(\"verify\")?;\n", "");
        let v = memo_key_coverage(SPEC_OK, &persist);
        assert!(
            v.iter().any(|x| x.message.contains("missing from spec_from_line_with")),
            "{v:?}"
        );
    }

    #[test]
    fn a_format_key_absent_from_the_table_fails() {
        let persist = persist_ok().replace("faults={} verify={}", "faults={} verify={} rogue={}");
        let v = memo_key_coverage(SPEC_OK, &persist);
        assert!(v.iter().any(|x| x.message.contains("`rogue`")), "{v:?}");
    }

    #[test]
    fn the_live_repo_sources_pass_the_linter() {
        // The real check CI runs via `graphmem lint --src`, kept as a
        // unit test so `cargo test` catches regressions first.
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src");
        let allow = fs::read_to_string(
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("lint-allowlist.txt"),
        )
        .unwrap_or_default();
        let rep = lint_sources(&root, &allow).expect("source tree is readable");
        assert!(
            rep.is_ok(),
            "source lint violations:\n{}",
            rep.violations
                .iter()
                .map(|v| v.to_string())
                .collect::<Vec<_>>()
                .join("\n")
        );
        assert!(rep.files > 20, "walked the real tree ({} files)", rep.files);
    }
}
