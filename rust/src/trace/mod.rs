//! Memory-access-pattern tracing and analysis — the subsystem that
//! turns raw request streams into the insights the paper is about
//! (Figs. 8–11): *which data structure* causes the traffic, *how
//! sequential* it is, and *how it behaves against the row buffers*.
//!
//! Three layers:
//!
//! * [`Region`] / [`TraceEvent`] ([`record`]) — every off-chip request
//!   carries a region tag (edges / vertices / updates / payload)
//!   stamped by the accelerator models at issue time, plus the text
//!   trace format for writing and re-reading event streams.
//! * [`AccessPatternAnalyzer`] ([`analysis`]) — a streaming analyzer
//!   over issue-order events: per-region request/byte counts,
//!   sequential-vs-strided-vs-random classification with maximal-run
//!   lengths, per-region and per-channel reuse-interval histograms
//!   (the region ones predict the [`crate::onchip`] buffer's hit rate
//!   via [`RegionSummary::predicted_hit_rate`]), and row-locality
//!   histograms. The same analyzer runs inside a live simulation
//!   (attach via `SimSpecBuilder::patterns(true)`) or over a trace
//!   file (`graphmem analyze --trace`), and produces bit-identical
//!   [`AccessPatternSummary`] values for the same event stream.
//! * Consumers — [`crate::sim::SimReport::patterns`] carries the
//!   summary through [`crate::sim::Session`] sweeps, and
//!   [`crate::report::pattern_tables`] renders the paper-style tables.
//!
//! # Example
//!
//! Feed a synthetic sequential edge stream through the analyzer:
//!
//! ```
//! use graphmem::dram::{ChannelMode, MemKind, MemTech};
//! use graphmem::trace::{AccessPatternAnalyzer, Region, TraceEvent};
//!
//! let mut analyzer =
//!     AccessPatternAnalyzer::new(MemTech::Ddr4.spec(1), ChannelMode::InterleaveLine);
//! for i in 0..64u64 {
//!     analyzer.observe(&TraceEvent {
//!         addr: i * 64,
//!         kind: MemKind::Read,
//!         region: Region::Edges,
//!         arrival: i,
//!         channel: 0,
//!     });
//! }
//! let summary = analyzer.finish();
//! let edges = summary.region(Region::Edges);
//! assert_eq!(edges.reads, 64);
//! assert!(edges.seq_fraction() > 0.9); // 63 of 64 accesses continue the walk
//! let (hit, _, _) = edges.row_mix();
//! assert!(hit > 0.9); // one 8 KiB row miss, then row hits
//! ```
//!
//! To get the same summary from a full simulation instead, build the
//! spec with `.patterns(true)` and read `SimReport::patterns` — see
//! [`crate::sim::spec::SimSpecBuilder::patterns`].

pub mod analysis;
pub mod record;

pub use analysis::{
    AccessPatternAnalyzer, AccessPatternSummary, ChannelSummary, Histogram, RegionSummary,
};
pub use record::{
    parse_events, parse_line, parse_meta, write_events, write_meta, Region, TraceEvent, TraceMeta,
};
