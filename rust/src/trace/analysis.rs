//! Streaming access-pattern analyzers: per-region traffic accounting,
//! sequential / strided / random classification with run lengths,
//! per-region and per-channel reuse-interval histograms and
//! row-locality histograms — the quantities behind the paper's
//! Figs. 8–11 discussion. The per-region reuse histograms additionally
//! predict the hit rate of the on-chip buffer model
//! ([`RegionSummary::predicted_hit_rate`] — see [`crate::onchip`]),
//! closing the loop between measurement and simulation.
//!
//! The analyzer consumes [`TraceEvent`]s **in issue order** and never
//! looks at controller scheduling. Row locality is therefore computed
//! under an in-order, open-page, single-row-buffer-per-bank model: it
//! is a property of the *request pattern* itself, independent of
//! FR-FCFS reordering. The controller-measured mix stays available in
//! [`crate::dram::DramStats`]; comparing the two shows how much the
//! scheduler recovers. Because the analyzer only depends on the event
//! stream, analyzing a live simulation and re-analyzing its written
//! trace file produce bit-identical summaries.

use super::record::{Region, TraceEvent};
use crate::dram::{AddrMap, AddressMapper, ChannelMode, DramSpec, MemKind, CACHE_LINE};
use std::collections::HashMap;

/// Power-of-two bucketed histogram: bucket 0 holds value 0, bucket
/// `k >= 1` holds values in `[2^(k-1), 2^k)`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Histogram {
    /// Bucket counts; only grown, never shrunk, so two identical
    /// streams produce structurally equal histograms.
    counts: Vec<u64>,
    total: u64,
    sum: u64,
}

impl Histogram {
    pub fn record(&mut self, value: u64) {
        let bucket = if value == 0 {
            0
        } else {
            64 - value.leading_zeros() as usize
        };
        if self.counts.len() <= bucket {
            self.counts.resize(bucket + 1, 0);
        }
        self.counts[bucket] += 1;
        self.total += 1;
        self.sum += value;
    }

    /// Number of recorded values.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Mean of recorded values (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    /// Sum of recorded values.
    pub fn sum(&self) -> u64 {
        self.sum
    }

    /// Bucket counts, lowest bucket first (`buckets()[0]` = exact
    /// zeros, `buckets()[k]` = values in `[2^(k-1), 2^k)`).
    pub fn buckets(&self) -> &[u64] {
        &self.counts
    }

    /// Rebuild a histogram from its serialized parts — the inverse of
    /// reading [`Histogram::buckets`]/[`Histogram::count`]/
    /// [`Histogram::sum`]. The parts are stored verbatim (no
    /// renormalization), so a round trip through `crate::persist` is
    /// structurally equal to the original.
    pub fn from_parts(counts: Vec<u64>, total: u64, sum: u64) -> Histogram {
        Histogram { counts, total, sum }
    }

    /// Upper bound (exclusive) of bucket `k`.
    pub fn bucket_limit(k: usize) -> u64 {
        if k == 0 {
            1
        } else {
            1u64 << k
        }
    }
}

/// How one access relates to the previous access of its region.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum StepClass {
    Sequential,
    Strided,
    Random,
}

/// Per-region accumulation state.
#[derive(Clone, Debug, Default)]
struct RegionState {
    reads: u64,
    writes: u64,
    sequential: u64,
    strided: u64,
    random: u64,
    last_addr: Option<u64>,
    last_delta: Option<i64>,
    /// Length of the current maximal sequential run.
    run_len: u64,
    run_lengths: Histogram,
    /// Region-local reuse intervals: same-region accesses between two
    /// touches of the same cache line — the input a region-scoped
    /// on-chip buffer model needs (see
    /// [`RegionSummary::predicted_hit_rate`]).
    reuse: Histogram,
    /// line -> sequence number of its last access in this region.
    last_seen: HashMap<u64, u64>,
    seq: u64,
}

impl RegionState {
    fn observe(&mut self, addr: u64, kind: MemKind) {
        if kind == MemKind::Write {
            self.writes += 1;
        } else {
            self.reads += 1;
        }
        let line = addr / CACHE_LINE;
        if let Some(prev) = self.last_seen.insert(line, self.seq) {
            self.reuse.record(self.seq - prev);
        }
        self.seq += 1;
        let class = match self.last_addr {
            None => StepClass::Random,
            Some(prev) => {
                let delta = addr.wrapping_sub(prev) as i64;
                let class = if delta == CACHE_LINE as i64 {
                    StepClass::Sequential
                } else if delta != 0 && self.last_delta == Some(delta) {
                    StepClass::Strided
                } else {
                    StepClass::Random
                };
                self.last_delta = Some(delta);
                class
            }
        };
        self.last_addr = Some(addr);
        match class {
            StepClass::Sequential => {
                self.sequential += 1;
                self.run_len += 1;
            }
            StepClass::Strided | StepClass::Random => {
                if class == StepClass::Strided {
                    self.strided += 1;
                } else {
                    self.random += 1;
                }
                // A non-sequential step ends the current run.
                if self.run_len > 0 {
                    self.run_lengths.record(self.run_len);
                }
                self.run_len = 1;
            }
        }
    }

    fn finish_runs(&mut self) {
        if self.run_len > 0 {
            self.run_lengths.record(self.run_len);
            self.run_len = 0;
        }
    }
}

/// Per-channel accumulation state.
#[derive(Clone, Debug, Default)]
struct ChannelState {
    reads: u64,
    writes: u64,
    row_hits: u64,
    row_misses: u64,
    row_conflicts: u64,
    reuse: Histogram,
    /// line -> sequence number of its last access on this channel.
    last_seen: HashMap<u64, u64>,
    seq: u64,
}

/// Streaming analyzer. Construct with the memory organization the
/// events were generated against (row geometry and channel routing
/// must match for the row-locality and per-channel numbers to mean
/// anything), feed every event through [`AccessPatternAnalyzer::observe`],
/// then call [`AccessPatternAnalyzer::finish`].
pub struct AccessPatternAnalyzer {
    mapper: AddressMapper,
    mode: ChannelMode,
    channels: usize,
    channel_bytes: u64,
    banks_per_channel: usize,
    /// Open row per (channel, flat bank) under the in-order model.
    open_rows: Vec<Option<u64>>,
    regions: Vec<RegionState>,
    chans: Vec<ChannelState>,
    region_row: Vec<[u64; 3]>, // [hit, miss, conflict] per region
}

impl AccessPatternAnalyzer {
    /// `spec.channels` and `mode` must match the memory system that
    /// produced (or will produce) the events. Uses the default
    /// `RoBaRaCoCh` address mapping; systems running a policy-ablation
    /// mapping must use [`AccessPatternAnalyzer::with_addr_map`].
    pub fn new(spec: DramSpec, mode: ChannelMode) -> AccessPatternAnalyzer {
        Self::with_addr_map(spec, mode, AddrMap::default())
    }

    /// Like [`AccessPatternAnalyzer::new`] with an explicit physical
    /// address mapping (must match the controller's
    /// `DramPolicy::addr_map` for the row-locality numbers to mean
    /// anything).
    pub fn with_addr_map(
        spec: DramSpec,
        mode: ChannelMode,
        addr_map: AddrMap,
    ) -> AccessPatternAnalyzer {
        let channels = spec.channels.max(1);
        // Events carry global addresses; rows are decoded from the
        // channel-local address exactly as MemorySystem rewrites it.
        let local = spec.with_channels(1);
        AccessPatternAnalyzer {
            mapper: AddressMapper::with_map(&local, addr_map),
            mode,
            channels,
            channel_bytes: spec.channel_bytes,
            banks_per_channel: spec.banks_per_channel(),
            open_rows: vec![None; channels * spec.banks_per_channel()],
            regions: vec![RegionState::default(); Region::COUNT],
            chans: vec![ChannelState::default(); channels],
            region_row: vec![[0; 3]; Region::COUNT],
        }
    }

    /// Consume one event (events must arrive in issue order).
    ///
    /// # Panics
    ///
    /// If `ev.channel` is outside this analyzer's channel count —
    /// a summary over mismatched organizations would be silently
    /// wrong, so the mismatch is rejected loudly. CLI paths validate
    /// first and report a friendly error.
    pub fn observe(&mut self, ev: &TraceEvent) {
        assert!(
            ev.channel < self.channels,
            "trace event on channel {} but the analyzer was built for {} channel(s); \
             construct it with the organization that produced the trace",
            ev.channel,
            self.channels
        );
        let ch = ev.channel;
        self.regions[ev.region.index()].observe(ev.addr, ev.kind);

        // In-order open-page row model (channel-local rewrite shared
        // with MemorySystem::enqueue via ChannelMode::local_addr).
        let d = self
            .mapper
            .decode(self.mode.local_addr(ev.addr, self.channels, self.channel_bytes));
        let slot = ch * self.banks_per_channel + d.flat_bank;
        let outcome = match self.open_rows[slot] {
            Some(row) if row == d.row => 0, // hit
            None => 1,                      // miss
            Some(_) => 2,                   // conflict
        };
        self.open_rows[slot] = Some(d.row);
        self.region_row[ev.region.index()][outcome] += 1;

        let c = &mut self.chans[ch];
        if ev.kind == MemKind::Write {
            c.writes += 1;
        } else {
            c.reads += 1;
        }
        match outcome {
            0 => c.row_hits += 1,
            1 => c.row_misses += 1,
            _ => c.row_conflicts += 1,
        }

        // Reuse interval: accesses on this channel since this line was
        // last touched (an LRU-stack-distance upper bound).
        let line = ev.addr / CACHE_LINE;
        if let Some(prev) = c.last_seen.insert(line, c.seq) {
            c.reuse.record(c.seq - prev);
        }
        c.seq += 1;
    }

    /// Flush run-length state and produce the summary.
    pub fn finish(mut self) -> AccessPatternSummary {
        let mut regions = Vec::with_capacity(Region::COUNT);
        for r in Region::all() {
            let mut st = std::mem::take(&mut self.regions[r.index()]);
            st.finish_runs();
            let [h, m, c] = self.region_row[r.index()];
            regions.push(RegionSummary {
                region: r,
                reads: st.reads,
                writes: st.writes,
                bytes: (st.reads + st.writes) * CACHE_LINE,
                sequential: st.sequential,
                strided: st.strided,
                random: st.random,
                row_hits: h,
                row_misses: m,
                row_conflicts: c,
                run_lengths: st.run_lengths,
                distinct_lines: st.last_seen.len() as u64,
                reuse: st.reuse,
            });
        }
        let channels = self
            .chans
            .into_iter()
            .enumerate()
            .map(|(i, c)| ChannelSummary {
                channel: i,
                reads: c.reads,
                writes: c.writes,
                row_hits: c.row_hits,
                row_misses: c.row_misses,
                row_conflicts: c.row_conflicts,
                distinct_lines: c.last_seen.len() as u64,
                reuse: c.reuse,
            })
            .collect();
        AccessPatternSummary { regions, channels }
    }

    /// Convenience: run a whole event stream through a fresh analyzer.
    pub fn analyze<'a>(
        spec: DramSpec,
        mode: ChannelMode,
        events: impl IntoIterator<Item = &'a TraceEvent>,
    ) -> AccessPatternSummary {
        let mut a = AccessPatternAnalyzer::new(spec, mode);
        for ev in events {
            a.observe(ev);
        }
        a.finish()
    }
}

/// Aggregated pattern statistics for one [`Region`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RegionSummary {
    pub region: Region,
    pub reads: u64,
    pub writes: u64,
    /// Bytes moved (requests × cache line).
    pub bytes: u64,
    /// Accesses continuing a +1-line sequential walk.
    pub sequential: u64,
    /// Accesses repeating the previous non-unit stride.
    pub strided: u64,
    /// Everything else (including each region's first access).
    pub random: u64,
    /// Row outcomes under the in-order open-page model.
    pub row_hits: u64,
    pub row_misses: u64,
    pub row_conflicts: u64,
    /// Lengths of maximal sequential runs (isolated accesses count as
    /// runs of length 1).
    pub run_lengths: Histogram,
    /// Distinct cache lines this region touched (footprint in lines).
    pub distinct_lines: u64,
    /// Region-local reuse intervals: same-region accesses between two
    /// touches of the same line. The first touch of a line records
    /// nothing, so `reuse.count() == requests() - distinct_lines`.
    pub reuse: Histogram,
}

impl RegionSummary {
    pub fn requests(&self) -> u64 {
        self.reads + self.writes
    }

    /// Predicted hits of a region-scoped on-chip buffer holding
    /// `capacity_lines` lines (see [`crate::onchip`]): every recorded
    /// reuse whose interval is at most the capacity is predicted to
    /// hit; cold touches and further reuses are predicted misses.
    ///
    /// The interval is an *upper bound* on the LRU stack distance
    /// (accesses counted, not distinct lines), so this is a lower
    /// bound on a fully-associative LRU scratchpad's hits. Bucketing
    /// is conservative too: a power-of-two bucket only counts when its
    /// entire range fits the capacity. The bound is *exact* once the
    /// capacity covers every recorded interval (capacity ≥ 2× the
    /// region's accesses certainly does): then every reuse is both
    /// predicted and simulated as a hit, and the cold touches are the
    /// misses on both sides. Merely covering the footprint is not
    /// enough — a line re-touched after many same-region accesses
    /// records a large interval and is conservatively predicted to
    /// miss even though an unevicted buffer would hit. The onchip
    /// equivalence suite cross-checks prediction against simulation.
    pub fn predicted_hits(&self, capacity_lines: u64) -> u64 {
        self.reuse
            .buckets()
            .iter()
            .enumerate()
            .filter(|(k, _)| Histogram::bucket_limit(*k) - 1 <= capacity_lines)
            .map(|(_, &count)| count)
            .sum()
    }

    /// [`RegionSummary::predicted_hits`] over this region's accesses
    /// (0.0 when the region saw no traffic).
    pub fn predicted_hit_rate(&self, capacity_lines: u64) -> f64 {
        let n = self.requests();
        if n == 0 {
            0.0
        } else {
            self.predicted_hits(capacity_lines) as f64 / n as f64
        }
    }

    /// Smallest power-of-two capacity (in lines, up to `max_lines`)
    /// whose [`RegionSummary::predicted_hits`] reach `fraction` of the
    /// hits predicted at `max_lines` itself, or `None` when even
    /// `max_lines` predicts no hits (a streaming region). This is the
    /// advisor's budget-sizing primitive (see [`crate::advisor`]): it
    /// walks the reuse-interval histogram buckets rather than
    /// re-simulating candidate buffers.
    pub fn min_capacity_for_hits(&self, fraction: f64, max_lines: u64) -> Option<u64> {
        let best = self.predicted_hits(max_lines);
        if best == 0 {
            return None;
        }
        let target = fraction * best as f64;
        let mut cap = 1u64;
        while cap < max_lines {
            if self.predicted_hits(cap) as f64 >= target {
                return Some(cap);
            }
            cap *= 2;
        }
        Some(max_lines)
    }

    /// This region's share of `total_requests` (0.0 on a zero total).
    pub fn traffic_share(&self, total_requests: u64) -> f64 {
        if total_requests == 0 {
            0.0
        } else {
            self.requests() as f64 / total_requests as f64
        }
    }

    /// Fraction of accesses classified sequential.
    pub fn seq_fraction(&self) -> f64 {
        let n = self.requests();
        if n == 0 {
            0.0
        } else {
            self.sequential as f64 / n as f64
        }
    }

    /// (hit, miss, conflict) fractions under the in-order model.
    pub fn row_mix(&self) -> (f64, f64, f64) {
        let n = self.requests().max(1) as f64;
        (
            self.row_hits as f64 / n,
            self.row_misses as f64 / n,
            self.row_conflicts as f64 / n,
        )
    }

    /// Mean maximal-sequential-run length.
    pub fn mean_run_length(&self) -> f64 {
        self.run_lengths.mean()
    }
}

/// Aggregated pattern statistics for one memory channel.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ChannelSummary {
    pub channel: usize,
    pub reads: u64,
    pub writes: u64,
    pub row_hits: u64,
    pub row_misses: u64,
    pub row_conflicts: u64,
    /// Distinct cache lines touched (footprint in lines).
    pub distinct_lines: u64,
    /// Reuse intervals: same-channel accesses between two touches of
    /// the same line.
    pub reuse: Histogram,
}

impl ChannelSummary {
    pub fn requests(&self) -> u64 {
        self.reads + self.writes
    }

    /// (hit, miss, conflict) fractions under the in-order model.
    pub fn row_mix(&self) -> (f64, f64, f64) {
        let n = self.requests().max(1) as f64;
        (
            self.row_hits as f64 / n,
            self.row_misses as f64 / n,
            self.row_conflicts as f64 / n,
        )
    }
}

/// The full access-pattern summary of one run (or one trace file):
/// per-region and per-channel roll-ups. Attach to a simulation via
/// `SimSpecBuilder::patterns(true)`; it then arrives on
/// `SimReport::patterns`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct AccessPatternSummary {
    /// One entry per [`Region`], in [`Region::all`] order (zero-filled
    /// for regions the run never touched).
    pub regions: Vec<RegionSummary>,
    /// One entry per channel.
    pub channels: Vec<ChannelSummary>,
}

impl AccessPatternSummary {
    /// The summary for one region.
    pub fn region(&self, r: Region) -> &RegionSummary {
        &self.regions[r.index()]
    }

    pub fn total_requests(&self) -> u64 {
        self.regions.iter().map(|r| r.requests()).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.regions.iter().map(|r| r.bytes).sum()
    }

    /// The region moving the most bytes.
    pub fn dominant_region(&self) -> Region {
        self.regions
            .iter()
            .max_by_key(|r| r.bytes)
            .map(|r| r.region)
            .unwrap_or(Region::Payload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::MemTech;

    fn ev(addr: u64, region: Region, kind: MemKind, channel: usize) -> TraceEvent {
        TraceEvent {
            addr,
            kind,
            region,
            arrival: 0,
            channel,
        }
    }

    fn analyzer1() -> AccessPatternAnalyzer {
        AccessPatternAnalyzer::new(MemTech::Ddr4.spec(1), ChannelMode::InterleaveLine)
    }

    #[test]
    fn histogram_buckets() {
        let mut h = Histogram::default();
        for v in [0, 1, 1, 2, 3, 4, 7, 8, 1000] {
            h.record(v);
        }
        assert_eq!(h.count(), 9);
        assert_eq!(h.buckets()[0], 1); // the zero
        assert_eq!(h.buckets()[1], 2); // 1, 1
        assert_eq!(h.buckets()[2], 2); // 2, 3
        assert_eq!(h.buckets()[3], 2); // 4, 7
        assert_eq!(h.buckets()[4], 1); // 8
        assert_eq!(h.buckets()[10], 1); // 1000 in [512, 1024)
        assert!((h.mean() - (1 + 1 + 2 + 3 + 4 + 7 + 8 + 1000) as f64 / 9.0).abs() < 1e-9);
        assert_eq!(Histogram::bucket_limit(0), 1);
        assert_eq!(Histogram::bucket_limit(4), 16);
    }

    #[test]
    fn sequential_stream_classified() {
        let mut a = analyzer1();
        for i in 0..10u64 {
            a.observe(&ev(i * CACHE_LINE, Region::Edges, MemKind::Read, 0));
        }
        let s = a.finish();
        let r = s.region(Region::Edges);
        assert_eq!(r.reads, 10);
        assert_eq!(r.sequential, 9);
        assert_eq!(r.random, 1); // the first access
        assert_eq!(r.strided, 0);
        // One maximal run of length 10.
        assert_eq!(r.run_lengths.count(), 1);
        assert!((r.mean_run_length() - 10.0).abs() < 1e-9);
        // Sequential within one 8 KiB row: 1 miss, 9 hits in-order.
        assert_eq!(r.row_misses, 1);
        assert_eq!(r.row_hits, 9);
    }

    #[test]
    fn strided_stream_classified() {
        let mut a = analyzer1();
        for i in 0..6u64 {
            a.observe(&ev(i * 4 * CACHE_LINE, Region::Vertices, MemKind::Read, 0));
        }
        let s = a.finish();
        let r = s.region(Region::Vertices);
        // first access random, second establishes the stride (random),
        // remaining four repeat it.
        assert_eq!(r.random, 2);
        assert_eq!(r.strided, 4);
        assert_eq!(r.sequential, 0);
    }

    #[test]
    fn random_stream_classified() {
        let mut a = analyzer1();
        let addrs = [0u64, 1 << 20, 1 << 14, 3 << 22, 1 << 9, 5 << 19];
        for &addr in &addrs {
            a.observe(&ev(addr, Region::Updates, MemKind::Write, 0));
        }
        let s = a.finish();
        let r = s.region(Region::Updates);
        assert_eq!(r.writes, 6);
        assert_eq!(r.random, 6);
        assert_eq!(r.sequential + r.strided, 0);
        // All isolated: six runs of length 1.
        assert_eq!(r.run_lengths.count(), 6);
        assert!((r.mean_run_length() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn regions_tracked_independently() {
        let mut a = analyzer1();
        // Interleave two sequential streams; each stays sequential in
        // its own region even though the merged address stream is not.
        for i in 0..8u64 {
            a.observe(&ev(i * CACHE_LINE, Region::Edges, MemKind::Read, 0));
            a.observe(&ev((1 << 24) + i * CACHE_LINE, Region::Vertices, MemKind::Read, 0));
        }
        let s = a.finish();
        assert_eq!(s.region(Region::Edges).sequential, 7);
        assert_eq!(s.region(Region::Vertices).sequential, 7);
        assert_eq!(s.region(Region::Updates).requests(), 0);
        assert_eq!(s.total_requests(), 16);
        assert_eq!(s.total_bytes(), 16 * CACHE_LINE);
    }

    #[test]
    fn reuse_intervals_per_channel() {
        let mut a = analyzer1();
        // touch line 0, then 3 other lines, then line 0 again ->
        // reuse interval 4.
        for &addr in &[0u64, 64, 128, 192, 0] {
            a.observe(&ev(addr, Region::Edges, MemKind::Read, 0));
        }
        let s = a.finish();
        let c = &s.channels[0];
        assert_eq!(c.requests(), 5);
        assert_eq!(c.distinct_lines, 4);
        assert_eq!(c.reuse.count(), 1);
        assert!((c.reuse.mean() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn region_reuse_intervals_count_region_local_accesses() {
        let mut a = analyzer1();
        // Vertex line 0, then 2 edge accesses (other region), then
        // vertex line 64, then vertex line 0 again: the vertex-region
        // interval is 2 (line 0 re-touched two vertex accesses later);
        // the interleaved edge traffic does not inflate it.
        a.observe(&ev(0, Region::Vertices, MemKind::Read, 0));
        a.observe(&ev(1 << 20, Region::Edges, MemKind::Read, 0));
        a.observe(&ev((1 << 20) + 64, Region::Edges, MemKind::Read, 0));
        a.observe(&ev(64, Region::Vertices, MemKind::Read, 0));
        a.observe(&ev(0, Region::Vertices, MemKind::Write, 0));
        let s = a.finish();
        let v = s.region(Region::Vertices);
        assert_eq!(v.distinct_lines, 2);
        assert_eq!(v.reuse.count(), 1);
        assert!((v.reuse.mean() - 2.0).abs() < 1e-9);
        assert_eq!(v.requests() - v.distinct_lines, v.reuse.count());
        // Edges saw no reuse at all.
        assert_eq!(s.region(Region::Edges).reuse.count(), 0);
        assert_eq!(s.region(Region::Edges).distinct_lines, 2);
    }

    #[test]
    fn predicted_hit_rate_from_region_reuse() {
        let mut a = analyzer1();
        // Two passes over 4 vertex lines: 4 cold touches + 4 reuses at
        // interval 4.
        for _ in 0..2 {
            for line in 0..4u64 {
                a.observe(&ev(line * CACHE_LINE, Region::Vertices, MemKind::Read, 0));
            }
        }
        let s = a.finish();
        let v = s.region(Region::Vertices);
        assert_eq!(v.reuse.count(), 4);
        // Capacity 7 lines covers the whole [4, 8) bucket -> all 4
        // reuses predicted hits over 8 accesses.
        assert_eq!(v.predicted_hits(7), 4);
        assert!((v.predicted_hit_rate(7) - 0.5).abs() < 1e-9);
        // Capacity 1 line: the [4, 8) bucket exceeds it -> no hits
        // (conservative whole-bucket rule).
        assert_eq!(v.predicted_hits(1), 0);
        assert_eq!(v.predicted_hit_rate(1), 0.0);
        // An untouched region predicts 0.0, not NaN.
        assert_eq!(s.region(Region::Updates).predicted_hit_rate(1024), 0.0);
    }

    #[test]
    fn min_capacity_walks_reuse_buckets() {
        let mut a = analyzer1();
        // Two passes over 4 vertex lines -> 4 reuses at interval 4,
        // which land in the [4, 8) bucket: the smallest power-of-two
        // capacity covering that whole bucket is 8 lines (capacity 4
        // predicts zero hits under the conservative bucket rule).
        for _ in 0..2 {
            for line in 0..4u64 {
                a.observe(&ev(line * CACHE_LINE, Region::Vertices, MemKind::Read, 0));
            }
        }
        let s = a.finish();
        let v = s.region(Region::Vertices);
        assert_eq!(v.min_capacity_for_hits(0.95, 4096), Some(8));
        assert_eq!(v.min_capacity_for_hits(1.0, 4096), Some(8));
        // A streaming region (no reuse at all) sizes to None.
        assert_eq!(s.region(Region::Edges).min_capacity_for_hits(0.95, 4096), None);
        // max_lines below every interval -> no predicted hits -> None.
        assert_eq!(v.min_capacity_for_hits(0.95, 2), None);
    }

    #[test]
    fn traffic_share_is_request_fraction() {
        let mut a = analyzer1();
        for i in 0..6u64 {
            a.observe(&ev(i * CACHE_LINE, Region::Edges, MemKind::Read, 0));
        }
        for i in 0..2u64 {
            a.observe(&ev((1 << 24) + i * CACHE_LINE, Region::Vertices, MemKind::Read, 0));
        }
        let s = a.finish();
        let total = s.total_requests();
        assert!((s.region(Region::Edges).traffic_share(total) - 0.75).abs() < 1e-9);
        assert!((s.region(Region::Vertices).traffic_share(total) - 0.25).abs() < 1e-9);
        assert_eq!(s.region(Region::Updates).traffic_share(0), 0.0);
    }

    #[test]
    fn channels_rolled_up_separately() {
        let spec = MemTech::Ddr4.spec(2);
        let mut a = AccessPatternAnalyzer::new(spec, ChannelMode::InterleaveLine);
        for i in 0..8u64 {
            let addr = i * CACHE_LINE;
            a.observe(&ev(addr, Region::Edges, MemKind::Read, (i % 2) as usize));
        }
        let s = a.finish();
        assert_eq!(s.channels.len(), 2);
        assert_eq!(s.channels[0].requests(), 4);
        assert_eq!(s.channels[1].requests(), 4);
    }

    #[test]
    fn conflict_detected_on_row_alternation() {
        let spec = MemTech::Ddr4.spec(1);
        let stride = spec.lines_per_row() * spec.banks_per_channel() as u64 * CACHE_LINE;
        let mut a = AccessPatternAnalyzer::new(spec, ChannelMode::InterleaveLine);
        for i in 0..6u64 {
            a.observe(&ev((i % 2) * stride, Region::Payload, MemKind::Read, 0));
        }
        let s = a.finish();
        let r = s.region(Region::Payload);
        assert_eq!(r.row_misses, 1);
        assert_eq!(r.row_conflicts, 5);
        assert_eq!(s.dominant_region(), Region::Payload);
    }
}
