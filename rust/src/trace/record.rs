//! Structured trace records: the [`Region`] tag and the [`TraceEvent`]
//! that every off-chip request carries, plus the text trace format.
//!
//! Events are stamped at request *issue* time by the accelerator
//! models (each [`crate::accel::stream::LineStream`] declares what data
//! structure it reads, and the phase driver maps that onto a region),
//! so an analysis never has to reverse-engineer address ranges to know
//! which data structure a request belongs to — the attribution the
//! paper performs for Figs. 8–11.
//!
//! The text format extends the seed's Ramulator-style trace with a
//! region column:
//!
//! ```text
//! <hex addr> <R|W> <arrival cycle> <channel> <region>
//! ```
//!
//! [`parse_events`] also accepts the old four-column form (region
//! defaults to [`Region::Payload`]) so pre-existing trace files stay
//! readable.

use crate::dram::{ChannelMode, MemKind};
use std::fmt;

/// Which logical data structure a request belongs to — the paper's
/// traffic-attribution axis (edges vs. vertex values vs. update sets
/// vs. auxiliary payload such as CSR pointers and shard metadata).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Region {
    /// Edge / neighbor arrays (sorted edge lists, in-CSR neighbors,
    /// shard edge blocks).
    Edges,
    /// Vertex values: prefetches, random source-value reads, value
    /// write-backs.
    Vertices,
    /// Update sets of the 2-phase systems (scatter writes, apply
    /// reads).
    Updates,
    /// Everything else an accelerator keeps off-chip: CSR row
    /// pointers, shard descriptors, other metadata.
    #[default]
    Payload,
}

impl Region {
    /// Number of regions (array-sized per-region counters use this).
    pub const COUNT: usize = 4;

    /// All regions, in display order.
    pub const fn all() -> [Region; Region::COUNT] {
        [Region::Edges, Region::Vertices, Region::Updates, Region::Payload]
    }

    /// Dense index in `0..Region::COUNT`.
    pub fn index(self) -> usize {
        match self {
            Region::Edges => 0,
            Region::Vertices => 1,
            Region::Updates => 2,
            Region::Payload => 3,
        }
    }

    /// Short lowercase name (the trace-file column).
    pub fn name(self) -> &'static str {
        match self {
            Region::Edges => "edges",
            Region::Vertices => "vertices",
            Region::Updates => "updates",
            Region::Payload => "payload",
        }
    }
}

impl fmt::Display for Region {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for Region {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "edges" => Ok(Region::Edges),
            "vertices" => Ok(Region::Vertices),
            "updates" => Ok(Region::Updates),
            "payload" => Ok(Region::Payload),
            other => Err(format!(
                "unknown region {other:?} (edges|vertices|updates|payload)"
            )),
        }
    }
}

/// One issued off-chip request, as the analyzers see it: the global
/// (pre-routing) byte address, direction, region tag, arrival cycle at
/// the controller, and the channel it routed to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    /// Global byte address (cache-line aligned).
    pub addr: u64,
    pub kind: MemKind,
    pub region: Region,
    /// Cycle the request became visible to the memory controller.
    pub arrival: u64,
    /// Channel the address routed to.
    pub channel: usize,
}

/// Memory-organization metadata for a trace file. Written as a `#`
/// comment header by `graphmem trace` so `graphmem analyze --trace`
/// can reconstruct the organization without the user re-specifying
/// `--dram/--channels/--mode`; old traces without a header still
/// parse (the flags then choose the organization).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceMeta {
    /// Memory technology name (`MemTech` short name, e.g. `ddr4`).
    pub dram: String,
    pub channels: usize,
    pub mode: ChannelMode,
}

/// Marker prefix of the metadata header line.
pub const META_PREFIX: &str = "# graphmem-trace";

/// Write the metadata header (one comment line).
pub fn write_meta(mut w: impl std::io::Write, meta: &TraceMeta) -> std::io::Result<()> {
    writeln!(
        w,
        "{META_PREFIX} dram={} channels={} mode={}",
        meta.dram,
        meta.channels,
        match meta.mode {
            ChannelMode::Region => "region",
            ChannelMode::InterleaveLine => "interleave",
        }
    )
}

/// Extract the metadata header, if the text starts with one (comment
/// lines before the first event are scanned; event lines end the
/// search).
pub fn parse_meta(text: &str) -> Option<TraceMeta> {
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if !line.starts_with('#') {
            return None; // first data line — no header present
        }
        let Some(rest) = line.strip_prefix(META_PREFIX) else {
            continue; // unrelated comment
        };
        let (mut dram, mut channels, mut mode) = (None, None, None);
        for kv in rest.split_whitespace() {
            let Some((k, v)) = kv.split_once('=') else {
                continue;
            };
            match k {
                "dram" => dram = Some(v.to_string()),
                "channels" => channels = v.parse::<usize>().ok(),
                "mode" => {
                    mode = match v {
                        "region" => Some(ChannelMode::Region),
                        "interleave" => Some(ChannelMode::InterleaveLine),
                        _ => None,
                    }
                }
                _ => {}
            }
        }
        return Some(TraceMeta {
            dram: dram?,
            channels: channels?,
            mode: mode?,
        });
    }
    None
}

/// Write events in the text trace format; returns the line count.
pub fn write_events(mut w: impl std::io::Write, events: &[TraceEvent]) -> std::io::Result<u64> {
    for e in events {
        writeln!(
            w,
            "0x{:x} {} {} {} {}",
            e.addr,
            if e.kind == MemKind::Write { "W" } else { "R" },
            e.arrival,
            e.channel,
            e.region
        )?;
    }
    Ok(events.len() as u64)
}

/// Parse one trace line (4- or 5-column form). Empty lines and `#`
/// comments yield `Ok(None)`.
pub fn parse_line(line: &str) -> Result<Option<TraceEvent>, String> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(None);
    }
    let mut parts = line.split_whitespace();
    let addr_s = parts.next().ok_or("missing address")?;
    let kind_s = parts.next().ok_or("missing R|W column")?;
    let arrival_s = parts.next().ok_or("missing arrival column")?;
    let channel_s = parts.next().ok_or("missing channel column")?;
    let region_s = parts.next(); // optional 5th column
    if parts.next().is_some() {
        return Err(format!("too many columns in {line:?}"));
    }
    let addr_digits = addr_s.strip_prefix("0x").unwrap_or(addr_s);
    let addr = u64::from_str_radix(addr_digits, 16)
        .map_err(|e| format!("bad address {addr_s:?}: {e}"))?;
    let kind = match kind_s {
        "R" | "r" => MemKind::Read,
        "W" | "w" => MemKind::Write,
        other => return Err(format!("bad kind {other:?} (expected R or W)")),
    };
    let arrival: u64 = arrival_s
        .parse()
        .map_err(|e| format!("bad arrival {arrival_s:?}: {e}"))?;
    let channel: usize = channel_s
        .parse()
        .map_err(|e| format!("bad channel {channel_s:?}: {e}"))?;
    let region = match region_s {
        Some(s) => s.parse::<Region>()?,
        None => Region::Payload,
    };
    Ok(Some(TraceEvent {
        addr,
        kind,
        region,
        arrival,
        channel,
    }))
}

/// Parse a whole trace text (as written by [`write_events`] or the
/// seed's `MemorySystem::write_trace`). Errors carry 1-based line
/// numbers.
pub fn parse_events(text: &str) -> Result<Vec<TraceEvent>, String> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if let Some(ev) = parse_line(line).map_err(|e| format!("line {}: {e}", i + 1))? {
            out.push(ev);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(addr: u64, region: Region) -> TraceEvent {
        TraceEvent {
            addr,
            kind: MemKind::Read,
            region,
            arrival: 7,
            channel: 1,
        }
    }

    #[test]
    fn region_round_trips() {
        for (i, r) in Region::all().into_iter().enumerate() {
            assert_eq!(r.index(), i);
            assert_eq!(r.name().parse::<Region>().unwrap(), r);
            assert_eq!(r.to_string(), r.name());
        }
        assert!("heap".parse::<Region>().is_err());
    }

    #[test]
    fn text_format_round_trips() {
        let events = vec![
            ev(0x40, Region::Edges),
            TraceEvent {
                addr: 0x1000,
                kind: MemKind::Write,
                region: Region::Updates,
                arrival: 123,
                channel: 3,
            },
        ];
        let mut buf = Vec::new();
        assert_eq!(write_events(&mut buf, &events).unwrap(), 2);
        let text = String::from_utf8(buf).unwrap();
        assert!(text.contains("0x40 R 7 1 edges"), "{text}");
        assert!(text.contains("0x1000 W 123 3 updates"), "{text}");
        assert_eq!(parse_events(&text).unwrap(), events);
    }

    #[test]
    fn four_column_form_defaults_to_payload() {
        let evs = parse_events("0x40 W 5 1\n").unwrap();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].region, Region::Payload);
        assert_eq!(evs[0].kind, MemKind::Write);
    }

    #[test]
    fn comments_and_blanks_skipped() {
        let evs = parse_events("# header\n\n0x0 R 0 0 vertices\n").unwrap();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].region, Region::Vertices);
    }

    #[test]
    fn meta_header_round_trips() {
        let meta = TraceMeta {
            dram: "hbm".to_string(),
            channels: 8,
            mode: ChannelMode::Region,
        };
        let mut buf = Vec::new();
        write_meta(&mut buf, &meta).unwrap();
        write_events(&mut buf, &[ev(0x40, Region::Edges)]).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert_eq!(parse_meta(&text).unwrap(), meta);
        // The header is a comment: event parsing is unaffected.
        assert_eq!(parse_events(&text).unwrap().len(), 1);
        // Headerless / data-first traces yield no meta.
        assert_eq!(parse_meta("0x0 R 0 0 edges\n"), None);
        assert_eq!(parse_meta("# some other comment\n0x0 R 0 0\n"), None);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse_events("0x0 R 0 0\n0xzz R 0 0\n").unwrap_err();
        assert!(err.starts_with("line 2:"), "{err}");
        assert!(parse_line("0x0 X 0 0").is_err());
        assert!(parse_line("0x0 R 0 0 edges extra").is_err());
        assert!(parse_line("0x0 R nope 0").is_err());
    }
}
