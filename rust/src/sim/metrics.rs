//! The paper's metric set: execution time, MTEPS/MREPS (§4.1), the
//! four critical performance metrics of Fig. 9, and the DRAM stat
//! roll-up of Fig. 11(b).

use crate::dram::DramStats;
use crate::onchip::OnChipStats;
use crate::trace::AccessPatternSummary;

/// Raw counters accumulated by an accelerator model during a run.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RunMetrics {
    /// Iterations executed (incl. the final no-change pass).
    pub iterations: u32,
    /// Edge primitives read, total (incl. padding / null edges).
    pub edges_read: u64,
    /// Vertex value elements read, total (prefetches + random reads).
    pub values_read: u64,
    /// Vertex value elements written.
    pub values_written: u64,
    /// Update records read + written (2-phase systems).
    pub updates_rw: u64,
    /// Partitions / shards skipped by skip optimizations.
    pub skipped: u64,
    /// Partitions / shards processed.
    pub processed: u64,
}

/// Full result of one simulated run.
///
/// `PartialEq` compares every field (including exact float bits via
/// `f64` equality) — the simulation is deterministic, so two runs of
/// the same [`crate::sim::SimSpec`] must compare equal; the parallel
/// sweep determinism test relies on this.
#[derive(Clone, Debug, PartialEq)]
pub struct SimReport {
    pub accelerator: &'static str,
    pub problem: &'static str,
    /// `|E|` of the input graph (for MTEPS).
    pub graph_edges: u64,
    /// Makespan in DRAM cycles and seconds.
    pub cycles: u64,
    pub seconds: f64,
    pub metrics: RunMetrics,
    pub dram: DramStats,
    /// Total bytes moved (requests x 64 B).
    pub bytes_total: u64,
    /// Aggregate data-bus utilization (Fig. 11(b)).
    pub bus_utilization: f64,
    pub channels: usize,
    /// Access-pattern summary — present when the spec was built with
    /// `SimSpecBuilder::patterns(true)` (filled in by `SimSpec::run`;
    /// the accelerator models themselves leave it `None`).
    pub patterns: Option<AccessPatternSummary>,
    /// On-chip buffer counters — present when the spec carried an
    /// [`crate::onchip::OnChipConfig`] (filled in by `SimSpec::run`;
    /// the accelerator models themselves leave it `None`). With a
    /// buffer configured, `dram` counts only the traffic that *missed*
    /// on chip.
    pub onchip: Option<OnChipStats>,
    /// Which of this run's choices the advisor made — stamped by
    /// advisor reporting paths ([`crate::sim::Sweep::validate_advisor`]
    /// and `graphmem advise`) via `Recommendation::annotate`. Always
    /// `None` on directly executed runs, *including* runs of specs
    /// built with the `auto_*` builder flags: advisor provenance lives
    /// on the report only, never in the [`crate::sim::SimSpec`] memo
    /// key, so advisor-resolved and manually built specs stay
    /// bit-identical and share one cache entry.
    pub advisor: Option<AdvisorChoices>,
}

/// Which decision axes of a spec were resolved by the advisor
/// ([`crate::advisor`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AdvisorChoices {
    /// Partition capacity came from the advisor.
    pub partition: bool,
    /// Channel count / placement mode came from the advisor.
    pub placement: bool,
    /// On-chip buffer budget came from the advisor.
    pub onchip: bool,
}

impl SimReport {
    /// Graph500 MTEPS: `|E| / t_exec` (§4.1) in millions.
    pub fn mteps(&self) -> f64 {
        if self.seconds == 0.0 {
            return 0.0;
        }
        self.graph_edges as f64 / self.seconds / 1e6
    }

    /// MREPS: edges *read* over execution time (raw edge processing
    /// performance; what most accelerator articles report).
    pub fn mreps(&self) -> f64 {
        if self.seconds == 0.0 {
            return 0.0;
        }
        self.metrics.edges_read as f64 / self.seconds / 1e6
    }

    /// Bytes read/written per edge read (Fig. 9(b)).
    pub fn bytes_per_edge(&self) -> f64 {
        if self.metrics.edges_read == 0 {
            return 0.0;
        }
        self.bytes_total as f64 / self.metrics.edges_read as f64
    }

    /// Values read per iteration (Fig. 9(c)).
    pub fn values_read_per_iter(&self) -> f64 {
        if self.metrics.iterations == 0 {
            return 0.0;
        }
        self.metrics.values_read as f64 / self.metrics.iterations as f64
    }

    /// Edges read per iteration (Fig. 9(d)).
    pub fn edges_read_per_iter(&self) -> f64 {
        if self.metrics.iterations == 0 {
            return 0.0;
        }
        self.metrics.edges_read as f64 / self.metrics.iterations as f64
    }

    /// Row-buffer outcome fractions (hits, misses, conflicts).
    pub fn row_mix(&self) -> (f64, f64, f64) {
        let n = self.dram.requests().max(1) as f64;
        (
            self.dram.row_hits as f64 / n,
            self.dram.row_misses as f64 / n,
            self.dram.row_conflicts as f64 / n,
        )
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "{:<10} {:<5} t={:.4}s MTEPS={:8.1} MREPS={:8.1} iters={} B/edge={:.2} util={:.1}%",
            self.accelerator,
            self.problem,
            self.seconds,
            self.mteps(),
            self.mreps(),
            self.metrics.iterations,
            self.bytes_per_edge(),
            100.0 * self.bus_utilization,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> SimReport {
        SimReport {
            accelerator: "Test",
            problem: "BFS",
            graph_edges: 1_000_000,
            cycles: 1000,
            seconds: 0.5,
            metrics: RunMetrics {
                iterations: 10,
                edges_read: 2_000_000,
                values_read: 500_000,
                values_written: 100_000,
                updates_rw: 0,
                skipped: 3,
                processed: 17,
            },
            dram: DramStats {
                reads: 700,
                writes: 300,
                row_hits: 600,
                row_misses: 100,
                row_conflicts: 300,
                ..Default::default()
            },
            bytes_total: 64_000_000,
            bus_utilization: 0.42,
            channels: 1,
            patterns: None,
            onchip: None,
            advisor: None,
        }
    }

    #[test]
    fn mteps_definition() {
        let r = report();
        assert!((r.mteps() - 2.0).abs() < 1e-9); // 1e6 edges / 0.5 s / 1e6
        assert!((r.mreps() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn fig9_metrics() {
        let r = report();
        assert!((r.bytes_per_edge() - 32.0).abs() < 1e-9);
        assert!((r.values_read_per_iter() - 50_000.0).abs() < 1e-9);
        assert!((r.edges_read_per_iter() - 200_000.0).abs() < 1e-9);
    }

    #[test]
    fn row_mix_sums_to_one() {
        let r = report();
        let (h, m, c) = r.row_mix();
        assert!((h + m + c - 1.0).abs() < 1e-9);
    }

    #[test]
    fn zero_division_safe() {
        let mut r = report();
        r.seconds = 0.0;
        r.metrics.edges_read = 0;
        r.metrics.iterations = 0;
        assert_eq!(r.mteps(), 0.0);
        assert_eq!(r.bytes_per_edge(), 0.0);
        assert_eq!(r.values_read_per_iter(), 0.0);
    }
}
