//! The phase execution engine: drains a [`Phase`]'s streams through
//! its merge tree into the [`MemorySystem`], honoring the
//! outstanding-request window and the chained-callback releases.
//!
//! Request ordering is exactly the paper's model: "we only simulate
//! request ordering through mandatory control flow caused by data
//! dependencies" — chained streams release on parent completion, and
//! everything else is limited only by the window and the merge
//! arbiter.
//!
//! The hot path is allocation-free and event-driven:
//!
//! * stream addresses come from [`LineSource`] descriptors, so
//!   readiness checks index the next line in O(1) (the channel of the
//!   next line is cached per stream and refreshed only when the
//!   cursor advances);
//! * completions are consumed in batches — after a window fill, the
//!   driver keeps servicing until a completion actually frees a slot
//!   some stream is waiting on or releases a chained request whose
//!   channel has capacity, instead of re-walking the merge tree after
//!   every single completion;
//! * once every request has been issued, the remaining in-flight tail
//!   is retired with one [`MemorySystem::service_until`] call;
//! * all per-phase working state (stream cursors, children adjacency,
//!   the merge-tree arena, per-channel window accounting) lives in a
//!   reusable [`PhaseScratch`] arena — a simulation allocates it once
//!   and threads it through every [`run_phase_with`] call, so
//!   steady-state phase execution performs no heap allocation at all
//!   (the compiled-program layer, [`crate::accel::program`], does
//!   exactly this).
//!
//! All of this is perf-only: issue order, arrival times and service
//! order are bit-identical to the naive per-request loop (the
//! equivalence suite enforces it via
//! [`set_materialize_streams`]).
//!
//! # On-chip buffering
//!
//! [`run_phase_onchip`] additionally consults an
//! [`OnChipBuffer`] *before* each request is enqueued: a hit is
//! retired at the buffer's fixed latency and never reaches the
//! [`MemorySystem`] — it occupies no window slot, and its completion
//! releases chained children exactly as a DRAM completion would. A
//! miss follows the unmodified path (and fills the buffer inside
//! [`OnChipBuffer::access`]). Passing `None` is byte-for-byte the
//! pre-buffer driver, which is what keeps default-off runs
//! bit-identical (`tests/onchip_equivalence.rs`).
//!
//! # Robustness
//!
//! The driver never unwraps on a wedged simulation. Both structural
//! stall cases — the memory system refusing to service while requests
//! are in flight, and a chain deadlock (nothing in flight, nothing
//! issuable, work remaining, e.g. a fan-out that under-releases its
//! stream) — raise a typed [`SimError::Stalled`] carrying per-stream
//! cursors and per-channel load ([`StallDiagnostics`]), deterministic
//! down to the last-progress cycle. An installed
//! [`RunBudget`](crate::robust::RunBudget) (see
//! [`crate::robust::budget`]) is charged one unit per issued request
//! and checked against the completion clock, so runaway phases
//! surface as [`SimError::BudgetExceeded`] instead of spinning
//! forever. Catch either with [`crate::robust::catch_sim`] (which is
//! what [`SimSpec::run_checked`](crate::sim::SimSpec::run_checked)
//! does).
//!
//! [`LineSource`]: crate::accel::stream::LineSource

use crate::accel::stream::{Fanout, Merge, Phase};
use crate::dram::{MemRequest, MemorySystem};
use crate::onchip::OnChipBuffer;
use crate::robust::{self, ChannelLoad, SimError, StallDiagnostics, StreamCursor};
use std::cell::Cell;
use std::collections::VecDeque;

/// Per-phase execution telemetry.
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseTelemetry {
    /// Requests the phase retired (on-chip hits included).
    pub requests: u64,
    /// The subset of `requests` retired by the on-chip buffer without
    /// reaching the memory system.
    pub onchip_hits: u64,
    /// Cycle at which the phase's last request completed.
    pub end_cycle: u64,
}

/// Per-stream execution state: a cursor over the line source plus the
/// release bookkeeping for chained streams.
#[derive(Default)]
struct StreamState {
    /// Requests issued so far (cursor into the line source).
    issued: usize,
    /// Stream length (cached; sources compute it on demand).
    len: usize,
    /// Requests released so far (`len` for independent streams; grows
    /// with parent completions for chained ones). `issued < available`
    /// means the stream has an issuable request pending.
    available: usize,
    /// Release times of released-but-unissued requests, run-length
    /// encoded as `(release_cycle, count)` — a barrier fan-out is one
    /// run, not N queue entries.
    pending_release: VecDeque<(u64, u32)>,
    independent: bool,
    /// Channel of the next line (`line(issued)`); valid while
    /// `issued < len`. Cached so the merge tree's readiness probe is
    /// O(1) with no address computation.
    next_ch: usize,
}

/// Arena form of the merge tree. Children lists are stored separately
/// from the (mutable) round-robin rotation state so `pick` can walk
/// the tree without cloning — it runs once per issued request and is
/// on the simulator's hot path. Node slots are pooled: `reset` keeps
/// every allocation (including the per-node child lists) for the next
/// phase, so rebuilding the arena is allocation-free once warm.
#[derive(Default)]
struct MergeArena {
    kinds: Vec<NodeKind>,
    rot: Vec<usize>,
    /// `children[i]` is live for `i < kinds.len()`; slots beyond that
    /// are retained capacity from earlier (larger) phases.
    children: Vec<Vec<usize>>,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum NodeKind {
    Leaf(usize),
    RoundRobin,
    Priority,
}

impl MergeArena {
    /// Forget the previous phase's tree but keep every buffer.
    fn reset(&mut self) {
        self.kinds.clear();
        self.rot.clear();
    }

    /// Claim the next node slot, reusing its pooled child list.
    fn alloc(&mut self, kind: NodeKind) -> usize {
        let id = self.kinds.len();
        self.kinds.push(kind);
        self.rot.push(0);
        if id == self.children.len() {
            self.children.push(Vec::new());
        } else {
            self.children[id].clear();
        }
        id
    }

    /// Add a merge (sub)tree; returns its node id. Parents are
    /// allocated before their children — node numbering does not
    /// affect pick order, which follows the tree structure.
    fn add(&mut self, m: &Merge) -> usize {
        match m {
            Merge::Leaf(s) => self.alloc(NodeKind::Leaf(*s)),
            Merge::RoundRobin(ch) => {
                let id = self.alloc(NodeKind::RoundRobin);
                for c in ch {
                    let kid = self.add(c);
                    self.children[id].push(kid);
                }
                id
            }
            Merge::Priority(ch) => {
                let id = self.alloc(NodeKind::Priority);
                for c in ch {
                    let kid = self.add(c);
                    self.children[id].push(kid);
                }
                id
            }
        }
    }

    /// Pick the next stream with an available request, advancing RR
    /// rotation state on success.
    fn pick<F: Fn(usize) -> bool>(&mut self, node: usize, ready: &F) -> Option<usize> {
        match self.kinds[node] {
            NodeKind::Leaf(s) => {
                if ready(s) {
                    Some(s)
                } else {
                    None
                }
            }
            NodeKind::Priority => {
                for i in 0..self.children[node].len() {
                    let c = self.children[node][i];
                    if let Some(s) = self.pick(c, ready) {
                        return Some(s);
                    }
                }
                None
            }
            NodeKind::RoundRobin => {
                let k = self.children[node].len();
                let rot0 = self.rot[node];
                for off in 0..k {
                    let i = (rot0 + off) % k;
                    let c = self.children[node][i];
                    if let Some(s) = self.pick(c, ready) {
                        self.rot[node] = (i + 1) % k;
                        return Some(s);
                    }
                }
                None
            }
        }
    }
}

/// Abort the phase with a structured stall diagnosis instead of a
/// bare panic: the payload is a [`SimError::Stalled`] that
/// [`crate::robust::catch_sim`] (and therefore `run_checked` and the
/// sweep layer) recovers as a typed error.
#[cold]
#[inline(never)]
fn raise_stall(
    state: &[StreamState],
    in_flight: &[usize],
    waiting: &[usize],
    last_progress_cycle: u64,
) -> ! {
    let diagnostics = StallDiagnostics {
        last_progress_cycle,
        streams: state
            .iter()
            .map(|st| StreamCursor {
                issued: st.issued as u64,
                len: st.len as u64,
                available: st.available as u64,
            })
            .collect(),
        channels: in_flight
            .iter()
            .zip(waiting)
            .map(|(&in_flight, &waiting)| ChannelLoad {
                in_flight: in_flight as u64,
                waiting: waiting as u64,
            })
            .collect(),
    };
    robust::raise(SimError::Stalled(diagnostics))
}

/// Encode (stream, index) into the request tag.
#[inline]
fn tag(stream: usize, idx: usize) -> u64 {
    ((stream as u64) << 40) | idx as u64
}

#[inline]
fn untag(t: u64) -> (usize, usize) {
    ((t >> 40) as usize, (t & 0xFF_FFFF_FFFF) as usize)
}

thread_local! {
    /// Test/validation hook (see [`set_materialize_streams`]).
    static MATERIALIZE_STREAMS: Cell<bool> = const { Cell::new(false) };
}

/// Validation hook for the zero-materialization refactor: while set on
/// this thread, every [`run_phase`] first expands the phase through
/// [`Phase::materialized`] (explicit address vectors, per-parent
/// fan-out vectors) and executes that instead. Descriptor and
/// materialized execution are required to be bit-identical — cycle
/// counts, DRAM stats, traces and pattern summaries — which the
/// `stream_equivalence` integration suite asserts by flipping this
/// switch around full simulations. Returns the previous value.
pub fn set_materialize_streams(on: bool) -> bool {
    MATERIALIZE_STREAMS.with(|c| c.replace(on))
}

/// Reusable per-phase working state: stream cursors (with their
/// release deques), the children adjacency of the chain graph, the
/// merge-tree arena and the per-channel in-flight/waiting/slot
/// bookkeeping. Allocate one per simulation and thread it through
/// [`run_phase_with`]: every buffer is retained between phases, so
/// once the largest phase shape has been seen, phase execution
/// performs zero heap allocations (the `driver.scratch_reuse` bench
/// row and the `driver_scratch` integration test measure exactly
/// this). [`run_phase`] remains as the allocate-per-call convenience
/// wrapper.
#[derive(Default)]
pub struct PhaseScratch {
    states: Vec<StreamState>,
    children: Vec<Vec<usize>>,
    arena: MergeArena,
    in_flight: Vec<usize>,
    slot_free_at: Vec<u64>,
    waiting: Vec<usize>,
}

impl PhaseScratch {
    pub fn new() -> PhaseScratch {
        PhaseScratch::default()
    }
}

/// Execute one phase starting at cycle `start`; returns telemetry with
/// the completion cycle of the phase's last request (`start` if the
/// phase is empty). Allocates a fresh [`PhaseScratch`] per call — use
/// [`run_phase_with`] on the hot path.
pub fn run_phase(mem: &mut MemorySystem, phase: &Phase, start: u64) -> PhaseTelemetry {
    run_phase_with(mem, phase, start, &mut PhaseScratch::new())
}

/// [`run_phase`] with caller-owned scratch state; bit-identical to it
/// in every observable (issue order, arrivals, stats), allocation-free
/// at steady state.
pub fn run_phase_with(
    mem: &mut MemorySystem,
    phase: &Phase,
    start: u64,
    scratch: &mut PhaseScratch,
) -> PhaseTelemetry {
    run_phase_onchip(mem, phase, start, scratch, None)
}

/// [`run_phase_with`] with an optional on-chip buffer consulted before
/// every enqueue (see the [module docs](self)). `None` is exactly
/// [`run_phase_with`]; hits are retired at the buffer's latency and
/// never reach `mem`.
pub fn run_phase_onchip(
    mem: &mut MemorySystem,
    phase: &Phase,
    start: u64,
    scratch: &mut PhaseScratch,
    mut onchip: Option<&mut OnChipBuffer>,
) -> PhaseTelemetry {
    if MATERIALIZE_STREAMS.with(|c| c.get()) {
        let materialized = phase.materialized();
        // Drop the flag around the nested call so it can't recurse.
        set_materialize_streams(false);
        let t = run_phase_onchip(mem, &materialized, start, scratch, onchip);
        set_materialize_streams(true);
        return t;
    }

    let n = phase.streams.len();
    let nch = mem.num_channels();
    let PhaseScratch {
        states,
        children,
        arena,
        in_flight,
        slot_free_at,
        waiting,
    } = scratch;
    while states.len() < n {
        states.push(StreamState::default());
    }
    let state = &mut states[..n];
    for (st, s) in state.iter_mut().zip(&phase.streams) {
        let len = s.len();
        st.issued = 0;
        st.len = len;
        st.available = if s.chained_to.is_none() { len } else { 0 };
        st.pending_release.clear();
        st.independent = s.chained_to.is_none();
        st.next_ch = if len > 0 { mem.channel_of(s.line(0)) } else { 0 };
    }
    // Children per parent stream.
    while children.len() < n {
        children.push(Vec::new());
    }
    let children = &mut children[..n];
    for c in children.iter_mut() {
        c.clear();
    }
    for (i, s) in phase.streams.iter().enumerate() {
        if let Some(p) = s.chained_to {
            assert!(p < n, "chained_to out of range");
            assert_ne!(p, i, "stream cannot chain to itself");
            if let Fanout::PerParent(v) = &s.fanout {
                assert_eq!(
                    v.len(),
                    phase.streams[p].len(),
                    "fanout must cover every parent completion"
                );
            }
            // A fan-out that under-releases its stream is NOT asserted
            // here: it surfaces deterministically as a chain deadlock
            // (`SimError::Stalled`) in the service loop below, in every
            // build profile, with full cursor diagnostics.
            children[p].push(i);
        }
    }

    arena.reset();
    let root = arena.add(&phase.merge);

    // The window is a per-channel (per memory port) limit: each PE
    // drives its own channel independently.
    in_flight.clear();
    in_flight.resize(nch, 0);
    slot_free_at.clear();
    slot_free_at.resize(nch, start);
    // Streams with an issuable (released, unissued) request, counted
    // per target channel. At a fill-loop fixpoint every such stream is
    // window-blocked, so a completion can only unblock the fill loop
    // if it frees a slot on a channel with waiters (or releases a
    // chained request onto a channel with capacity) — anything else
    // can be serviced back-to-back without re-walking the merge tree.
    waiting.clear();
    waiting.resize(nch, 0);
    for st in state.iter() {
        if st.available > 0 {
            waiting[st.next_ch] += 1;
        }
    }
    let mut remaining: usize = state.iter().map(|st| st.len).sum();
    let mut total_in_flight = 0usize;
    let mut telemetry = PhaseTelemetry::default();
    let mut end = start;

    loop {
        // Fill windows.
        loop {
            let picked = {
                let state_ref = &state;
                let inflight_ref = &in_flight;
                let window = phase.window;
                let ready = move |s: usize| -> bool {
                    let st = &state_ref[s];
                    st.issued < st.available
                        && st.issued < st.len
                        && inflight_ref[st.next_ch] < window
                };
                arena.pick(root, &ready)
            };
            let Some(s) = picked else { break };
            let st = &mut state[s];
            let idx = st.issued;
            let release = if st.independent {
                start
            } else {
                let run = st.pending_release.front_mut().unwrap();
                let t = run.0;
                run.1 -= 1;
                if run.1 == 0 {
                    st.pending_release.pop_front();
                }
                t
            };
            let stream = &phase.streams[s];
            let addr = stream.line(idx);
            let ch = st.next_ch;
            let parent_len = st.len;
            debug_assert_eq!(ch, mem.channel_of(addr));
            // On-chip consult (tentpole): a hit is retired at the
            // buffer's fixed latency and never reaches the memory
            // system; the miss path below is the unmodified driver.
            let onchip_done = match onchip.as_deref_mut() {
                Some(buf) => buf.access(addr, stream.kind, stream.class.region(), release),
                None => None,
            };
            if onchip_done.is_none() {
                // A request cannot arrive before its data dependency
                // is met, nor before its port had a free slot.
                let arrival = release.max(if in_flight[ch] + 1 == phase.window {
                    slot_free_at[ch]
                } else {
                    start
                });
                mem.enqueue(
                    MemRequest {
                        addr,
                        kind: stream.kind,
                        tag: tag(s, idx),
                        region: stream.class.region(),
                    },
                    arrival,
                );
            }
            st.issued += 1;
            remaining -= 1;
            // Advance the cursor's cached channel and the per-channel
            // waiter counts.
            if st.issued < st.len {
                let nc = mem.channel_of(stream.line(st.issued));
                st.next_ch = nc;
                if st.issued < st.available {
                    if nc != ch {
                        waiting[ch] -= 1;
                        waiting[nc] += 1;
                    }
                } else {
                    waiting[ch] -= 1; // out of released requests
                }
            } else {
                waiting[ch] -= 1; // stream exhausted
            }
            telemetry.requests += 1;
            robust::charge_request();
            match onchip_done {
                None => {
                    in_flight[ch] += 1;
                    total_in_flight += 1;
                }
                Some(done) => {
                    // The hit *is* this request's completion: release
                    // chained children now, exactly as the service
                    // loop below would on a DRAM completion.
                    telemetry.onchip_hits += 1;
                    end = end.max(done);
                    for &c in &children[s] {
                        let f = phase.streams[c].fanout.released_by(idx, parent_len);
                        if f == 0 {
                            continue;
                        }
                        let stc = &mut state[c];
                        if stc.issued == stc.available && stc.issued < stc.len {
                            waiting[stc.next_ch] += 1;
                        }
                        stc.available += f as usize;
                        stc.pending_release.push_back((done, f));
                    }
                }
            }
        }

        if total_in_flight == 0 {
            if remaining > 0 {
                // Chain deadlock: nothing in flight, nothing issuable,
                // yet the phase still holds unissued requests (e.g. a
                // fan-out that releases fewer requests than the chained
                // stream holds). This used to silently terminate with
                // wrong results in release builds.
                raise_stall(state, in_flight, waiting, end);
            }
            break; // nothing issued and nothing issuable -> done
        }

        if remaining == 0 {
            // Everything is issued: no completion can release or
            // unblock anything the fill loop cares about. Retire the
            // in-flight tail in one batch call.
            end = end.max(mem.service_until(u64::MAX, |_| {}));
            robust::note_cycle(end);
            break;
        }

        // Event-driven servicing: keep completing requests until one
        // of them can actually unblock an issue.
        loop {
            let Some(tok) = mem.service_one() else {
                // The memory system refuses to service while the
                // window accounting says requests are in flight — an
                // accelerator-model or memory-model bug. Surface it as
                // a diagnostic, not a panic.
                raise_stall(state, in_flight, waiting, end);
            };
            in_flight[tok.channel] -= 1;
            total_in_flight -= 1;
            slot_free_at[tok.channel] = tok.done_at;
            end = end.max(tok.done_at);
            let (s, idx) = untag(tok.tag);
            // A freed slot matters iff some stream is waiting on this
            // channel's window.
            let mut unblocked = waiting[tok.channel] > 0;
            // Release chained children.
            let parent_len = phase.streams[s].len();
            for &c in &children[s] {
                let f = phase.streams[c].fanout.released_by(idx, parent_len);
                if f == 0 {
                    continue;
                }
                let st = &mut state[c];
                if st.issued == st.available && st.issued < st.len {
                    // The release turns this stream issuable.
                    waiting[st.next_ch] += 1;
                    if in_flight[st.next_ch] < phase.window {
                        unblocked = true;
                    }
                }
                st.available += f as usize;
                st.pending_release.push_back((tok.done_at, f));
            }
            if total_in_flight == 0 || unblocked {
                robust::note_cycle(end);
                break;
            }
        }
    }
    // Every request issued and completed: the structural stall
    // detector above guarantees `remaining == 0` on this path.
    robust::note_cycle(end);

    telemetry.end_cycle = end;
    telemetry
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::stream::{seq_lines, LineSource, LineStream, Merge, Phase, StreamClass};
    use crate::dram::{DramSpec, MemKind};

    fn mem() -> MemorySystem {
        MemorySystem::new(DramSpec::ddr4_2400(1))
    }

    #[test]
    fn empty_phase_is_noop() {
        let mut m = mem();
        let p = Phase::single(StreamClass::Values, MemKind::Read, Vec::<u64>::new(), 8);
        let t = run_phase(&mut m, &p, 100);
        assert_eq!(t.requests, 0);
        assert_eq!(t.end_cycle, 100);
    }

    #[test]
    fn sequential_phase_completes_all() {
        let mut m = mem();
        let p = Phase::single(
            StreamClass::Values,
            MemKind::Read,
            LineSource::seq(0, 64 * 256),
            16,
        );
        let t = run_phase(&mut m, &p, 0);
        assert_eq!(t.requests, 256);
        assert_eq!(m.stats().requests(), 256);
        assert!(t.end_cycle > 0);
    }

    #[test]
    fn phases_compose_in_time() {
        let mut m = mem();
        let p1 = Phase::single(StreamClass::Values, MemKind::Read, LineSource::seq(0, 4096), 8);
        let t1 = run_phase(&mut m, &p1, 0);
        let p2 =
            Phase::single(StreamClass::Writes, MemKind::Write, LineSource::seq(8192, 4096), 8);
        let t2 = run_phase(&mut m, &p2, t1.end_cycle);
        assert!(t2.end_cycle > t1.end_cycle);
    }

    #[test]
    fn chained_stream_waits_for_parent() {
        let mut m = mem();
        // parent: 4 reads; child: 4 writes, one per parent completion.
        let parent = LineStream::independent(
            StreamClass::Edges,
            MemKind::Read,
            LineSource::seq(0, 4 * 64),
        );
        let child = LineStream::chained(
            StreamClass::Writes,
            MemKind::Write,
            LineSource::seq(1 << 20, 4 * 64),
            0,
            vec![1, 1, 1, 1],
        );
        let phase = Phase {
            streams: vec![parent, child],
            merge: Merge::prio([1, 0]).into(), // writes prioritized, as in AccuGraph
            window: 8,
        };
        let t = run_phase(&mut m, &phase, 0);
        assert_eq!(t.requests, 8);
        assert_eq!(m.stats().writes, 4);
        assert_eq!(m.stats().reads, 4);
        // The driver stamps each request with its stream's region.
        use crate::trace::Region;
        assert_eq!(m.stats().region_requests(Region::Edges), 4);
        assert_eq!(m.stats().region_requests(Region::Vertices), 4);
    }

    #[test]
    fn chained_fanout_zero_and_many() {
        let mut m = mem();
        let parent =
            LineStream::independent(StreamClass::Edges, MemKind::Read, LineSource::seq(0, 3 * 64));
        // completion 0 releases 0, completion 1 releases 3, completion 2 releases 1
        let child = LineStream::chained(
            StreamClass::Updates,
            MemKind::Write,
            LineSource::seq(1 << 20, 4 * 64),
            0,
            vec![0, 3, 1],
        );
        let phase = Phase {
            streams: vec![parent, child],
            merge: Merge::prio([0, 1]).into(),
            window: 4,
        };
        let t = run_phase(&mut m, &phase, 0);
        assert_eq!(t.requests, 7);
    }

    #[test]
    fn two_level_chain_completes() {
        let mut m = mem();
        let a =
            LineStream::independent(StreamClass::Edges, MemKind::Read, LineSource::seq(0, 2 * 64));
        let b = LineStream::chained(
            StreamClass::Updates,
            MemKind::Read,
            LineSource::seq(1 << 20, 2 * 64),
            0,
            vec![1, 1],
        );
        let c = LineStream::chained(
            StreamClass::Writes,
            MemKind::Write,
            LineSource::seq(1 << 22, 2 * 64),
            1,
            vec![1, 1],
        );
        let phase = Phase {
            streams: vec![a, b, c],
            merge: Merge::prio([2, 1, 0]).into(),
            window: 4,
        };
        let t = run_phase(&mut m, &phase, 0);
        assert_eq!(t.requests, 6);
        assert_eq!(m.stats().writes, 2);
    }

    #[test]
    fn round_robin_alternates_streams() {
        let mut m = mem();
        let a =
            LineStream::independent(StreamClass::Values, MemKind::Read, LineSource::seq(0, 512));
        let b = LineStream::independent(
            StreamClass::Pointers,
            MemKind::Read,
            LineSource::seq(1 << 21, 512),
        );
        let phase = Phase {
            streams: vec![a, b],
            merge: Merge::rr([0, 1]).into(),
            window: 2,
        };
        let t = run_phase(&mut m, &phase, 0);
        assert_eq!(t.requests, 16);
    }

    #[test]
    fn nested_merge_tree() {
        let mut m = mem();
        let mk = |base: u64| {
            LineStream::independent(StreamClass::Values, MemKind::Read, LineSource::seq(base, 256))
        };
        let phase = Phase {
            streams: vec![mk(0), mk(1 << 20), mk(1 << 21), mk(1 << 22)],
            merge: Merge::Priority(vec![
                Merge::Leaf(3),
                Merge::RoundRobin(vec![Merge::Leaf(0), Merge::Leaf(1), Merge::Leaf(2)]),
            ])
            .into(),
            window: 4,
        };
        let t = run_phase(&mut m, &phase, 0);
        assert_eq!(t.requests, 16);
    }

    #[test]
    fn window_of_one_serializes() {
        let mut m1 = mem();
        let mut m16 = mem();
        // stride of one full row (8 KiB) walks the banks (RoBaRaCoCh:
        // bank bits sit right above the column bits), so bank-level
        // parallelism is available when the window allows it
        let lines = LineSource::strided(0, 8192, 128);
        let p1 = Phase::single(StreamClass::Values, MemKind::Read, lines.clone(), 1);
        let p16 = Phase::single(StreamClass::Values, MemKind::Read, lines, 16);
        let t1 = run_phase(&mut m1, &p1, 0);
        let t16 = run_phase(&mut m16, &p16, 0);
        assert!(
            t1.end_cycle > t16.end_cycle,
            "window=1 {} should be slower than window=16 {}",
            t1.end_cycle,
            t16.end_cycle
        );
    }

    #[test]
    fn materialize_hook_is_bit_identical() {
        // Seq parent releasing one gather-line per completion
        // (Uniform) — exercises every descriptor the models emit.
        let gather = LineSource::gather(1 << 20, 64, (0..40u64).map(|i| i * 7 % 97));
        assert_eq!(gather.len(), 40, "distinct 64 B elements never merge");
        let build = || Phase {
            streams: vec![
                LineStream::independent(
                    StreamClass::Edges,
                    MemKind::Read,
                    LineSource::seq(0, 40 * 64),
                ),
                LineStream::chained(
                    StreamClass::Writes,
                    MemKind::Write,
                    gather.clone(),
                    0,
                    crate::accel::stream::Fanout::Uniform(1),
                ),
            ],
            merge: Merge::prio([1, 0]).into(),
            window: 8,
        };
        let mut m_desc = mem();
        let t_desc = run_phase(&mut m_desc, &build(), 0);
        let mut m_mat = mem();
        let prev = set_materialize_streams(true);
        let t_mat = run_phase(&mut m_mat, &build(), 0);
        set_materialize_streams(prev);
        assert_eq!(t_desc.requests, t_mat.requests);
        assert_eq!(t_desc.end_cycle, t_mat.end_cycle);
        assert_eq!(m_desc.stats(), m_mat.stats());
    }

    #[test]
    fn shared_scratch_is_bit_identical_across_phase_shapes() {
        // One scratch arena reused across phases of different stream
        // counts, chain shapes and merge trees must produce exactly
        // the per-call results (fresh scratch every time).
        let shapes: Vec<Phase> = vec![
            Phase::single(StreamClass::Values, MemKind::Read, LineSource::seq(0, 4096), 8),
            Phase {
                streams: vec![
                    LineStream::independent(
                        StreamClass::Edges,
                        MemKind::Read,
                        LineSource::seq(0, 8 * 64),
                    ),
                    LineStream::chained(
                        StreamClass::Writes,
                        MemKind::Write,
                        LineSource::gather(1 << 20, 4, [0u64, 31, 2, 77, 3]),
                        0,
                        Fanout::AfterLast(5),
                    ),
                ],
                merge: Merge::prio([1, 0]).into(),
                window: 4,
            },
            Phase {
                streams: vec![
                    LineStream::independent(
                        StreamClass::Values,
                        MemKind::Read,
                        LineSource::seq(0, 512),
                    ),
                    LineStream::independent(
                        StreamClass::Pointers,
                        MemKind::Read,
                        LineSource::seq(1 << 21, 512),
                    ),
                    LineStream::independent(
                        StreamClass::Edges,
                        MemKind::Read,
                        LineSource::seq(1 << 22, 512),
                    ),
                ],
                merge: Merge::rr([0, 1, 2]).into(),
                window: 2,
            },
        ];
        let mut m_fresh = mem();
        let mut m_shared = mem();
        let mut scratch = PhaseScratch::new();
        let mut c_fresh = 0;
        let mut c_shared = 0;
        // Two passes so the second pass replays shapes against a
        // fully warmed scratch.
        for _ in 0..2 {
            for ph in &shapes {
                let a = run_phase(&mut m_fresh, ph, c_fresh);
                let b = run_phase_with(&mut m_shared, ph, c_shared, &mut scratch);
                assert_eq!(a.requests, b.requests);
                assert_eq!(a.end_cycle, b.end_cycle);
                c_fresh = a.end_cycle;
                c_shared = b.end_cycle;
            }
        }
        assert_eq!(m_fresh.stats(), m_shared.stats());
    }

    #[test]
    fn onchip_hits_never_reach_the_memory_system() {
        use crate::dram::CACHE_LINE;
        use crate::onchip::{OnChipBuffer, OnChipConfig};
        use crate::trace::Region;
        let mut m = mem();
        // The same 4 vertex lines read twice: second pass must hit.
        let lines: Vec<u64> = [0u64, 1, 2, 3, 0, 1, 2, 3]
            .iter()
            .map(|i| i * CACHE_LINE)
            .collect();
        let phase = Phase::single(StreamClass::Values, MemKind::Read, lines, 8);
        let mut buf = OnChipBuffer::new(OnChipConfig::vertex_cache(8 * CACHE_LINE));
        let t = run_phase_onchip(&mut m, &phase, 0, &mut PhaseScratch::new(), Some(&mut buf));
        assert_eq!(t.requests, 8, "all requests retired");
        assert_eq!(t.onchip_hits, 4, "second pass hits on chip");
        assert_eq!(m.stats().requests(), 4, "hits never reach DRAM");
        assert_eq!(buf.stats().region_hits(Region::Vertices), 4);
        assert_eq!(buf.stats().region_misses(Region::Vertices), 4);
    }

    #[test]
    fn onchip_hit_releases_chained_children() {
        use crate::dram::CACHE_LINE;
        use crate::onchip::{OnChipBuffer, OnChipConfig};
        let mut m = mem();
        // Parent: 2 vertex reads of the SAME line (second hits on
        // chip); child: 2 writes released one per parent completion.
        // If hit completions failed to release children, the driver's
        // exhaustion debug_assert (or a hang) would trip.
        let parent = LineStream::independent(
            StreamClass::Values,
            MemKind::Read,
            vec![0u64, 0u64],
        );
        let child = LineStream::chained(
            StreamClass::Updates,
            MemKind::Write,
            LineSource::seq(1 << 20, 2 * CACHE_LINE),
            0,
            vec![1, 1],
        );
        let phase = Phase {
            streams: vec![parent, child],
            merge: Merge::prio([1, 0]).into(),
            window: 4,
        };
        let mut buf = OnChipBuffer::new(OnChipConfig::vertex_cache(4 * CACHE_LINE));
        let t = run_phase_onchip(&mut m, &phase, 0, &mut PhaseScratch::new(), Some(&mut buf));
        assert_eq!(t.requests, 4);
        assert_eq!(t.onchip_hits, 1);
        assert_eq!(m.stats().writes, 2, "both children released and issued");
        assert_eq!(m.stats().reads, 1, "one parent read hit on chip");
    }

    #[test]
    fn onchip_none_is_the_plain_driver() {
        let mut m_plain = mem();
        let mut m_none = mem();
        let phase = Phase::single(
            StreamClass::Values,
            MemKind::Read,
            LineSource::seq(0, 64 * 64),
            8,
        );
        let a = run_phase_with(&mut m_plain, &phase, 7, &mut PhaseScratch::new());
        let b = run_phase_onchip(&mut m_none, &phase, 7, &mut PhaseScratch::new(), None);
        assert_eq!(a.requests, b.requests);
        assert_eq!(a.end_cycle, b.end_cycle);
        assert_eq!(b.onchip_hits, 0);
        assert_eq!(m_plain.stats(), m_none.stats());
    }

    #[test]
    fn fully_onchip_phase_completes_without_dram() {
        use crate::dram::CACHE_LINE;
        use crate::onchip::{OnChipBuffer, OnChipConfig};
        let mut m = mem();
        let mut buf = OnChipBuffer::new(OnChipConfig::vertex_cache(2 * CACHE_LINE));
        // Pre-warm line 0, then run a phase that only touches it.
        buf.access(0, MemKind::Read, crate::trace::Region::Vertices, 0);
        let phase = Phase::single(StreamClass::Values, MemKind::Read, vec![0u64, 0, 0], 4);
        let t = run_phase_onchip(&mut m, &phase, 50, &mut PhaseScratch::new(), Some(&mut buf));
        assert_eq!(t.requests, 3);
        assert_eq!(t.onchip_hits, 3);
        assert_eq!(m.stats().requests(), 0);
        assert_eq!(t.end_cycle, 50 + OnChipConfig::DEFAULT_HIT_LATENCY);
    }

    #[test]
    #[should_panic(expected = "fanout must cover")]
    fn bad_fanout_panics() {
        let mut m = mem();
        let parent =
            LineStream::independent(StreamClass::Edges, MemKind::Read, seq_lines(0, 2 * 64));
        let child = LineStream::chained(
            StreamClass::Writes,
            MemKind::Write,
            seq_lines(1 << 20, 64),
            0,
            vec![1], // parent has 2 completions
        );
        let phase = Phase {
            streams: vec![parent, child],
            merge: Merge::prio([0, 1]).into(),
            window: 4,
        };
        run_phase(&mut m, &phase, 0);
    }

    /// Parent of 1 completion, chained child of 2 lines released
    /// `Uniform(1)`: one child request can never be released. The
    /// driver must diagnose the chain deadlock as `SimError::Stalled`
    /// (in every build profile), not hang or silently drop work.
    fn stalling_phase() -> Phase {
        let parent =
            LineStream::independent(StreamClass::Edges, MemKind::Read, seq_lines(0, 64));
        let child = LineStream::chained(
            StreamClass::Writes,
            MemKind::Write,
            seq_lines(1 << 20, 2 * 64),
            0,
            Fanout::Uniform(1),
        );
        Phase {
            streams: vec![parent, child],
            merge: Merge::prio([1, 0]).into(),
            window: 4,
        }
    }

    #[test]
    fn chain_deadlock_raises_structured_stall() {
        let phase = stalling_phase();
        let err = crate::robust::catch_sim(|| {
            let mut m = mem();
            run_phase(&mut m, &phase, 0)
        })
        .expect_err("under-releasing fanout must stall");
        let SimError::Stalled(diag) = err else {
            panic!("expected Stalled, got {err:?}");
        };
        // Parent fully issued, child stuck at 1 of 2 with nothing
        // released; both channels idle.
        assert_eq!(diag.streams.len(), 2);
        assert_eq!(diag.streams[0].issued, 1);
        assert_eq!(diag.streams[1].issued, 1);
        assert_eq!(diag.streams[1].len, 2);
        assert_eq!(diag.streams[1].available, 1);
        assert_eq!(diag.total_in_flight(), 0);
        assert!(diag.last_progress_cycle > 0, "parent completed first");
    }

    #[test]
    fn chain_deadlock_diagnosis_is_deterministic() {
        let phase = stalling_phase();
        let run = || {
            crate::robust::catch_sim(|| {
                let mut m = mem();
                run_phase(&mut m, &phase, 0)
            })
            .expect_err("must stall")
        };
        assert_eq!(run(), run(), "same phase, same diagnostics");
    }

    #[test]
    fn budget_max_requests_surfaces_as_typed_error() {
        use crate::robust::{budget, RunBudget};
        let phase = Phase::single(
            StreamClass::Values,
            MemKind::Read,
            LineSource::seq(0, 64 * 64),
            8,
        );
        let err = crate::robust::catch_sim(|| {
            let _scope = budget::install(Some(RunBudget::default().with_max_requests(10)));
            let mut m = mem();
            run_phase(&mut m, &phase, 0)
        })
        .expect_err("64 requests must blow a 10-request budget");
        match err {
            SimError::BudgetExceeded { limit, observed, .. } => {
                assert_eq!(limit, 10);
                assert_eq!(observed, 11, "aborts on the first over-budget request");
            }
            other => panic!("expected BudgetExceeded, got {other:?}"),
        }
    }

    #[test]
    fn budget_max_cycles_surfaces_as_typed_error() {
        use crate::robust::{budget, RunBudget};
        let phase = Phase::single(
            StreamClass::Values,
            MemKind::Read,
            LineSource::seq(0, 64 * 64),
            8,
        );
        let err = crate::robust::catch_sim(|| {
            let _scope = budget::install(Some(RunBudget::default().with_max_cycles(1)));
            let mut m = mem();
            run_phase(&mut m, &phase, 0)
        })
        .expect_err("any real phase outlives a 1-cycle budget");
        assert!(
            matches!(err, SimError::BudgetExceeded { .. }),
            "expected BudgetExceeded, got {err:?}"
        );
    }

    #[test]
    fn unbudgeted_run_is_unaffected() {
        // No budget scope installed: the charge/note hooks must be
        // inert and the phase bit-identical to the pre-robustness
        // driver.
        let phase = Phase::single(
            StreamClass::Values,
            MemKind::Read,
            LineSource::seq(0, 64 * 64),
            8,
        );
        let mut m = mem();
        let t = run_phase(&mut m, &phase, 0);
        assert_eq!(t.requests, 64);
        assert_eq!(m.stats().requests(), 64);
    }
}
