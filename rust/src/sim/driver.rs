//! The phase execution engine: drains a [`Phase`]'s streams through
//! its merge tree into the [`MemorySystem`], honoring the
//! outstanding-request window and the chained-callback releases.
//!
//! Request ordering is exactly the paper's model: "we only simulate
//! request ordering through mandatory control flow caused by data
//! dependencies" — chained streams release on parent completion, and
//! everything else is limited only by the window and the merge
//! arbiter.

use crate::accel::stream::{Merge, Phase};
use crate::dram::{MemRequest, MemorySystem};
use std::collections::VecDeque;

/// Per-phase execution telemetry.
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseTelemetry {
    pub requests: u64,
    /// Cycle at which the phase's last request completed.
    pub end_cycle: u64,
}

/// Per-stream execution state.
struct StreamState {
    issued: usize,
    /// Release times of not-yet-issued requests (chained streams).
    pending_release: VecDeque<u64>,
    independent: bool,
}

/// Arena form of the merge tree. Children lists are stored separately
/// from the (mutable) round-robin rotation state so `pick` can walk
/// the tree without cloning — it runs once per issued request and is
/// on the simulator's hot path.
struct MergeArena {
    kinds: Vec<NodeKind>,
    children: Vec<Vec<usize>>,
    rot: Vec<usize>,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum NodeKind {
    Leaf(usize),
    RoundRobin,
    Priority,
}

impl MergeArena {
    fn build(m: &Merge) -> (MergeArena, usize) {
        let mut arena = MergeArena {
            kinds: Vec::new(),
            children: Vec::new(),
            rot: Vec::new(),
        };
        let root = arena.add(m);
        (arena, root)
    }

    fn add(&mut self, m: &Merge) -> usize {
        match m {
            Merge::Leaf(s) => {
                self.kinds.push(NodeKind::Leaf(*s));
                self.children.push(Vec::new());
                self.rot.push(0);
                self.kinds.len() - 1
            }
            Merge::RoundRobin(ch) => {
                let kids: Vec<usize> = ch.iter().map(|c| self.add(c)).collect();
                self.kinds.push(NodeKind::RoundRobin);
                self.children.push(kids);
                self.rot.push(0);
                self.kinds.len() - 1
            }
            Merge::Priority(ch) => {
                let kids: Vec<usize> = ch.iter().map(|c| self.add(c)).collect();
                self.kinds.push(NodeKind::Priority);
                self.children.push(kids);
                self.rot.push(0);
                self.kinds.len() - 1
            }
        }
    }

    /// Pick the next stream with an available request, advancing RR
    /// rotation state on success.
    fn pick<F: Fn(usize) -> bool>(&mut self, node: usize, ready: &F) -> Option<usize> {
        match self.kinds[node] {
            NodeKind::Leaf(s) => {
                if ready(s) {
                    Some(s)
                } else {
                    None
                }
            }
            NodeKind::Priority => {
                for i in 0..self.children[node].len() {
                    let c = self.children[node][i];
                    if let Some(s) = self.pick(c, ready) {
                        return Some(s);
                    }
                }
                None
            }
            NodeKind::RoundRobin => {
                let k = self.children[node].len();
                let rot0 = self.rot[node];
                for off in 0..k {
                    let i = (rot0 + off) % k;
                    let c = self.children[node][i];
                    if let Some(s) = self.pick(c, ready) {
                        self.rot[node] = (i + 1) % k;
                        return Some(s);
                    }
                }
                None
            }
        }
    }
}

/// Encode (stream, index) into the request tag.
#[inline]
fn tag(stream: usize, idx: usize) -> u64 {
    ((stream as u64) << 40) | idx as u64
}

#[inline]
fn untag(t: u64) -> (usize, usize) {
    ((t >> 40) as usize, (t & 0xFF_FFFF_FFFF) as usize)
}

/// Execute one phase starting at cycle `start`; returns telemetry with
/// the completion cycle of the phase's last request (`start` if the
/// phase is empty).
pub fn run_phase(mem: &mut MemorySystem, phase: &Phase, start: u64) -> PhaseTelemetry {
    let n = phase.streams.len();
    let mut state: Vec<StreamState> = phase
        .streams
        .iter()
        .map(|s| StreamState {
            issued: 0,
            pending_release: VecDeque::new(),
            independent: s.chained_to.is_none(),
        })
        .collect();
    // Children per parent stream.
    let mut children: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, s) in phase.streams.iter().enumerate() {
        if let Some(p) = s.chained_to {
            assert!(p < n, "chained_to out of range");
            assert_ne!(p, i, "stream cannot chain to itself");
            assert_eq!(
                s.fanout.len(),
                phase.streams[p].lines.len(),
                "fanout must cover every parent completion"
            );
            children[p].push(i);
        }
    }

    let (mut arena, root) = MergeArena::build(&phase.merge);

    // The window is a per-channel (per memory port) limit: each PE
    // drives its own channel independently.
    let nch = mem.num_channels();
    let _ = nch;
    let mut in_flight = vec![0usize; nch];
    let mut slot_free_at = vec![start; nch];
    let mut total_in_flight = 0usize;
    let mut telemetry = PhaseTelemetry::default();
    let mut end = start;

    loop {
        // Fill windows.
        loop {
            let picked = {
                let state_ref = &state;
                let streams = &phase.streams;
                let inflight_ref = &in_flight;
                let window = phase.window;
                let mem_ref: &MemorySystem = mem;
                let ready = move |s: usize| -> bool {
                    let st = &state_ref[s];
                    if st.issued >= streams[s].lines.len() {
                        return false;
                    }
                    if !(st.independent || !st.pending_release.is_empty()) {
                        return false;
                    }
                    // target channel must have window capacity
                    let ch = mem_ref.channel_of(streams[s].lines[st.issued]);
                    inflight_ref[ch] < window
                };
                arena.pick(root, &ready)
            };
            let Some(s) = picked else { break };
            let st = &mut state[s];
            let idx = st.issued;
            let release = if st.independent {
                start
            } else {
                st.pending_release.pop_front().unwrap()
            };
            let addr = phase.streams[s].lines[idx];
            let ch = mem.channel_of(addr);
            // A request cannot arrive before its data dependency is
            // met, nor before its port had a free slot.
            let arrival = release.max(if in_flight[ch] + 1 == phase.window {
                slot_free_at[ch]
            } else {
                start
            });
            mem.enqueue(
                MemRequest {
                    addr,
                    kind: phase.streams[s].kind,
                    tag: tag(s, idx),
                    region: phase.streams[s].class.region(),
                },
                arrival,
            );
            st.issued += 1;
            in_flight[ch] += 1;
            total_in_flight += 1;
            telemetry.requests += 1;
        }

        if total_in_flight == 0 {
            break; // nothing issued and nothing issuable -> done
        }

        let tok = mem
            .service_one()
            .expect("in-flight requests must be serviceable");
        in_flight[tok.channel] -= 1;
        total_in_flight -= 1;
        slot_free_at[tok.channel] = tok.done_at;
        end = end.max(tok.done_at);
        let (s, idx) = untag(tok.tag);
        // Release chained children.
        for &c in &children[s] {
            let f = phase.streams[c].fanout[idx];
            for _ in 0..f {
                state[c].pending_release.push_back(tok.done_at);
            }
        }
    }

    // Sanity: every request issued and completed.
    for (i, st) in state.iter().enumerate() {
        debug_assert_eq!(
            st.issued,
            phase.streams[i].lines.len(),
            "stream {i} stuck: issued {} of {} (broken chain?)",
            st.issued,
            phase.streams[i].lines.len()
        );
    }

    telemetry.end_cycle = end;
    telemetry
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::stream::{seq_lines, LineStream, Merge, Phase, StreamClass};
    use crate::dram::{DramSpec, MemKind};

    fn mem() -> MemorySystem {
        MemorySystem::new(DramSpec::ddr4_2400(1))
    }

    #[test]
    fn empty_phase_is_noop() {
        let mut m = mem();
        let p = Phase::single(StreamClass::Values, MemKind::Read, vec![], 8);
        let t = run_phase(&mut m, &p, 100);
        assert_eq!(t.requests, 0);
        assert_eq!(t.end_cycle, 100);
    }

    #[test]
    fn sequential_phase_completes_all() {
        let mut m = mem();
        let p = Phase::single(StreamClass::Values, MemKind::Read, seq_lines(0, 64 * 256), 16);
        let t = run_phase(&mut m, &p, 0);
        assert_eq!(t.requests, 256);
        assert_eq!(m.stats().requests(), 256);
        assert!(t.end_cycle > 0);
    }

    #[test]
    fn phases_compose_in_time() {
        let mut m = mem();
        let p1 = Phase::single(StreamClass::Values, MemKind::Read, seq_lines(0, 4096), 8);
        let t1 = run_phase(&mut m, &p1, 0);
        let p2 = Phase::single(StreamClass::Writes, MemKind::Write, seq_lines(8192, 4096), 8);
        let t2 = run_phase(&mut m, &p2, t1.end_cycle);
        assert!(t2.end_cycle > t1.end_cycle);
    }

    #[test]
    fn chained_stream_waits_for_parent() {
        let mut m = mem();
        // parent: 4 reads; child: 4 writes, one per parent completion.
        let parent = LineStream::independent(
            StreamClass::Edges,
            MemKind::Read,
            seq_lines(0, 4 * 64),
        );
        let child = LineStream::chained(
            StreamClass::Writes,
            MemKind::Write,
            seq_lines(1 << 20, 4 * 64),
            0,
            vec![1, 1, 1, 1],
        );
        let phase = Phase {
            streams: vec![parent, child],
            merge: Merge::prio([1, 0]), // writes prioritized, as in AccuGraph
            window: 8,
        };
        let t = run_phase(&mut m, &phase, 0);
        assert_eq!(t.requests, 8);
        assert_eq!(m.stats().writes, 4);
        assert_eq!(m.stats().reads, 4);
        // The driver stamps each request with its stream's region.
        use crate::trace::Region;
        assert_eq!(m.stats().region_requests(Region::Edges), 4);
        assert_eq!(m.stats().region_requests(Region::Vertices), 4);
    }

    #[test]
    fn chained_fanout_zero_and_many() {
        let mut m = mem();
        let parent =
            LineStream::independent(StreamClass::Edges, MemKind::Read, seq_lines(0, 3 * 64));
        // completion 0 releases 0, completion 1 releases 3, completion 2 releases 1
        let child = LineStream::chained(
            StreamClass::Updates,
            MemKind::Write,
            seq_lines(1 << 20, 4 * 64),
            0,
            vec![0, 3, 1],
        );
        let phase = Phase {
            streams: vec![parent, child],
            merge: Merge::prio([0, 1]),
            window: 4,
        };
        let t = run_phase(&mut m, &phase, 0);
        assert_eq!(t.requests, 7);
    }

    #[test]
    fn two_level_chain_completes() {
        let mut m = mem();
        let a = LineStream::independent(StreamClass::Edges, MemKind::Read, seq_lines(0, 2 * 64));
        let b = LineStream::chained(
            StreamClass::Updates,
            MemKind::Read,
            seq_lines(1 << 20, 2 * 64),
            0,
            vec![1, 1],
        );
        let c = LineStream::chained(
            StreamClass::Writes,
            MemKind::Write,
            seq_lines(1 << 22, 2 * 64),
            1,
            vec![1, 1],
        );
        let phase = Phase {
            streams: vec![a, b, c],
            merge: Merge::prio([2, 1, 0]),
            window: 4,
        };
        let t = run_phase(&mut m, &phase, 0);
        assert_eq!(t.requests, 6);
        assert_eq!(m.stats().writes, 2);
    }

    #[test]
    fn round_robin_alternates_streams() {
        let mut m = mem();
        let a = LineStream::independent(StreamClass::Values, MemKind::Read, seq_lines(0, 512));
        let b = LineStream::independent(
            StreamClass::Pointers,
            MemKind::Read,
            seq_lines(1 << 21, 512),
        );
        let phase = Phase {
            streams: vec![a, b],
            merge: Merge::rr([0, 1]),
            window: 2,
        };
        let t = run_phase(&mut m, &phase, 0);
        assert_eq!(t.requests, 16);
    }

    #[test]
    fn nested_merge_tree() {
        let mut m = mem();
        let mk = |base: u64| {
            LineStream::independent(StreamClass::Values, MemKind::Read, seq_lines(base, 256))
        };
        let phase = Phase {
            streams: vec![mk(0), mk(1 << 20), mk(1 << 21), mk(1 << 22)],
            merge: Merge::Priority(vec![
                Merge::Leaf(3),
                Merge::RoundRobin(vec![Merge::Leaf(0), Merge::Leaf(1), Merge::Leaf(2)]),
            ]),
            window: 4,
        };
        let t = run_phase(&mut m, &phase, 0);
        assert_eq!(t.requests, 16);
    }

    #[test]
    fn window_of_one_serializes() {
        let mut m1 = mem();
        let mut m16 = mem();
        // stride of one full row (8 KiB) walks the banks (RoBaRaCoCh:
        // bank bits sit right above the column bits), so bank-level
        // parallelism is available when the window allows it
        let lines: Vec<u64> = (0..128u64).map(|i| i * 8192).collect();
        let p1 = Phase::single(StreamClass::Values, MemKind::Read, lines.clone(), 1);
        let p16 = Phase::single(StreamClass::Values, MemKind::Read, lines, 16);
        let t1 = run_phase(&mut m1, &p1, 0);
        let t16 = run_phase(&mut m16, &p16, 0);
        assert!(
            t1.end_cycle > t16.end_cycle,
            "window=1 {} should be slower than window=16 {}",
            t1.end_cycle,
            t16.end_cycle
        );
    }

    #[test]
    #[should_panic(expected = "fanout must cover")]
    fn bad_fanout_panics() {
        let mut m = mem();
        let parent =
            LineStream::independent(StreamClass::Edges, MemKind::Read, seq_lines(0, 2 * 64));
        let child = LineStream::chained(
            StreamClass::Writes,
            MemKind::Write,
            seq_lines(1 << 20, 64),
            0,
            vec![1], // parent has 2 completions
        );
        let phase = Phase {
            streams: vec![parent, child],
            merge: Merge::prio([0, 1]),
            window: 4,
        };
        run_phase(&mut m, &phase, 0);
    }
}
