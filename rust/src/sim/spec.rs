//! Typed simulation specifications — the session API that replaces the
//! stringly-typed `run_one(kind, "wv", ..., "ddr4", 1, &cfg)` entry
//! point.
//!
//! A [`SimSpec`] pins down one run completely: accelerator, workload,
//! problem, memory technology, channel count and full
//! [`AcceleratorConfig`]. It is built through [`SimSpecBuilder`], which
//! rejects every unsupported combination (Tab. 1 capability matrix,
//! Fig. 12 channel support, weighted-problem requirements) at *build*
//! time — a successfully built spec always simulates, so
//! [`SimSpec::run`] is infallible.
//!
//! `SimSpec` derives `Hash`/`Eq`, so memoization keys (see
//! [`super::sweep::Session`]) come from the type itself rather than a
//! hand-rolled format string; fields can no longer be silently omitted
//! from the cache key.
//!
//! Workloads are either the named Tab. 2 stand-ins
//! ([`Workload::Named`]) or any user-supplied edge list
//! ([`Workload::Custom`]) — custom graphs flow through the same
//! builder, validation and cache as the benchmark set.

use crate::accel::{AcceleratorConfig, AcceleratorKind, PhaseProgram};
use crate::algo::problem::{GraphProblem, ProblemKind};
use crate::dram::{ChannelMode, DramPolicy, FaultPlan, MemTech, MemorySystem, ServiceOrder};
use crate::graph::datasets::DatasetId;
use crate::graph::EdgeList;
use crate::onchip::{OnChipBuffer, OnChipConfig};
use crate::robust::{RunBudget, SimError};
use crate::sim::metrics::SimReport;
use crate::trace::{AccessPatternAnalyzer, TraceEvent};
use std::fmt;
use std::hash::{Hash, Hasher};
use std::sync::Arc;

/// What graph a simulation runs on.
#[derive(Clone, Debug)]
pub enum Workload {
    /// One of the twelve Tab. 2 benchmark stand-ins.
    Named(DatasetId),
    /// A user-supplied graph. Identity (for `Eq`/`Hash`/memoization)
    /// is the label plus a content digest, so two custom workloads
    /// with the same label but different edges never alias.
    Custom {
        name: String,
        graph: Arc<EdgeList>,
        digest: u64,
    },
}

impl Workload {
    /// Wrap a user-supplied graph.
    pub fn custom(name: impl Into<String>, graph: EdgeList) -> Workload {
        let digest = edge_list_digest(&graph);
        Workload::Custom {
            name: name.into(),
            graph: Arc::new(graph),
            digest,
        }
    }

    /// Short display label ("lj", or the custom name).
    pub fn label(&self) -> &str {
        match self {
            Workload::Named(id) => id.name(),
            Workload::Custom { name, .. } => name,
        }
    }

    /// Materialize the edge list (weighted variant when asked). Both
    /// arms hand out a shared `Arc` — no edge-list copy per run,
    /// however many threads sweep the same graph. Crate-visible so the
    /// advisor's probe can sample the same graph a spec will run on.
    pub(crate) fn resolve(&self, weighted: bool) -> Arc<EdgeList> {
        match self {
            Workload::Named(id) => {
                if weighted {
                    id.load_weighted_shared()
                } else {
                    id.load_shared()
                }
            }
            Workload::Custom { graph, .. } => Arc::clone(graph),
        }
    }
}

impl PartialEq for Workload {
    fn eq(&self, other: &Workload) -> bool {
        match (self, other) {
            (Workload::Named(a), Workload::Named(b)) => a == b,
            (
                Workload::Custom {
                    name: an,
                    digest: ad,
                    ..
                },
                Workload::Custom {
                    name: bn,
                    digest: bd,
                    ..
                },
            ) => an == bn && ad == bd,
            _ => false,
        }
    }
}

impl Eq for Workload {}

impl Hash for Workload {
    fn hash<H: Hasher>(&self, state: &mut H) {
        match self {
            Workload::Named(id) => {
                0u8.hash(state);
                id.hash(state);
            }
            Workload::Custom { name, digest, .. } => {
                1u8.hash(state);
                name.hash(state);
                digest.hash(state);
            }
        }
    }
}

impl From<DatasetId> for Workload {
    fn from(id: DatasetId) -> Workload {
        Workload::Named(id)
    }
}

/// FNV-1a over the structural content of an edge list.
fn edge_list_digest(g: &EdgeList) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    let mut mix = |v: u64| {
        for byte in v.to_le_bytes() {
            h = (h ^ u64::from(byte)).wrapping_mul(PRIME);
        }
    };
    mix(g.num_vertices as u64);
    mix(u64::from(g.directed));
    mix(u64::from(g.weighted));
    for e in &g.edges {
        mix(u64::from(e.src));
        mix(u64::from(e.dst));
        mix(u64::from(e.weight.to_bits()));
    }
    h
}

/// Everything [`SimSpecBuilder::build`] can reject. All combination
/// errors surface here, *before* any simulation work starts.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SpecError {
    /// A required builder field was never set.
    MissingField(&'static str),
    /// `channels == 0` is meaningless.
    ZeroChannels,
    /// Weighted problem on a system without weight support (Tab. 1).
    WeightedUnsupported {
        accelerator: AcceleratorKind,
        problem: ProblemKind,
    },
    /// Multi-channel request on a single-channel design (Fig. 12)
    /// without the open-challenge-(c) experimental flag.
    MultiChannelUnsupported {
        accelerator: AcceleratorKind,
        channels: usize,
    },
    /// More channels than the technology's Tab. 3 / Fig. 12
    /// configuration space provides.
    ChannelsExceedMemTech { mem: MemTech, channels: usize },
    /// Weighted problem on a custom workload that has no weights.
    CustomGraphUnweighted { name: String, problem: ProblemKind },
    /// A dataset name that is not one of the Tab. 2 identifiers.
    UnknownDataset(String),
    /// A DRAM technology name outside ddr3|ddr4|hbm|hbm2.
    UnknownMemTech(String),
    /// A structurally invalid on-chip buffer configuration (see
    /// [`crate::onchip::OnChipConfig::validate`]).
    OnChipInvalid(&'static str),
    /// A sweep axis was left empty.
    EmptyAxis(&'static str),
}

impl fmt::Display for SpecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SpecError::MissingField(field) => {
                write!(f, "spec incomplete: `{field}` was never set")
            }
            SpecError::ZeroChannels => write!(f, "channel count must be at least 1"),
            SpecError::WeightedUnsupported {
                accelerator,
                problem,
            } => write!(
                f,
                "{accelerator} does not support weighted problems (Tab. 1); \
                 {problem} requires edge weights"
            ),
            SpecError::MultiChannelUnsupported {
                accelerator,
                channels,
            } => write!(
                f,
                "{accelerator} is not enabled for multi-channel operation \
                 ({channels} channels requested, Fig. 12); set \
                 experimental_multichannel for the open-challenge-(c) extension"
            ),
            SpecError::ChannelsExceedMemTech { mem, channels } => write!(
                f,
                "{mem} supports at most {} channels in the paper's configuration \
                 space (Tab. 3 / Fig. 12); got {channels}",
                mem.max_channels()
            ),
            SpecError::CustomGraphUnweighted { name, problem } => write!(
                f,
                "custom workload {name:?} has no edge weights, but {problem} \
                 requires them; attach weights (e.g. \
                 EdgeList::with_random_weights) first"
            ),
            SpecError::UnknownDataset(name) => {
                write!(
                    f,
                    "unknown dataset {name:?} (expected one of: {})",
                    crate::graph::datasets::dataset_names().join(" ")
                )
            }
            SpecError::UnknownMemTech(name) => {
                write!(f, "unknown DRAM type {name:?} (ddr3|ddr4|hbm|hbm2)")
            }
            SpecError::OnChipInvalid(why) => {
                write!(f, "invalid on-chip buffer configuration: {why}")
            }
            SpecError::EmptyAxis(axis) => {
                write!(f, "sweep axis `{axis}` is empty — nothing to run")
            }
        }
    }
}

impl std::error::Error for SpecError {}

impl From<SpecError> for SimError {
    /// Build-time rejections fold into the run-time error taxonomy as
    /// [`SimError::InvalidInput`] — callers that assemble specs from
    /// untrusted input (the CLI, sweep frontends) can carry one error
    /// type end to end.
    fn from(err: SpecError) -> SimError {
        SimError::InvalidInput(err.to_string())
    }
}

/// A fully validated simulation specification.
///
/// Construct through [`SimSpec::builder`]; every value of this type is
/// runnable — shape errors are rejected at build time, and run-time
/// failures (a tripped [`RunBudget`], a stalled driver) abort the run
/// as a typed panic that [`SimSpec::run_checked`] catches. Derived
/// `Hash`/`Eq` make it the memoization key of
/// [`super::sweep::Session`].
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct SimSpec {
    accelerator: AcceleratorKind,
    workload: Workload,
    problem: ProblemKind,
    mem: MemTech,
    channels: usize,
    config: AcceleratorConfig,
    /// Collect an access-pattern summary during the run. Part of the
    /// spec's identity (memoized with- and without-analysis runs never
    /// alias).
    patterns: bool,
    /// On-chip buffer model consulted before every request (see
    /// [`crate::onchip`]). Part of the spec's identity; `None` (the
    /// default) is bit-identical to the pre-buffer simulator.
    onchip: Option<OnChipConfig>,
    /// Run budget enforced by the phase driver (see [`crate::robust`]).
    /// Part of the spec's identity; `None` (the default) runs
    /// unguarded, bit-identical to the pre-budget simulator.
    budget: Option<RunBudget>,
    /// Deterministic DRAM fault-injection plan (see
    /// [`crate::dram::fault`]). Part of the spec's identity — faulted
    /// and clean runs never alias in the memo — but, like `onchip`,
    /// absent from [`SimSpec::program_key`]: faults perturb memory
    /// timing only, never compilation.
    faults: Option<FaultPlan>,
    /// Statically verify the compiled program in release builds too
    /// (debug builds always verify; see [`crate::verify`]). Part of
    /// the spec's identity — verified and unverified builds never
    /// alias in the memo — but, like `onchip`, absent from
    /// [`SimSpec::program_key`]: verification proves properties of
    /// the compiled artifact, it never changes it.
    verify: bool,
}

impl SimSpec {
    pub fn builder() -> SimSpecBuilder {
        SimSpecBuilder::new()
    }

    pub fn accelerator(&self) -> AcceleratorKind {
        self.accelerator
    }

    pub fn workload(&self) -> &Workload {
        &self.workload
    }

    pub fn problem(&self) -> ProblemKind {
        self.problem
    }

    pub fn mem(&self) -> MemTech {
        self.mem
    }

    pub fn channels(&self) -> usize {
        self.channels
    }

    pub fn config(&self) -> &AcceleratorConfig {
        &self.config
    }

    /// Whether this spec collects an access-pattern summary.
    pub fn patterns_enabled(&self) -> bool {
        self.patterns
    }

    /// The on-chip buffer configuration, if any.
    pub fn onchip(&self) -> Option<&OnChipConfig> {
        self.onchip.as_ref()
    }

    /// The same spec with a different on-chip buffer (validated) —
    /// the hook for sweeping BRAM budgets over one base spec.
    pub fn with_onchip(mut self, onchip: Option<OnChipConfig>) -> Result<SimSpec, SpecError> {
        if let Some(cfg) = &onchip {
            cfg.validate().map_err(SpecError::OnChipInvalid)?;
        }
        self.onchip = onchip;
        Ok(self)
    }

    /// The run budget, if any.
    pub fn budget(&self) -> Option<&RunBudget> {
        self.budget.as_ref()
    }

    /// The fault-injection plan, if any.
    pub fn faults(&self) -> Option<&FaultPlan> {
        self.faults.as_ref()
    }

    /// Whether this spec statically verifies its compiled program in
    /// release builds too (debug builds always verify).
    pub fn verify_enabled(&self) -> bool {
        self.verify
    }

    /// The same spec with a different run budget — the hook for
    /// wrapping an already-built spec in guardrails. Always valid
    /// (every budget value is enforceable), hence infallible.
    pub fn with_budget(mut self, budget: Option<RunBudget>) -> SimSpec {
        self.budget = budget;
        self
    }

    /// The same spec with a different fault plan — the hook for
    /// sweeping fault scenarios over one base spec. Always valid.
    pub fn with_faults(mut self, faults: Option<FaultPlan>) -> SimSpec {
        self.faults = faults;
        self
    }

    /// How this accelerator places data across channels: the
    /// multi-channel designs (HitGraph, ThunderGP) own per-channel
    /// regions; the single-channel designs stripe line-interleaved.
    pub fn channel_mode(&self) -> ChannelMode {
        if self.accelerator.multi_channel() {
            ChannelMode::Region
        } else {
            ChannelMode::InterleaveLine
        }
    }

    /// An [`AccessPatternAnalyzer`] configured exactly as this spec's
    /// in-simulation analysis: feed it the events of a trace produced
    /// by [`SimSpec::run_traced`] and it yields the same summary that
    /// `.patterns(true)` attaches to the report.
    pub fn pattern_analyzer(&self) -> AccessPatternAnalyzer {
        AccessPatternAnalyzer::new(self.mem.spec(self.channels), self.channel_mode())
    }

    /// Compact human label, e.g. `AccuGraph/lj/BFS/ddr4x1`.
    pub fn label(&self) -> String {
        format!(
            "{}/{}/{}/{}x{}",
            self.accelerator,
            self.workload.label(),
            self.problem,
            self.mem,
            self.channels
        )
    }

    /// The memory-independent sub-key of this spec: exactly what
    /// [`SimSpec::compile_program`] consumes. Specs that differ only
    /// in memory technology, pattern collection, or the *kind* of
    /// problem (compilation reads just the weighted-variant graph,
    /// never the algorithm) share a key — and therefore share one
    /// compiled [`PhaseProgram`] in a [`super::sweep::Session`]'s
    /// program cache. The channel count participates through the
    /// normalized config (multi-channel partitioning depends on it).
    pub fn program_key(&self) -> ProgramKey {
        ProgramKey {
            accelerator: self.accelerator,
            workload: self.workload.clone(),
            weighted: self.problem.weighted(),
            config: self.config.clone(),
        }
    }

    /// Compile this spec's [`PhaseProgram`]: the iteration-invariant,
    /// memory-independent half of the simulation (partitioning,
    /// layout, stream descriptors, merge trees). The result is
    /// immutable and `Send + Sync` — share it across threads and
    /// replay it with [`SimSpec::run_with_program`].
    pub fn compile_program(&self) -> Arc<PhaseProgram> {
        let program = self.compile_unverified();
        if cfg!(debug_assertions) || self.verify {
            let rep = self.verify_report(&program);
            assert!(
                rep.is_ok(),
                "compiled {:?} program failed static verification ({rep}):\n{}",
                self.accelerator,
                rep.violations
                    .iter()
                    .map(|v| format!("  {v}"))
                    .collect::<Vec<_>>()
                    .join("\n"),
            );
        }
        program
    }

    fn compile_unverified(&self) -> Arc<PhaseProgram> {
        let g = self.workload.resolve(self.problem.weighted());
        Arc::new(
            PhaseProgram::compile(self.accelerator, &g, &self.config)
                .with_key(self.program_key()),
        )
    }

    /// Statically verify an already-compiled program against this
    /// spec's memory system and on-chip buffer — the non-panicking
    /// form of the [`SimSpec::compile_program`] tripwire, used by
    /// `graphmem serve` admission and `graphmem lint`. See
    /// [`crate::verify`] for the invariants proven.
    pub fn verify_report(&self, program: &PhaseProgram) -> crate::verify::VerifyReport {
        crate::verify::ProgramChecker::new(self.mem.spec(self.channels).channel_bytes)
            .check(&program.facts(), self.onchip.as_ref())
    }

    /// Compile this spec's program and statically verify it,
    /// returning the typed report instead of panicking.
    pub fn verify_program(&self) -> crate::verify::VerifyReport {
        self.verify_report(&self.compile_unverified())
    }

    /// Execute the simulation. Infallible: every invalid combination
    /// was rejected by [`SimSpecBuilder::build`]. When the spec was
    /// built with `.patterns(true)`, the returned report carries an
    /// [`crate::trace::AccessPatternSummary`] in
    /// [`SimReport::patterns`]. Compiles a fresh program per call;
    /// [`super::sweep::Session::run`] amortizes compilation across a
    /// sweep instead.
    pub fn run(&self) -> SimReport {
        self.run_inner(false).0
    }

    /// [`SimSpec::run`] with every abnormal outcome returned as a
    /// typed [`SimError`] instead of unwinding: a stalled phase
    /// engine, an exceeded [`RunBudget`], or any panic escaping the
    /// simulation core (reported as [`SimError::Panicked`] with the
    /// payload message). A successful run is bit-identical to
    /// [`SimSpec::run`].
    pub fn run_checked(&self) -> Result<SimReport, SimError> {
        crate::robust::catch_sim(|| self.run())
    }

    /// [`SimSpec::run`] against a pre-compiled program (see
    /// [`SimSpec::compile_program`]); bit-identical to a fresh
    /// compile. The program must stem from a spec with the same
    /// [`SimSpec::program_key`] — a mismatch panics (a program
    /// compiled for a different workload/config would otherwise
    /// silently simulate the wrong graph under this spec's label).
    pub fn run_with_program(&self, program: &PhaseProgram) -> SimReport {
        self.run_with_program_inner(program, false).0
    }

    /// Like [`SimSpec::run`], but records every issued request and
    /// returns the issue-order trace alongside the report (the
    /// `graphmem trace` / `graphmem analyze --trace` substrate).
    pub fn run_traced(&self) -> (SimReport, Vec<TraceEvent>) {
        let (report, trace) = self.run_inner(true);
        (report, trace.unwrap_or_default())
    }

    /// [`SimSpec::run_traced`] with every DRAM completion selected by
    /// the linear-scan reference
    /// ([`crate::dram::MemorySystem::service_one_scan`]) instead of
    /// the arrival heap. Bit-identical report and trace — the
    /// heap/scan equivalence suite (`tests/heap_scan_c32.rs`) asserts
    /// this end-to-end at up to 32 HBM2 pseudo-channels.
    pub fn run_traced_scan(&self) -> (SimReport, Vec<TraceEvent>) {
        let program = self.compile_program();
        let mut mem =
            MemorySystem::with_mode(self.mem.spec(self.channels), self.channel_mode());
        mem.set_service_order(ServiceOrder::Scan);
        let (report, trace) = self.run_on(&program, &mut mem, true);
        (report, trace.unwrap_or_default())
    }

    /// [`SimSpec::run_with_program`] against a caller-owned, reusable
    /// [`RunScratch`]: the scratch's [`MemorySystem`] is reset in
    /// place instead of constructed per run — the last per-run
    /// allocation of any size on the sweep hot path. Bit-identical to
    /// [`SimSpec::run_with_program`] (asserted by the sweep
    /// equivalence tests); [`super::sweep::Session`] threads one
    /// scratch per worker thread through its batches.
    pub fn run_with_program_scratch(
        &self,
        program: &PhaseProgram,
        scratch: &mut RunScratch,
    ) -> SimReport {
        let dram = self.mem.spec(self.channels);
        let mode = self.channel_mode();
        let mem = match &mut scratch.mem {
            Some(m) => {
                m.reset(dram, mode, DramPolicy::default());
                m
            }
            None => scratch.mem.insert(MemorySystem::with_mode(dram, mode)),
        };
        self.run_on(program, mem, false).0
    }

    fn run_inner(&self, record_trace: bool) -> (SimReport, Option<Vec<TraceEvent>>) {
        let program = self.compile_program();
        self.run_with_program_inner(&program, record_trace)
    }

    fn run_with_program_inner(
        &self,
        program: &PhaseProgram,
        record_trace: bool,
    ) -> (SimReport, Option<Vec<TraceEvent>>) {
        let mut mem =
            MemorySystem::with_mode(self.mem.spec(self.channels), self.channel_mode());
        self.run_on(program, &mut mem, record_trace)
    }

    /// Execute against an already-configured memory system (freshly
    /// constructed or [`MemorySystem::reset`]). The single execution
    /// path behind every `run*` entry point.
    fn run_on(
        &self,
        program: &PhaseProgram,
        mem: &mut MemorySystem,
        record_trace: bool,
    ) -> (SimReport, Option<Vec<TraceEvent>>) {
        assert_eq!(
            program.kind(),
            self.accelerator,
            "program compiled for a different accelerator"
        );
        if let Some(key) = program.key() {
            assert!(
                *key == self.program_key(),
                "program/spec mismatch: the program was compiled for a different \
                 workload/problem/config than {}",
                self.label()
            );
        }
        let g = self.workload.resolve(self.problem.weighted());
        // Structural guard for hand-compiled programs too (key-less):
        // graph shape, weightedness and configuration must match.
        assert!(
            program.compiled_for(&g, &self.config),
            "program/spec mismatch: the program was compiled for a different \
             graph shape or configuration than {}",
            self.label()
        );
        let p = GraphProblem::new(self.problem, &g);
        if record_trace {
            mem.enable_trace();
        }
        if self.patterns {
            mem.attach_analyzer();
        }
        // Guardrails: install the fault lanes on the (fresh or reset)
        // memory system and scope the run budget to this thread for
        // the duration of the execution. Both are no-ops when unset.
        mem.set_faults(self.faults.as_ref());
        let _budget = crate::robust::budget::install(self.budget.clone());
        let mut onchip = self.onchip.as_ref().map(|c| OnChipBuffer::new(c.clone()));
        let mut report = program.execute_onchip(&p, mem, onchip.as_mut());
        report.patterns = mem.take_pattern_summary();
        report.onchip = onchip.map(OnChipBuffer::into_stats);
        let trace = mem.take_trace();
        (report, trace)
    }
}

/// Reusable per-worker run state: one [`MemorySystem`] reset in place
/// per run instead of constructed per spec (see
/// [`SimSpec::run_with_program_scratch`]). Lazily initialized on first
/// use; reconfigures itself across memory technologies, channel counts
/// and channel modes while retaining queue and bank allocations.
#[derive(Default)]
pub struct RunScratch {
    mem: Option<MemorySystem>,
}

impl RunScratch {
    pub fn new() -> RunScratch {
        RunScratch::default()
    }
}

/// The memory-independent sub-key of a [`SimSpec`] — the program-cache
/// key of [`super::sweep::Session`]. Everything
/// [`SimSpec::compile_program`] reads, nothing it doesn't: memory
/// technology, the `patterns` toggle and the problem *kind* are
/// deliberately absent (compilation consumes only the
/// weighted-or-not variant of the graph plus the configuration), so
/// `mem_techs` and `problems` sweep axes share compiled programs.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ProgramKey {
    pub accelerator: AcceleratorKind,
    pub workload: Workload,
    /// Whether the weighted variant of the workload is compiled
    /// against (12 B edges vs 8 B — changes layouts and line counts).
    pub weighted: bool,
    pub config: AcceleratorConfig,
}

/// Fluent builder for [`SimSpec`]; all validation happens in
/// [`SimSpecBuilder::build`].
#[derive(Clone, Debug, Default)]
pub struct SimSpecBuilder {
    accelerator: Option<AcceleratorKind>,
    workload: Option<Workload>,
    problem: Option<ProblemKind>,
    mem: Option<MemTech>,
    channels: Option<usize>,
    config: Option<AcceleratorConfig>,
    /// Parse errors from the `*_named` convenience setters, one slot
    /// per axis (so a bad dataset name cannot shadow a bad DRAM name),
    /// surfaced at build time. A later successful setter for the same
    /// axis clears its slot — fallback patterns like "try the user's
    /// name, then a default" must not stay poisoned.
    deferred_dataset: Option<SpecError>,
    deferred_mem: Option<SpecError>,
    patterns: bool,
    onchip: Option<OnChipConfig>,
    /// Resolve [`OnChipConfig::default_for`] at build time (when the
    /// accelerator and configuration are known). Between
    /// [`SimSpecBuilder::onchip`] and [`SimSpecBuilder::onchip_default`],
    /// the later call wins.
    onchip_default: bool,
    budget: Option<RunBudget>,
    faults: Option<FaultPlan>,
    verify: bool,
    /// Advisor resolution flags: when any is set, `build` runs the
    /// advisor probe and folds the chosen values into the spec. The
    /// flags themselves never reach [`SimSpec`] — only the resolved
    /// choices do — so advisor-built and hand-built specs with the
    /// same values stay bit-identical.
    auto_partition: bool,
    auto_placement: bool,
    auto_onchip: bool,
}

impl SimSpecBuilder {
    pub fn new() -> SimSpecBuilder {
        SimSpecBuilder::default()
    }

    pub fn accelerator(mut self, kind: AcceleratorKind) -> Self {
        self.accelerator = Some(kind);
        self
    }

    /// Benchmark workload by typed id.
    pub fn graph(mut self, id: DatasetId) -> Self {
        self.workload = Some(Workload::Named(id));
        self.deferred_dataset = None;
        self
    }

    /// Benchmark workload by paper short name; an unknown name is
    /// reported by [`SimSpecBuilder::build`].
    pub fn graph_named(mut self, name: &str) -> Self {
        match name.parse::<DatasetId>() {
            Ok(id) => {
                self.workload = Some(Workload::Named(id));
                self.deferred_dataset = None;
            }
            Err(_) => {
                self.deferred_dataset = Some(SpecError::UnknownDataset(name.to_string()));
            }
        }
        self
    }

    /// Any workload value (named or custom).
    pub fn workload(mut self, workload: Workload) -> Self {
        self.workload = Some(workload);
        self.deferred_dataset = None;
        self
    }

    /// User-supplied graph; flows through the same validation and
    /// cache as the named datasets.
    pub fn custom_graph(mut self, name: impl Into<String>, graph: EdgeList) -> Self {
        self.workload = Some(Workload::custom(name, graph));
        self.deferred_dataset = None;
        self
    }

    pub fn problem(mut self, problem: ProblemKind) -> Self {
        self.problem = Some(problem);
        self
    }

    /// Memory technology (defaults to DDR4, the paper's baseline).
    pub fn mem(mut self, mem: MemTech) -> Self {
        self.mem = Some(mem);
        self.deferred_mem = None;
        self
    }

    /// Memory technology by name; an unknown name is reported by
    /// [`SimSpecBuilder::build`].
    pub fn mem_named(mut self, name: &str) -> Self {
        match name.parse::<MemTech>() {
            Ok(tech) => {
                self.mem = Some(tech);
                self.deferred_mem = None;
            }
            Err(_) => {
                self.deferred_mem = Some(SpecError::UnknownMemTech(name.to_string()));
            }
        }
        self
    }

    /// Memory channel count (defaults to 1). Also applied to the
    /// accelerator configuration at build time.
    pub fn channels(mut self, channels: usize) -> Self {
        self.channels = Some(channels);
        self
    }

    /// Full accelerator configuration (defaults to
    /// [`AcceleratorConfig::default`], the no-optimization baseline).
    pub fn config(mut self, config: AcceleratorConfig) -> Self {
        self.config = Some(config);
        self
    }

    /// Collect an access-pattern summary during the run (off by
    /// default — the streaming analyzer costs a few percent of
    /// simulation time). The summary arrives on
    /// [`SimReport::patterns`]:
    ///
    /// ```
    /// use graphmem::accel::AcceleratorKind;
    /// use graphmem::algo::problem::ProblemKind;
    /// use graphmem::graph::DatasetId;
    /// use graphmem::sim::SimSpec;
    /// use graphmem::trace::Region;
    ///
    /// let report = SimSpec::builder()
    ///     .accelerator(AcceleratorKind::ThunderGp)
    ///     .graph(DatasetId::Sd)
    ///     .problem(ProblemKind::Bfs)
    ///     .patterns(true)
    ///     .build()
    ///     .unwrap()
    ///     .run();
    /// let summary = report.patterns.as_ref().unwrap();
    /// assert!(summary.region(Region::Edges).seq_fraction() > 0.5);
    /// assert!(summary.region(Region::Updates).requests() > 0);
    /// ```
    pub fn patterns(mut self, on: bool) -> Self {
        self.patterns = on;
        self
    }

    /// Model an on-chip buffer (see [`crate::onchip`]): the phase
    /// driver consults it before every request — hits retire at the
    /// buffer's fixed latency and never reach DRAM. Part of the spec's
    /// identity (memoized buffered and unbuffered runs never alias)
    /// but **not** of [`SimSpec::program_key`]: the buffer affects
    /// execution only, so BRAM-budget sweeps share one compiled
    /// program. Default `None` keeps every report bit-identical to the
    /// pre-buffer simulator.
    ///
    /// ```
    /// use graphmem::accel::AcceleratorKind;
    /// use graphmem::algo::problem::ProblemKind;
    /// use graphmem::graph::DatasetId;
    /// use graphmem::onchip::OnChipConfig;
    /// use graphmem::sim::SimSpec;
    /// use graphmem::trace::Region;
    ///
    /// // AccuGraph with its on-chip vertex array modelled: vertex
    /// // hits retire in BRAM, so DRAM sees less vertex traffic.
    /// let cached = SimSpec::builder()
    ///     .accelerator(AcceleratorKind::AccuGraph)
    ///     .graph(DatasetId::Sd)
    ///     .problem(ProblemKind::Bfs)
    ///     .onchip(OnChipConfig::vertex_cache(64 * 1024))
    ///     .build()
    ///     .unwrap()
    ///     .run();
    /// let stats = cached.onchip.as_ref().unwrap();
    /// assert!(stats.region_hits(Region::Vertices) > 0);
    /// assert!(cached.dram.region_requests(Region::Vertices) < stats.region_accesses(Region::Vertices));
    /// ```
    pub fn onchip(mut self, config: impl Into<Option<OnChipConfig>>) -> Self {
        self.onchip = config.into();
        self.onchip_default = false;
        self
    }

    /// Use the accelerator's paper-faithful default buffer
    /// ([`OnChipConfig::default_for`]), resolved at build time:
    /// AccuGraph's vertex array, ForeGraph's interval cache, and no
    /// buffer for the streaming designs (HitGraph, ThunderGP).
    pub fn onchip_default(mut self) -> Self {
        self.onchip = None;
        self.onchip_default = true;
        self
    }

    /// Abort the run when it exceeds the given [`RunBudget`] —
    /// simulated cycles, issued requests, or wall-clock time. The
    /// violation surfaces as [`SimError::BudgetExceeded`] through
    /// [`SimSpec::run_checked`] (plain [`SimSpec::run`] unwinds with
    /// the same typed payload). Part of the spec's identity, so a
    /// budgeted run never aliases an unguarded one in the memo.
    ///
    /// ```
    /// use graphmem::accel::AcceleratorKind;
    /// use graphmem::algo::problem::ProblemKind;
    /// use graphmem::graph::DatasetId;
    /// use graphmem::robust::{RunBudget, SimError};
    /// use graphmem::sim::SimSpec;
    ///
    /// let spec = SimSpec::builder()
    ///     .accelerator(AcceleratorKind::HitGraph)
    ///     .graph(DatasetId::Sd)
    ///     .problem(ProblemKind::Bfs)
    ///     .budget(RunBudget::default().with_max_requests(100))
    ///     .build()
    ///     .unwrap();
    /// match spec.run_checked() {
    ///     Err(SimError::BudgetExceeded { limit: 100, .. }) => {}
    ///     other => panic!("expected a budget violation, got {other:?}"),
    /// }
    /// ```
    pub fn budget(mut self, budget: impl Into<Option<RunBudget>>) -> Self {
        self.budget = budget.into();
        self
    }

    /// Statically verify the compiled program (see [`crate::verify`])
    /// in release builds too — debug builds always verify. The flag
    /// joins the memo key (verified and unverified runs never alias)
    /// but not [`SimSpec::program_key`]: the checker proves
    /// properties of the compiled artifact, it never changes it.
    pub fn verify(mut self, on: bool) -> Self {
        self.verify = on;
        self
    }

    /// Inject deterministic DRAM faults (see [`crate::dram::fault`])
    /// during the run: the seeded plan adds completion delay to
    /// selected serviced requests — results are invariant, cycles
    /// move, and [`crate::dram::DramStats::faults_injected`] proves
    /// the faults fired. Part of the spec's identity but not of
    /// [`SimSpec::program_key`] (faults never touch compilation).
    ///
    /// ```
    /// use graphmem::accel::AcceleratorKind;
    /// use graphmem::algo::problem::ProblemKind;
    /// use graphmem::dram::FaultPlan;
    /// use graphmem::graph::DatasetId;
    /// use graphmem::sim::SimSpec;
    ///
    /// let base = SimSpec::builder()
    ///     .accelerator(AcceleratorKind::HitGraph)
    ///     .graph(DatasetId::Sd)
    ///     .problem(ProblemKind::Bfs);
    /// let clean = base.clone().build().unwrap().run();
    /// let faulted = base.faults(FaultPlan::refresh_storm(7)).build().unwrap().run();
    /// assert!(faulted.dram.faults_injected > 0);
    /// assert_eq!(faulted.dram.requests(), clean.dram.requests());
    /// assert!(faulted.cycles >= clean.cycles);
    /// ```
    pub fn faults(mut self, plan: impl Into<Option<FaultPlan>>) -> Self {
        self.faults = plan.into();
        self
    }

    /// Let the advisor ([`crate::advisor`]) pick the partition
    /// capacity: at build time a cheap probe runs and the balanced
    /// capacity it derives replaces `bram_values`
    /// (`foregraph_interval` for ForeGraph) in the returned spec.
    /// Resolution is by value — the result is bit-identical to the
    /// same choice made by hand:
    ///
    /// ```
    /// use graphmem::accel::{AcceleratorConfig, AcceleratorKind};
    /// use graphmem::algo::problem::ProblemKind;
    /// use graphmem::graph::synthetic;
    /// use graphmem::sim::SimSpec;
    ///
    /// let g = synthetic::erdos_renyi(2_000, 8_000, 7);
    /// let auto = SimSpec::builder()
    ///     .accelerator(AcceleratorKind::AccuGraph)
    ///     .custom_graph("er2k", g.clone())
    ///     .problem(ProblemKind::PageRank)
    ///     .auto_partition(true)
    ///     .build()
    ///     .unwrap();
    /// // 2,000 vertices fit one partition, so the advisor balances
    /// // the default 16,384-value capacity down to exactly 2,000.
    /// assert_eq!(auto.config().bram_values, 2_000);
    /// let mut cfg = AcceleratorConfig::default();
    /// cfg.bram_values = 2_000;
    /// let manual = SimSpec::builder()
    ///     .accelerator(AcceleratorKind::AccuGraph)
    ///     .custom_graph("er2k", g)
    ///     .problem(ProblemKind::PageRank)
    ///     .config(cfg)
    ///     .build()
    ///     .unwrap();
    /// assert_eq!(auto, manual); // one memo entry, shared program
    /// ```
    pub fn auto_partition(mut self, on: bool) -> Self {
        self.auto_partition = on;
        self
    }

    /// Let the advisor pick the channel count (and thereby the
    /// placement mode) from the probe's bus utilization. Overrides an
    /// explicit [`SimSpecBuilder::channels`] value when set.
    pub fn auto_placement(mut self, on: bool) -> Self {
        self.auto_placement = on;
        self
    }

    /// Let the advisor size the on-chip buffer from the probe's
    /// reuse-interval histograms — possibly to `None` for streaming
    /// workloads. Overrides [`SimSpecBuilder::onchip`] /
    /// [`SimSpecBuilder::onchip_default`] when set.
    pub fn auto_onchip(mut self, on: bool) -> Self {
        self.auto_onchip = on;
        self
    }

    /// Validate and freeze. Every unsupported combination is rejected
    /// here, before any simulation work. When any `auto_*` flag is
    /// set, the advisor probes the workload first and its choices are
    /// resolved *into* the returned spec (a second validation pass
    /// then applies as usual), so downstream memoization never sees
    /// the flags — only their resolved values.
    pub fn build(self) -> Result<SimSpec, SpecError> {
        let (auto_partition, auto_placement, auto_onchip) =
            (self.auto_partition, self.auto_placement, self.auto_onchip);
        let patterns = self.patterns;
        let verify = self.verify;
        let base = self.build_base()?;
        if !(auto_partition || auto_placement || auto_onchip) {
            return Ok(base);
        }
        // The probe spec inside recommend() is built without auto
        // flags, so this recursion is one level deep.
        let rec = crate::advisor::Advisor::new().recommend(&base)?;
        let mut config = base.config().clone();
        if auto_partition {
            match base.accelerator() {
                AcceleratorKind::ForeGraph => {
                    config.foregraph_interval = rec.partitioning.capacity_values;
                }
                _ => config.bram_values = rec.partitioning.capacity_values,
            }
        }
        let channels = if auto_placement {
            rec.placement.channels
        } else {
            base.channels()
        };
        let onchip = if auto_onchip {
            rec.onchip.config.clone()
        } else {
            base.onchip.clone()
        };
        SimSpec::builder()
            .accelerator(base.accelerator())
            .workload(base.workload().clone())
            .problem(base.problem())
            .mem(base.mem())
            .channels(channels)
            .config(config)
            .patterns(patterns)
            .onchip(onchip)
            .budget(base.budget.clone())
            .faults(base.faults.clone())
            .verify(verify)
            .build_base()
    }

    /// The validation core shared by plain and advisor-resolved
    /// builds.
    fn build_base(self) -> Result<SimSpec, SpecError> {
        if let Some(err) = self.deferred_dataset {
            return Err(err);
        }
        if let Some(err) = self.deferred_mem {
            return Err(err);
        }
        let accelerator = self.accelerator.ok_or(SpecError::MissingField("accelerator"))?;
        let workload = self.workload.ok_or(SpecError::MissingField("workload"))?;
        let problem = self.problem.ok_or(SpecError::MissingField("problem"))?;
        let mem = self.mem.unwrap_or(MemTech::Ddr4);
        let channels = self.channels.unwrap_or(1);
        let config = self.config.unwrap_or_default();

        if channels == 0 {
            return Err(SpecError::ZeroChannels);
        }
        if problem.weighted() && !accelerator.supports_weighted() {
            return Err(SpecError::WeightedUnsupported {
                accelerator,
                problem,
            });
        }
        if channels > 1 && !accelerator.multi_channel() && !config.experimental_multichannel {
            return Err(SpecError::MultiChannelUnsupported {
                accelerator,
                channels,
            });
        }
        if channels > mem.max_channels() {
            return Err(SpecError::ChannelsExceedMemTech { mem, channels });
        }
        if let Workload::Custom { name, graph, .. } = &workload {
            if problem.weighted() && !graph.weighted {
                return Err(SpecError::CustomGraphUnweighted {
                    name: name.clone(),
                    problem,
                });
            }
        }
        // Normalize: the spec's channel axis is authoritative, so the
        // config the accelerator sees (and the derived cache key)
        // always agree with it; the optimization list is canonicalized
        // so insertion order cannot split the memo key.
        let mut config = config.with_channels(channels);
        config.optimizations.sort_unstable();
        config.optimizations.dedup();
        let onchip = if self.onchip_default {
            OnChipConfig::default_for(accelerator, &config)
        } else {
            self.onchip
        };
        if let Some(cfg) = &onchip {
            cfg.validate().map_err(SpecError::OnChipInvalid)?;
        }
        Ok(SimSpec {
            accelerator,
            workload,
            problem,
            mem,
            channels,
            config,
            patterns: self.patterns,
            onchip,
            budget: self.budget,
            faults: self.faults,
            verify: self.verify,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synthetic;

    fn base() -> SimSpecBuilder {
        SimSpec::builder()
            .accelerator(AcceleratorKind::HitGraph)
            .graph(DatasetId::Sd)
            .problem(ProblemKind::Bfs)
    }

    #[test]
    fn builder_defaults() {
        let spec = base().build().unwrap();
        assert_eq!(spec.mem(), MemTech::Ddr4);
        assert_eq!(spec.channels(), 1);
        assert_eq!(spec.config().channels, 1);
        assert_eq!(spec.label(), "HitGraph/sd/BFS/ddr4x1");
    }

    #[test]
    fn missing_fields_are_reported() {
        let err = SimSpec::builder().build().unwrap_err();
        assert_eq!(err, SpecError::MissingField("accelerator"));
        let err = SimSpec::builder()
            .accelerator(AcceleratorKind::HitGraph)
            .build()
            .unwrap_err();
        assert_eq!(err, SpecError::MissingField("workload"));
    }

    #[test]
    fn weighted_combinations_validated_at_build() {
        for kind in AcceleratorKind::all() {
            let res = base().accelerator(kind).problem(ProblemKind::Sssp).build();
            if kind.supports_weighted() {
                assert!(res.is_ok(), "{kind}");
            } else {
                assert!(
                    matches!(res, Err(SpecError::WeightedUnsupported { .. })),
                    "{kind}"
                );
            }
        }
    }

    #[test]
    fn multichannel_needs_support_or_flag() {
        let err = base()
            .accelerator(AcceleratorKind::ForeGraph)
            .channels(4)
            .build()
            .unwrap_err();
        assert!(matches!(err, SpecError::MultiChannelUnsupported { .. }));
        // Experimental flag unlocks the open-challenge-(c) extension.
        let ok = base()
            .accelerator(AcceleratorKind::ForeGraph)
            .channels(4)
            .config(AcceleratorConfig::default().with_experimental_multichannel(true))
            .build();
        assert!(ok.is_ok());
        // Native multi-channel designs need no flag.
        assert!(base().channels(4).build().is_ok());
    }

    #[test]
    fn named_setters_defer_errors_to_build() {
        let err = base().graph_named("zz").build().unwrap_err();
        assert_eq!(err, SpecError::UnknownDataset("zz".to_string()));
        assert!(err.to_string().contains("unknown dataset"));
        let err = base().mem_named("dd5").build().unwrap_err();
        assert_eq!(err, SpecError::UnknownMemTech("dd5".to_string()));
        assert!(base().graph_named("lj").mem_named("hbm").build().is_ok());
    }

    #[test]
    fn later_valid_setter_overrides_deferred_parse_error() {
        // Fallback pattern: a bad user-supplied name followed by a
        // valid default must not stay poisoned...
        assert!(base().graph_named("zz").graph(DatasetId::Lj).build().is_ok());
        assert!(base().graph_named("zz").graph_named("lj").build().is_ok());
        assert!(base().mem_named("dd5").mem(MemTech::Hbm).build().is_ok());
        // ...but an untouched axis keeps its error: the slots are
        // per-axis, so fixing the dataset cannot swallow a bad DRAM
        // name (and vice versa).
        let err = base().graph_named("zz").mem_named("hbm").build().unwrap_err();
        assert_eq!(err, SpecError::UnknownDataset("zz".to_string()));
        let err = base()
            .graph_named("zz")
            .mem_named("dd5")
            .graph(DatasetId::Lj)
            .build()
            .unwrap_err();
        assert_eq!(err, SpecError::UnknownMemTech("dd5".to_string()));
    }

    #[test]
    fn optimization_order_does_not_split_the_memo_key() {
        use crate::accel::Optimization;
        let ab = AcceleratorConfig::default()
            .with(Optimization::EdgeSorting)
            .with(Optimization::UpdateCombining);
        let ba = AcceleratorConfig::default()
            .with(Optimization::UpdateCombining)
            .with(Optimization::EdgeSorting);
        assert_ne!(ab, ba, "raw configs differ by insertion order");
        let sa = base().config(ab).build().unwrap();
        let sb = base().config(ba).build().unwrap();
        assert_eq!(sa, sb, "built specs canonicalize the optimization list");
    }

    #[test]
    fn custom_workload_identity_is_content_based() {
        let a = Workload::custom("mine", synthetic::erdos_renyi(64, 256, 1));
        let b = Workload::custom("mine", synthetic::erdos_renyi(64, 256, 1));
        let c = Workload::custom("mine", synthetic::erdos_renyi(64, 256, 2));
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_ne!(a, Workload::Named(DatasetId::Sd));
    }

    #[test]
    fn custom_unweighted_rejected_for_weighted_problems() {
        let g = synthetic::erdos_renyi(64, 256, 3);
        let err = base()
            .custom_graph("mine", g.clone())
            .problem(ProblemKind::Sssp)
            .build()
            .unwrap_err();
        assert!(matches!(err, SpecError::CustomGraphUnweighted { .. }));
        let ok = base()
            .custom_graph("mine", g.with_random_weights(9, 8.0))
            .problem(ProblemKind::Sssp)
            .build();
        assert!(ok.is_ok());
    }

    #[test]
    fn patterns_opt_in_attaches_summary() {
        let plain = base().build().unwrap();
        assert!(!plain.patterns_enabled());
        assert!(plain.run().patterns.is_none());
        let spec = base().patterns(true).build().unwrap();
        assert!(spec.patterns_enabled());
        let r = spec.run();
        let s = r.patterns.as_ref().unwrap();
        // The analyzer sees every enqueued request; the controller
        // services each exactly once.
        assert_eq!(s.total_requests(), r.dram.requests());
        // The flag is part of the spec's identity (memoization key).
        assert_ne!(plain, spec);
    }

    #[test]
    fn program_key_ignores_mem_tech_and_patterns() {
        let a = base().mem(MemTech::Ddr4).build().unwrap();
        let b = base().mem(MemTech::Hbm).build().unwrap();
        assert_ne!(a, b, "specs differ");
        assert_eq!(a.program_key(), b.program_key(), "programs shared");
        let c = base().patterns(true).build().unwrap();
        assert_eq!(a.program_key(), c.program_key());
        // The problem *kind* does not split the key (compilation only
        // reads the weighted-variant graph)...
        let pr = base().problem(ProblemKind::PageRank).build().unwrap();
        assert_eq!(a.program_key(), pr.program_key());
        // ...but weightedness does (12 B vs 8 B edge layouts).
        let sssp = base().problem(ProblemKind::Sssp).build().unwrap();
        assert_ne!(a.program_key(), sssp.program_key());
        // The channel count splits the key: multi-channel partitioning
        // (and the normalized config) depend on it.
        let d = base().channels(2).build().unwrap();
        assert_ne!(a.program_key(), d.program_key());
    }

    #[test]
    fn run_with_program_matches_fresh_compile() {
        let spec = base().patterns(true).build().unwrap();
        let program = spec.compile_program();
        let cached = spec.run_with_program(&program);
        let fresh = spec.run();
        assert_eq!(cached, fresh);
        // Replays of one program are independent.
        assert_eq!(spec.run_with_program(&program), cached);
    }

    #[test]
    fn zero_channels_rejected() {
        assert_eq!(base().channels(0).build().unwrap_err(), SpecError::ZeroChannels);
    }

    #[test]
    fn onchip_is_part_of_the_memo_key_but_not_the_program_key() {
        use crate::onchip::OnChipConfig;
        let plain = base().build().unwrap();
        assert!(plain.onchip().is_none());
        let cached = base().onchip(OnChipConfig::vertex_cache(4096)).build().unwrap();
        assert!(cached.onchip().is_some());
        // Buffered and unbuffered runs must never alias in the memo...
        assert_ne!(plain, cached);
        // ...while the compiled program is shared (the buffer affects
        // execution only, never compilation).
        assert_eq!(plain.program_key(), cached.program_key());
        // Different budgets are distinct memo keys too.
        let bigger = base().onchip(OnChipConfig::vertex_cache(8192)).build().unwrap();
        assert_ne!(cached, bigger);
    }

    #[test]
    fn budget_and_faults_join_the_memo_key_but_not_the_program_key() {
        use crate::dram::FaultPlan;
        use crate::robust::RunBudget;
        let plain = base().build().unwrap();
        assert!(plain.budget().is_none());
        assert!(plain.faults().is_none());
        let budgeted = base()
            .budget(RunBudget::default().with_max_cycles(1_000_000))
            .build()
            .unwrap();
        let faulted = base().faults(FaultPlan::mixed(7)).build().unwrap();
        // Guarded, faulted and plain runs must never alias in the memo...
        assert_ne!(plain, budgeted);
        assert_ne!(plain, faulted);
        assert_ne!(budgeted, faulted);
        assert_ne!(faulted, base().faults(FaultPlan::mixed(8)).build().unwrap());
        // ...while the compiled program is shared (both affect
        // execution only, never compilation).
        assert_eq!(plain.program_key(), budgeted.program_key());
        assert_eq!(plain.program_key(), faulted.program_key());
        // The advisor-resolution path preserves both.
        let auto = base()
            .accelerator(AcceleratorKind::AccuGraph)
            .budget(RunBudget::default().with_max_cycles(1_000_000))
            .faults(FaultPlan::mixed(7))
            .auto_partition(true)
            .build()
            .unwrap();
        assert!(auto.budget().is_some());
        assert_eq!(auto.faults(), Some(&FaultPlan::mixed(7)));
        // Post-build hooks round-trip.
        let rearmed = plain.clone().with_faults(Some(FaultPlan::mixed(7)));
        assert_eq!(rearmed, base().faults(FaultPlan::mixed(7)).build().unwrap());
        assert_eq!(rearmed.with_faults(None), plain);
    }

    #[test]
    fn verify_joins_the_memo_key_but_not_the_program_key() {
        let plain = base().build().unwrap();
        assert!(!plain.verify_enabled());
        let verified = base().verify(true).build().unwrap();
        assert!(verified.verify_enabled());
        // Verified and unverified runs must never alias in the memo...
        assert_ne!(plain, verified);
        // ...while the compiled program is shared (verification
        // proves properties of the artifact, it never changes it).
        assert_eq!(plain.program_key(), verified.program_key());
        // The advisor-resolution path preserves the flag.
        let auto = base()
            .accelerator(AcceleratorKind::AccuGraph)
            .verify(true)
            .auto_partition(true)
            .build()
            .unwrap();
        assert!(auto.verify_enabled());
        // Every builder-valid program passes its own verification —
        // release-mode semantics of the flag, debug tripwire aside.
        let rep = verified.verify_program();
        assert!(rep.is_ok(), "{rep}: {:?}", rep.violations);
        assert!(rep.phases > 0 && rep.streams > 0);
    }

    #[test]
    fn run_checked_ok_is_bit_identical_to_run() {
        let spec = base().build().unwrap();
        assert_eq!(spec.run_checked().unwrap(), spec.run());
    }

    #[test]
    fn run_checked_surfaces_budget_violations_as_typed_errors() {
        use crate::robust::{BudgetResource, RunBudget, SimError};
        let spec = base()
            .budget(RunBudget::default().with_max_requests(5))
            .build()
            .unwrap();
        match spec.run_checked() {
            Err(SimError::BudgetExceeded {
                resource,
                limit,
                observed,
            }) => {
                assert_eq!(resource, BudgetResource::Requests);
                assert_eq!(limit, 5);
                assert!(observed > 5);
            }
            other => panic!("expected a budget violation, got {other:?}"),
        }
        // An unbounded budget is never enforced.
        let free = base().budget(RunBudget::default()).build().unwrap();
        assert!(free.run_checked().is_ok());
    }

    #[test]
    fn faulted_runs_move_cycles_never_results() {
        use crate::dram::FaultPlan;
        let clean = base().build().unwrap().run();
        let spec = base().faults(FaultPlan::mixed(0xF0)).build().unwrap();
        let faulted = spec.run();
        assert!(faulted.dram.faults_injected > 0, "plan never fired");
        assert!(faulted.dram.fault_delay_cycles > 0);
        assert_eq!(clean.dram.faults_injected, 0);
        // Results are invariant: same algorithm metrics, same request
        // counts — only timing moves, and only upward.
        assert_eq!(clean.metrics, faulted.metrics);
        assert_eq!(clean.dram.requests(), faulted.dram.requests());
        assert!(faulted.cycles >= clean.cycles);
        // Determinism: the same plan reproduces the report bit for bit.
        assert_eq!(spec.run(), faulted);
    }

    #[test]
    fn onchip_default_resolves_per_accelerator() {
        use crate::onchip::OnChipConfig;
        let accu = base()
            .accelerator(AcceleratorKind::AccuGraph)
            .onchip_default()
            .build()
            .unwrap();
        let expected =
            OnChipConfig::default_for(AcceleratorKind::AccuGraph, accu.config()).unwrap();
        assert_eq!(accu.onchip(), Some(&expected));
        // Streaming designs resolve to no buffer.
        let hit = base().onchip_default().build().unwrap();
        assert!(hit.onchip().is_none());
        // An explicit buffer wins over the default request.
        let explicit = base()
            .accelerator(AcceleratorKind::AccuGraph)
            .onchip_default()
            .onchip(OnChipConfig::vertex_cache(64))
            .build()
            .unwrap();
        assert_eq!(explicit.onchip().unwrap().capacity_bytes(), 64);
    }

    #[test]
    fn invalid_onchip_rejected_at_build() {
        use crate::onchip::{Geometry, OnChipConfig};
        use crate::trace::Region;
        let bad = OnChipConfig::new(4096, Geometry::SetAssociative { ways: 0 }, [Region::Vertices]);
        let err = base().onchip(bad.clone()).build().unwrap_err();
        assert!(matches!(err, SpecError::OnChipInvalid(_)));
        assert!(err.to_string().contains("on-chip"));
        // ...and via the post-build hook too.
        let spec = base().build().unwrap();
        assert!(spec.with_onchip(Some(bad)).is_err());
    }

    #[test]
    fn scratch_reuse_is_bit_identical_across_spec_shapes() {
        // One RunScratch reconfigured across accelerators, memory
        // technologies, channel counts and channel modes must produce
        // exactly the fresh-construction reports.
        let mut scratch = RunScratch::new();
        let specs = [
            base().build().unwrap(),
            base().mem(MemTech::Hbm).channels(4).build().unwrap(),
            base()
                .accelerator(AcceleratorKind::AccuGraph)
                .graph(DatasetId::Sd)
                .build()
                .unwrap(),
            base().mem(MemTech::Ddr3).build().unwrap(),
        ];
        for spec in &specs {
            let program = spec.compile_program();
            let fresh = spec.run_with_program(&program);
            let reused = spec.run_with_program_scratch(&program, &mut scratch);
            assert_eq!(fresh, reused, "scratch diverged for {}", spec.label());
            // Replay on the warm scratch too.
            assert_eq!(spec.run_with_program_scratch(&program, &mut scratch), fresh);
        }
    }

    #[test]
    fn custom_workload_runs_like_named() {
        let g = synthetic::erdos_renyi(200, 900, 7);
        let spec = base()
            .accelerator(AcceleratorKind::AccuGraph)
            .custom_graph("er200", g)
            .build()
            .unwrap();
        let r = spec.run();
        assert!(r.cycles > 0);
        assert!(r.metrics.iterations > 0);
    }

    #[test]
    fn spec_hash_distinguishes_window_and_flag() {
        let s1 = base()
            .config(AcceleratorConfig::default().with_window(32))
            .build()
            .unwrap();
        let s2 = base()
            .config(AcceleratorConfig::default().with_window(1))
            .build()
            .unwrap();
        assert_ne!(s1, s2);
        let s3 = base()
            .config(AcceleratorConfig::default().with_experimental_multichannel(true))
            .build()
            .unwrap();
        let s4 = base().config(AcceleratorConfig::default()).build().unwrap();
        assert_ne!(s3, s4);
    }

    #[test]
    fn auto_flags_resolve_into_plain_spec_values() {
        let g = synthetic::erdos_renyi(1_500, 6_000, 5);
        let auto = base()
            .accelerator(AcceleratorKind::AccuGraph)
            .custom_graph("er1500", g.clone())
            .auto_partition(true)
            .auto_onchip(true)
            .build()
            .unwrap();
        // The balanced capacity for 1,500 vertices is 1,500 (one
        // partition), not the 16,384 default.
        assert_eq!(auto.config().bram_values, 1_500);
        // No advisor trace survives in the spec: the same choices made
        // by hand produce a bit-identical value (one memo entry).
        let mut cfg = AcceleratorConfig::default();
        cfg.bram_values = 1_500;
        let manual = base()
            .accelerator(AcceleratorKind::AccuGraph)
            .custom_graph("er1500", g)
            .config(cfg)
            .onchip(auto.onchip().cloned())
            .build()
            .unwrap();
        assert_eq!(auto, manual);
        assert_eq!(auto.program_key(), manual.program_key());
        // Directly running an auto-built spec never claims advisor
        // provenance on the report.
        assert!(auto.run().advisor.is_none());
    }
}
