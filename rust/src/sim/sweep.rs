//! Parallel sweep execution over [`SimSpec`]s.
//!
//! [`Session`] is the shared, lock-striped result cache that replaces
//! the old serial `coordinator::Runner`: results are memoized per
//! [`SimSpec`] (derived `Hash`/`Eq` keys — no hand-rolled strings),
//! and [`Session::run_all`] fans a batch of specs out across worker
//! threads. The simulator is deterministic, so parallel execution
//! yields reports identical to the serial path.
//!
//! Two layers of caching, both compute-once (an in-progress gate per
//! key makes racing workers wait instead of duplicating work):
//!
//! * **Report memo** — every distinct spec simulates at most once per
//!   session, even when a parallel batch contains duplicates.
//! * **Program cache** — compiled [`PhaseProgram`]s keyed on the
//!   memory-independent sub-key of a spec
//!   ([`SimSpec::program_key`]), so a `mem_techs × channels` sweep
//!   compiles each workload once per channel count and shares the
//!   program across memory technologies and worker threads by `Arc`.
//!
//! An optional third layer, [`Session::with_disk_cache`], puts a
//! durable [`crate::persist::CacheDir`] under the report memo: misses
//! consult the disk before simulating and computed results (reports
//! *and* typed failures) are atomically persisted, so warm results
//! survive restarts and are shared across processes. Corrupt or
//! truncated entries read as misses and are recomputed and rewritten.
//!
//! [`Session::stats`] reports both layers' traffic (programs
//! compiled/reused, runs executed/memoized/duplicate-waited); the CLI
//! surfaces it behind `graphmem sweep --stats`.
//!
//! **Panic isolation.** Every simulation executes behind
//! [`crate::robust::catch_sim`]: a stalled phase engine, an exceeded
//! [`crate::robust::RunBudget`] or a stray panic becomes a typed
//! [`crate::robust::SimError`] memoized like any result (the simulator
//! is deterministic, so a failure is as cacheable as a report). The
//! `try_run*` entry points surface the `Result`; the legacy infallible
//! entry points panic with the failure's display form. One failing
//! spec never takes down a batch — [`Session::run_trials`] /
//! [`Sweep::run_outcomes`] pair every spec with its
//! [`SweepOutcome`], and all internal locks recover from poisoning
//! (a worker that died mid-publish cannot wedge the session).
//!
//! [`Sweep`] declares experiment axes (accelerators × workloads ×
//! problems × memory technologies × channel counts × configurations ×
//! on-chip buffers), takes their cartesian product and executes it
//! through a session:
//!
//! ```
//! use graphmem::accel::AcceleratorKind;
//! use graphmem::algo::problem::ProblemKind;
//! use graphmem::dram::MemTech;
//! use graphmem::graph::DatasetId;
//! use graphmem::sim::Sweep;
//!
//! let specs = Sweep::new()
//!     .accelerators(AcceleratorKind::all())
//!     .graphs([DatasetId::Sd])
//!     .problems([ProblemKind::Bfs])
//!     .mem_techs([MemTech::Ddr4, MemTech::Hbm])
//!     .specs()
//!     .unwrap();
//! assert_eq!(specs.len(), 10);
//! // `.run()` / `.run_with(&session)` executes the product.
//! ```

use super::metrics::{AdvisorChoices, SimReport};
use super::spec::{ProgramKey, RunScratch, SimSpec, SpecError, Workload};
use crate::accel::{AcceleratorConfig, AcceleratorKind, PhaseProgram};
use crate::advisor::{Advisor, Recommendation};
use crate::algo::problem::ProblemKind;
use crate::dram::MemTech;
use crate::graph::datasets::DatasetId;
use crate::onchip::OnChipConfig;
use crate::persist::CacheDir;
use crate::robust::SimError;
use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};

/// Lock a mutex, recovering the data from a poisoned state: the
/// session's values are published atomically (a slot is either `None`
/// or a complete value), so a thread that panicked while holding a
/// lock cannot have left partial state behind. Without this, one
/// panicking worker would wedge every later lock on the same shard.
fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Number of independent cache shards; keeps lock contention low when
/// many worker threads publish results concurrently.
const CACHE_SHARDS: usize = 16;

/// How a [`OnceMap::get_or_compute`] call was satisfied.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Fetch {
    /// This call ran the computation.
    Computed,
    /// The value was already cached.
    Hit,
    /// Another thread was computing it; this call waited for it.
    Waited,
}

enum GateState<V> {
    Pending,
    Done(V),
    /// The computing thread panicked; waiters retry (and one of them
    /// becomes the new computer).
    Cancelled,
}

/// One in-progress computation: waiters block on the condvar until
/// the computing thread publishes (or cancels).
struct Gate<V> {
    state: Mutex<GateState<V>>,
    cv: Condvar,
}

struct OnceShard<K, V> {
    done: HashMap<K, V>,
    running: HashMap<K, Arc<Gate<V>>>,
}

/// Lock-striped compute-once map: for any key, the computation runs
/// exactly once per map, concurrent callers for the same key wait on
/// its gate instead of duplicating the work.
struct OnceMap<K, V> {
    shards: Vec<Mutex<OnceShard<K, V>>>,
}

impl<K: Hash + Eq + Clone, V: Clone> OnceMap<K, V> {
    fn new() -> OnceMap<K, V> {
        OnceMap {
            shards: (0..CACHE_SHARDS)
                .map(|_| {
                    Mutex::new(OnceShard {
                        done: HashMap::new(),
                        running: HashMap::new(),
                    })
                })
                .collect(),
        }
    }

    fn shard(&self, key: &K) -> &Mutex<OnceShard<K, V>> {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) % CACHE_SHARDS]
    }

    /// Cached values across all shards.
    fn len(&self) -> usize {
        self.shards.iter().map(|s| lock_unpoisoned(s).done.len()).sum()
    }

    /// Non-blocking lookup: the cached value if the computation has
    /// completed, `None` otherwise (including while it is in flight).
    fn peek(&self, key: &K) -> Option<V> {
        lock_unpoisoned(self.shard(key)).done.get(key).cloned()
    }

    fn get_or_compute(&self, key: &K, mut f: impl FnMut() -> V) -> (V, Fetch) {
        loop {
            enum Role<V> {
                Compute(Arc<Gate<V>>),
                Wait(Arc<Gate<V>>),
            }
            let role = {
                let mut shard = lock_unpoisoned(self.shard(key));
                if let Some(v) = shard.done.get(key) {
                    return (v.clone(), Fetch::Hit);
                }
                match shard.running.get(key) {
                    Some(gate) => Role::Wait(Arc::clone(gate)),
                    None => {
                        let gate = Arc::new(Gate {
                            state: Mutex::new(GateState::Pending),
                            cv: Condvar::new(),
                        });
                        shard.running.insert(key.clone(), Arc::clone(&gate));
                        Role::Compute(gate)
                    }
                }
            };
            match role {
                Role::Compute(gate) => {
                    // Compute outside every lock. If `f` panics, the
                    // guard cancels the gate so waiters retry rather
                    // than hang.
                    struct Cancel<'a, K: Hash + Eq + Clone, V: Clone> {
                        map: &'a OnceMap<K, V>,
                        key: &'a K,
                        gate: &'a Arc<Gate<V>>,
                        armed: bool,
                    }
                    impl<K: Hash + Eq + Clone, V: Clone> Drop for Cancel<'_, K, V> {
                        fn drop(&mut self) {
                            if !self.armed {
                                return;
                            }
                            let mut shard = lock_unpoisoned(self.map.shard(self.key));
                            shard.running.remove(self.key);
                            drop(shard);
                            *lock_unpoisoned(&self.gate.state) = GateState::Cancelled;
                            self.gate.cv.notify_all();
                        }
                    }
                    let value = {
                        let mut guard = Cancel {
                            map: self,
                            key,
                            gate: &gate,
                            armed: true,
                        };
                        let v = f();
                        guard.armed = false;
                        v
                    };
                    {
                        let mut shard = lock_unpoisoned(self.shard(key));
                        shard.done.insert(key.clone(), value.clone());
                        shard.running.remove(key);
                    }
                    *lock_unpoisoned(&gate.state) = GateState::Done(value.clone());
                    gate.cv.notify_all();
                    return (value, Fetch::Computed);
                }
                Role::Wait(gate) => {
                    let mut st = lock_unpoisoned(&gate.state);
                    loop {
                        match &*st {
                            GateState::Done(v) => return (v.clone(), Fetch::Waited),
                            GateState::Cancelled => break,
                            GateState::Pending => {}
                        }
                        st = gate.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
                    }
                    // Cancelled: fall through and retry from the top.
                }
            }
        }
    }
}

/// A point-in-time snapshot of a [`Session`]'s cache traffic (see
/// [`Session::stats`]). The accounting identity holds at any quiet
/// point: every [`Session::run`] call is exactly one of
/// `sim_runs` (executed), `memo_hits` (served from cache) or
/// `duplicate_waits` (waited on a concurrent duplicate).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SessionStats {
    /// Distinct simulations executed (== [`Session::cached_runs`]).
    pub sim_runs: usize,
    /// Runs served straight from the report memo.
    pub memo_hits: usize,
    /// Runs that waited for a concurrent duplicate to finish instead
    /// of simulating the same spec twice.
    pub duplicate_waits: usize,
    /// Phase programs compiled (distinct [`SimSpec::program_key`]s).
    pub programs_compiled: usize,
    /// Program-cache hits (incl. waits on a concurrent compile).
    pub programs_reused: usize,
    /// Results loaded from the layered [`CacheDir`] instead of being
    /// simulated ([`Session::with_disk_cache`]). Each disk hit still
    /// lands in the in-memory memo, so `sim_runs` counts it; the
    /// number of simulations actually *executed* this session is
    /// `sim_runs - disk_hits`, and a fully warm run satisfies
    /// `sim_runs == disk_hits`.
    pub disk_hits: usize,
    /// Results durably written to the layered [`CacheDir`].
    pub disk_writes: usize,
}

/// Shared memoizing simulation session: run any number of specs
/// (serially or in parallel) and every distinct [`SimSpec`] simulates
/// at most once per session — racing duplicates wait on an
/// in-progress gate instead of simulating twice. A second cache layer
/// holds compiled [`PhaseProgram`]s keyed on
/// [`SimSpec::program_key`], shared across memory technologies and
/// worker threads.
pub struct Session {
    reports: OnceMap<SimSpec, Result<SimReport, SimError>>,
    programs: OnceMap<ProgramKey, Arc<PhaseProgram>>,
    /// Worker threads used by [`Session::run_all`]; `None` = derive
    /// from the machine.
    threads: Option<usize>,
    /// Durable third cache layer ([`Session::with_disk_cache`]):
    /// consulted before simulating, written after. Misses (including
    /// corrupt or foreign entries) fall through to a normal compute.
    disk: Option<Arc<CacheDir>>,
    memo_hits: AtomicUsize,
    duplicate_waits: AtomicUsize,
    programs_compiled: AtomicUsize,
    programs_reused: AtomicUsize,
    disk_hits: AtomicUsize,
    disk_writes: AtomicUsize,
}

impl Session {
    pub fn new() -> Session {
        Session {
            reports: OnceMap::new(),
            programs: OnceMap::new(),
            threads: None,
            disk: None,
            memo_hits: AtomicUsize::new(0),
            duplicate_waits: AtomicUsize::new(0),
            programs_compiled: AtomicUsize::new(0),
            programs_reused: AtomicUsize::new(0),
            disk_hits: AtomicUsize::new(0),
            disk_writes: AtomicUsize::new(0),
        }
    }

    /// Fix the worker-thread count for batched runs (1 = serial).
    pub fn with_threads(mut self, threads: usize) -> Session {
        self.threads = Some(threads.max(1));
        self
    }

    /// Layer a durable [`CacheDir`] under the in-memory memo: every
    /// miss first consults the disk (a valid entry is adopted without
    /// simulating — [`SessionStats::disk_hits`]), and every computed
    /// result (report *or* typed failure) is atomically persisted so
    /// it survives restarts and is shared across processes. Disk I/O
    /// happens at most once per distinct spec per session; the
    /// compute-once gate covers the disk probe too.
    pub fn with_disk_cache(mut self, dir: Arc<CacheDir>) -> Session {
        self.disk = Some(dir);
        self
    }

    /// The layered disk cache, if one was attached.
    pub fn disk_cache(&self) -> Option<&Arc<CacheDir>> {
        self.disk.as_ref()
    }

    /// The compiled program for `spec`, from the session's program
    /// cache (compiling on first use). Also the pre-warm hook: call
    /// this ahead of time and subsequent runs of any spec sharing the
    /// [`SimSpec::program_key`] skip compilation.
    pub fn program_for(&self, spec: &SimSpec) -> Arc<PhaseProgram> {
        let key = spec.program_key();
        let (program, how) = self.programs.get_or_compute(&key, || spec.compile_program());
        match how {
            Fetch::Computed => self.programs_compiled.fetch_add(1, Ordering::Relaxed),
            Fetch::Hit | Fetch::Waited => self.programs_reused.fetch_add(1, Ordering::Relaxed),
        };
        program
    }

    /// Run one spec (or fetch its memoized report). Concurrent calls
    /// with the same spec simulate once: later callers wait on the
    /// first one's gate ([`SessionStats::duplicate_waits`]).
    ///
    /// Panics if the simulation fails (stall, exceeded budget, stray
    /// panic) — use [`Session::try_run`] for the typed `Result`.
    pub fn run(&self, spec: &SimSpec) -> SimReport {
        self.run_scratch(spec, &mut RunScratch::new())
    }

    /// [`Session::run`] with every failure returned as a typed
    /// [`SimError`] instead of unwinding. Failures are memoized like
    /// reports: the simulator is deterministic, so a spec that stalled
    /// once stalls every time — re-asking costs a cache hit, not a
    /// re-simulation.
    pub fn try_run(&self, spec: &SimSpec) -> Result<SimReport, SimError> {
        self.try_run_scratch(spec, &mut RunScratch::new())
    }

    /// [`Session::run`] against a caller-owned [`RunScratch`]: a run
    /// that actually simulates resets the scratch's `MemorySystem` in
    /// place instead of constructing one — [`Session::run_batch`]
    /// keeps one scratch per worker thread, eliminating the last
    /// per-run allocation on the sweep hot path. Bit-identical to
    /// [`Session::run`].
    pub fn run_scratch(&self, spec: &SimSpec, scratch: &mut RunScratch) -> SimReport {
        self.try_run_scratch(spec, scratch)
            .unwrap_or_else(|err| panic!("simulation of {} failed: {err}", spec.label()))
    }

    /// [`Session::try_run`] against a caller-owned [`RunScratch`].
    /// The simulation body runs behind [`crate::robust::catch_sim`],
    /// so a failing spec leaves the session (and the scratch) usable.
    pub fn try_run_scratch(
        &self,
        spec: &SimSpec,
        scratch: &mut RunScratch,
    ) -> Result<SimReport, SimError> {
        let (report, how) = self.reports.get_or_compute(spec, || {
            if let Some(disk) = &self.disk {
                if let Some(stored) = disk.load(spec) {
                    self.disk_hits.fetch_add(1, Ordering::Relaxed);
                    return stored;
                }
            }
            let result = crate::robust::catch_sim(|| {
                let program = self.program_for(spec);
                spec.run_with_program_scratch(&program, scratch)
            });
            if let Some(disk) = &self.disk {
                // A failed store leaves the cache cold for this key;
                // the in-memory result is still correct.
                if disk.store(spec, &result).is_ok() {
                    self.disk_writes.fetch_add(1, Ordering::Relaxed);
                }
            }
            result
        });
        match how {
            Fetch::Computed => {}
            Fetch::Hit => {
                self.memo_hits.fetch_add(1, Ordering::Relaxed);
            }
            Fetch::Waited => {
                self.duplicate_waits.fetch_add(1, Ordering::Relaxed);
            }
        }
        report
    }

    /// Run a batch of specs across worker threads; the result vector
    /// is index-aligned with `specs`. Reports are identical to calling
    /// [`Session::run`] serially (the simulator is deterministic).
    /// Panics on the first failed spec — see [`Session::try_run_all`].
    pub fn run_all(&self, specs: &[SimSpec]) -> Vec<SimReport> {
        self.run_batch(specs, self.threads.unwrap_or_else(default_threads))
    }

    /// [`Session::run_all`] with an explicit worker-thread count.
    pub fn run_batch(&self, specs: &[SimSpec], threads: usize) -> Vec<SimReport> {
        self.try_run_batch(specs, threads)
            .into_iter()
            .zip(specs)
            .map(|(res, spec)| {
                res.unwrap_or_else(|err| {
                    panic!("simulation of {} failed: {err}", spec.label())
                })
            })
            .collect()
    }

    /// Fallible batch run: every spec yields its own
    /// `Result<SimReport, SimError>` — one stalling or over-budget
    /// spec never takes down the rest of the batch. Index-aligned
    /// with `specs`.
    pub fn try_run_all(&self, specs: &[SimSpec]) -> Vec<Result<SimReport, SimError>> {
        self.try_run_batch(specs, self.threads.unwrap_or_else(default_threads))
    }

    /// [`Session::try_run_all`] with an explicit worker-thread count.
    pub fn try_run_batch(
        &self,
        specs: &[SimSpec],
        threads: usize,
    ) -> Vec<Result<SimReport, SimError>> {
        let threads = threads.min(specs.len().max(1));
        if threads <= 1 || specs.len() <= 1 {
            let mut scratch = RunScratch::new();
            return specs
                .iter()
                .map(|s| self.try_run_scratch(s, &mut scratch))
                .collect();
        }
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<Result<SimReport, SimError>>>> =
            specs.iter().map(|_| Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(|| {
                    // One reusable memory system per worker: every
                    // simulation this worker executes resets it in
                    // place instead of allocating a fresh one.
                    let mut scratch = RunScratch::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        let Some(spec) = specs.get(i) else { break };
                        let result = self.try_run_scratch(spec, &mut scratch);
                        *lock_unpoisoned(&slots[i]) = Some(result);
                    }
                });
            }
        });
        slots
            .into_iter()
            .map(|slot| {
                slot.into_inner()
                    .unwrap_or_else(PoisonError::into_inner)
                    .expect("every slot filled")
            })
            .collect()
    }

    /// Run an explicit list of specs, pairing each with its
    /// [`SweepOutcome`] — the keep-going sweep substrate: failures are
    /// isolated per spec and the rest of the batch always completes.
    pub fn run_trials(&self, specs: &[SimSpec]) -> Vec<SweepTrial> {
        self.try_run_all(specs)
            .into_iter()
            .zip(specs)
            .map(|(res, spec)| SweepTrial {
                spec: spec.clone(),
                outcome: match res {
                    Ok(report) => SweepOutcome::Ok(report),
                    Err(err) => SweepOutcome::Failed(err),
                },
            })
            .collect()
    }

    /// Number of distinct simulations materialized so far.
    pub fn cached_runs(&self) -> usize {
        self.reports.len()
    }

    /// Non-blocking memo lookup: the memoized result if `spec` has
    /// already materialized in *this* session, without touching disk
    /// and without triggering a computation. The serve daemon uses it
    /// to report `cache_hit` truthfully before running a request.
    pub fn peek(&self, spec: &SimSpec) -> Option<Result<SimReport, SimError>> {
        self.reports.peek(spec)
    }

    /// Snapshot of the session's cache traffic.
    pub fn stats(&self) -> SessionStats {
        SessionStats {
            sim_runs: self.reports.len(),
            memo_hits: self.memo_hits.load(Ordering::Relaxed),
            duplicate_waits: self.duplicate_waits.load(Ordering::Relaxed),
            programs_compiled: self.programs_compiled.load(Ordering::Relaxed),
            programs_reused: self.programs_reused.load(Ordering::Relaxed),
            disk_hits: self.disk_hits.load(Ordering::Relaxed),
            disk_writes: self.disk_writes.load(Ordering::Relaxed),
        }
    }
}

impl Default for Session {
    fn default() -> Session {
        Session::new()
    }
}

/// Worker threads when none are requested: the machine's parallelism,
/// capped to keep memory in check on very wide hosts.
fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(8)
}

/// One executed sweep point.
#[derive(Clone, Debug)]
pub struct SweepRun {
    pub spec: SimSpec,
    pub report: SimReport,
}

/// How one sweep point ended: a report, or a typed failure. The
/// keep-going sweep mode ([`Sweep::run_outcomes`], `graphmem sweep
/// --keep-going`) collects these instead of aborting on the first
/// failed spec.
#[derive(Clone, Debug)]
pub enum SweepOutcome {
    Ok(SimReport),
    Failed(SimError),
}

impl SweepOutcome {
    pub fn is_ok(&self) -> bool {
        matches!(self, SweepOutcome::Ok(_))
    }

    /// The report, when the point succeeded.
    pub fn report(&self) -> Option<&SimReport> {
        match self {
            SweepOutcome::Ok(r) => Some(r),
            SweepOutcome::Failed(_) => None,
        }
    }

    /// The failure, when the point failed.
    pub fn error(&self) -> Option<&SimError> {
        match self {
            SweepOutcome::Ok(_) => None,
            SweepOutcome::Failed(e) => Some(e),
        }
    }

    pub fn into_result(self) -> Result<SimReport, SimError> {
        match self {
            SweepOutcome::Ok(r) => Ok(r),
            SweepOutcome::Failed(e) => Err(e),
        }
    }
}

/// One attempted sweep point: the spec plus however it ended.
#[derive(Clone, Debug)]
pub struct SweepTrial {
    pub spec: SimSpec,
    pub outcome: SweepOutcome,
}

/// Declarative cartesian sweep over simulation axes.
///
/// Axis order in the product (outer to inner): accelerators,
/// workloads, problems, memory technologies, channels, configurations
/// — deterministic, so sweep output order is stable.
#[derive(Clone, Debug)]
pub struct Sweep {
    accelerators: Vec<AcceleratorKind>,
    workloads: Vec<Workload>,
    problems: Vec<ProblemKind>,
    mem_techs: Vec<MemTech>,
    channels: Vec<usize>,
    configs: Vec<AcceleratorConfig>,
    onchips: Vec<Option<OnChipConfig>>,
    skip_unsupported: bool,
    threads: Option<usize>,
    patterns: bool,
}

impl Sweep {
    /// Empty accelerator/workload/problem axes (must be filled);
    /// memory defaults to single-channel DDR4 with the default
    /// configuration.
    pub fn new() -> Sweep {
        Sweep {
            accelerators: Vec::new(),
            workloads: Vec::new(),
            problems: Vec::new(),
            mem_techs: vec![MemTech::Ddr4],
            channels: vec![1],
            configs: vec![AcceleratorConfig::default()],
            onchips: vec![None],
            skip_unsupported: false,
            threads: None,
            patterns: false,
        }
    }

    pub fn accelerators(mut self, kinds: impl IntoIterator<Item = AcceleratorKind>) -> Self {
        self.accelerators = kinds.into_iter().collect();
        self
    }

    /// Named benchmark graphs.
    pub fn graphs(mut self, ids: impl IntoIterator<Item = DatasetId>) -> Self {
        self.workloads = ids.into_iter().map(Workload::Named).collect();
        self
    }

    /// Arbitrary workloads (named and/or custom).
    pub fn workloads(mut self, workloads: impl IntoIterator<Item = Workload>) -> Self {
        self.workloads = workloads.into_iter().collect();
        self
    }

    pub fn problems(mut self, problems: impl IntoIterator<Item = ProblemKind>) -> Self {
        self.problems = problems.into_iter().collect();
        self
    }

    pub fn mem_techs(mut self, techs: impl IntoIterator<Item = MemTech>) -> Self {
        self.mem_techs = techs.into_iter().collect();
        self
    }

    pub fn channels(mut self, channels: impl IntoIterator<Item = usize>) -> Self {
        self.channels = channels.into_iter().collect();
        self
    }

    pub fn configs(mut self, configs: impl IntoIterator<Item = AcceleratorConfig>) -> Self {
        self.configs = configs.into_iter().collect();
        self
    }

    /// On-chip buffer axis (the BRAM-size sweep the on-chip model
    /// unlocks): each entry is one buffer configuration, `None` being
    /// the streaming-only baseline. Defaults to `[None]`. All entries
    /// share compiled programs — the buffer is not part of
    /// [`SimSpec::program_key`].
    pub fn onchip_configs(
        mut self,
        configs: impl IntoIterator<Item = Option<OnChipConfig>>,
    ) -> Self {
        self.onchips = configs.into_iter().collect();
        self
    }

    /// Silently drop invalid combinations (e.g. weighted problems on
    /// AccuGraph in a product that also contains HitGraph) instead of
    /// failing the whole sweep.
    pub fn skip_unsupported(mut self) -> Self {
        self.skip_unsupported = true;
        self
    }

    /// Fix the worker-thread count (1 = serial).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = Some(threads.max(1));
        self
    }

    /// Collect an access-pattern summary for every point (see
    /// `SimSpecBuilder::patterns`): each run's
    /// [`SimReport::patterns`] is then populated, so a sweep can
    /// compare patterns across accelerators × memories without
    /// writing trace files.
    pub fn collect_patterns(mut self) -> Self {
        self.patterns = true;
        self
    }

    /// The validated cartesian product. With
    /// [`Sweep::skip_unsupported`], invalid points are filtered;
    /// otherwise the first invalid combination aborts with its
    /// [`SpecError`].
    pub fn specs(&self) -> Result<Vec<SimSpec>, SpecError> {
        if self.accelerators.is_empty() {
            return Err(SpecError::EmptyAxis("accelerators"));
        }
        if self.workloads.is_empty() {
            return Err(SpecError::EmptyAxis("workloads"));
        }
        if self.problems.is_empty() {
            return Err(SpecError::EmptyAxis("problems"));
        }
        if self.mem_techs.is_empty() {
            return Err(SpecError::EmptyAxis("mem_techs"));
        }
        if self.channels.is_empty() {
            return Err(SpecError::EmptyAxis("channels"));
        }
        if self.configs.is_empty() {
            return Err(SpecError::EmptyAxis("configs"));
        }
        if self.onchips.is_empty() {
            return Err(SpecError::EmptyAxis("onchip"));
        }
        let mut specs = Vec::new();
        for &kind in &self.accelerators {
            for workload in &self.workloads {
                for &problem in &self.problems {
                    for &mem in &self.mem_techs {
                        for &ch in &self.channels {
                            for cfg in &self.configs {
                                for onchip in &self.onchips {
                                    let built = SimSpec::builder()
                                        .accelerator(kind)
                                        .workload(workload.clone())
                                        .problem(problem)
                                        .mem(mem)
                                        .channels(ch)
                                        .config(cfg.clone())
                                        .patterns(self.patterns)
                                        .onchip(onchip.clone())
                                        .build();
                                    match built {
                                        Ok(spec) => specs.push(spec),
                                        Err(_) if self.skip_unsupported => {}
                                        Err(e) => return Err(e),
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        Ok(specs)
    }

    /// Execute against a fresh session.
    pub fn run(&self) -> Result<Vec<SweepRun>, SpecError> {
        self.run_with(&Session::new())
    }

    /// Execute against a shared session (reusing its memoized runs
    /// and compiled programs).
    pub fn run_with(&self, session: &Session) -> Result<Vec<SweepRun>, SpecError> {
        let specs = self.specs()?;
        let reports = match self.threads {
            Some(t) => session.run_batch(&specs, t),
            None => session.run_all(&specs),
        };
        Ok(specs
            .into_iter()
            .zip(reports)
            .map(|(spec, report)| SweepRun { spec, report })
            .collect())
    }

    /// Keep-going execution: every point yields a [`SweepTrial`] —
    /// failed points carry their typed [`SimError`] and never abort
    /// the rest of the product. The `Err` arm covers *declaration*
    /// errors only (an empty or invalid axis).
    pub fn run_outcomes(&self) -> Result<Vec<SweepTrial>, SpecError> {
        self.run_outcomes_with(&Session::new())
    }

    /// [`Sweep::run_outcomes`] against a shared session.
    pub fn run_outcomes_with(&self, session: &Session) -> Result<Vec<SweepTrial>, SpecError> {
        let specs = self.specs()?;
        let results = match self.threads {
            Some(t) => session.try_run_batch(&specs, t),
            None => session.try_run_all(&specs),
        };
        Ok(specs
            .into_iter()
            .zip(results)
            .map(|(spec, res)| SweepTrial {
                spec,
                outcome: match res {
                    Ok(report) => SweepOutcome::Ok(report),
                    Err(err) => SweepOutcome::Failed(err),
                },
            })
            .collect())
    }

    /// Score the advisor against this sweep: probe the sweep's *first*
    /// point (its base configuration), apply the recommended on-chip
    /// budget to it, then run the full sweep plus the advisor's pick
    /// through `session` and compare the pick against the sweep
    /// optimum (minimum cycles). The advisor pick's report is
    /// annotated with [`AdvisorChoices`]; the sweep's own reports are
    /// not.
    ///
    /// This is the measure→act quality gate: the
    /// `tests/advisor_validation.rs` suite requires the gap to stay
    /// within 10% on reuse-heavy workloads.
    pub fn validate_advisor(&self, session: &Session) -> Result<AdvisorValidation, SpecError> {
        let specs = self.specs()?;
        let base = specs
            .first()
            .cloned()
            .ok_or(SpecError::EmptyAxis("sweep product"))?;
        let recommendation = Advisor::new().recommend(&base)?;
        let advisor_spec = base.with_onchip(recommendation.onchip.config.clone())?;
        let reports = match self.threads {
            Some(t) => session.run_batch(&specs, t),
            None => session.run_all(&specs),
        };
        let advisor_raw = session.run(&advisor_spec);
        let (best_i, best_report) = reports
            .iter()
            .enumerate()
            .min_by_key(|(_, r)| r.cycles)
            .map(|(i, r)| (i, r.clone()))
            .expect("specs() returned a non-empty product");
        let gap = advisor_raw.cycles as f64 / best_report.cycles as f64 - 1.0;
        let advisor_report = recommendation.annotate(
            &advisor_raw,
            AdvisorChoices {
                partition: false,
                placement: false,
                onchip: true,
            },
        );
        Ok(AdvisorValidation {
            recommendation,
            advisor_spec,
            advisor_report,
            best_spec: specs[best_i].clone(),
            best_report,
            sweep_points: specs.len(),
            gap,
        })
    }
}

/// Result of [`Sweep::validate_advisor`]: the advisor's pick scored
/// against the sweep optimum.
#[derive(Clone, Debug)]
pub struct AdvisorValidation {
    pub recommendation: Recommendation,
    /// The sweep's base point with the recommended on-chip budget
    /// applied.
    pub advisor_spec: SimSpec,
    /// The advisor pick's report, annotated with [`AdvisorChoices`].
    pub advisor_report: SimReport,
    /// The sweep point with the fewest cycles.
    pub best_spec: SimSpec,
    pub best_report: SimReport,
    /// Number of sweep points scored against.
    pub sweep_points: usize,
    /// `advisor_cycles / best_cycles - 1.0`. May be negative: the
    /// advisor can propose a budget absent from the sweep axis and
    /// beat every listed point.
    pub gap: f64,
}

impl Default for Sweep {
    fn default() -> Sweep {
        Sweep::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_sweep() -> Sweep {
        Sweep::new()
            .accelerators([AcceleratorKind::AccuGraph, AcceleratorKind::HitGraph])
            .graphs([DatasetId::Sd])
            .problems([ProblemKind::Bfs])
    }

    #[test]
    fn product_order_is_deterministic() {
        let specs = quick_sweep()
            .mem_techs([MemTech::Ddr4, MemTech::Hbm])
            .specs()
            .unwrap();
        assert_eq!(specs.len(), 4);
        assert_eq!(specs[0].accelerator(), AcceleratorKind::AccuGraph);
        assert_eq!(specs[0].mem(), MemTech::Ddr4);
        assert_eq!(specs[1].mem(), MemTech::Hbm);
        assert_eq!(specs[2].accelerator(), AcceleratorKind::HitGraph);
    }

    #[test]
    fn empty_axis_is_an_error() {
        let err = Sweep::new().specs().unwrap_err();
        assert_eq!(err, SpecError::EmptyAxis("accelerators"));
        let err = quick_sweep().channels([]).specs().unwrap_err();
        assert_eq!(err, SpecError::EmptyAxis("channels"));
    }

    #[test]
    fn invalid_points_error_or_skip() {
        let bad = Sweep::new()
            .accelerators(AcceleratorKind::all())
            .graphs([DatasetId::Sd])
            .problems([ProblemKind::Sssp]);
        assert!(bad.specs().is_err());
        let kept = bad.clone().skip_unsupported().specs().unwrap();
        // Only HitGraph, ThunderGP and ReGraph support weighted problems.
        assert_eq!(kept.len(), 3);
        assert!(kept.iter().all(|s| s.accelerator().supports_weighted()));
    }

    #[test]
    fn channel_axis_scales_to_32_on_hbm2() {
        // The channel axis may now name counts up to HBM2's 32
        // pseudo-channels; points beyond a technology's envelope are
        // skippable rather than capped silently.
        let specs = Sweep::new()
            .accelerators([AcceleratorKind::ReGraph])
            .graphs([DatasetId::Sd])
            .problems([ProblemKind::Bfs])
            .mem_techs([MemTech::Hbm, MemTech::Hbm2])
            .channels([8, 16, 32])
            .skip_unsupported()
            .specs()
            .unwrap();
        // HBM keeps only 8; HBM2 keeps all three.
        assert_eq!(specs.len(), 4);
        assert!(specs
            .iter()
            .all(|s| s.channels() <= s.mem().max_channels()));
        let c32 = specs
            .iter()
            .find(|s| s.channels() == 32)
            .expect("32-channel HBM2 point present");
        let report = c32.run();
        assert_eq!(report.channels, 32);
        assert!(report.cycles > 0);
        assert!(report.dram.requests() > 0);
    }

    #[test]
    fn session_memoizes() {
        let session = Session::new();
        let spec = SimSpec::builder()
            .accelerator(AcceleratorKind::AccuGraph)
            .graph(DatasetId::Sd)
            .problem(ProblemKind::PageRank)
            .config(AcceleratorConfig::all_optimizations())
            .build()
            .unwrap();
        let a = session.run(&spec);
        assert_eq!(session.cached_runs(), 1);
        let b = session.run(&spec);
        assert_eq!(session.cached_runs(), 1);
        assert_eq!(a, b);
        let st = session.stats();
        assert_eq!(st.sim_runs, 1);
        assert_eq!(st.memo_hits, 1);
        assert_eq!(st.duplicate_waits, 0);
        assert_eq!(st.programs_compiled, 1);
    }

    #[test]
    fn duplicate_specs_in_a_batch_simulate_once() {
        // 16 copies of one spec across 8 workers: the in-progress
        // gate guarantees exactly one simulation; every other call is
        // either a memo hit or a duplicate wait. The accounting
        // identity `sim_runs + memo_hits + duplicate_waits == calls`
        // holds regardless of scheduling.
        let session = Session::new();
        let spec = SimSpec::builder()
            .accelerator(AcceleratorKind::HitGraph)
            .graph(DatasetId::Sd)
            .problem(ProblemKind::Bfs)
            .build()
            .unwrap();
        let specs = vec![spec.clone(); 16];
        let reports = session.run_batch(&specs, 8);
        assert_eq!(reports.len(), 16);
        for r in &reports {
            assert_eq!(r, &reports[0]);
        }
        assert_eq!(session.cached_runs(), 1, "duplicates must not simulate");
        let st = session.stats();
        assert_eq!(st.sim_runs, 1);
        assert_eq!(
            st.sim_runs + st.memo_hits + st.duplicate_waits,
            16,
            "every run call accounted for: {st:?}"
        );
        // Exactly one compile; the program cache never saw a second
        // distinct key.
        assert_eq!(st.programs_compiled, 1);
    }

    #[test]
    fn program_cache_shared_across_mem_axis() {
        // DDR4 and HBM points share one compiled program (the key is
        // memory-independent); distinct channel counts do not.
        let session = Session::new();
        let mk = |mem: MemTech, ch: usize| {
            SimSpec::builder()
                .accelerator(AcceleratorKind::ThunderGp)
                .graph(DatasetId::Sd)
                .problem(ProblemKind::Bfs)
                .mem(mem)
                .channels(ch)
                .build()
                .unwrap()
        };
        session.run(&mk(MemTech::Ddr4, 2));
        session.run(&mk(MemTech::Hbm, 2));
        let st = session.stats();
        assert_eq!(st.sim_runs, 2, "different mem techs simulate separately");
        assert_eq!(st.programs_compiled, 1, "but compile once");
        assert_eq!(st.programs_reused, 1);
        session.run(&mk(MemTech::Hbm, 4));
        assert_eq!(session.stats().programs_compiled, 2, "channels split the key");
    }

    #[test]
    fn sweep_collects_patterns_when_asked() {
        let session = Session::new();
        let runs = quick_sweep().collect_patterns().run_with(&session).unwrap();
        assert_eq!(runs.len(), 2);
        for run in &runs {
            let s = run.report.patterns.as_ref().expect("summary attached");
            assert_eq!(s.total_requests(), run.report.dram.requests());
        }
        // Without the toggle no summary is attached (distinct specs,
        // so the memo cache cannot hand a pattern run back)... while
        // the *program* cache does carry over: the pattern toggle is
        // not part of the program key.
        let plain = quick_sweep().run_with(&session).unwrap();
        assert!(plain.iter().all(|r| r.report.patterns.is_none()));
        let st = session.stats();
        assert_eq!(st.sim_runs, 4);
        assert_eq!(st.programs_compiled, 2, "pattern toggle must not recompile");
        assert_eq!(st.programs_reused, 2);
    }

    #[test]
    fn onchip_axis_sweeps_budgets_and_shares_programs() {
        // The BRAM-size sweep the on-chip model unlocks: one workload,
        // several budgets, a single compiled program across all of
        // them (the buffer is not part of the program key).
        let session = Session::new();
        let runs = Sweep::new()
            .accelerators([AcceleratorKind::AccuGraph])
            .graphs([DatasetId::Sd])
            .problems([ProblemKind::PageRank])
            .onchip_configs([
                None,
                Some(OnChipConfig::vertex_cache(4 * 1024)),
                Some(OnChipConfig::vertex_cache(64 * 1024)),
            ])
            .run_with(&session)
            .unwrap();
        assert_eq!(runs.len(), 3);
        assert!(runs[0].report.onchip.is_none());
        let small = runs[1].report.onchip.as_ref().unwrap();
        let big = runs[2].report.onchip.as_ref().unwrap();
        assert!(big.hits_total() >= small.hits_total(), "bigger budget, no fewer hits");
        assert!(
            runs[2].report.dram.requests() < runs[0].report.dram.requests(),
            "a real budget must shed DRAM traffic"
        );
        let st = session.stats();
        assert_eq!(st.sim_runs, 3);
        assert_eq!(st.programs_compiled, 1, "budgets share one compiled program");
        assert_eq!(st.programs_reused, 2);
        // An empty axis is rejected like every other axis.
        let err = Sweep::new()
            .accelerators([AcceleratorKind::AccuGraph])
            .graphs([DatasetId::Sd])
            .problems([ProblemKind::Bfs])
            .onchip_configs([])
            .specs()
            .unwrap_err();
        assert_eq!(err, SpecError::EmptyAxis("onchip"));
    }

    #[test]
    fn sweep_runs_in_parallel_and_fills_session() {
        let session = Session::new();
        let runs = quick_sweep().threads(4).run_with(&session).unwrap();
        assert_eq!(runs.len(), 2);
        assert_eq!(session.cached_runs(), 2);
        for run in &runs {
            assert!(run.report.cycles > 0, "{}", run.spec.label());
        }
    }

    /// The string key of the retired `coordinator::Runner` shim,
    /// reproduced here to document why the shim had to go: it ignored
    /// the window and experimental-multichannel fields, so two specs
    /// with different timing collided on one cache entry. The derived
    /// `Hash`/`Eq` memo key cannot collide structurally.
    fn old_key(
        kind: AcceleratorKind,
        graph: &str,
        problem: ProblemKind,
        dram: &str,
        channels: usize,
        cfg: &AcceleratorConfig,
    ) -> String {
        format!(
            "{}|{}|{}|{}|{}|{:?}|{}|{}|{}",
            kind.name(),
            graph,
            problem.name(),
            dram,
            channels,
            cfg.optimizations,
            cfg.bram_values,
            cfg.foregraph_interval,
            cfg.num_pes
        )
    }

    #[test]
    fn old_key_collision_is_structurally_impossible_now() {
        let wide = AcceleratorConfig::default().with_window(32);
        let narrow = AcceleratorConfig::default().with_window(1);
        assert_ne!(wide, narrow);
        assert_eq!(
            old_key(AcceleratorKind::HitGraph, "sd", ProblemKind::Bfs, "ddr4", 1, &wide),
            old_key(AcceleratorKind::HitGraph, "sd", ProblemKind::Bfs, "ddr4", 1, &narrow),
            "the retired string key conflated distinct windows"
        );
        let flagged = AcceleratorConfig::default().with_experimental_multichannel(true);
        assert_eq!(
            old_key(AcceleratorKind::HitGraph, "sd", ProblemKind::Bfs, "ddr4", 1, &flagged),
            old_key(
                AcceleratorKind::HitGraph,
                "sd",
                ProblemKind::Bfs,
                "ddr4",
                1,
                &AcceleratorConfig::default()
            ),
            "...and the experimental flag too"
        );
        let build = |cfg: &AcceleratorConfig| {
            SimSpec::builder()
                .accelerator(AcceleratorKind::HitGraph)
                .graph(DatasetId::Sd)
                .problem(ProblemKind::Bfs)
                .config(cfg.clone())
                .build()
                .unwrap()
        };
        let sa = build(&wide);
        let sb = build(&narrow);
        assert_ne!(sa, sb, "typed specs keep the window distinct");
        let session = Session::new();
        let ra = session.run(&sa);
        let rb = session.run(&sb);
        assert_eq!(session.cached_runs(), 2, "two entries, no collision");
        assert_ne!(ra.cycles, rb.cycles, "window must affect timing");
    }

    #[test]
    fn oncemap_survives_a_panicking_computation() {
        let map: OnceMap<u32, u32> = OnceMap::new();
        let boom = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            map.get_or_compute(&7, || panic!("boom"))
        }));
        assert!(boom.is_err());
        // The gate was cancelled, not leaked: the next caller for the
        // same key computes fresh instead of hanging on a dead gate.
        assert_eq!(map.get_or_compute(&7, || 42), (42, Fetch::Computed));
        assert_eq!(map.get_or_compute(&7, || 43), (42, Fetch::Hit));
        assert_eq!(map.len(), 1);
    }

    /// The ISSUE acceptance scenario: a batch containing a panicking
    /// spec and a budget-exceeding spec completes every remaining spec
    /// and reports per-spec outcomes.
    #[test]
    fn failing_specs_are_isolated_and_the_batch_completes() {
        use crate::graph::{Edge, EdgeList};
        use crate::robust::{RunBudget, SimError};
        let session = Session::new();
        let healthy = quick_sweep().specs().unwrap();
        assert_eq!(healthy.len(), 2);
        // A spec that panics mid-simulation: an edge endpoint beyond
        // |V| indexes out of bounds deep in the phase engine.
        let mut bad_graph = EdgeList::new(4, true);
        bad_graph.edges.push(Edge { src: 0, dst: 999, weight: 1.0 });
        let panicking = SimSpec::builder()
            .accelerator(AcceleratorKind::HitGraph)
            .custom_graph("corrupt", bad_graph)
            .problem(ProblemKind::Bfs)
            .build()
            .unwrap();
        // A spec that exceeds its request budget immediately.
        let over_budget = healthy[0]
            .clone()
            .with_budget(Some(RunBudget::default().with_max_requests(3)));
        let specs = vec![
            healthy[0].clone(),
            panicking.clone(),
            over_budget.clone(),
            healthy[1].clone(),
        ];
        let trials = session.run_trials(&specs);
        assert_eq!(trials.len(), 4);
        assert!(trials[0].outcome.is_ok(), "healthy spec must survive the batch");
        assert!(trials[3].outcome.is_ok(), "specs after a failure still run");
        match trials[1].outcome.error() {
            Some(SimError::Panicked { message }) => {
                assert!(!message.is_empty(), "panic payload captured");
            }
            other => panic!("expected a captured panic, got {other:?}"),
        }
        match trials[2].outcome.error() {
            Some(SimError::BudgetExceeded { limit: 3, observed, .. }) => {
                assert!(*observed > 3);
            }
            other => panic!("expected a budget violation, got {other:?}"),
        }
        // Failures are memoized like reports: asking again is a cache
        // hit, not a re-simulation.
        let runs_before = session.stats().sim_runs;
        let again = session.try_run(&panicking);
        assert_eq!(
            again.unwrap_err().kind(),
            "panicked",
            "memoized failure keeps its type"
        );
        assert_eq!(session.stats().sim_runs, runs_before);
        // The parallel path isolates failures the same way.
        let parallel = session.try_run_batch(&specs, 4);
        assert!(parallel[0].is_ok() && parallel[3].is_ok());
        assert!(parallel[1].is_err() && parallel[2].is_err());
        // The infallible entry point surfaces the typed failure as a
        // labelled panic.
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            session.run(&over_budget)
        }));
        assert!(err.is_err());
    }

    #[test]
    fn validate_advisor_scores_against_the_sweep_optimum() {
        let session = Session::new();
        let v = Sweep::new()
            .accelerators([AcceleratorKind::AccuGraph])
            .graphs([DatasetId::Sd])
            .problems([ProblemKind::PageRank])
            .onchip_configs([
                None,
                Some(OnChipConfig::vertex_cache(4 * 1024)),
                Some(OnChipConfig::vertex_cache(64 * 1024)),
            ])
            .validate_advisor(&session)
            .unwrap();
        assert_eq!(v.sweep_points, 3);
        assert!(v.best_report.cycles > 0);
        assert!(v.advisor_report.cycles > 0);
        assert!(v.gap.is_finite());
        // Only the advisor pick's report carries provenance, and only
        // for the axis validate_advisor varies.
        assert_eq!(
            v.advisor_report.advisor,
            Some(AdvisorChoices {
                partition: false,
                placement: false,
                onchip: true,
            })
        );
        assert!(v.best_report.advisor.is_none());
        assert!(!v.recommendation.onchip.rationale.is_empty());
    }
}
