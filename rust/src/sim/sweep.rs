//! Parallel sweep execution over [`SimSpec`]s.
//!
//! [`Session`] is the shared, lock-striped result cache that replaces
//! the old serial `coordinator::Runner`: results are memoized per
//! [`SimSpec`] (derived `Hash`/`Eq` keys — no hand-rolled strings),
//! and [`Session::run_all`] fans a batch of specs out across worker
//! threads. The simulator is deterministic, so parallel execution
//! yields reports identical to the serial path.
//!
//! [`Sweep`] declares experiment axes (accelerators × workloads ×
//! problems × memory technologies × channel counts × configurations),
//! takes their cartesian product and executes it through a session:
//!
//! ```
//! use graphmem::accel::AcceleratorKind;
//! use graphmem::algo::problem::ProblemKind;
//! use graphmem::dram::MemTech;
//! use graphmem::graph::DatasetId;
//! use graphmem::sim::Sweep;
//!
//! let runs = Sweep::new()
//!     .accelerators(AcceleratorKind::all())
//!     .graphs([DatasetId::Sd])
//!     .problems([ProblemKind::Bfs])
//!     .mem_techs([MemTech::Ddr4, MemTech::Hbm])
//!     .run()
//!     .unwrap();
//! assert_eq!(runs.len(), 8);
//! ```

use super::metrics::SimReport;
use super::spec::{SimSpec, SpecError, Workload};
use crate::accel::{AcceleratorConfig, AcceleratorKind};
use crate::algo::problem::ProblemKind;
use crate::dram::MemTech;
use crate::graph::datasets::DatasetId;
use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of independent cache shards; keeps lock contention low when
/// many worker threads publish results concurrently.
const CACHE_SHARDS: usize = 16;

/// Shared memoizing simulation session: run any number of specs
/// (serially or in parallel) and every distinct [`SimSpec`] simulates
/// at most once per session.
pub struct Session {
    shards: Vec<Mutex<HashMap<SimSpec, SimReport>>>,
    /// Worker threads used by [`Session::run_all`]; `None` = derive
    /// from the machine.
    threads: Option<usize>,
}

impl Session {
    pub fn new() -> Session {
        Session {
            shards: (0..CACHE_SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            threads: None,
        }
    }

    /// Fix the worker-thread count for batched runs (1 = serial).
    pub fn with_threads(mut self, threads: usize) -> Session {
        self.threads = Some(threads.max(1));
        self
    }

    fn shard(&self, spec: &SimSpec) -> &Mutex<HashMap<SimSpec, SimReport>> {
        let mut h = DefaultHasher::new();
        spec.hash(&mut h);
        &self.shards[(h.finish() as usize) % CACHE_SHARDS]
    }

    /// Run one spec (or fetch its memoized report).
    pub fn run(&self, spec: &SimSpec) -> SimReport {
        if let Some(hit) = self.shard(spec).lock().unwrap().get(spec) {
            return hit.clone();
        }
        // Simulate outside the lock; a racing duplicate computes the
        // same deterministic report, and the first insert wins.
        let report = spec.run();
        self.shard(spec)
            .lock()
            .unwrap()
            .entry(spec.clone())
            .or_insert(report)
            .clone()
    }

    /// Run a batch of specs across worker threads; the result vector
    /// is index-aligned with `specs`. Reports are identical to calling
    /// [`Session::run`] serially (the simulator is deterministic).
    pub fn run_all(&self, specs: &[SimSpec]) -> Vec<SimReport> {
        self.run_batch(specs, self.threads.unwrap_or_else(default_threads))
    }

    /// [`Session::run_all`] with an explicit worker-thread count.
    pub fn run_batch(&self, specs: &[SimSpec], threads: usize) -> Vec<SimReport> {
        let threads = threads.min(specs.len().max(1));
        if threads <= 1 || specs.len() <= 1 {
            return specs.iter().map(|s| self.run(s)).collect();
        }
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<SimReport>>> =
            specs.iter().map(|_| Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    let Some(spec) = specs.get(i) else { break };
                    let report = self.run(spec);
                    *slots[i].lock().unwrap() = Some(report);
                });
            }
        });
        slots
            .into_iter()
            .map(|slot| slot.into_inner().unwrap().expect("every slot filled"))
            .collect()
    }

    /// Number of distinct simulations materialized so far.
    pub fn cached_runs(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }
}

impl Default for Session {
    fn default() -> Session {
        Session::new()
    }
}

/// Worker threads when none are requested: the machine's parallelism,
/// capped to keep memory in check on very wide hosts.
fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(8)
}

/// One executed sweep point.
#[derive(Clone, Debug)]
pub struct SweepRun {
    pub spec: SimSpec,
    pub report: SimReport,
}

/// Declarative cartesian sweep over simulation axes.
///
/// Axis order in the product (outer to inner): accelerators,
/// workloads, problems, memory technologies, channels, configurations
/// — deterministic, so sweep output order is stable.
#[derive(Clone, Debug)]
pub struct Sweep {
    accelerators: Vec<AcceleratorKind>,
    workloads: Vec<Workload>,
    problems: Vec<ProblemKind>,
    mem_techs: Vec<MemTech>,
    channels: Vec<usize>,
    configs: Vec<AcceleratorConfig>,
    skip_unsupported: bool,
    threads: Option<usize>,
    patterns: bool,
}

impl Sweep {
    /// Empty accelerator/workload/problem axes (must be filled);
    /// memory defaults to single-channel DDR4 with the default
    /// configuration.
    pub fn new() -> Sweep {
        Sweep {
            accelerators: Vec::new(),
            workloads: Vec::new(),
            problems: Vec::new(),
            mem_techs: vec![MemTech::Ddr4],
            channels: vec![1],
            configs: vec![AcceleratorConfig::default()],
            skip_unsupported: false,
            threads: None,
            patterns: false,
        }
    }

    pub fn accelerators(mut self, kinds: impl IntoIterator<Item = AcceleratorKind>) -> Self {
        self.accelerators = kinds.into_iter().collect();
        self
    }

    /// Named benchmark graphs.
    pub fn graphs(mut self, ids: impl IntoIterator<Item = DatasetId>) -> Self {
        self.workloads = ids.into_iter().map(Workload::Named).collect();
        self
    }

    /// Arbitrary workloads (named and/or custom).
    pub fn workloads(mut self, workloads: impl IntoIterator<Item = Workload>) -> Self {
        self.workloads = workloads.into_iter().collect();
        self
    }

    pub fn problems(mut self, problems: impl IntoIterator<Item = ProblemKind>) -> Self {
        self.problems = problems.into_iter().collect();
        self
    }

    pub fn mem_techs(mut self, techs: impl IntoIterator<Item = MemTech>) -> Self {
        self.mem_techs = techs.into_iter().collect();
        self
    }

    pub fn channels(mut self, channels: impl IntoIterator<Item = usize>) -> Self {
        self.channels = channels.into_iter().collect();
        self
    }

    pub fn configs(mut self, configs: impl IntoIterator<Item = AcceleratorConfig>) -> Self {
        self.configs = configs.into_iter().collect();
        self
    }

    /// Silently drop invalid combinations (e.g. weighted problems on
    /// AccuGraph in a product that also contains HitGraph) instead of
    /// failing the whole sweep.
    pub fn skip_unsupported(mut self) -> Self {
        self.skip_unsupported = true;
        self
    }

    /// Fix the worker-thread count (1 = serial).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = Some(threads.max(1));
        self
    }

    /// Collect an access-pattern summary for every point (see
    /// `SimSpecBuilder::patterns`): each run's
    /// [`SimReport::patterns`] is then populated, so a sweep can
    /// compare patterns across accelerators × memories without
    /// writing trace files.
    pub fn collect_patterns(mut self) -> Self {
        self.patterns = true;
        self
    }

    /// The validated cartesian product. With
    /// [`Sweep::skip_unsupported`], invalid points are filtered;
    /// otherwise the first invalid combination aborts with its
    /// [`SpecError`].
    pub fn specs(&self) -> Result<Vec<SimSpec>, SpecError> {
        if self.accelerators.is_empty() {
            return Err(SpecError::EmptyAxis("accelerators"));
        }
        if self.workloads.is_empty() {
            return Err(SpecError::EmptyAxis("workloads"));
        }
        if self.problems.is_empty() {
            return Err(SpecError::EmptyAxis("problems"));
        }
        if self.mem_techs.is_empty() {
            return Err(SpecError::EmptyAxis("mem_techs"));
        }
        if self.channels.is_empty() {
            return Err(SpecError::EmptyAxis("channels"));
        }
        if self.configs.is_empty() {
            return Err(SpecError::EmptyAxis("configs"));
        }
        let mut specs = Vec::new();
        for &kind in &self.accelerators {
            for workload in &self.workloads {
                for &problem in &self.problems {
                    for &mem in &self.mem_techs {
                        for &ch in &self.channels {
                            for cfg in &self.configs {
                                let built = SimSpec::builder()
                                    .accelerator(kind)
                                    .workload(workload.clone())
                                    .problem(problem)
                                    .mem(mem)
                                    .channels(ch)
                                    .config(cfg.clone())
                                    .patterns(self.patterns)
                                    .build();
                                match built {
                                    Ok(spec) => specs.push(spec),
                                    Err(_) if self.skip_unsupported => {}
                                    Err(e) => return Err(e),
                                }
                            }
                        }
                    }
                }
            }
        }
        Ok(specs)
    }

    /// Execute against a fresh session.
    pub fn run(&self) -> Result<Vec<SweepRun>, SpecError> {
        self.run_with(&Session::new())
    }

    /// Execute against a shared session (reusing its memoized runs).
    pub fn run_with(&self, session: &Session) -> Result<Vec<SweepRun>, SpecError> {
        let specs = self.specs()?;
        let reports = match self.threads {
            Some(t) => session.run_batch(&specs, t),
            None => session.run_all(&specs),
        };
        Ok(specs
            .into_iter()
            .zip(reports)
            .map(|(spec, report)| SweepRun { spec, report })
            .collect())
    }
}

impl Default for Sweep {
    fn default() -> Sweep {
        Sweep::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_sweep() -> Sweep {
        Sweep::new()
            .accelerators([AcceleratorKind::AccuGraph, AcceleratorKind::HitGraph])
            .graphs([DatasetId::Sd])
            .problems([ProblemKind::Bfs])
    }

    #[test]
    fn product_order_is_deterministic() {
        let specs = quick_sweep()
            .mem_techs([MemTech::Ddr4, MemTech::Hbm])
            .specs()
            .unwrap();
        assert_eq!(specs.len(), 4);
        assert_eq!(specs[0].accelerator(), AcceleratorKind::AccuGraph);
        assert_eq!(specs[0].mem(), MemTech::Ddr4);
        assert_eq!(specs[1].mem(), MemTech::Hbm);
        assert_eq!(specs[2].accelerator(), AcceleratorKind::HitGraph);
    }

    #[test]
    fn empty_axis_is_an_error() {
        let err = Sweep::new().specs().unwrap_err();
        assert_eq!(err, SpecError::EmptyAxis("accelerators"));
        let err = quick_sweep().channels([]).specs().unwrap_err();
        assert_eq!(err, SpecError::EmptyAxis("channels"));
    }

    #[test]
    fn invalid_points_error_or_skip() {
        let bad = Sweep::new()
            .accelerators(AcceleratorKind::all())
            .graphs([DatasetId::Sd])
            .problems([ProblemKind::Sssp]);
        assert!(bad.specs().is_err());
        let kept = bad.clone().skip_unsupported().specs().unwrap();
        // Only HitGraph and ThunderGP support weighted problems.
        assert_eq!(kept.len(), 2);
        assert!(kept.iter().all(|s| s.accelerator().supports_weighted()));
    }

    #[test]
    fn session_memoizes() {
        let session = Session::new();
        let spec = SimSpec::builder()
            .accelerator(AcceleratorKind::AccuGraph)
            .graph(DatasetId::Sd)
            .problem(ProblemKind::PageRank)
            .config(AcceleratorConfig::all_optimizations())
            .build()
            .unwrap();
        let a = session.run(&spec);
        assert_eq!(session.cached_runs(), 1);
        let b = session.run(&spec);
        assert_eq!(session.cached_runs(), 1);
        assert_eq!(a, b);
    }

    #[test]
    fn sweep_collects_patterns_when_asked() {
        let session = Session::new();
        let runs = quick_sweep().collect_patterns().run_with(&session).unwrap();
        assert_eq!(runs.len(), 2);
        for run in &runs {
            let s = run.report.patterns.as_ref().expect("summary attached");
            assert_eq!(s.total_requests(), run.report.dram.requests());
        }
        // Without the toggle no summary is attached (distinct specs,
        // so the memo cache cannot hand a pattern run back).
        let plain = quick_sweep().run_with(&session).unwrap();
        assert!(plain.iter().all(|r| r.report.patterns.is_none()));
    }

    #[test]
    fn sweep_runs_in_parallel_and_fills_session() {
        let session = Session::new();
        let runs = quick_sweep().threads(4).run_with(&session).unwrap();
        assert_eq!(runs.len(), 2);
        assert_eq!(session.cached_runs(), 2);
        for run in &runs {
            assert!(run.report.cycles > 0, "{}", run.spec.label());
        }
    }
}
