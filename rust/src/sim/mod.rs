//! Co-simulation: accelerator request streams against the DRAM model,
//! the paper's metric set, and the typed session API.
//!
//! Entry points, highest level first:
//!
//! * [`spec`] — [`SimSpec`] / [`SimSpecBuilder`]: a typed, validated
//!   description of one run (accelerator × workload × problem ×
//!   memory technology × channels × configuration). Invalid
//!   combinations are rejected at build time; a built spec always
//!   simulates.
//! * [`sweep`] — [`Sweep`] (cartesian axes) and [`Session`] (shared
//!   lock-striped memo cache + compiled-program cache + parallel
//!   batch execution; see [`crate::accel::program`] for the
//!   compile/execute split).
//! * [`driver`] / [`metrics`] — the phase-level co-simulation engine
//!   (with its reusable [`PhaseScratch`] arena) and the metric set
//!   the specs produce.
//!
//! Abnormal outcomes (stalls, exceeded [`crate::robust::RunBudget`]s,
//! stray panics) surface as typed [`crate::robust::SimError`]s through
//! [`SimSpec::run_checked`] / [`Session::try_run`] /
//! [`Sweep::run_outcomes`]; see [`crate::robust`].

pub mod driver;
pub mod metrics;
pub mod spec;
pub mod sweep;

pub use driver::{
    run_phase, run_phase_onchip, run_phase_with, set_materialize_streams, PhaseScratch,
    PhaseTelemetry,
};
pub use metrics::{AdvisorChoices, RunMetrics, SimReport};
pub use spec::{ProgramKey, RunScratch, SimSpec, SimSpecBuilder, SpecError, Workload};
pub use sweep::{
    AdvisorValidation, Session, SessionStats, Sweep, SweepOutcome, SweepRun, SweepTrial,
};
