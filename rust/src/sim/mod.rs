//! Co-simulation: accelerator request streams against the DRAM model,
//! plus the paper's metric set.

pub mod driver;
pub mod metrics;

pub use driver::{run_phase, PhaseTelemetry};
pub use metrics::{RunMetrics, SimReport};
