//! O(1) LRU order over cache-line ids: a `HashMap` into an
//! arena-allocated doubly-linked list. Backs the fully-associative
//! [`super::Geometry::Scratchpad`] buffer, where a stamp-scan per
//! eviction would cost O(capacity) on large BRAM budgets.

use std::collections::HashMap;

const NIL: usize = usize::MAX;

#[derive(Clone, Debug)]
struct Node {
    line: u64,
    prev: usize,
    next: usize,
}

/// Most-recent-first list of cache lines with O(1) touch / insert /
/// evict. Node slots are pooled, so steady-state churn (insert one,
/// evict one) performs no allocation beyond the map's own bookkeeping.
#[derive(Clone, Debug, Default)]
pub(crate) struct Lru {
    map: HashMap<u64, usize>,
    nodes: Vec<Node>,
    free: Vec<usize>,
    head: usize,
    tail: usize,
}

impl Lru {
    pub(crate) fn new() -> Lru {
        Lru {
            map: HashMap::new(),
            nodes: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
        }
    }

    /// Lines currently held.
    pub(crate) fn len(&self) -> usize {
        self.map.len()
    }

    /// Is the list empty? (Companion of [`Lru::len`]; clippy insists.)
    #[allow(dead_code)]
    pub(crate) fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Is `line` present (without touching recency)?
    #[cfg(test)]
    pub(crate) fn contains(&self, line: u64) -> bool {
        self.map.contains_key(&line)
    }

    fn unlink(&mut self, idx: usize) {
        let (prev, next) = (self.nodes[idx].prev, self.nodes[idx].next);
        if prev == NIL {
            self.head = next;
        } else {
            self.nodes[prev].next = next;
        }
        if next == NIL {
            self.tail = prev;
        } else {
            self.nodes[next].prev = prev;
        }
    }

    fn push_front(&mut self, idx: usize) {
        self.nodes[idx].prev = NIL;
        self.nodes[idx].next = self.head;
        if self.head != NIL {
            self.nodes[self.head].prev = idx;
        }
        self.head = idx;
        if self.tail == NIL {
            self.tail = idx;
        }
    }

    /// Move `line` to the most-recent position; returns whether it was
    /// present.
    pub(crate) fn touch(&mut self, line: u64) -> bool {
        let Some(&idx) = self.map.get(&line) else {
            return false;
        };
        if self.head != idx {
            self.unlink(idx);
            self.push_front(idx);
        }
        true
    }

    /// Insert `line` at the most-recent position (it must not already
    /// be present). When the list already holds `cap` lines, the
    /// least-recent line is evicted and returned.
    pub(crate) fn insert(&mut self, line: u64, cap: u64) -> Option<u64> {
        debug_assert!(!self.map.contains_key(&line), "insert of a present line");
        debug_assert!(cap > 0, "zero-capacity buffers never fill");
        let evicted = if self.len() as u64 >= cap {
            let victim = self.tail;
            debug_assert_ne!(victim, NIL);
            let victim_line = self.nodes[victim].line;
            self.unlink(victim);
            self.map.remove(&victim_line);
            self.free.push(victim);
            Some(victim_line)
        } else {
            None
        };
        let idx = match self.free.pop() {
            Some(slot) => {
                self.nodes[slot].line = line;
                slot
            }
            None => {
                self.nodes.push(Node {
                    line,
                    prev: NIL,
                    next: NIL,
                });
                self.nodes.len() - 1
            }
        };
        self.push_front(idx);
        self.map.insert(line, idx);
        evicted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_touch_evict_in_lru_order() {
        let mut l = Lru::new();
        assert_eq!(l.insert(1, 2), None);
        assert_eq!(l.insert(2, 2), None);
        assert_eq!(l.len(), 2);
        // 1 is least recent -> evicted by the third insert.
        assert_eq!(l.insert(3, 2), Some(1));
        assert!(!l.contains(1));
        assert!(l.contains(2) && l.contains(3));
        // Touch 2 so 3 becomes the victim.
        assert!(l.touch(2));
        assert!(!l.touch(99));
        assert_eq!(l.insert(4, 2), Some(3));
        assert!(l.contains(2) && l.contains(4));
    }

    #[test]
    fn slots_are_pooled_across_evictions() {
        let mut l = Lru::new();
        for i in 0..100u64 {
            l.insert(i, 4);
        }
        assert_eq!(l.len(), 4);
        // 100 inserts through a 4-line list allocate at most 4 + 1
        // node slots (the arena reuses freed slots).
        assert!(l.nodes.len() <= 5, "node arena grew to {}", l.nodes.len());
        for i in 96..100 {
            assert!(l.contains(i));
        }
    }

    #[test]
    fn touch_head_is_noop_and_order_survives() {
        let mut l = Lru::new();
        l.insert(7, 3);
        assert!(l.touch(7)); // head touch
        l.insert(8, 3);
        l.insert(9, 3);
        assert_eq!(l.insert(10, 3), Some(7));
    }
}
