//! On-chip vertex-buffer (BRAM) model — the subsystem that closes the
//! reuse-histogram loop.
//!
//! The paper's central finding is that the studied accelerators differ
//! most in how they *avoid* DRAM traffic: AccuGraph holds vertex
//! values in on-chip arrays, ForeGraph caches subgraph intervals in
//! BRAM, while HitGraph and ThunderGP stream everything. Before this
//! module, the simulator sent every vertex access to
//! [`crate::dram::MemorySystem`], so the reuse-interval histograms the
//! [`crate::trace`] analyzer computes measured a dimension nothing in
//! the simulator acted on.
//!
//! An [`OnChipBuffer`] is consulted by the phase driver
//! ([`crate::sim::driver::run_phase_onchip`]) *before* each line
//! request is enqueued: **hits** are retired at a fixed on-chip
//! latency and never reach the memory system; **misses** pass through
//! unchanged and (for cached regions) fill the buffer. The model is
//! line-granular over a BRAM byte budget with three geometries
//! ([`Geometry`]) and caches a configurable set of [`Region`]s.
//!
//! Determinism: fills take effect at issue time (no
//! miss-status-holding registers), eviction is LRU with stable
//! tie-breaking, and a hit's completion time is
//! `issue + hit_latency` — so a configured simulation is exactly as
//! reproducible as an unconfigured one, and
//! `OnChipConfig` with zero capacity is *bit-identical* to no buffer
//! at all (asserted by `tests/onchip_equivalence.rs`).
//!
//! Closing the loop: the analyzer's per-region reuse histograms
//! ([`crate::trace::RegionSummary::predicted_hit_rate`]) predict this
//! buffer's hit rate from a streaming-only run — reuse distance ≤
//! capacity-in-lines ⇒ predicted hit — and the equivalence suite
//! cross-checks prediction against simulation.
//!
//! ```
//! use graphmem::onchip::{Geometry, OnChipConfig};
//! use graphmem::trace::Region;
//!
//! // AccuGraph's on-chip vertex array: a 64 KiB value scratchpad.
//! let cfg = OnChipConfig::vertex_cache(64 * 1024);
//! assert_eq!(cfg.capacity_lines(), 1024);
//! assert_eq!(cfg.geometry(), Geometry::Scratchpad);
//! assert!(cfg.caches(Region::Vertices) && !cfg.caches(Region::Edges));
//! ```

mod lru;

use crate::accel::{AcceleratorConfig, AcceleratorKind};
use crate::dram::{MemKind, CACHE_LINE};
use crate::trace::Region;
use lru::Lru;

/// How the BRAM byte budget is organized.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Geometry {
    /// One way: each line maps to exactly one slot (`line % sets`).
    DirectMapped,
    /// `ways`-way set-associative with per-set LRU replacement.
    SetAssociative { ways: usize },
    /// Fully-associative LRU over the whole budget — the explicit
    /// on-chip arrays of AccuGraph/ForeGraph, where the accelerator
    /// controls placement and the budget is the only constraint.
    Scratchpad,
}

/// Configuration of an on-chip buffer: which [`Region`]s it caches,
/// the BRAM byte budget, the geometry, the fixed hit latency and the
/// write-allocation policy.
///
/// Part of a [`crate::sim::SimSpec`]'s identity (memoized runs with
/// different buffers never alias) but *not* of its
/// [`crate::sim::SimSpec::program_key`]: the buffer only affects
/// execution, never compilation, so a BRAM-size sweep shares one
/// compiled program across every buffer configuration.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct OnChipConfig {
    /// Cached regions, canonicalized (sorted, deduplicated) so the
    /// derived `Hash`/`Eq` cannot be split by construction order.
    regions: Vec<Region>,
    capacity_bytes: u64,
    geometry: Geometry,
    hit_latency: u64,
    write_allocate: bool,
}

impl OnChipConfig {
    /// Default hit latency in DRAM-controller cycles: one BRAM access.
    pub const DEFAULT_HIT_LATENCY: u64 = 1;

    /// A buffer over `capacity_bytes` of BRAM caching `regions`.
    /// Writes allocate by default (the modelled designs keep their
    /// vertex values readable *and* writable on chip).
    pub fn new(
        capacity_bytes: u64,
        geometry: Geometry,
        regions: impl IntoIterator<Item = Region>,
    ) -> OnChipConfig {
        let mut regions: Vec<Region> = regions.into_iter().collect();
        regions.sort_unstable();
        regions.dedup();
        OnChipConfig {
            regions,
            capacity_bytes,
            geometry,
            hit_latency: Self::DEFAULT_HIT_LATENCY,
            write_allocate: true,
        }
    }

    /// AccuGraph's on-chip vertex value array (§3.2.1): a
    /// fully-associative scratchpad over the vertex region.
    pub fn vertex_cache(capacity_bytes: u64) -> OnChipConfig {
        OnChipConfig::new(capacity_bytes, Geometry::Scratchpad, [Region::Vertices])
    }

    /// ForeGraph's BRAM interval cache (§3.2.2): source + destination
    /// interval values held on chip while a shard is processed. Same
    /// mechanics as [`OnChipConfig::vertex_cache`] — interval values
    /// *are* vertex values — sized for two intervals by
    /// [`OnChipConfig::default_for`].
    pub fn interval_cache(capacity_bytes: u64) -> OnChipConfig {
        OnChipConfig::new(capacity_bytes, Geometry::Scratchpad, [Region::Vertices])
    }

    /// A fully-associative scratchpad over an explicit region set —
    /// the shape the advisor emits when it sizes per-region budgets
    /// from the reuse-interval histograms (see [`crate::advisor`]).
    /// Equivalent to [`OnChipConfig::new`] with
    /// [`Geometry::Scratchpad`].
    pub fn scratchpad(
        capacity_bytes: u64,
        regions: impl IntoIterator<Item = Region>,
    ) -> OnChipConfig {
        OnChipConfig::new(capacity_bytes, Geometry::Scratchpad, regions)
    }

    /// The paper-faithful default buffer for an accelerator, sized
    /// from its [`AcceleratorConfig`] capacities:
    ///
    /// * AccuGraph — vertex array of `bram_values` 4 B values,
    /// * ForeGraph — interval cache of 2 × `foregraph_interval` values
    ///   (source + destination interval),
    /// * HitGraph / ThunderGP / ReGraph — `None`: streaming designs
    ///   whose value prefetches (and ReGraph's big-pipeline gathers)
    ///   are already modelled as explicit request streams.
    pub fn default_for(kind: AcceleratorKind, cfg: &AcceleratorConfig) -> Option<OnChipConfig> {
        match kind {
            AcceleratorKind::AccuGraph => {
                Some(OnChipConfig::vertex_cache(cfg.bram_values as u64 * 4))
            }
            AcceleratorKind::ForeGraph => {
                Some(OnChipConfig::interval_cache(2 * cfg.foregraph_interval as u64 * 4))
            }
            AcceleratorKind::HitGraph
            | AcceleratorKind::ThunderGp
            | AcceleratorKind::ReGraph => None,
        }
    }

    /// Override the geometry.
    pub fn with_geometry(mut self, geometry: Geometry) -> OnChipConfig {
        self.geometry = geometry;
        self
    }

    /// Override the fixed hit latency (cycles).
    pub fn with_hit_latency(mut self, cycles: u64) -> OnChipConfig {
        self.hit_latency = cycles;
        self
    }

    /// Whether a write miss allocates the line (default: yes).
    pub fn with_write_allocate(mut self, on: bool) -> OnChipConfig {
        self.write_allocate = on;
        self
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_bytes
    }

    /// Budget in whole cache lines (the unit everything is tracked in).
    pub fn capacity_lines(&self) -> u64 {
        self.capacity_bytes / CACHE_LINE
    }

    pub fn geometry(&self) -> Geometry {
        self.geometry
    }

    pub fn hit_latency(&self) -> u64 {
        self.hit_latency
    }

    pub fn write_allocate(&self) -> bool {
        self.write_allocate
    }

    /// Cached regions (sorted).
    pub fn regions(&self) -> &[Region] {
        &self.regions
    }

    /// Does this buffer cache `region`?
    pub fn caches(&self, region: Region) -> bool {
        self.regions.contains(&region)
    }

    /// Structural validity (checked by `SimSpecBuilder::build` so an
    /// invalid buffer is rejected before any simulation).
    pub fn validate(&self) -> Result<(), &'static str> {
        if let Geometry::SetAssociative { ways: 0 } = self.geometry {
            return Err("set-associative geometry needs at least 1 way");
        }
        if self.regions.is_empty() {
            return Err("an on-chip buffer must cache at least one region");
        }
        Ok(())
    }
}

/// Hit / miss / fill counters of one run's buffer, per [`Region`].
/// Attached to [`crate::sim::SimReport::onchip`] when the spec carried
/// an [`OnChipConfig`]. Accesses to regions the buffer does not cache
/// bypass it entirely and are not counted here.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct OnChipStats {
    hits: [u64; Region::COUNT],
    misses: [u64; Region::COUNT],
    fills: [u64; Region::COUNT],
    evictions: u64,
    capacity_lines: u64,
}

impl OnChipStats {
    /// Rebuild stats from serialized counters — the inverse of reading
    /// the per-region accessors in `Region::all()` order. Stored
    /// verbatim, so a round trip through `crate::persist` is
    /// structurally equal to the original.
    pub fn from_parts(
        hits: [u64; Region::COUNT],
        misses: [u64; Region::COUNT],
        fills: [u64; Region::COUNT],
        evictions: u64,
        capacity_lines: u64,
    ) -> OnChipStats {
        OnChipStats {
            hits,
            misses,
            fills,
            evictions,
            capacity_lines,
        }
    }

    pub fn region_hits(&self, r: Region) -> u64 {
        self.hits[r.index()]
    }

    pub fn region_misses(&self, r: Region) -> u64 {
        self.misses[r.index()]
    }

    pub fn region_fills(&self, r: Region) -> u64 {
        self.fills[r.index()]
    }

    /// Accesses the buffer arbitrated for `r` (hits + misses).
    pub fn region_accesses(&self, r: Region) -> u64 {
        self.hits[r.index()] + self.misses[r.index()]
    }

    pub fn hits_total(&self) -> u64 {
        self.hits.iter().sum()
    }

    pub fn misses_total(&self) -> u64 {
        self.misses.iter().sum()
    }

    pub fn fills_total(&self) -> u64 {
        self.fills.iter().sum()
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// The buffer's capacity in lines (for hit-rate predictions).
    pub fn capacity_lines(&self) -> u64 {
        self.capacity_lines
    }

    /// Hit rate over one region's arbitrated accesses (0.0 when none).
    pub fn region_hit_rate(&self, r: Region) -> f64 {
        let n = self.region_accesses(r);
        if n == 0 {
            0.0
        } else {
            self.hits[r.index()] as f64 / n as f64
        }
    }

    /// Hit rate over all arbitrated accesses (0.0 when none).
    pub fn hit_rate(&self) -> f64 {
        let n = self.hits_total() + self.misses_total();
        if n == 0 {
            0.0
        } else {
            self.hits_total() as f64 / n as f64
        }
    }
}

enum Storage {
    /// Inert: zero capacity — every access misses, nothing fills.
    Empty,
    /// Direct-mapped / set-associative: per-slot tags with per-set LRU
    /// stamps (`u64::MAX` tag = empty slot).
    Sets {
        sets: u64,
        ways: usize,
        tags: Vec<u64>,
        stamps: Vec<u64>,
        tick: u64,
    },
    /// Fully-associative scratchpad backed by the O(1) LRU list.
    Scratchpad { lru: Lru, cap: u64 },
}

/// One run's buffer instance: the [`OnChipConfig`] plus the live tag
/// state and counters. Created per simulation by
/// [`crate::sim::SimSpec`] and threaded through the phase driver.
pub struct OnChipBuffer {
    cached: [bool; Region::COUNT],
    hit_latency: u64,
    write_allocate: bool,
    storage: Storage,
    stats: OnChipStats,
}

impl OnChipBuffer {
    pub fn new(cfg: OnChipConfig) -> OnChipBuffer {
        let cap = cfg.capacity_lines();
        let storage = if cap == 0 {
            Storage::Empty
        } else {
            match cfg.geometry {
                Geometry::Scratchpad => Storage::Scratchpad {
                    lru: Lru::new(),
                    cap,
                },
                Geometry::DirectMapped => Storage::Sets {
                    sets: cap,
                    ways: 1,
                    tags: vec![u64::MAX; cap as usize],
                    stamps: vec![0; cap as usize],
                    tick: 0,
                },
                Geometry::SetAssociative { ways } => {
                    // A budget smaller than one set degrades to fewer
                    // ways; leftover lines beyond sets*ways are unused.
                    let ways = ways.min(cap as usize).max(1);
                    let sets = (cap / ways as u64).max(1);
                    let slots = (sets * ways as u64) as usize;
                    Storage::Sets {
                        sets,
                        ways,
                        tags: vec![u64::MAX; slots],
                        stamps: vec![0; slots],
                        tick: 0,
                    }
                }
            }
        };
        OnChipBuffer {
            cached: {
                let mut m = [false; Region::COUNT];
                for &r in cfg.regions() {
                    m[r.index()] = true;
                }
                m
            },
            hit_latency: cfg.hit_latency,
            write_allocate: cfg.write_allocate,
            storage,
            stats: OnChipStats {
                capacity_lines: cap,
                ..OnChipStats::default()
            },
        }
    }

    /// Arbitrate one line request issued at cycle `now`.
    ///
    /// * `Some(done_at)` — on-chip **hit**: the request is retired at
    ///   `now + hit_latency` and must not be sent to the memory
    ///   system.
    /// * `None` — bypass (uncached region) or **miss**: the request
    ///   proceeds to DRAM unchanged; a miss on a cached region has
    ///   already filled the buffer (reads always, writes when
    ///   write-allocate is on).
    #[inline]
    pub fn access(&mut self, addr: u64, kind: MemKind, region: Region, now: u64) -> Option<u64> {
        if !self.cached[region.index()] {
            return None;
        }
        let line = addr / CACHE_LINE;
        if self.lookup_and_touch(line) {
            self.stats.hits[region.index()] += 1;
            return Some(now + self.hit_latency);
        }
        self.stats.misses[region.index()] += 1;
        if kind == MemKind::Read || self.write_allocate {
            if !matches!(self.storage, Storage::Empty) {
                self.stats.fills[region.index()] += 1;
            }
            if self.fill(line) {
                self.stats.evictions += 1;
            }
        }
        None
    }

    fn lookup_and_touch(&mut self, line: u64) -> bool {
        match &mut self.storage {
            Storage::Empty => false,
            Storage::Scratchpad { lru, .. } => lru.touch(line),
            Storage::Sets {
                sets,
                ways,
                tags,
                stamps,
                tick,
            } => {
                let base = (line % *sets) as usize * *ways;
                for w in 0..*ways {
                    if tags[base + w] == line {
                        *tick += 1;
                        stamps[base + w] = *tick;
                        return true;
                    }
                }
                false
            }
        }
    }

    /// Insert `line`; returns whether a valid line was evicted.
    fn fill(&mut self, line: u64) -> bool {
        match &mut self.storage {
            Storage::Empty => false,
            Storage::Scratchpad { lru, cap } => lru.insert(line, *cap).is_some(),
            Storage::Sets {
                sets,
                ways,
                tags,
                stamps,
                tick,
            } => {
                let base = (line % *sets) as usize * *ways;
                // Empty way first; otherwise per-set LRU (lowest
                // stamp; stamps are unique, so this is deterministic).
                let mut victim = base;
                let mut evict = true;
                for w in 0..*ways {
                    if tags[base + w] == u64::MAX {
                        victim = base + w;
                        evict = false;
                        break;
                    }
                    if stamps[base + w] < stamps[victim] {
                        victim = base + w;
                    }
                }
                tags[victim] = line;
                *tick += 1;
                stamps[victim] = *tick;
                evict
            }
        }
    }

    /// Counters so far.
    pub fn stats(&self) -> &OnChipStats {
        &self.stats
    }

    /// Consume the buffer, yielding its counters (attached to the
    /// report by `SimSpec::run`).
    pub fn into_stats(self) -> OnChipStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn read(buf: &mut OnChipBuffer, addr: u64) -> Option<u64> {
        buf.access(addr, MemKind::Read, Region::Vertices, 100)
    }

    #[test]
    fn scratchpad_hits_after_fill_at_fixed_latency() {
        let mut b = OnChipBuffer::new(OnChipConfig::vertex_cache(4 * CACHE_LINE));
        assert_eq!(read(&mut b, 0), None); // cold miss, fills
        assert_eq!(read(&mut b, 0), Some(100 + OnChipConfig::DEFAULT_HIT_LATENCY));
        assert_eq!(read(&mut b, 63), Some(101), "same line, any offset");
        assert_eq!(b.stats().region_hits(Region::Vertices), 2);
        assert_eq!(b.stats().region_misses(Region::Vertices), 1);
        assert_eq!(b.stats().region_fills(Region::Vertices), 1);
    }

    #[test]
    fn lru_eviction_over_capacity() {
        let mut b = OnChipBuffer::new(OnChipConfig::vertex_cache(2 * CACHE_LINE));
        read(&mut b, 0);
        read(&mut b, 64);
        read(&mut b, 128); // evicts line 0
        assert_eq!(b.stats().evictions(), 1);
        assert_eq!(read(&mut b, 0), None, "line 0 was evicted");
        assert!(read(&mut b, 128).is_some());
    }

    #[test]
    fn uncached_regions_bypass_without_counting() {
        let mut b = OnChipBuffer::new(OnChipConfig::vertex_cache(4 * CACHE_LINE));
        assert_eq!(b.access(0, MemKind::Read, Region::Edges, 0), None);
        assert_eq!(b.access(0, MemKind::Read, Region::Edges, 0), None);
        assert_eq!(b.stats().region_accesses(Region::Edges), 0);
        assert_eq!(b.stats().hits_total() + b.stats().misses_total(), 0);
    }

    #[test]
    fn zero_capacity_is_inert() {
        let mut b = OnChipBuffer::new(OnChipConfig::vertex_cache(0));
        for _ in 0..5 {
            assert_eq!(read(&mut b, 0), None);
        }
        assert_eq!(b.stats().hits_total(), 0);
        assert_eq!(b.stats().fills_total(), 0);
        assert_eq!(b.stats().misses_total(), 5);
        assert_eq!(b.stats().capacity_lines(), 0);
    }

    #[test]
    fn write_allocate_policy() {
        let alloc = OnChipConfig::vertex_cache(4 * CACHE_LINE);
        assert!(alloc.write_allocate());
        let mut b = OnChipBuffer::new(alloc);
        assert_eq!(b.access(0, MemKind::Write, Region::Vertices, 0), None);
        assert!(b.access(0, MemKind::Read, Region::Vertices, 0).is_some());

        let mut b = OnChipBuffer::new(
            OnChipConfig::vertex_cache(4 * CACHE_LINE).with_write_allocate(false),
        );
        assert_eq!(b.access(0, MemKind::Write, Region::Vertices, 0), None);
        assert_eq!(
            b.access(0, MemKind::Read, Region::Vertices, 0),
            None,
            "no-allocate write must not fill"
        );
    }

    #[test]
    fn direct_mapped_conflicts_where_scratchpad_holds() {
        // Two lines `capacity` apart collide direct-mapped but coexist
        // in a scratchpad of the same budget.
        let cap_lines = 8u64;
        let bytes = cap_lines * CACHE_LINE;
        let mut dm = OnChipBuffer::new(
            OnChipConfig::vertex_cache(bytes).with_geometry(Geometry::DirectMapped),
        );
        let mut sp = OnChipBuffer::new(OnChipConfig::vertex_cache(bytes));
        for b in [&mut dm, &mut sp] {
            read(b, 0);
            read(b, cap_lines * CACHE_LINE); // same set direct-mapped
        }
        assert_eq!(read(&mut dm, 0), None, "direct-mapped conflict evicted it");
        assert!(read(&mut sp, 0).is_some(), "scratchpad keeps both");
    }

    #[test]
    fn set_associative_ways_prevent_one_conflict() {
        let cap_lines = 8u64;
        let bytes = cap_lines * CACHE_LINE;
        let mut sa = OnChipBuffer::new(
            OnChipConfig::vertex_cache(bytes)
                .with_geometry(Geometry::SetAssociative { ways: 2 }),
        );
        // sets = 4; lines 0 and 4 share set 0 but occupy both ways.
        read(&mut sa, 0);
        read(&mut sa, 4 * CACHE_LINE);
        assert!(read(&mut sa, 0).is_some());
        assert!(read(&mut sa, 4 * CACHE_LINE).is_some());
        // A third same-set line evicts the LRU way (line 0).
        read(&mut sa, 8 * CACHE_LINE);
        assert_eq!(read(&mut sa, 0), None);
    }

    #[test]
    fn config_canonicalizes_and_validates() {
        let a = OnChipConfig::new(64, Geometry::Scratchpad, [Region::Updates, Region::Vertices]);
        let b = OnChipConfig::new(64, Geometry::Scratchpad, [Region::Vertices, Region::Updates]);
        assert_eq!(a, b, "region order must not split the identity");
        assert!(a.validate().is_ok());
        assert!(OnChipConfig::new(64, Geometry::SetAssociative { ways: 0 }, [Region::Vertices])
            .validate()
            .is_err());
        assert!(OnChipConfig::new(64, Geometry::Scratchpad, []).validate().is_err());
    }

    #[test]
    fn accelerator_defaults_match_the_paper() {
        let cfg = AcceleratorConfig::default();
        let accu = OnChipConfig::default_for(AcceleratorKind::AccuGraph, &cfg).unwrap();
        assert_eq!(accu.capacity_bytes(), cfg.bram_values as u64 * 4);
        let fore = OnChipConfig::default_for(AcceleratorKind::ForeGraph, &cfg).unwrap();
        assert_eq!(fore.capacity_bytes(), 2 * cfg.foregraph_interval as u64 * 4);
        assert!(OnChipConfig::default_for(AcceleratorKind::HitGraph, &cfg).is_none());
        assert!(OnChipConfig::default_for(AcceleratorKind::ThunderGp, &cfg).is_none());
        assert!(OnChipConfig::default_for(AcceleratorKind::ReGraph, &cfg).is_none());
    }
}
