//! Per-channel memory controller: FR-FCFS scheduling over an
//! open-page row-buffer policy, with transactional JEDEC timing.
//!
//! Each serviced request computes its earliest legal command times
//! (PRE / ACT / CAS) from the bank, rank and bus state, then advances
//! that state. The channel services one request per call in scheduler
//! order; overlap between banks is captured because issue times are
//! derived from per-resource constraints rather than a global serial
//! clock.

use super::address::{AddressMapper, DecodedAddr};
use super::fault::FaultLane;
use super::spec::{DramPolicy, DramSpec, RowPolicy, SchedPolicy};
use super::stats::{DramStats, RowOutcome};
use super::system::{MemKind, MemRequest};
use std::collections::VecDeque;

/// Per-bank timing state.
#[derive(Clone, Debug)]
struct Bank {
    open_row: Option<u64>,
    /// Earliest next ACT (tRC from last ACT, tRP from PRE).
    next_act: u64,
    /// Earliest next PRE (tRAS from ACT, tRTP/tWR from CAS).
    next_pre: u64,
    /// Earliest next CAS (tRCD from ACT).
    next_cas: u64,
}

impl Bank {
    fn new() -> Self {
        Bank {
            open_row: None,
            next_act: 0,
            next_pre: 0,
            next_cas: 0,
        }
    }
}

/// Per-rank state: activation throttling windows.
#[derive(Clone, Debug)]
struct RankState {
    /// Times of the last 4 ACTs (for tFAW).
    act_window: VecDeque<u64>,
    /// Last ACT time (for tRRD), per bank group.
    last_act_in_group: Vec<u64>,
    /// Last ACT time anywhere in the rank.
    last_act: u64,
}

/// A queued request with its decoded coordinates.
#[derive(Clone, Debug)]
struct Queued {
    req: MemRequest,
    decoded: DecodedAddr,
    /// Arrival time at the controller.
    arrival: u64,
    /// Monotone sequence number for FCFS tie-breaking.
    seq: u64,
}

/// Result of servicing one request.
#[derive(Clone, Copy, Debug)]
pub struct Serviced {
    pub tag: u64,
    pub kind: MemKind,
    /// Cycle at which the data transfer finished (completion time).
    pub done_at: u64,
    pub outcome: RowOutcome,
}

/// One memory channel.
pub struct Channel {
    spec: DramSpec,
    policy: DramPolicy,
    mapper: AddressMapper,
    banks: Vec<Bank>,
    ranks: Vec<RankState>,
    /// Earliest start of the next data burst (bus occupancy).
    next_burst: u64,
    /// Last CAS bookkeeping for tCCD / turnaround.
    last_cas_time: u64,
    last_cas_group: usize,
    last_cas_was_write: bool,
    /// End of the last write burst (for tWTR).
    last_write_data_end: u64,
    /// End of the last read burst (for the read→write bus turnaround).
    last_read_data_end: u64,
    next_refresh: u64,
    queue: Vec<Queued>,
    /// Cached minimum arrival over `queue` (`None` when empty).
    /// Maintained incrementally: an enqueue can only lower it (O(1));
    /// servicing removes a request, forcing one window-bounded rescan.
    earliest: Option<u64>,
    seq: u64,
    /// Installed fault injector for this channel, if any: adds a
    /// deterministic, selection-independent delay to serviced
    /// completions (see [`super::fault`]).
    fault: Option<FaultLane>,
    pub stats: DramStats,
}

impl Channel {
    pub fn new(spec: DramSpec) -> Self {
        Self::with_policy(spec, DramPolicy::default())
    }

    pub fn with_policy(spec: DramSpec, policy: DramPolicy) -> Self {
        let mapper = AddressMapper::with_map(&spec, policy.addr_map);
        let nbanks = spec.banks_per_channel();
        let ranks = (0..spec.ranks)
            .map(|_| RankState {
                act_window: VecDeque::with_capacity(4),
                last_act_in_group: vec![0; spec.bank_groups],
                last_act: 0,
            })
            .collect();
        Channel {
            spec,
            policy,
            mapper,
            banks: vec![Bank::new(); nbanks],
            ranks,
            next_burst: 0,
            last_cas_time: 0,
            last_cas_group: 0,
            last_cas_was_write: false,
            last_write_data_end: 0,
            last_read_data_end: 0,
            next_refresh: spec.speed.trefi,
            queue: Vec::with_capacity(64),
            earliest: None,
            seq: 0,
            fault: None,
            stats: DramStats::default(),
        }
    }

    pub fn spec(&self) -> &DramSpec {
        &self.spec
    }

    /// Reconfigure in place for a (possibly different) spec/policy,
    /// retaining the queue's and the bank vector's heap capacity —
    /// the per-worker reuse hook behind
    /// [`super::MemorySystem::reset`]. Logically identical to
    /// `*self = Channel::with_policy(spec, policy)`.
    pub(super) fn reset(&mut self, spec: DramSpec, policy: DramPolicy) {
        self.spec = spec;
        self.policy = policy;
        self.mapper = AddressMapper::with_map(&spec, policy.addr_map);
        self.banks.clear();
        self.banks.resize(spec.banks_per_channel(), Bank::new());
        self.ranks.truncate(spec.ranks);
        for r in &mut self.ranks {
            r.act_window.clear();
            r.last_act_in_group.clear();
            r.last_act_in_group.resize(spec.bank_groups, 0);
            r.last_act = 0;
        }
        while self.ranks.len() < spec.ranks {
            self.ranks.push(RankState {
                act_window: VecDeque::with_capacity(4),
                last_act_in_group: vec![0; spec.bank_groups],
                last_act: 0,
            });
        }
        self.next_burst = 0;
        self.last_cas_time = 0;
        self.last_cas_group = 0;
        self.last_cas_was_write = false;
        self.last_write_data_end = 0;
        self.last_read_data_end = 0;
        self.next_refresh = spec.speed.trefi;
        self.queue.clear();
        self.earliest = None;
        self.seq = 0;
        self.fault = None;
        self.stats = DramStats::default();
    }

    /// Install (or clear) this channel's fault lane. The spec layer
    /// re-installs lanes at the start of every run, so a reset channel
    /// is always fault-free until told otherwise.
    pub(super) fn set_fault_lane(&mut self, lane: Option<FaultLane>) {
        self.fault = lane;
    }

    /// Number of requests waiting.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Enqueue a request that becomes visible to the scheduler at
    /// `arrival` (cycles).
    pub fn enqueue(&mut self, req: MemRequest, arrival: u64) {
        let decoded = self.mapper.decode(req.addr);
        debug_assert_eq!(
            decoded.channel, 0,
            "channel routing happens in MemorySystem; channel-local addresses must decode to 0"
        );
        self.queue.push(Queued {
            req,
            decoded,
            arrival,
            seq: self.seq,
        });
        self.earliest = Some(match self.earliest {
            Some(e) => e.min(arrival),
            None => arrival,
        });
        self.seq += 1;
    }

    /// Earliest arrival among queued requests (scheduling horizon).
    /// O(1): the minimum is cached — enqueues lower it in place, and
    /// [`Channel::service_one`] rescans the (window-bounded) queue
    /// only after it removes a request. The [`super::MemorySystem`]
    /// completion heap keys on this value.
    pub fn earliest_arrival(&self) -> Option<u64> {
        self.earliest
    }

    /// FR-FCFS pick: prefer the oldest *row-hit* request among those
    /// arrived by the scheduling horizon; otherwise the oldest request.
    ///
    /// The horizon is the earliest arrival in the queue: a request
    /// cannot be reordered behind requests that arrive later than the
    /// moment the controller could serve it, so we consider arrived
    /// requests within a small lookahead window of the horizon. This
    /// matches FR-FCFS behaviour on a continuously-fed queue.
    fn pick(&self, horizon: u64) -> Option<usize> {
        if self.queue.is_empty() {
            return None;
        }
        // Lookahead: requests arriving within one row cycle of the
        // horizon compete (they would be queued by the time the
        // controller finishes the current command).
        let window = horizon + self.spec.speed.trc;
        let first_ready = self.policy.sched == SchedPolicy::FrFcfs;
        let mut best_hit: Option<(usize, u64)> = None; // (index, seq)
        let mut best_any: Option<(usize, u64)> = None;
        for (i, q) in self.queue.iter().enumerate() {
            if q.arrival > window {
                continue;
            }
            if best_any.map_or(true, |(_, s)| q.seq < s) {
                best_any = Some((i, q.seq));
            }
            if !first_ready {
                continue; // strict FCFS: ignore row-hit preference
            }
            let bank = &self.banks[q.decoded.flat_bank];
            if bank.open_row == Some(q.decoded.row) && best_hit.map_or(true, |(_, s)| q.seq < s) {
                best_hit = Some((i, q.seq));
            }
        }
        best_hit.or(best_any).map(|(i, _)| i)
    }

    /// Apply a refresh if `t` has crossed the refresh deadline.
    /// All rows close; banks stall for tRFC.
    fn maybe_refresh(&mut self, t: u64) {
        while t >= self.next_refresh {
            let end = self.next_refresh + self.spec.speed.trfc;
            for b in &mut self.banks {
                b.open_row = None;
                b.next_act = b.next_act.max(end);
            }
            self.stats.refreshes += 1;
            self.next_refresh += self.spec.speed.trefi;
        }
    }

    /// Earliest legal ACT time for `bank` in `rank`/`group`, at or
    /// after `t`.
    fn act_ready(&self, t: u64, d: &DecodedAddr) -> u64 {
        let bank = &self.banks[d.flat_bank];
        let rank = &self.ranks[d.rank];
        let sp = &self.spec.speed;
        let mut at = t.max(bank.next_act);
        // tRRD: same-group uses _L, cross-group uses _S (no groups => equal).
        let same_group_last = rank.last_act_in_group[d.bank_group];
        at = at.max(same_group_last + sp.trrd_l);
        at = at.max(rank.last_act + sp.trrd_s);
        // tFAW: at most 4 ACTs per window.
        if rank.act_window.len() == 4 {
            at = at.max(rank.act_window[0] + sp.tfaw);
        }
        at
    }

    /// Earliest legal CAS (read/write command) time at or after `t`.
    fn cas_ready(&self, t: u64, d: &DecodedAddr, is_write: bool) -> u64 {
        let sp = &self.spec.speed;
        let bank = &self.banks[d.flat_bank];
        let mut ct = t.max(bank.next_cas);
        // CAS-to-CAS spacing (bank-group aware).
        let ccd = if d.bank_group == self.last_cas_group {
            sp.tccd_l
        } else {
            sp.tccd_s
        };
        ct = ct.max(self.last_cas_time + ccd);
        // Write -> read turnaround.
        if !is_write && self.last_cas_was_write {
            ct = ct.max(self.last_write_data_end + sp.twtr);
        }
        let lat = if is_write { sp.cwl } else { sp.cl };
        // Read -> write turnaround: the write burst must not start the
        // same cycle the preceding read burst ends — burst occupancy
        // alone allows back-to-back bursts, so the one-cycle bus
        // direction bubble is enforced explicitly here.
        if is_write && !self.last_cas_was_write {
            let min_burst_start = self.last_read_data_end + 1;
            if min_burst_start > ct + lat {
                ct = min_burst_start - lat;
            }
        }
        // Data-bus occupancy: burst start = CAS + CL/CWL must be >= next_burst.
        if self.next_burst > ct + lat {
            ct = self.next_burst - lat;
        }
        ct
    }

    /// Service the next request per FR-FCFS. Returns `None` when the
    /// queue is empty.
    pub fn service_one(&mut self) -> Option<Serviced> {
        let horizon = self.earliest_arrival()?;
        let idx = self.pick(horizon)?;
        let q = self.queue.swap_remove(idx);
        self.earliest = self.queue.iter().map(|r| r.arrival).min();
        let sp = self.spec.speed;
        let d = q.decoded;
        let t0 = q.arrival;
        self.maybe_refresh(t0);

        let is_write = q.req.kind == MemKind::Write;
        let (outcome, cas_t, act_t_opt) = match self.banks[d.flat_bank].open_row {
            Some(row) if row == d.row => {
                let cas = self.cas_ready(t0, &d, is_write);
                (RowOutcome::Hit, cas, None)
            }
            None => {
                let act = self.act_ready(t0, &d);
                let cas = self.cas_ready(act + sp.trcd, &d, is_write);
                (RowOutcome::Miss, cas, Some(act))
            }
            Some(_) => {
                let bank = &self.banks[d.flat_bank];
                let pre = t0.max(bank.next_pre);
                let act = self.act_ready(pre + sp.trp, &d);
                let cas = self.cas_ready(act + sp.trcd, &d, is_write);
                (RowOutcome::Conflict, cas, Some(act))
            }
        };

        // Commit state updates.
        if let Some(act_t) = act_t_opt {
            let rank = &mut self.ranks[d.rank];
            rank.last_act = act_t;
            rank.last_act_in_group[d.bank_group] = act_t;
            rank.act_window.push_back(act_t);
            if rank.act_window.len() > 4 {
                rank.act_window.pop_front();
            }
            let bank = &mut self.banks[d.flat_bank];
            bank.open_row = Some(d.row);
            bank.next_act = act_t + sp.trc;
            bank.next_pre = act_t + sp.tras;
            bank.next_cas = act_t + sp.trcd;
        }

        let lat = if is_write { sp.cwl } else { sp.cl };
        let burst_start = cas_t + lat;
        let mut data_end = burst_start + sp.burst;
        // Fault injection (deterministic, keyed on the per-channel
        // serviced count): the delay is structural — it pushes the
        // data bus, the write-recovery window and the completion time
        // alike — so faulted timing composes exactly like slow DRAM.
        if let Some(lane) = &mut self.fault {
            let inj = lane.next_injection();
            if inj.events > 0 {
                data_end += inj.extra_cycles;
                self.stats.faults_injected += inj.events;
                self.stats.fault_delay_cycles += inj.extra_cycles;
            }
        }
        self.next_burst = data_end;
        self.last_cas_time = cas_t;
        self.last_cas_group = d.bank_group;
        self.last_cas_was_write = is_write;
        if is_write {
            self.last_write_data_end = data_end;
        } else {
            self.last_read_data_end = data_end;
        }

        {
            let bank = &mut self.banks[d.flat_bank];
            if is_write {
                bank.next_pre = bank.next_pre.max(data_end + sp.twr);
            } else {
                bank.next_pre = bank.next_pre.max(cas_t + sp.trtp);
            }
            bank.next_cas = bank.next_cas.max(cas_t);
            if self.policy.row == RowPolicy::ClosedPage {
                // auto-precharge: row closes; next ACT waits for the
                // precharge completing after the access
                bank.next_act = bank.next_act.max(bank.next_pre + sp.trp);
                bank.open_row = None;
            }
        }

        // Stats.
        if is_write {
            self.stats.writes += 1;
        } else {
            self.stats.reads += 1;
        }
        self.stats.record_region(q.req.region, is_write);
        self.stats.record(outcome);
        self.stats.data_bus_cycles += sp.burst;
        self.stats.total_latency += data_end - q.arrival;
        self.stats.finish_cycle = self.stats.finish_cycle.max(data_end);

        Some(Serviced {
            tag: q.req.tag,
            kind: q.req.kind,
            done_at: data_end,
            outcome,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::CACHE_LINE;

    fn read(addr: u64, tag: u64) -> MemRequest {
        MemRequest {
            addr,
            kind: MemKind::Read,
            tag,
            region: crate::trace::Region::Edges,
        }
    }

    fn write(addr: u64, tag: u64) -> MemRequest {
        MemRequest {
            addr,
            kind: MemKind::Write,
            tag,
            region: crate::trace::Region::Updates,
        }
    }

    #[test]
    fn sequential_reads_are_row_hits() {
        let spec = DramSpec::ddr4_2400(1);
        let mut ch = Channel::new(spec);
        for i in 0..64u64 {
            ch.enqueue(read(i * CACHE_LINE, i), 0);
        }
        let mut outcomes = Vec::new();
        while let Some(s) = ch.service_one() {
            outcomes.push(s.outcome);
        }
        assert_eq!(outcomes.len(), 64);
        assert_eq!(outcomes[0], RowOutcome::Miss);
        assert!(outcomes[1..].iter().all(|&o| o == RowOutcome::Hit));
        // 64 hits back to back: bus-bound, ~burst cycles apiece.
        assert!(ch.stats.bus_utilization() > 0.5, "util {}", ch.stats.bus_utilization());
    }

    #[test]
    fn alternating_rows_same_bank_conflict() {
        let spec = DramSpec::ddr4_2400(1);
        let mapper = AddressMapper::new(&spec);
        let mut ch = Channel::new(spec);
        // two addresses in the same bank, different rows
        let a = 0u64;
        let stride_to_same_bank_next_row = {
            // row is the top field; step one full row-of-all-banks block
            let lines = spec.lines_per_row()
                * spec.ranks as u64
                * spec.banks() as u64;
            lines * CACHE_LINE
        };
        let b = a + stride_to_same_bank_next_row;
        let da = mapper.decode(a);
        let db = mapper.decode(b);
        assert_eq!(da.flat_bank, db.flat_bank);
        assert_ne!(da.row, db.row);
        for i in 0..10 {
            ch.enqueue(read(if i % 2 == 0 { a } else { b }, i), i * 1000);
        }
        let mut conflicts = 0;
        while let Some(s) = ch.service_one() {
            if s.outcome == RowOutcome::Conflict {
                conflicts += 1;
            }
        }
        assert!(conflicts >= 8, "conflicts {conflicts}");
    }

    #[test]
    fn random_access_slower_than_sequential() {
        let spec = DramSpec::ddr4_2400(1);
        let mut seq = Channel::new(spec);
        let mut rnd = Channel::new(spec);
        let n = 512u64;
        let mut rng = crate::util::rng::Rng::new(5);
        for i in 0..n {
            seq.enqueue(read(i * CACHE_LINE, i), 0);
            let r = rng.next_below(spec.channel_bytes / CACHE_LINE) * CACHE_LINE;
            rnd.enqueue(read(r, i), 0);
        }
        while seq.service_one().is_some() {}
        while rnd.service_one().is_some() {}
        assert!(
            rnd.stats.finish_cycle > 2 * seq.stats.finish_cycle,
            "rnd {} seq {}",
            rnd.stats.finish_cycle,
            seq.stats.finish_cycle
        );
    }

    #[test]
    fn completion_latency_at_least_cas() {
        let spec = DramSpec::ddr3_1600(1, 1);
        let mut ch = Channel::new(spec);
        ch.enqueue(read(0, 0), 100);
        let s = ch.service_one().unwrap();
        // Miss: ACT + tRCD + CL + burst
        let sp = spec.speed;
        assert!(s.done_at >= 100 + sp.trcd + sp.cl + sp.burst);
    }

    #[test]
    fn read_to_write_bus_turnaround_enforced() {
        // Regression (PR 5): the read→write bubble the cas_ready
        // comment promised was never added — a write burst could start
        // the exact cycle the preceding read burst ended. The write's
        // burst must now start at least one cycle after the read
        // burst's end (pre-fix this asserts r.done_at + burst, which
        // is one cycle short).
        for spec in [DramSpec::ddr3_2133(1), DramSpec::ddr4_2400(1), DramSpec::hbm_1000(1)] {
            let mut ch = Channel::new(spec);
            ch.enqueue(read(0, 0), 0);
            ch.enqueue(write(64, 1), 0); // same row: CAS-limited, not ACT-limited
            let r = ch.service_one().unwrap();
            assert_eq!(r.kind, MemKind::Read);
            let w = ch.service_one().unwrap();
            assert_eq!(w.kind, MemKind::Write);
            assert!(
                w.done_at >= r.done_at + spec.speed.burst + 1,
                "{:?}: write burst [{}..{}] must not abut read burst end {}",
                spec.standard,
                w.done_at - spec.speed.burst,
                w.done_at,
                r.done_at
            );
        }
    }

    #[test]
    fn write_to_write_needs_no_turnaround_bubble() {
        // The bubble is a bus *direction* penalty: back-to-back write
        // bursts may still abut. Cross bank groups so tCCD_S (= burst
        // occupancy on DDR4) is the only CAS spacing in play.
        let spec = DramSpec::ddr4_2400(1);
        let far = spec.lines_per_row() * spec.ranks as u64 * spec.banks_per_group as u64
            * CACHE_LINE; // next bank group under RoBaRaCoCh
        let mut ch = Channel::new(spec);
        ch.enqueue(write(0, 0), 0);
        ch.enqueue(write(far, 1), 0);
        let a = ch.service_one().unwrap();
        let b = ch.service_one().unwrap();
        assert_eq!(
            b.done_at,
            a.done_at + spec.speed.burst,
            "same-direction bursts stay back to back"
        );
    }

    #[test]
    fn reset_matches_fresh_construction() {
        // Drive traffic, reset to a different spec, and replay a
        // workload against a genuinely fresh channel: every completion
        // and the stats roll-up must be identical.
        let mut reused = Channel::new(DramSpec::ddr4_2400(1));
        for i in 0..64u64 {
            reused.enqueue(read(i * CACHE_LINE, i), i * 3);
        }
        while reused.service_one().is_some() {}
        let target = DramSpec::hbm_1000(1);
        reused.reset(target, DramPolicy::default());
        let mut fresh = Channel::new(target);
        let mut rng = crate::util::rng::Rng::new(0xC0FFEE);
        for i in 0..200u64 {
            let addr = rng.next_below(1 << 20) * CACHE_LINE;
            let at = rng.next_below(5_000);
            let req = if i % 3 == 0 { write(addr, i) } else { read(addr, i) };
            reused.enqueue(req, at);
            fresh.enqueue(req, at);
        }
        loop {
            match (reused.service_one(), fresh.service_one()) {
                (None, None) => break,
                (Some(a), Some(b)) => {
                    assert_eq!(a.tag, b.tag);
                    assert_eq!(a.done_at, b.done_at);
                    assert_eq!(a.outcome, b.outcome);
                }
                _ => panic!("one channel finished early"),
            }
        }
        assert_eq!(reused.stats, fresh.stats);
    }

    #[test]
    fn writes_tracked_separately() {
        let spec = DramSpec::ddr4_2400(1);
        let mut ch = Channel::new(spec);
        ch.enqueue(write(0, 0), 0);
        ch.enqueue(read(64, 1), 0);
        while ch.service_one().is_some() {}
        assert_eq!(ch.stats.writes, 1);
        assert_eq!(ch.stats.reads, 1);
        // Region attribution follows the request tags.
        assert_eq!(ch.stats.region_requests(crate::trace::Region::Edges), 1);
        assert_eq!(ch.stats.region_requests(crate::trace::Region::Updates), 1);
    }

    #[test]
    fn frfcfs_prefers_row_hit() {
        let spec = DramSpec::ddr4_2400(1);
        let mut ch = Channel::new(spec);
        // Open row 0 via first request; then queue a conflicting row and a hit.
        ch.enqueue(read(0, 0), 0);
        let s0 = ch.service_one().unwrap();
        assert_eq!(s0.outcome, RowOutcome::Miss);
        let far = spec.lines_per_row() * spec.ranks as u64 * spec.banks() as u64 * CACHE_LINE;
        ch.enqueue(read(far, 1), 10); // conflict, arrives first (older seq)
        ch.enqueue(read(64, 2), 10); // hit on open row
        let s1 = ch.service_one().unwrap();
        assert_eq!(s1.tag, 2, "row hit should be served first");
        assert_eq!(s1.outcome, RowOutcome::Hit);
    }

    #[test]
    fn refresh_fires() {
        let spec = DramSpec::ddr4_2400(1);
        let mut ch = Channel::new(spec);
        // Request arriving long after several tREFI periods.
        ch.enqueue(read(0, 0), spec.speed.trefi * 3 + 5);
        ch.service_one().unwrap();
        assert!(ch.stats.refreshes >= 3);
    }

    #[test]
    fn closed_page_never_hits_or_conflicts() {
        let policy = DramPolicy {
            row: RowPolicy::ClosedPage,
            ..Default::default()
        };
        let mut ch = Channel::with_policy(DramSpec::ddr4_2400(1), policy);
        for i in 0..128u64 {
            ch.enqueue(read(i * CACHE_LINE, i), 0);
        }
        while ch.service_one().is_some() {}
        assert_eq!(ch.stats.row_hits, 0);
        assert_eq!(ch.stats.row_conflicts, 0);
        assert_eq!(ch.stats.row_misses, 128);
    }

    #[test]
    fn closed_page_slower_on_sequential() {
        let mut open = Channel::new(DramSpec::ddr4_2400(1));
        let closed = DramPolicy {
            row: RowPolicy::ClosedPage,
            ..Default::default()
        };
        let mut cl = Channel::with_policy(DramSpec::ddr4_2400(1), closed);
        for i in 0..512u64 {
            open.enqueue(read(i * CACHE_LINE, i), 0);
            cl.enqueue(read(i * CACHE_LINE, i), 0);
        }
        while open.service_one().is_some() {}
        while cl.service_one().is_some() {}
        assert!(
            cl.stats.finish_cycle > open.stats.finish_cycle,
            "closed {} !> open {}",
            cl.stats.finish_cycle,
            open.stats.finish_cycle
        );
    }

    #[test]
    fn fcfs_ignores_row_hits() {
        let policy = DramPolicy {
            sched: SchedPolicy::Fcfs,
            ..Default::default()
        };
        let mut ch = Channel::with_policy(DramSpec::ddr4_2400(1), policy);
        ch.enqueue(read(0, 0), 0);
        ch.service_one().unwrap();
        let far = DramSpec::ddr4_2400(1).lines_per_row()
            * DramSpec::ddr4_2400(1).banks() as u64
            * CACHE_LINE;
        ch.enqueue(read(far, 1), 10); // older, conflicts
        ch.enqueue(read(64, 2), 10); // newer, would hit
        let s = ch.service_one().unwrap();
        assert_eq!(s.tag, 1, "FCFS must serve strictly in order");
    }

    #[test]
    fn bank_interleaved_map_improves_sequential_utilization() {
        // open challenge (b): bank-group-low mapping turns a tCCD_L-
        // bound sequential stream into a tCCD_S-bound one. The effect
        // only exists under a bounded request window (an unbounded
        // FR-FCFS queue re-sorts the stream into same-bank hit runs),
        // so feed window-sized batches like the phase driver does.
        let run = |policy: DramPolicy| -> u64 {
            let mut ch = Channel::with_policy(DramSpec::ddr4_2400(1), policy);
            for batch in 0..128u64 {
                for i in 0..32u64 {
                    let idx = batch * 32 + i;
                    ch.enqueue(read(idx * CACHE_LINE, idx), 0);
                }
                while ch.service_one().is_some() {}
            }
            ch.stats.finish_cycle
        };
        let base = run(DramPolicy::default());
        let inter = run(DramPolicy {
            addr_map: crate::dram::AddrMap::BankInterleaved,
            ..Default::default()
        });
        assert!(
            inter < base * 9 / 10,
            "interleaved {inter} !< 0.9 x {base}"
        );
    }

    #[test]
    fn hbm_row_smaller_more_misses() {
        // Same sequential stream: HBM's 2KB rows (32 lines) force 4x the
        // activates of DDR4's 8KB rows (128 lines).
        let mut d4 = Channel::new(DramSpec::ddr4_2400(1));
        let mut hbm = Channel::new(DramSpec::hbm_1000(1));
        for i in 0..1024u64 {
            d4.enqueue(read(i * CACHE_LINE, i), 0);
            hbm.enqueue(read(i * CACHE_LINE, i), 0);
        }
        while d4.service_one().is_some() {}
        while hbm.service_one().is_some() {}
        let d4_act = d4.stats.row_misses + d4.stats.row_conflicts;
        let hbm_act = hbm.stats.row_misses + hbm.stats.row_conflicts;
        assert!(hbm_act >= 3 * d4_act, "hbm {hbm_act} ddr4 {d4_act}");
    }
}
