//! DRAM statistics: the observable quantities of the paper's
//! evaluation — row buffer outcome mix (Fig. 11(b)), data-bus busy
//! cycles for bandwidth utilization, request counts and latencies,
//! and serviced-request counts per [`Region`] (the controller-side
//! half of the traffic attribution; the issue-order pattern analysis
//! lives in [`crate::trace`]).

use crate::trace::Region;

/// How a request was served by the row buffer (§2.1 scenarios 1-3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RowOutcome {
    /// Addressed row already in the row buffer.
    Hit,
    /// Row buffer empty: activate then serve.
    Miss,
    /// Different row present: precharge, activate, serve.
    Conflict,
}

/// Aggregated statistics for one channel (or a roll-up of channels).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct DramStats {
    pub reads: u64,
    pub writes: u64,
    pub row_hits: u64,
    pub row_misses: u64,
    pub row_conflicts: u64,
    /// Clock cycles the data bus carried a burst.
    pub data_bus_cycles: u64,
    /// Refresh operations performed.
    pub refreshes: u64,
    /// Sum of request latencies (arrival -> data done), cycles.
    pub total_latency: u64,
    /// Final completion time (cycles) — simulation makespan.
    pub finish_cycle: u64,
    /// Serviced reads per [`Region`] (indexed by [`Region::index`]).
    pub region_reads: [u64; Region::COUNT],
    /// Serviced writes per [`Region`].
    pub region_writes: [u64; Region::COUNT],
    /// Fault-injection events that fired (see [`super::fault`]).
    pub faults_injected: u64,
    /// Total completion delay injected by faults, cycles.
    pub fault_delay_cycles: u64,
}

impl DramStats {
    pub fn requests(&self) -> u64 {
        self.reads + self.writes
    }

    pub fn record(&mut self, outcome: RowOutcome) {
        match outcome {
            RowOutcome::Hit => self.row_hits += 1,
            RowOutcome::Miss => self.row_misses += 1,
            RowOutcome::Conflict => self.row_conflicts += 1,
        }
    }

    /// Count one serviced request against its region.
    pub fn record_region(&mut self, region: Region, is_write: bool) {
        if is_write {
            self.region_writes[region.index()] += 1;
        } else {
            self.region_reads[region.index()] += 1;
        }
    }

    /// Serviced requests (reads + writes) attributed to `region`.
    pub fn region_requests(&self, region: Region) -> u64 {
        self.region_reads[region.index()] + self.region_writes[region.index()]
    }

    /// Fraction of cycles the data bus was busy, i.e. achieved /
    /// theoretical bandwidth (what Fig. 11(b) plots).
    pub fn bus_utilization(&self) -> f64 {
        if self.finish_cycle == 0 {
            return 0.0;
        }
        self.data_bus_cycles as f64 / self.finish_cycle as f64
    }

    pub fn hit_rate(&self) -> f64 {
        let n = self.requests();
        if n == 0 {
            return 0.0;
        }
        self.row_hits as f64 / n as f64
    }

    pub fn avg_latency(&self) -> f64 {
        let n = self.requests();
        if n == 0 {
            return 0.0;
        }
        self.total_latency as f64 / n as f64
    }

    /// Merge another channel's stats into a roll-up. `finish_cycle`
    /// takes the max (channels run concurrently).
    pub fn merge(&mut self, other: &DramStats) {
        self.reads += other.reads;
        self.writes += other.writes;
        self.row_hits += other.row_hits;
        self.row_misses += other.row_misses;
        self.row_conflicts += other.row_conflicts;
        self.data_bus_cycles += other.data_bus_cycles;
        self.refreshes += other.refreshes;
        self.total_latency += other.total_latency;
        self.finish_cycle = self.finish_cycle.max(other.finish_cycle);
        for i in 0..Region::COUNT {
            self.region_reads[i] += other.region_reads[i];
            self.region_writes[i] += other.region_writes[i];
        }
        self.faults_injected += other.faults_injected;
        self.fault_delay_cycles += other.fault_delay_cycles;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outcome_accounting() {
        let mut s = DramStats::default();
        s.record(RowOutcome::Hit);
        s.record(RowOutcome::Hit);
        s.record(RowOutcome::Miss);
        s.record(RowOutcome::Conflict);
        assert_eq!(s.row_hits, 2);
        assert_eq!(s.row_misses, 1);
        assert_eq!(s.row_conflicts, 1);
    }

    #[test]
    fn utilization_and_merge() {
        let mut a = DramStats {
            data_bus_cycles: 50,
            finish_cycle: 100,
            reads: 10,
            ..Default::default()
        };
        assert!((a.bus_utilization() - 0.5).abs() < 1e-12);
        let b = DramStats {
            data_bus_cycles: 30,
            finish_cycle: 200,
            writes: 5,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.finish_cycle, 200);
        assert_eq!(a.data_bus_cycles, 80);
        assert_eq!(a.requests(), 15);
    }

    #[test]
    fn empty_stats_are_safe() {
        let s = DramStats::default();
        assert_eq!(s.bus_utilization(), 0.0);
        assert_eq!(s.hit_rate(), 0.0);
        assert_eq!(s.avg_latency(), 0.0);
        for r in Region::all() {
            assert_eq!(s.region_requests(r), 0);
        }
    }

    #[test]
    fn region_accounting_and_merge() {
        let mut a = DramStats::default();
        a.record_region(Region::Edges, false);
        a.record_region(Region::Edges, false);
        a.record_region(Region::Updates, true);
        let mut b = DramStats::default();
        b.record_region(Region::Edges, true);
        a.merge(&b);
        assert_eq!(a.region_requests(Region::Edges), 3);
        assert_eq!(a.region_reads[Region::Edges.index()], 2);
        assert_eq!(a.region_writes[Region::Edges.index()], 1);
        assert_eq!(a.region_requests(Region::Updates), 1);
        assert_eq!(a.region_requests(Region::Vertices), 0);
    }
}
