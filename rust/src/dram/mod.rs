//! Cycle-level DRAM timing simulator — the Ramulator-equivalent
//! substrate of the paper's simulation environment (§2.2, Fig. 1).
//!
//! Scope: everything the paper's evaluation observes —
//! * DDR3 / DDR4 / HBM standards with the Tab. 3 configurations,
//! * multi-channel, multi-rank organization, DDR4/HBM bank groups,
//! * open-page row-buffer policy with FR-FCFS scheduling,
//! * row hit / miss (empty) / conflict accounting (Fig. 11(b)),
//! * data-bus occupancy for bandwidth-utilization reporting,
//! * periodic refresh (tREFI / tRFC),
//! * per-region serviced-request accounting and optional issue-order
//!   tracing / streaming pattern analysis (see [`crate::trace`]).
//!
//! The model is *transactional*: commands are not replayed cycle by
//! cycle; instead each serviced request computes its earliest legal
//! CAS issue time from the JEDEC-style timing state of its bank, rank
//! and channel, then updates that state. This is first-order exact for
//! the constraint set we model and orders of magnitude faster than
//! per-cycle ticking — see DESIGN.md §5(3).

pub mod address;
pub mod channel;
pub mod fault;
pub mod spec;
pub mod stats;
pub mod system;

pub use address::{AddressMapper, DecodedAddr};
pub use channel::Channel;
pub use fault::{ChannelDegrade, FaultPlan, LatencySpikes, TransientRetries};
pub use spec::{
    AddrMap, DramPolicy, DramSpec, DramStandard, MemTech, RowPolicy, SchedPolicy, SpeedGrade,
};
pub use stats::{DramStats, RowOutcome};
pub use system::{ChannelMode, MemKind, MemRequest, MemorySystem, ReqToken, ServiceOrder};

/// Cache-line size in bytes. All modelled requests are line-granular
/// (the paper's "64 bytes are returned for each request which we call
/// a cache line").
pub const CACHE_LINE: u64 = 64;
