//! Multi-channel memory system facade.
//!
//! Routes line requests to channels by address, services them in
//! (approximate) global time order, and exposes completions for the
//! co-simulation driver. The paper's HitGraph model merges PE request
//! streams round-robin because Ramulator has a single endpoint; here
//! every channel is an independent endpoint, which matches the
//! hardware more closely while preserving the same per-channel
//! ordering.

use super::channel::{Channel, Serviced};
use super::fault;
use super::spec::{DramPolicy, DramSpec};
use super::stats::DramStats;
use crate::trace::{AccessPatternAnalyzer, AccessPatternSummary, Region, TraceEvent};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Read or write.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemKind {
    Read,
    Write,
}

/// A cache-line request. `tag` is an opaque token the issuer uses to
/// route the completion callback; `region` attributes the request to
/// the data structure it belongs to (stamped at issue time by the
/// accelerator models — see [`crate::trace`]).
#[derive(Clone, Copy, Debug)]
pub struct MemRequest {
    pub addr: u64,
    pub kind: MemKind,
    pub tag: u64,
    pub region: Region,
}

/// Token identifying a completed request.
#[derive(Clone, Copy, Debug)]
pub struct ReqToken {
    pub tag: u64,
    pub kind: MemKind,
    pub channel: usize,
    pub done_at: u64,
}

/// Which completion-selection implementation retires queued requests.
/// Both select exactly the same request every time (min arrival, ties
/// by channel index) — the scan variant is the pre-heap reference kept
/// for equivalence tests and the `perf_hotpath` baseline.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ServiceOrder {
    /// O(log C) incrementally-maintained arrival heap (default).
    #[default]
    Heap,
    /// O(C) linear scan over every channel queue per request.
    Scan,
}

/// How byte addresses map to channels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChannelMode {
    /// Cache-line interleaving (Ramulator's default; single data
    /// structure striped across channels).
    InterleaveLine,
    /// Region mode: each channel owns a contiguous region of
    /// `channel_bytes`. HitGraph and ThunderGP explicitly place each
    /// partition's data structures on "their" channel (§3.2.3/3.2.4),
    /// which this mode expresses.
    Region,
}

impl ChannelMode {
    /// Rewrite a global byte address into the channel-local address
    /// space. The single definition shared by [`MemorySystem::enqueue`]
    /// and the trace analyzer — the bit-identical live-vs-trace
    /// analysis guarantee depends on both using exactly this rewrite.
    ///
    /// Region mode clamps the channel index exactly like
    /// [`MemorySystem::channel_of`] and subtracts that channel's base.
    /// (It used to wrap modulo `channel_bytes`, so an out-of-range
    /// address like `100 * channel_bytes` silently aliased onto the
    /// last channel's line 0 — colliding with a real address — while
    /// routing clamped; the two now agree, distinct out-of-range
    /// globals stay distinct, and [`MemorySystem::enqueue`]
    /// additionally `debug_assert!`s that Region-mode addresses are in
    /// range. In-range addresses are rewritten exactly as before.)
    #[inline]
    pub fn local_addr(self, addr: u64, channels: usize, channel_bytes: u64) -> u64 {
        match self {
            ChannelMode::InterleaveLine => {
                let line = addr / super::CACHE_LINE / channels as u64;
                line * super::CACHE_LINE
            }
            ChannelMode::Region => {
                let ch = (addr / channel_bytes).min(channels as u64 - 1);
                addr - ch * channel_bytes
            }
        }
    }
}

/// The full memory system: one controller per channel.
pub struct MemorySystem {
    spec: DramSpec,
    mode: ChannelMode,
    policy: DramPolicy,
    channels: Vec<Channel>,
    /// Event queue over per-channel earliest arrivals, with lazy
    /// invalidation: an entry `(a, ch)` is live iff channel `ch`'s
    /// cached earliest arrival is still exactly `a`. Invariant: every
    /// non-empty channel has a live entry, so a pop-until-live loop
    /// finds the global minimum in O(log C) instead of scanning every
    /// channel queue per serviced request.
    arrivals: BinaryHeap<Reverse<(u64, usize)>>,
    order: ServiceOrder,
    trace: Option<Vec<TraceEvent>>,
    analyzer: Option<AccessPatternAnalyzer>,
}

impl MemorySystem {
    pub fn new(spec: DramSpec) -> Self {
        Self::with_mode(spec, ChannelMode::InterleaveLine)
    }

    pub fn with_mode(spec: DramSpec, mode: ChannelMode) -> Self {
        Self::with_mode_and_policy(spec, mode, DramPolicy::default())
    }

    /// Full control: channel mode + controller policy bundle
    /// (scheduling, row policy, address mapping — the ablation axes).
    pub fn with_mode_and_policy(spec: DramSpec, mode: ChannelMode, policy: DramPolicy) -> Self {
        MemorySystem {
            spec,
            mode,
            policy,
            channels: (0..spec.channels)
                .map(|_| Channel::with_policy(spec.with_channels(1), policy))
                .collect(),
            arrivals: BinaryHeap::new(),
            order: ServiceOrder::Heap,
            trace: None,
            analyzer: None,
        }
    }

    /// Select the completion-selection implementation for every
    /// subsequent `service_*` call. [`ServiceOrder::Scan`] reroutes
    /// [`MemorySystem::service_one`] and [`MemorySystem::service_until`]
    /// through the linear-scan reference — bit-identical results, kept
    /// switchable so whole simulations can be replayed under the
    /// reference selector (see `tests/heap_scan_c32.rs`).
    pub fn set_service_order(&mut self, order: ServiceOrder) {
        self.order = order;
    }

    /// The active completion-selection implementation.
    pub fn service_order(&self) -> ServiceOrder {
        self.order
    }

    /// Install (or clear, with `None`) a deterministic fault plan:
    /// every channel gets a [`fault::FaultLane`] seeded with its
    /// global channel index, so the injected delays are a pure
    /// function of `(plan, channel, per-channel serviced count)` —
    /// independent of the completion selector. [`MemorySystem::reset`]
    /// clears lanes (via [`Channel`] reset); the spec layer re-installs
    /// them per run.
    pub fn set_faults(&mut self, plan: Option<&fault::FaultPlan>) {
        let plan = plan.filter(|p| !p.is_noop());
        for (i, ch) in self.channels.iter_mut().enumerate() {
            ch.set_fault_lane(plan.map(|p| fault::FaultLane::new(p.clone(), i)));
        }
    }

    /// Reconfigure in place for a (possibly different) spec / channel
    /// mode / policy, retaining every channel's queue and bank
    /// allocations — the per-worker reuse hook behind
    /// [`crate::sim::RunScratch`]. Logically identical to
    /// `*self = MemorySystem::with_mode_and_policy(spec, mode, policy)`
    /// (tracing and the attached analyzer are dropped too); the sweep
    /// equivalence tests assert bit-identical behavior.
    pub fn reset(&mut self, spec: DramSpec, mode: ChannelMode, policy: DramPolicy) {
        self.spec = spec;
        self.mode = mode;
        self.policy = policy;
        let per = spec.with_channels(1);
        self.channels.truncate(spec.channels);
        for ch in &mut self.channels {
            ch.reset(per, policy);
        }
        while self.channels.len() < spec.channels {
            self.channels.push(Channel::with_policy(per, policy));
        }
        self.arrivals.clear();
        self.order = ServiceOrder::Heap;
        self.trace = None;
        self.analyzer = None;
    }

    /// Start recording every enqueued request (addresses are the
    /// global, pre-routing addresses). Costs memory; off by default.
    pub fn enable_trace(&mut self) {
        self.trace = Some(Vec::new());
    }

    /// Attach a streaming [`AccessPatternAnalyzer`] matched to this
    /// system's spec, channel mode and address mapping. Every
    /// subsequently enqueued request is fed through it — no trace
    /// buffer required. Collect the result with
    /// [`MemorySystem::take_pattern_summary`].
    pub fn attach_analyzer(&mut self) {
        self.analyzer = Some(AccessPatternAnalyzer::with_addr_map(
            self.spec,
            self.mode,
            self.policy.addr_map,
        ));
    }

    /// Detach the analyzer (if any) and return its summary.
    pub fn take_pattern_summary(&mut self) -> Option<AccessPatternSummary> {
        self.analyzer.take().map(AccessPatternAnalyzer::finish)
    }

    /// The recorded trace, if tracing was enabled.
    pub fn trace(&self) -> Option<&[TraceEvent]> {
        self.trace.as_deref()
    }

    /// Detach and return the recorded trace (if tracing was enabled).
    pub fn take_trace(&mut self) -> Option<Vec<TraceEvent>> {
        self.trace.take()
    }

    /// Write the trace in the text format of [`crate::trace::record`]:
    /// `<hex addr> <R|W> <arrival> <channel> <region>` per line.
    pub fn write_trace(&self, w: impl std::io::Write) -> std::io::Result<u64> {
        let Some(trace) = &self.trace else {
            return Ok(0);
        };
        crate::trace::write_events(w, trace)
    }

    /// Base byte address of channel `c`'s region (Region mode).
    pub fn region_base(&self, c: usize) -> u64 {
        c as u64 * self.spec.channel_bytes
    }

    pub fn spec(&self) -> &DramSpec {
        &self.spec
    }

    pub fn num_channels(&self) -> usize {
        self.channels.len()
    }

    /// Which channel a byte address routes to.
    #[inline]
    pub fn channel_of(&self, addr: u64) -> usize {
        match self.mode {
            ChannelMode::InterleaveLine => {
                ((addr / super::CACHE_LINE) % self.channels.len() as u64) as usize
            }
            ChannelMode::Region => {
                ((addr / self.spec.channel_bytes) as usize).min(self.channels.len() - 1)
            }
        }
    }

    /// Enqueue a request. The address is rewritten into the channel-
    /// local address space.
    pub fn enqueue(&mut self, req: MemRequest, arrival: u64) {
        debug_assert!(
            self.mode != ChannelMode::Region
                || req.addr < self.spec.channel_bytes * self.channels.len() as u64,
            "Region-mode address {:#x} outside the {}-channel address space \
             ({:#x} bytes/channel)",
            req.addr,
            self.channels.len(),
            self.spec.channel_bytes
        );
        let ch = self.channel_of(req.addr);
        if self.trace.is_some() || self.analyzer.is_some() {
            let ev = TraceEvent {
                addr: req.addr,
                kind: req.kind,
                region: req.region,
                arrival,
                channel: ch,
            };
            if let Some(trace) = &mut self.trace {
                trace.push(ev);
            }
            if let Some(analyzer) = &mut self.analyzer {
                analyzer.observe(&ev);
            }
        }
        let local = MemRequest {
            addr: self
                .mode
                .local_addr(req.addr, self.channels.len(), self.spec.channel_bytes),
            ..req
        };
        let before = self.channels[ch].earliest_arrival();
        self.channels[ch].enqueue(local, arrival);
        let after = self.channels[ch].earliest_arrival();
        // A new heap entry is needed only when the channel's minimum
        // actually moved (first request, or an earlier arrival): the
        // previous live entry covers every other case.
        if after != before {
            self.arrivals.push(Reverse((arrival, ch)));
        }
    }

    /// Total queued requests.
    pub fn pending(&self) -> usize {
        self.channels.iter().map(|c| c.pending()).sum()
    }

    /// Queued requests on one channel.
    pub fn pending_on(&self, ch: usize) -> usize {
        self.channels[ch].pending()
    }

    /// Pop stale heap entries until the top is live; returns the
    /// channel holding the globally-earliest arrival (ties broken by
    /// channel index, matching a linear scan) without removing its
    /// entry. `None` when every channel is idle.
    fn earliest_channel(&mut self) -> Option<(u64, usize)> {
        while let Some(&Reverse((a, ch))) = self.arrivals.peek() {
            if self.channels[ch].earliest_arrival() == Some(a) {
                return Some((a, ch));
            }
            self.arrivals.pop(); // stale: the channel moved on
        }
        None
    }

    /// Service the live entry found by [`MemorySystem::earliest_channel`].
    fn service_channel(&mut self, ch: usize) -> ReqToken {
        self.arrivals.pop();
        let Serviced {
            tag,
            kind,
            done_at,
            outcome: _,
        } = self.channels[ch]
            .service_one()
            .expect("live heap entry implies a non-empty channel");
        if let Some(next) = self.channels[ch].earliest_arrival() {
            self.arrivals.push(Reverse((next, ch)));
        }
        ReqToken {
            tag,
            kind,
            channel: ch,
            done_at,
        }
    }

    /// Service one request from the channel whose oldest work is
    /// earliest (global-time approximation); returns its completion.
    /// O(log channels) via the incrementally-maintained arrival heap,
    /// unless [`MemorySystem::set_service_order`] routed selection
    /// through the scan reference.
    pub fn service_one(&mut self) -> Option<ReqToken> {
        if self.order == ServiceOrder::Scan {
            return self.service_one_scan();
        }
        let (_, ch) = self.earliest_channel()?;
        Some(self.service_channel(ch))
    }

    /// Reference completion selection: a linear scan over every
    /// channel queue per request — the pre-heap implementation, kept
    /// for equivalence tests and as the `perf_hotpath` baseline
    /// comparison. Selects exactly the request
    /// [`MemorySystem::service_one`] would (min arrival, ties by
    /// channel index); the two can be freely interleaved.
    pub fn service_one_scan(&mut self) -> Option<ReqToken> {
        let ch = self
            .channels
            .iter()
            .enumerate()
            .filter_map(|(i, c)| c.earliest_arrival().map(|a| (a, i)))
            .min()
            .map(|(_, i)| i)?;
        let Serviced {
            tag,
            kind,
            done_at,
            outcome: _,
        } = self.channels[ch].service_one()?;
        // Keep the heap invariant: the channel's old live entry is now
        // stale (lazily discarded); publish its new minimum.
        if let Some(next) = self.channels[ch].earliest_arrival() {
            self.arrivals.push(Reverse((next, ch)));
        }
        Some(ReqToken {
            tag,
            kind,
            channel: ch,
            done_at,
        })
    }

    /// Batch servicing: complete every queued request whose selection
    /// arrival is `<= horizon`, invoking `on_token` per completion in
    /// exactly the order [`MemorySystem::service_one`] would have
    /// produced. Returns the latest completion cycle seen (0 if none
    /// serviced). `horizon = u64::MAX` drains everything — the phase
    /// driver uses that to retire a phase's tail in one call instead
    /// of ping-ponging per request.
    pub fn service_until(&mut self, horizon: u64, mut on_token: impl FnMut(ReqToken)) -> u64 {
        if self.order == ServiceOrder::Scan {
            return self.service_until_scan(horizon, on_token);
        }
        let mut last = 0;
        while let Some((a, ch)) = self.earliest_channel() {
            if a > horizon {
                break;
            }
            let tok = self.service_channel(ch);
            last = last.max(tok.done_at);
            on_token(tok);
        }
        last
    }

    /// [`MemorySystem::service_until`] with scan selection: each
    /// iteration re-derives the global minimum by linear scan (tie
    /// broken by channel index, matching the heap path exactly).
    fn service_until_scan(&mut self, horizon: u64, mut on_token: impl FnMut(ReqToken)) -> u64 {
        let mut last = 0;
        loop {
            let Some((a, _)) = self
                .channels
                .iter()
                .enumerate()
                .filter_map(|(i, c)| c.earliest_arrival().map(|arr| (arr, i)))
                .min()
            else {
                break;
            };
            if a > horizon {
                break;
            }
            let tok = self
                .service_one_scan()
                .expect("selection just saw a non-empty channel");
            last = last.max(tok.done_at);
            on_token(tok);
        }
        last
    }

    /// Drain everything; returns the completion time of the last
    /// request (makespan in cycles).
    pub fn drain(&mut self) -> u64 {
        self.service_until(u64::MAX, |_| {})
    }

    /// Current makespan across channels.
    pub fn finish_cycle(&self) -> u64 {
        self.channels
            .iter()
            .map(|c| c.stats.finish_cycle)
            .max()
            .unwrap_or(0)
    }

    /// Roll-up of all channel stats.
    pub fn stats(&self) -> DramStats {
        let mut s = DramStats::default();
        for c in &self.channels {
            s.merge(&c.stats);
        }
        s
    }

    /// Per-channel stats (for scalability studies).
    pub fn channel_stats(&self, ch: usize) -> &DramStats {
        &self.channels[ch].stats
    }

    /// Makespan in seconds.
    pub fn elapsed_seconds(&self) -> f64 {
        self.finish_cycle() as f64 * self.spec.seconds_per_cycle()
    }

    /// Aggregate bus utilization: busy data cycles / (makespan x channels).
    pub fn utilization(&self) -> f64 {
        let fin = self.finish_cycle();
        if fin == 0 {
            return 0.0;
        }
        let busy: u64 = self.channels.iter().map(|c| c.stats.data_bus_cycles).sum();
        busy as f64 / (fin as f64 * self.channels.len() as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::CACHE_LINE;

    #[test]
    fn routes_by_line_interleaving() {
        let sys = MemorySystem::new(DramSpec::ddr4_2400(4));
        assert_eq!(sys.channel_of(0), 0);
        assert_eq!(sys.channel_of(64), 1);
        assert_eq!(sys.channel_of(128), 2);
        assert_eq!(sys.channel_of(256), 0);
    }

    #[test]
    fn all_requests_complete() {
        let mut sys = MemorySystem::new(DramSpec::ddr4_2400(2));
        for i in 0..100u64 {
            sys.enqueue(
                MemRequest {
                    addr: i * CACHE_LINE,
                    kind: MemKind::Read,
                    tag: i,
                    region: Region::Edges,
                },
                0,
            );
        }
        let mut seen = vec![false; 100];
        while let Some(t) = sys.service_one() {
            assert!(!seen[t.tag as usize]);
            seen[t.tag as usize] = true;
        }
        assert!(seen.iter().all(|&b| b));
        assert_eq!(sys.stats().requests(), 100);
    }

    #[test]
    fn more_channels_finish_sooner_on_sequential_stream() {
        let mut one = MemorySystem::new(DramSpec::ddr4_2400(1));
        let mut four = MemorySystem::new(DramSpec::ddr4_2400(4));
        for i in 0..4096u64 {
            let r = MemRequest {
                addr: i * CACHE_LINE,
                kind: MemKind::Read,
                tag: i,
                region: Region::Edges,
            };
            one.enqueue(r, 0);
            four.enqueue(r, 0);
        }
        let t1 = one.drain();
        let t4 = four.drain();
        assert!(
            (t1 as f64) / (t4 as f64) > 3.0,
            "1ch {t1} vs 4ch {t4}: expected ~4x"
        );
    }

    #[test]
    fn region_mode_routes_by_region() {
        let spec = DramSpec::ddr4_2400(4);
        let sys = MemorySystem::with_mode(spec, ChannelMode::Region);
        assert_eq!(sys.channel_of(0), 0);
        assert_eq!(sys.channel_of(spec.channel_bytes), 1);
        assert_eq!(sys.channel_of(3 * spec.channel_bytes + 4096), 3);
        // out-of-range clamps to the last channel
        assert_eq!(sys.channel_of(100 * spec.channel_bytes), 3);
    }

    #[test]
    fn region_mode_out_of_range_no_longer_aliases() {
        // Regression (PR 5): `channel_of` clamps out-of-range
        // addresses to the last channel while `local_addr` wrapped
        // them modulo `channel_bytes` — so 100 * channel_bytes landed
        // on channel N-1's *line 0*, colliding with the genuine
        // address 3 * channel_bytes. Both now clamp: distinct
        // out-of-range globals rewrite to distinct local addresses,
        // none of which collide with in-range ones.
        let spec = DramSpec::ddr4_2400(4);
        let cb = spec.channel_bytes;
        let n = 4usize;
        let local = |addr: u64| ChannelMode::Region.local_addr(addr, n, cb);
        // In-range addresses are rewritten exactly as before (the
        // in-sim bit-identity guarantee).
        assert_eq!(local(0), 0);
        assert_eq!(local(3 * cb + 4096), 4096);
        // The seed bug: 100 * cb wrapped onto local 0 == local(3 * cb).
        assert_ne!(local(100 * cb), local(3 * cb));
        assert_eq!(local(100 * cb), 97 * cb);
        // Distinct out-of-range globals stay distinct.
        assert_ne!(local(100 * cb), local(101 * cb));
        assert_ne!(local(100 * cb), local(100 * cb + CACHE_LINE));
        // And routing agrees with the rewrite's clamped channel.
        let sys = MemorySystem::with_mode(spec, ChannelMode::Region);
        assert_eq!(sys.channel_of(100 * cb), 3);
        assert_eq!(local(100 * cb), 100 * cb - 3 * cb);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "outside the")]
    fn region_mode_enqueue_rejects_out_of_range_in_debug() {
        let spec = DramSpec::ddr4_2400(2);
        let mut sys = MemorySystem::with_mode(spec, ChannelMode::Region);
        sys.enqueue(
            MemRequest {
                addr: 100 * spec.channel_bytes,
                kind: MemKind::Read,
                tag: 0,
                region: Region::Vertices,
            },
            0,
        );
    }

    #[test]
    fn reset_system_matches_fresh_construction() {
        // Drive a 2-channel DDR4 system, reset it to 4-channel HBM in
        // Region mode, and replay a workload against a genuinely fresh
        // system: identical completions and stats.
        let mut reused = MemorySystem::new(DramSpec::ddr4_2400(2));
        reused.enable_trace();
        for i in 0..64u64 {
            reused.enqueue(
                MemRequest {
                    addr: i * CACHE_LINE,
                    kind: MemKind::Read,
                    tag: i,
                    region: Region::Edges,
                },
                0,
            );
        }
        reused.drain();
        let target = DramSpec::hbm_1000(4);
        reused.reset(target, ChannelMode::Region, DramPolicy::default());
        assert!(reused.trace().is_none(), "reset drops tracing state");
        assert_eq!(reused.pending(), 0);
        let mut fresh = MemorySystem::with_mode(target, ChannelMode::Region);
        let mut rng = crate::util::rng::Rng::new(0x5E7);
        for i in 0..300u64 {
            let ch = rng.next_below(4);
            let addr = ch * target.channel_bytes
                + rng.next_below(1 << 20) * CACHE_LINE;
            let req = MemRequest {
                addr,
                kind: if i % 4 == 0 { MemKind::Write } else { MemKind::Read },
                tag: i,
                region: Region::Updates,
            };
            let at = rng.next_below(10_000);
            reused.enqueue(req, at);
            fresh.enqueue(req, at);
        }
        loop {
            match (reused.service_one(), fresh.service_one()) {
                (None, None) => break,
                (Some(a), Some(b)) => {
                    assert_eq!(a.tag, b.tag);
                    assert_eq!(a.channel, b.channel);
                    assert_eq!(a.done_at, b.done_at);
                }
                _ => panic!("one system finished early"),
            }
        }
        assert_eq!(reused.stats(), fresh.stats());
        // A reset to *fewer* channels shrinks the fan-out too.
        reused.reset(DramSpec::ddr4_2400(1), ChannelMode::InterleaveLine, DramPolicy::default());
        assert_eq!(reused.num_channels(), 1);
    }

    #[test]
    fn region_mode_requests_complete() {
        let spec = DramSpec::ddr4_2400(2);
        let mut sys = MemorySystem::with_mode(spec, ChannelMode::Region);
        for i in 0..64u64 {
            sys.enqueue(
                MemRequest {
                    addr: sys.region_base((i % 2) as usize) + (i / 2) * CACHE_LINE,
                    kind: MemKind::Read,
                    tag: i,
                    region: Region::Vertices,
                },
                0,
            );
        }
        let mut count = 0;
        while sys.service_one().is_some() {
            count += 1;
        }
        assert_eq!(count, 64);
        assert_eq!(sys.channel_stats(0).requests(), 32);
        assert_eq!(sys.channel_stats(1).requests(), 32);
    }

    #[test]
    fn service_until_matches_service_one_order() {
        // The batch API must produce exactly the per-request sequence.
        let mk = || {
            let mut sys = MemorySystem::new(DramSpec::ddr4_2400(2));
            let mut rng = crate::util::rng::Rng::new(42);
            for i in 0..200u64 {
                sys.enqueue(
                    MemRequest {
                        addr: rng.next_below(1 << 20) * CACHE_LINE,
                        kind: MemKind::Read,
                        tag: i,
                        region: Region::Edges,
                    },
                    rng.next_below(5_000),
                );
            }
            sys
        };
        let mut one = mk();
        let mut seq_tags = Vec::new();
        let mut last_one = 0;
        while let Some(t) = one.service_one() {
            seq_tags.push(t.tag);
            last_one = last_one.max(t.done_at);
        }
        let mut batch = mk();
        let mut batch_tags = Vec::new();
        let last_batch = batch.service_until(u64::MAX, |t| batch_tags.push(t.tag));
        assert_eq!(seq_tags, batch_tags);
        assert_eq!(last_one, last_batch);
        assert_eq!(one.stats(), batch.stats());
    }

    #[test]
    fn service_until_respects_horizon() {
        let mut sys = MemorySystem::new(DramSpec::ddr4_2400(1));
        for i in 0..10u64 {
            sys.enqueue(
                MemRequest {
                    addr: i * CACHE_LINE,
                    kind: MemKind::Read,
                    tag: i,
                    region: Region::Edges,
                },
                i * 1_000,
            );
        }
        let mut served = 0u64;
        sys.service_until(4_999, |_| served += 1);
        assert_eq!(served, 5, "only arrivals <= horizon are retired");
        assert_eq!(sys.pending(), 5);
        assert!(sys.drain() > 0);
        assert_eq!(sys.pending(), 0);
    }

    #[test]
    fn heap_and_scan_selection_identical() {
        let mk = || {
            let mut sys = MemorySystem::new(DramSpec::ddr4_2400(4));
            let mut rng = crate::util::rng::Rng::new(7);
            for i in 0..300u64 {
                sys.enqueue(
                    MemRequest {
                        addr: rng.next_below(1 << 22) * CACHE_LINE,
                        kind: MemKind::Read,
                        tag: i,
                        region: Region::Edges,
                    },
                    rng.next_below(10_000),
                );
            }
            sys
        };
        let mut heap = mk();
        let mut scan = mk();
        loop {
            let a = heap.service_one();
            let b = scan.service_one_scan();
            match (a, b) {
                (None, None) => break,
                (Some(a), Some(b)) => {
                    assert_eq!(a.tag, b.tag);
                    assert_eq!(a.channel, b.channel);
                    assert_eq!(a.done_at, b.done_at);
                }
                _ => panic!("one path finished early"),
            }
        }
        assert_eq!(heap.stats(), scan.stats());
        // Interleaving both selectors on one system stays consistent.
        let mut both = mk();
        let mut n = 0;
        loop {
            let t = if n % 2 == 0 {
                both.service_one()
            } else {
                both.service_one_scan()
            };
            if t.is_none() {
                break;
            }
            n += 1;
        }
        assert_eq!(n, 300);
    }

    #[test]
    fn faults_are_selector_independent_and_cleared_on_reset() {
        use crate::dram::FaultPlan;
        let plan = FaultPlan::mixed(0xFA);
        let mk = |faulted: bool| {
            let mut sys = MemorySystem::new(DramSpec::ddr4_2400(4));
            if faulted {
                sys.set_faults(Some(&plan));
            }
            let mut rng = crate::util::rng::Rng::new(77);
            for i in 0..300u64 {
                sys.enqueue(
                    MemRequest {
                        addr: rng.next_below(1 << 22) * CACHE_LINE,
                        kind: if i % 5 == 0 { MemKind::Write } else { MemKind::Read },
                        tag: i,
                        region: Region::Edges,
                    },
                    rng.next_below(10_000),
                );
            }
            sys
        };
        // Identical selection and identical (faulted) timing under
        // both selectors: the injected delay keys on per-channel
        // serviced counts, which faults themselves never reorder.
        let mut heap = mk(true);
        let mut scan = mk(true);
        loop {
            match (heap.service_one(), scan.service_one_scan()) {
                (None, None) => break,
                (Some(a), Some(b)) => {
                    assert_eq!((a.tag, a.channel, a.done_at), (b.tag, b.channel, b.done_at));
                }
                _ => panic!("one path finished early"),
            }
        }
        assert_eq!(heap.stats(), scan.stats());
        assert!(heap.stats().faults_injected > 0, "plan must actually fire");
        assert!(heap.stats().fault_delay_cycles > 0);
        // The clean run services the same requests, no fault counters,
        // and finishes no later than the faulted one.
        let mut clean = mk(false);
        clean.drain();
        assert_eq!(clean.stats().requests(), heap.stats().requests());
        assert_eq!(clean.stats().faults_injected, 0);
        assert!(clean.stats().finish_cycle <= heap.stats().finish_cycle);
        // Reset clears lanes: a replay after reset is fault-free.
        heap.reset(DramSpec::ddr4_2400(4), ChannelMode::InterleaveLine, DramPolicy::default());
        let mut rng = crate::util::rng::Rng::new(77);
        for i in 0..300u64 {
            heap.enqueue(
                MemRequest {
                    addr: rng.next_below(1 << 22) * CACHE_LINE,
                    kind: if i % 5 == 0 { MemKind::Write } else { MemKind::Read },
                    tag: i,
                    region: Region::Edges,
                },
                rng.next_below(10_000),
            );
        }
        heap.drain();
        assert_eq!(heap.stats().faults_injected, 0, "reset must clear fault lanes");
    }

    #[test]
    fn scan_order_reroutes_every_service_entry_point() {
        // With `ServiceOrder::Scan` the heap entry points must behave
        // bit-identically — including `service_until`, the phase
        // driver's only servicing call.
        let mk = |order| {
            let mut sys = MemorySystem::new(DramSpec::ddr4_2400(4));
            sys.set_service_order(order);
            let mut rng = crate::util::rng::Rng::new(13);
            for i in 0..400u64 {
                sys.enqueue(
                    MemRequest {
                        addr: rng.next_below(1 << 22) * CACHE_LINE,
                        kind: if i % 3 == 0 { MemKind::Write } else { MemKind::Read },
                        tag: i,
                        region: Region::Updates,
                    },
                    rng.next_below(20_000),
                );
            }
            sys
        };
        let mut heap = mk(ServiceOrder::Heap);
        let mut scan = mk(ServiceOrder::Scan);
        assert_eq!(scan.service_order(), ServiceOrder::Scan);
        let mut heap_toks = Vec::new();
        let h_last = heap.service_until(u64::MAX, |t| heap_toks.push((t.tag, t.channel, t.done_at)));
        let mut scan_toks = Vec::new();
        let s_last = scan.service_until(u64::MAX, |t| scan_toks.push((t.tag, t.channel, t.done_at)));
        assert_eq!(heap_toks, scan_toks);
        assert_eq!(h_last, s_last);
        assert_eq!(heap.stats(), scan.stats());
        // `service_one` dispatches too, and reset restores the default.
        let mut one = mk(ServiceOrder::Scan);
        let mut n = 0;
        while one.service_one().is_some() {
            n += 1;
        }
        assert_eq!(n, 400);
        one.reset(DramSpec::ddr4_2400(1), ChannelMode::InterleaveLine, DramPolicy::default());
        assert_eq!(one.service_order(), ServiceOrder::Heap);
    }

    #[test]
    fn interleaved_enqueue_service_keeps_heap_live() {
        // Exercises lazy invalidation: enqueues that lower the minimum,
        // enqueues that don't, and services that leave duplicates.
        let mut sys = MemorySystem::new(DramSpec::ddr4_2400(2));
        let mut next_tag = 0u64;
        let mut enq = |sys: &mut MemorySystem, addr: u64, at: u64| {
            sys.enqueue(
                MemRequest {
                    addr,
                    kind: MemKind::Read,
                    tag: next_tag,
                    region: Region::Vertices,
                },
                at,
            );
            next_tag += 1;
        };
        enq(&mut sys, 0, 100);
        enq(&mut sys, 64, 100);
        enq(&mut sys, 0, 50); // lowers channel 0's min
        assert!(sys.service_one().is_some());
        enq(&mut sys, 128, 10); // channel 0 again, below everything
        enq(&mut sys, 192, 500);
        let mut count = 0;
        while sys.service_one().is_some() {
            count += 1;
        }
        assert_eq!(count, 4);
        assert_eq!(sys.stats().requests(), 5);
        assert_eq!(sys.pending(), 0);
    }

    #[test]
    fn trace_records_requests() {
        let mut sys = MemorySystem::new(DramSpec::ddr4_2400(2));
        sys.enable_trace();
        for i in 0..10u64 {
            sys.enqueue(
                MemRequest {
                    addr: i * CACHE_LINE,
                    kind: if i % 2 == 0 { MemKind::Read } else { MemKind::Write },
                    tag: i,
                    region: if i % 2 == 0 { Region::Edges } else { Region::Updates },
                },
                i * 5,
            );
        }
        sys.drain();
        let trace = sys.trace().unwrap();
        assert_eq!(trace.len(), 10);
        assert_eq!(trace[3].arrival, 15);
        assert_eq!(trace[1].kind, MemKind::Write);
        assert_eq!(trace[1].region, Region::Updates);
        let mut buf = Vec::new();
        let n = sys.write_trace(&mut buf).unwrap();
        assert_eq!(n, 10);
        let text = String::from_utf8(buf).unwrap();
        assert!(text.lines().count() == 10);
        assert!(text.contains("0x40 W 5 1 updates"), "{text}");
        // The written trace parses back to the recorded events.
        let parsed = crate::trace::parse_events(&text).unwrap();
        assert_eq!(parsed.as_slice(), sys.trace().unwrap());
    }

    #[test]
    fn trace_disabled_by_default() {
        let mut sys = MemorySystem::new(DramSpec::ddr4_2400(1));
        sys.enqueue(
            MemRequest {
                addr: 0,
                kind: MemKind::Read,
                tag: 0,
                region: Region::Payload,
            },
            0,
        );
        assert!(sys.trace().is_none());
        let mut buf = Vec::new();
        assert_eq!(sys.write_trace(&mut buf).unwrap(), 0);
    }

    #[test]
    fn attached_analyzer_summarizes_without_trace_buffer() {
        let mut sys = MemorySystem::new(DramSpec::ddr4_2400(2));
        sys.attach_analyzer();
        for i in 0..32u64 {
            sys.enqueue(
                MemRequest {
                    addr: i * CACHE_LINE,
                    kind: MemKind::Read,
                    tag: i,
                    region: Region::Edges,
                },
                0,
            );
        }
        sys.drain();
        assert!(sys.trace().is_none(), "analyzer must not allocate a trace");
        let summary = sys.take_pattern_summary().unwrap();
        assert_eq!(summary.region(Region::Edges).reads, 32);
        assert_eq!(summary.channels.len(), 2);
        assert_eq!(summary.channels[0].requests(), 16);
        // Detached: a second take yields nothing.
        assert!(sys.take_pattern_summary().is_none());
    }

    #[test]
    fn elapsed_seconds_scales_with_tck() {
        let mut sys = MemorySystem::new(DramSpec::ddr4_2400(1));
        sys.enqueue(
            MemRequest {
                addr: 0,
                kind: MemKind::Read,
                tag: 0,
                region: Region::Payload,
            },
            0,
        );
        sys.drain();
        let secs = sys.elapsed_seconds();
        assert!(secs > 0.0 && secs < 1e-6);
    }
}
