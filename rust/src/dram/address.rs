//! Physical address mapping: byte address -> (channel, rank, bank
//! group, bank, row, column).
//!
//! We use Ramulator's default `RoBaRaCoCh` order (row : bank : rank :
//! column : channel, MSB -> LSB): channels interleave at cache-line
//! granularity, a sequential stream walks the columns of one row
//! before moving to the next bank — the layout the paper's
//! "data structures lie adjacent in memory as plain arrays" assumption
//! interacts with.

use super::spec::{AddrMap, DramSpec};
use super::CACHE_LINE;

/// Decomposed request address.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DecodedAddr {
    pub channel: usize,
    pub rank: usize,
    pub bank_group: usize,
    pub bank: usize,
    /// Flat bank index within the channel (rank-major).
    pub flat_bank: usize,
    pub row: u64,
    /// Cache-line column within the row.
    pub column: u64,
}

/// Maps byte addresses to DRAM coordinates for a given spec.
#[derive(Clone, Debug)]
pub struct AddressMapper {
    channels: u64,
    ranks: u64,
    groups: u64,
    banks_per_group: u64,
    lines_per_row: u64,
    rows: u64,
    map: AddrMap,
}

impl AddressMapper {
    pub fn new(spec: &DramSpec) -> Self {
        Self::with_map(spec, AddrMap::RowBankColumn)
    }

    pub fn with_map(spec: &DramSpec, map: AddrMap) -> Self {
        AddressMapper {
            channels: spec.channels as u64,
            ranks: spec.ranks as u64,
            groups: spec.bank_groups as u64,
            banks_per_group: spec.banks_per_group as u64,
            lines_per_row: spec.lines_per_row(),
            rows: spec.rows_per_bank(),
            map,
        }
    }

    /// Decode a byte address. Addresses beyond capacity wrap on the row
    /// dimension (the simulation environment lays data structures out
    /// virtually; only relative locality matters).
    pub fn decode(&self, byte_addr: u64) -> DecodedAddr {
        let mut line = byte_addr / CACHE_LINE;
        let channel = (line % self.channels) as usize;
        line /= self.channels;
        let (rank, bank_group, bank, row, column);
        match self.map {
            AddrMap::RowBankColumn => {
                column = line % self.lines_per_row;
                line /= self.lines_per_row;
                rank = (line % self.ranks) as usize;
                line /= self.ranks;
                bank = (line % self.banks_per_group) as usize;
                line /= self.banks_per_group;
                bank_group = (line % self.groups) as usize;
                line /= self.groups;
                row = line % self.rows;
            }
            AddrMap::BankInterleaved => {
                // bank-group bits lowest: consecutive lines alternate
                // groups first (tCCD_S), then banks, then columns.
                bank_group = (line % self.groups) as usize;
                line /= self.groups;
                bank = (line % self.banks_per_group) as usize;
                line /= self.banks_per_group;
                rank = (line % self.ranks) as usize;
                line /= self.ranks;
                column = line % self.lines_per_row;
                line /= self.lines_per_row;
                row = line % self.rows;
            }
        }
        let flat_bank = rank * (self.groups * self.banks_per_group) as usize
            + bank_group * self.banks_per_group as usize
            + bank;
        DecodedAddr {
            channel,
            rank,
            bank_group,
            bank,
            flat_bank,
            row,
            column,
        }
    }

    /// Inverse of [`decode`] (for tests; assumes row < rows).
    pub fn encode(&self, d: &DecodedAddr) -> u64 {
        let mut line = d.row;
        match self.map {
            AddrMap::RowBankColumn => {
                line = line * self.groups + d.bank_group as u64;
                line = line * self.banks_per_group + d.bank as u64;
                line = line * self.ranks + d.rank as u64;
                line = line * self.lines_per_row + d.column;
            }
            AddrMap::BankInterleaved => {
                line = line * self.lines_per_row + d.column;
                line = line * self.ranks + d.rank as u64;
                line = line * self.banks_per_group + d.bank as u64;
                line = line * self.groups + d.bank_group as u64;
            }
        }
        line = line * self.channels + d.channel as u64;
        line * CACHE_LINE
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn sequential_lines_interleave_channels() {
        let spec = DramSpec::ddr4_2400(4);
        let m = AddressMapper::new(&spec);
        for i in 0..16u64 {
            let d = m.decode(i * CACHE_LINE);
            assert_eq!(d.channel as u64, i % 4);
        }
    }

    #[test]
    fn single_channel_sequential_walks_columns() {
        let spec = DramSpec::ddr4_2400(1);
        let m = AddressMapper::new(&spec);
        let lines = spec.lines_per_row();
        let first = m.decode(0);
        for c in 0..lines {
            let d = m.decode(c * CACHE_LINE);
            assert_eq!(d.row, first.row);
            assert_eq!(d.flat_bank, first.flat_bank);
            assert_eq!(d.column, c);
        }
        // next line leaves the bank (RoBaRaCoCh: bank above column)
        let next = m.decode(lines * CACHE_LINE);
        assert_ne!(next.flat_bank, first.flat_bank);
    }

    #[test]
    fn decode_encode_roundtrip() {
        for spec in [
            DramSpec::ddr3_1600(4, 2),
            DramSpec::ddr4_2400(2),
            DramSpec::hbm_1000(8),
        ] {
            let m = AddressMapper::new(&spec);
            let mut rng = Rng::new(11);
            for _ in 0..2000 {
                let addr = (rng.next_below(spec.channel_bytes * spec.channels as u64 / CACHE_LINE))
                    * CACHE_LINE;
                let d = m.decode(addr);
                assert_eq!(m.encode(&d), addr, "spec {:?} addr {addr}", spec.standard);
                assert!(d.channel < spec.channels);
                assert!(d.flat_bank < spec.banks_per_channel());
                assert!(d.column < spec.lines_per_row());
            }
        }
    }

    #[test]
    fn bank_interleaved_alternates_groups() {
        let spec = DramSpec::ddr4_2400(1);
        let m = AddressMapper::with_map(&spec, AddrMap::BankInterleaved);
        let d0 = m.decode(0);
        let d1 = m.decode(CACHE_LINE);
        assert_ne!(d0.bank_group, d1.bank_group, "consecutive lines switch groups");
        // round-trip holds under the alternate map too
        let mut rng = Rng::new(3);
        for _ in 0..500 {
            let addr = rng.next_below(spec.channel_bytes / CACHE_LINE) * CACHE_LINE;
            assert_eq!(m.encode(&m.decode(addr)), addr);
        }
    }

    #[test]
    fn flat_bank_is_dense_and_unique() {
        let spec = DramSpec::ddr3_1600(1, 2);
        let m = AddressMapper::new(&spec);
        let mut seen = vec![false; spec.banks_per_channel()];
        // walk one line in each (rank, bank) at column 0, row 0
        for rank in 0..spec.ranks {
            for bank in 0..spec.banks() {
                let d = DecodedAddr {
                    channel: 0,
                    rank,
                    bank_group: bank / spec.banks_per_group,
                    bank: bank % spec.banks_per_group,
                    flat_bank: 0, // ignored by encode
                    row: 3,
                    column: 5,
                };
                let rd = m.decode(m.encode(&d));
                assert!(!seen[rd.flat_bank], "duplicate flat bank");
                seen[rd.flat_bank] = true;
            }
        }
        assert!(seen.iter().all(|&x| x));
    }
}
