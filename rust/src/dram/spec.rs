//! DRAM standards, speed grades and organization presets (Tab. 3).
//!
//! Timing values are expressed in memory-controller clock cycles
//! (`tCK`). Data rate is 2x the clock (DDR), so a `BL=8` burst over an
//! 8n-prefetch 64-bit bus occupies `BL/2 = 4` clock cycles and moves 64
//! bytes — one cache line. HBM moves the same line in `BL=4` over its
//! 128-bit channel (4n prefetch), i.e. 2 clock cycles.

/// The DRAM standard families used in the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DramStandard {
    Ddr3,
    Ddr4,
    Hbm,
}

/// A memory *technology* as the evaluation sweeps it (Tab. 3 rows):
/// the typed replacement for the old `"ddr3" | "ddr4" | "hbm"` strings.
/// Each variant maps to one concrete [`DramSpec`] preset via
/// [`MemTech::spec`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MemTech {
    /// DDR3-2133 (Tab. 3 "DDR3" row).
    Ddr3,
    /// DDR4-2400 — the paper's default.
    Ddr4,
    /// HBM-1000 pseudo-channels.
    Hbm,
    /// HBM2-2000 in pseudo-channel mode: 32 independent 64-bit
    /// pseudo-channels per stack pair — the scale-out axis the paper
    /// predates (ReGraph-class accelerators bind pipeline groups to
    /// disjoint pseudo-channel groups).
    Hbm2,
}

impl MemTech {
    pub fn name(self) -> &'static str {
        match self {
            MemTech::Ddr3 => "ddr3",
            MemTech::Ddr4 => "ddr4",
            MemTech::Hbm => "hbm",
            MemTech::Hbm2 => "hbm2",
        }
    }

    pub fn all() -> [MemTech; 4] {
        [MemTech::Ddr3, MemTech::Ddr4, MemTech::Hbm, MemTech::Hbm2]
    }

    /// The Tab. 3 [`DramSpec`] for this technology at a channel count
    /// (HBM2 extends the table along the pseudo-channel axis).
    pub fn spec(self, channels: usize) -> DramSpec {
        match self {
            MemTech::Ddr3 => DramSpec::ddr3_2133(channels),
            MemTech::Ddr4 => DramSpec::ddr4_2400(channels),
            MemTech::Hbm => DramSpec::hbm_1000(channels),
            MemTech::Hbm2 => DramSpec::hbm2_2000(channels),
        }
    }

    /// Highest channel count this technology's configuration space
    /// provides (Fig. 12: DDR3/DDR4 up to 4 channels, HBM up to 8;
    /// HBM2 pseudo-channel mode scales to 32).
    pub fn max_channels(self) -> usize {
        match self {
            MemTech::Ddr3 | MemTech::Ddr4 => 4,
            MemTech::Hbm => 8,
            MemTech::Hbm2 => 32,
        }
    }
}

impl std::str::FromStr for MemTech {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "ddr3" => Ok(MemTech::Ddr3),
            "ddr4" => Ok(MemTech::Ddr4),
            "hbm" => Ok(MemTech::Hbm),
            "hbm2" | "hbm2pc" => Ok(MemTech::Hbm2),
            other => Err(format!("unknown DRAM type {other:?} (ddr3|ddr4|hbm|hbm2)")),
        }
    }
}

impl std::fmt::Display for MemTech {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Row-buffer management policy (ablation axis; the paper's systems
/// all assume open-page, which is Ramulator's default).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum RowPolicy {
    /// Keep the row open after an access (default).
    #[default]
    OpenPage,
    /// Auto-precharge after every access: no row reuse, but no
    /// conflict penalty either.
    ClosedPage,
}

/// Request scheduling policy (ablation axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SchedPolicy {
    /// First-ready FCFS: row hits bypass older non-hits (default;
    /// what Ramulator and the paper model).
    #[default]
    FrFcfs,
    /// Strict arrival order.
    Fcfs,
}

/// Physical address mapping (ablation axis; open challenge (b) —
/// "investigate schemes to improve utilization of bank-level
/// parallelism in modern memories").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum AddrMap {
    /// Ramulator's `RoBaRaCoCh`: a sequential stream walks all the
    /// columns of one row before switching banks (default).
    #[default]
    RowBankColumn,
    /// Bank bits *below* the column bits: consecutive cache lines
    /// interleave banks (and bank groups), converting tCCD_L-bound
    /// sequential streams into tCCD_S-bound ones at the cost of more
    /// row activations.
    BankInterleaved,
}

/// Controller policy bundle.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DramPolicy {
    pub row: RowPolicy,
    pub sched: SchedPolicy,
    pub addr_map: AddrMap,
}

impl DramStandard {
    pub fn name(self) -> &'static str {
        match self {
            DramStandard::Ddr3 => "DDR3",
            DramStandard::Ddr4 => "DDR4",
            DramStandard::Hbm => "HBM",
        }
    }
}

/// JEDEC-style timing parameters in clock cycles.
#[derive(Clone, Copy, Debug)]
pub struct SpeedGrade {
    /// Clock period in picoseconds.
    pub tck_ps: u64,
    /// CAS (read) latency.
    pub cl: u64,
    /// CAS write latency.
    pub cwl: u64,
    /// ACT -> internal read/write.
    pub trcd: u64,
    /// PRE -> ACT.
    pub trp: u64,
    /// ACT -> PRE (row restore).
    pub tras: u64,
    /// ACT -> ACT, same bank (= tras + trp).
    pub trc: u64,
    /// ACT -> ACT, different bank, same rank (same bank group where groups exist).
    pub trrd_l: u64,
    /// ACT -> ACT, different bank group (DDR4/HBM); == trrd_l when no groups.
    pub trrd_s: u64,
    /// Four-activate window.
    pub tfaw: u64,
    /// CAS -> CAS, same bank group.
    pub tccd_l: u64,
    /// CAS -> CAS, different bank group; == burst occupancy minimum.
    pub tccd_s: u64,
    /// End of write burst -> PRE (write recovery).
    pub twr: u64,
    /// End of write burst -> read command (same rank turnaround).
    pub twtr: u64,
    /// Read -> PRE.
    pub trtp: u64,
    /// Burst occupancy on the data bus in clock cycles (BL / 2).
    pub burst: u64,
    /// Average refresh interval.
    pub trefi: u64,
    /// Refresh cycle time (all banks busy).
    pub trfc: u64,
}

/// Full DRAM configuration: standard + speed + organization.
///
/// `row_bytes` is the row-buffer size per bank as seen by the
/// controller (Tab. 3 "RBS": 8 KB for DDR3/DDR4 ranks, 2 KB for HBM
/// pseudo-channels).
#[derive(Clone, Copy, Debug)]
pub struct DramSpec {
    pub standard: DramStandard,
    pub speed: SpeedGrade,
    pub channels: usize,
    pub ranks: usize,
    pub bank_groups: usize,
    /// Banks per bank group.
    pub banks_per_group: usize,
    /// Row-buffer size per bank in bytes.
    pub row_bytes: u64,
    /// Total capacity per channel in bytes (drives the row count).
    pub channel_bytes: u64,
    /// Data-bus width in bits.
    pub bus_bits: u64,
    /// Mega-transfers per second (for reporting).
    pub data_rate_mts: u64,
}

impl DramSpec {
    /// Banks per rank.
    pub fn banks(&self) -> usize {
        self.bank_groups * self.banks_per_group
    }

    /// Total bank state machines per channel.
    pub fn banks_per_channel(&self) -> usize {
        self.ranks * self.banks()
    }

    /// Cache lines per row buffer.
    pub fn lines_per_row(&self) -> u64 {
        self.row_bytes / super::CACHE_LINE
    }

    /// Rows per bank (derived from capacity).
    pub fn rows_per_bank(&self) -> u64 {
        self.channel_bytes / (self.row_bytes * self.banks_per_channel() as u64)
    }

    /// Peak bandwidth per channel in bytes/second.
    pub fn peak_bw_per_channel(&self) -> f64 {
        self.data_rate_mts as f64 * 1e6 * (self.bus_bits as f64 / 8.0)
    }

    /// Seconds per controller clock cycle.
    pub fn seconds_per_cycle(&self) -> f64 {
        self.speed.tck_ps as f64 * 1e-12
    }

    /// DDR3-1600 (11-11-11), the HitGraph paper configuration.
    pub fn ddr3_1600(channels: usize, ranks: usize) -> Self {
        DramSpec {
            standard: DramStandard::Ddr3,
            speed: SpeedGrade {
                tck_ps: 1250,
                cl: 11,
                cwl: 8,
                trcd: 11,
                trp: 11,
                tras: 28,
                trc: 39,
                trrd_l: 6,
                trrd_s: 6,
                tfaw: 32,
                tccd_l: 4,
                tccd_s: 4,
                twr: 12,
                twtr: 6,
                trtp: 6,
                burst: 4,
                trefi: 6240,
                trfc: 280,
            },
            channels,
            ranks,
            bank_groups: 1,
            banks_per_group: 8,
            row_bytes: 8 * 1024,
            channel_bytes: 8 * 1024 * 1024 * 1024 / 8, // 8 Gb chips -> 1 GiB/ch modelled
            bus_bits: 64,
            data_rate_mts: 1600,
        }
    }

    /// DDR3-2133 (14-14-14) — the paper's "DDR3" comparison row in Tab. 3
    /// (2133 MT/s, 17.1 GB/s, 8 Gb).
    pub fn ddr3_2133(channels: usize) -> Self {
        DramSpec {
            standard: DramStandard::Ddr3,
            speed: SpeedGrade {
                tck_ps: 938,
                cl: 14,
                cwl: 10,
                trcd: 14,
                trp: 14,
                tras: 34,
                trc: 48,
                trrd_l: 6,
                trrd_s: 6,
                tfaw: 37,
                tccd_l: 4,
                tccd_s: 4,
                twr: 16,
                twtr: 8,
                trtp: 8,
                burst: 4,
                trefi: 8320,
                trfc: 374,
            },
            channels,
            ranks: 1,
            bank_groups: 1,
            banks_per_group: 8,
            row_bytes: 8 * 1024,
            channel_bytes: 1024 * 1024 * 1024,
            bus_bits: 64,
            data_rate_mts: 2133,
        }
    }

    /// DDR4-2400 (17-17-17) — the paper's default (Tab. 3).
    ///
    /// DDR4 doubles the bank count over DDR3 via 4 bank groups x 4
    /// banks, "at the cost of added latency due to another hierarchy
    /// level" — modelled by the _L vs _S split of tRRD/tCCD.
    pub fn ddr4_2400(channels: usize) -> Self {
        DramSpec {
            standard: DramStandard::Ddr4,
            speed: SpeedGrade {
                tck_ps: 833,
                cl: 17,
                cwl: 12,
                trcd: 17,
                trp: 17,
                tras: 39,
                trc: 56,
                trrd_l: 6,
                trrd_s: 4,
                tfaw: 26,
                tccd_l: 6,
                tccd_s: 4,
                twr: 18,
                twtr: 9,
                trtp: 9,
                burst: 4,
                trefi: 9360,
                trfc: 420,
            },
            channels,
            ranks: 1,
            bank_groups: 4,
            banks_per_group: 4,
            row_bytes: 8 * 1024,
            channel_bytes: 2 * 1024 * 1024 * 1024, // 16 Gb default row of Tab. 3
            bus_bits: 64,
            data_rate_mts: 2400,
        }
    }

    /// HBM-1000 (Tab. 3: 1000 MT/s, 16 GB/s and 2 KB row buffers per
    /// channel, 16 banks, 4n prefetch over a 128-bit channel).
    pub fn hbm_1000(channels: usize) -> Self {
        DramSpec {
            standard: DramStandard::Hbm,
            speed: SpeedGrade {
                tck_ps: 2000, // 500 MHz clock, 1000 MT/s DDR
                cl: 7,
                cwl: 4,
                trcd: 7,
                trp: 7,
                tras: 17,
                trc: 24,
                trrd_l: 3,
                trrd_s: 2,
                tfaw: 15,
                tccd_l: 3,
                tccd_s: 2,
                twr: 8,
                twtr: 4,
                trtp: 4,
                burst: 2,
                trefi: 1950,
                trfc: 130,
            },
            channels,
            ranks: 1,
            bank_groups: 4,
            banks_per_group: 4,
            row_bytes: 2 * 1024,
            channel_bytes: 512 * 1024 * 1024, // 4 Gb per channel
            bus_bits: 128,
            data_rate_mts: 1000,
        }
    }

    /// HBM2-2000 in pseudo-channel mode: each 128-bit legacy channel
    /// splits into two independent 64-bit pseudo-channels, so a
    /// two-stack board exposes 32 of them. Per pseudo-channel: 2000
    /// MT/s over 64 bits (16 GB/s — one cache line per 4-clock burst),
    /// 16 banks in 4 groups, 1 KB row buffers, 256 MiB capacity.
    /// Timings are the HBM-1000 grade rescaled to the 1 GHz clock.
    pub fn hbm2_2000(channels: usize) -> Self {
        DramSpec {
            standard: DramStandard::Hbm,
            speed: SpeedGrade {
                tck_ps: 1000, // 1 GHz clock, 2000 MT/s DDR
                cl: 14,
                cwl: 8,
                trcd: 14,
                trp: 14,
                tras: 34,
                trc: 48,
                trrd_l: 6,
                trrd_s: 4,
                tfaw: 30,
                tccd_l: 4,
                tccd_s: 2,
                twr: 16,
                twtr: 8,
                trtp: 6,
                burst: 4, // BL8 over the 64-bit pseudo-channel bus
                trefi: 3900,
                trfc: 260,
            },
            channels,
            ranks: 1,
            bank_groups: 4,
            banks_per_group: 4,
            row_bytes: 1024,
            channel_bytes: 256 * 1024 * 1024, // 2 Gb per pseudo-channel
            bus_bits: 64,
            data_rate_mts: 2000,
        }
    }

    /// Named Tab. 3 rows.
    pub fn preset(name: &str) -> Option<DramSpec> {
        match name {
            "accugraph" => Some(Self::ddr4_2400(1)),
            "foregraph" => Some(Self::ddr4_2400(1)),
            "hitgraph" => Some(Self::ddr3_1600(4, 2)),
            "thundergp" => Some(Self::ddr4_2400(4)),
            "regraph" => Some(Self::hbm2_2000(32)),
            "default" | "ddr4" => Some(Self::ddr4_2400(1)),
            "ddr3" => Some(Self::ddr3_2133(1)),
            "hbm" => Some(Self::hbm_1000(1)),
            "hbm2" => Some(Self::hbm2_2000(1)),
            _ => None,
        }
    }

    /// The same spec with a different channel count (scale tests, Fig. 12).
    pub fn with_channels(mut self, channels: usize) -> Self {
        self.channels = channels;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mem_tech_round_trips_and_maps_to_specs() {
        for tech in MemTech::all() {
            let parsed: MemTech = tech.name().parse().unwrap();
            assert_eq!(parsed, tech);
            assert_eq!(tech.to_string(), tech.name());
            let s = tech.spec(2);
            assert_eq!(s.channels, 2);
        }
        assert_eq!(MemTech::Ddr4.spec(1).standard, DramStandard::Ddr4);
        assert_eq!(MemTech::Hbm.spec(1).standard, DramStandard::Hbm);
        assert_eq!(MemTech::Hbm2.spec(1).standard, DramStandard::Hbm);
        assert_eq!("hbm2pc".parse::<MemTech>().unwrap(), MemTech::Hbm2);
        assert!("lpddr".parse::<MemTech>().is_err());
    }

    #[test]
    fn presets_resolve() {
        for name in [
            "accugraph",
            "foregraph",
            "hitgraph",
            "thundergp",
            "regraph",
            "default",
            "ddr3",
            "hbm",
            "hbm2",
        ] {
            assert!(DramSpec::preset(name).is_some(), "{name}");
        }
        assert!(DramSpec::preset("nope").is_none());
    }

    #[test]
    fn max_channels_per_tech() {
        assert_eq!(MemTech::Ddr3.max_channels(), 4);
        assert_eq!(MemTech::Ddr4.max_channels(), 4);
        assert_eq!(MemTech::Hbm.max_channels(), 8);
        assert_eq!(MemTech::Hbm2.max_channels(), 32);
    }

    #[test]
    fn hbm2_pseudo_channel_organization() {
        let h = DramSpec::hbm2_2000(32);
        assert_eq!(h.channels, 32);
        // 2000 MT/s over 64 bits = 16 GB/s per pseudo-channel —
        // 512 GB/s across the full 32-pseudo-channel configuration.
        assert!((h.peak_bw_per_channel() - 16.0e9).abs() < 1e6);
        assert_eq!(h.row_bytes, 1024);
        assert_eq!(h.banks(), 16);
        assert_eq!(h.lines_per_row(), 16);
        assert!(h.rows_per_bank() > 1000);
        // One cache line per burst: 64-bit bus x 4 DDR clocks = 64 B.
        assert_eq!(h.bus_bits / 8 * h.speed.burst * 2, super::super::CACHE_LINE);
    }

    #[test]
    fn ddr4_organization() {
        let s = DramSpec::ddr4_2400(1);
        assert_eq!(s.banks(), 16);
        assert_eq!(s.lines_per_row(), 128);
        assert!(s.rows_per_bank() > 1000);
        // 2400 MT/s * 8 B = 19.2 GB/s (Tab. 3).
        assert!((s.peak_bw_per_channel() - 19.2e9).abs() < 1e6);
    }

    #[test]
    fn ddr3_bandwidths_match_tab3() {
        let hit = DramSpec::ddr3_1600(4, 2);
        assert!((hit.peak_bw_per_channel() - 12.8e9).abs() < 1e6);
        let d3 = DramSpec::ddr3_2133(1);
        assert!((d3.peak_bw_per_channel() - 17.064e9).abs() < 0.1e9);
    }

    #[test]
    fn hbm_matches_tab3() {
        let h = DramSpec::hbm_1000(8);
        assert!((h.peak_bw_per_channel() - 16.0e9).abs() < 1e6);
        assert_eq!(h.row_bytes, 2048);
        assert_eq!(h.banks(), 16);
        assert_eq!(h.lines_per_row(), 32);
    }

    #[test]
    fn trc_is_consistent() {
        for s in [
            DramSpec::ddr3_1600(1, 1),
            DramSpec::ddr3_2133(1),
            DramSpec::ddr4_2400(1),
            DramSpec::hbm_1000(1),
            DramSpec::hbm2_2000(1),
        ] {
            assert!(s.speed.trc >= s.speed.tras + s.speed.trp - 1);
            assert!(s.speed.tras >= s.speed.trcd);
        }
    }
}
