//! Deterministic DRAM fault injection: a seeded [`FaultPlan`]
//! perturbs per-channel service timing to model the degraded-memory
//! conditions a real HBM/DDR subsystem exhibits — refresh storms
//! (periodic latency spikes), thermal throttling (windows of degraded
//! service), and transient bus errors retried with bounded backoff.
//!
//! Faults are *purely additive delay* applied when a request is
//! serviced, keyed only on `(seed, channel, per-channel serviced
//! count)`. Two consequences the test suite relies on:
//!
//! * **Determinism** — the same plan on the same workload produces
//!   bit-identical reports, every time. No wall clock, no global RNG.
//! * **Selector independence** — completion *selection* (event heap
//!   vs. the linear-scan reference) keys on queue-arrival times, which
//!   faults never touch; the per-channel service order is therefore
//!   unchanged, and the k-th serviced request on a channel is the same
//!   request under either selector. Heap-vs-scan equivalence holds
//!   under every fault plan (`tests/fault_equivalence.rs`).
//!
//! A plan only ever *slows* the serviced request: results (values,
//! request counts, region mixes) are invariant; cycles move. The
//! injected events and delay are accounted in
//! [`DramStats::faults_injected`](super::stats::DramStats) /
//! [`fault_delay_cycles`](super::stats::DramStats) so a run can prove
//! faults actually fired.

/// Periodic per-channel latency spikes (refresh-storm model): every
/// `period`-th serviced request on a channel — phase-shifted per
/// channel by the seed — completes `extra_cycles` late.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct LatencySpikes {
    /// Spike cadence in serviced requests (≥ 1).
    pub period: u64,
    /// Extra completion delay per spike.
    pub extra_cycles: u64,
}

/// Temporary channel degradation (thermal-throttle model): within
/// every `every`-request stretch, a window of `window` consecutive
/// serviced requests — phase-shifted per channel by the seed — each
/// completes `extra_cycles` late.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ChannelDegrade {
    /// Stretch length in serviced requests (≥ 1).
    pub every: u64,
    /// Degraded-window length within each stretch.
    pub window: u64,
    /// Extra delay per request inside a degraded window.
    pub extra_cycles: u64,
}

/// Transient request retries with bounded linear backoff (flaky-bus
/// model): every `every`-th serviced request — phase-shifted per
/// channel — transiently fails `r` times, `r` drawn deterministically
/// in `1..=max_retries`, and retry `i` waits `i * backoff_cycles`,
/// delaying completion by `backoff_cycles * r * (r + 1) / 2`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TransientRetries {
    /// Failure cadence in serviced requests (≥ 1).
    pub every: u64,
    /// Retry-count bound (≥ 1).
    pub max_retries: u32,
    /// Backoff unit per retry.
    pub backoff_cycles: u64,
}

/// A seeded, deterministic fault-injection plan. Attach one to a run
/// via `SimSpecBuilder::faults(..)` — it joins the memoization key
/// (faulted and clean runs are distinct cache entries) but not the
/// memory-independent program key. The default plan injects nothing.
#[derive(Clone, Debug, Default, PartialEq, Eq, Hash)]
pub struct FaultPlan {
    /// Phase-shifts every fault source per channel and draws the
    /// retry counts; same seed ⇒ same faults, always.
    pub seed: u64,
    /// Periodic latency spikes, if any.
    pub spikes: Option<LatencySpikes>,
    /// Degraded-service windows, if any.
    pub degrade: Option<ChannelDegrade>,
    /// Transient retries, if any.
    pub retries: Option<TransientRetries>,
}

impl FaultPlan {
    /// Heavy periodic spikes: every 7th request +350 cycles, the
    /// pattern of a refresh storm.
    pub fn refresh_storm(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            spikes: Some(LatencySpikes { period: 7, extra_cycles: 350 }),
            ..FaultPlan::default()
        }
    }

    /// Thermal throttling: 16-request degraded windows every 64
    /// requests, +40 cycles each.
    pub fn thermal_throttle(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            degrade: Some(ChannelDegrade { every: 64, window: 16, extra_cycles: 40 }),
            ..FaultPlan::default()
        }
    }

    /// Flaky bus: every 11th request transiently fails up to 3 times
    /// with 120-cycle linear backoff.
    pub fn flaky_bus(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            retries: Some(TransientRetries { every: 11, max_retries: 3, backoff_cycles: 120 }),
            ..FaultPlan::default()
        }
    }

    /// All three fault sources at once.
    pub fn mixed(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            spikes: FaultPlan::refresh_storm(seed).spikes,
            degrade: FaultPlan::thermal_throttle(seed).degrade,
            retries: FaultPlan::flaky_bus(seed).retries,
        }
    }

    /// True iff the plan can never inject a delay.
    pub fn is_noop(&self) -> bool {
        self.spikes.is_none() && self.degrade.is_none() && self.retries.is_none()
    }

    /// Extra completion delay and fault-event count for the `k`-th
    /// serviced request on `channel`. Pure function of
    /// `(self, channel, k)`.
    pub fn injection_for(&self, channel: usize, k: u64) -> Injection {
        let mut inj = Injection::default();
        if let Some(sp) = self.spikes {
            let period = sp.period.max(1);
            if k % period == mix(self.seed, channel, 1) % period {
                inj.extra_cycles += sp.extra_cycles;
                inj.events += 1;
            }
        }
        if let Some(dg) = self.degrade {
            let every = dg.every.max(1);
            if (k + mix(self.seed, channel, 2)) % every < dg.window.min(every) {
                inj.extra_cycles += dg.extra_cycles;
                inj.events += 1;
            }
        }
        if let Some(rt) = self.retries {
            let every = rt.every.max(1);
            if k % every == mix(self.seed, channel, 3) % every {
                let draw = mix(self.seed, channel, k.rotate_left(17) ^ 4);
                let r = 1 + draw % rt.max_retries.max(1) as u64;
                inj.extra_cycles += rt.backoff_cycles * r * (r + 1) / 2;
                inj.events += 1;
            }
        }
        inj
    }
}

/// The delay a [`FaultPlan`] injects into one serviced request.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Injection {
    /// Cycles added to the request's completion (and to the channel's
    /// bus availability — the delay is structural, not cosmetic).
    pub extra_cycles: u64,
    /// Distinct fault events that fired (spike / degrade / retry).
    pub events: u64,
}

/// Per-channel fault state: the plan plus this channel's serviced
/// counter. Owned by [`Channel`](super::channel::Channel); reset
/// clears it (faults are re-installed per run by the spec layer).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultLane {
    plan: FaultPlan,
    channel: usize,
    serviced: u64,
}

impl FaultLane {
    /// Lane for global channel index `channel`.
    pub fn new(plan: FaultPlan, channel: usize) -> FaultLane {
        FaultLane { plan, channel, serviced: 0 }
    }

    /// Injection for the next serviced request; advances the counter.
    pub fn next_injection(&mut self) -> Injection {
        let inj = self.plan.injection_for(self.channel, self.serviced);
        self.serviced += 1;
        inj
    }
}

/// splitmix64-style mixer: deterministic per-(seed, channel, salt)
/// phase offsets and retry draws.
fn mix(seed: u64, channel: usize, salt: u64) -> u64 {
    let mut x = seed
        ^ (channel as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ salt.wrapping_mul(0xD6E8_FEB8_6659_FD93);
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plan_is_noop() {
        let plan = FaultPlan::default();
        assert!(plan.is_noop());
        for k in 0..100 {
            assert_eq!(plan.injection_for(0, k), Injection::default());
        }
    }

    #[test]
    fn presets_inject_somewhere() {
        for plan in [
            FaultPlan::refresh_storm(1),
            FaultPlan::thermal_throttle(2),
            FaultPlan::flaky_bus(3),
            FaultPlan::mixed(4),
        ] {
            assert!(!plan.is_noop());
            let total: u64 = (0..1000).map(|k| plan.injection_for(0, k).events).sum();
            assert!(total > 0, "{plan:?} never fired in 1000 requests");
        }
    }

    #[test]
    fn injections_are_deterministic_and_seed_sensitive() {
        let a = FaultPlan::mixed(0xAB);
        let b = FaultPlan::mixed(0xCD);
        let series = |p: &FaultPlan, ch: usize| -> Vec<Injection> {
            (0..256).map(|k| p.injection_for(ch, k)).collect()
        };
        assert_eq!(series(&a, 3), series(&a, 3), "pure function of (plan, ch, k)");
        assert_ne!(series(&a, 0), series(&b, 0), "seed must matter");
        assert_ne!(series(&a, 0), series(&a, 1), "channel phase must matter");
    }

    #[test]
    fn lane_counter_matches_direct_injection() {
        let plan = FaultPlan::flaky_bus(9);
        let mut lane = FaultLane::new(plan.clone(), 5);
        for k in 0..64 {
            assert_eq!(lane.next_injection(), plan.injection_for(5, k));
        }
    }

    #[test]
    fn retry_backoff_is_bounded() {
        let plan = FaultPlan::flaky_bus(7);
        let rt = plan.retries.unwrap();
        let worst = rt.backoff_cycles * (rt.max_retries as u64) * (rt.max_retries as u64 + 1) / 2;
        for ch in 0..4 {
            for k in 0..2000 {
                let inj = plan.injection_for(ch, k);
                assert!(inj.extra_cycles <= worst, "unbounded backoff at ch{ch} k{k}");
            }
        }
    }

    #[test]
    fn plans_are_hashable_memo_keys() {
        use std::collections::HashSet;
        let mut set = HashSet::new();
        set.insert(FaultPlan::refresh_storm(1));
        set.insert(FaultPlan::refresh_storm(1));
        set.insert(FaultPlan::refresh_storm(2));
        set.insert(FaultPlan::default());
        assert_eq!(set.len(), 3);
    }
}
