//! The simulator as a long-running shared service.
//!
//! [`Server`] owns one memoizing [`Session`] (optionally layered over
//! a durable [`CacheDir`]) and speaks the line protocol of [`proto`]
//! on a [`std::net::TcpListener`]. The design goal is *crash safety
//! under load*, in order of the request path:
//!
//! * **Admission control** — a bounded in-flight permit counter.
//!   Requests beyond `max_inflight` are rejected immediately with a
//!   typed `BUSY retry_after_ms=…` instead of queueing unboundedly;
//!   `PING`/`STATS` bypass admission so liveness probes always answer.
//! * **Per-request budgets** — the server's admission [`RunBudget`]
//!   is merged (axis-wise minimum) into every request's own budget,
//!   so no single spec can monopolize the daemon; exceeding it is a
//!   typed error (or, in degraded mode, an advisor estimate).
//! * **Panic isolation** — simulations already run behind
//!   [`crate::robust::catch_sim`] inside the session; a panicking
//!   request becomes a typed `panicked` response and the daemon keeps
//!   serving (the `BOOM` diagnostic request proves it end to end).
//! * **Durability** — with a disk cache attached, every computed
//!   result (reports *and* failure memos) is persisted atomically;
//!   a restarted daemon serves pre-restart results bit-identically
//!   without re-simulating.
//! * **Graceful drain** — shutdown (flag or `SHUTDOWN` request) stops
//!   accepting work, lets every in-flight request finish and answer,
//!   then returns from [`Server::run`].
//!
//! The CLI front-ends are `graphmem serve` and `graphmem submit`
//! (the retrying [`Client`] with exponential backoff and jitter).

pub mod client;
pub mod proto;

pub use client::{Client, SubmitOutcome};
pub use proto::{DegradedEstimate, Request, Response};

use crate::advisor::Advisor;
use crate::coordinator::{figure_matrix_specs, Scope};
use crate::persist::{builtin_graphs, spec_from_line_with, CacheDir};
use crate::robust::{RunBudget, SimError};
use crate::sim::{Session, SimSpec};
use std::io::{self, BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Knobs of a [`Server`]. `Default` is a sane interactive daemon:
/// four in-flight requests, 250 ms busy hint, no admission budget,
/// memory-only cache, cold start.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Maximum concurrently executing `RUN` requests; beyond this the
    /// server answers `BUSY`. `0` rejects every `RUN` (a deterministic
    /// overload mode — `PING`/`STATS` still answer).
    pub max_inflight: usize,
    /// Back-off hint attached to `BUSY` responses.
    pub retry_after_ms: u64,
    /// Admission budget merged (axis-wise minimum) into every
    /// request's own [`RunBudget`].
    pub admission: Option<RunBudget>,
    /// Root of the durable result cache; `None` = memory only.
    pub cache_dir: Option<PathBuf>,
    /// Precompile the paper's figure matrix (quick scope) at startup
    /// and adopt any matching disk entries.
    pub warm: bool,
    /// Accept-loop poll interval while idle.
    pub poll_ms: u64,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            max_inflight: 4,
            retry_after_ms: 250,
            admission: None,
            cache_dir: None,
            warm: false,
            poll_ms: 20,
        }
    }
}

/// Point-in-time serve counters (`STATS` carries these plus the
/// session's [`crate::sim::SessionStats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeStats {
    /// Request lines handled (any command, any outcome).
    pub requests: usize,
    /// `RUN`s rejected by admission control.
    pub busy_rejections: usize,
    /// `RUN`s answered with a typed `ERR sim` (incl. spec rejects and
    /// the `BOOM` diagnostic).
    pub sim_failures: usize,
    /// `RUN`s answered without simulating (memo or disk).
    pub cache_hits: usize,
    /// `RUN`s answered with an advisor estimate in degraded mode.
    pub degraded_replies: usize,
    /// `RUN`s rejected by the static program verifier at admission
    /// (`ERR verify`; see [`crate::verify`]). No simulation ran.
    pub verify_rejections: usize,
}

/// In-flight permit: holding one is the right to execute a `RUN`.
/// Dropping it (normally or through an unwind) frees the slot.
struct Permit<'a>(&'a AtomicUsize);

impl Drop for Permit<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// The serve daemon. See the module docs for the request path.
pub struct Server {
    listener: TcpListener,
    session: Session,
    cfg: ServerConfig,
    shutdown: Arc<AtomicBool>,
    inflight: AtomicUsize,
    requests: AtomicUsize,
    busy_rejections: AtomicUsize,
    sim_failures: AtomicUsize,
    cache_hits: AtomicUsize,
    degraded_replies: AtomicUsize,
    verify_rejections: AtomicUsize,
}

impl Server {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port),
    /// attach the disk cache and pre-warm if configured.
    pub fn bind(addr: &str, cfg: ServerConfig) -> io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let mut session = Session::new();
        if let Some(root) = &cfg.cache_dir {
            session = session.with_disk_cache(Arc::new(CacheDir::new(root)?));
        }
        let server = Server {
            listener,
            session,
            cfg,
            shutdown: Arc::new(AtomicBool::new(false)),
            inflight: AtomicUsize::new(0),
            requests: AtomicUsize::new(0),
            busy_rejections: AtomicUsize::new(0),
            sim_failures: AtomicUsize::new(0),
            cache_hits: AtomicUsize::new(0),
            degraded_replies: AtomicUsize::new(0),
            verify_rejections: AtomicUsize::new(0),
        };
        if server.cfg.warm {
            server.warm();
        }
        Ok(server)
    }

    /// The bound address (useful with an ephemeral port).
    pub fn local_addr(&self) -> io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// A handle that makes [`Server::run`] drain and return when set
    /// (e.g. from a signal handler or a test harness).
    pub fn shutdown_handle(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.shutdown)
    }

    /// The underlying session (counters, peeks — diagnostics only).
    pub fn session(&self) -> &Session {
        &self.session
    }

    /// Serve counters so far.
    pub fn stats(&self) -> ServeStats {
        ServeStats {
            requests: self.requests.load(Ordering::Relaxed),
            busy_rejections: self.busy_rejections.load(Ordering::Relaxed),
            sim_failures: self.sim_failures.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            degraded_replies: self.degraded_replies.load(Ordering::Relaxed),
            verify_rejections: self.verify_rejections.load(Ordering::Relaxed),
        }
    }

    /// Precompile the paper's core figure matrix (quick scope) and
    /// adopt any results already on disk, so a fresh daemon answers
    /// figure-grade requests without first-touch compile latency and
    /// a restarted one without re-simulating at all.
    fn warm(&self) {
        let Ok(specs) = figure_matrix_specs(Scope::Quick) else {
            return;
        };
        for spec in &specs {
            self.session.program_for(spec);
            if let Some(disk) = self.session.disk_cache() {
                if disk.contains(spec) {
                    // The disk layer satisfies this without simulating.
                    let _ = self.session.try_run(spec);
                }
            }
        }
    }

    /// Accept-and-serve until shutdown, then drain: every connection
    /// accepted before the flag was set finishes its in-flight
    /// request and gets its response before this returns.
    pub fn run(&self) -> io::Result<ServeStats> {
        std::thread::scope(|scope| {
            while !self.shutdown.load(Ordering::SeqCst) {
                match self.listener.accept() {
                    Ok((stream, _peer)) => {
                        scope.spawn(move || self.serve_connection(stream));
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(self.cfg.poll_ms.max(1)));
                    }
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                    Err(e) => {
                        // Transient accept failure (fd pressure, RST in
                        // the backlog): log and keep serving.
                        eprintln!("graphmem serve: accept error: {e}");
                        std::thread::sleep(Duration::from_millis(self.cfg.poll_ms.max(1)));
                    }
                }
            }
            // Scope exit joins every connection thread — the drain.
        });
        Ok(self.stats())
    }

    /// One connection: line in, line out, until EOF or shutdown.
    fn serve_connection(&self, stream: TcpStream) {
        let read_timeout = Duration::from_millis(self.cfg.poll_ms.max(1) * 5);
        if stream.set_read_timeout(Some(read_timeout)).is_err() {
            return;
        }
        let Ok(read_half) = stream.try_clone() else {
            return;
        };
        let mut reader = BufReader::new(read_half);
        let mut stream = stream;
        let mut line = String::new();
        loop {
            match reader.read_line(&mut line) {
                Ok(0) => return, // EOF: client hung up.
                Ok(_) => {
                    let trimmed = line.trim().to_string();
                    line.clear();
                    if trimmed.is_empty() {
                        continue;
                    }
                    let response = self.handle_line(&trimmed);
                    let closing = matches!(response, Response::ShuttingDown);
                    let mut out = response.render();
                    out.push('\n');
                    if stream.write_all(out.as_bytes()).is_err() || stream.flush().is_err() {
                        return;
                    }
                    if closing {
                        return;
                    }
                }
                Err(e)
                    if matches!(
                        e.kind(),
                        io::ErrorKind::WouldBlock
                            | io::ErrorKind::TimedOut
                            | io::ErrorKind::Interrupted
                    ) =>
                {
                    // Idle poll tick: drop the connection once draining
                    // (no new requests are admitted after shutdown).
                    if self.shutdown.load(Ordering::SeqCst) {
                        return;
                    }
                }
                Err(_) => return,
            }
        }
    }

    /// Dispatch one request line to a response. Never panics out:
    /// everything that can fail answers typed.
    fn handle_line(&self, line: &str) -> Response {
        self.requests.fetch_add(1, Ordering::Relaxed);
        match Request::parse(line) {
            Err(msg) => Response::Proto(msg),
            Ok(Request::Ping) => Response::Pong,
            Ok(Request::Stats) => Response::Stats(self.stats_rows()),
            Ok(Request::Shutdown) => {
                self.shutdown.store(true, Ordering::SeqCst);
                Response::ShuttingDown
            }
            Ok(Request::Boom) => {
                // Deliberate panic inside the sim boundary: the typed
                // `panicked` answer (and the daemon still being alive)
                // is the point of this diagnostic.
                self.sim_failures.fetch_add(1, Ordering::Relaxed);
                let err = crate::robust::catch_sim::<()>(|| {
                    panic!("boom: operator-requested diagnostic panic")
                })
                .unwrap_err();
                Response::SimFailed(err)
            }
            Ok(Request::Run {
                spec_line,
                degraded,
            }) => self.handle_run(&spec_line, degraded),
        }
    }

    fn handle_run(&self, spec_line: &str, degraded: bool) -> Response {
        // Admission before any parsing or simulation work.
        let Some(_permit) = self.try_acquire() else {
            self.busy_rejections.fetch_add(1, Ordering::Relaxed);
            return Response::Busy {
                retry_after_ms: self.cfg.retry_after_ms,
            };
        };
        let spec = match spec_from_line_with(spec_line, Some(&builtin_graphs)) {
            Ok(spec) => spec,
            Err(err) => {
                // Malformed or invalid specs fold into the run-time
                // error taxonomy — the client sees one error type.
                self.sim_failures.fetch_add(1, Ordering::Relaxed);
                return Response::SimFailed(err.into());
            }
        };
        let spec = self.admitted(spec);
        // Static verification at admission: the compiled program (from
        // the session's shared cache — at most one compile per
        // workload) is checked before any simulation work, so a
        // structurally broken program earns a typed `ERR verify`
        // instead of burning a run slot on an execution the stall
        // watchdog would have to kill.
        let program = self.session.program_for(&spec);
        let verdict = spec.verify_report(&program);
        if !verdict.is_ok() {
            self.verify_rejections.fetch_add(1, Ordering::Relaxed);
            let first = verdict
                .violations
                .first()
                .map(|v| v.to_string())
                .unwrap_or_default();
            return Response::VerifyRejected {
                violations: verdict.violations.len(),
                first,
            };
        }
        let warm = self.session.peek(&spec).is_some()
            || self
                .session
                .disk_cache()
                .is_some_and(|disk| disk.contains(&spec));
        match self.session.try_run(&spec) {
            Ok(report) => {
                if warm {
                    self.cache_hits.fetch_add(1, Ordering::Relaxed);
                }
                Response::Report {
                    cache_hit: warm,
                    report,
                }
            }
            Err(err @ SimError::BudgetExceeded { .. }) if degraded => {
                // Graceful degradation: the cheap advisor probe stands
                // in for the over-budget run, clearly marked. If even
                // the probe fails, the original typed error stands.
                match Advisor::new().recommend(&spec) {
                    Ok(rec) => {
                        self.degraded_replies.fetch_add(1, Ordering::Relaxed);
                        Response::Degraded(DegradedEstimate::from_recommendation(&rec))
                    }
                    Err(_) => {
                        self.sim_failures.fetch_add(1, Ordering::Relaxed);
                        Response::SimFailed(err)
                    }
                }
            }
            Err(err) => {
                self.sim_failures.fetch_add(1, Ordering::Relaxed);
                Response::SimFailed(err)
            }
        }
    }

    /// The request's spec with the server's admission budget merged
    /// in (axis-wise minimum — a request can tighten its own budget
    /// but never exceed the server's).
    fn admitted(&self, spec: SimSpec) -> SimSpec {
        let Some(cap) = &self.cfg.admission else {
            return spec;
        };
        let merged = merge_budgets(spec.budget(), cap);
        spec.with_budget(Some(merged))
    }

    fn try_acquire(&self) -> Option<Permit<'_>> {
        let mut current = self.inflight.load(Ordering::SeqCst);
        loop {
            if current >= self.cfg.max_inflight {
                return None;
            }
            match self.inflight.compare_exchange(
                current,
                current + 1,
                Ordering::SeqCst,
                Ordering::SeqCst,
            ) {
                Ok(_) => return Some(Permit(&self.inflight)),
                Err(actual) => current = actual,
            }
        }
    }

    fn stats_rows(&self) -> Vec<(String, String)> {
        let serve = self.stats();
        let session = self.session.stats();
        let row = |k: &str, v: usize| (k.to_string(), v.to_string());
        vec![
            row("requests", serve.requests),
            row("busy_rejections", serve.busy_rejections),
            row("sim_failures", serve.sim_failures),
            row("cache_hits", serve.cache_hits),
            row("degraded_replies", serve.degraded_replies),
            row("verify_rejections", serve.verify_rejections),
            row("sim_runs", session.sim_runs),
            row("memo_hits", session.memo_hits),
            row("duplicate_waits", session.duplicate_waits),
            row("programs_compiled", session.programs_compiled),
            row("programs_reused", session.programs_reused),
            row("disk_hits", session.disk_hits),
            row("disk_writes", session.disk_writes),
        ]
    }
}

/// Axis-wise minimum of a request budget and the server cap: every
/// limit the cap sets applies, and a request that set a *tighter*
/// limit keeps it.
fn merge_budgets(request: Option<&RunBudget>, cap: &RunBudget) -> RunBudget {
    fn tighter<T: Ord + Copy>(a: Option<T>, b: Option<T>) -> Option<T> {
        match (a, b) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (Some(a), None) => Some(a),
            (None, b) => b,
        }
    }
    let Some(req) = request else {
        return cap.clone();
    };
    RunBudget {
        max_cycles: tighter(req.max_cycles, cap.max_cycles),
        max_requests: tighter(req.max_requests, cap.max_requests),
        wall_deadline: tighter(req.wall_deadline, cap.wall_deadline),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_merge_takes_axiswise_minimum() {
        let cap = RunBudget {
            max_cycles: Some(1_000),
            max_requests: None,
            wall_deadline: Some(Duration::from_secs(5)),
        };
        // No request budget: the cap applies verbatim.
        assert_eq!(merge_budgets(None, &cap), cap);
        // Tighter request limits survive, looser ones are clamped, and
        // axes only the request sets are kept.
        let req = RunBudget {
            max_cycles: Some(2_000),
            max_requests: Some(7),
            wall_deadline: Some(Duration::from_secs(1)),
        };
        let merged = merge_budgets(Some(&req), &cap);
        assert_eq!(merged.max_cycles, Some(1_000));
        assert_eq!(merged.max_requests, Some(7));
        assert_eq!(merged.wall_deadline, Some(Duration::from_secs(1)));
    }
}
