//! The line-delimited wire protocol of `graphmem serve`.
//!
//! One request line in, one response line out, both built from the
//! same primitives as [`crate::persist`] (percent-escaped strings,
//! `key=value` tokens, floats as `f64::to_bits` hex), so a report
//! travels the wire **bit-identically**. Parsing is total on both
//! sides: a malformed request earns a typed `ERR proto` response and
//! a malformed response earns a typed [`PersistError`] at the client
//! — never a panic, never a wedged connection.
//!
//! Requests:
//!
//! ```text
//! RUN [degraded] <spec line>     simulate (or fetch) one spec
//! PING                           liveness probe
//! STATS                          session + serve counters
//! SHUTDOWN                       drain in-flight work, then exit
//! BOOM                           diagnostic: panic inside the sim
//!                                boundary (proves isolation)
//! ```
//!
//! Responses:
//!
//! ```text
//! OK report cache_hit=<bool> <report line>
//! OK degraded <estimate tokens>
//! OK pong | OK stats <k=v ...> | OK shutting-down
//! ERR sim <error line>           typed SimError (incl. spec rejects)
//! ERR verify violations=<n> first=<escaped violation>
//!                                static verification rejected the
//!                                compiled program before any run
//!                                slot was spent (see crate::verify)
//! ERR proto <escaped message>    unparseable request
//! BUSY retry_after_ms=<n>        admission queue full — back off
//! ```

use crate::advisor::Recommendation;
use crate::persist::{
    error_from_line, error_to_line, esc, report_from_line, report_to_line, unesc, PersistError,
};
use crate::robust::SimError;
use crate::sim::SimReport;

/// One client request line.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Request {
    /// Simulate (or fetch) one serialized [`crate::sim::SimSpec`].
    /// With `degraded`, a budget-exceeded run falls back to the
    /// advisor's probe-based estimate instead of a hard failure.
    Run { spec_line: String, degraded: bool },
    Ping,
    Stats,
    Shutdown,
    /// Diagnostic: panics inside the simulation boundary. The daemon
    /// must answer with a typed `panicked` error and keep serving.
    Boom,
}

impl Request {
    /// Render as one protocol line (no trailing newline).
    pub fn render(&self) -> String {
        match self {
            Request::Run {
                spec_line,
                degraded: false,
            } => format!("RUN {spec_line}"),
            Request::Run {
                spec_line,
                degraded: true,
            } => format!("RUN degraded {spec_line}"),
            Request::Ping => "PING".to_string(),
            Request::Stats => "STATS".to_string(),
            Request::Shutdown => "SHUTDOWN".to_string(),
            Request::Boom => "BOOM".to_string(),
        }
    }

    /// Total parse; the error string is a human-readable reason the
    /// server echoes back as `ERR proto`.
    pub fn parse(line: &str) -> Result<Request, String> {
        let line = line.trim();
        let (cmd, rest) = match line.split_once(' ') {
            Some((c, r)) => (c, r.trim()),
            None => (line, ""),
        };
        let bare = |req: Request| {
            if rest.is_empty() {
                Ok(req)
            } else {
                Err(format!("{cmd} takes no arguments, got {rest:?}"))
            }
        };
        match cmd {
            "RUN" => {
                let (degraded, spec_line) = if rest == "degraded" {
                    (true, "")
                } else {
                    match rest.strip_prefix("degraded ") {
                        Some(r) => (true, r.trim()),
                        None => (false, rest),
                    }
                };
                if spec_line.is_empty() {
                    return Err("RUN needs a serialized spec line".to_string());
                }
                Ok(Request::Run {
                    spec_line: spec_line.to_string(),
                    degraded,
                })
            }
            "PING" => bare(Request::Ping),
            "STATS" => bare(Request::Stats),
            "SHUTDOWN" => bare(Request::Shutdown),
            "BOOM" => bare(Request::Boom),
            "" => Err("empty request".to_string()),
            other => Err(format!(
                "unknown command {other:?} (expected RUN|PING|STATS|SHUTDOWN|BOOM)"
            )),
        }
    }
}

/// What a budget-exceeded request gets instead of a hard failure when
/// the client opted into degraded mode: the advisor's probe-based
/// estimate, clearly marked as such. `predicted_cycles` is the
/// advisor's placement-axis cost model output, not a measurement.
#[derive(Clone, Debug, PartialEq)]
pub struct DegradedEstimate {
    /// Label of the probe spec the advisor actually simulated.
    pub probe_label: String,
    /// DRAM requests the probe issued.
    pub probe_requests: u64,
    /// Whether the probe ran on a sampled subgraph.
    pub probe_sampled: bool,
    /// Predicted cycles for the full run (advisor cost model).
    pub predicted_cycles: f64,
    /// Recommended partition count the prediction assumes.
    pub partitions: usize,
    /// Recommended channel count the prediction assumes.
    pub channels: usize,
    /// The advisor's evidence for the prediction.
    pub rationale: String,
}

impl DegradedEstimate {
    /// Distill a full advisor [`Recommendation`] down to the estimate
    /// the wire carries.
    pub fn from_recommendation(rec: &Recommendation) -> DegradedEstimate {
        DegradedEstimate {
            probe_label: rec.probe_label.clone(),
            probe_requests: rec.probe_requests,
            probe_sampled: rec.probe_sampled,
            predicted_cycles: rec.placement.predicted_cost,
            partitions: rec.partitioning.partitions,
            channels: rec.placement.channels,
            rationale: rec.placement.rationale.clone(),
        }
    }

    fn render_fields(&self) -> String {
        format!(
            "probe={} requests={} sampled={} cycles={:016x} partitions={} channels={} \
             rationale={}",
            esc(&self.probe_label),
            self.probe_requests,
            u8::from(self.probe_sampled),
            self.predicted_cycles.to_bits(),
            self.partitions,
            self.channels,
            esc(&self.rationale),
        )
    }

    fn parse_fields(s: &str) -> Result<DegradedEstimate, PersistError> {
        let mut probe = None;
        let mut requests = None;
        let mut sampled = None;
        let mut cycles = None;
        let mut partitions = None;
        let mut channels = None;
        let mut rationale = None;
        for tok in s.split_whitespace() {
            let (k, v) = tok.split_once('=').ok_or_else(|| PersistError::Field {
                field: "degraded",
                detail: format!("token {tok:?} is not key=value"),
            })?;
            let bad = |detail: String| PersistError::Field {
                field: "degraded",
                detail,
            };
            match k {
                "probe" => probe = Some(unesc(v)?),
                "requests" => {
                    requests = Some(v.parse::<u64>().map_err(|e| bad(format!("requests: {e}")))?)
                }
                "sampled" => sampled = Some(v == "1"),
                "cycles" => {
                    let bits = u64::from_str_radix(v, 16)
                        .map_err(|e| bad(format!("cycles: {e}")))?;
                    cycles = Some(f64::from_bits(bits));
                }
                "partitions" => {
                    partitions =
                        Some(v.parse::<usize>().map_err(|e| bad(format!("partitions: {e}")))?)
                }
                "channels" => {
                    channels = Some(v.parse::<usize>().map_err(|e| bad(format!("channels: {e}")))?)
                }
                "rationale" => rationale = Some(unesc(v)?),
                other => return Err(PersistError::UnknownKey(other.to_string())),
            }
        }
        Ok(DegradedEstimate {
            probe_label: probe.ok_or(PersistError::MissingField("probe"))?,
            probe_requests: requests.ok_or(PersistError::MissingField("requests"))?,
            probe_sampled: sampled.ok_or(PersistError::MissingField("sampled"))?,
            predicted_cycles: cycles.ok_or(PersistError::MissingField("cycles"))?,
            partitions: partitions.ok_or(PersistError::MissingField("partitions"))?,
            channels: channels.ok_or(PersistError::MissingField("channels"))?,
            rationale: rationale.ok_or(PersistError::MissingField("rationale"))?,
        })
    }
}

/// One server response line.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// A full report; `cache_hit` is true when it was served without
    /// simulating (memo or disk).
    Report { cache_hit: bool, report: SimReport },
    /// Advisor estimate in place of an over-budget run.
    Degraded(DegradedEstimate),
    /// The simulation (or the spec itself) failed, typed.
    SimFailed(SimError),
    /// Static verification (see [`crate::verify`]) rejected the
    /// compiled program at admission — before a run slot was spent.
    /// Carries the violation count and the first diagnostic.
    VerifyRejected { violations: usize, first: String },
    /// Admission queue full; retry after the hinted delay.
    Busy { retry_after_ms: u64 },
    /// The request line could not be parsed.
    Proto(String),
    Pong,
    /// Serve + session counters as ordered `(key, value)` pairs.
    Stats(Vec<(String, String)>),
    ShuttingDown,
}

impl Response {
    /// Render as one protocol line (no trailing newline).
    pub fn render(&self) -> String {
        match self {
            Response::Report { cache_hit, report } => {
                format!("OK report cache_hit={cache_hit} {}", report_to_line(report))
            }
            Response::Degraded(est) => format!("OK degraded {}", est.render_fields()),
            Response::SimFailed(err) => format!("ERR sim {}", error_to_line(err)),
            Response::VerifyRejected { violations, first } => {
                format!("ERR verify violations={violations} first={}", esc(first))
            }
            Response::Busy { retry_after_ms } => {
                format!("BUSY retry_after_ms={retry_after_ms}")
            }
            Response::Proto(msg) => format!("ERR proto {}", esc(msg)),
            Response::Pong => "OK pong".to_string(),
            Response::Stats(kvs) => {
                let mut out = "OK stats".to_string();
                for (k, v) in kvs {
                    out.push(' ');
                    out.push_str(&format!("{}={}", esc(k), esc(v)));
                }
                out
            }
            Response::ShuttingDown => "OK shutting-down".to_string(),
        }
    }

    /// Total parse of a server response line.
    pub fn parse(line: &str) -> Result<Response, PersistError> {
        let line = line.trim();
        let bad = |detail: String| PersistError::Field {
            field: "response",
            detail,
        };
        if let Some(rest) = line.strip_prefix("OK report cache_hit=") {
            let (flag, report_line) = rest
                .split_once(' ')
                .ok_or_else(|| bad("report response lacks a report line".to_string()))?;
            let cache_hit = match flag {
                "true" => true,
                "false" => false,
                other => return Err(bad(format!("cache_hit {other:?} is not a bool"))),
            };
            return Ok(Response::Report {
                cache_hit,
                report: report_from_line(report_line)?,
            });
        }
        if let Some(rest) = line.strip_prefix("OK degraded ") {
            return Ok(Response::Degraded(DegradedEstimate::parse_fields(rest)?));
        }
        if let Some(rest) = line.strip_prefix("ERR sim ") {
            return Ok(Response::SimFailed(error_from_line(rest)?));
        }
        if let Some(rest) = line.strip_prefix("ERR verify ") {
            let mut violations = None;
            let mut first = None;
            for tok in rest.split_whitespace() {
                let (k, v) = tok.split_once('=').ok_or_else(|| {
                    bad(format!("verify token {tok:?} is not key=value"))
                })?;
                match k {
                    "violations" => {
                        violations = Some(
                            v.parse::<usize>().map_err(|e| bad(format!("violations: {e}")))?,
                        )
                    }
                    "first" => first = Some(unesc(v)?),
                    other => return Err(PersistError::UnknownKey(other.to_string())),
                }
            }
            return Ok(Response::VerifyRejected {
                violations: violations.ok_or(PersistError::MissingField("violations"))?,
                first: first.ok_or(PersistError::MissingField("first"))?,
            });
        }
        if let Some(rest) = line.strip_prefix("ERR proto ") {
            return Ok(Response::Proto(unesc(rest.trim())?));
        }
        if let Some(rest) = line.strip_prefix("BUSY retry_after_ms=") {
            let ms = rest
                .trim()
                .parse::<u64>()
                .map_err(|e| bad(format!("retry_after_ms: {e}")))?;
            return Ok(Response::Busy { retry_after_ms: ms });
        }
        if line == "OK pong" {
            return Ok(Response::Pong);
        }
        if line == "OK shutting-down" {
            return Ok(Response::ShuttingDown);
        }
        if let Some(rest) = line.strip_prefix("OK stats") {
            let mut kvs = Vec::new();
            for tok in rest.split_whitespace() {
                let (k, v) = tok.split_once('=').ok_or_else(|| {
                    bad(format!("stats token {tok:?} is not key=value"))
                })?;
                kvs.push((unesc(k)?, unesc(v)?));
            }
            return Ok(Response::Stats(kvs));
        }
        Err(bad(format!("unrecognized response line {line:?}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::AcceleratorKind;
    use crate::algo::problem::ProblemKind;
    use crate::graph::datasets::DatasetId;
    use crate::persist::spec_to_line;
    use crate::robust::{BudgetResource, SimError};
    use crate::sim::SimSpec;

    fn spec() -> SimSpec {
        SimSpec::builder()
            .accelerator(AcceleratorKind::AccuGraph)
            .graph(DatasetId::Sd)
            .problem(ProblemKind::Bfs)
            .build()
            .unwrap()
    }

    #[test]
    fn requests_round_trip() {
        let cases = [
            Request::Run {
                spec_line: spec_to_line(&spec()),
                degraded: false,
            },
            Request::Run {
                spec_line: spec_to_line(&spec()),
                degraded: true,
            },
            Request::Ping,
            Request::Stats,
            Request::Shutdown,
            Request::Boom,
        ];
        for req in cases {
            assert_eq!(Request::parse(&req.render()).unwrap(), req);
        }
    }

    #[test]
    fn malformed_requests_are_typed_errors() {
        for line in ["", "RUN", "FETCH x=1", "PING extra", "RUN degraded "] {
            assert!(Request::parse(line).is_err(), "{line:?}");
        }
    }

    #[test]
    fn report_response_is_bit_identical() {
        let report = spec().run();
        let resp = Response::Report {
            cache_hit: true,
            report: report.clone(),
        };
        match Response::parse(&resp.render()).unwrap() {
            Response::Report { cache_hit, report: parsed } => {
                assert!(cache_hit);
                assert_eq!(parsed, report);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn every_response_variant_round_trips() {
        let est = DegradedEstimate {
            probe_label: "probe:sd sampled".to_string(),
            probe_requests: 1234,
            probe_sampled: true,
            predicted_cycles: 1.5e9,
            partitions: 7,
            channels: 4,
            rationale: "bus utilization 61.2% > 40% knee".to_string(),
        };
        let err = SimError::BudgetExceeded {
            resource: BudgetResource::Cycles,
            limit: 10,
            observed: 11,
        };
        let cases = [
            Response::Degraded(est),
            Response::SimFailed(err),
            Response::VerifyRejected {
                violations: 3,
                first: "phase 0 (`scatter[wave 0]`) stream 2: owning channel 9 out of range \
                        for 4 channels"
                    .to_string(),
            },
            Response::Busy { retry_after_ms: 250 },
            Response::Proto("unknown command \"FETCH\"".to_string()),
            Response::Pong,
            Response::Stats(vec![
                ("sim_runs".to_string(), "3".to_string()),
                ("cache_hits".to_string(), "1".to_string()),
            ]),
            Response::ShuttingDown,
        ];
        for resp in cases {
            assert_eq!(Response::parse(&resp.render()).unwrap(), resp);
        }
    }

    #[test]
    fn corrupt_response_lines_error_never_panic() {
        for line in [
            "",
            "OK",
            "OK report cache_hit=maybe x",
            "OK report cache_hit=true",
            "BUSY retry_after_ms=soon",
            "ERR sim ",
            "ERR verify ",
            "ERR verify violations=lots first=x",
            "ERR verify violations=2",
            "ERR verify violations=2 first=x rogue=1",
            "OK degraded cycles=zz",
            "garbage with spaces",
        ] {
            assert!(Response::parse(line).is_err(), "{line:?}");
        }
    }
}
