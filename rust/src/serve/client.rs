//! `graphmem submit` — the retrying client of the serve daemon.
//!
//! Transient conditions (connection refused/reset, `BUSY` admission
//! rejections) are retried with capped exponential backoff plus
//! deterministic jitter ([`crate::util::rng::Rng`], so a herd of
//! clients with distinct seeds staggers instead of stampeding).
//! Everything the *server* decided — a report, a typed simulation
//! failure, a degraded advisor estimate — is returned as a
//! [`SubmitOutcome`], never retried: the simulator is deterministic,
//! so re-asking cannot change a typed failure.

use super::proto::{DegradedEstimate, Request, Response};
use crate::persist::spec_to_line;
use crate::robust::SimError;
use crate::sim::{SimReport, SimSpec};
use crate::util::rng::Rng;
use std::io::{self, BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

/// How one submission ended, as the server decided it.
#[derive(Clone, Debug)]
pub enum SubmitOutcome {
    /// A full report; `cache_hit` is true when the server answered
    /// without simulating (memo or disk).
    Report { report: SimReport, cache_hit: bool },
    /// The run exceeded its budget and the client opted into degraded
    /// mode: the advisor's probe-based estimate, clearly marked.
    Degraded(DegradedEstimate),
    /// The simulation (or the spec) failed, typed.
    Failed(SimError),
    /// The server's static verifier rejected the compiled program at
    /// admission (see [`crate::verify`]); no run slot was spent.
    /// Deterministic — never retried.
    VerifyRejected { violations: usize, first: String },
}

/// A retrying protocol client. One TCP connection per request keeps
/// the client stateless across retries and daemon restarts.
#[derive(Clone, Debug)]
pub struct Client {
    addr: String,
    max_attempts: u32,
    base_backoff: Duration,
    read_timeout: Duration,
    seed: u64,
}

impl Client {
    pub fn new(addr: impl Into<String>) -> Client {
        Client {
            addr: addr.into(),
            max_attempts: 5,
            base_backoff: Duration::from_millis(100),
            read_timeout: Duration::from_secs(600),
            seed: 0x5EED,
        }
    }

    /// Total connection + `BUSY` attempts before giving up (min 1).
    pub fn with_max_attempts(mut self, attempts: u32) -> Client {
        self.max_attempts = attempts.max(1);
        self
    }

    /// First backoff step; doubles per retry, capped at 2 s.
    pub fn with_base_backoff(mut self, base: Duration) -> Client {
        self.base_backoff = base;
        self
    }

    /// How long to wait for a response before declaring the request
    /// lost (simulations can be slow; default 600 s).
    pub fn with_read_timeout(mut self, timeout: Duration) -> Client {
        self.read_timeout = timeout;
        self
    }

    /// Jitter seed — give concurrent clients distinct seeds so their
    /// retries stagger.
    pub fn with_seed(mut self, seed: u64) -> Client {
        self.seed = seed;
        self
    }

    /// Submit one spec. `degraded` opts into the advisor-estimate
    /// fallback for budget-exceeded runs.
    pub fn submit(&self, spec: &SimSpec, degraded: bool) -> io::Result<SubmitOutcome> {
        self.submit_line(&spec_to_line(spec), degraded)
    }

    /// [`Client::submit`] from an already serialized spec line.
    pub fn submit_line(&self, spec_line: &str, degraded: bool) -> io::Result<SubmitOutcome> {
        let request = Request::Run {
            spec_line: spec_line.to_string(),
            degraded,
        };
        match self.request(&request)? {
            Response::Report { cache_hit, report } => {
                Ok(SubmitOutcome::Report { report, cache_hit })
            }
            Response::Degraded(est) => Ok(SubmitOutcome::Degraded(est)),
            Response::SimFailed(err) => Ok(SubmitOutcome::Failed(err)),
            Response::VerifyRejected { violations, first } => {
                Ok(SubmitOutcome::VerifyRejected { violations, first })
            }
            other => Err(unexpected(&other)),
        }
    }

    /// Liveness probe.
    pub fn ping(&self) -> io::Result<()> {
        match self.request(&Request::Ping)? {
            Response::Pong => Ok(()),
            other => Err(unexpected(&other)),
        }
    }

    /// Serve + session counters as ordered `(key, value)` pairs.
    pub fn stats(&self) -> io::Result<Vec<(String, String)>> {
        match self.request(&Request::Stats)? {
            Response::Stats(rows) => Ok(rows),
            other => Err(unexpected(&other)),
        }
    }

    /// Ask the daemon to drain and exit.
    pub fn shutdown(&self) -> io::Result<()> {
        match self.request(&Request::Shutdown)? {
            Response::ShuttingDown => Ok(()),
            other => Err(unexpected(&other)),
        }
    }

    /// Fire the panic-isolation diagnostic; returns the typed error
    /// the daemon answered with (the daemon must stay alive).
    pub fn boom(&self) -> io::Result<SimError> {
        match self.request(&Request::Boom)? {
            Response::SimFailed(err) => Ok(err),
            other => Err(unexpected(&other)),
        }
    }

    /// One request with retry: connection failures and `BUSY` retry
    /// with backoff + jitter; any other response returns as-is.
    pub fn request(&self, request: &Request) -> io::Result<Response> {
        let line = request.render();
        let mut rng = Rng::new(self.seed);
        let mut last_err: Option<io::Error> = None;
        for attempt in 0..self.max_attempts {
            if attempt > 0 {
                std::thread::sleep(self.backoff(attempt, &mut rng));
            }
            match self.once(&line) {
                Ok(Response::Busy { retry_after_ms }) => {
                    // Honor the server's hint on top of our own step.
                    std::thread::sleep(Duration::from_millis(retry_after_ms));
                    last_err = Some(io::Error::new(
                        io::ErrorKind::WouldBlock,
                        format!("server busy after {} attempts", attempt + 1),
                    ));
                }
                Ok(response) => return Ok(response),
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err.unwrap_or_else(|| {
            io::Error::new(io::ErrorKind::TimedOut, "retry attempts exhausted")
        }))
    }

    /// One connect → write → read-line exchange, no retry.
    fn once(&self, line: &str) -> io::Result<Response> {
        let stream = TcpStream::connect(&self.addr)?;
        stream.set_read_timeout(Some(self.read_timeout))?;
        let mut writer = stream.try_clone()?;
        writer.write_all(line.as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        let mut reader = BufReader::new(stream);
        let mut response = String::new();
        reader.read_line(&mut response)?;
        if response.is_empty() {
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "server closed the connection before answering",
            ));
        }
        Response::parse(response.trim()).map_err(|e| {
            io::Error::new(
                io::ErrorKind::InvalidData,
                format!("unparseable server response: {e}"),
            )
        })
    }

    /// Capped exponential backoff with deterministic jitter: step
    /// `base * 2^(attempt-1)` capped at 2 s, plus up to 50% extra.
    fn backoff(&self, attempt: u32, rng: &mut Rng) -> Duration {
        let base = self.base_backoff.as_millis().max(1) as u64;
        let step = base
            .saturating_mul(1u64 << (attempt - 1).min(20))
            .min(2_000);
        Duration::from_millis(step + rng.next_below(step / 2 + 1))
    }
}

fn unexpected(response: &Response) -> io::Error {
    io::Error::new(
        io::ErrorKind::InvalidData,
        format!("unexpected server response: {}", response.render()),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_grows_caps_and_jitters_deterministically() {
        let c = Client::new("127.0.0.1:1").with_base_backoff(Duration::from_millis(100));
        let mut rng = Rng::new(7);
        let b1 = c.backoff(1, &mut rng);
        let mut rng = Rng::new(7);
        let b1_again = c.backoff(1, &mut rng);
        assert_eq!(b1, b1_again, "same seed, same jitter");
        assert!(b1 >= Duration::from_millis(100) && b1 < Duration::from_millis(151));
        let mut rng = Rng::new(7);
        let b5 = c.backoff(5, &mut rng);
        assert!(b5 <= Duration::from_millis(3_000), "capped at 2s + 50%");
        // Distinct seeds stagger.
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(c.backoff(3, &mut a), c.backoff(3, &mut b));
    }

    #[test]
    fn connection_refused_exhausts_attempts_quickly() {
        // Port 1 is essentially never listening; every attempt fails
        // at connect, so this exercises the retry loop end to end.
        let c = Client::new("127.0.0.1:1")
            .with_max_attempts(2)
            .with_base_backoff(Duration::from_millis(1));
        let err = c.ping().unwrap_err();
        // Refused (or permission-denied on some kernels) — anything
        // but success; the point is it returned instead of hanging.
        assert!(c.submit_line("accel=AccuGraph", false).is_err());
        let _ = err;
    }
}
