//! Graph partitioning schemes (§3.1): horizontal (AccuGraph,
//! HitGraph, ReGraph), vertical (ThunderGP) and interval-shard
//! (ForeGraph, after GridGraph).
//!
//! All schemes divide the vertex set into equal intervals whose size
//! is bounded by the accelerator's on-chip (BRAM) capacity. The paper
//! works with a 1,024,000-value BRAM budget for AccuGraph; our
//! workloads are scaled by ~64x (DESIGN.md §6), so the default scaled
//! capacity is 16,384 values and the ForeGraph interval is 1,024
//! (paper: 65,536).

pub mod horizontal;
pub mod interval_shard;
pub mod vertical;

pub use horizontal::HorizontalPartitioning;
pub use interval_shard::IntervalShardPartitioning;
pub use vertical::VerticalPartitioning;

use crate::accel::AcceleratorKind;
use std::fmt;

/// The three partitioning families of §3.1, as a value the advisor
/// ([`crate::advisor`]) can recommend and report on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PartitionScheme {
    /// Destination-interval rows (AccuGraph, HitGraph).
    Horizontal,
    /// Source-interval columns (ThunderGP).
    Vertical,
    /// 2-D interval-shard grid (ForeGraph, after GridGraph).
    IntervalShard,
}

impl PartitionScheme {
    /// The scheme an accelerator's architecture fixes (Tab. 1): the
    /// choice is not free per run — it is baked into each design's
    /// datapath — so the advisor reports it with the capacity that
    /// balances the partitions rather than picking across schemes.
    pub fn for_accelerator(kind: AcceleratorKind) -> PartitionScheme {
        match kind {
            AcceleratorKind::AccuGraph
            | AcceleratorKind::HitGraph
            | AcceleratorKind::ReGraph => PartitionScheme::Horizontal,
            AcceleratorKind::ThunderGp => PartitionScheme::Vertical,
            AcceleratorKind::ForeGraph => PartitionScheme::IntervalShard,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PartitionScheme::Horizontal => "horizontal",
            PartitionScheme::Vertical => "vertical",
            PartitionScheme::IntervalShard => "interval-shard",
        }
    }
}

impl fmt::Display for PartitionScheme {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Scaled stand-in for the 1,024,000-vertex BRAM budget of the paper.
pub const SCALED_BRAM_VALUES: usize = 16_384;

/// Scaled stand-in for ForeGraph's 65,536-vertex interval.
pub const SCALED_FOREGRAPH_INTERVAL: usize = 1_024;

/// A contiguous vertex interval `[start, end)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Interval {
    pub start: u32,
    pub end: u32,
}

impl Interval {
    #[inline]
    pub fn len(&self) -> usize {
        (self.end - self.start) as usize
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }

    #[inline]
    pub fn contains(&self, v: u32) -> bool {
        v >= self.start && v < self.end
    }
}

/// Split `n` vertices into `ceil(n / cap)` equal intervals of at most
/// `cap` vertices.
pub fn intervals(n: usize, cap: usize) -> Vec<Interval> {
    assert!(cap > 0);
    if n == 0 {
        return vec![];
    }
    let k = (n + cap - 1) / cap;
    let per = (n + k - 1) / k;
    (0..k)
        .map(|i| Interval {
            start: (i * per) as u32,
            end: ((i + 1) * per).min(n) as u32,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intervals_cover_and_disjoint() {
        for (n, cap) in [(100, 30), (100, 100), (100, 101), (1, 5), (16384, 16384)] {
            let iv = intervals(n, cap);
            assert!(!iv.is_empty());
            assert_eq!(iv[0].start, 0);
            assert_eq!(iv.last().unwrap().end as usize, n);
            for w in iv.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
            for i in &iv {
                assert!(i.len() <= cap);
            }
        }
        assert!(intervals(0, 10).is_empty());
    }

    #[test]
    fn single_partition_when_fits() {
        assert_eq!(intervals(1000, 16384).len(), 1);
        assert_eq!(intervals(16384, 16384).len(), 1);
        assert_eq!(intervals(16385, 16384).len(), 2);
    }
}
