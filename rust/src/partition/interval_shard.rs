//! Interval-shard partitioning (ForeGraph, after GridGraph [29]):
//! vertical and horizontal at once. The vertex set is cut into `q`
//! intervals of at most `I` vertices; shard `(i, j)` holds the edges
//! from interval `i` to interval `j`, stored as *compressed* 32-bit
//! edges — two 16-bit interval-local vertex ids (§3.2.2), possible
//! because `I <= 65,536`.

use super::Interval;
use crate::graph::edgelist::EdgeList;

/// A compressed edge: interval-local source and destination ids.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CompressedEdge {
    pub src_local: u16,
    pub dst_local: u16,
}

/// Interval-shard partitioned graph.
#[derive(Clone, Debug)]
pub struct IntervalShardPartitioning {
    pub intervals: Vec<Interval>,
    /// `shards[i][j]` = compressed edges interval i -> interval j.
    pub shards: Vec<Vec<Vec<CompressedEdge>>>,
    pub interval_size: usize,
}

impl IntervalShardPartitioning {
    /// Build with intervals of at most `interval_size` vertices
    /// (<= 65,536 for the 16-bit compression to be valid).
    pub fn new(g: &EdgeList, interval_size: usize) -> Self {
        assert!(interval_size <= 65_536, "16-bit ids need intervals <= 65,536");
        let intervals = super::intervals(g.num_vertices, interval_size);
        let per = intervals.first().map_or(1, |i| i.len().max(1));
        let q = intervals.len();
        let mut shards: Vec<Vec<Vec<CompressedEdge>>> = vec![vec![Vec::new(); q]; q];
        for e in &g.edges {
            let i = e.src as usize / per;
            let j = e.dst as usize / per;
            shards[i][j].push(CompressedEdge {
                src_local: (e.src as usize - intervals[i].start as usize) as u16,
                dst_local: (e.dst as usize - intervals[j].start as usize) as u16,
            });
        }
        IntervalShardPartitioning {
            intervals,
            shards,
            interval_size,
        }
    }

    pub fn num_intervals(&self) -> usize {
        self.intervals.len()
    }

    pub fn total_edges(&self) -> usize {
        self.shards
            .iter()
            .flat_map(|row| row.iter())
            .map(|s| s.len())
            .sum()
    }

    /// Decompress an edge of shard `(i, j)` back to global ids.
    pub fn globalize(&self, i: usize, j: usize, e: CompressedEdge) -> (u32, u32) {
        (
            self.intervals[i].start + e.src_local as u32,
            self.intervals[j].start + e.dst_local as u32,
        )
    }

    /// Bytes per edge in the compressed representation (4 B — insight 2).
    pub const EDGE_BYTES: u64 = 4;

    /// Per-destination-interval edge counts (partition-skew metric:
    /// the paper's Fig. 9(d) discussion — interval-shard introduces
    /// "many more edges read than necessary" for skewed shards).
    pub fn shard_sizes(&self) -> Vec<Vec<usize>> {
        self.shards
            .iter()
            .map(|row| row.iter().map(|s| s.len()).collect())
            .collect()
    }

    /// Coefficient of variation of shard sizes — a scalar skew measure.
    pub fn shard_skew(&self) -> f64 {
        let sizes: Vec<f64> = self
            .shards
            .iter()
            .flat_map(|row| row.iter())
            .map(|s| s.len() as f64)
            .collect();
        let m = crate::util::stats::mean(&sizes);
        if m == 0.0 {
            return 0.0;
        }
        crate::util::stats::std_dev(&sizes) / m
    }
}

/// Stride mapping (the `Map.` optimization): rename vertices so that
/// intervals are "sets of vertices with a constant stride instead of
/// consecutive vertices". With `q` intervals, vertex `v` maps to
/// interval `v % q`, slot `v / q` — spreading hubs across intervals.
pub fn stride_permutation(n: usize, num_intervals: usize) -> Vec<u32> {
    if n == 0 {
        return vec![];
    }
    let q = num_intervals.max(1);
    // Count residue-class sizes, then assign dense prefix offsets so
    // the mapping stays bijective when `n % q != 0`.
    let mut count = vec![0usize; q];
    for v in 0..n {
        count[v % q] += 1;
    }
    let mut offset = vec![0usize; q];
    for i in 1..q {
        offset[i] = offset[i - 1] + count[i - 1];
    }
    let mut perm = vec![0u32; n];
    for v in 0..n {
        perm[v] = (offset[v % q] + v / q) as u32;
    }
    perm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synthetic::{erdos_renyi, preferential_attachment};

    #[test]
    fn edge_conservation_and_compression_roundtrip() {
        let g = erdos_renyi(3000, 15000, 1);
        let p = IntervalShardPartitioning::new(&g, 1024);
        assert_eq!(p.total_edges(), 15000);
        assert_eq!(p.num_intervals(), 3);
        // Round-trip every edge of one shard through compression.
        let mut found = 0;
        for i in 0..p.num_intervals() {
            for j in 0..p.num_intervals() {
                for &ce in &p.shards[i][j] {
                    let (s, d) = p.globalize(i, j, ce);
                    assert!(p.intervals[i].contains(s));
                    assert!(p.intervals[j].contains(d));
                    found += 1;
                }
            }
        }
        assert_eq!(found, 15000);
    }

    #[test]
    #[should_panic(expected = "65,536")]
    fn rejects_oversized_intervals() {
        let g = erdos_renyi(10, 10, 1);
        IntervalShardPartitioning::new(&g, 100_000);
    }

    #[test]
    fn stride_permutation_is_bijective() {
        for (n, q) in [(100, 4), (103, 4), (1, 1), (1024, 16), (5138, 6), (7, 3)] {
            let perm = stride_permutation(n, q);
            let mut seen = vec![false; n];
            for &x in &perm {
                assert!(!seen[x as usize]);
                seen[x as usize] = true;
            }
            assert!(seen.iter().all(|&b| b));
        }
    }

    #[test]
    fn stride_mapping_reduces_shard_skew_on_skewed_graph() {
        let g = preferential_attachment(4096, 8, 7);
        let before = IntervalShardPartitioning::new(&g, 512).shard_skew();
        let perm = stride_permutation(g.num_vertices, 8);
        let after = IntervalShardPartitioning::new(&g.renamed(&perm), 512).shard_skew();
        // PA graphs concentrate hubs at low ids; striding spreads them.
        assert!(
            after < before,
            "stride mapping should reduce skew: {before} -> {after}"
        );
    }

    #[test]
    fn compressed_edge_is_4_bytes() {
        assert_eq!(std::mem::size_of::<CompressedEdge>(), 4);
        assert_eq!(IntervalShardPartitioning::EDGE_BYTES, 4);
    }
}
