//! Horizontal partitioning: "dividing up the vertex set into equal
//! intervals and letting each partition contain the outgoing edges of
//! one interval" (§3.1).
//!
//! * HitGraph uses a horizontally partitioned **edge list**: partition
//!   `q` holds the edges whose *source* lies in interval `q`.
//! * AccuGraph uses a horizontally partitioned **in-CSR** of the
//!   inverted graph: partition `q` holds, for *every* destination
//!   vertex, the in-neighbors that lie in interval `q` — which is why
//!   each AccuGraph partition needs `n + 1` CSR pointers (insight 4).

use super::Interval;
use crate::graph::edgelist::{Edge, EdgeList};

/// Horizontally partitioned edge list (HitGraph flavor).
#[derive(Clone, Debug)]
pub struct HorizontalPartitioning {
    pub intervals: Vec<Interval>,
    /// Edges per partition (source in the interval).
    pub edges: Vec<Vec<Edge>>,
}

impl HorizontalPartitioning {
    pub fn new(g: &EdgeList, cap: usize) -> Self {
        let intervals = super::intervals(g.num_vertices, cap);
        let per = intervals.first().map_or(1, |i| i.len().max(1));
        let mut edges: Vec<Vec<Edge>> = vec![Vec::new(); intervals.len()];
        for e in &g.edges {
            let q = e.src as usize / per;
            edges[q].push(*e);
        }
        HorizontalPartitioning { intervals, edges }
    }

    pub fn num_partitions(&self) -> usize {
        self.intervals.len()
    }

    /// Sort each partition's edges by destination (HitGraph `Sort`
    /// optimization: gather-phase write locality + update combining).
    pub fn sort_by_dst(&mut self) {
        for part in &mut self.edges {
            part.sort_by_key(|e| (e.dst, e.src));
        }
    }

    pub fn total_edges(&self) -> usize {
        self.edges.iter().map(|p| p.len()).sum()
    }
}

/// Horizontally partitioned in-CSR (AccuGraph flavor): one CSR per
/// partition over all `n` destinations, neighbors restricted to
/// sources in the partition interval.
#[derive(Clone, Debug)]
pub struct HorizontalInCsr {
    pub intervals: Vec<Interval>,
    /// Per partition: `n + 1` offsets.
    pub offsets: Vec<Vec<u32>>,
    /// Per partition: in-neighbors (sources) of each destination.
    pub neighbors: Vec<Vec<u32>>,
}

impl HorizontalInCsr {
    pub fn new(g: &EdgeList, cap: usize) -> Self {
        let n = g.num_vertices;
        let intervals = super::intervals(n, cap);
        let per = intervals.first().map_or(1, |i| i.len().max(1));
        let k = intervals.len();
        let mut counts = vec![vec![0u32; n + 1]; k];
        for e in &g.edges {
            let q = e.src as usize / per;
            counts[q][e.dst as usize + 1] += 1;
        }
        let mut offsets = Vec::with_capacity(k);
        let mut neighbors = Vec::with_capacity(k);
        for q in 0..k {
            for i in 0..n {
                counts[q][i + 1] += counts[q][i];
            }
            let offs = counts[q].clone();
            let total = offs.last().copied().unwrap_or(0) as usize;
            neighbors.push(vec![0u32; total]);
            offsets.push(offs);
        }
        let mut cursor: Vec<Vec<u32>> = offsets.clone();
        for e in &g.edges {
            let q = e.src as usize / per;
            let pos = cursor[q][e.dst as usize] as usize;
            neighbors[q][pos] = e.src;
            cursor[q][e.dst as usize] += 1;
        }
        HorizontalInCsr {
            intervals,
            offsets,
            neighbors,
        }
    }

    pub fn num_partitions(&self) -> usize {
        self.intervals.len()
    }

    /// In-neighbors of `dst` from partition `q`.
    pub fn neighbors_of(&self, q: usize, dst: u32) -> &[u32] {
        let s = self.offsets[q][dst as usize] as usize;
        let e = self.offsets[q][dst as usize + 1] as usize;
        &self.neighbors[q][s..e]
    }

    /// Edges stored in partition `q`.
    pub fn partition_edges(&self, q: usize) -> usize {
        self.neighbors[q].len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synthetic::erdos_renyi;

    #[test]
    fn edge_conservation() {
        let g = erdos_renyi(1000, 5000, 1);
        let p = HorizontalPartitioning::new(&g, 256);
        assert_eq!(p.total_edges(), 5000);
        assert_eq!(p.num_partitions(), 4); // 1000/256 -> 4 intervals of 250
        // every edge's source is inside its interval
        for (q, part) in p.edges.iter().enumerate() {
            for e in part {
                assert!(p.intervals[q].contains(e.src));
            }
        }
    }

    #[test]
    fn single_partition_small_graph() {
        let g = erdos_renyi(100, 300, 2);
        let p = HorizontalPartitioning::new(&g, 16384);
        assert_eq!(p.num_partitions(), 1);
    }

    #[test]
    fn in_csr_partition_semantics() {
        // edges: 0->2, 1->2, 3->2 with cap 2 -> intervals [0,2) [2,4)
        let mut g = EdgeList::new(4, true);
        g.add(0, 2);
        g.add(1, 2);
        g.add(3, 2);
        let p = HorizontalInCsr::new(&g, 2);
        assert_eq!(p.num_partitions(), 2);
        assert_eq!(p.neighbors_of(0, 2), &[0, 1]); // sources in [0,2)
        assert_eq!(p.neighbors_of(1, 2), &[3]); // sources in [2,4)
        assert_eq!(p.neighbors_of(0, 0), &[] as &[u32]);
        assert_eq!(p.partition_edges(0) + p.partition_edges(1), 3);
    }

    #[test]
    fn in_csr_pointer_array_is_n_plus_1_per_partition() {
        let g = erdos_renyi(500, 2000, 3);
        let p = HorizontalInCsr::new(&g, 100);
        for offs in &p.offsets {
            assert_eq!(offs.len(), 501); // insight 4: n + 1 per partition
        }
    }

    #[test]
    fn sort_by_dst_orders_within_partition() {
        let g = erdos_renyi(200, 1000, 4);
        let mut p = HorizontalPartitioning::new(&g, 64);
        p.sort_by_dst();
        for part in &p.edges {
            assert!(part.windows(2).all(|w| w[0].dst <= w[1].dst));
        }
    }
}
