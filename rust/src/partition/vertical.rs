//! Vertical partitioning (ThunderGP, §3.1): the vertex set is divided
//! into intervals and partition `q` contains the *incoming* edges of
//! interval `q`. Each partition is further split into `p` chunks, one
//! per memory channel; every channel holds a full copy of the vertex
//! value array (insights 8 and 9).

use super::Interval;
use crate::graph::edgelist::{Edge, EdgeList};

/// Vertically partitioned, chunked edge list.
#[derive(Clone, Debug)]
pub struct VerticalPartitioning {
    pub intervals: Vec<Interval>,
    /// `edges[q][c]` = chunk `c` of partition `q` (destination in
    /// interval `q`). Chunks are contiguous ranges of the partition's
    /// source-sorted edge list.
    pub chunks: Vec<Vec<Vec<Edge>>>,
    pub num_channels: usize,
}

impl VerticalPartitioning {
    /// Build with intervals of at most `cap` destinations, `channels`
    /// chunks per partition. Edges inside a partition are sorted by
    /// source vertex (ThunderGP's "sorted edge list", Tab. 1), which
    /// makes scatter-gather source reads semi-sequential.
    pub fn new(g: &EdgeList, cap: usize, channels: usize) -> Self {
        assert!(channels >= 1);
        let intervals = super::intervals(g.num_vertices, cap);
        let per = intervals.first().map_or(1, |i| i.len().max(1));
        let mut parts: Vec<Vec<Edge>> = vec![Vec::new(); intervals.len()];
        for e in &g.edges {
            parts[e.dst as usize / per].push(*e);
        }
        let mut chunks = Vec::with_capacity(parts.len());
        for mut part in parts {
            part.sort_by_key(|e| (e.src, e.dst));
            let m = part.len();
            let per_chunk = (m + channels - 1) / channels.max(1);
            let mut cs: Vec<Vec<Edge>> = Vec::with_capacity(channels);
            for c in 0..channels {
                let s = (c * per_chunk).min(m);
                let e = ((c + 1) * per_chunk).min(m);
                cs.push(part[s..e].to_vec());
            }
            chunks.push(cs);
        }
        VerticalPartitioning {
            intervals,
            chunks,
            num_channels: channels,
        }
    }

    pub fn num_partitions(&self) -> usize {
        self.intervals.len()
    }

    pub fn total_edges(&self) -> usize {
        self.chunks
            .iter()
            .flat_map(|p| p.iter())
            .map(|c| c.len())
            .sum()
    }

    /// Edges of partition `q`, chunk `c`.
    pub fn chunk(&self, q: usize, c: usize) -> &[Edge] {
        &self.chunks[q][c]
    }

    /// ThunderGP memory footprint in vertex-value units:
    /// `n*c + m + n*c` (insight 9).
    pub fn footprint_values(&self, n: usize) -> usize {
        2 * n * self.num_channels + self.total_edges()
    }

    /// Greedy offline chunk scheduling (the `Schd.` optimization):
    /// re-balance chunks across channels by predicted execution time
    /// (~ edge count), assigning the largest chunk to the least-loaded
    /// channel. Returns per-partition chunk->channel maps.
    pub fn schedule_chunks(&self) -> Vec<Vec<usize>> {
        self.chunks
            .iter()
            .map(|part| {
                let mut order: Vec<usize> = (0..part.len()).collect();
                order.sort_by_key(|&c| std::cmp::Reverse(part[c].len()));
                let mut load = vec![0usize; self.num_channels];
                let mut assign = vec![0usize; part.len()];
                for c in order {
                    let target = (0..self.num_channels)
                        .min_by_key(|&ch| load[ch])
                        .unwrap();
                    assign[c] = target;
                    load[target] += part[c].len();
                }
                assign
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synthetic::erdos_renyi;

    #[test]
    fn edge_conservation_and_dst_locality() {
        let g = erdos_renyi(1000, 8000, 1);
        let p = VerticalPartitioning::new(&g, 256, 4);
        assert_eq!(p.total_edges(), 8000);
        for (q, part) in p.chunks.iter().enumerate() {
            for chunk in part {
                for e in chunk {
                    assert!(p.intervals[q].contains(e.dst), "dst in interval");
                }
            }
        }
    }

    #[test]
    fn chunks_sorted_by_source() {
        let g = erdos_renyi(500, 4000, 2);
        let p = VerticalPartitioning::new(&g, 128, 2);
        for part in &p.chunks {
            for chunk in part {
                assert!(chunk.windows(2).all(|w| w[0].src <= w[1].src));
            }
        }
    }

    #[test]
    fn footprint_scales_with_channels() {
        let g = erdos_renyi(1000, 8000, 3);
        let p1 = VerticalPartitioning::new(&g, 256, 1);
        let p4 = VerticalPartitioning::new(&g, 256, 4);
        // n*c + m + n*c: channel term grows linearly (insight 9)
        assert_eq!(p1.footprint_values(1000), 2 * 1000 + 8000);
        assert_eq!(p4.footprint_values(1000), 8 * 1000 + 8000);
    }

    #[test]
    fn scheduling_balances_load() {
        let g = erdos_renyi(1000, 10000, 4);
        let p = VerticalPartitioning::new(&g, 250, 4);
        let sched = p.schedule_chunks();
        assert_eq!(sched.len(), p.num_partitions());
        for (part, assign) in p.chunks.iter().zip(&sched) {
            let mut load = vec![0usize; 4];
            for (c, &ch) in assign.iter().enumerate() {
                load[ch] += part[c].len();
            }
            let max = *load.iter().max().unwrap();
            let min = *load.iter().min().unwrap();
            // chunks are near-equal already; schedule must not unbalance
            assert!(max - min <= part.iter().map(|c| c.len()).max().unwrap());
        }
    }
}
