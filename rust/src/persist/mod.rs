//! Durable, versioned, checksummed text serialization for the sim
//! types — plus the disk-backed result cache ([`CacheDir`]) layered
//! under [`crate::sim::Session`].
//!
//! The build is offline (vendored `anyhow`/`xla` only, no serde), so
//! the format is hand-rolled and deliberately boring: one line of
//! space-separated `key=value` tokens per object, strings
//! percent-escaped down to `[A-Za-z0-9._-]`, floats carried as
//! `f64::to_bits` hex so round trips are **bit-identical**, and every
//! multi-line artifact (cache entries, sweep manifests) framed by a
//! version header and a trailing FNV-1a checksum line.
//!
//! Three properties the whole service layer leans on:
//!
//! * **Canonical**: [`spec_to_line`] of equal [`SimSpec`]s is equal
//!   text (the builder already canonicalizes configs), so the spec
//!   line doubles as a cross-process memo key.
//! * **Total parsing**: corrupt, truncated, or version-mismatched
//!   input returns a typed [`PersistError`] — never a panic. The
//!   cache treats any error as a miss (recompute and rewrite); the
//!   server answers a malformed request with a typed error response.
//! * **Atomic writes**: [`CacheDir::store`] writes a temp file and
//!   `rename`s it into place, so a crashed or concurrent writer can
//!   leave at worst a stale temp file, never a torn entry.

mod cache;

pub use cache::CacheDir;

use crate::accel::{AcceleratorConfig, AcceleratorKind, Optimization};
use crate::algo::problem::ProblemKind;
use crate::dram::{
    ChannelDegrade, DramStats, FaultPlan, LatencySpikes, MemTech, TransientRetries,
};
use crate::graph::datasets::DatasetId;
use crate::graph::EdgeList;
use crate::onchip::{Geometry, OnChipConfig, OnChipStats};
use crate::robust::{
    BudgetResource, ChannelLoad, RunBudget, SimError, StallDiagnostics, StreamCursor,
};
use crate::sim::metrics::{AdvisorChoices, RunMetrics};
use crate::sim::{SimReport, SimSpec, Workload};
use crate::trace::{AccessPatternSummary, ChannelSummary, Histogram, Region, RegionSummary};
use std::fmt;
use std::time::Duration;

/// Version header of a cache entry. Bump on any format change: a
/// mismatched header is a parse error, which the cache treats as a
/// miss — old entries are recomputed and rewritten, never misread.
pub const ENTRY_HEADER: &str = "graphmem-cache v1";

/// Version header of a sweep manifest.
pub const MANIFEST_HEADER: &str = "graphmem-manifest v1";

/// Resolves a custom workload's name back to its edge list when
/// parsing a spec line. Named (Tab. 2) workloads never need one; the
/// parsed digest is verified against the resolved graph either way.
pub type GraphResolver<'a> = dyn Fn(&str) -> Option<EdgeList> + Sync + 'a;

/// The synthetic custom workloads the CLI mints by name: `rmat-small`
/// (the scale-10, edge-factor-8 Graph500 R-MAT quick-analysis graph)
/// and `rmat-small-w` (the same graph with the deterministic random
/// weights the CLI adds for SSSP/SpMV). Specs serialized over these
/// stay self-contained across processes — the serve daemon and
/// `sweep --from-manifest` both pass this as their [`GraphResolver`];
/// the digest check still guards against generator drift.
pub fn builtin_graphs(name: &str) -> Option<EdgeList> {
    use crate::graph::rmat::{self, RmatParams};
    let base = || rmat::generate(RmatParams::graph500(10, 8, 0x5A));
    match name {
        "rmat-small" => Some(base()),
        "rmat-small-w" => Some(base().with_random_weights(0x77EE, 64.0)),
        _ => None,
    }
}

/// Everything the parsers can reject. Deliberately stringly in the
/// detail positions — the consumer decision is always the same
/// (treat as miss / answer with a typed error), the detail is for
/// humans.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PersistError {
    /// The version header line was missing or not the expected one.
    Header(String),
    /// The trailing checksum disagrees with the content.
    Checksum { expected: u64, found: u64 },
    /// The artifact ended before its frame was complete.
    Truncated(&'static str),
    /// A required `key=value` token was absent.
    MissingField(&'static str),
    /// A token was present but malformed.
    Field { field: &'static str, detail: String },
    /// A token key outside the format (strict v1 parsing).
    UnknownKey(String),
    /// An enum name no parser recognizes.
    UnknownName { what: &'static str, name: String },
    /// The rebuilt spec failed builder validation.
    Spec(String),
    /// A custom workload resolved to different edges than were
    /// serialized (content digest mismatch).
    DigestMismatch { name: String, expected: u64, found: u64 },
    /// A custom workload with no [`GraphResolver`] to resolve it.
    UnresolvedWorkload(String),
}

impl fmt::Display for PersistError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PersistError::Header(got) => write!(f, "unrecognized header {got:?}"),
            PersistError::Checksum { expected, found } => write!(
                f,
                "checksum mismatch: stored {expected:016x}, content hashes to {found:016x}"
            ),
            PersistError::Truncated(what) => write!(f, "truncated input: missing {what}"),
            PersistError::MissingField(key) => write!(f, "missing field `{key}`"),
            PersistError::Field { field, detail } => write!(f, "bad field `{field}`: {detail}"),
            PersistError::UnknownKey(key) => write!(f, "unknown field `{key}`"),
            PersistError::UnknownName { what, name } => write!(f, "unknown {what} {name:?}"),
            PersistError::Spec(why) => write!(f, "spec rejected: {why}"),
            PersistError::DigestMismatch { name, expected, found } => write!(
                f,
                "custom workload {name:?} resolved to different edges: serialized digest \
                 {expected:016x}, resolved {found:016x}"
            ),
            PersistError::UnresolvedWorkload(name) => write!(
                f,
                "custom workload {name:?} needs a graph resolver to deserialize"
            ),
        }
    }
}

impl std::error::Error for PersistError {}

impl From<PersistError> for SimError {
    /// Persistence failures fold into the run-time taxonomy as
    /// invalid input, so the serve layer carries one error type.
    fn from(err: PersistError) -> SimError {
        SimError::InvalidInput(err.to_string())
    }
}

/// FNV-1a over raw bytes — the checksum of every framed artifact and
/// the filename hash of [`CacheDir`].
pub fn fnv1a(bytes: &[u8]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    for &b in bytes {
        h = (h ^ u64::from(b)).wrapping_mul(PRIME);
    }
    h
}

/// Percent-escape a string down to `[A-Za-z0-9._-]` so it fits in one
/// whitespace-free token.
pub fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for &b in s.as_bytes() {
        match b {
            b'A'..=b'Z' | b'a'..=b'z' | b'0'..=b'9' | b'.' | b'_' | b'-' => out.push(b as char),
            _ => {
                const HEX: &[u8; 16] = b"0123456789abcdef";
                out.push('%');
                out.push(HEX[usize::from(b >> 4)] as char);
                out.push(HEX[usize::from(b & 0xf)] as char);
            }
        }
    }
    out
}

/// Inverse of [`esc`]. Total: malformed escapes and invalid UTF-8
/// are errors, never panics.
pub fn unesc(s: &str) -> Result<String, PersistError> {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'%' {
            if i + 3 > bytes.len() {
                return Err(PersistError::Field {
                    field: "escape",
                    detail: format!("dangling escape in {s:?}"),
                });
            }
            let hi = (bytes[i + 1] as char).to_digit(16);
            let lo = (bytes[i + 2] as char).to_digit(16);
            match (hi, lo) {
                (Some(hi), Some(lo)) => out.push((hi * 16 + lo) as u8),
                _ => {
                    return Err(PersistError::Field {
                        field: "escape",
                        detail: format!("non-hex escape in {s:?}"),
                    })
                }
            }
            i += 3;
        } else {
            out.push(bytes[i]);
            i += 1;
        }
    }
    String::from_utf8(out).map_err(|_| PersistError::Field {
        field: "escape",
        detail: format!("escaped bytes in {s:?} are not UTF-8"),
    })
}

fn f64_hex(x: f64) -> String {
    format!("{:016x}", x.to_bits())
}

fn f64_from_hex(field: &'static str, v: &str) -> Result<f64, PersistError> {
    u64::from_str_radix(v, 16)
        .map(f64::from_bits)
        .map_err(|e| PersistError::Field { field, detail: format!("{v:?}: {e}") })
}

fn parse_num<T: std::str::FromStr>(field: &'static str, v: &str) -> Result<T, PersistError>
where
    T::Err: fmt::Display,
{
    v.parse::<T>()
        .map_err(|e| PersistError::Field { field, detail: format!("{v:?}: {e}") })
}

fn parse_bool(field: &'static str, v: &str) -> Result<bool, PersistError> {
    match v {
        "0" => Ok(false),
        "1" => Ok(true),
        other => Err(PersistError::Field {
            field,
            detail: format!("expected 0|1, got {other:?}"),
        }),
    }
}

fn join_u64<I: IntoIterator<Item = u64>>(vals: I) -> String {
    let strs: Vec<String> = vals.into_iter().map(|v| v.to_string()).collect();
    strs.join(",")
}

fn parse_u64_list(field: &'static str, v: &str) -> Result<Vec<u64>, PersistError> {
    if v.is_empty() {
        return Ok(Vec::new());
    }
    v.split(',').map(|part| parse_num(field, part)).collect()
}

/// `key=value` token bag with strict take-once semantics.
struct Tokens {
    pairs: Vec<(String, String)>,
}

impl Tokens {
    fn parse(line: &str) -> Result<Tokens, PersistError> {
        let mut pairs = Vec::new();
        for tok in line.split_ascii_whitespace() {
            let (k, v) = tok.split_once('=').ok_or_else(|| PersistError::Field {
                field: "token",
                detail: format!("{tok:?} is not key=value"),
            })?;
            pairs.push((k.to_string(), v.to_string()));
        }
        Ok(Tokens { pairs })
    }

    fn take(&mut self, key: &'static str) -> Result<String, PersistError> {
        let i = self
            .pairs
            .iter()
            .position(|(k, _)| k == key)
            .ok_or(PersistError::MissingField(key))?;
        Ok(self.pairs.swap_remove(i).1)
    }

    /// Strict v1 parsing: leftover keys are an error (a future-format
    /// entry must read as a miss, not as a silently narrowed value).
    fn finish(self) -> Result<(), PersistError> {
        match self.pairs.into_iter().next() {
            None => Ok(()),
            Some((k, _)) => Err(PersistError::UnknownKey(k)),
        }
    }
}

// ---------------------------------------------------------------------------
// SimSpec
// ---------------------------------------------------------------------------

/// Serialize a spec as one canonical line. Equal specs produce equal
/// lines (the builder canonicalizes configs), so this doubles as the
/// cross-process memo key and the manifest entry format.
pub fn spec_to_line(spec: &SimSpec) -> String {
    let cfg = spec.config();
    let graph = match spec.workload() {
        Workload::Named(id) => format!("named:{}", id.name()),
        Workload::Custom { name, digest, .. } => {
            format!("custom:{}:{digest:016x}", esc(name))
        }
    };
    let opts = if cfg.optimizations.is_empty() {
        "-".to_string()
    } else {
        let names: Vec<&str> = cfg.optimizations.iter().map(|o| o.name()).collect();
        names.join(",")
    };
    format!(
        "accel={} graph={} problem={} mem={} channels={} patterns={} opts={} bram={} \
         interval={} pes={} window={} xmc={} onchip={} budget={} faults={} verify={}",
        spec.accelerator().name(),
        graph,
        spec.problem().name(),
        spec.mem().name(),
        spec.channels(),
        u8::from(spec.patterns_enabled()),
        opts,
        cfg.bram_values,
        cfg.foregraph_interval,
        cfg.num_pes,
        cfg.window,
        u8::from(cfg.experimental_multichannel),
        onchip_value(spec.onchip()),
        budget_value(spec.budget()),
        faults_value(spec.faults()),
        u8::from(spec.verify_enabled()),
    )
}

/// Parse a spec line that holds a named (Tab. 2) workload. Custom
/// workloads error with [`PersistError::UnresolvedWorkload`]; use
/// [`spec_from_line_with`] to supply a resolver.
pub fn spec_from_line(line: &str) -> Result<SimSpec, PersistError> {
    spec_from_line_with(line, None)
}

/// Parse a spec line, resolving custom workloads through `resolver`.
/// The serialized content digest is verified against the resolved
/// graph, so a resolver that returns different edges is detected.
pub fn spec_from_line_with(
    line: &str,
    resolver: Option<&GraphResolver<'_>>,
) -> Result<SimSpec, PersistError> {
    let mut t = Tokens::parse(line)?;

    let accel_name = t.take("accel")?;
    let accel = AcceleratorKind::parse(&accel_name).ok_or(PersistError::UnknownName {
        what: "accelerator",
        name: accel_name.clone(),
    })?;

    let graph_v = t.take("graph")?;
    let workload = if let Some(name) = graph_v.strip_prefix("named:") {
        let id: DatasetId = name.parse().map_err(|_| PersistError::UnknownName {
            what: "dataset",
            name: name.to_string(),
        })?;
        Workload::Named(id)
    } else if let Some(rest) = graph_v.strip_prefix("custom:") {
        let (name_esc, digest_hex) = rest.rsplit_once(':').ok_or_else(|| PersistError::Field {
            field: "graph",
            detail: format!("custom workload {rest:?} lacks a digest"),
        })?;
        let name = unesc(name_esc)?;
        let expected = u64::from_str_radix(digest_hex, 16).map_err(|e| PersistError::Field {
            field: "graph",
            detail: format!("digest {digest_hex:?}: {e}"),
        })?;
        let resolver = resolver.ok_or_else(|| PersistError::UnresolvedWorkload(name.clone()))?;
        let graph = resolver(&name).ok_or_else(|| PersistError::UnresolvedWorkload(name.clone()))?;
        let workload = Workload::custom(name.clone(), graph);
        let found = match &workload {
            Workload::Custom { digest, .. } => *digest,
            Workload::Named(_) => unreachable!(),
        };
        if found != expected {
            return Err(PersistError::DigestMismatch { name, expected, found });
        }
        workload
    } else {
        return Err(PersistError::Field {
            field: "graph",
            detail: format!("expected named:<id> or custom:<name>:<digest>, got {graph_v:?}"),
        });
    };

    let problem_name = t.take("problem")?;
    let problem = ProblemKind::parse(&problem_name).ok_or(PersistError::UnknownName {
        what: "problem",
        name: problem_name.clone(),
    })?;

    let mem_name = t.take("mem")?;
    let mem: MemTech = mem_name.parse().map_err(|_| PersistError::UnknownName {
        what: "memory technology",
        name: mem_name.clone(),
    })?;

    let channels: usize = parse_num("channels", &t.take("channels")?)?;
    let patterns = parse_bool("patterns", &t.take("patterns")?)?;

    let opts_v = t.take("opts")?;
    let optimizations = if opts_v == "-" {
        Vec::new()
    } else {
        opts_v
            .split(',')
            .map(|name| {
                Optimization::parse(name).ok_or(PersistError::UnknownName {
                    what: "optimization",
                    name: name.to_string(),
                })
            })
            .collect::<Result<Vec<_>, _>>()?
    };
    let config = AcceleratorConfig {
        optimizations,
        bram_values: parse_num("bram", &t.take("bram")?)?,
        foregraph_interval: parse_num("interval", &t.take("interval")?)?,
        num_pes: parse_num("pes", &t.take("pes")?)?,
        // Normalized to the spec's channel axis by the builder.
        channels: 1,
        window: parse_num("window", &t.take("window")?)?,
        experimental_multichannel: parse_bool("xmc", &t.take("xmc")?)?,
    };

    let onchip = onchip_parse(&t.take("onchip")?)?;
    let budget = budget_parse(&t.take("budget")?)?;
    let faults = faults_parse(&t.take("faults")?)?;
    let verify = parse_bool("verify", &t.take("verify")?)?;
    t.finish()?;

    SimSpec::builder()
        .accelerator(accel)
        .workload(workload)
        .problem(problem)
        .mem(mem)
        .channels(channels)
        .config(config)
        .patterns(patterns)
        .onchip(onchip)
        .budget(budget)
        .faults(faults)
        .verify(verify)
        .build()
        .map_err(|e| PersistError::Spec(e.to_string()))
}

fn onchip_value(cfg: Option<&OnChipConfig>) -> String {
    let Some(c) = cfg else {
        return "-".to_string();
    };
    let regions: Vec<&str> = c.regions().iter().map(|r| r.name()).collect();
    let geom = match c.geometry() {
        Geometry::DirectMapped => "dm".to_string(),
        Geometry::SetAssociative { ways } => format!("sa{ways}"),
        Geometry::Scratchpad => "sp".to_string(),
    };
    format!(
        "r:{};c:{};g:{geom};l:{};w:{}",
        regions.join("+"),
        c.capacity_bytes(),
        c.hit_latency(),
        u8::from(c.write_allocate()),
    )
}

fn onchip_parse(v: &str) -> Result<Option<OnChipConfig>, PersistError> {
    if v == "-" {
        return Ok(None);
    }
    let mut regions = None;
    let mut capacity = None;
    let mut geometry = None;
    let mut latency = None;
    let mut write_allocate = None;
    for part in v.split(';') {
        let (tag, val) = part.split_once(':').ok_or_else(|| PersistError::Field {
            field: "onchip",
            detail: format!("part {part:?} is not tag:value"),
        })?;
        match tag {
            "r" => {
                regions = Some(
                    val.split('+')
                        .map(|name| {
                            name.parse::<Region>().map_err(|_| PersistError::UnknownName {
                                what: "region",
                                name: name.to_string(),
                            })
                        })
                        .collect::<Result<Vec<_>, _>>()?,
                );
            }
            "c" => capacity = Some(parse_num::<u64>("onchip", val)?),
            "g" => {
                geometry = Some(if val == "dm" {
                    Geometry::DirectMapped
                } else if val == "sp" {
                    Geometry::Scratchpad
                } else if let Some(ways) = val.strip_prefix("sa") {
                    Geometry::SetAssociative { ways: parse_num("onchip", ways)? }
                } else {
                    return Err(PersistError::UnknownName {
                        what: "geometry",
                        name: val.to_string(),
                    });
                });
            }
            "l" => latency = Some(parse_num::<u64>("onchip", val)?),
            "w" => write_allocate = Some(parse_bool("onchip", val)?),
            other => {
                return Err(PersistError::Field {
                    field: "onchip",
                    detail: format!("unknown part tag {other:?}"),
                })
            }
        }
    }
    let missing = |what| PersistError::Field {
        field: "onchip",
        detail: format!("missing part `{what}`"),
    };
    let cfg = OnChipConfig::new(
        capacity.ok_or_else(|| missing("c"))?,
        geometry.ok_or_else(|| missing("g"))?,
        regions.ok_or_else(|| missing("r"))?,
    )
    .with_hit_latency(latency.ok_or_else(|| missing("l"))?)
    .with_write_allocate(write_allocate.ok_or_else(|| missing("w"))?);
    Ok(Some(cfg))
}

fn budget_value(budget: Option<&RunBudget>) -> String {
    let Some(b) = budget else {
        return "-".to_string();
    };
    let opt = |v: Option<u64>| v.map_or("-".to_string(), |n| n.to_string());
    let wall = b
        .wall_deadline
        .map_or("-".to_string(), |d| format!("{}.{:09}", d.as_secs(), d.subsec_nanos()));
    format!("c:{};r:{};w:{wall}", opt(b.max_cycles), opt(b.max_requests))
}

fn budget_parse(v: &str) -> Result<Option<RunBudget>, PersistError> {
    if v == "-" {
        return Ok(None);
    }
    let mut budget = RunBudget::default();
    for part in v.split(';') {
        let (tag, val) = part.split_once(':').ok_or_else(|| PersistError::Field {
            field: "budget",
            detail: format!("part {part:?} is not tag:value"),
        })?;
        match (tag, val) {
            (_, "-") => {}
            ("c", val) => budget.max_cycles = Some(parse_num("budget", val)?),
            ("r", val) => budget.max_requests = Some(parse_num("budget", val)?),
            ("w", val) => {
                let (secs, nanos) = val.split_once('.').ok_or_else(|| PersistError::Field {
                    field: "budget",
                    detail: format!("wall deadline {val:?} is not secs.nanos"),
                })?;
                budget.wall_deadline = Some(Duration::new(
                    parse_num("budget", secs)?,
                    parse_num("budget", nanos)?,
                ));
            }
            (other, _) => {
                return Err(PersistError::Field {
                    field: "budget",
                    detail: format!("unknown part tag {other:?}"),
                })
            }
        }
    }
    Ok(Some(budget))
}

fn faults_value(faults: Option<&FaultPlan>) -> String {
    let Some(p) = faults else {
        return "-".to_string();
    };
    let spikes = p
        .spikes
        .map_or("-".to_string(), |s| format!("{},{}", s.period, s.extra_cycles));
    let degrade = p.degrade.map_or("-".to_string(), |d| {
        format!("{},{},{}", d.every, d.window, d.extra_cycles)
    });
    let retries = p.retries.map_or("-".to_string(), |r| {
        format!("{},{},{}", r.every, r.max_retries, r.backoff_cycles)
    });
    format!("s:{};sp:{spikes};dg:{degrade};rt:{retries}", p.seed)
}

fn faults_parse(v: &str) -> Result<Option<FaultPlan>, PersistError> {
    if v == "-" {
        return Ok(None);
    }
    let mut plan = FaultPlan::default();
    for part in v.split(';') {
        let (tag, val) = part.split_once(':').ok_or_else(|| PersistError::Field {
            field: "faults",
            detail: format!("part {part:?} is not tag:value"),
        })?;
        let triple = |val: &str| -> Result<Vec<u64>, PersistError> {
            let nums = parse_u64_list("faults", val)?;
            if nums.len() == 3 {
                Ok(nums)
            } else {
                Err(PersistError::Field {
                    field: "faults",
                    detail: format!("expected 3 numbers, got {val:?}"),
                })
            }
        };
        match (tag, val) {
            ("s", val) => plan.seed = parse_num("faults", val)?,
            (_, "-") => {}
            ("sp", val) => {
                let nums = parse_u64_list("faults", val)?;
                if nums.len() != 2 {
                    return Err(PersistError::Field {
                        field: "faults",
                        detail: format!("expected 2 numbers, got {val:?}"),
                    });
                }
                plan.spikes = Some(LatencySpikes { period: nums[0], extra_cycles: nums[1] });
            }
            ("dg", val) => {
                let nums = triple(val)?;
                plan.degrade = Some(ChannelDegrade {
                    every: nums[0],
                    window: nums[1],
                    extra_cycles: nums[2],
                });
            }
            ("rt", val) => {
                let nums = triple(val)?;
                let max_retries = u32::try_from(nums[1]).map_err(|_| PersistError::Field {
                    field: "faults",
                    detail: format!("retry bound {} exceeds u32", nums[1]),
                })?;
                plan.retries = Some(TransientRetries {
                    every: nums[0],
                    max_retries,
                    backoff_cycles: nums[2],
                });
            }
            (other, _) => {
                return Err(PersistError::Field {
                    field: "faults",
                    detail: format!("unknown part tag {other:?}"),
                })
            }
        }
    }
    Ok(Some(plan))
}

// ---------------------------------------------------------------------------
// SimReport
// ---------------------------------------------------------------------------

/// Number of flat counters a [`DramStats`] serializes to.
const DRAM_FIELDS: usize = 9 + 2 * Region::COUNT + 2;

/// Serialize a report as one line. Floats are carried as bit
/// patterns, so parsing reproduces the report **bit-identically**
/// (asserted by the round-trip suite).
pub fn report_to_line(r: &SimReport) -> String {
    let m = &r.metrics;
    let d = &r.dram;
    let mut dram: Vec<u64> = vec![
        d.reads,
        d.writes,
        d.row_hits,
        d.row_misses,
        d.row_conflicts,
        d.data_bus_cycles,
        d.refreshes,
        d.total_latency,
        d.finish_cycle,
    ];
    dram.extend_from_slice(&d.region_reads);
    dram.extend_from_slice(&d.region_writes);
    dram.push(d.faults_injected);
    dram.push(d.fault_delay_cycles);
    let advisor = r.advisor.as_ref().map_or("-".to_string(), |a| {
        format!(
            "{},{},{}",
            u8::from(a.partition),
            u8::from(a.placement),
            u8::from(a.onchip)
        )
    });
    format!(
        "accel={} problem={} edges={} cycles={} seconds={} iters={} eread={} vread={} \
         vwrite={} urw={} skip={} proc={} bytes={} util={} channels={} dram={} patterns={} \
         onchip={} advisor={advisor}",
        esc(r.accelerator),
        esc(r.problem),
        r.graph_edges,
        r.cycles,
        f64_hex(r.seconds),
        m.iterations,
        m.edges_read,
        m.values_read,
        m.values_written,
        m.updates_rw,
        m.skipped,
        m.processed,
        r.bytes_total,
        f64_hex(r.bus_utilization),
        r.channels,
        join_u64(dram),
        patterns_value(r.patterns.as_ref()),
        onchip_stats_value(r.onchip.as_ref()),
    )
}

/// Inverse of [`report_to_line`].
pub fn report_from_line(line: &str) -> Result<SimReport, PersistError> {
    let mut t = Tokens::parse(line)?;
    let accel_name = unesc(&t.take("accel")?)?;
    let accelerator = AcceleratorKind::parse(&accel_name)
        .ok_or(PersistError::UnknownName { what: "accelerator", name: accel_name })?
        .name();
    let problem_name = unesc(&t.take("problem")?)?;
    let problem = ProblemKind::parse(&problem_name)
        .ok_or(PersistError::UnknownName { what: "problem", name: problem_name })?
        .name();
    let graph_edges = parse_num("edges", &t.take("edges")?)?;
    let cycles = parse_num("cycles", &t.take("cycles")?)?;
    let seconds = f64_from_hex("seconds", &t.take("seconds")?)?;
    let metrics = RunMetrics {
        iterations: parse_num("iters", &t.take("iters")?)?,
        edges_read: parse_num("eread", &t.take("eread")?)?,
        values_read: parse_num("vread", &t.take("vread")?)?,
        values_written: parse_num("vwrite", &t.take("vwrite")?)?,
        updates_rw: parse_num("urw", &t.take("urw")?)?,
        skipped: parse_num("skip", &t.take("skip")?)?,
        processed: parse_num("proc", &t.take("proc")?)?,
    };
    let bytes_total = parse_num("bytes", &t.take("bytes")?)?;
    let bus_utilization = f64_from_hex("util", &t.take("util")?)?;
    let channels = parse_num("channels", &t.take("channels")?)?;
    let nums = parse_u64_list("dram", &t.take("dram")?)?;
    if nums.len() != DRAM_FIELDS {
        return Err(PersistError::Field {
            field: "dram",
            detail: format!("expected {DRAM_FIELDS} counters, got {}", nums.len()),
        });
    }
    let mut region_reads = [0u64; Region::COUNT];
    let mut region_writes = [0u64; Region::COUNT];
    region_reads.copy_from_slice(&nums[9..9 + Region::COUNT]);
    region_writes.copy_from_slice(&nums[9 + Region::COUNT..9 + 2 * Region::COUNT]);
    let dram = DramStats {
        reads: nums[0],
        writes: nums[1],
        row_hits: nums[2],
        row_misses: nums[3],
        row_conflicts: nums[4],
        data_bus_cycles: nums[5],
        refreshes: nums[6],
        total_latency: nums[7],
        finish_cycle: nums[8],
        region_reads,
        region_writes,
        faults_injected: nums[DRAM_FIELDS - 2],
        fault_delay_cycles: nums[DRAM_FIELDS - 1],
    };
    let patterns = patterns_parse(&t.take("patterns")?)?;
    let onchip = onchip_stats_parse(&t.take("onchip")?)?;
    let advisor_v = t.take("advisor")?;
    let advisor = if advisor_v == "-" {
        None
    } else {
        let parts: Vec<&str> = advisor_v.split(',').collect();
        if parts.len() != 3 {
            return Err(PersistError::Field {
                field: "advisor",
                detail: format!("expected 3 flags, got {advisor_v:?}"),
            });
        }
        Some(AdvisorChoices {
            partition: parse_bool("advisor", parts[0])?,
            placement: parse_bool("advisor", parts[1])?,
            onchip: parse_bool("advisor", parts[2])?,
        })
    };
    t.finish()?;
    Ok(SimReport {
        accelerator,
        problem,
        graph_edges,
        cycles,
        seconds,
        metrics,
        dram,
        bytes_total,
        bus_utilization,
        channels,
        patterns,
        onchip,
        advisor,
    })
}

fn hist_value(h: &Histogram) -> String {
    format!("{}:{}:{}", h.count(), h.sum(), join_u64(h.buckets().iter().copied()))
}

fn hist_parse(v: &str) -> Result<Histogram, PersistError> {
    let mut parts = v.splitn(3, ':');
    let (total, sum, counts) = match (parts.next(), parts.next(), parts.next()) {
        (Some(t), Some(s), Some(c)) => (t, s, c),
        _ => {
            return Err(PersistError::Field {
                field: "histogram",
                detail: format!("expected total:sum:counts, got {v:?}"),
            })
        }
    };
    Ok(Histogram::from_parts(
        parse_u64_list("histogram", counts)?,
        parse_num("histogram", total)?,
        parse_num("histogram", sum)?,
    ))
}

fn patterns_value(summary: Option<&AccessPatternSummary>) -> String {
    let Some(s) = summary else {
        return "-".to_string();
    };
    let regions: Vec<String> = s
        .regions
        .iter()
        .map(|r| {
            format!(
                "{};{};{};{};{};{};{};{};{};{};{};{};{}",
                r.region.name(),
                r.reads,
                r.writes,
                r.bytes,
                r.sequential,
                r.strided,
                r.random,
                r.row_hits,
                r.row_misses,
                r.row_conflicts,
                hist_value(&r.run_lengths),
                r.distinct_lines,
                hist_value(&r.reuse),
            )
        })
        .collect();
    let channels: Vec<String> = s
        .channels
        .iter()
        .map(|c| {
            format!(
                "{};{};{};{};{};{};{};{}",
                c.channel,
                c.reads,
                c.writes,
                c.row_hits,
                c.row_misses,
                c.row_conflicts,
                c.distinct_lines,
                hist_value(&c.reuse),
            )
        })
        .collect();
    format!("{}~{}", regions.join("/"), channels.join("/"))
}

fn patterns_parse(v: &str) -> Result<Option<AccessPatternSummary>, PersistError> {
    if v == "-" {
        return Ok(None);
    }
    let (regions_v, channels_v) = v.split_once('~').ok_or_else(|| PersistError::Field {
        field: "patterns",
        detail: format!("missing region/channel separator in {v:?}"),
    })?;
    let mut summary = AccessPatternSummary::default();
    if !regions_v.is_empty() {
        for entry in regions_v.split('/') {
            let p: Vec<&str> = entry.split(';').collect();
            if p.len() != 13 {
                return Err(PersistError::Field {
                    field: "patterns",
                    detail: format!("region entry has {} parts, expected 13", p.len()),
                });
            }
            summary.regions.push(RegionSummary {
                region: p[0].parse().map_err(|_| PersistError::UnknownName {
                    what: "region",
                    name: p[0].to_string(),
                })?,
                reads: parse_num("patterns", p[1])?,
                writes: parse_num("patterns", p[2])?,
                bytes: parse_num("patterns", p[3])?,
                sequential: parse_num("patterns", p[4])?,
                strided: parse_num("patterns", p[5])?,
                random: parse_num("patterns", p[6])?,
                row_hits: parse_num("patterns", p[7])?,
                row_misses: parse_num("patterns", p[8])?,
                row_conflicts: parse_num("patterns", p[9])?,
                run_lengths: hist_parse(p[10])?,
                distinct_lines: parse_num("patterns", p[11])?,
                reuse: hist_parse(p[12])?,
            });
        }
    }
    if !channels_v.is_empty() {
        for entry in channels_v.split('/') {
            let p: Vec<&str> = entry.split(';').collect();
            if p.len() != 8 {
                return Err(PersistError::Field {
                    field: "patterns",
                    detail: format!("channel entry has {} parts, expected 8", p.len()),
                });
            }
            summary.channels.push(ChannelSummary {
                channel: parse_num("patterns", p[0])?,
                reads: parse_num("patterns", p[1])?,
                writes: parse_num("patterns", p[2])?,
                row_hits: parse_num("patterns", p[3])?,
                row_misses: parse_num("patterns", p[4])?,
                row_conflicts: parse_num("patterns", p[5])?,
                distinct_lines: parse_num("patterns", p[6])?,
                reuse: hist_parse(p[7])?,
            });
        }
    }
    Ok(Some(summary))
}

fn onchip_stats_value(stats: Option<&OnChipStats>) -> String {
    let Some(s) = stats else {
        return "-".to_string();
    };
    let per_region = |f: &dyn Fn(Region) -> u64| join_u64(Region::all().into_iter().map(f));
    format!(
        "h:{};m:{};f:{};e:{};cap:{}",
        per_region(&|r| s.region_hits(r)),
        per_region(&|r| s.region_misses(r)),
        per_region(&|r| s.region_fills(r)),
        s.evictions(),
        s.capacity_lines(),
    )
}

fn onchip_stats_parse(v: &str) -> Result<Option<OnChipStats>, PersistError> {
    if v == "-" {
        return Ok(None);
    }
    let mut hits = None;
    let mut misses = None;
    let mut fills = None;
    let mut evictions = None;
    let mut capacity = None;
    let array = |val: &str| -> Result<[u64; Region::COUNT], PersistError> {
        let nums = parse_u64_list("onchip-stats", val)?;
        if nums.len() != Region::COUNT {
            return Err(PersistError::Field {
                field: "onchip-stats",
                detail: format!("expected {} counters, got {}", Region::COUNT, nums.len()),
            });
        }
        let mut arr = [0u64; Region::COUNT];
        arr.copy_from_slice(&nums);
        Ok(arr)
    };
    for part in v.split(';') {
        let (tag, val) = part.split_once(':').ok_or_else(|| PersistError::Field {
            field: "onchip-stats",
            detail: format!("part {part:?} is not tag:value"),
        })?;
        match tag {
            "h" => hits = Some(array(val)?),
            "m" => misses = Some(array(val)?),
            "f" => fills = Some(array(val)?),
            "e" => evictions = Some(parse_num::<u64>("onchip-stats", val)?),
            "cap" => capacity = Some(parse_num::<u64>("onchip-stats", val)?),
            other => {
                return Err(PersistError::Field {
                    field: "onchip-stats",
                    detail: format!("unknown part tag {other:?}"),
                })
            }
        }
    }
    let missing = |what| PersistError::Field {
        field: "onchip-stats",
        detail: format!("missing part `{what}`"),
    };
    Ok(Some(OnChipStats::from_parts(
        hits.ok_or_else(|| missing("h"))?,
        misses.ok_or_else(|| missing("m"))?,
        fills.ok_or_else(|| missing("f"))?,
        evictions.ok_or_else(|| missing("e"))?,
        capacity.ok_or_else(|| missing("cap"))?,
    )))
}

// ---------------------------------------------------------------------------
// SimError
// ---------------------------------------------------------------------------

/// Serialize a typed failure as one line (failure memos persist and
/// travel the wire exactly like reports).
pub fn error_to_line(err: &SimError) -> String {
    match err {
        SimError::Stalled(d) => {
            let streams = if d.streams.is_empty() {
                "-".to_string()
            } else {
                let parts: Vec<String> = d
                    .streams
                    .iter()
                    .map(|s| format!("{}:{}:{}", s.issued, s.len, s.available))
                    .collect();
                parts.join("/")
            };
            let chans = if d.channels.is_empty() {
                "-".to_string()
            } else {
                let parts: Vec<String> = d
                    .channels
                    .iter()
                    .map(|c| format!("{}:{}", c.in_flight, c.waiting))
                    .collect();
                parts.join("/")
            };
            format!(
                "kind=stalled cycle={} streams={streams} chans={chans}",
                d.last_progress_cycle
            )
        }
        SimError::BudgetExceeded { resource, limit, observed } => {
            let res = match resource {
                BudgetResource::Cycles => "cycles",
                BudgetResource::Requests => "requests",
                BudgetResource::WallMillis => "wall-ms",
            };
            format!("kind=budget-exceeded resource={res} limit={limit} observed={observed}")
        }
        SimError::InvalidInput(msg) => format!("kind=invalid-input msg={}", esc(msg)),
        SimError::Panicked { message } => format!("kind=panicked msg={}", esc(message)),
    }
}

/// Inverse of [`error_to_line`].
pub fn error_from_line(line: &str) -> Result<SimError, PersistError> {
    let mut t = Tokens::parse(line)?;
    let kind = t.take("kind")?;
    let err = match kind.as_str() {
        "stalled" => {
            let mut d = StallDiagnostics {
                last_progress_cycle: parse_num("cycle", &t.take("cycle")?)?,
                ..StallDiagnostics::default()
            };
            let streams_v = t.take("streams")?;
            if streams_v != "-" {
                for part in streams_v.split('/') {
                    let nums: Vec<&str> = part.split(':').collect();
                    if nums.len() != 3 {
                        return Err(PersistError::Field {
                            field: "streams",
                            detail: format!("cursor {part:?} is not issued:len:available"),
                        });
                    }
                    d.streams.push(StreamCursor {
                        issued: parse_num("streams", nums[0])?,
                        len: parse_num("streams", nums[1])?,
                        available: parse_num("streams", nums[2])?,
                    });
                }
            }
            let chans_v = t.take("chans")?;
            if chans_v != "-" {
                for part in chans_v.split('/') {
                    let (in_flight, waiting) =
                        part.split_once(':').ok_or_else(|| PersistError::Field {
                            field: "chans",
                            detail: format!("load {part:?} is not in_flight:waiting"),
                        })?;
                    d.channels.push(ChannelLoad {
                        in_flight: parse_num("chans", in_flight)?,
                        waiting: parse_num("chans", waiting)?,
                    });
                }
            }
            SimError::Stalled(d)
        }
        "budget-exceeded" => {
            let res_v = t.take("resource")?;
            let resource = match res_v.as_str() {
                "cycles" => BudgetResource::Cycles,
                "requests" => BudgetResource::Requests,
                "wall-ms" => BudgetResource::WallMillis,
                other => {
                    return Err(PersistError::UnknownName {
                        what: "budget resource",
                        name: other.to_string(),
                    })
                }
            };
            SimError::BudgetExceeded {
                resource,
                limit: parse_num("limit", &t.take("limit")?)?,
                observed: parse_num("observed", &t.take("observed")?)?,
            }
        }
        "invalid-input" => SimError::InvalidInput(unesc(&t.take("msg")?)?),
        "panicked" => SimError::Panicked { message: unesc(&t.take("msg")?)? },
        other => {
            return Err(PersistError::UnknownName {
                what: "error kind",
                name: other.to_string(),
            })
        }
    };
    t.finish()?;
    Ok(err)
}

// ---------------------------------------------------------------------------
// Framed artifacts: cache entries and sweep manifests
// ---------------------------------------------------------------------------

/// Render a complete cache-entry file: header, spec line, result line
/// (`ok …` or `err …`), trailing checksum over everything above it.
pub fn render_entry(spec: &SimSpec, result: &Result<SimReport, SimError>) -> String {
    let mut body = String::new();
    body.push_str(ENTRY_HEADER);
    body.push('\n');
    body.push_str("spec ");
    body.push_str(&spec_to_line(spec));
    body.push('\n');
    match result {
        Ok(report) => {
            body.push_str("ok ");
            body.push_str(&report_to_line(report));
        }
        Err(err) => {
            body.push_str("err ");
            body.push_str(&error_to_line(err));
        }
    }
    body.push('\n');
    let sum = fnv1a(body.as_bytes());
    body.push_str(&format!("checksum {sum:016x}\n"));
    body
}

/// Parse a cache-entry file back into its spec line and memoized
/// result. Total: truncation, bit flips, a foreign header, or any
/// malformed field is a [`PersistError`] — the cache treats every one
/// as a miss.
pub fn parse_entry(text: &str) -> Result<(String, Result<SimReport, SimError>), PersistError> {
    let body = verify_frame(text, ENTRY_HEADER)?;
    let mut lines = body.lines();
    let spec_line = lines
        .next()
        .and_then(|l| l.strip_prefix("spec "))
        .ok_or(PersistError::Truncated("spec line"))?;
    let result_line = lines.next().ok_or(PersistError::Truncated("result line"))?;
    if lines.next().is_some() {
        return Err(PersistError::Field {
            field: "entry",
            detail: "trailing lines after the result".to_string(),
        });
    }
    let result = if let Some(rest) = result_line.strip_prefix("ok ") {
        Ok(report_from_line(rest)?)
    } else if let Some(rest) = result_line.strip_prefix("err ") {
        Err(error_from_line(rest)?)
    } else {
        return Err(PersistError::Field {
            field: "entry",
            detail: format!("result line starts with neither `ok ` nor `err `: {result_line:?}"),
        });
    };
    Ok((spec_line.to_string(), result))
}

/// Checksum-verify a framed artifact and strip its header and
/// checksum line, returning the inner body.
fn verify_frame<'t>(text: &'t str, header: &str) -> Result<&'t str, PersistError> {
    let idx = text.rfind("\nchecksum ").ok_or(PersistError::Truncated("checksum line"))?;
    let (content, sum_line) = text.split_at(idx + 1);
    let sum_hex = sum_line
        .strip_prefix("checksum ")
        .ok_or(PersistError::Truncated("checksum line"))?
        .trim_end();
    let expected = u64::from_str_radix(sum_hex, 16).map_err(|e| PersistError::Field {
        field: "checksum",
        detail: format!("{sum_hex:?}: {e}"),
    })?;
    let found = fnv1a(content.as_bytes());
    if found != expected {
        return Err(PersistError::Checksum { expected, found });
    }
    let body = content
        .strip_prefix(header)
        .and_then(|rest| rest.strip_prefix('\n'))
        .ok_or_else(|| {
            PersistError::Header(content.lines().next().unwrap_or_default().to_string())
        })?;
    Ok(body)
}

/// Render a sweep manifest: one canonical spec line per entry,
/// framed like a cache entry. Replaying the manifest rebuilds the
/// exact spec list — same memo keys, bit-identical reports.
pub fn write_manifest(specs: &[SimSpec]) -> String {
    let mut body = String::new();
    body.push_str(MANIFEST_HEADER);
    body.push('\n');
    for spec in specs {
        body.push_str("spec ");
        body.push_str(&spec_to_line(spec));
        body.push('\n');
    }
    let sum = fnv1a(body.as_bytes());
    body.push_str(&format!("checksum {sum:016x}\n"));
    body
}

/// Parse a manifest of named-workload specs.
pub fn parse_manifest(text: &str) -> Result<Vec<SimSpec>, PersistError> {
    parse_manifest_with(text, None)
}

/// Parse a manifest, resolving custom workloads through `resolver`.
pub fn parse_manifest_with(
    text: &str,
    resolver: Option<&GraphResolver<'_>>,
) -> Result<Vec<SimSpec>, PersistError> {
    let body = verify_frame(text, MANIFEST_HEADER)?;
    let mut specs = Vec::new();
    for line in body.lines() {
        let spec_line = line
            .strip_prefix("spec ")
            .ok_or(PersistError::Truncated("spec line"))?;
        specs.push(spec_from_line_with(spec_line, resolver)?);
    }
    Ok(specs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> SimSpec {
        SimSpec::builder()
            .accelerator(AcceleratorKind::HitGraph)
            .graph(DatasetId::Sd)
            .problem(ProblemKind::Bfs)
            .build()
            .unwrap()
    }

    #[test]
    fn esc_round_trips_arbitrary_strings() {
        for s in ["", "plain", "has space", "a=b;c|d%e\nf", "ünïcode 🎈", "-"] {
            let e = esc(s);
            assert!(
                e.bytes().all(|b| b.is_ascii_alphanumeric()
                    || b == b'.'
                    || b == b'_'
                    || b == b'-'
                    || b == b'%'),
                "{e:?} has unsafe bytes"
            );
            assert!(!e.contains(' '));
            assert_eq!(unesc(&e).unwrap(), s);
        }
        assert!(unesc("%").is_err());
        assert!(unesc("%zz").is_err());
        assert!(unesc("%ff").is_err(), "lone 0xff is not UTF-8");
    }

    #[test]
    fn spec_line_is_canonical_and_round_trips() {
        let s = spec();
        let line = spec_to_line(&s);
        assert_eq!(line, spec_to_line(&s.clone()), "equal specs, equal lines");
        let back = spec_from_line(&line).unwrap();
        assert_eq!(back, s, "round trip is identity (same memo key)");
        assert_eq!(spec_to_line(&back), line);
    }

    #[test]
    fn report_round_trip_is_bit_identical() {
        let r = spec().run();
        let back = report_from_line(&report_to_line(&r)).unwrap();
        assert_eq!(back, r);
        assert_eq!(back.seconds.to_bits(), r.seconds.to_bits());
    }

    #[test]
    fn error_lines_round_trip_every_variant() {
        let errors = [
            SimError::Stalled(StallDiagnostics {
                last_progress_cycle: 99,
                streams: vec![StreamCursor { issued: 1, len: 3, available: 2 }],
                channels: vec![ChannelLoad { in_flight: 4, waiting: 5 }],
            }),
            SimError::Stalled(StallDiagnostics::default()),
            SimError::BudgetExceeded {
                resource: BudgetResource::WallMillis,
                limit: 10,
                observed: 22,
            },
            SimError::InvalidInput("spaces and = signs %".to_string()),
            SimError::Panicked { message: "index out of bounds: 9 > 3".to_string() },
        ];
        for err in errors {
            let line = error_to_line(&err);
            assert_eq!(error_from_line(&line).unwrap(), err, "{line}");
        }
    }

    #[test]
    fn entries_verify_and_reject_corruption() {
        let s = spec();
        let ok_entry = render_entry(&s, &Ok(s.run()));
        let (line, result) = parse_entry(&ok_entry).unwrap();
        assert_eq!(line, spec_to_line(&s));
        assert_eq!(result.unwrap(), s.run());

        // Truncation, bit flips and header swaps all error — never panic.
        assert!(parse_entry(&ok_entry[..ok_entry.len() / 2]).is_err());
        let mut flipped = ok_entry.clone().into_bytes();
        let mid = flipped.len() / 2;
        flipped[mid] ^= 0x01;
        match String::from_utf8(flipped) {
            Ok(text) => assert!(parse_entry(&text).is_err()),
            Err(_) => {} // non-UTF-8 never reaches the parser
        }
        let vmism = ok_entry.replace("graphmem-cache v1", "graphmem-cache v9");
        assert!(parse_entry(&vmism).is_err(), "version mismatch is a miss");
    }

    #[test]
    fn manifests_round_trip_spec_lists() {
        let specs = vec![
            spec(),
            SimSpec::builder()
                .accelerator(AcceleratorKind::AccuGraph)
                .graph(DatasetId::Sd)
                .problem(ProblemKind::PageRank)
                .build()
                .unwrap(),
        ];
        let text = write_manifest(&specs);
        assert_eq!(parse_manifest(&text).unwrap(), specs);
        assert_eq!(write_manifest(&parse_manifest(&text).unwrap()), text);
        assert!(parse_manifest(&text.replace("v1", "v2")).is_err());
    }

    #[test]
    fn custom_workloads_need_a_resolver_and_verify_digests() {
        use crate::graph::synthetic;
        let g = synthetic::erdos_renyi(64, 256, 11);
        let s = SimSpec::builder()
            .accelerator(AcceleratorKind::AccuGraph)
            .custom_graph("mine", g.clone())
            .problem(ProblemKind::Bfs)
            .build()
            .unwrap();
        let line = spec_to_line(&s);
        assert!(matches!(
            spec_from_line(&line),
            Err(PersistError::UnresolvedWorkload(_))
        ));
        let resolve = move |name: &str| (name == "mine").then(|| g.clone());
        let back = spec_from_line_with(&line, Some(&resolve)).unwrap();
        assert_eq!(back, s);
        // A resolver returning different edges is caught by the digest.
        let wrong = |_: &str| Some(synthetic::erdos_renyi(64, 256, 12));
        assert!(matches!(
            spec_from_line_with(&line, Some(&wrong)),
            Err(PersistError::DigestMismatch { .. })
        ));
    }
}
