//! Disk-backed result cache: one checksummed text file per memoized
//! `(SimSpec → Result<SimReport, SimError>)` entry, written atomically
//! (`tmp` + `rename`) so concurrent writers and crashes can never tear
//! an entry. Layered under [`crate::sim::Session`] via
//! [`Session::with_disk_cache`](crate::sim::Session::with_disk_cache),
//! it makes warm reports and failure memos survive restarts and lets
//! separate processes (CI runs, serve daemons) share one cache.
//!
//! Load is *total*: a missing, truncated, bit-flipped, foreign-version
//! or hash-colliding file reads as a **miss** — the caller recomputes
//! and rewrites, and correctness never depends on the cache's health.

use super::{fnv1a, parse_entry, render_entry, spec_to_line};
use crate::robust::SimError;
use crate::sim::{SimReport, SimSpec};
use std::fs;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// File extension of cache entries (anything else in the directory is
/// ignored, including abandoned temp files).
const ENTRY_EXT: &str = "gmc";

/// A directory of durable simulation results.
#[derive(Debug)]
pub struct CacheDir {
    root: PathBuf,
    /// Distinguishes concurrent temp files within one process; the
    /// pid distinguishes processes.
    seq: AtomicU64,
}

impl CacheDir {
    /// Open (creating if needed) a cache rooted at `root`.
    pub fn new(root: impl Into<PathBuf>) -> io::Result<CacheDir> {
        let root = root.into();
        fs::create_dir_all(&root)?;
        Ok(CacheDir { root, seq: AtomicU64::new(0) })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Where `spec`'s entry lives: the filename is the FNV-1a hash of
    /// the canonical spec line, so equal specs map to one file across
    /// processes. Collisions are survivable — `load` verifies the
    /// stored spec line and treats a mismatch as a miss.
    pub fn entry_path(&self, spec: &SimSpec) -> PathBuf {
        let hash = fnv1a(spec_to_line(spec).as_bytes());
        self.root.join(format!("r{hash:016x}.{ENTRY_EXT}"))
    }

    /// Fetch `spec`'s memoized result, or `None` on any miss:
    /// no file, unreadable file, checksum/version/parse failure, or a
    /// filename collision with a different spec. Never panics and
    /// never returns a result for the wrong spec.
    pub fn load(&self, spec: &SimSpec) -> Option<Result<SimReport, SimError>> {
        let text = fs::read_to_string(self.entry_path(spec)).ok()?;
        let (stored_line, result) = parse_entry(&text).ok()?;
        if stored_line != spec_to_line(spec) {
            return None;
        }
        Some(result)
    }

    /// True iff a valid entry for `spec` is on disk.
    pub fn contains(&self, spec: &SimSpec) -> bool {
        self.load(spec).is_some()
    }

    /// Durably store `spec`'s result. Atomic: the entry is rendered
    /// into a uniquely named temp file in the same directory and
    /// `rename`d over the final path, so readers see either the old
    /// entry or the new one, never a torn write. A failed store is
    /// reported but harmless — the cache just stays cold for this key.
    pub fn store(
        &self,
        spec: &SimSpec,
        result: &Result<SimReport, SimError>,
    ) -> io::Result<PathBuf> {
        let body = render_entry(spec, result);
        let path = self.entry_path(spec);
        let tmp = self.root.join(format!(
            ".tmp-{}-{}",
            std::process::id(),
            self.seq.fetch_add(1, Ordering::Relaxed)
        ));
        fs::write(&tmp, body)?;
        match fs::rename(&tmp, &path) {
            Ok(()) => Ok(path),
            Err(e) => {
                // Don't leave the temp file behind on a failed rename.
                let _ = fs::remove_file(&tmp);
                Err(e)
            }
        }
    }

    /// Number of entry files currently on disk (valid or not) —
    /// diagnostics only.
    pub fn len(&self) -> usize {
        let Ok(dir) = fs::read_dir(&self.root) else {
            return 0;
        };
        dir.filter_map(|e| e.ok())
            .filter(|e| {
                e.path().extension().map(|x| x == ENTRY_EXT).unwrap_or(false)
            })
            .count()
    }

    /// True iff no entry files are on disk.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::AcceleratorKind;
    use crate::algo::problem::ProblemKind;
    use crate::graph::datasets::DatasetId;
    use std::fs;

    fn tmp_root(tag: &str) -> PathBuf {
        let root = std::env::temp_dir().join(format!(
            "graphmem-cachedir-{tag}-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&root);
        root
    }

    fn spec() -> SimSpec {
        SimSpec::builder()
            .accelerator(AcceleratorKind::HitGraph)
            .graph(DatasetId::Sd)
            .problem(ProblemKind::Bfs)
            .build()
            .unwrap()
    }

    #[test]
    fn store_load_round_trip_and_miss_semantics() {
        let root = tmp_root("roundtrip");
        let cache = CacheDir::new(&root).unwrap();
        let s = spec();
        assert!(cache.load(&s).is_none(), "cold cache misses");
        assert!(cache.is_empty());

        let report = s.run();
        cache.store(&s, &Ok(report.clone())).unwrap();
        assert_eq!(cache.len(), 1);
        assert!(cache.contains(&s));
        assert_eq!(cache.load(&s).unwrap().unwrap(), report, "bit-identical");

        // A second cache on the same root shares the entry (restart /
        // cross-process durability).
        let other = CacheDir::new(&root).unwrap();
        assert_eq!(other.load(&s).unwrap().unwrap(), report);

        // Overwrite in place keeps exactly one file.
        cache.store(&s, &Ok(report.clone())).unwrap();
        assert_eq!(cache.len(), 1);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn corrupt_entries_read_as_misses() {
        let root = tmp_root("corrupt");
        let cache = CacheDir::new(&root).unwrap();
        let s = spec();
        let path = cache.store(&s, &Ok(s.run())).unwrap();

        // Truncation.
        let full = fs::read_to_string(&path).unwrap();
        fs::write(&path, &full[..full.len() / 3]).unwrap();
        assert!(cache.load(&s).is_none(), "truncated entry is a miss");

        // Bit flip.
        let mut bytes = full.clone().into_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x04;
        fs::write(&path, &bytes).unwrap();
        assert!(cache.load(&s).is_none(), "bit-flipped entry is a miss");

        // Version mismatch.
        fs::write(&path, full.replace("graphmem-cache v1", "graphmem-cache v0")).unwrap();
        assert!(cache.load(&s).is_none(), "foreign version is a miss");

        // Recompute-and-rewrite heals the entry.
        cache.store(&s, &Ok(s.run())).unwrap();
        assert_eq!(cache.load(&s).unwrap().unwrap(), s.run());
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn failure_memos_persist_too() {
        let root = tmp_root("failure");
        let cache = CacheDir::new(&root).unwrap();
        let s = spec();
        let err = SimError::Panicked { message: "model bug".to_string() };
        cache.store(&s, &Err(err.clone())).unwrap();
        assert_eq!(cache.load(&s).unwrap().unwrap_err(), err);
        let _ = fs::remove_dir_all(&root);
    }
}
