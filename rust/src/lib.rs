//! # graphmem
//!
// Library code returns typed errors; panics belong to tests. The
// offline form of this gate is `graphmem lint --src` (see
// `verify::srclint`), whose allowlist ratchets the grandfathered
// sites down; clippy enforces the same rule once a toolchain runs it
// (tests and benches are exempt via `allow-unwrap-in-tests` /
// `allow-expect-in-tests` in clippy.toml and the cfg guard here).
#![cfg_attr(not(test), warn(clippy::unwrap_used, clippy::expect_used))]
//!
//! Reproduction of *"Demystifying Memory Access Patterns of FPGA-Based
//! Graph Processing Accelerators"* (Dann, Ritter, Fröning, 2021).
//!
//! The crate provides:
//!
//! * [`dram`] — a cycle-level, multi-standard (DDR3 / DDR4 / HBM) DRAM
//!   timing simulator (a Ramulator-equivalent built from scratch) with
//!   row hit/miss/conflict accounting and bandwidth-utilization stats.
//! * [`graph`] — graph substrate: edge lists, (in-)CSR, the Graph500
//!   R-MAT generator, synthetic stand-ins for the paper's twelve
//!   benchmark graphs, and dataset property analysis (density, degree
//!   skewness, …).
//! * [`partition`] — the three partitioning schemes used by the studied
//!   accelerators: horizontal, vertical, and interval-shard.
//! * [`algo`] — the five graph problems (BFS, PR, WCC, SSSP, SpMV) as
//!   value semantics plus golden reference executors for the paper's
//!   three update-propagation schemes.
//! * [`accel`] — memory-access-pattern models of the four accelerators:
//!   AccuGraph, HitGraph, ForeGraph, ThunderGP, with every optimization
//!   the paper ablates (prefetch/partition/shard skipping, edge
//!   shuffling, stride mapping, edge sorting, update combining, update
//!   filtering, chunk scheduling). Each model is split compile/execute:
//!   [`accel::program::PhaseProgram`] freezes the iteration-invariant,
//!   memory-independent artifacts once per workload and is replayed by
//!   `Arc` reference across sweeps.
//! * [`trace`] — the access-pattern analysis subsystem: every off-chip
//!   request carries a [`trace::Region`] tag (edges / vertices /
//!   updates / payload) stamped at issue time, and the streaming
//!   [`trace::AccessPatternAnalyzer`] turns issue-order event streams
//!   (live simulations or written trace files — identical results)
//!   into per-region traffic, sequentiality and row-locality
//!   summaries: the paper's Figs. 8–11 analysis as a library.
//! * [`onchip`] — the on-chip vertex-buffer (BRAM) model: a
//!   configurable line-granular buffer (direct-mapped / set-associative
//!   / scratchpad over a byte budget, per [`trace::Region`]) the phase
//!   driver consults before every request — hits retire on chip and
//!   never reach DRAM. Closes the loop on the analyzer's reuse
//!   histograms: [`trace::RegionSummary::predicted_hit_rate`] predicts
//!   the buffer's hit rate from a streaming-only run.
//! * [`advisor`] — the measure→act step the paper stops short of: a
//!   cheap pattern-collecting probe feeds an explainable cost model
//!   that recommends partition capacity, channel placement and
//!   per-region on-chip budgets, each with a predicted cost and a
//!   rationale naming the histogram evidence. Resolved at build time
//!   via the `auto_*` flags on [`sim::SimSpecBuilder`], validated
//!   against sweep optima by `Sweep::validate_advisor`, printed by
//!   `graphmem advise`.
//! * [`sim`] — the typed session API and the co-simulation engine:
//!   [`sim::SimSpec`] describes one run (accelerator × workload ×
//!   problem × memory technology × channels × configuration) with all
//!   invalid combinations rejected at build time; [`sim::Sweep`] /
//!   [`sim::Session`] execute whole cartesian products in parallel
//!   against a shared memoizing cache; [`sim::driver`] marries
//!   accelerator request producers to the DRAM model and produces the
//!   paper's metric set (MTEPS, MREPS, iterations, bytes/edge, …).
//! * [`engine`] + [`runtime`] — the golden algorithm engine, available
//!   as a pure-Rust implementation and as an AOT-compiled JAX/Pallas
//!   artifact executed through PJRT (the `xla` crate). Python is only
//!   ever used at build time.
//! * [`coordinator`] + [`report`] — experiment registry covering every
//!   figure and table of the paper's evaluation (each expressed as
//!   `SimSpec` sweeps over a shared session), and table/figure
//!   formatters.
//! * [`robust`] — typed failures ([`robust::SimError`]) with stall
//!   diagnostics, per-run budgets enforced by a watchdog in the phase
//!   driver, and the panic-capture boundary that lets sweeps return
//!   per-spec outcomes instead of crashing; [`dram::FaultPlan`] is the
//!   matching seeded fault injector that perturbs DRAM timing to prove
//!   the engine livelock-free under degraded memory.
//! * [`persist`] — versioned, checksummed text serialization for
//!   [`sim::SimSpec`] / [`sim::SimReport`] / [`robust::SimError`]
//!   (bit-identical round trips, no serde) plus the atomic-write
//!   disk cache [`persist::CacheDir`] layered under [`sim::Session`]:
//!   warm reports and failure memos survive restarts and are shared
//!   across processes. Spec serialization also yields reproducible
//!   sweep manifests (`graphmem sweep --manifest/--from-manifest`).
//! * [`verify`] — static analysis: [`verify::ProgramChecker`] proves
//!   structural invariants of a compiled [`accel::PhaseProgram`]
//!   without executing it (Region bounds through the memory system's
//!   own address rewrite, fanout/merge token conservation — the
//!   compile-time form of the stall watchdog — chain acyclicity,
//!   gather domains, per-channel footprints, on-chip capacity
//!   consistency), each violation a typed, location-naming
//!   [`verify::VerifyError`]. Runs on every `compile_program` in
//!   debug builds and behind [`sim::SimSpecBuilder::verify`] in
//!   release; [`verify::srclint`] is the dependency-free repo linter
//!   (`graphmem lint --src`): unwrap/expect ratchet, memo-key
//!   coverage cross-referencing `sim/spec.rs` against `persist`'s
//!   serializer, wall-clock bans in deterministic paths.
//! * [`serve`] — the simulator as a long-running shared service:
//!   `graphmem serve` speaks a line-delimited TCP protocol with
//!   bounded in-flight admission (typed `busy` back-pressure),
//!   per-request [`robust::RunBudget`] caps, panic isolation, disk
//!   cache durability and drain-then-exit shutdown; `graphmem submit`
//!   is the retrying client with an advisor-estimate degraded mode.
//!
//! # Quick start
//!
//! ```
//! use graphmem::accel::{AcceleratorConfig, AcceleratorKind};
//! use graphmem::algo::problem::ProblemKind;
//! use graphmem::graph::DatasetId;
//! use graphmem::sim::SimSpec;
//!
//! let report = SimSpec::builder()
//!     .accelerator(AcceleratorKind::AccuGraph)
//!     .graph(DatasetId::Sd)
//!     .problem(ProblemKind::Bfs)
//!     .config(AcceleratorConfig::all_optimizations())
//!     .patterns(true) // opt in to the access-pattern summary
//!     .build()
//!     .unwrap() // invalid combinations fail here, never mid-run
//!     .run();
//! assert!(report.mteps() > 0.0);
//! let patterns = report.patterns.as_ref().unwrap();
//! assert!(patterns.total_requests() > 0);
//! ```

pub mod accel;
pub mod advisor;
pub mod algo;
pub mod coordinator;
pub mod dram;
pub mod engine;
pub mod graph;
pub mod onchip;
pub mod partition;
pub mod persist;
pub mod report;
pub mod robust;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod trace;
pub mod util;
pub mod verify;
