//! # graphmem
//!
//! Reproduction of *"Demystifying Memory Access Patterns of FPGA-Based
//! Graph Processing Accelerators"* (Dann, Ritter, Fröning, 2021).
//!
//! The crate provides:
//!
//! * [`dram`] — a cycle-level, multi-standard (DDR3 / DDR4 / HBM) DRAM
//!   timing simulator (a Ramulator-equivalent built from scratch) with
//!   row hit/miss/conflict accounting and bandwidth-utilization stats.
//! * [`graph`] — graph substrate: edge lists, (in-)CSR, the Graph500
//!   R-MAT generator, synthetic stand-ins for the paper's twelve
//!   benchmark graphs, and dataset property analysis (density, degree
//!   skewness, …).
//! * [`partition`] — the three partitioning schemes used by the studied
//!   accelerators: horizontal, vertical, and interval-shard.
//! * [`algo`] — the five graph problems (BFS, PR, WCC, SSSP, SpMV) as
//!   value semantics plus golden reference executors for the paper's
//!   three update-propagation schemes.
//! * [`accel`] — memory-access-pattern models of the four accelerators:
//!   AccuGraph, HitGraph, ForeGraph, ThunderGP, with every optimization
//!   the paper ablates (prefetch/partition/shard skipping, edge
//!   shuffling, stride mapping, edge sorting, update combining, update
//!   filtering, chunk scheduling).
//! * [`sim`] — the co-simulation driver marrying accelerator request
//!   producers to the DRAM model, and the metric set of the paper
//!   (MTEPS, MREPS, iterations, bytes/edge, …).
//! * [`engine`] + [`runtime`] — the golden algorithm engine, available
//!   as a pure-Rust implementation and as an AOT-compiled JAX/Pallas
//!   artifact executed through PJRT (the `xla` crate). Python is only
//!   ever used at build time.
//! * [`coordinator`] + [`report`] — experiment registry covering every
//!   figure and table of the paper's evaluation, sweep runner, and
//!   table/figure formatters.

pub mod accel;
pub mod algo;
pub mod coordinator;
pub mod dram;
pub mod engine;
pub mod graph;
pub mod partition;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod util;
