//! The golden algorithm engine: iterate a graph problem to fixpoint,
//! independently of any accelerator model. Two interchangeable
//! backends:
//!
//! * [`native`] — pure Rust, mirrors the padded edge-block semantics
//!   of `python/compile/model.py` exactly; handles any graph size.
//! * [`xla`] — executes the AOT-compiled JAX/Pallas artifacts through
//!   PJRT ([`crate::runtime`]); bounded by the artifact buckets and
//!   used as the cross-language verification path and in the
//!   end-to-end example.
//!
//! Integration tests assert native == XLA on random graphs
//! (`rust/tests/xla_engine.rs`).

pub mod native;
pub mod xla;

pub use native::NativeEngine;
// `self::` disambiguates the local module from the extern `xla` crate.
pub use self::xla::XlaEngine;

use crate::algo::problem::GraphProblem;
use crate::graph::EdgeList;
use anyhow::Result;

/// Result of running a problem to fixpoint.
#[derive(Clone, Debug)]
pub struct EngineResult {
    /// Final values for the *real* (unpadded) vertices.
    pub values: Vec<f32>,
    /// Iterations executed, including the final no-change pass.
    pub iterations: u32,
}

/// A fixpoint engine over the 2-phase (level-synchronous) semantics —
/// the semantics the L2 JAX model implements.
pub trait AlgorithmEngine {
    fn name(&self) -> &'static str;

    /// Run `problem` on `graph` until no value changes (or the
    /// problem's fixed iteration count), up to `max_iters`.
    fn run(
        &mut self,
        problem: &GraphProblem,
        graph: &EdgeList,
        max_iters: u32,
    ) -> Result<EngineResult>;
}
