//! XLA-backed engine: pads the graph into an artifact bucket and
//! drives the AOT-compiled JAX/Pallas step through PJRT. The Rust side
//! owns the convergence loop; the compiled step owns the compute.

use super::{AlgorithmEngine, EngineResult};
use crate::algo::problem::{GraphProblem, ProblemKind, INF};
use crate::graph::EdgeList;
use crate::runtime::Runtime;
use anyhow::{bail, Result};

/// Map a [`ProblemKind`] to its artifact name.
pub fn problem_key(kind: ProblemKind) -> &'static str {
    match kind {
        ProblemKind::Bfs => "bfs",
        ProblemKind::PageRank => "pr",
        ProblemKind::Wcc => "wcc",
        ProblemKind::Sssp => "sssp",
        ProblemKind::SpMV => "spmv",
    }
}

/// Engine backed by the PJRT runtime.
pub struct XlaEngine {
    runtime: Runtime,
}

impl XlaEngine {
    pub fn new(runtime: Runtime) -> Self {
        XlaEngine { runtime }
    }

    /// Convenience: artifacts from the default location.
    pub fn from_repo_root() -> Result<Self> {
        Ok(XlaEngine {
            runtime: Runtime::from_repo_root()?,
        })
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    /// Does an artifact bucket exist for this (problem, graph)?
    pub fn fits(&self, kind: ProblemKind, g: &EdgeList) -> bool {
        self.runtime
            .pick_bucket(problem_key(kind), g.num_vertices, g.num_edges())
            .is_some()
    }
}

impl AlgorithmEngine for XlaEngine {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn run(
        &mut self,
        problem: &GraphProblem,
        graph: &EdgeList,
        max_iters: u32,
    ) -> Result<EngineResult> {
        let key = problem_key(problem.kind);
        let n = graph.num_vertices;
        let m = graph.num_edges();
        let Some(entry) = self.runtime.pick_bucket(key, n, m) else {
            bail!(
                "graph (n={n}, m={m}) exceeds every artifact bucket for {key}; \
                 use the native engine for large graphs"
            );
        };
        let (n_pad, m_pad) = (entry.n_pad, entry.m_pad);

        // Pad values: INF for min-problems keeps padding inert; 0 for
        // add-problems (their padded edges are masked anyway).
        let mut vals = problem.init_values();
        let pad_val = if problem.kind.reduces_with_min() {
            INF
        } else {
            0.0
        };
        vals.resize(n_pad, pad_val);

        // Pad edges with mask = 0.
        let mut src = vec![0i32; m_pad];
        let mut dst = vec![0i32; m_pad];
        let mut w = vec![0f32; m_pad];
        let mut mask = vec![0f32; m_pad];
        for (i, e) in graph.edges.iter().enumerate() {
            src[i] = e.src as i32;
            dst[i] = e.dst as i32;
            w[i] = e.weight;
            mask[i] = 1.0;
        }

        // aux = 1/out_degree for PR; zeros otherwise.
        let mut aux = vec![0f32; n_pad];
        if problem.kind == ProblemKind::PageRank {
            aux[..problem.inv_out_deg.len()].copy_from_slice(&problem.inv_out_deg);
        }

        let limit = problem
            .kind
            .fixed_iterations()
            .unwrap_or(max_iters)
            .min(max_iters);
        let mut iterations = 0u32;
        loop {
            iterations += 1;
            let (new_vals, changed) = self.runtime.run_step(
                key,
                &vals,
                &src,
                &dst,
                &w,
                &mask,
                &aux,
                n as f32,
            )?;
            vals = new_vals;
            if iterations >= limit || !changed {
                break;
            }
        }
        vals.truncate(n);
        Ok(EngineResult {
            values: vals,
            iterations,
        })
    }
}

// Integration tests (require built artifacts): rust/tests/xla_engine.rs
