//! Pure-Rust engine mirroring `python/compile/model.py` step-for-step:
//! gather source values, combine, scatter-reduce, apply — all against
//! the previous iteration's values (2-phase semantics).

use super::{AlgorithmEngine, EngineResult};
use crate::algo::problem::{GraphProblem, ProblemKind};
use crate::graph::EdgeList;
use anyhow::Result;

/// The pure-Rust golden engine.
#[derive(Default)]
pub struct NativeEngine;

impl NativeEngine {
    pub fn new() -> Self {
        NativeEngine
    }

    /// One iteration step; mirrors `model.step` exactly.
    /// Returns (new_values, changed).
    pub fn step(p: &GraphProblem, g: &EdgeList, vals: &[f32]) -> (Vec<f32>, bool) {
        let n = g.num_vertices;
        let mut acc = vec![p.reduce_identity(); n];
        for e in &g.edges {
            let u = p.combine(e.src, vals[e.src as usize], e.weight);
            let a = &mut acc[e.dst as usize];
            *a = p.reduce(*a, u);
        }
        let mut new = Vec::with_capacity(n);
        let mut changed = false;
        for v in 0..n {
            let nv = match p.kind {
                // model.py: new = min(vals, acc)
                ProblemKind::Bfs | ProblemKind::Sssp | ProblemKind::Wcc => vals[v].min(acc[v]),
                // model.py: (1-d)/n + d*acc ; acc directly for SpMV
                ProblemKind::PageRank | ProblemKind::SpMV => p.apply(vals[v], acc[v]),
            };
            if p.kind.reduces_with_min() {
                if nv < vals[v] {
                    changed = true;
                }
            }
            new.push(nv);
        }
        if !p.kind.reduces_with_min() {
            changed = true; // single-pass problems always report change
        }
        (new, changed)
    }
}

impl AlgorithmEngine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn run(
        &mut self,
        problem: &GraphProblem,
        graph: &EdgeList,
        max_iters: u32,
    ) -> Result<EngineResult> {
        let mut values = problem.init_values();
        let mut iterations = 0u32;
        let limit = problem
            .kind
            .fixed_iterations()
            .unwrap_or(max_iters)
            .min(max_iters);
        loop {
            iterations += 1;
            let (new, changed) = Self::step(problem, graph, &values);
            values = new;
            if iterations >= limit || !changed {
                break;
            }
        }
        Ok(EngineResult { values, iterations })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::golden::{run_golden, values_agree, Propagation};
    use crate::algo::problem::ProblemKind;
    use crate::graph::synthetic::erdos_renyi;

    #[test]
    fn matches_golden_two_phase_on_all_problems() {
        let g = erdos_renyi(400, 2400, 1).with_random_weights(2, 8.0);
        for kind in [
            ProblemKind::Bfs,
            ProblemKind::PageRank,
            ProblemKind::Wcc,
            ProblemKind::Sssp,
            ProblemKind::SpMV,
        ] {
            let p = GraphProblem::new(kind, &g);
            let golden = run_golden(&p, &g, Propagation::TwoPhase);
            let mut engine = NativeEngine::new();
            let res = engine.run(&p, &g, 10_000).unwrap();
            assert!(
                values_agree(kind, &golden.values, &res.values),
                "{kind:?} values diverge"
            );
            assert_eq!(res.iterations, golden.iterations, "{kind:?} iterations");
        }
    }

    #[test]
    fn max_iters_caps_execution() {
        let g = erdos_renyi(200, 400, 3);
        let p = GraphProblem::new(ProblemKind::Bfs, &g);
        let mut engine = NativeEngine::new();
        let res = engine.run(&p, &g, 2).unwrap();
        assert_eq!(res.iterations, 2);
    }

    #[test]
    fn empty_graph() {
        let g = EdgeList::new(3, true);
        let p = GraphProblem::with_root(ProblemKind::Bfs, &g, 0);
        let mut engine = NativeEngine::new();
        let res = engine.run(&p, &g, 100).unwrap();
        assert_eq!(res.values[0], 0.0);
        assert_eq!(res.iterations, 1);
    }

    use crate::graph::edgelist::EdgeList;
}
