//! Graph500-style R-MAT generator.
//!
//! Recursive-matrix sampling with the Graph500 parameters
//! (a, b, c, d) = (0.57, 0.19, 0.19, 0.05), with the standard per-level
//! parameter noise to avoid degenerate diagonals. Deterministic for a
//! given seed. Used for the paper's `r21` / `r24` workloads (we run
//! scaled-down instances; the process is identical).

use super::edgelist::EdgeList;
use super::VertexId;
use crate::util::rng::Rng;

/// R-MAT parameters.
#[derive(Clone, Copy, Debug)]
pub struct RmatParams {
    pub a: f64,
    pub b: f64,
    pub c: f64,
    /// Scale: `n = 2^scale` vertices.
    pub scale: u32,
    /// Edge factor: `m = n * edge_factor` edges.
    pub edge_factor: u32,
    pub seed: u64,
}

impl RmatParams {
    /// Graph500 defaults at a given scale/edge-factor.
    pub fn graph500(scale: u32, edge_factor: u32, seed: u64) -> Self {
        RmatParams {
            a: 0.57,
            b: 0.19,
            c: 0.19,
            scale,
            edge_factor,
            seed,
        }
    }
}

/// Generate an R-MAT graph (directed; may contain self-loops and
/// multi-edges, like the Graph500 kernel output).
pub fn generate(p: RmatParams) -> EdgeList {
    let n: u64 = 1 << p.scale;
    let m: u64 = n * p.edge_factor as u64;
    let mut rng = Rng::new(p.seed);
    let mut g = EdgeList::new(n as usize, true);
    g.edges.reserve(m as usize);
    for _ in 0..m {
        let (src, dst) = sample_edge(&mut rng, p);
        g.add(src, dst);
    }
    g
}

fn sample_edge(rng: &mut Rng, p: RmatParams) -> (VertexId, VertexId) {
    let mut src: u64 = 0;
    let mut dst: u64 = 0;
    // Jitter quadrant probabilities +-10% once per edge (Graph500
    // jitters per level; per-edge noise preserves the distribution
    // shape at a fraction of the RNG cost — see EXPERIMENTS.md §Perf).
    let jitter = |rng: &mut Rng, base: f64| base * (0.9 + 0.2 * rng.next_f64());
    let a = jitter(rng, p.a);
    let b = jitter(rng, p.b);
    let c = jitter(rng, p.c);
    let d = jitter(rng, 1.0 - p.a - p.b - p.c);
    let total = a + b + c + d;
    let ab = a + b;
    let abc = ab + c;
    let _ = d;
    for _ in 0..p.scale {
        src <<= 1;
        dst <<= 1;
        let r = rng.next_f64() * total;
        if r < a {
            // top-left: nothing to add
        } else if r < ab {
            dst |= 1;
        } else if r < abc {
            src |= 1;
        } else {
            src |= 1;
            dst |= 1;
        }
    }
    (src as VertexId, dst as VertexId)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::skewness;

    #[test]
    fn deterministic() {
        let a = generate(RmatParams::graph500(10, 8, 1));
        let b = generate(RmatParams::graph500(10, 8, 1));
        assert_eq!(a.num_edges(), b.num_edges());
        assert_eq!(a.edges[..100], b.edges[..100]);
    }

    #[test]
    fn sizes_match_scale() {
        let g = generate(RmatParams::graph500(12, 16, 2));
        assert_eq!(g.num_vertices, 1 << 12);
        assert_eq!(g.num_edges(), (1 << 12) * 16);
    }

    #[test]
    fn degree_distribution_is_skewed() {
        let g = generate(RmatParams::graph500(12, 16, 3));
        let degs: Vec<f64> = g.out_degrees().iter().map(|&d| d as f64).collect();
        let sk = skewness(&degs);
        assert!(sk > 2.0, "R-MAT should be heavily right-skewed, got {sk}");
    }

    #[test]
    fn vertices_in_range() {
        let g = generate(RmatParams::graph500(8, 8, 4));
        for e in &g.edges {
            assert!((e.src as usize) < g.num_vertices);
            assert!((e.dst as usize) < g.num_vertices);
        }
    }
}
