//! Compressed sparse row (CSR): `n + 1` 32-bit pointers plus `m`
//! 32-bit neighbor ids. AccuGraph processes the *inverted* graph in
//! CSR ("in-CSR"): `neighbors(v)` are the in-neighbors (sources) of
//! `v`, which a pull-based data flow reads sequentially.

use super::edgelist::EdgeList;
use super::VertexId;

/// CSR adjacency structure.
#[derive(Clone, Debug)]
pub struct Csr {
    /// `n + 1` offsets into `neighbors`.
    pub offsets: Vec<u32>,
    /// Neighbor ids, grouped by vertex.
    pub neighbors: Vec<VertexId>,
    /// Parallel weights (empty when unweighted).
    pub weights: Vec<f32>,
}

impl Csr {
    /// Build CSR over out-edges: `neighbors(v)` = destinations of `v`.
    pub fn from_edges(g: &EdgeList) -> Csr {
        Self::build(g, false)
    }

    /// Build CSR over in-edges (the "in-CSR" of AccuGraph):
    /// `neighbors(v)` = sources pointing at `v`.
    pub fn inverted_from_edges(g: &EdgeList) -> Csr {
        Self::build(g, true)
    }

    fn build(g: &EdgeList, inverted: bool) -> Csr {
        let n = g.num_vertices;
        let mut counts = vec![0u32; n + 1];
        for e in &g.edges {
            let key = if inverted { e.dst } else { e.src } as usize;
            counts[key + 1] += 1;
        }
        for i in 0..n {
            counts[i + 1] += counts[i];
        }
        let offsets = counts.clone();
        let mut cursor = counts;
        let mut neighbors = vec![0 as VertexId; g.edges.len()];
        let mut weights = if g.weighted {
            vec![0f32; g.edges.len()]
        } else {
            Vec::new()
        };
        for e in &g.edges {
            let (key, val) = if inverted {
                (e.dst as usize, e.src)
            } else {
                (e.src as usize, e.dst)
            };
            let pos = cursor[key] as usize;
            neighbors[pos] = val;
            if g.weighted {
                weights[pos] = e.weight;
            }
            cursor[key] += 1;
        }
        Csr {
            offsets,
            neighbors,
            weights,
        }
    }

    pub fn num_vertices(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn num_edges(&self) -> usize {
        self.neighbors.len()
    }

    /// Neighbor slice of vertex `v`.
    pub fn neighbors_of(&self, v: VertexId) -> &[VertexId] {
        let s = self.offsets[v as usize] as usize;
        let e = self.offsets[v as usize + 1] as usize;
        &self.neighbors[s..e]
    }

    /// Degree of vertex `v` in this CSR's direction.
    pub fn degree(&self, v: VertexId) -> u32 {
        self.offsets[v as usize + 1] - self.offsets[v as usize]
    }

    /// Total byte size of the structure with 32-bit fields (the
    /// quantity behind the paper's bytes-per-edge metric for
    /// AccuGraph: `4 * (n + 1 + m)` plus weights).
    pub fn byte_size(&self) -> u64 {
        (self.offsets.len() * 4 + self.neighbors.len() * 4 + self.weights.len() * 4) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> EdgeList {
        // 0 -> 1, 0 -> 2, 1 -> 3, 2 -> 3
        let mut g = EdgeList::new(4, true);
        g.add(0, 1);
        g.add(0, 2);
        g.add(1, 3);
        g.add(2, 3);
        g
    }

    #[test]
    fn out_csr_structure() {
        let c = Csr::from_edges(&diamond());
        assert_eq!(c.num_vertices(), 4);
        assert_eq!(c.num_edges(), 4);
        assert_eq!(c.neighbors_of(0), &[1, 2]);
        assert_eq!(c.neighbors_of(1), &[3]);
        assert_eq!(c.neighbors_of(3), &[] as &[u32]);
        assert_eq!(c.degree(0), 2);
    }

    #[test]
    fn in_csr_structure() {
        let c = Csr::inverted_from_edges(&diamond());
        assert_eq!(c.neighbors_of(3), &[1, 2]);
        assert_eq!(c.neighbors_of(0), &[] as &[u32]);
        assert_eq!(c.degree(3), 2);
    }

    #[test]
    fn offsets_monotone_and_cover_edges() {
        let c = Csr::from_edges(&diamond());
        assert!(c.offsets.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(*c.offsets.last().unwrap() as usize, c.num_edges());
    }

    #[test]
    fn weighted_csr_carries_weights() {
        let g = diamond().with_random_weights(3, 5.0);
        let c = Csr::from_edges(&g);
        assert_eq!(c.weights.len(), 4);
        assert_eq!(c.byte_size(), (5 * 4 + 4 * 4 + 4 * 4) as u64);
    }

    #[test]
    fn empty_graph() {
        let g = EdgeList::new(0, true);
        let c = Csr::from_edges(&g);
        assert_eq!(c.num_vertices(), 0);
        assert_eq!(c.num_edges(), 0);
    }
}
