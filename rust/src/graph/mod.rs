//! Graph substrate: representations, generators, dataset stand-ins and
//! property analysis (the paper's Tab. 2 inputs).

pub mod csr;
pub mod datasets;
pub mod edgelist;
pub mod io;
pub mod properties;
pub mod rmat;
pub mod synthetic;

pub use csr::Csr;
pub use datasets::{dataset, dataset_names, DatasetId, DatasetSpec};
pub use edgelist::{Edge, EdgeList};
pub use properties::GraphProperties;

/// Vertex identifier (the paper uses 32-bit ids throughout, §4.1).
pub type VertexId = u32;
