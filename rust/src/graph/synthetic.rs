//! Synthetic graph generators used to build the dataset stand-ins:
//! Erdős–Rényi (low skew), 2-D grid (road-network-like: degree ~4,
//! huge diameter), preferential attachment (power-law), and small-world
//! ring lattices (moderate diameter, low skew — protein/brain-like).

use super::edgelist::EdgeList;
use super::VertexId;
use crate::util::rng::Rng;

/// G(n, m): `m` uniformly random directed edges (allows multi-edges —
/// matches how the accelerators see raw edge lists).
pub fn erdos_renyi(n: usize, m: usize, seed: u64) -> EdgeList {
    let mut rng = Rng::new(seed);
    let mut g = EdgeList::new(n, true);
    g.edges.reserve(m);
    for _ in 0..m {
        let s = rng.next_below(n as u64) as VertexId;
        let d = rng.next_below(n as u64) as VertexId;
        g.add(s, d);
    }
    g
}

/// 2-D grid (4-neighborhood), road-network stand-in: `rows * cols`
/// vertices, degree <= 4, diameter `rows + cols` — the shape that makes
/// rd/bk need many BFS iterations in the paper.
pub fn grid_2d(rows: usize, cols: usize) -> EdgeList {
    let n = rows * cols;
    let mut g = EdgeList::new(n, false);
    let idx = |r: usize, c: usize| (r * cols + c) as VertexId;
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                g.add(idx(r, c), idx(r, c + 1));
                g.add(idx(r, c + 1), idx(r, c));
            }
            if r + 1 < rows {
                g.add(idx(r, c), idx(r + 1, c));
                g.add(idx(r + 1, c), idx(r, c));
            }
        }
    }
    g
}

/// Barabási–Albert-style preferential attachment: each new vertex
/// attaches `k` edges to existing vertices with probability
/// proportional to degree. Produces power-law (skewed) degree
/// distributions — the social-network stand-in.
pub fn preferential_attachment(n: usize, k: usize, seed: u64) -> EdgeList {
    assert!(n > k && k >= 1);
    let mut rng = Rng::new(seed);
    let mut g = EdgeList::new(n, true);
    // Repeated-target list trick: sample proportional to degree.
    let mut targets: Vec<VertexId> = Vec::with_capacity(2 * n * k);
    // Seed clique among the first k+1 vertices.
    for v in 0..=k {
        for u in 0..v {
            g.add(v as VertexId, u as VertexId);
            targets.push(v as VertexId);
            targets.push(u as VertexId);
        }
    }
    for v in (k + 1)..n {
        for _ in 0..k {
            let t = targets[rng.next_below(targets.len() as u64) as usize];
            g.add(v as VertexId, t);
            targets.push(v as VertexId);
            targets.push(t);
        }
    }
    g
}

/// Watts–Strogatz-style ring lattice with rewiring: each vertex links
/// to `k/2` clockwise neighbors; each edge rewired with probability
/// `beta`. Low skew, tunable diameter — the bio-graph stand-in.
pub fn small_world(n: usize, k: usize, beta: f64, seed: u64) -> EdgeList {
    assert!(k % 2 == 0 && k < n);
    let mut rng = Rng::new(seed);
    let mut g = EdgeList::new(n, false);
    for v in 0..n {
        for j in 1..=(k / 2) {
            let mut t = ((v + j) % n) as VertexId;
            if rng.chance(beta) {
                t = rng.next_below(n as u64) as VertexId;
            }
            g.add(v as VertexId, t);
            g.add(t, v as VertexId);
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::skewness;

    #[test]
    fn er_sizes() {
        let g = erdos_renyi(1000, 5000, 1);
        assert_eq!(g.num_vertices, 1000);
        assert_eq!(g.num_edges(), 5000);
    }

    #[test]
    fn grid_structure() {
        let g = grid_2d(10, 10);
        assert_eq!(g.num_vertices, 100);
        // interior edges: 2 * rows*(cols-1) horizontals + ... doubled for symmetry
        assert_eq!(g.num_edges(), 2 * (10 * 9 + 9 * 10));
        let degs = g.out_degrees();
        assert!(degs.iter().all(|&d| d >= 2 && d <= 4));
    }

    #[test]
    fn pa_is_skewed_er_is_not() {
        let pa = preferential_attachment(2000, 4, 2);
        let er = erdos_renyi(2000, 8000, 2);
        let sk_pa = skewness(&pa.in_degrees().iter().map(|&d| d as f64).collect::<Vec<_>>());
        let sk_er = skewness(&er.in_degrees().iter().map(|&d| d as f64).collect::<Vec<_>>());
        assert!(sk_pa > 3.0, "PA skew {sk_pa}");
        assert!(sk_er < 1.0, "ER skew {sk_er}");
    }

    #[test]
    fn small_world_regular_degree() {
        let g = small_world(500, 4, 0.05, 3);
        assert_eq!(g.num_edges(), 500 * 4); // 2 per vertex, symmetrized
        let degs = g.out_degrees();
        let sk = skewness(&degs.iter().map(|&d| d as f64).collect::<Vec<_>>());
        assert!(sk.abs() < 2.0, "small-world skew {sk}");
    }

    #[test]
    fn generators_deterministic() {
        assert_eq!(
            erdos_renyi(100, 300, 9).edges,
            erdos_renyi(100, 300, 9).edges
        );
        assert_eq!(
            preferential_attachment(100, 3, 9).edges,
            preferential_attachment(100, 3, 9).edges
        );
        assert_eq!(
            small_world(100, 4, 0.1, 9).edges,
            small_world(100, 4, 0.1, 9).edges
        );
    }
}
