//! Edge-list file I/O: whitespace-separated text (`src dst [weight]`,
//! `#` comments — the SNAP format) and a compact binary format.

use super::edgelist::{Edge, EdgeList};
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

/// Parse SNAP-style text: one `src dst [weight]` pair per line,
/// `#`-prefixed comment lines ignored. Vertex count = max id + 1.
pub fn parse_text(reader: impl Read, directed: bool) -> Result<EdgeList> {
    let reader = BufReader::new(reader);
    let mut edges: Vec<Edge> = Vec::new();
    let mut max_id: u32 = 0;
    let mut weighted = false;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line.context("read line")?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let mut it = t.split_whitespace();
        let src: u32 = it
            .next()
            .with_context(|| format!("line {}: missing src", lineno + 1))?
            .parse()
            .with_context(|| format!("line {}: bad src", lineno + 1))?;
        let dst: u32 = it
            .next()
            .with_context(|| format!("line {}: missing dst", lineno + 1))?
            .parse()
            .with_context(|| format!("line {}: bad dst", lineno + 1))?;
        let weight = match it.next() {
            Some(w) => {
                weighted = true;
                w.parse::<f32>()
                    .with_context(|| format!("line {}: bad weight", lineno + 1))?
            }
            None => 1.0,
        };
        max_id = max_id.max(src).max(dst);
        edges.push(Edge { src, dst, weight });
    }
    if edges.is_empty() {
        bail!("no edges in input");
    }
    Ok(EdgeList {
        num_vertices: max_id as usize + 1,
        edges,
        directed,
        weighted,
    })
}

/// Load from a text file path.
pub fn load_text(path: impl AsRef<Path>, directed: bool) -> Result<EdgeList> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("open {}", path.as_ref().display()))?;
    parse_text(f, directed)
}

/// Write text format.
pub fn save_text(g: &EdgeList, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# graphmem edge list: n={} m={}", g.num_vertices, g.num_edges())?;
    for e in &g.edges {
        if g.weighted {
            writeln!(w, "{} {} {}", e.src, e.dst, e.weight)?;
        } else {
            writeln!(w, "{} {}", e.src, e.dst)?;
        }
    }
    Ok(())
}

/// Parse MatrixMarket coordinate format (`%%MatrixMarket matrix
/// coordinate ...`): 1-based indices, optional per-entry value used as
/// the edge weight. `symmetric` matrices are expanded to both
/// directions.
pub fn parse_matrix_market(reader: impl Read) -> Result<EdgeList> {
    let reader = BufReader::new(reader);
    let mut lines = reader.lines();
    let header = loop {
        match lines.next() {
            Some(l) => {
                let l = l?;
                if l.starts_with("%%MatrixMarket") {
                    break l;
                } else if !l.starts_with('%') && !l.trim().is_empty() {
                    bail!("missing %%MatrixMarket header");
                }
            }
            None => bail!("empty MatrixMarket file"),
        }
    };
    if !header.contains("coordinate") {
        bail!("only coordinate-format MatrixMarket is supported");
    }
    let symmetric = header.contains("symmetric");
    // size line: first non-comment line
    let size_line = loop {
        let l = lines
            .next()
            .ok_or_else(|| anyhow::anyhow!("missing size line"))??;
        if !l.starts_with('%') && !l.trim().is_empty() {
            break l;
        }
    };
    let mut it = size_line.split_whitespace();
    let rows: usize = it.next().context("rows")?.parse()?;
    let cols: usize = it.next().context("cols")?.parse()?;
    let nnz: usize = it.next().context("nnz")?.parse()?;
    let n = rows.max(cols);
    let mut g = EdgeList::new(n, !symmetric);
    g.edges.reserve(if symmetric { 2 * nnz } else { nnz });
    let mut weighted = false;
    for l in lines {
        let l = l?;
        let t = l.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let r: u32 = it.next().context("row")?.parse::<u32>()?;
        let c: u32 = it.next().context("col")?.parse::<u32>()?;
        if r == 0 || c == 0 {
            bail!("MatrixMarket indices are 1-based");
        }
        let w = match it.next() {
            Some(v) => {
                weighted = true;
                v.parse::<f32>().context("value")?
            }
            None => 1.0,
        };
        let (src, dst) = (r - 1, c - 1);
        g.edges.push(Edge { src, dst, weight: w });
        if symmetric && src != dst {
            g.edges.push(Edge {
                src: dst,
                dst: src,
                weight: w,
            });
        }
    }
    g.weighted = weighted;
    if g.edges.is_empty() {
        bail!("no entries in MatrixMarket file");
    }
    Ok(g)
}

/// Load a `.mtx` file.
pub fn load_matrix_market(path: impl AsRef<Path>) -> Result<EdgeList> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("open {}", path.as_ref().display()))?;
    parse_matrix_market(f)
}

/// Binary format: the accelerator on-disk layout — header
/// (`magic, n, m, flags`) then `m` records of `src:u32 dst:u32
/// [weight:f32]` little-endian. 8 B/edge unweighted, 12 B weighted
/// (§4.1 of the paper).
pub fn save_binary(g: &EdgeList, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())?;
    let mut w = BufWriter::new(f);
    w.write_all(b"GMEL")?;
    w.write_all(&(g.num_vertices as u64).to_le_bytes())?;
    w.write_all(&(g.num_edges() as u64).to_le_bytes())?;
    let flags: u32 = (g.directed as u32) | ((g.weighted as u32) << 1);
    w.write_all(&flags.to_le_bytes())?;
    for e in &g.edges {
        w.write_all(&e.src.to_le_bytes())?;
        w.write_all(&e.dst.to_le_bytes())?;
        if g.weighted {
            w.write_all(&e.weight.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Load the binary format.
pub fn load_binary(path: impl AsRef<Path>) -> Result<EdgeList> {
    let mut f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("open {}", path.as_ref().display()))?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf)?;
    if buf.len() < 24 || &buf[0..4] != b"GMEL" {
        bail!("not a graphmem binary edge list");
    }
    let n = u64::from_le_bytes(buf[4..12].try_into().unwrap());
    let m = u64::from_le_bytes(buf[12..20].try_into().unwrap());
    let flags = u32::from_le_bytes(buf[20..24].try_into().unwrap());
    let directed = flags & 1 != 0;
    let weighted = flags & 2 != 0;
    let rec: u64 = if weighted { 12 } else { 8 };
    // Checked size validation *before* any allocation: a corrupt
    // header must not drive `Vec::with_capacity` (or a wrapping
    // length check) into an abort. Truncated payloads, trailing
    // garbage and absurd record counts all land here.
    let expected = m.checked_mul(rec).and_then(|p| p.checked_add(24));
    if expected != Some(buf.len() as u64) {
        bail!(
            "corrupt edge list: header declares {m} record(s) of {rec} B, \
             file carries {} payload byte(s)",
            buf.len() - 24
        );
    }
    let n = usize::try_from(n).context("vertex count exceeds this platform's address space")?;
    let m = m as usize; // m * rec == payload length, so m fits usize
    let mut edges = Vec::with_capacity(m);
    let mut off = 24;
    for _ in 0..m {
        let src = u32::from_le_bytes(buf[off..off + 4].try_into().unwrap());
        let dst = u32::from_le_bytes(buf[off + 4..off + 8].try_into().unwrap());
        // Endpoints must stay inside the declared vertex range — an
        // out-of-range id would otherwise surface as an index panic
        // deep in partitioning or simulation.
        if src as usize >= n || dst as usize >= n {
            bail!(
                "corrupt edge list: edge {src} -> {dst} references a vertex \
                 beyond the declared {n}"
            );
        }
        let weight = if weighted {
            f32::from_le_bytes(buf[off + 8..off + 12].try_into().unwrap())
        } else {
            1.0
        };
        edges.push(Edge { src, dst, weight });
        off += rec as usize;
    }
    Ok(EdgeList {
        num_vertices: n,
        edges,
        directed,
        weighted,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synthetic::erdos_renyi;

    #[test]
    fn parse_text_with_comments() {
        let input = "# comment\n0 1\n1 2\n\n2 0\n";
        let g = parse_text(input.as_bytes(), true).unwrap();
        assert_eq!(g.num_vertices, 3);
        assert_eq!(g.num_edges(), 3);
        assert!(!g.weighted);
    }

    #[test]
    fn parse_weighted_text() {
        let g = parse_text("0 1 2.5\n1 0 3.0\n".as_bytes(), true).unwrap();
        assert!(g.weighted);
        assert_eq!(g.edges[0].weight, 2.5);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_text("a b\n".as_bytes(), true).is_err());
        assert!(parse_text("".as_bytes(), true).is_err());
        assert!(parse_text("0\n".as_bytes(), true).is_err());
    }

    #[test]
    fn binary_roundtrip() {
        let dir = std::env::temp_dir().join("graphmem_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.bin");
        let g = erdos_renyi(100, 500, 1).with_random_weights(2, 8.0);
        save_binary(&g, &p).unwrap();
        let h = load_binary(&p).unwrap();
        assert_eq!(g.num_vertices, h.num_vertices);
        assert_eq!(g.edges, h.edges);
        assert_eq!(g.weighted, h.weighted);
    }

    #[test]
    fn text_roundtrip() {
        let dir = std::env::temp_dir().join("graphmem_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.txt");
        let g = erdos_renyi(50, 200, 3);
        save_text(&g, &p).unwrap();
        let h = load_text(&p, true).unwrap();
        assert_eq!(g.num_edges(), h.num_edges());
        assert_eq!(g.edges[..20], h.edges[..20]);
    }

    #[test]
    fn matrix_market_general() {
        let mtx = "%%MatrixMarket matrix coordinate real general\n\
                   % comment\n\
                   3 3 3\n1 2 0.5\n2 3 1.5\n3 1 2.0\n";
        let g = parse_matrix_market(mtx.as_bytes()).unwrap();
        assert_eq!(g.num_vertices, 3);
        assert_eq!(g.num_edges(), 3);
        assert!(g.weighted);
        assert_eq!(g.edges[0].src, 0);
        assert_eq!(g.edges[0].dst, 1);
        assert_eq!(g.edges[0].weight, 0.5);
    }

    #[test]
    fn matrix_market_symmetric_expands() {
        let mtx = "%%MatrixMarket matrix coordinate pattern symmetric\n\
                   2 2 1\n1 2\n";
        let g = parse_matrix_market(mtx.as_bytes()).unwrap();
        assert_eq!(g.num_edges(), 2);
        assert!(!g.directed);
        assert!(!g.weighted);
    }

    #[test]
    fn matrix_market_rejects_garbage() {
        assert!(parse_matrix_market("not mtx\n".as_bytes()).is_err());
        assert!(parse_matrix_market(
            "%%MatrixMarket matrix coordinate real general\n1 1 1\n0 1 2.0\n".as_bytes()
        )
        .is_err()); // 0-based index
        assert!(parse_matrix_market(
            "%%MatrixMarket matrix array real general\n1 1\n".as_bytes()
        )
        .is_err()); // array format
    }

    #[test]
    fn binary_rejects_corrupt() {
        let dir = std::env::temp_dir().join("graphmem_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, b"NOPE").unwrap();
        assert!(load_binary(&p).is_err());
    }

    /// Serialize a well-formed unweighted file, then corrupt it one
    /// way at a time: every malformation must surface as `Err`, never
    /// as a panic or an allocation blow-up.
    #[test]
    fn binary_rejects_every_malformation_without_panicking() {
        let dir = std::env::temp_dir().join("graphmem_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let good_path = dir.join("good.bin");
        let g = erdos_renyi(10, 30, 5);
        save_binary(&g, &good_path).unwrap();
        let good = std::fs::read(&good_path).unwrap();
        let write = |name: &str, bytes: &[u8]| {
            let p = dir.join(name);
            std::fs::write(&p, bytes).unwrap();
            p
        };
        // Truncated header: valid magic, but fewer than 24 bytes.
        let p = write("short_header.bin", &good[..12]);
        assert!(load_binary(&p).is_err());
        // Truncated payload: header promises 30 records, one is cut.
        let p = write("short_payload.bin", &good[..good.len() - 4]);
        let err = load_binary(&p).unwrap_err().to_string();
        assert!(err.contains("corrupt edge list"), "{err}");
        // Trailing garbage after the declared payload.
        let mut long = good.clone();
        long.extend_from_slice(b"JUNK");
        let p = write("trailing.bin", &long);
        assert!(load_binary(&p).is_err());
        // Absurd record count: m = u64::MAX must fail the checked
        // size validation, not reach `Vec::with_capacity`.
        let mut absurd = good.clone();
        absurd[12..20].copy_from_slice(&u64::MAX.to_le_bytes());
        let p = write("absurd_m.bin", &absurd);
        let err = load_binary(&p).unwrap_err().to_string();
        assert!(err.contains("corrupt edge list"), "{err}");
        // Out-of-range endpoint: shrink the declared vertex count
        // below the ids actually present.
        let mut shrunk = good.clone();
        shrunk[4..12].copy_from_slice(&1u64.to_le_bytes());
        let p = write("shrunk_n.bin", &shrunk);
        let err = load_binary(&p).unwrap_err().to_string();
        assert!(err.contains("beyond the declared"), "{err}");
        // The untouched original still loads.
        assert_eq!(load_binary(&good_path).unwrap().edges, g.edges);
    }
}
