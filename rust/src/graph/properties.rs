//! Graph property analysis — the Tab. 2 columns: |V|, |E|,
//! directedness, average degree `D_avg`, degree-distribution skewness
//! (Fig. 10), diameter estimate (ø) and largest-SCC ratio.

use super::csr::Csr;
use super::edgelist::EdgeList;
use super::VertexId;
use crate::util::stats::skewness;

/// Computed properties of a graph.
#[derive(Clone, Debug)]
pub struct GraphProperties {
    pub num_vertices: usize,
    pub num_edges: usize,
    pub directed: bool,
    pub avg_degree: f64,
    /// Pearson's moment coefficient of skewness over out-degrees.
    pub degree_skewness: f64,
    /// Lower-bound diameter estimate from a double-sweep BFS.
    pub diameter_estimate: u32,
    /// Ratio of vertices in the largest strongly-connected component.
    pub scc_ratio: f64,
}

impl GraphProperties {
    pub fn compute(g: &EdgeList) -> GraphProperties {
        let degs: Vec<f64> = g.out_degrees().iter().map(|&d| d as f64).collect();
        GraphProperties {
            num_vertices: g.num_vertices,
            num_edges: g.num_edges(),
            directed: g.directed,
            avg_degree: g.avg_degree(),
            degree_skewness: skewness(&degs),
            diameter_estimate: diameter_estimate(g),
            scc_ratio: largest_scc_ratio(g),
        }
    }
}

/// BFS levels from `root` over out-edges; `u32::MAX` = unreachable.
pub fn bfs_levels(csr: &Csr, root: VertexId) -> Vec<u32> {
    let n = csr.num_vertices();
    let mut level = vec![u32::MAX; n];
    if n == 0 {
        return level;
    }
    let mut frontier = vec![root];
    level[root as usize] = 0;
    let mut depth = 0u32;
    while !frontier.is_empty() {
        depth += 1;
        let mut next = Vec::new();
        for &v in &frontier {
            for &u in csr.neighbors_of(v) {
                if level[u as usize] == u32::MAX {
                    level[u as usize] = depth;
                    next.push(u);
                }
            }
        }
        frontier = next;
    }
    level
}

/// Double-sweep BFS diameter lower bound (treats the graph as
/// undirected, matching how diameters are usually reported).
pub fn diameter_estimate(g: &EdgeList) -> u32 {
    if g.num_vertices == 0 {
        return 0;
    }
    let sym = if g.directed { g.symmetrized() } else { g.clone() };
    let csr = Csr::from_edges(&sym);
    // Start from the max-degree vertex to land in the big component.
    let start = max_out_degree_vertex(&sym);
    let l1 = bfs_levels(&csr, start);
    let (far, d1) = farthest(&l1);
    let l2 = bfs_levels(&csr, far);
    let (_, d2) = farthest(&l2);
    d1.max(d2)
}

fn farthest(levels: &[u32]) -> (VertexId, u32) {
    let mut best = (0 as VertexId, 0u32);
    for (v, &l) in levels.iter().enumerate() {
        if l != u32::MAX && l > best.1 {
            best = (v as VertexId, l);
        }
    }
    best
}

/// Deterministic BFS/SSSP root choice: among the vertices with maximal
/// out-degree, the one closest to index `n/2`.
///
/// The paper pins specific root ids per graph; for our synthetic
/// stand-ins the max-degree criterion guarantees a root inside the
/// giant component, and the middle-index tie-break avoids degenerate
/// boundary placements on mesh-like graphs (a corner root would let
/// scan-order immediate propagation look either uselessly bad or
/// unrealistically good).
pub fn max_out_degree_vertex(g: &EdgeList) -> VertexId {
    let degs = g.out_degrees();
    let max = degs.iter().copied().max().unwrap_or(0);
    let mid = g.num_vertices as i64 / 2;
    degs.iter()
        .enumerate()
        .filter(|(_, &d)| d == max)
        .min_by_key(|(v, _)| (*v as i64 - mid).abs())
        .map(|(v, _)| v as VertexId)
        .unwrap_or(0)
}

/// Largest-SCC size ratio via iterative Kosaraju.
pub fn largest_scc_ratio(g: &EdgeList) -> f64 {
    let n = g.num_vertices;
    if n == 0 {
        return 0.0;
    }
    let fwd = Csr::from_edges(g);
    let bwd = Csr::inverted_from_edges(g);

    // Pass 1: iterative DFS finish order on the forward graph.
    let mut visited = vec![false; n];
    let mut order: Vec<VertexId> = Vec::with_capacity(n);
    let mut stack: Vec<(VertexId, usize)> = Vec::new();
    for s in 0..n as VertexId {
        if visited[s as usize] {
            continue;
        }
        visited[s as usize] = true;
        stack.push((s, 0));
        while let Some(&mut (v, ref mut i)) = stack.last_mut() {
            let nbrs = fwd.neighbors_of(v);
            if *i < nbrs.len() {
                let u = nbrs[*i];
                *i += 1;
                if !visited[u as usize] {
                    visited[u as usize] = true;
                    stack.push((u, 0));
                }
            } else {
                order.push(v);
                stack.pop();
            }
        }
    }

    // Pass 2: reverse-graph DFS in reverse finish order.
    let mut comp = vec![u32::MAX; n];
    let mut ncomp = 0u32;
    let mut largest = 0usize;
    let mut dfs: Vec<VertexId> = Vec::new();
    for &s in order.iter().rev() {
        if comp[s as usize] != u32::MAX {
            continue;
        }
        let mut size = 0usize;
        dfs.push(s);
        comp[s as usize] = ncomp;
        while let Some(v) = dfs.pop() {
            size += 1;
            for &u in bwd.neighbors_of(v) {
                if comp[u as usize] == u32::MAX {
                    comp[u as usize] = ncomp;
                    dfs.push(u);
                }
            }
        }
        largest = largest.max(size);
        ncomp += 1;
    }
    largest as f64 / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synthetic::{erdos_renyi, grid_2d};

    #[test]
    fn bfs_levels_on_path() {
        let mut g = EdgeList::new(4, true);
        g.add(0, 1);
        g.add(1, 2);
        g.add(2, 3);
        let csr = Csr::from_edges(&g);
        assert_eq!(bfs_levels(&csr, 0), vec![0, 1, 2, 3]);
        assert_eq!(bfs_levels(&csr, 3), vec![u32::MAX, u32::MAX, u32::MAX, 0]);
    }

    #[test]
    fn grid_diameter() {
        let g = grid_2d(10, 10);
        let d = diameter_estimate(&g);
        assert_eq!(d, 18); // (10-1) + (10-1)
    }

    #[test]
    fn scc_of_cycle_is_one() {
        let mut g = EdgeList::new(5, true);
        for v in 0..5 {
            g.add(v, (v + 1) % 5);
        }
        assert!((largest_scc_ratio(&g) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn scc_of_dag_is_small() {
        let mut g = EdgeList::new(5, true);
        g.add(0, 1);
        g.add(1, 2);
        g.add(2, 3);
        g.add(3, 4);
        assert!((largest_scc_ratio(&g) - 0.2).abs() < 1e-12);
    }

    #[test]
    fn properties_on_er() {
        let g = erdos_renyi(500, 5000, 1);
        let p = GraphProperties::compute(&g);
        assert_eq!(p.num_vertices, 500);
        assert_eq!(p.num_edges, 5000);
        assert!((p.avg_degree - 10.0).abs() < 1e-9);
        assert!(p.degree_skewness.abs() < 1.5);
        assert!(p.diameter_estimate >= 2);
        assert!(p.scc_ratio > 0.9); // dense ER is one big SCC
    }

    #[test]
    fn max_degree_vertex() {
        let mut g = EdgeList::new(3, true);
        g.add(1, 0);
        g.add(1, 2);
        g.add(0, 2);
        assert_eq!(max_out_degree_vertex(&g), 1);
    }

    #[test]
    fn empty_graph_is_safe() {
        let g = EdgeList::new(0, true);
        assert_eq!(diameter_estimate(&g), 0);
        assert_eq!(largest_scc_ratio(&g), 0.0);
    }
}
