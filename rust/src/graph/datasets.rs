//! Scaled synthetic stand-ins for the paper's twelve benchmark graphs
//! (Tab. 2).
//!
//! The SNAP datasets are not available in this environment, so each
//! graph is replaced by a deterministic synthetic generator matched on
//! the properties the paper's analysis depends on: directedness,
//! density `D_avg`, degree-distribution skewness, and diameter class
//! (social vs road-like). Sizes are reduced by a per-graph scale
//! factor (recorded here and reported by the harness) to fit the
//! single-core simulation budget; all of the paper's comparisons are
//! relative (rankings, ratios, crossovers), which scaling preserves.
//! See DESIGN.md §6.

use super::edgelist::EdgeList;
use super::rmat::{self, RmatParams};
use super::synthetic;
use super::VertexId;
use crate::util::rng::Rng;
use std::sync::Arc;

/// Typed identifier for the twelve Tab. 2 benchmark graphs — the
/// typed replacement for the bare `"sd" | "db" | ...` strings. Parse
/// user input with [`FromStr`](std::str::FromStr); the short paper
/// name round-trips through [`Display`](std::fmt::Display).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DatasetId {
    Sd,
    Db,
    Yt,
    Pk,
    Wt,
    Or,
    Lj,
    Tw,
    Bk,
    Rd,
    R21,
    R24,
}

impl DatasetId {
    /// Short identifier used throughout the paper.
    pub fn name(self) -> &'static str {
        match self {
            DatasetId::Sd => "sd",
            DatasetId::Db => "db",
            DatasetId::Yt => "yt",
            DatasetId::Pk => "pk",
            DatasetId::Wt => "wt",
            DatasetId::Or => "or",
            DatasetId::Lj => "lj",
            DatasetId::Tw => "tw",
            DatasetId::Bk => "bk",
            DatasetId::Rd => "rd",
            DatasetId::R21 => "r21",
            DatasetId::R24 => "r24",
        }
    }

    /// All twelve Tab. 2 graphs, in appendix-table order.
    pub const fn all() -> [DatasetId; 12] {
        [
            DatasetId::Sd,
            DatasetId::Db,
            DatasetId::Yt,
            DatasetId::Pk,
            DatasetId::Wt,
            DatasetId::Or,
            DatasetId::Lj,
            DatasetId::Tw,
            DatasetId::Bk,
            DatasetId::Rd,
            DatasetId::R21,
            DatasetId::R24,
        ]
    }

    /// The Fig. 12 / Fig. 13 deep-dive subset.
    pub const fn ablation() -> [DatasetId; 4] {
        [DatasetId::Db, DatasetId::Lj, DatasetId::Or, DatasetId::Rd]
    }

    /// The dataset specification (sizes, scale factor, ...).
    pub fn spec(self) -> DatasetSpec {
        spec(self.name()).expect("every DatasetId has a spec")
    }

    /// Build (or fetch from the process-wide cache) the unweighted
    /// stand-in graph.
    pub fn load(self) -> EdgeList {
        dataset(self.name()).expect("every DatasetId has a generator")
    }

    /// Weighted variant (SSSP / SpMV, Tab. 5).
    pub fn load_weighted(self) -> EdgeList {
        dataset_weighted(self.name()).expect("every DatasetId has a generator")
    }

    /// Like [`DatasetId::load`] but hands out the cache's shared
    /// `Arc` — no copy of the edge list.
    pub fn load_shared(self) -> Arc<EdgeList> {
        dataset_shared(self.name()).expect("every DatasetId has a generator")
    }

    /// Like [`DatasetId::load_weighted`], shared.
    pub fn load_weighted_shared(self) -> Arc<EdgeList> {
        dataset_weighted_shared(self.name()).expect("every DatasetId has a generator")
    }
}

impl std::str::FromStr for DatasetId {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        DatasetId::all()
            .into_iter()
            .find(|d| d.name() == s.to_ascii_lowercase())
            .ok_or_else(|| {
                format!(
                    "unknown dataset {s:?} (expected one of: {})",
                    dataset_names().join(" ")
                )
            })
    }
}

impl std::fmt::Display for DatasetId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Description + generator for one benchmark graph.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    /// Short identifier used throughout the paper (tw, lj, or, ...).
    pub name: &'static str,
    /// Full name of the original dataset.
    pub full_name: &'static str,
    /// |V| of the original (for reporting).
    pub paper_vertices: u64,
    /// |E| of the original.
    pub paper_edges: u64,
    pub directed: bool,
    /// Paper-reported average degree.
    pub paper_avg_degree: f64,
    /// Linear scale factor applied to |V| (and roughly |E|).
    pub scale_factor: u32,
}

/// All twelve Tab. 2 graphs, ordered as the appendix tables list them.
pub fn dataset_names() -> &'static [&'static str] {
    &[
        "sd", "db", "yt", "pk", "wt", "or", "lj", "tw", "bk", "rd", "r21", "r24",
    ]
}

/// The subset used by the paper's Fig. 12 / Fig. 13 deep-dives.
pub fn ablation_dataset_names() -> &'static [&'static str] {
    &["db", "lj", "or", "rd"]
}

/// Specification for a named dataset.
pub fn spec(name: &str) -> Option<DatasetSpec> {
    let s = match name {
        "sd" => DatasetSpec {
            name: "sd",
            full_name: "soc-Slashdot0902 (stand-in)",
            paper_vertices: 82_200,
            paper_edges: 948_400,
            directed: true,
            paper_avg_degree: 11.54,
            scale_factor: 16,
        },
        "db" => DatasetSpec {
            name: "db",
            full_name: "com-DBLP (stand-in)",
            paper_vertices: 426_000,
            paper_edges: 1_000_000,
            directed: false,
            paper_avg_degree: 4.93,
            scale_factor: 64,
        },
        "yt" => DatasetSpec {
            name: "yt",
            full_name: "com-Youtube (stand-in)",
            paper_vertices: 1_200_000,
            paper_edges: 3_000_000,
            directed: false,
            paper_avg_degree: 5.16,
            scale_factor: 64,
        },
        "pk" => DatasetSpec {
            name: "pk",
            full_name: "soc-Pokec (stand-in)",
            paper_vertices: 1_600_000,
            paper_edges: 30_600_000,
            directed: true,
            paper_avg_degree: 19.1,
            scale_factor: 64,
        },
        "wt" => DatasetSpec {
            name: "wt",
            full_name: "wiki-Talk (stand-in)",
            paper_vertices: 2_400_000,
            paper_edges: 5_000_000,
            directed: true,
            paper_avg_degree: 2.10,
            scale_factor: 64,
        },
        "or" => DatasetSpec {
            name: "or",
            full_name: "com-Orkut (stand-in)",
            paper_vertices: 3_100_000,
            paper_edges: 117_200_000,
            directed: false,
            paper_avg_degree: 76.28,
            scale_factor: 64,
        },
        "lj" => DatasetSpec {
            name: "lj",
            full_name: "soc-LiveJournal1 (stand-in)",
            paper_vertices: 4_800_000,
            paper_edges: 69_000_000,
            directed: true,
            paper_avg_degree: 14.23,
            scale_factor: 64,
        },
        "tw" => DatasetSpec {
            name: "tw",
            full_name: "twitter-2010 (stand-in)",
            paper_vertices: 41_700_000,
            paper_edges: 1_468_400_000,
            directed: true,
            paper_avg_degree: 35.25,
            scale_factor: 512,
        },
        "bk" => DatasetSpec {
            name: "bk",
            full_name: "large-diameter bio/mesh graph (stand-in)",
            paper_vertices: 685_200,
            paper_edges: 7_600_000,
            directed: false,
            paper_avg_degree: 11.09,
            scale_factor: 64,
        },
        "rd" => DatasetSpec {
            name: "rd",
            full_name: "roadNet-CA (stand-in)",
            paper_vertices: 2_000_000,
            paper_edges: 2_800_000,
            directed: false,
            paper_avg_degree: 2.81,
            scale_factor: 64,
        },
        "r21" => DatasetSpec {
            name: "r21",
            full_name: "rmat-21-86 (scaled to rmat-14-86)",
            paper_vertices: 2_100_000,
            paper_edges: 180_400_000,
            directed: true,
            paper_avg_degree: 86.0,
            scale_factor: 128,
        },
        "r24" => DatasetSpec {
            name: "r24",
            full_name: "rmat-24-16 (scaled to rmat-17-16)",
            paper_vertices: 16_800_000,
            paper_edges: 268_400_000,
            directed: true,
            paper_avg_degree: 16.0,
            scale_factor: 128,
        },
        _ => return None,
    };
    Some(s)
}

/// Build a named dataset stand-in, returning the process-wide cache's
/// shared `Arc` (no edge-list copy). Deterministic; generation
/// (especially R-MAT) dominates short simulation runs otherwise
/// (§Perf in EXPERIMENTS.md).
pub fn dataset_shared(name: &str) -> Option<Arc<EdgeList>> {
    cached(name, || build_dataset(name))
}

/// Weighted variant, shared (cached separately from the unweighted
/// graph so weights are attached once, not per call).
pub fn dataset_weighted_shared(name: &str) -> Option<Arc<EdgeList>> {
    cached(&format!("{name}#weighted"), || {
        dataset_shared(name).map(|g| (*g).clone().with_random_weights(0x77EE, 64.0))
    })
}

fn cached(key: &str, build: impl FnOnce() -> Option<EdgeList>) -> Option<Arc<EdgeList>> {
    use std::collections::HashMap;
    use std::sync::{Mutex, OnceLock, PoisonError};
    static CACHE: OnceLock<Mutex<HashMap<String, Arc<EdgeList>>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    // Poison recovery: the cache only ever holds fully built graphs,
    // so a panic elsewhere cannot leave a half-written entry.
    if let Some(g) = cache
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
        .get(key)
    {
        return Some(Arc::clone(g));
    }
    // Build outside the lock (R-MAT generation can take seconds); a
    // racing duplicate builds the same deterministic graph and the
    // first insert wins.
    let g = Arc::new(build()?);
    Some(Arc::clone(
        cache
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .entry(key.to_string())
            .or_insert(g),
    ))
}

/// Build a named dataset stand-in (owned copy; see [`dataset_shared`]
/// for the copy-free variant).
pub fn dataset(name: &str) -> Option<EdgeList> {
    dataset_shared(name).map(|g| (*g).clone())
}

fn build_dataset(name: &str) -> Option<EdgeList> {
    let g = match name {
        // Slashdot: mid-size directed social graph, skewed.
        "sd" => randomize_orientation(synthetic::preferential_attachment(5_138, 11, 0xD5), 0xD51),
        // DBLP: undirected co-authorship, low skew, sparse.
        "db" => synthetic::erdos_renyi(6_656, 16_400, 0xDB).symmetrized(),
        // Youtube: undirected, sparse, skewed (hub channels).
        "yt" => synthetic::preferential_attachment(18_750, 2, 0x17).symmetrized(),
        // Pokec: directed, dense-ish, moderately skewed.
        "pk" => randomize_orientation(synthetic::preferential_attachment(25_000, 19, 0x9C), 0x9C1),
        // wiki-Talk: directed, very sparse, extreme skew, tiny SCC.
        "wt" => hub_graph(37_500, 78_000, 0.01, 0x37),
        // Orkut: undirected, dense, low skew.
        "or" => synthetic::erdos_renyi(48_400, 915_000, 0x08).symmetrized(),
        // LiveJournal: directed, skewed social graph.
        "lj" => randomize_orientation(synthetic::preferential_attachment(75_000, 14, 0x15), 0x151),
        // Twitter: the big one; R-MAT matches its heavy skew.
        "tw" => rmat::generate(RmatParams::graph500(16, 35, 0x70)),
        // bk: large-diameter, moderate degree -> near-ring lattice.
        // Ids scrambled: lattice construction order would otherwise be
        // perfectly anti-correlated with processing order, which makes
        // scan-order immediate propagation degenerate (real datasets'
        // ids are not topologically sorted).
        "bk" => scramble_ids(synthetic::small_world(10_700, 10, 0.0005, 0xBC), 0xBC2),
        // roadNet-CA: planar grid thinned to deg ~2.8, huge diameter;
        // ids scrambled for the same reason.
        "rd" => scramble_ids(thinned_grid(177, 177, 0.30, 0x4D), 0x4D2),
        // Graph500 R-MATs, scaled; edge factors preserved.
        "r21" => rmat::generate(RmatParams::graph500(14, 86, 0x21)),
        "r24" => rmat::generate(RmatParams::graph500(17, 16, 0x24)),
        _ => return None,
    };
    Some(g)
}

/// Weighted variant (SSSP / SpMV, Tab. 5; owned copy).
pub fn dataset_weighted(name: &str) -> Option<EdgeList> {
    dataset_weighted_shared(name).map(|g| (*g).clone())
}

/// Rename vertices by a random permutation (destroys construction-
/// order artifacts in generated graphs).
fn scramble_ids(g: EdgeList, seed: u64) -> EdgeList {
    let mut rng = Rng::new(seed);
    let perm = rng.permutation(g.num_vertices);
    g.renamed(&perm)
}

/// Preferential attachment emits all edges *from* the newest vertex,
/// which leaves out-degrees uniform. Real directed social graphs (sd,
/// pk, lj) have skewed out- AND in-degrees; flipping each edge's
/// orientation with p = 0.5 gives hubs both directions and creates
/// the large SCC the originals have.
fn randomize_orientation(mut g: EdgeList, seed: u64) -> EdgeList {
    let mut rng = Rng::new(seed);
    for e in &mut g.edges {
        if rng.chance(0.5) {
            std::mem::swap(&mut e.src, &mut e.dst);
        }
    }
    g
}

/// wiki-Talk-like generator: a tiny fraction of "talker" hubs emit
/// almost all edges toward uniformly random vertices, giving extreme
/// out-degree skew and a very small SCC.
fn hub_graph(n: usize, m: usize, hub_fraction: f64, seed: u64) -> EdgeList {
    let mut rng = Rng::new(seed);
    let mut g = EdgeList::new(n, true);
    g.edges.reserve(m);
    let hubs = ((n as f64 * hub_fraction) as usize).max(1);
    for _ in 0..m {
        // 85% of edges come from hubs (heavily skewed Zipf-ish mass),
        // the rest from the long tail.
        let src = if rng.chance(0.85) {
            // Within hubs, mass concentrates on the first few.
            let r = rng.next_f64();
            ((r * r * hubs as f64) as usize).min(hubs - 1) as VertexId
        } else {
            rng.range(hubs as u64, n as u64) as VertexId
        };
        let dst = rng.next_below(n as u64) as VertexId;
        g.add(src, dst);
    }
    g
}

/// Grid with a fraction of lattice links removed (kept symmetric):
/// road-network degree (~2.8) and diameter shape.
fn thinned_grid(rows: usize, cols: usize, drop: f64, seed: u64) -> EdgeList {
    let full = synthetic::grid_2d(rows, cols);
    let mut rng = Rng::new(seed);
    let mut g = EdgeList::new(full.num_vertices, false);
    // grid_2d emits symmetric pairs consecutively; walk in pairs.
    let mut i = 0;
    while i + 1 < full.edges.len() {
        if !rng.chance(drop) {
            g.edges.push(full.edges[i]);
            g.edges.push(full.edges[i + 1]);
        }
        i += 2;
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::properties::GraphProperties;

    #[test]
    fn dataset_id_round_trips() {
        assert_eq!(DatasetId::all().len(), dataset_names().len());
        for (id, &name) in DatasetId::all().iter().zip(dataset_names()) {
            assert_eq!(id.name(), name);
            assert_eq!(name.parse::<DatasetId>().unwrap(), *id);
            assert_eq!(id.to_string(), name);
            assert_eq!(id.spec().name, name);
        }
        assert_eq!(
            DatasetId::ablation().map(|d| d.name()),
            *ablation_dataset_names()
        );
        let err = "zz".parse::<DatasetId>().unwrap_err();
        assert!(err.contains("unknown dataset"), "{err}");
    }

    #[test]
    fn dataset_id_loads_graphs() {
        let g = DatasetId::Sd.load();
        assert!(g.num_edges() > 0);
        assert!(DatasetId::Sd.load_weighted().weighted);
    }

    #[test]
    fn all_names_resolve() {
        for &name in dataset_names() {
            assert!(spec(name).is_some(), "spec {name}");
            let g = dataset(name).unwrap_or_else(|| panic!("dataset {name}"));
            assert!(g.num_vertices > 0);
            assert!(g.num_edges() > 0);
        }
        assert!(dataset("nope").is_none());
    }

    #[test]
    fn directedness_matches_spec() {
        for &name in dataset_names() {
            let s = spec(name).unwrap();
            let g = dataset(name).unwrap();
            assert_eq!(g.directed, s.directed, "{name}");
        }
    }

    #[test]
    fn deterministic() {
        let a = dataset("lj").unwrap();
        let b = dataset("lj").unwrap();
        assert_eq!(a.edges[..50], b.edges[..50]);
        assert_eq!(a.num_edges(), b.num_edges());
    }

    #[test]
    fn density_shape_preserved() {
        // or must be densest; wt and rd sparsest — the Fig. 14 x-axis.
        let d = |n: &str| dataset(n).unwrap().avg_degree();
        assert!(d("or") > 30.0, "or {}", d("or"));
        assert!(d("r21") > 60.0, "r21 {}", d("r21"));
        assert!(d("wt") < 4.0, "wt {}", d("wt"));
        assert!(d("rd") < 4.0, "rd {}", d("rd"));
        assert!(d("or") > d("lj") && d("lj") > d("db"));
    }

    #[test]
    fn skewness_shape_preserved() {
        // wt and tw highly skewed; db, or, rd low skew — Fig. 10 x-axis.
        let sk = |n: &str| {
            GraphProperties::compute(&dataset(n).unwrap()).degree_skewness
        };
        assert!(sk("wt") > 5.0, "wt {}", sk("wt"));
        assert!(sk("tw") > 3.0, "tw {}", sk("tw"));
        assert!(sk("db") < 1.5, "db {}", sk("db"));
        assert!(sk("rd") < 1.5, "rd {}", sk("rd"));
    }

    #[test]
    fn road_like_graphs_have_large_diameter() {
        let p_rd = GraphProperties::compute(&dataset("rd").unwrap());
        let p_lj = GraphProperties::compute(&dataset("lj").unwrap());
        assert!(
            p_rd.diameter_estimate > 20 * p_lj.diameter_estimate,
            "rd {} lj {}",
            p_rd.diameter_estimate,
            p_lj.diameter_estimate
        );
        let p_bk = GraphProperties::compute(&dataset("bk").unwrap());
        assert!(p_bk.diameter_estimate > 100, "bk {}", p_bk.diameter_estimate);
    }

    #[test]
    fn wt_has_small_scc() {
        let p = GraphProperties::compute(&dataset("wt").unwrap());
        assert!(p.scc_ratio < 0.3, "wt scc {}", p.scc_ratio);
    }

    #[test]
    fn weighted_variant() {
        let g = dataset_weighted("sd").unwrap();
        assert!(g.weighted);
        assert!(g.edges.iter().all(|e| e.weight >= 1.0));
    }
}
