//! Edge-list representation: the binary format HitGraph and ThunderGP
//! iterate over (8 B per unweighted edge: two 32-bit vertex ids;
//! +4 B for a weight, §4.1).

use super::VertexId;
use crate::util::rng::Rng;

/// A directed edge.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Edge {
    pub src: VertexId,
    pub dst: VertexId,
    /// Weight; 1.0 for unweighted graphs.
    pub weight: f32,
}

/// A graph as a list of directed edges.
#[derive(Clone, Debug)]
pub struct EdgeList {
    /// Number of vertices `n = |V|`.
    pub num_vertices: usize,
    pub edges: Vec<Edge>,
    /// Whether the source data was directed. Undirected inputs are
    /// stored with both edge directions materialized (as the
    /// accelerators do).
    pub directed: bool,
    /// Whether edges carry meaningful weights (SSSP / SpMV).
    pub weighted: bool,
}

impl EdgeList {
    pub fn new(num_vertices: usize, directed: bool) -> Self {
        EdgeList {
            num_vertices,
            edges: Vec::new(),
            directed,
            weighted: false,
        }
    }

    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    pub fn add(&mut self, src: VertexId, dst: VertexId) {
        debug_assert!((src as usize) < self.num_vertices);
        debug_assert!((dst as usize) < self.num_vertices);
        self.edges.push(Edge {
            src,
            dst,
            weight: 1.0,
        });
    }

    /// Average degree `m / n` (the paper's `D_avg`).
    pub fn avg_degree(&self) -> f64 {
        if self.num_vertices == 0 {
            return 0.0;
        }
        self.edges.len() as f64 / self.num_vertices as f64
    }

    /// Out-degree per vertex.
    pub fn out_degrees(&self) -> Vec<u32> {
        let mut d = vec![0u32; self.num_vertices];
        for e in &self.edges {
            d[e.src as usize] += 1;
        }
        d
    }

    /// In-degree per vertex.
    pub fn in_degrees(&self) -> Vec<u32> {
        let mut d = vec![0u32; self.num_vertices];
        for e in &self.edges {
            d[e.dst as usize] += 1;
        }
        d
    }

    /// Sort edges by source vertex (ThunderGP's "sorted edge list").
    pub fn sort_by_src(&mut self) {
        self.edges.sort_by_key(|e| (e.src, e.dst));
    }

    /// Sort edges by destination vertex (HitGraph's `Sort` optimization).
    pub fn sort_by_dst(&mut self) {
        self.edges.sort_by_key(|e| (e.dst, e.src));
    }

    /// Reverse every edge (for pull-based / inverted-CSR processing).
    pub fn inverted(&self) -> EdgeList {
        EdgeList {
            num_vertices: self.num_vertices,
            edges: self
                .edges
                .iter()
                .map(|e| Edge {
                    src: e.dst,
                    dst: e.src,
                    weight: e.weight,
                })
                .collect(),
            directed: self.directed,
            weighted: self.weighted,
        }
    }

    /// Materialize both directions (undirected semantics).
    pub fn symmetrized(&self) -> EdgeList {
        let mut edges = Vec::with_capacity(self.edges.len() * 2);
        for e in &self.edges {
            edges.push(*e);
            edges.push(Edge {
                src: e.dst,
                dst: e.src,
                weight: e.weight,
            });
        }
        EdgeList {
            num_vertices: self.num_vertices,
            edges,
            directed: false,
            weighted: self.weighted,
        }
    }

    /// Attach deterministic pseudo-random weights in `[1, max_w)`
    /// (for SSSP/SpMV, which "require edge weights", §4.1).
    pub fn with_random_weights(mut self, seed: u64, max_w: f32) -> EdgeList {
        let mut rng = Rng::new(seed);
        for e in &mut self.edges {
            e.weight = 1.0 + rng.next_f32() * (max_w - 1.0);
        }
        self.weighted = true;
        self
    }

    /// Rename vertices by a permutation (`perm[old] = new`). Used by
    /// ForeGraph's stride mapping.
    pub fn renamed(&self, perm: &[VertexId]) -> EdgeList {
        assert_eq!(perm.len(), self.num_vertices);
        EdgeList {
            num_vertices: self.num_vertices,
            edges: self
                .edges
                .iter()
                .map(|e| Edge {
                    src: perm[e.src as usize],
                    dst: perm[e.dst as usize],
                    weight: e.weight,
                })
                .collect(),
            directed: self.directed,
            weighted: self.weighted,
        }
    }

    /// Bytes of one edge record in the accelerator binary formats.
    pub fn edge_bytes(&self) -> u64 {
        if self.weighted {
            12
        } else {
            8
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle() -> EdgeList {
        let mut g = EdgeList::new(3, true);
        g.add(0, 1);
        g.add(1, 2);
        g.add(2, 0);
        g
    }

    #[test]
    fn degrees() {
        let g = triangle();
        assert_eq!(g.out_degrees(), vec![1, 1, 1]);
        assert_eq!(g.in_degrees(), vec![1, 1, 1]);
        assert!((g.avg_degree() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn inversion_swaps_directions() {
        let g = triangle().inverted();
        assert!(g.edges.contains(&Edge {
            src: 1,
            dst: 0,
            weight: 1.0
        }));
        assert_eq!(g.num_edges(), 3);
    }

    #[test]
    fn symmetrize_doubles_edges() {
        let g = triangle().symmetrized();
        assert_eq!(g.num_edges(), 6);
        assert!(!g.directed);
    }

    #[test]
    fn sorting_orders() {
        let mut g = EdgeList::new(4, true);
        g.add(3, 0);
        g.add(1, 2);
        g.add(1, 0);
        g.sort_by_src();
        assert_eq!(g.edges[0].src, 1);
        assert_eq!(g.edges[0].dst, 0);
        g.sort_by_dst();
        assert_eq!(g.edges[0].dst, 0);
    }

    #[test]
    fn weights_are_deterministic() {
        let a = triangle().with_random_weights(7, 10.0);
        let b = triangle().with_random_weights(7, 10.0);
        assert_eq!(a.edges, b.edges);
        assert!(a.weighted);
        assert_eq!(a.edge_bytes(), 12);
        assert!(a.edges.iter().all(|e| e.weight >= 1.0 && e.weight < 10.0));
    }

    #[test]
    fn rename_applies_permutation() {
        let g = triangle().renamed(&[2, 0, 1]);
        assert!(g.edges.contains(&Edge {
            src: 2,
            dst: 0,
            weight: 1.0
        }));
    }
}
