//! `graphmem` CLI — the L3 entrypoint.
//!
//! Subcommands:
//!   list                         available experiments / datasets / accelerators
//!   datasets                     Tab. 2-style dataset property table
//!   run <accel> <graph> <prob>   one simulation (options: --dram, --channels, --no-opt)
//!   sweep                        parallel multi-axis sweep (options below)
//!   trace <accel> <graph> <prob> write an issue-order request trace (--dram, --channels, --out)
//!   analyze <accel> <graph> <prob>  per-region access-pattern analysis of a live sim
//!   analyze --trace <file>       the same analysis over an existing trace file
//!   advise <accel> <graph> <prob>  probe the workload and print the advisor's
//!                                recommendation (partitioning / placement / on-chip)
//!   report --exp <id>            regenerate a figure/table (options: --scope, --csv)
//!   verify <graph> <prob>        golden-engine cross-check (native vs XLA/PJRT)
//!   lint <accel> <graph> <prob>  compile the spec's phase program and run the
//!                                static verifier (options: --dram, --channels, --no-opt)
//!   lint --src [--root DIR]      repo invariant linter: unwrap/expect ratchet,
//!                                memo-key coverage, wall-clock bans
//!   serve                        crash-safe simulation daemon with a durable disk
//!                                cache (--listen, --cache-dir, --max-inflight,
//!                                --max-cycles/--max-requests/--wall-timeout-ms, --warm)
//!   submit <accel> <graph> <prob>  submit one run to a daemon, with retry/backoff
//!                                and an opt-in --degraded advisor-estimate fallback
//!
//! All argument parsing goes through the typed `FromStr` impls
//! (`AcceleratorKind`, `DatasetId`, `ProblemKind`, `MemTech`) and into
//! `SimSpec`s; invalid combinations are rejected before any simulation
//! starts. Std-only argument parsing (the offline crate set has no
//! clap).

use anyhow::{anyhow, bail, Result};
use graphmem::accel::{AcceleratorConfig, AcceleratorKind};
use graphmem::advisor::Advisor;
use graphmem::algo::golden::values_agree;
use graphmem::algo::problem::{GraphProblem, ProblemKind};
use graphmem::coordinator::{run_experiment, Experiment, Scope};
use graphmem::dram::{ChannelMode, MemTech};
use graphmem::engine::{AlgorithmEngine, NativeEngine, XlaEngine};
use graphmem::graph::rmat::{self, RmatParams};
use graphmem::graph::{datasets, properties::GraphProperties, DatasetId};
use graphmem::onchip::OnChipConfig;
use graphmem::persist::{builtin_graphs, parse_manifest_with, write_manifest};
use graphmem::report::{
    advice_table, failure_details, failure_table, onchip_table, pattern_tables, rationale_lines,
    Table,
};
use graphmem::robust::RunBudget;
use graphmem::serve::{Client, Server, ServerConfig, SubmitOutcome};
use graphmem::sim::{Session, SimSpec, SpecError, Sweep, SweepOutcome, SweepTrial, Workload};
use graphmem::trace::{
    parse_events, parse_meta, write_events, write_meta, AccessPatternAnalyzer, TraceMeta,
};
use std::str::FromStr;
use std::time::Duration;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn flag_value<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
}

fn has_flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

/// Parse through a typed `FromStr` impl, lifting its message.
fn parse_arg<T: FromStr<Err = String>>(s: &str) -> Result<T> {
    s.parse().map_err(|e: String| anyhow!(e))
}

/// Parse a comma-separated list through a typed `FromStr` impl.
fn parse_list<T: FromStr<Err = String>>(s: &str) -> Result<Vec<T>> {
    s.split(',').filter(|p| !p.is_empty()).map(parse_arg).collect()
}

fn dispatch(args: &[String]) -> Result<()> {
    match args.first().map(|s| s.as_str()) {
        Some("list") => cmd_list(),
        Some("datasets") => cmd_datasets(),
        Some("run") => cmd_run(&args[1..]),
        Some("sweep") => cmd_sweep(&args[1..]),
        Some("trace") => cmd_trace(&args[1..]),
        Some("analyze") => cmd_analyze(&args[1..]),
        Some("advise") => cmd_advise(&args[1..]),
        Some("report") => cmd_report(&args[1..]),
        Some("verify") => cmd_verify(&args[1..]),
        Some("lint") => cmd_lint(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("submit") => cmd_submit(&args[1..]),
        Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => bail!("unknown command {other:?} (try `graphmem help`)"),
    }
}

fn print_help() {
    println!(
        "graphmem — reproduction of 'Demystifying Memory Access Patterns of \
         FPGA-Based Graph Processing Accelerators'\n\n\
         USAGE:\n  graphmem list\n  graphmem datasets\n  \
         graphmem run <accel> <graph> <problem> [--dram ddr3|ddr4|hbm|hbm2] [--channels N] [--no-opt]\n  \
         graphmem sweep [--accels a,b,..] [--graphs g,..] [--problems p,..] [--drams d,..]\n  \
         \x20            [--channels n,..] [--threads N] [--no-opt] [--skip-unsupported] [--stats]\n  \
         \x20            [--keep-going|--fail-fast] [--manifest FILE] [--from-manifest FILE]\n  \
         \x20            (--manifest writes the expanded run plan to FILE; --from-manifest\n  \
         \x20             replays a previously written plan bit-identically instead of\n  \
         \x20             expanding the axis flags)\n  \
         \x20            (--stats prints the session's cache summary: phase programs\n  \
         \x20             compiled/reused, sim runs executed/memoized; failed points are\n  \
         \x20             isolated and tabulated by default [--keep-going] — --fail-fast\n  \
         \x20             aborts at the first failure instead)\n  \
         graphmem trace <accel> <graph> <problem> [--dram ddr3|ddr4|hbm|hbm2] [--channels N] [--out <file>]\n  \
         \x20            (issue-order request trace; --channels is validated against the DRAM's\n  \
         \x20             Tab. 3 maximum: 4 for DDR3/DDR4, 8 for HBM, 32 for HBM2 pseudo-channels)\n  \
         graphmem analyze <accel> <graph> <problem> [--dram d] [--channels N] [--no-opt] [--csv]\n  \
         \x20            [--onchip default|off|<bytes>]\n  \
         \x20            (per-region access-pattern tables from a live simulation; --onchip\n  \
         \x20             additionally models the accelerator's BRAM buffer and prints the\n  \
         \x20             reuse-histogram-predicted vs simulated hit rate)\n  \
         graphmem analyze --trace <file> [--dram d] [--channels N] [--mode interleave|region] [--csv]\n  \
         \x20            (same analysis over a trace file; flags default to the file's header)\n  \
         graphmem advise <accel> <graph> <problem> [--dram d] [--no-opt] [--probe-edges N] [--csv]\n  \
         \x20            (probe the workload, then print the advisor's partitioning /\n  \
         \x20             placement / on-chip recommendation with per-choice rationale;\n  \
         \x20             graphs above N edges are sampled before probing)\n  \
         graphmem report --exp <id|all> [--scope quick|standard|full] [--csv]\n  \
         graphmem verify <graph> <problem> [--max-iters N]\n  \
         graphmem lint <accel> <graph> <problem> [--dram d] [--channels N] [--no-opt]\n  \
         \x20            (compile the spec's phase program and statically verify it:\n  \
         \x20             region bounds, fanout/merge token conservation, chain\n  \
         \x20             acyclicity, gather domains, footprints, on-chip consistency)\n  \
         graphmem lint --src [--root DIR]\n  \
         \x20            (repo invariant linter: unwrap/expect ratchet against\n  \
         \x20             lint-allowlist.txt, SimSpec<->persist memo-key coverage,\n  \
         \x20             wall-clock bans in sim/ dram/ accel/)\n  \
         graphmem serve [--listen ADDR] [--cache-dir DIR] [--max-inflight N] [--retry-after-ms N]\n  \
         \x20            [--max-cycles N] [--max-requests N] [--wall-timeout-ms N] [--warm]\n  \
         \x20            (line-protocol daemon; --cache-dir makes reports and failure memos\n  \
         \x20             durable across restarts, the --max-* flags cap every admitted run,\n  \
         \x20             --warm precompiles the quick-scope figure matrix; stop it with\n  \
         \x20             `graphmem submit --shutdown`)\n  \
         graphmem submit <accel> <graph> <problem> [--addr ADDR] [--dram d] [--channels N]\n  \
         \x20            [--no-opt] [--degraded] [--retries N] [--max-cycles N] [--max-requests N]\n  \
         \x20            [--wall-timeout-ms N]\n  \
         graphmem submit --ping|--stats|--shutdown|--boom [--addr ADDR]\n  \
         \x20            (client with exponential-backoff retries on BUSY/connect failure;\n  \
         \x20             --degraded answers budget-exceeded runs with the advisor's\n  \
         \x20             probe-based estimate, clearly marked)\n\n\
         accel: accugraph|foregraph|hitgraph|thundergp|regraph   problem: bfs|pr|wcc|sssp|spmv\n\
         graph: any Tab. 2 name (see `graphmem list`) or rmat-small (synthetic quick-analysis graph)"
    );
}

fn cmd_list() -> Result<()> {
    println!("experiments:");
    for e in Experiment::all() {
        println!("  {:<6} {}", e.id(), e.description());
    }
    println!("\naccelerators:");
    for k in AcceleratorKind::all() {
        println!(
            "  {:<10} multi-channel={} weighted={}",
            k.name(),
            k.multi_channel(),
            k.supports_weighted()
        );
    }
    println!("\ndatasets: {}", datasets::dataset_names().join(" "));
    Ok(())
}

fn cmd_datasets() -> Result<()> {
    let mut t = Table::new(
        "Tab. 2 — dataset stand-ins (scaled; paper sizes in parentheses)",
        &[
            "graph", "|V|", "|E|", "dir", "D_avg", "skew", "diam~", "SCC", "paper |V|",
            "paper |E|", "scale",
        ],
    );
    for id in DatasetId::all() {
        let spec = id.spec();
        let g = id.load_shared();
        let p = GraphProperties::compute(&g);
        t.row(vec![
            id.to_string(),
            graphmem::util::fmt_count(p.num_vertices as u64),
            graphmem::util::fmt_count(p.num_edges as u64),
            if p.directed { "yes" } else { "no" }.into(),
            format!("{:.2}", p.avg_degree),
            format!("{:.1}", p.degree_skewness),
            p.diameter_estimate.to_string(),
            format!("{:.2}", p.scc_ratio),
            graphmem::util::fmt_count(spec.paper_vertices),
            graphmem::util::fmt_count(spec.paper_edges),
            format!("1/{}", spec.scale_factor),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_run(args: &[String]) -> Result<()> {
    let (accel, graph, problem) = match (args.first(), args.get(1), args.get(2)) {
        (Some(a), Some(g), Some(p)) => (a, g, p),
        _ => bail!("usage: graphmem run <accel> <graph> <problem> [options]"),
    };
    let kind: AcceleratorKind = parse_arg(accel)?;
    let problem: ProblemKind = parse_arg(problem)?;
    let workload = parse_workload(graph, problem.weighted())?;
    let mem: MemTech = parse_arg(flag_value(args, "--dram").unwrap_or("ddr4"))?;
    let channels: usize = flag_value(args, "--channels").unwrap_or("1").parse()?;
    let cfg = if has_flag(args, "--no-opt") {
        AcceleratorConfig::baseline()
    } else {
        AcceleratorConfig::all_optimizations()
    };
    let spec = SimSpec::builder()
        .accelerator(kind)
        .workload(workload)
        .problem(problem)
        .mem(mem)
        .channels(channels)
        .config(cfg)
        .build()?;
    let r = spec.run();
    println!("{}", r.summary());
    println!(
        "  cycles={} requests={} (r={} w={}) bytes={}",
        r.cycles,
        r.dram.requests(),
        r.dram.reads,
        r.dram.writes,
        r.bytes_total
    );
    let (h, m, c) = r.row_mix();
    println!(
        "  row mix: {:.1}% hit / {:.1}% miss / {:.1}% conflict; refreshes={}",
        100.0 * h,
        100.0 * m,
        100.0 * c,
        r.dram.refreshes
    );
    let regions: Vec<String> = graphmem::trace::Region::all()
        .iter()
        .map(|&reg| format!("{reg}={}", r.dram.region_requests(reg)))
        .collect();
    println!("  region requests: {}", regions.join(" "));
    println!(
        "  iterations={} edges_read={} values_read={} values_written={} updates={} skipped={}/{}",
        r.metrics.iterations,
        r.metrics.edges_read,
        r.metrics.values_read,
        r.metrics.values_written,
        r.metrics.updates_rw,
        r.metrics.skipped,
        r.metrics.skipped + r.metrics.processed,
    );
    Ok(())
}

fn cmd_sweep(args: &[String]) -> Result<()> {
    let accels: Vec<AcceleratorKind> = match flag_value(args, "--accels") {
        Some(s) => parse_list(s)?,
        None => AcceleratorKind::all().to_vec(),
    };
    let problems: Vec<ProblemKind> = match flag_value(args, "--problems") {
        Some(s) => parse_list(s)?,
        None => vec![ProblemKind::Bfs],
    };
    // Graphs go through the workload parser so the synthetic aliases
    // (rmat-small) are valid here too, weighted when any problem
    // needs weights.
    let weighted = problems.iter().any(|p| p.weighted());
    let workloads: Vec<Workload> = match flag_value(args, "--graphs") {
        Some(s) => s
            .split(',')
            .filter(|p| !p.is_empty())
            .map(|n| parse_workload(n, weighted))
            .collect::<Result<_>>()?,
        None => [DatasetId::Sd, DatasetId::Db, DatasetId::Yt, DatasetId::Wt]
            .into_iter()
            .map(Workload::Named)
            .collect(),
    };
    let drams: Vec<MemTech> = match flag_value(args, "--drams") {
        Some(s) => parse_list(s)?,
        None => vec![MemTech::Ddr4],
    };
    let channels: Vec<usize> = match flag_value(args, "--channels") {
        Some(s) => s
            .split(',')
            .filter(|p| !p.is_empty())
            .map(|p| p.parse::<usize>().map_err(|e| anyhow!("bad channel count {p:?}: {e}")))
            .collect::<Result<_>>()?,
        None => vec![1],
    };
    let cfg = if has_flag(args, "--no-opt") {
        AcceleratorConfig::baseline()
    } else {
        AcceleratorConfig::all_optimizations()
    };
    let mut sweep = Sweep::new()
        .accelerators(accels)
        .workloads(workloads)
        .problems(problems)
        .mem_techs(drams)
        .channels(channels)
        .configs([cfg]);
    if has_flag(args, "--skip-unsupported") {
        sweep = sweep.skip_unsupported();
    }
    // Translate internal axis names into the flags this command exposes.
    let axis_error = |e: SpecError| match e {
        SpecError::EmptyAxis(axis) => {
            let flag = match axis {
                "accelerators" => "--accels",
                "workloads" => "--graphs",
                "problems" => "--problems",
                "mem_techs" => "--drams",
                "channels" => "--channels",
                other => other,
            };
            anyhow!("nothing to sweep: {flag} is empty")
        }
        other => anyhow!("{other}"),
    };
    // The run plan is an explicit spec list either way: expanded from
    // the axis flags, or replayed bit-identically from a manifest
    // written by an earlier `--manifest` run (synthetic graphs are
    // resolved by name through `persist::builtin_graphs`).
    let specs: Vec<SimSpec> = match flag_value(args, "--from-manifest") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow!("cannot read manifest {path}: {e}"))?;
            parse_manifest_with(&text, Some(&builtin_graphs))
                .map_err(|e| anyhow!("{path}: {e}"))?
        }
        None => sweep.specs().map_err(axis_error)?,
    };
    if let Some(path) = flag_value(args, "--manifest") {
        std::fs::write(path, write_manifest(&specs))
            .map_err(|e| anyhow!("cannot write manifest {path}: {e}"))?;
        eprintln!("wrote {} spec(s) to {path}", specs.len());
    }
    let mut session = Session::new();
    if let Some(t) = flag_value(args, "--threads") {
        session = session.with_threads(t.parse()?);
    }
    let t0 = std::time::Instant::now();
    // Failure handling: by default every point runs to an outcome
    // (--keep-going) and failures are tabulated afterwards;
    // --fail-fast aborts serially at the first failed point instead.
    let trials: Vec<SweepTrial> = if has_flag(args, "--fail-fast") {
        let mut trials = Vec::with_capacity(specs.len());
        for spec in specs {
            match session.try_run(&spec) {
                Ok(report) => trials.push(SweepTrial {
                    spec,
                    outcome: SweepOutcome::Ok(report),
                }),
                Err(err) => bail!(
                    "sweep aborted at {}: {err} (drop --fail-fast to run the remaining points)",
                    spec.label()
                ),
            }
        }
        trials
    } else {
        session.run_trials(&specs)
    };
    let wall = t0.elapsed().as_secs_f64();
    let mut t = Table::new(
        "Sweep results",
        &["accel", "graph", "problem", "dram", "ch", "sim time (s)", "MTEPS", "util%"],
    );
    for trial in &trials {
        let Some(r) = trial.outcome.report() else { continue };
        let s = &trial.spec;
        t.row(vec![
            s.accelerator().to_string(),
            s.workload().label().to_string(),
            s.problem().to_string(),
            s.mem().to_string(),
            s.channels().to_string(),
            format!("{:.5}", r.seconds),
            format!("{:.1}", r.mteps()),
            format!("{:.1}", 100.0 * r.bus_utilization),
        ]);
    }
    println!("{}", t.render());
    if let Some(failures) = failure_table(&trials) {
        println!("{}", failures.render());
        for block in failure_details(&trials) {
            eprintln!("{block}");
        }
    }
    if has_flag(args, "--stats") {
        let st = session.stats();
        println!(
            "cache: programs {} compiled / {} reused; sim runs {} executed / {} memoized / {} \
             duplicate-waits; disk {} hits / {} writes",
            st.programs_compiled,
            st.programs_reused,
            st.sim_runs - st.disk_hits,
            st.memo_hits,
            st.duplicate_waits,
            st.disk_hits,
            st.disk_writes
        );
    }
    let failed = trials.iter().filter(|t| !t.outcome.is_ok()).count();
    eprintln!(
        "{} runs ({} distinct simulations, {} failed) in {wall:.2}s wall",
        trials.len(),
        session.cached_runs(),
        failed
    );
    if failed > 0 {
        bail!("{failed} of {} sweep points failed (see the failure table above)", trials.len());
    }
    Ok(())
}

/// A CLI workload: any Tab. 2 dataset name, or the `rmat-small` alias
/// (a scale-10, edge-factor-8 Graph500 R-MAT — small enough for
/// instant pattern analysis). Weighted problems get deterministic
/// random weights, like the named datasets — under the distinct name
/// `rmat-small-w`, so the two variants (different edge digests) never
/// collide in manifests or the serve daemon's name-keyed resolver
/// (`graphmem::persist::builtin_graphs`).
fn parse_workload(name: &str, weighted: bool) -> Result<Workload> {
    if let Ok(id) = name.parse::<DatasetId>() {
        return Ok(Workload::Named(id));
    }
    match name.to_ascii_lowercase().as_str() {
        "rmat-small" if !weighted => Ok(Workload::custom(
            "rmat-small",
            rmat::generate(RmatParams::graph500(10, 8, 0x5A)),
        )),
        "rmat-small" | "rmat-small-w" => Ok(Workload::custom(
            "rmat-small-w",
            rmat::generate(RmatParams::graph500(10, 8, 0x5A)).with_random_weights(0x77EE, 64.0),
        )),
        _ => bail!(
            "unknown graph {name:?} (expected one of: {} or rmat-small)",
            datasets::dataset_names().join(" ")
        ),
    }
}

/// Build the spec shared by `trace` and `analyze` live runs. The
/// builder validates `--channels` against both the accelerator's
/// multi-channel capability and the DRAM technology's Tab. 3 maximum
/// (`MemTech::max_channels`).
fn spec_from_args(args: &[String], patterns: bool) -> Result<SimSpec> {
    let (accel, graph, problem) = match (args.first(), args.get(1), args.get(2)) {
        (Some(a), Some(g), Some(p)) => (a, g, p),
        _ => bail!(
            "usage: graphmem <trace|analyze|advise|submit> <accel> <graph> <problem> [options]"
        ),
    };
    let kind: AcceleratorKind = parse_arg(accel)?;
    let problem: ProblemKind = parse_arg(problem)?;
    let workload = parse_workload(graph, problem.weighted())?;
    let mem: MemTech = parse_arg(flag_value(args, "--dram").unwrap_or("ddr4"))?;
    let channels: usize = flag_value(args, "--channels").unwrap_or("1").parse()?;
    let cfg = if has_flag(args, "--no-opt") {
        AcceleratorConfig::baseline()
    } else {
        AcceleratorConfig::all_optimizations()
    };
    Ok(SimSpec::builder()
        .accelerator(kind)
        .workload(workload)
        .problem(problem)
        .mem(mem)
        .channels(channels)
        .config(cfg)
        .patterns(patterns)
        .build()?)
}

fn cmd_trace(args: &[String]) -> Result<()> {
    let out = flag_value(args, "--out").unwrap_or("trace.txt");
    let spec = spec_from_args(args, false)?;
    let (r, events) = spec.run_traced();
    let f = std::fs::File::create(out)?;
    let mut w = std::io::BufWriter::new(f);
    // Header records the organization so `analyze --trace` needs no
    // flags to reproduce the in-sim analysis.
    write_meta(
        &mut w,
        &TraceMeta {
            dram: spec.mem().name().to_string(),
            channels: spec.channels(),
            mode: spec.channel_mode(),
        },
    )?;
    let n = write_events(&mut w, &events)?;
    println!(
        "wrote {n} requests to {out} ({}, {} channel(s), {} iterations, sim {:.5}s)",
        spec.label(),
        spec.channels(),
        r.metrics.iterations,
        r.seconds
    );
    Ok(())
}

fn cmd_analyze(args: &[String]) -> Result<()> {
    let csv = has_flag(args, "--csv");
    // One session so the base analysis run and the optional --onchip
    // run share a single compiled phase program.
    let session = Session::new();
    let mut live_spec = None;
    let (label, summary) = if let Some(path) = flag_value(args, "--trace") {
        if flag_value(args, "--onchip").is_some() {
            bail!("--onchip needs a live simulation to model the buffer; drop --trace");
        }
        // Offline mode: re-analyze an existing trace file. The
        // organization comes from the file's header when present;
        // explicit flags override it (headerless traces default to
        // ddr4 x1 interleave).
        let text = std::fs::read_to_string(path)?;
        let meta = parse_meta(&text);
        let mem: MemTech = match flag_value(args, "--dram") {
            Some(s) => parse_arg(s)?,
            None => match &meta {
                Some(m) => parse_arg(&m.dram)?,
                None => MemTech::Ddr4,
            },
        };
        let channels: usize = match flag_value(args, "--channels") {
            Some(s) => s.parse()?,
            None => meta.as_ref().map(|m| m.channels).unwrap_or(1),
        };
        if channels == 0 || channels > mem.max_channels() {
            bail!(
                "--channels must be in 1..={} for {mem} (Tab. 3 / Fig. 12)",
                mem.max_channels()
            );
        }
        let mode = match flag_value(args, "--mode") {
            Some("interleave") => ChannelMode::InterleaveLine,
            Some("region") => ChannelMode::Region,
            Some(other) => bail!("bad --mode {other:?} (interleave|region)"),
            None => meta
                .as_ref()
                .map(|m| m.mode)
                .unwrap_or(ChannelMode::InterleaveLine),
        };
        let events = parse_events(&text).map_err(|e| anyhow!("{path}: {e}"))?;
        // A trace records which channel each request routed to; the
        // analysis is only meaningful under the same organization.
        if let Some(max_ch) = events.iter().map(|e| e.channel).max() {
            if max_ch >= channels {
                bail!(
                    "{path} contains events for channel {max_ch} but --channels is {channels}; \
                     re-run with the trace's organization (--channels {} or more, and --mode \
                     region for HitGraph/ThunderGP traces)",
                    max_ch + 1
                );
            }
        }
        let mut analyzer = AccessPatternAnalyzer::new(mem.spec(channels), mode);
        for ev in &events {
            analyzer.observe(ev);
        }
        (
            format!("{path} ({mem}x{channels}, {} events)", events.len()),
            analyzer.finish(),
        )
    } else {
        // Live mode: run the spec with the analyzer attached.
        let spec = spec_from_args(args, true)?;
        let r = session.run(&spec);
        println!("{}", r.summary());
        let summary = r
            .patterns
            .expect("patterns(true) specs always attach a summary");
        let label = spec.label();
        live_spec = Some((spec, r.dram));
        (label, summary)
    };
    for t in pattern_tables(&label, &summary) {
        if csv {
            println!("# {}", t.title);
            println!("{}", t.to_csv());
        } else {
            println!("{}", t.render());
        }
    }
    // On-chip axis: re-run the same spec with a buffer modelled and
    // close the loop — the reuse histograms above predict the hit
    // rate, the second run measures it.
    if let (Some((spec, dram_off)), Some(value)) = (live_spec, flag_value(args, "--onchip")) {
        let cfg = match value {
            "off" => return Ok(()), // explicit streaming-only: nothing to add
            "default" => {
                // Exit-code contract: an unsatisfiable request fails
                // the command instead of printing and returning zero.
                let Some(cfg) = OnChipConfig::default_for(spec.accelerator(), spec.config())
                else {
                    bail!(
                        "on-chip: {} is a streaming design with no default buffer; pass \
                         `--onchip <bytes>` to model a vertex scratchpad anyway, or \
                         `--onchip off`",
                        spec.accelerator()
                    );
                };
                cfg
            }
            bytes => OnChipConfig::vertex_cache(bytes.parse().map_err(|e| {
                anyhow!("bad --onchip {bytes:?}: expected default|off|<BRAM bytes> ({e})")
            })?),
        };
        let capacity_lines = cfg.capacity_lines();
        let regions: Vec<_> = cfg.regions().to_vec();
        // Second run: patterns off (the analysis above already ran);
        // the session reuses the compiled program — the buffer and
        // the patterns toggle are not part of the program key.
        let on_spec = spec_from_args(args, false)?.with_onchip(Some(cfg))?;
        let on = session.run(&on_spec);
        let stats = on.onchip.expect("onchip specs always attach counters");
        let t = onchip_table(&label, &stats);
        if csv {
            println!("# {}", t.title);
            println!("{}", t.to_csv());
        } else {
            println!("{}", t.render());
        }
        for region in regions {
            let reg = summary.region(region);
            if reg.requests() == 0 {
                continue;
            }
            println!(
                "{region}: reuse-histogram predicted hit rate {:.1}% vs simulated {:.1}% \
                 ({} lines); DRAM requests {} -> {}",
                100.0 * reg.predicted_hit_rate(capacity_lines),
                100.0 * stats.region_hit_rate(region),
                capacity_lines,
                dram_off.region_requests(region),
                on.dram.region_requests(region),
            );
        }
    }
    Ok(())
}

/// `graphmem advise <accel> <graph> <problem>`: run the advisor's
/// probe and print the recommendation table plus the per-choice
/// rationales. Invalid spec combinations surface as `SpecError`s
/// through `?`, so the process exits non-zero on bad arguments — the
/// same contract as `trace` and `analyze`.
fn cmd_advise(args: &[String]) -> Result<()> {
    let spec = spec_from_args(args, false)?;
    let mut advisor = Advisor::new();
    if let Some(v) = flag_value(args, "--probe-edges") {
        let max: usize = v
            .parse()
            .map_err(|e| anyhow!("bad --probe-edges {v:?}: {e}"))?;
        advisor = advisor.with_probe_max_edges(max);
    }
    let rec = advisor.recommend(&spec)?;
    let t = advice_table(&rec);
    if has_flag(args, "--csv") {
        println!("# {}", t.title);
        println!("{}", t.to_csv());
    } else {
        println!("{}", t.render());
    }
    for line in rationale_lines(&rec) {
        println!("{line}");
    }
    println!(
        "probe: {}{} — {} DRAM requests",
        rec.probe_label,
        if rec.probe_sampled { " (sampled)" } else { "" },
        rec.probe_requests
    );
    Ok(())
}

fn cmd_report(args: &[String]) -> Result<()> {
    let exp_id = flag_value(args, "--exp").unwrap_or("all");
    let scope = Scope::parse(flag_value(args, "--scope").unwrap_or("quick"))
        .ok_or_else(|| anyhow!("bad --scope (quick|standard|full)"))?;
    let csv = has_flag(args, "--csv");
    let experiments: Vec<Experiment> = if exp_id == "all" {
        Experiment::all().to_vec()
    } else {
        vec![Experiment::parse(exp_id).ok_or_else(|| anyhow!("unknown experiment {exp_id:?}"))?]
    };
    for exp in experiments {
        eprintln!("running {} ({}) ...", exp.id(), exp.description());
        let tables = run_experiment(exp, scope)?;
        for t in tables {
            if csv {
                println!("# {}", t.title);
                println!("{}", t.to_csv());
            } else {
                println!("{}", t.render());
            }
        }
    }
    Ok(())
}

fn cmd_verify(args: &[String]) -> Result<()> {
    let (graph, problem) = match (args.first(), args.get(1)) {
        (Some(g), Some(p)) => (g, p),
        _ => bail!("usage: graphmem verify <graph> <problem>"),
    };
    let graph: DatasetId = parse_arg(graph)?;
    let problem: ProblemKind = parse_arg(problem)?;
    let max_iters: u32 = flag_value(args, "--max-iters").unwrap_or("10000").parse()?;
    let g = if problem.weighted() {
        graph.load_weighted()
    } else {
        graph.load()
    };
    let p = GraphProblem::new(problem, &g);

    let mut native = NativeEngine::new();
    let t0 = std::time::Instant::now();
    let nres = native.run(&p, &g, max_iters)?;
    let native_t = t0.elapsed();
    println!(
        "native: {} iterations in {:.3}s",
        nres.iterations,
        native_t.as_secs_f64()
    );

    let mut xla = XlaEngine::from_repo_root()?;
    if !xla.fits(problem, &g) {
        println!(
            "xla: graph (n={}, m={}) exceeds artifact buckets — native-only verification done",
            g.num_vertices,
            g.num_edges()
        );
        return Ok(());
    }
    let t1 = std::time::Instant::now();
    let xres = xla.run(&p, &g, max_iters)?;
    let xla_t = t1.elapsed();
    println!(
        "xla:    {} iterations in {:.3}s (PJRT, AOT Pallas kernel)",
        xres.iterations,
        xla_t.as_secs_f64()
    );
    if xres.iterations == nres.iterations && values_agree(problem, &nres.values, &xres.values) {
        println!("VERIFY OK — native and XLA engines agree");
        Ok(())
    } else {
        bail!("VERIFY FAILED — engines diverge");
    }
}

fn cmd_lint(args: &[String]) -> Result<()> {
    if has_flag(args, "--src") {
        return cmd_lint_src(args);
    }
    // Program mode: compile the spec's phase program and run the
    // static verifier, printing every typed violation.
    let spec = spec_from_args(args, false)?;
    let report = spec.verify_program();
    println!("{} — {report}", spec.label());
    for v in &report.violations {
        println!("  {v}");
    }
    if report.is_ok() {
        println!("LINT OK — program passes static verification");
        Ok(())
    } else {
        bail!("{} violation(s) — see above", report.violations.len());
    }
}

fn cmd_lint_src(args: &[String]) -> Result<()> {
    use graphmem::verify::srclint::{find_src_root, lint_sources};
    let start = std::path::PathBuf::from(flag_value(args, "--root").unwrap_or("."));
    let src_root = find_src_root(&start).ok_or_else(|| {
        anyhow!(
            "no crate source root under {} (expected rust/src, src, or a lib.rs); \
             point --root at the repo or crate root",
            start.display()
        )
    })?;
    // The ratchet file sits next to Cargo.toml, one level above src/.
    let allowlist_path = src_root
        .parent()
        .map(|d| d.join("lint-allowlist.txt"))
        .ok_or_else(|| anyhow!("source root {} has no parent", src_root.display()))?;
    let allowlist = match std::fs::read_to_string(&allowlist_path) {
        Ok(text) => text,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => String::new(),
        Err(e) => bail!("reading {}: {e}", allowlist_path.display()),
    };
    let report = lint_sources(&src_root, &allowlist)?;
    for v in &report.violations {
        println!("{}:{}: {}", v.file, v.line, v.message);
    }
    for n in &report.notices {
        println!("notice: {n}");
    }
    println!(
        "{} file(s), {} grandfathered unwrap/expect site(s), {} violation(s), {} notice(s)",
        report.files,
        report.unwrap_sites,
        report.violations.len(),
        report.notices.len()
    );
    if report.is_ok() {
        println!("LINT OK");
        Ok(())
    } else {
        bail!("{} lint violation(s) — see above", report.violations.len());
    }
}

/// Shared `--max-cycles` / `--max-requests` / `--wall-timeout-ms`
/// parsing for `serve` (admission cap) and `submit` (per-spec budget).
fn budget_from_args(args: &[String]) -> Result<Option<RunBudget>> {
    let max_cycles: Option<u64> = flag_value(args, "--max-cycles")
        .map(|v| v.parse().map_err(|e| anyhow!("bad --max-cycles {v:?}: {e}")))
        .transpose()?;
    let max_requests: Option<u64> = flag_value(args, "--max-requests")
        .map(|v| v.parse().map_err(|e| anyhow!("bad --max-requests {v:?}: {e}")))
        .transpose()?;
    let wall_deadline: Option<Duration> = flag_value(args, "--wall-timeout-ms")
        .map(|v| v.parse().map_err(|e| anyhow!("bad --wall-timeout-ms {v:?}: {e}")))
        .transpose()?
        .map(Duration::from_millis);
    if max_cycles.is_none() && max_requests.is_none() && wall_deadline.is_none() {
        return Ok(None);
    }
    Ok(Some(RunBudget {
        max_cycles,
        max_requests,
        wall_deadline,
    }))
}

/// `graphmem serve`: bind the crash-safe simulation daemon and run it
/// until a `SHUTDOWN` request drains it. The "listening on" line is
/// flushed eagerly so supervisors (and the CI smoke job) can block on
/// it even through a pipe.
fn cmd_serve(args: &[String]) -> Result<()> {
    let listen = flag_value(args, "--listen").unwrap_or("127.0.0.1:7421");
    let defaults = ServerConfig::default();
    let cfg = ServerConfig {
        max_inflight: match flag_value(args, "--max-inflight") {
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("bad --max-inflight {v:?}: {e}"))?,
            None => defaults.max_inflight,
        },
        retry_after_ms: match flag_value(args, "--retry-after-ms") {
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("bad --retry-after-ms {v:?}: {e}"))?,
            None => defaults.retry_after_ms,
        },
        admission: budget_from_args(args)?,
        cache_dir: flag_value(args, "--cache-dir").map(std::path::PathBuf::from),
        warm: has_flag(args, "--warm"),
        ..defaults
    };
    let server = Server::bind(listen, cfg)?;
    let addr = server.local_addr()?;
    println!("listening on {addr}");
    {
        use std::io::Write;
        std::io::stdout().flush()?;
    }
    let stats = server.run()?;
    eprintln!(
        "served {} request(s): {} busy-rejected, {} sim failures, {} cache hits, {} degraded \
         replies",
        stats.requests,
        stats.busy_rejections,
        stats.sim_failures,
        stats.cache_hits,
        stats.degraded_replies
    );
    Ok(())
}

/// `graphmem submit`: one request to a running daemon, with the
/// client's retry/backoff handling `BUSY` and connection failures.
/// Failed simulations exit non-zero — the same contract as `run`.
fn cmd_submit(args: &[String]) -> Result<()> {
    let addr = flag_value(args, "--addr").unwrap_or("127.0.0.1:7421");
    let mut client = Client::new(addr);
    if let Some(r) = flag_value(args, "--retries") {
        client = client.with_max_attempts(
            r.parse().map_err(|e| anyhow!("bad --retries {r:?}: {e}"))?,
        );
    }
    if has_flag(args, "--ping") {
        client.ping()?;
        println!("pong");
        return Ok(());
    }
    if has_flag(args, "--stats") {
        for (k, v) in client.stats()? {
            println!("{k}={v}");
        }
        return Ok(());
    }
    if has_flag(args, "--shutdown") {
        client.shutdown()?;
        println!("shutting down");
        return Ok(());
    }
    if has_flag(args, "--boom") {
        let err = client.boom()?;
        println!("daemon survived an injected panic: {err}");
        return Ok(());
    }
    let mut spec = spec_from_args(args, false)?;
    if let Some(budget) = budget_from_args(args)? {
        spec = spec.with_budget(Some(budget));
    }
    match client.submit(&spec, has_flag(args, "--degraded"))? {
        SubmitOutcome::Report { report, cache_hit } => {
            println!("cache_hit={cache_hit}");
            println!("{}", report.summary());
            println!(
                "  cycles={} requests={} bytes={}",
                report.cycles,
                report.dram.requests(),
                report.bytes_total
            );
            Ok(())
        }
        SubmitOutcome::Degraded(est) => {
            println!("degraded=true (budget exceeded; advisor probe estimate, not a simulation)");
            println!(
                "  probe={}{} requests={} predicted_cycles={:.0} partitions={} channels={}",
                est.probe_label,
                if est.probe_sampled { " (sampled)" } else { "" },
                est.probe_requests,
                est.predicted_cycles,
                est.partitions,
                est.channels
            );
            println!("  rationale: {}", est.rationale);
            Ok(())
        }
        SubmitOutcome::Failed(err) => bail!("simulation failed: {err}"),
        SubmitOutcome::VerifyRejected { violations, first } => {
            bail!("server rejected the compiled program ({violations} violation(s)): {first}")
        }
    }
}
