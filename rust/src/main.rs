//! `graphmem` CLI — the L3 entrypoint.
//!
//! Subcommands:
//!   list                         available experiments / datasets / accelerators
//!   datasets                     Tab. 2-style dataset property table
//!   run <accel> <graph> <prob>   one simulation (options: --dram, --channels, --no-opt)
//!   sweep                        parallel multi-axis sweep (options below)
//!   report --exp <id>            regenerate a figure/table (options: --scope, --csv)
//!   verify <graph> <prob>        golden-engine cross-check (native vs XLA/PJRT)
//!
//! All argument parsing goes through the typed `FromStr` impls
//! (`AcceleratorKind`, `DatasetId`, `ProblemKind`, `MemTech`) and into
//! `SimSpec`s; invalid combinations are rejected before any simulation
//! starts. Std-only argument parsing (the offline crate set has no
//! clap).

use anyhow::{anyhow, bail, Result};
use graphmem::accel::{AcceleratorConfig, AcceleratorKind};
use graphmem::algo::golden::values_agree;
use graphmem::algo::problem::{GraphProblem, ProblemKind};
use graphmem::coordinator::{run_experiment, Experiment, Scope};
use graphmem::dram::MemTech;
use graphmem::engine::{AlgorithmEngine, NativeEngine, XlaEngine};
use graphmem::graph::{datasets, properties::GraphProperties, DatasetId};
use graphmem::report::Table;
use graphmem::sim::{Session, SimSpec, SpecError, Sweep};
use std::str::FromStr;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn flag_value<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
}

fn has_flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

/// Parse through a typed `FromStr` impl, lifting its message.
fn parse_arg<T: FromStr<Err = String>>(s: &str) -> Result<T> {
    s.parse().map_err(|e: String| anyhow!(e))
}

/// Parse a comma-separated list through a typed `FromStr` impl.
fn parse_list<T: FromStr<Err = String>>(s: &str) -> Result<Vec<T>> {
    s.split(',').filter(|p| !p.is_empty()).map(parse_arg).collect()
}

fn dispatch(args: &[String]) -> Result<()> {
    match args.first().map(|s| s.as_str()) {
        Some("list") => cmd_list(),
        Some("datasets") => cmd_datasets(),
        Some("run") => cmd_run(&args[1..]),
        Some("sweep") => cmd_sweep(&args[1..]),
        Some("trace") => cmd_trace(&args[1..]),
        Some("report") => cmd_report(&args[1..]),
        Some("verify") => cmd_verify(&args[1..]),
        Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => bail!("unknown command {other:?} (try `graphmem help`)"),
    }
}

fn print_help() {
    println!(
        "graphmem — reproduction of 'Demystifying Memory Access Patterns of \
         FPGA-Based Graph Processing Accelerators'\n\n\
         USAGE:\n  graphmem list\n  graphmem datasets\n  \
         graphmem run <accel> <graph> <problem> [--dram ddr3|ddr4|hbm] [--channels N] [--no-opt]\n  \
         graphmem sweep [--accels a,b,..] [--graphs g,..] [--problems p,..] [--drams d,..]\n  \
         \x20            [--channels n,..] [--threads N] [--no-opt] [--skip-unsupported]\n  \
         graphmem trace <accel> <graph> <problem> --out <file>   (Ramulator-style request trace)\n  \
         graphmem report --exp <id|all> [--scope quick|standard|full] [--csv]\n  \
         graphmem verify <graph> <problem> [--max-iters N]\n\n\
         accel: accugraph|foregraph|hitgraph|thundergp   problem: bfs|pr|wcc|sssp|spmv"
    );
}

fn cmd_list() -> Result<()> {
    println!("experiments:");
    for e in Experiment::all() {
        println!("  {:<6} {}", e.id(), e.description());
    }
    println!("\naccelerators:");
    for k in AcceleratorKind::all() {
        println!(
            "  {:<10} multi-channel={} weighted={}",
            k.name(),
            k.multi_channel(),
            k.supports_weighted()
        );
    }
    println!("\ndatasets: {}", datasets::dataset_names().join(" "));
    Ok(())
}

fn cmd_datasets() -> Result<()> {
    let mut t = Table::new(
        "Tab. 2 — dataset stand-ins (scaled; paper sizes in parentheses)",
        &[
            "graph", "|V|", "|E|", "dir", "D_avg", "skew", "diam~", "SCC", "paper |V|",
            "paper |E|", "scale",
        ],
    );
    for id in DatasetId::all() {
        let spec = id.spec();
        let g = id.load_shared();
        let p = GraphProperties::compute(&g);
        t.row(vec![
            id.to_string(),
            graphmem::util::fmt_count(p.num_vertices as u64),
            graphmem::util::fmt_count(p.num_edges as u64),
            if p.directed { "yes" } else { "no" }.into(),
            format!("{:.2}", p.avg_degree),
            format!("{:.1}", p.degree_skewness),
            p.diameter_estimate.to_string(),
            format!("{:.2}", p.scc_ratio),
            graphmem::util::fmt_count(spec.paper_vertices),
            graphmem::util::fmt_count(spec.paper_edges),
            format!("1/{}", spec.scale_factor),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_run(args: &[String]) -> Result<()> {
    let (accel, graph, problem) = match (args.first(), args.get(1), args.get(2)) {
        (Some(a), Some(g), Some(p)) => (a, g, p),
        _ => bail!("usage: graphmem run <accel> <graph> <problem> [options]"),
    };
    let kind: AcceleratorKind = parse_arg(accel)?;
    let graph: DatasetId = parse_arg(graph)?;
    let problem: ProblemKind = parse_arg(problem)?;
    let mem: MemTech = parse_arg(flag_value(args, "--dram").unwrap_or("ddr4"))?;
    let channels: usize = flag_value(args, "--channels").unwrap_or("1").parse()?;
    let cfg = if has_flag(args, "--no-opt") {
        AcceleratorConfig::baseline()
    } else {
        AcceleratorConfig::all_optimizations()
    };
    let spec = SimSpec::builder()
        .accelerator(kind)
        .graph(graph)
        .problem(problem)
        .mem(mem)
        .channels(channels)
        .config(cfg)
        .build()?;
    let r = spec.run();
    println!("{}", r.summary());
    println!(
        "  cycles={} requests={} (r={} w={}) bytes={}",
        r.cycles,
        r.dram.requests(),
        r.dram.reads,
        r.dram.writes,
        r.bytes_total
    );
    let (h, m, c) = r.row_mix();
    println!(
        "  row mix: {:.1}% hit / {:.1}% miss / {:.1}% conflict; refreshes={}",
        100.0 * h,
        100.0 * m,
        100.0 * c,
        r.dram.refreshes
    );
    println!(
        "  iterations={} edges_read={} values_read={} values_written={} updates={} skipped={}/{}",
        r.metrics.iterations,
        r.metrics.edges_read,
        r.metrics.values_read,
        r.metrics.values_written,
        r.metrics.updates_rw,
        r.metrics.skipped,
        r.metrics.skipped + r.metrics.processed,
    );
    Ok(())
}

fn cmd_sweep(args: &[String]) -> Result<()> {
    let accels: Vec<AcceleratorKind> = match flag_value(args, "--accels") {
        Some(s) => parse_list(s)?,
        None => AcceleratorKind::all().to_vec(),
    };
    let graphs: Vec<DatasetId> = match flag_value(args, "--graphs") {
        Some(s) => parse_list(s)?,
        None => vec![DatasetId::Sd, DatasetId::Db, DatasetId::Yt, DatasetId::Wt],
    };
    let problems: Vec<ProblemKind> = match flag_value(args, "--problems") {
        Some(s) => parse_list(s)?,
        None => vec![ProblemKind::Bfs],
    };
    let drams: Vec<MemTech> = match flag_value(args, "--drams") {
        Some(s) => parse_list(s)?,
        None => vec![MemTech::Ddr4],
    };
    let channels: Vec<usize> = match flag_value(args, "--channels") {
        Some(s) => s
            .split(',')
            .filter(|p| !p.is_empty())
            .map(|p| p.parse::<usize>().map_err(|e| anyhow!("bad channel count {p:?}: {e}")))
            .collect::<Result<_>>()?,
        None => vec![1],
    };
    let cfg = if has_flag(args, "--no-opt") {
        AcceleratorConfig::baseline()
    } else {
        AcceleratorConfig::all_optimizations()
    };
    let mut sweep = Sweep::new()
        .accelerators(accels)
        .graphs(graphs)
        .problems(problems)
        .mem_techs(drams)
        .channels(channels)
        .configs([cfg]);
    if has_flag(args, "--skip-unsupported") {
        sweep = sweep.skip_unsupported();
    }
    if let Some(t) = flag_value(args, "--threads") {
        sweep = sweep.threads(t.parse()?);
    }
    let session = Session::new();
    let t0 = std::time::Instant::now();
    // Translate internal axis names into the flags this command exposes.
    let runs = sweep.run_with(&session).map_err(|e| match e {
        SpecError::EmptyAxis(axis) => {
            let flag = match axis {
                "accelerators" => "--accels",
                "workloads" => "--graphs",
                "problems" => "--problems",
                "mem_techs" => "--drams",
                "channels" => "--channels",
                other => other,
            };
            anyhow!("nothing to sweep: {flag} is empty")
        }
        other => anyhow!("{other}"),
    })?;
    let wall = t0.elapsed().as_secs_f64();
    let mut t = Table::new(
        "Sweep results",
        &["accel", "graph", "problem", "dram", "ch", "sim time (s)", "MTEPS", "util%"],
    );
    for run in &runs {
        let (s, r) = (&run.spec, &run.report);
        t.row(vec![
            s.accelerator().to_string(),
            s.workload().label().to_string(),
            s.problem().to_string(),
            s.mem().to_string(),
            s.channels().to_string(),
            format!("{:.5}", r.seconds),
            format!("{:.1}", r.mteps()),
            format!("{:.1}", 100.0 * r.bus_utilization),
        ]);
    }
    println!("{}", t.render());
    eprintln!(
        "{} runs ({} distinct simulations) in {wall:.2}s wall",
        runs.len(),
        session.cached_runs()
    );
    Ok(())
}

fn cmd_trace(args: &[String]) -> Result<()> {
    use graphmem::accel::build;
    use graphmem::dram::{ChannelMode, MemorySystem};

    let (accel, graph, problem) = match (args.first(), args.get(1), args.get(2)) {
        (Some(a), Some(g), Some(p)) => (a, g, p),
        _ => bail!("usage: graphmem trace <accel> <graph> <problem> --out <file>"),
    };
    let out = flag_value(args, "--out").unwrap_or("trace.txt");
    let kind: AcceleratorKind = parse_arg(accel)?;
    let graph: DatasetId = parse_arg(graph)?;
    let problem: ProblemKind = parse_arg(problem)?;
    let mem: MemTech = parse_arg(flag_value(args, "--dram").unwrap_or("ddr4"))?;
    let g = if problem.weighted() {
        graph.load_weighted()
    } else {
        graph.load()
    };
    let p = GraphProblem::new(problem, &g);
    let cfg = AcceleratorConfig::all_optimizations();
    let mode = if kind.multi_channel() {
        ChannelMode::Region
    } else {
        ChannelMode::InterleaveLine
    };
    let mut mem = MemorySystem::with_mode(mem.spec(1), mode);
    mem.enable_trace();
    let mut a = build(kind, &g, &cfg);
    let r = a.run(&p, &mut mem);
    let f = std::fs::File::create(out)?;
    let n = mem.write_trace(std::io::BufWriter::new(f))?;
    println!(
        "wrote {n} requests to {out} ({} iterations, sim {:.5}s)",
        r.metrics.iterations, r.seconds
    );
    Ok(())
}

fn cmd_report(args: &[String]) -> Result<()> {
    let exp_id = flag_value(args, "--exp").unwrap_or("all");
    let scope = Scope::parse(flag_value(args, "--scope").unwrap_or("quick"))
        .ok_or_else(|| anyhow!("bad --scope (quick|standard|full)"))?;
    let csv = has_flag(args, "--csv");
    let experiments: Vec<Experiment> = if exp_id == "all" {
        Experiment::all().to_vec()
    } else {
        vec![Experiment::parse(exp_id).ok_or_else(|| anyhow!("unknown experiment {exp_id:?}"))?]
    };
    for exp in experiments {
        eprintln!("running {} ({}) ...", exp.id(), exp.description());
        let tables = run_experiment(exp, scope)?;
        for t in tables {
            if csv {
                println!("# {}", t.title);
                println!("{}", t.to_csv());
            } else {
                println!("{}", t.render());
            }
        }
    }
    Ok(())
}

fn cmd_verify(args: &[String]) -> Result<()> {
    let (graph, problem) = match (args.first(), args.get(1)) {
        (Some(g), Some(p)) => (g, p),
        _ => bail!("usage: graphmem verify <graph> <problem>"),
    };
    let graph: DatasetId = parse_arg(graph)?;
    let problem: ProblemKind = parse_arg(problem)?;
    let max_iters: u32 = flag_value(args, "--max-iters").unwrap_or("10000").parse()?;
    let g = if problem.weighted() {
        graph.load_weighted()
    } else {
        graph.load()
    };
    let p = GraphProblem::new(problem, &g);

    let mut native = NativeEngine::new();
    let t0 = std::time::Instant::now();
    let nres = native.run(&p, &g, max_iters)?;
    let native_t = t0.elapsed();
    println!(
        "native: {} iterations in {:.3}s",
        nres.iterations,
        native_t.as_secs_f64()
    );

    let mut xla = XlaEngine::from_repo_root()?;
    if !xla.fits(problem, &g) {
        println!(
            "xla: graph (n={}, m={}) exceeds artifact buckets — native-only verification done",
            g.num_vertices,
            g.num_edges()
        );
        return Ok(());
    }
    let t1 = std::time::Instant::now();
    let xres = xla.run(&p, &g, max_iters)?;
    let xla_t = t1.elapsed();
    println!(
        "xla:    {} iterations in {:.3}s (PJRT, AOT Pallas kernel)",
        xres.iterations,
        xla_t.as_secs_f64()
    );
    if xres.iterations == nres.iterations && values_agree(problem, &nres.values, &xres.values) {
        println!("VERIFY OK — native and XLA engines agree");
        Ok(())
    } else {
        bail!("VERIFY FAILED — engines diverge");
    }
}
