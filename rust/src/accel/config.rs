//! Accelerator configuration: which system, which optimizations, and
//! the scaled on-chip capacities (DESIGN.md §6).

use crate::partition::{SCALED_BRAM_VALUES, SCALED_FOREGRAPH_INTERVAL};

/// The five modelled systems: the paper's four plus the post-paper
/// ReGraph-style heterogeneous HBM2 design.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AcceleratorKind {
    AccuGraph,
    ForeGraph,
    HitGraph,
    ThunderGp,
    ReGraph,
}

impl AcceleratorKind {
    pub fn name(self) -> &'static str {
        match self {
            AcceleratorKind::AccuGraph => "AccuGraph",
            AcceleratorKind::ForeGraph => "ForeGraph",
            AcceleratorKind::HitGraph => "HitGraph",
            AcceleratorKind::ThunderGp => "ThunderGP",
            AcceleratorKind::ReGraph => "ReGraph",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "accugraph" | "accu" | "ag" => Some(AcceleratorKind::AccuGraph),
            "foregraph" | "fore" | "fg" => Some(AcceleratorKind::ForeGraph),
            "hitgraph" | "hit" | "hg" => Some(AcceleratorKind::HitGraph),
            "thundergp" | "thunder" | "tgp" => Some(AcceleratorKind::ThunderGp),
            "regraph" | "rg" => Some(AcceleratorKind::ReGraph),
            _ => None,
        }
    }

    pub fn all() -> [AcceleratorKind; 5] {
        [
            AcceleratorKind::AccuGraph,
            AcceleratorKind::ForeGraph,
            AcceleratorKind::HitGraph,
            AcceleratorKind::ThunderGp,
            AcceleratorKind::ReGraph,
        ]
    }

    /// Does this system support multi-channel memory (Fig. 12)?
    pub fn multi_channel(self) -> bool {
        matches!(
            self,
            AcceleratorKind::HitGraph | AcceleratorKind::ThunderGp | AcceleratorKind::ReGraph
        )
    }

    /// Does this system support weighted problems (Tab. 5)?
    pub fn supports_weighted(self) -> bool {
        matches!(
            self,
            AcceleratorKind::HitGraph | AcceleratorKind::ThunderGp | AcceleratorKind::ReGraph
        )
    }
}

impl std::str::FromStr for AcceleratorKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        AcceleratorKind::parse(s).ok_or_else(|| {
            format!("unknown accelerator {s:?} (accugraph|foregraph|hitgraph|thundergp|regraph)")
        })
    }
}

impl std::fmt::Display for AcceleratorKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Every optimization the paper ablates (Fig. 13 / Tab. 8).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Optimization {
    /// AccuGraph: skip the value prefetch when the on-chip partition
    /// is already the to-be-prefetched one (`Pref.`).
    PrefetchSkipping,
    /// AccuGraph / HitGraph: skip partitions with no active sources
    /// (`Skip.`).
    PartitionSkipping,
    /// ForeGraph: zip the edge lists of `p` shards (`Shuf.`).
    EdgeShuffling,
    /// ForeGraph: skip shards whose source interval is unchanged (`Skip.`).
    ShardSkipping,
    /// ForeGraph: rename vertices to constant-stride intervals (`Map.`).
    StrideMapping,
    /// HitGraph: sort partition edges by destination (`Sort`).
    EdgeSorting,
    /// HitGraph: combine updates to the same destination (`Cmb.`).
    UpdateCombining,
    /// HitGraph: bitmap-filter updates from inactive sources (`Filt.`).
    UpdateFiltering,
    /// ThunderGP: offline chunk-to-channel scheduling (`Schd.`).
    ChunkScheduling,
}

impl Optimization {
    /// Stable serialization name (used by `crate::persist`).
    pub fn name(self) -> &'static str {
        match self {
            Optimization::PrefetchSkipping => "PrefetchSkipping",
            Optimization::PartitionSkipping => "PartitionSkipping",
            Optimization::EdgeShuffling => "EdgeShuffling",
            Optimization::ShardSkipping => "ShardSkipping",
            Optimization::StrideMapping => "StrideMapping",
            Optimization::EdgeSorting => "EdgeSorting",
            Optimization::UpdateCombining => "UpdateCombining",
            Optimization::UpdateFiltering => "UpdateFiltering",
            Optimization::ChunkScheduling => "ChunkScheduling",
        }
    }

    /// Inverse of [`Optimization::name`] (case-insensitive).
    pub fn parse(s: &str) -> Option<Optimization> {
        match s.to_ascii_lowercase().as_str() {
            "prefetchskipping" => Some(Optimization::PrefetchSkipping),
            "partitionskipping" => Some(Optimization::PartitionSkipping),
            "edgeshuffling" => Some(Optimization::EdgeShuffling),
            "shardskipping" => Some(Optimization::ShardSkipping),
            "stridemapping" => Some(Optimization::StrideMapping),
            "edgesorting" => Some(Optimization::EdgeSorting),
            "updatecombining" => Some(Optimization::UpdateCombining),
            "updatefiltering" => Some(Optimization::UpdateFiltering),
            "chunkscheduling" => Some(Optimization::ChunkScheduling),
            _ => None,
        }
    }
}

/// Full accelerator configuration.
///
/// Derives `Hash`/`Eq` so memoization keys (see
/// [`crate::sim::Session`]) are derived from the *whole* value — the
/// old hand-rolled string key silently omitted `window` and
/// `experimental_multichannel`, aliasing distinct runs.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct AcceleratorConfig {
    /// Enabled optimizations.
    pub optimizations: Vec<Optimization>,
    /// On-chip value capacity (AccuGraph / HitGraph / ThunderGP
    /// interval bound). Scaled stand-in for 1,024,000.
    pub bram_values: usize,
    /// ForeGraph interval size (<= 65,536; scaled stand-in for 65,536).
    pub foregraph_interval: usize,
    /// Processing elements (ForeGraph PEs; HitGraph/ThunderGP PEs ==
    /// memory channels).
    pub num_pes: usize,
    /// Memory channels the accelerator drives.
    pub channels: usize,
    /// Outstanding-request window per phase.
    pub window: usize,
    /// Open challenge (c) extension: allow the immediate-propagation
    /// systems (AccuGraph, ForeGraph) to drive multiple channels by
    /// striping their data structures line-interleaved across
    /// channels. Not part of the paper's reproduction (the originals
    /// are single-channel designs); see EXPERIMENTS.md §Extensions.
    pub experimental_multichannel: bool,
}

impl Default for AcceleratorConfig {
    fn default() -> Self {
        AcceleratorConfig {
            optimizations: Vec::new(),
            bram_values: SCALED_BRAM_VALUES,
            foregraph_interval: SCALED_FOREGRAPH_INTERVAL,
            num_pes: 4,
            channels: 1,
            window: 32,
            experimental_multichannel: false,
        }
    }
}

impl AcceleratorConfig {
    /// All optimizations on — the configuration of Tab. 4/6/7.
    pub fn all_optimizations() -> Self {
        AcceleratorConfig {
            optimizations: vec![
                Optimization::PrefetchSkipping,
                Optimization::PartitionSkipping,
                Optimization::EdgeShuffling,
                Optimization::ShardSkipping,
                Optimization::StrideMapping,
                Optimization::EdgeSorting,
                Optimization::UpdateCombining,
                Optimization::UpdateFiltering,
                Optimization::ChunkScheduling,
            ],
            ..Default::default()
        }
    }

    /// No optimizations — the Fig. 13 baseline.
    pub fn baseline() -> Self {
        Self::default()
    }

    pub fn with(mut self, opt: Optimization) -> Self {
        if !self.optimizations.contains(&opt) {
            self.optimizations.push(opt);
        }
        self
    }

    pub fn with_channels(mut self, channels: usize) -> Self {
        self.channels = channels;
        self
    }

    pub fn has(&self, opt: Optimization) -> bool {
        self.optimizations.contains(&opt)
    }

    /// Outstanding-request window override (sweep axis; the old string
    /// cache key famously ignored this field).
    pub fn with_window(mut self, window: usize) -> Self {
        self.window = window;
        self
    }

    /// Enable the open-challenge-(c) experimental multi-channel mode
    /// for the immediate-propagation systems.
    pub fn with_experimental_multichannel(mut self, on: bool) -> Self {
        self.experimental_multichannel = on;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_kinds() {
        assert_eq!(AcceleratorKind::parse("accugraph"), Some(AcceleratorKind::AccuGraph));
        assert_eq!(AcceleratorKind::parse("TGP"), Some(AcceleratorKind::ThunderGp));
        assert_eq!(AcceleratorKind::parse("x"), None);
    }

    #[test]
    fn from_str_display_round_trip() {
        for kind in AcceleratorKind::all() {
            assert_eq!(kind.to_string(), kind.name());
            assert_eq!(kind.name().parse::<AcceleratorKind>().unwrap(), kind);
        }
        let err = "x".parse::<AcceleratorKind>().unwrap_err();
        assert!(err.contains("unknown accelerator"), "{err}");
    }

    #[test]
    fn capability_matrix_matches_paper() {
        assert!(!AcceleratorKind::AccuGraph.multi_channel());
        assert!(!AcceleratorKind::ForeGraph.multi_channel());
        assert!(AcceleratorKind::HitGraph.multi_channel());
        assert!(AcceleratorKind::ThunderGp.multi_channel());
        assert!(AcceleratorKind::ReGraph.multi_channel());
        assert!(!AcceleratorKind::AccuGraph.supports_weighted());
        assert!(AcceleratorKind::HitGraph.supports_weighted());
        assert!(AcceleratorKind::ReGraph.supports_weighted());
        assert_eq!(AcceleratorKind::parse("rg"), Some(AcceleratorKind::ReGraph));
    }

    #[test]
    fn config_builders() {
        let c = AcceleratorConfig::baseline().with(Optimization::EdgeSorting);
        assert!(c.has(Optimization::EdgeSorting));
        assert!(!c.has(Optimization::UpdateCombining));
        let all = AcceleratorConfig::all_optimizations();
        assert!(all.has(Optimization::PartitionSkipping));
        assert!(all.has(Optimization::ChunkScheduling));
        let c2 = c.with(Optimization::EdgeSorting);
        assert_eq!(
            c2.optimizations
                .iter()
                .filter(|&&o| o == Optimization::EdgeSorting)
                .count(),
            1
        );
    }
}
