//! ForeGraph model (§3.2.2, Fig. 5): edge-centric over
//! **interval-shard** partitioning with **compressed 32-bit edges**
//! (two 16-bit interval-local ids), **immediate** update propagation.
//!
//! Per iteration, source intervals are prefetched one after another;
//! for each source interval its shards are processed by additionally
//! prefetching the destination interval, sequentially reading the
//! shard's edges (all random vertex accesses hit on-chip caches) and
//! sequentially writing the destination interval back. `p` PEs work
//! on their own sets of source intervals and share memory round-robin.
//!
//! Optimizations (§4.5): `Shuf.` zips the edge lists of `p` shards
//! into one padded stream (better PE utilization, more edges read),
//! `Skip.` skips shards with unchanged source intervals, `Map.`
//! renames vertices into constant-stride intervals to fight partition
//! skew.
//!
//! Split compile/execute (see [`crate::accel::program`]):
//! [`ForeGraphProgram`] owns the partitioning, the (optional) stride
//! permutation, the shard address layout, the per-interval prefetch
//! streams and write-back phases, and the small family of merge trees
//! the model ever uses — all iteration-invariant. Execution assembles
//! phases from those cached pieces; only the *composition* (which
//! shards are live, which intervals are skipped) is decided per
//! iteration.

use super::config::{AcceleratorConfig, Optimization};
use super::stream::{LineSource, LineStream, Merge, Phase, StreamClass};
use super::Accelerator;
use crate::algo::problem::GraphProblem;
use crate::dram::{MemKind, MemorySystem, CACHE_LINE};
use crate::graph::EdgeList;
use crate::onchip::OnChipBuffer;
use crate::partition::interval_shard::{stride_permutation, IntervalShardPartitioning};
use crate::sim::driver::{run_phase_onchip, PhaseScratch};
use crate::sim::metrics::{RunMetrics, SimReport};
use std::sync::Arc;

/// Compiled ForeGraph program (iteration-invariant artifacts).
pub struct ForeGraphProgram {
    part: IntervalShardPartitioning,
    /// Permutation applied to the graph (stride mapping), if any:
    /// `perm[original] = renamed`.
    perm: Option<Vec<u32>>,
    n: usize,
    m: usize,
    cfg: AcceleratorConfig,
    /// Base address of shard (i, j)'s edge array.
    shard_base: Vec<Vec<u64>>,
    /// Per-interval value prefetch stream (used both as the source
    /// prefetch of the PE group and as the destination prefetch of
    /// the shard phase — the construction is identical).
    pre_stream: Vec<LineStream>,
    /// Per-interval destination write-back phase.
    writeback: Vec<Phase>,
    /// `rr_merge[k-1]`: round-robin over `k` group prefetch streams.
    rr_merge: Vec<Arc<Merge>>,
    /// Shuffled-edge arbiter: Priority(dst prefetch, zipped stream).
    prio_single: Arc<Merge>,
    /// `prio_rr[c-1]`: Priority(dst prefetch, RR over `c` live shard
    /// streams at indices 1..=c).
    prio_rr: Vec<Arc<Merge>>,
}

impl ForeGraphProgram {
    pub fn compile(g: &EdgeList, cfg: &AcceleratorConfig) -> Self {
        let interval = cfg.foregraph_interval;
        let (graph, perm) = if cfg.has(Optimization::StrideMapping) {
            let q = (g.num_vertices + interval - 1) / interval.max(1);
            let perm = stride_permutation(g.num_vertices, q.max(1));
            (g.renamed(&perm), Some(perm))
        } else {
            (g.clone(), None)
        };
        let part = IntervalShardPartitioning::new(&graph, interval);
        let n = g.num_vertices;
        let q = part.num_intervals();
        let val_base = 0u64;
        let mut cursor = (n as u64 * 4 + CACHE_LINE - 1) / CACHE_LINE * CACHE_LINE;
        let mut shard_base = vec![vec![0u64; q]; q];
        for i in 0..q {
            for j in 0..q {
                shard_base[i][j] = cursor;
                let bytes = part.shards[i][j].len() as u64 * IntervalShardPartitioning::EDGE_BYTES;
                cursor += (bytes + CACHE_LINE - 1) / CACHE_LINE * CACHE_LINE;
            }
        }

        let window = cfg.window;
        let pes = cfg.num_pes.max(1);
        let mut pre_stream = Vec::with_capacity(q);
        let mut writeback = Vec::with_capacity(q);
        for i in 0..q {
            let iv = part.intervals[i];
            pre_stream.push(LineStream::independent(
                StreamClass::Prefetch,
                MemKind::Read,
                LineSource::seq(val_base + iv.start as u64 * 4, iv.len() as u64 * 4),
            ));
            writeback.push(Phase::single(
                StreamClass::Writes,
                MemKind::Write,
                LineSource::seq(val_base + iv.start as u64 * 4, iv.len() as u64 * 4),
                window,
            ));
        }
        let rr_merge = (1..=pes).map(|k| Arc::new(Merge::rr(0..k))).collect();
        let prio_single = Arc::new(Merge::Priority(vec![Merge::Leaf(0), Merge::Leaf(1)]));
        let prio_rr = (1..=pes)
            .map(|c| {
                Arc::new(Merge::Priority(vec![
                    Merge::Leaf(0),
                    Merge::RoundRobin((1..=c).map(Merge::Leaf).collect()),
                ]))
            })
            .collect();

        ForeGraphProgram {
            part,
            perm,
            n,
            m: g.num_edges(),
            cfg: cfg.clone(),
            shard_base,
            pre_stream,
            writeback,
            rr_merge,
            prio_single,
            prio_rr,
        }
    }

    pub fn num_intervals(&self) -> usize {
        self.part.num_intervals()
    }

    /// The checkable mirror of this program (see [`crate::verify`]):
    /// the phases an iteration assembles in the maximal case — every
    /// PE live, no shard skipped. Group prefetches, shard reads and
    /// write-backs are all compile-time streams; the one
    /// value-dependent stream is the shuffled zipped-edge read, whose
    /// stand-in covers the largest padded span a group can produce.
    pub(crate) fn facts(&self) -> crate::verify::ProgramFacts {
        use crate::dram::ChannelMode;
        use crate::verify::{PhaseFacts, ProgramFacts, StreamFacts};
        let q = self.part.num_intervals();
        let pes = self.cfg.num_pes.max(1);
        let shuf = self.cfg.has(Optimization::EdgeShuffling);
        let window = self.cfg.window;
        let mut phases = Vec::new();
        let mut round_start = 0usize;
        while round_start < q {
            let group: Vec<usize> = (round_start..(round_start + pes).min(q)).collect();
            round_start += pes;
            let k = group.len();
            phases.push(PhaseFacts {
                label: format!("group-prefetch[{}..{}]", group[0], group[k - 1]),
                streams: group
                    .iter()
                    .map(|&i| StreamFacts::of(&self.pre_stream[i], None))
                    .collect(),
                merge: Arc::clone(&self.rr_merge[k - 1]),
                window,
            });
            for j in 0..q {
                let live: Vec<usize> = group
                    .iter()
                    .copied()
                    .filter(|&i| !self.part.shards[i][j].is_empty())
                    .collect();
                if live.is_empty() {
                    continue;
                }
                let mut streams = vec![StreamFacts::of(&self.pre_stream[j], None)];
                let merge;
                if shuf {
                    let max_len = live
                        .iter()
                        .map(|&i| self.part.shards[i][j].len())
                        .max()
                        .unwrap_or(0);
                    let bytes =
                        (max_len * live.len()) as u64 * IntervalShardPartitioning::EDGE_BYTES;
                    // Anchor at the group's largest shard base: at
                    // execute time the zip starts at `live[0]`'s base,
                    // so this stand-in reaches the farthest address any
                    // live set can touch.
                    streams.push(StreamFacts {
                        class: StreamClass::Edges,
                        source: LineSource::seq(self.shard_base[live[live.len() - 1]][j], bytes),
                        chained_to: None,
                        fanout: super::stream::Fanout::Uniform(0),
                        owner: None,
                        gather_domain: None,
                        dynamic: true,
                    });
                    merge = Arc::clone(&self.prio_single);
                } else {
                    for &i in &live {
                        let len = self.part.shards[i][j].len() as u64;
                        streams.push(StreamFacts {
                            class: StreamClass::Edges,
                            source: LineSource::seq(
                                self.shard_base[i][j],
                                len * IntervalShardPartitioning::EDGE_BYTES,
                            ),
                            chained_to: None,
                            fanout: super::stream::Fanout::Uniform(0),
                            owner: None,
                            gather_domain: None,
                            dynamic: false,
                        });
                    }
                    merge = Arc::clone(&self.prio_rr[live.len() - 1]);
                }
                phases.push(PhaseFacts {
                    label: format!("shards[{}..{}][{j}]", group[0], group[k - 1]),
                    streams,
                    merge,
                    window,
                });
                phases.push(PhaseFacts::of(format!("writeback[{j}]"), &self.writeback[j], None));
            }
        }
        ProgramFacts::assemble(
            super::AcceleratorKind::ForeGraph,
            self.n,
            self.m,
            self.cfg.channels,
            ChannelMode::InterleaveLine,
            phases,
        )
    }

    /// Undo the stride permutation on a value vector (for result
    /// verification).
    pub fn unpermute(&self, values: &[f32]) -> Vec<f32> {
        match &self.perm {
            None => values.to_vec(),
            Some(perm) => {
                let mut out = vec![0f32; values.len()];
                for (orig, &renamed) in perm.iter().enumerate() {
                    out[orig] = values[renamed as usize];
                }
                out
            }
        }
    }

    pub fn execute(&self, p0: &GraphProblem, mem: &mut MemorySystem) -> SimReport {
        self.execute_onchip(p0, mem, None)
    }

    /// [`ForeGraphProgram::execute`] with an optional on-chip buffer
    /// (see [`crate::onchip`]) — models the BRAM interval cache:
    /// interval-value hits (source/destination prefetches, write-backs
    /// of recently prefetched intervals) retire on chip.
    pub fn execute_onchip(
        &self,
        p0: &GraphProblem,
        mem: &mut MemorySystem,
        mut onchip: Option<&mut OnChipBuffer>,
    ) -> SimReport {
        assert!(
            !p0.kind.weighted(),
            "ForeGraph does not support weighted problems (Tab. 1)"
        );
        // Rebind the problem onto the renamed vertex space.
        let root = match &self.perm {
            Some(perm) => perm[p0.root as usize],
            None => p0.root,
        };
        let p = GraphProblem {
            kind: p0.kind,
            root,
            inv_out_deg: match &self.perm {
                Some(perm) => {
                    let mut v = vec![0f32; p0.inv_out_deg.len()];
                    for (orig, &ren) in perm.iter().enumerate() {
                        if !p0.inv_out_deg.is_empty() {
                            v[ren as usize] = p0.inv_out_deg[orig];
                        }
                    }
                    v
                }
                None => p0.inv_out_deg.clone(),
            },
            num_vertices: p0.num_vertices,
        };

        let n = self.n;
        let q = self.part.num_intervals();
        let pes = self.cfg.num_pes.max(1);
        let window = self.cfg.window;
        let shuf = self.cfg.has(Optimization::EdgeShuffling);
        let skip = self.cfg.has(Optimization::ShardSkipping);
        let immediate = p.kind.reduces_with_min();

        let mut values = p.init_values();
        let mut prev_changed_interval = vec![true; q];
        let mut metrics = RunMetrics::default();
        let mut cursor = 0u64;
        let max_iters = p.kind.fixed_iterations().unwrap_or(u32::MAX);
        let mut scratch = PhaseScratch::new();

        loop {
            metrics.iterations += 1;
            let mut changed_now = vec![false; q];
            let mut any = false;
            let snapshot = if immediate { None } else { Some(values.clone()) };
            let mut acc = if immediate {
                Vec::new()
            } else {
                vec![p.reduce_identity(); n]
            };

            // PEs process source intervals in rounds of `pes`.
            let mut round_start = 0usize;
            while round_start < q {
                let group: Vec<usize> = (round_start..(round_start + pes).min(q))
                    .filter(|&i| {
                        if skip && !prev_changed_interval[i] {
                            metrics.skipped += q as u64; // skips all of i's shards
                            false
                        } else {
                            true
                        }
                    })
                    .collect();
                round_start += pes;
                if group.is_empty() {
                    continue;
                }

                // --- Source interval prefetches (one per active PE) ---
                let mut pre_streams = Vec::new();
                for &i in &group {
                    pre_streams.push(self.pre_stream[i].clone());
                    metrics.values_read += self.part.intervals[i].len() as u64;
                }
                let k = pre_streams.len();
                let pre_phase = Phase {
                    streams: pre_streams,
                    merge: Arc::clone(&self.rr_merge[k - 1]),
                    window,
                };
                cursor =
                    run_phase_onchip(mem, &pre_phase, cursor, &mut scratch, onchip.as_deref_mut())
                        .end_cycle;

                // --- Per destination interval: prefetch, edges, write ---
                for j in 0..q {
                    let jv = self.part.intervals[j];
                    // Which of the group's shards into j are non-empty?
                    let live: Vec<usize> = group
                        .iter()
                        .copied()
                        .filter(|&i| !self.part.shards[i][j].is_empty())
                        .collect();
                    if live.is_empty() {
                        continue;
                    }
                    metrics.processed += live.len() as u64;

                    // Algorithm semantics: process shards' edges.
                    for &i in &live {
                        for &ce in &self.part.shards[i][j] {
                            let (src, dst) = self.part.globalize(i, j, ce);
                            let sval = match &snapshot {
                                Some(s) => s[src as usize],
                                None => values[src as usize],
                            };
                            let u = p.combine(src, sval, 1.0);
                            if immediate {
                                let old = values[dst as usize];
                                let new = p.apply(old, u);
                                if p.changed(old, new) {
                                    values[dst as usize] = new;
                                    changed_now[j] = true;
                                    any = true;
                                }
                            } else {
                                let a = &mut acc[dst as usize];
                                *a = p.reduce(*a, u);
                            }
                        }
                    }

                    // Edge volume: shuffled -> p * max (null-edge padding);
                    // unshuffled -> plain sum, streams merged round-robin.
                    let mut streams = Vec::new();
                    // dst interval prefetch first
                    streams.push(self.pre_stream[j].clone());
                    metrics.values_read += jv.len() as u64;
                    let merge;
                    if shuf {
                        let max_len = live
                            .iter()
                            .map(|&i| self.part.shards[i][j].len())
                            .max()
                            .unwrap_or(0);
                        let padded = (max_len * live.len()) as u64;
                        metrics.edges_read += padded;
                        let bytes = padded * IntervalShardPartitioning::EDGE_BYTES;
                        streams.push(LineStream::independent(
                            StreamClass::Edges,
                            MemKind::Read,
                            LineSource::seq(self.shard_base[live[0]][j], bytes),
                        ));
                        merge = Arc::clone(&self.prio_single);
                    } else {
                        for &i in &live {
                            let len = self.part.shards[i][j].len() as u64;
                            metrics.edges_read += len;
                            streams.push(LineStream::independent(
                                StreamClass::Edges,
                                MemKind::Read,
                                LineSource::seq(
                                    self.shard_base[i][j],
                                    len * IntervalShardPartitioning::EDGE_BYTES,
                                ),
                            ));
                        }
                        merge = Arc::clone(&self.prio_rr[live.len() - 1]);
                    }
                    // Edge streams wait on the dst prefetch? Fig. 5 reads
                    // edges after the interval prefetch; model via
                    // priority: prefetch first, then edges.
                    let phase = Phase {
                        streams,
                        merge,
                        window,
                    };
                    cursor =
                        run_phase_onchip(mem, &phase, cursor, &mut scratch, onchip.as_deref_mut())
                            .end_cycle;

                    // Destination interval written back sequentially.
                    metrics.values_written += jv.len() as u64;
                    cursor = run_phase_onchip(
                        mem,
                        &self.writeback[j],
                        cursor,
                        &mut scratch,
                        onchip.as_deref_mut(),
                    )
                    .end_cycle;
                }
            }

            if !immediate {
                for v in 0..n {
                    let new = p.apply(values[v], acc[v]);
                    if p.changed(values[v], new) {
                        let j = (v / self.part.intervals[0].len().max(1)).min(q - 1);
                        changed_now[j] = true;
                        any = true;
                    }
                    values[v] = new;
                }
            }

            prev_changed_interval = changed_now;
            if metrics.iterations >= max_iters {
                break;
            }
            if !any {
                break;
            }
        }

        let dram = mem.stats();
        SimReport {
            accelerator: "ForeGraph",
            problem: p.kind.name(),
            graph_edges: self.m as u64,
            cycles: cursor,
            seconds: cursor as f64 * mem.spec().seconds_per_cycle(),
            bytes_total: dram.requests() * CACHE_LINE,
            bus_utilization: mem.utilization(),
            channels: mem.num_channels(),
            metrics,
            dram,
            // Filled in by SimSpec::run when pattern analysis /
            // on-chip buffering is configured.
            patterns: None,
            onchip: None,
            // Stamped only by the advisor reporting paths.
            advisor: None,
        }
    }
}

/// ForeGraph simulator instance: a handle on a compiled
/// [`ForeGraphProgram`]. (Cross-thread program sharing happens one
/// level up, via `Arc<PhaseProgram>`.)
pub struct ForeGraph {
    program: ForeGraphProgram,
}

impl ForeGraph {
    pub fn new(g: &EdgeList, cfg: &AcceleratorConfig) -> Self {
        ForeGraph {
            program: ForeGraphProgram::compile(g, cfg),
        }
    }

    pub fn num_intervals(&self) -> usize {
        self.program.num_intervals()
    }

    /// Undo the stride permutation on a value vector (for result
    /// verification).
    pub fn unpermute(&self, values: &[f32]) -> Vec<f32> {
        self.program.unpermute(values)
    }
}

impl Accelerator for ForeGraph {
    fn name(&self) -> &'static str {
        "ForeGraph"
    }

    fn run(&mut self, p0: &GraphProblem, mem: &mut MemorySystem) -> SimReport {
        self.program.execute(p0, mem)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::problem::ProblemKind;
    use crate::dram::DramSpec;
    use crate::graph::synthetic::{erdos_renyi, preferential_attachment};

    fn run(g: &EdgeList, kind: ProblemKind, cfg: &AcceleratorConfig) -> SimReport {
        let p = GraphProblem::new(kind, g);
        let mut acc = ForeGraph::new(g, cfg);
        let mut mem = MemorySystem::new(DramSpec::ddr4_2400(1));
        acc.run(&p, &mut mem)
    }

    #[test]
    fn bfs_completes_and_reads_compressed_edges() {
        let g = erdos_renyi(4000, 24000, 1);
        let r = run(&g, ProblemKind::Bfs, &AcceleratorConfig::default());
        assert!(r.metrics.iterations >= 2);
        assert!(r.metrics.edges_read >= 24000);
        assert!(r.seconds > 0.0);
    }

    #[test]
    fn pr_single_iteration_4_bytes_per_edge_plus_intervals() {
        let g = erdos_renyi(2000, 40000, 2); // dense: interval overhead amortizes
        let r = run(&g, ProblemKind::PageRank, &AcceleratorConfig::default());
        assert_eq!(r.metrics.iterations, 1);
        assert_eq!(r.metrics.edges_read, 40000);
        // compressed edges: edge bytes alone are 4/edge; with interval
        // prefetch/writeback the total stays well under the 8 B/edge of
        // an uncompressed edge list on dense graphs... but above 4.
        assert!(r.bytes_per_edge() > 4.0);
    }

    #[test]
    fn shard_skipping_reduces_work() {
        let g = crate::graph::synthetic::grid_2d(70, 70); // n=4900, several intervals
        let cfg = AcceleratorConfig::default();
        let base = run(&g, ProblemKind::Bfs, &cfg);
        let skip = run(
            &g,
            ProblemKind::Bfs,
            &cfg.clone().with(Optimization::ShardSkipping),
        );
        assert!(
            skip.metrics.edges_read < base.metrics.edges_read,
            "{} !< {}",
            skip.metrics.edges_read,
            base.metrics.edges_read
        );
        assert!(skip.seconds < base.seconds);
    }

    #[test]
    fn edge_shuffling_alone_increases_edges_read() {
        let g = preferential_attachment(4000, 6, 3); // skewed shards
        let base = run(&g, ProblemKind::PageRank, &AcceleratorConfig::default());
        let shuf = run(
            &g,
            ProblemKind::PageRank,
            &AcceleratorConfig::default().with(Optimization::EdgeShuffling),
        );
        // Paper: shuffling alone aggravates load imbalance via padding.
        assert!(
            shuf.metrics.edges_read > base.metrics.edges_read,
            "{} !> {}",
            shuf.metrics.edges_read,
            base.metrics.edges_read
        );
    }

    #[test]
    fn stride_mapping_tames_shuffling_padding() {
        let g = preferential_attachment(4000, 6, 4);
        let shuf = run(
            &g,
            ProblemKind::PageRank,
            &AcceleratorConfig::default().with(Optimization::EdgeShuffling),
        );
        let both = run(
            &g,
            ProblemKind::PageRank,
            &AcceleratorConfig::default()
                .with(Optimization::EdgeShuffling)
                .with(Optimization::StrideMapping),
        );
        assert!(
            both.metrics.edges_read < shuf.metrics.edges_read,
            "{} !< {}",
            both.metrics.edges_read,
            shuf.metrics.edges_read
        );
    }

    #[test]
    fn unpermute_restores_original_order() {
        let g = preferential_attachment(1000, 4, 5);
        let cfg = AcceleratorConfig::default().with(Optimization::StrideMapping);
        let fg = ForeGraph::new(&g, &cfg);
        let perm = fg.program.perm.clone().unwrap();
        let renamed_vals: Vec<f32> = {
            // value[renamed] = original index as f32
            let mut v = vec![0f32; 1000];
            for (orig, &ren) in perm.iter().enumerate() {
                v[ren as usize] = orig as f32;
            }
            v
        };
        let restored = fg.unpermute(&renamed_vals);
        for (i, &x) in restored.iter().enumerate() {
            assert_eq!(x, i as f32);
        }
    }
}
