//! Memory-access-pattern models of the four graph processing
//! accelerators the paper studies (§3.2), plus the post-paper
//! ReGraph-style heterogeneous HBM2 design:
//!
//! | Model | Iteration | Partitioning | Binary rep. | Update prop. |
//! |-------|-----------|--------------|-------------|--------------|
//! | [`accugraph`] | vertex-centric pull | horizontal | in-CSR | immediate |
//! | [`foregraph`] | edge-centric | interval-shard | compressed edge list | immediate |
//! | [`hitgraph`]  | edge-centric | horizontal | sorted edge list | 2-phase |
//! | [`thundergp`] | edge-centric | vertical | sorted edge list | 2-phase |
//! | [`regraph`] | edge-centric | horizontal, dense/sparse split | sorted edge list | 2-phase, little/big pipelines |
//!
//! Each model executes the real algorithm semantics (so iteration
//! counts, convergence, and the skip/filter optimizations are
//! data-faithful) while emitting the off-chip request streams of
//! Figs. 4–7 through the [`stream`] vocabulary, co-simulated against
//! the DRAM model by [`crate::sim::driver`].
//!
//! Every model is split compile/execute: the [`program`] layer holds
//! the memory-independent, iteration-invariant artifacts
//! ([`PhaseProgram`]), built once per (accelerator, workload,
//! weightedness, config) and replayed by `Arc` reference — see
//! [`crate::sim::Session`]'s program cache.

pub mod accugraph;
pub mod config;
pub mod foregraph;
pub mod hitgraph;
pub mod program;
pub mod regraph;
pub mod stream;
pub mod thundergp;

pub use accugraph::AccuGraph;
pub use config::{AcceleratorConfig, AcceleratorKind, Optimization};
pub use foregraph::ForeGraph;
pub use hitgraph::HitGraph;
pub use program::PhaseProgram;
pub use regraph::ReGraph;
pub use thundergp::ThunderGp;

use crate::algo::problem::GraphProblem;
use crate::dram::MemorySystem;
use crate::sim::metrics::SimReport;

/// Common interface: run a bound problem against a memory system,
/// producing the paper's metric set.
pub trait Accelerator {
    fn name(&self) -> &'static str;
    /// Run to convergence (or the problem's fixed iteration count).
    fn run(&mut self, problem: &GraphProblem, mem: &mut MemorySystem) -> SimReport;
}

/// Construct any accelerator by kind.
pub fn build(
    kind: AcceleratorKind,
    g: &crate::graph::EdgeList,
    cfg: &AcceleratorConfig,
) -> Box<dyn Accelerator> {
    match kind {
        AcceleratorKind::AccuGraph => Box::new(AccuGraph::new(g, cfg)),
        AcceleratorKind::ForeGraph => Box::new(ForeGraph::new(g, cfg)),
        AcceleratorKind::HitGraph => Box::new(HitGraph::new(g, cfg)),
        AcceleratorKind::ThunderGp => Box::new(ThunderGp::new(g, cfg)),
        AcceleratorKind::ReGraph => Box::new(ReGraph::new(g, cfg)),
    }
}
