//! Compile-once phase programs: the compile/execute split of the
//! accelerator models.
//!
//! Simulating one spec breaks into two very different kinds of work:
//!
//! * **Compile** — everything that depends only on (accelerator,
//!   workload, configuration) plus the problem's *weightedness* (the
//!   12 B vs 8 B edge layout): partitioning the graph (incl.
//!   `Sort`/`Map.` passes), laying out the data structures, building
//!   the [`LineSource`] descriptors, the compressed [`Fanout`] release
//!   schedules and the merge trees. This is memory-independent and
//!   iteration-invariant.
//! * **Execute** — everything value- or memory-dependent: running the
//!   algorithm semantics, building the dynamic streams (BFS frontier
//!   write-backs, AccuGraph skip decisions, HitGraph update queues)
//!   against the cached skeleton, and driving the phases through a
//!   concrete [`MemorySystem`].
//!
//! A [`PhaseProgram`] is the compile half, frozen. It is immutable and
//! `Send + Sync`, so a sweep shares one compiled program across worker
//! threads by `Arc` — [`crate::sim::Session`] keys its program cache
//! on the memory-independent sub-key of a spec
//! ([`crate::sim::SimSpec::program_key`]), which is how a
//! `mem_techs × channels × problems` sweep compiles each workload
//! once per channel count and reuses it across every memory
//! technology and problem kind.
//! Multi-channel programs store *channel-relative* addresses and are
//! relocated onto the concrete system's region bases at execute time
//! ([`LineSource::rebase`]), which is what makes one program valid
//! for both DDR4 and HBM region layouts.
//!
//! What is deliberately **not** cached: anything derived from problem
//! values. Frontier-dependent gathers, update-queue contents and skip
//! decisions are rebuilt every iteration — caching them would bake
//! one execution's data into another's. Execution is bit-identical to
//! a fresh compile (`tests/program_cache.rs` and the
//! `stream_equivalence` suite assert reports, traces and pattern
//! summaries are equal).
//!
//! ```
//! use graphmem::accel::AcceleratorKind;
//! use graphmem::algo::problem::ProblemKind;
//! use graphmem::graph::DatasetId;
//! use graphmem::sim::SimSpec;
//!
//! let spec = SimSpec::builder()
//!     .accelerator(AcceleratorKind::AccuGraph)
//!     .graph(DatasetId::Sd)
//!     .problem(ProblemKind::Bfs)
//!     .build()
//!     .unwrap();
//! // Compile once, execute twice: bit-identical to fresh compiles.
//! let program = spec.compile_program();
//! let a = spec.run_with_program(&program);
//! let b = spec.run_with_program(&program);
//! assert_eq!(a, b);
//! assert_eq!(a, spec.run()); // fresh compile agrees too
//! ```
//!
//! [`LineSource`]: crate::accel::stream::LineSource
//! [`LineSource::rebase`]: crate::accel::stream::LineSource::rebase
//! [`Fanout`]: crate::accel::stream::Fanout

use super::accugraph::AccuGraphProgram;
use super::config::{AcceleratorConfig, AcceleratorKind};
use super::foregraph::ForeGraphProgram;
use super::hitgraph::HitGraphProgram;
use super::regraph::ReGraphProgram;
use super::thundergp::ThunderGpProgram;
use crate::algo::problem::GraphProblem;
use crate::dram::MemorySystem;
use crate::graph::EdgeList;
use crate::onchip::OnChipBuffer;
use crate::sim::metrics::SimReport;
use crate::sim::spec::ProgramKey;

/// A compiled, reusable phase program for one accelerator model (see
/// the [module docs](self)). Build with [`PhaseProgram::compile`],
/// replay with [`PhaseProgram::execute`] as many times as needed —
/// executions are independent and bit-identical.
pub struct PhaseProgram {
    kind: AcceleratorKind,
    model: Model,
    /// The spec sub-key this program was compiled for — stamped by
    /// [`crate::sim::SimSpec::compile_program`] so
    /// `run_with_program` can reject a program/spec mismatch (a
    /// program compiled for a different workload or config would
    /// otherwise silently simulate the wrong graph under this spec's
    /// label). `None` for hand-compiled programs, which still carry
    /// the O(1) structural stamp below.
    key: Option<ProgramKey>,
    /// Structural stamp of the compile inputs, recorded for *every*
    /// program (incl. hand-compiled ones): checked by
    /// `run_with_program` so a program for a different-shaped graph
    /// or configuration cannot silently execute under the wrong spec.
    graph_vertices: usize,
    graph_edges: usize,
    graph_weighted: bool,
    config: AcceleratorConfig,
}

enum Model {
    AccuGraph(AccuGraphProgram),
    ForeGraph(ForeGraphProgram),
    HitGraph(HitGraphProgram),
    ThunderGp(ThunderGpProgram),
    ReGraph(ReGraphProgram),
}

impl PhaseProgram {
    /// Compile the iteration-invariant, memory-independent artifacts
    /// for `kind` on this workload + configuration. This is the
    /// expensive half of a simulation (partitioning, sorting,
    /// renaming, descriptor construction).
    pub fn compile(kind: AcceleratorKind, g: &EdgeList, cfg: &AcceleratorConfig) -> PhaseProgram {
        let model = match kind {
            AcceleratorKind::AccuGraph => Model::AccuGraph(AccuGraphProgram::compile(g, cfg)),
            AcceleratorKind::ForeGraph => Model::ForeGraph(ForeGraphProgram::compile(g, cfg)),
            AcceleratorKind::HitGraph => Model::HitGraph(HitGraphProgram::compile(g, cfg)),
            AcceleratorKind::ThunderGp => Model::ThunderGp(ThunderGpProgram::compile(g, cfg)),
            AcceleratorKind::ReGraph => Model::ReGraph(ReGraphProgram::compile(g, cfg)),
        };
        PhaseProgram {
            kind,
            model,
            key: None,
            graph_vertices: g.num_vertices,
            graph_edges: g.num_edges(),
            graph_weighted: g.weighted,
            config: cfg.clone(),
        }
    }

    /// O(1) structural guard: does this program's compile input match
    /// the given graph + configuration? (Counts, weightedness and the
    /// full config — not a content digest; the
    /// [`crate::sim::SimSpec::compile_program`] path additionally
    /// carries the exact [`ProgramKey`], incl. workload identity.)
    pub fn compiled_for(&self, g: &EdgeList, cfg: &AcceleratorConfig) -> bool {
        self.graph_vertices == g.num_vertices
            && self.graph_edges == g.num_edges()
            && self.graph_weighted == g.weighted
            && self.config == *cfg
    }

    /// Stamp the spec sub-key this program was compiled from (see
    /// [`crate::sim::SimSpec::compile_program`]).
    pub(crate) fn with_key(mut self, key: ProgramKey) -> PhaseProgram {
        self.key = Some(key);
        self
    }

    /// The spec sub-key this program was compiled for, when known.
    pub fn key(&self) -> Option<&ProgramKey> {
        self.key.as_ref()
    }

    pub fn kind(&self) -> AcceleratorKind {
        self.kind
    }

    /// The checkable mirror of this program's structure — streams,
    /// merge trees, release schedules, channel ownership and
    /// per-channel layout footprints — for the static verifier (see
    /// [`crate::verify`]). Value-dependent execute-time streams
    /// appear as static maximal-bounds stand-ins flagged
    /// [`crate::verify::StreamFacts::dynamic`]: their descriptors
    /// cover the largest span execution can produce, so bounds proven
    /// here hold for every iteration.
    pub fn facts(&self) -> crate::verify::ProgramFacts {
        match &self.model {
            Model::AccuGraph(m) => m.facts(),
            Model::ForeGraph(m) => m.facts(),
            Model::HitGraph(m) => m.facts(),
            Model::ThunderGp(m) => m.facts(),
            Model::ReGraph(m) => m.facts(),
        }
    }

    /// Execute the program against a problem instance and a memory
    /// system. Value-dependent streams are built per call; the
    /// compiled skeleton is only read, so `&self` — any number of
    /// executions (incl. concurrent ones on separate memory systems)
    /// share one program.
    pub fn execute(&self, p: &GraphProblem, mem: &mut MemorySystem) -> SimReport {
        self.execute_onchip(p, mem, None)
    }

    /// [`PhaseProgram::execute`] with an optional on-chip buffer (see
    /// [`crate::onchip`]): the phase driver consults it before every
    /// request, so hits retire in BRAM and never reach `mem`. The
    /// buffer is per-execution mutable state — the compiled program
    /// itself stays immutable and shareable, which is why the buffer
    /// is a parameter here rather than part of the program.
    pub fn execute_onchip(
        &self,
        p: &GraphProblem,
        mem: &mut MemorySystem,
        onchip: Option<&mut OnChipBuffer>,
    ) -> SimReport {
        match &self.model {
            Model::AccuGraph(m) => m.execute_onchip(p, mem, onchip),
            Model::ForeGraph(m) => m.execute_onchip(p, mem, onchip),
            Model::HitGraph(m) => m.execute_onchip(p, mem, onchip),
            Model::ThunderGp(m) => m.execute_onchip(p, mem, onchip),
            Model::ReGraph(m) => m.execute_onchip(p, mem, onchip),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::problem::ProblemKind;
    use crate::dram::{ChannelMode, DramSpec};
    use crate::graph::synthetic::erdos_renyi;

    #[test]
    fn compile_dispatches_every_kind() {
        let g = erdos_renyi(400, 2400, 0xC0);
        for kind in AcceleratorKind::all() {
            let cfg = AcceleratorConfig::default();
            let program = PhaseProgram::compile(kind, &g, &cfg);
            assert_eq!(program.kind(), kind);
            let p = GraphProblem::new(ProblemKind::Bfs, &g);
            let mode = if kind.multi_channel() {
                ChannelMode::Region
            } else {
                ChannelMode::InterleaveLine
            };
            let mut mem = MemorySystem::with_mode(DramSpec::ddr4_2400(1), mode);
            let r = program.execute(&p, &mut mem);
            assert!(r.cycles > 0);
            assert_eq!(r.accelerator, kind.name());
        }
    }
}
