//! AccuGraph model (§3.2.1, Fig. 4): vertex-centric **pull** over a
//! horizontally partitioned **in-CSR**, **immediate** update
//! propagation via the parallel accumulator.
//!
//! Per iteration, per partition `q` (sources restricted to interval
//! `q`):
//! 1. prefetch the partition's `n/k` source values (skippable via
//!    `Pref.` when the on-chip partition is unchanged),
//! 2. read destination values and the partition's `n + 1` CSR
//!    pointers sequentially, merged **round-robin** ("a value is only
//!    useful with the associated pointers"),
//! 3. read neighbors sequentially; the accumulator produces updates;
//!    changed destination values are written back (the *filter*
//!    abstraction drops unchanged ones),
//! 4. all streams merged by **priority**: writes > neighbors >
//!    values/pointers.
//!
//! `Skip.` (partition skipping) drops partitions none of whose source
//! values changed in the previous iteration.
//!
//! The model is split compile/execute (see [`crate::accel::program`]):
//! [`AccuGraphProgram`] holds everything iteration-invariant — the
//! partitioning, the address layout, the per-partition prefetch
//! phases and the three invariant Phase-B streams plus their shared
//! merge tree — while [`AccuGraphProgram::execute`] builds only the
//! value-dependent write stream per partition per iteration.

use super::config::{AcceleratorConfig, Optimization};
use super::stream::{LineSource, LineStream, Merge, Phase, StreamClass};
use super::Accelerator;
use crate::algo::problem::GraphProblem;
use crate::dram::{MemKind, MemorySystem, CACHE_LINE};
use crate::graph::EdgeList;
use crate::onchip::OnChipBuffer;
use crate::partition::horizontal::HorizontalInCsr;
use crate::sim::driver::{run_phase_onchip, PhaseScratch};
use crate::sim::metrics::{RunMetrics, SimReport};
use std::sync::Arc;

/// Compiled AccuGraph program: the memory-independent,
/// iteration-invariant artifacts, built once per (workload, config)
/// and replayed by every execution.
pub struct AccuGraphProgram {
    part: HorizontalInCsr,
    n: usize,
    m: usize,
    cfg: AcceleratorConfig,
    /// Base byte address of the vertex value array (plain adjacent
    /// arrays, §2.2); the write-back gather targets it.
    val_base: u64,
    /// Per-partition Phase A: the source-value prefetch, complete and
    /// replayed by reference.
    prefetch: Vec<Phase>,
    /// Per-partition invariant Phase-B streams: destination values,
    /// CSR pointers, neighbors (stream indices 0, 1, 2).
    body: Vec<[LineStream; 3]>,
    /// Cache-line count of each partition's neighbor stream (the
    /// write fan-out's domain).
    nbr_lines: Vec<usize>,
    /// Shared Phase-B arbiter: writes > neighbors > RR(values,
    /// pointers) — identical for every partition.
    merge: Arc<Merge>,
}

impl AccuGraphProgram {
    /// Compile the iteration-invariant phase skeletons. This is the
    /// expensive part of instantiating the model (partitioning the
    /// graph into in-CSR partitions); nothing here depends on the
    /// memory technology or on problem values.
    pub fn compile(g: &EdgeList, cfg: &AcceleratorConfig) -> Self {
        let part = HorizontalInCsr::new(g, cfg.bram_values);
        let n = g.num_vertices;
        let val_base = 0u64;
        let mut cursor = (n as u64 * 4 + CACHE_LINE - 1) / CACHE_LINE * CACHE_LINE;
        let mut ptr_base = Vec::with_capacity(part.num_partitions());
        for _ in 0..part.num_partitions() {
            ptr_base.push(cursor);
            cursor += ((n as u64 + 1) * 4 + CACHE_LINE - 1) / CACHE_LINE * CACHE_LINE;
        }
        let mut nbr_base = Vec::with_capacity(part.num_partitions());
        for q in 0..part.num_partitions() {
            nbr_base.push(cursor);
            cursor +=
                (part.neighbors[q].len() as u64 * 4 + CACHE_LINE - 1) / CACHE_LINE * CACHE_LINE;
        }

        let window = cfg.window;
        let mut prefetch = Vec::with_capacity(part.num_partitions());
        let mut body = Vec::with_capacity(part.num_partitions());
        let mut nbr_lines = Vec::with_capacity(part.num_partitions());
        for q in 0..part.num_partitions() {
            let interval = part.intervals[q];
            prefetch.push(Phase::single(
                StreamClass::Prefetch,
                MemKind::Read,
                LineSource::seq(
                    val_base + interval.start as u64 * 4,
                    interval.len() as u64 * 4,
                ),
                window,
            ));
            let m_q = part.neighbors[q].len();
            let s_vals = LineStream::independent(
                StreamClass::Values,
                MemKind::Read,
                LineSource::seq(val_base, n as u64 * 4),
            );
            let s_ptrs = LineStream::independent(
                StreamClass::Pointers,
                MemKind::Read,
                LineSource::seq(ptr_base[q], (n as u64 + 1) * 4),
            );
            let nbr_src = LineSource::seq(nbr_base[q], m_q as u64 * 4);
            nbr_lines.push(nbr_src.len());
            let s_nbrs = LineStream::independent(StreamClass::Edges, MemKind::Read, nbr_src);
            body.push([s_vals, s_ptrs, s_nbrs]);
        }
        // Priority: writes > neighbors > RR(values, pointers)
        let merge = Arc::new(Merge::Priority(vec![
            Merge::Leaf(3),
            Merge::Leaf(2),
            Merge::RoundRobin(vec![Merge::Leaf(0), Merge::Leaf(1)]),
        ]));

        AccuGraphProgram {
            part,
            n,
            m: g.num_edges(),
            cfg: cfg.clone(),
            val_base,
            prefetch,
            body,
            nbr_lines,
            merge,
        }
    }

    pub fn num_partitions(&self) -> usize {
        self.part.num_partitions()
    }

    /// The checkable mirror of this program for the static verifier
    /// (see [`crate::verify`]): the per-partition prefetch phases and
    /// Phase-B skeletons verbatim, with the value-dependent
    /// write-back stream as its maximal stand-in — a write-back
    /// gather targets vertex values, so it can never span more than
    /// the value region, chained to the neighbor stream exactly as at
    /// execute time.
    pub(crate) fn facts(&self) -> crate::verify::ProgramFacts {
        use crate::dram::ChannelMode;
        use crate::verify::{PhaseFacts, ProgramFacts, StreamFacts};
        let mut phases = Vec::with_capacity(self.prefetch.len() + self.body.len());
        for (q, ph) in self.prefetch.iter().enumerate() {
            phases.push(PhaseFacts::of(format!("prefetch[{q}]"), ph, None));
        }
        for (q, body) in self.body.iter().enumerate() {
            let mut streams: Vec<StreamFacts> =
                body.iter().map(|s| StreamFacts::of(s, None)).collect();
            let stub = if self.nbr_lines[q] == 0 {
                LineSource::seq(self.val_base, 0)
            } else {
                LineSource::seq(self.val_base, self.n as u64 * 4)
            };
            let released = stub.len() as u32;
            streams.push(StreamFacts {
                class: StreamClass::Writes,
                source: stub,
                chained_to: Some(2), // the neighbor stream
                fanout: super::stream::Fanout::AfterLast(released),
                owner: None,
                gather_domain: None,
                dynamic: true,
            });
            phases.push(PhaseFacts {
                label: format!("body[{q}]"),
                streams,
                merge: Arc::clone(&self.merge),
                window: self.cfg.window,
            });
        }
        ProgramFacts::assemble(
            super::AcceleratorKind::AccuGraph,
            self.n,
            self.m,
            self.cfg.channels,
            ChannelMode::InterleaveLine,
            phases,
        )
    }

    /// Execute the compiled program against a problem and a memory
    /// system. Value-dependent state (frontiers, accumulators, the
    /// write-back streams) is built here, against the cached skeleton.
    pub fn execute(&self, p: &GraphProblem, mem: &mut MemorySystem) -> SimReport {
        self.execute_onchip(p, mem, None)
    }

    /// [`AccuGraphProgram::execute`] with an optional on-chip buffer
    /// consulted on every request (see [`crate::onchip`]) — this is
    /// where the model's on-chip vertex array stops being a fiction:
    /// vertex-value hits retire in BRAM instead of going to DRAM.
    pub fn execute_onchip(
        &self,
        p: &GraphProblem,
        mem: &mut MemorySystem,
        mut onchip: Option<&mut OnChipBuffer>,
    ) -> SimReport {
        assert!(
            !p.kind.weighted(),
            "AccuGraph does not support weighted problems (Tab. 1)"
        );
        let n = self.n;
        let k = self.part.num_partitions();
        let skip = self.cfg.has(Optimization::PartitionSkipping);
        let pref_skip = self.cfg.has(Optimization::PrefetchSkipping);
        let window = self.cfg.window;

        let mut values = p.init_values();
        // Activity: which vertices changed last iteration (iteration 1
        // sees the initialization as a change).
        let mut prev_changed = vec![true; n];
        let mut metrics = RunMetrics::default();
        let mut cursor = 0u64;
        let mut on_chip: Option<usize> = None;
        let max_iters = p.kind.fixed_iterations().unwrap_or(u32::MAX);
        // For add-problems (PR/SpMV) updates must read a frozen
        // snapshot; min-problems propagate immediately.
        let immediate = p.kind.reduces_with_min();
        let mut scratch = PhaseScratch::new();

        loop {
            metrics.iterations += 1;
            let mut changed_now = vec![false; n];
            let mut any = false;
            let snapshot = if immediate { None } else { Some(values.clone()) };
            // Accumulators for add-problems.
            let mut acc = if immediate {
                Vec::new()
            } else {
                vec![p.reduce_identity(); n]
            };

            for q in 0..k {
                let interval = self.part.intervals[q];
                let active = (interval.start..interval.end).any(|v| prev_changed[v as usize]);
                if skip && !active {
                    metrics.skipped += 1;
                    continue;
                }
                metrics.processed += 1;

                // --- Phase A: prefetch source values of interval q ---
                let do_prefetch = !(pref_skip && on_chip == Some(q));
                if do_prefetch {
                    metrics.values_read += interval.len() as u64;
                    cursor = run_phase_onchip(
                        mem,
                        &self.prefetch[q],
                        cursor,
                        &mut scratch,
                        onchip.as_deref_mut(),
                    )
                    .end_cycle;
                }
                on_chip = Some(q);

                // --- Algorithm: process the partition, record writes ---
                let mut write_dsts: Vec<u64> = Vec::new();
                // Map each write to the neighbor position that produced
                // it (for chaining writes to neighbor completions).
                let mut write_nbr_pos: Vec<usize> = Vec::new();
                let mut pos_base = 0usize;
                for dst in 0..n as u32 {
                    let nbrs = self.part.neighbors_of(q, dst);
                    if nbrs.is_empty() {
                        continue;
                    }
                    let mut local_changed = false;
                    let mut last_pos = pos_base;
                    for (i, &src) in nbrs.iter().enumerate() {
                        let sval = match &snapshot {
                            Some(s) => s[src as usize],
                            None => values[src as usize],
                        };
                        let u = p.combine(src, sval, 1.0);
                        if immediate {
                            let old = values[dst as usize];
                            let new = p.apply(old, u);
                            if p.changed(old, new) {
                                values[dst as usize] = new;
                                local_changed = true;
                                last_pos = pos_base + i;
                            }
                        } else {
                            let a = &mut acc[dst as usize];
                            *a = p.reduce(*a, u);
                            local_changed = true;
                            last_pos = pos_base + i;
                        }
                    }
                    if local_changed {
                        if immediate {
                            changed_now[dst as usize] = true;
                            any = true;
                        }
                        write_dsts.push(dst as u64);
                        write_nbr_pos.push(last_pos);
                    }
                    pos_base += nbrs.len();
                }
                let m_q = self.part.neighbors[q].len();
                metrics.edges_read += m_q as u64;
                metrics.values_read += n as u64; // destination values
                metrics.values_written += write_dsts.len() as u64;

                // --- Phase B: cached skeleton + dynamic write stream ---
                let [s_vals, s_ptrs, s_nbrs] = &self.body[q];
                let num_nbr_lines = self.nbr_lines[q];
                // Writes chained to the neighbor line that produced them.
                let write_src = LineSource::gather(self.val_base, 4, write_dsts.iter().copied());
                // The gather merges adjacent same-line writes; map the
                // *merged* lines back onto neighbor-line fanouts.
                let mut fanout = vec![0u32; num_nbr_lines];
                {
                    let mut li = 0usize; // index into the merged write lines
                    let mut prev_line = u64::MAX;
                    for (w, &pos) in write_nbr_pos.iter().enumerate() {
                        let line = (self.val_base + write_dsts[w] * 4) / CACHE_LINE * CACHE_LINE;
                        if line == prev_line && li > 0 {
                            continue; // merged into the previous write
                        }
                        prev_line = line;
                        let nbr_line = (pos * 4) / CACHE_LINE as usize;
                        fanout[nbr_line.min(num_nbr_lines.saturating_sub(1))] += 1;
                        li += 1;
                    }
                    debug_assert_eq!(li, write_src.len());
                }
                let s_writes = LineStream::chained(
                    StreamClass::Writes,
                    MemKind::Write,
                    write_src,
                    2, // neighbors stream index
                    fanout,
                );
                let phase = Phase {
                    streams: vec![s_vals.clone(), s_ptrs.clone(), s_nbrs.clone(), s_writes],
                    merge: Arc::clone(&self.merge),
                    window,
                };
                cursor =
                    run_phase_onchip(mem, &phase, cursor, &mut scratch, onchip.as_deref_mut())
                        .end_cycle;
            }

            // Apply accumulated values for add-problems.
            if !immediate {
                for v in 0..n {
                    let new = p.apply(values[v], acc[v]);
                    if p.changed(values[v], new) {
                        changed_now[v] = true;
                        any = true;
                    }
                    values[v] = new;
                }
            }

            prev_changed = changed_now;
            if metrics.iterations >= max_iters {
                break;
            }
            if !any {
                break;
            }
        }

        let dram = mem.stats();
        SimReport {
            accelerator: "AccuGraph",
            problem: p.kind.name(),
            graph_edges: self.m as u64,
            cycles: cursor,
            seconds: cursor as f64 * mem.spec().seconds_per_cycle(),
            bytes_total: dram.requests() * CACHE_LINE,
            bus_utilization: mem.utilization(),
            channels: mem.num_channels(),
            metrics,
            dram,
            // Filled in by SimSpec::run when pattern analysis /
            // on-chip buffering is configured.
            patterns: None,
            onchip: None,
            // Stamped only by the advisor reporting paths.
            advisor: None,
        }
    }
}

/// AccuGraph simulator instance: a handle on a compiled
/// [`AccuGraphProgram`]. (Cross-thread program sharing happens one
/// level up, via `Arc<PhaseProgram>`.)
pub struct AccuGraph {
    program: AccuGraphProgram,
}

impl AccuGraph {
    pub fn new(g: &EdgeList, cfg: &AcceleratorConfig) -> Self {
        AccuGraph {
            program: AccuGraphProgram::compile(g, cfg),
        }
    }

    pub fn num_partitions(&self) -> usize {
        self.program.num_partitions()
    }
}

impl Accelerator for AccuGraph {
    fn name(&self) -> &'static str {
        "AccuGraph"
    }

    fn run(&mut self, p: &GraphProblem, mem: &mut MemorySystem) -> SimReport {
        self.program.execute(p, mem)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::golden::{run_golden, Propagation};
    use crate::algo::problem::ProblemKind;
    use crate::dram::DramSpec;
    use crate::graph::synthetic::erdos_renyi;

    fn run(
        g: &EdgeList,
        kind: ProblemKind,
        cfg: &AcceleratorConfig,
    ) -> (SimReport, GraphProblem) {
        let p = GraphProblem::new(kind, g);
        let mut acc = AccuGraph::new(g, cfg);
        let mut mem = MemorySystem::new(DramSpec::ddr4_2400(1));
        let r = acc.run(&p, &mut mem);
        (r, p)
    }

    #[test]
    fn bfs_iteration_count_matches_immediate_golden() {
        let g = erdos_renyi(2000, 12000, 1);
        let cfg = AcceleratorConfig::default();
        let (r, p) = run(&g, ProblemKind::Bfs, &cfg);
        // Golden immediate with the same edge order (partition-major,
        // dst-major) is not identical, but iteration counts must be in
        // the immediate regime: <= 2-phase count.
        let two = run_golden(&p, &g, Propagation::TwoPhase);
        assert!(r.metrics.iterations <= two.iterations);
        assert!(r.metrics.iterations >= 2);
        assert!(r.seconds > 0.0);
        assert!(r.mteps() > 0.0);
    }

    #[test]
    fn pr_is_one_iteration() {
        let g = erdos_renyi(1000, 8000, 2);
        let (r, _) = run(&g, ProblemKind::PageRank, &AcceleratorConfig::default());
        assert_eq!(r.metrics.iterations, 1);
        assert_eq!(r.metrics.edges_read, 8000);
    }

    #[test]
    fn partition_skipping_reduces_requests() {
        // grid-like sparse graph with many partitions and localized
        // activity -> skipping must help
        let g = crate::graph::synthetic::grid_2d(60, 60); // n=3600 > 1 partition at cap 1024
        let mut cfg = AcceleratorConfig::default();
        cfg.bram_values = 1024;
        let base = run(&g, ProblemKind::Bfs, &cfg).0;
        let skip = run(
            &g,
            ProblemKind::Bfs,
            &cfg.clone().with(Optimization::PartitionSkipping),
        )
        .0;
        assert!(skip.metrics.skipped > 0, "some partitions must be skipped");
        assert!(
            skip.metrics.edges_read < base.metrics.edges_read,
            "skipping reduces edges read: {} vs {}",
            skip.metrics.edges_read,
            base.metrics.edges_read
        );
        assert!(skip.seconds < base.seconds);
    }

    #[test]
    fn prefetch_skipping_on_single_partition_graph() {
        let g = erdos_renyi(500, 3000, 3); // single partition at default cap
        let base = run(&g, ProblemKind::Bfs, &AcceleratorConfig::default()).0;
        let pref = run(
            &g,
            ProblemKind::Bfs,
            &AcceleratorConfig::default().with(Optimization::PrefetchSkipping),
        )
        .0;
        // With one partition the prefetch is skipped from iteration 2 on.
        assert!(pref.metrics.values_read < base.metrics.values_read);
    }

    #[test]
    fn wcc_converges() {
        let g = erdos_renyi(800, 4000, 4);
        let (r, p) = run(&g, ProblemKind::Wcc, &AcceleratorConfig::all_optimizations());
        let golden = run_golden(&p, &g, Propagation::TwoPhase);
        // WCC immediate converges in <= 2-phase iterations.
        assert!(r.metrics.iterations <= golden.iterations);
    }

    #[test]
    fn bytes_per_edge_reflects_csr() {
        // dense single-partition graph: ~4 B/edge for neighbors plus
        // value/pointer streams amortized over many edges
        let g = erdos_renyi(1000, 50_000, 5);
        let (r, _) = run(&g, ProblemKind::PageRank, &AcceleratorConfig::default());
        assert!(
            r.bytes_per_edge() < 8.0,
            "CSR should be < 8 B/edge on dense graphs, got {}",
            r.bytes_per_edge()
        );
    }

    #[test]
    fn shared_program_executions_are_independent() {
        // Two executions of one compiled program (fresh memory each)
        // must be identical — execute holds no mutable program state.
        let g = erdos_renyi(600, 3600, 6);
        let program = AccuGraphProgram::compile(&g, &AcceleratorConfig::default());
        let p = GraphProblem::new(ProblemKind::Bfs, &g);
        let mut m1 = MemorySystem::new(DramSpec::ddr4_2400(1));
        let mut m2 = MemorySystem::new(DramSpec::ddr4_2400(1));
        let r1 = program.execute(&p, &mut m1);
        let r2 = program.execute(&p, &mut m2);
        assert_eq!(r1, r2);
    }
}
