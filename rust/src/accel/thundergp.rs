//! ThunderGP model (§3.2.4, Fig. 7): edge-centric over **vertical**
//! partitioning with a source-**sorted edge list**, **2-phase** update
//! propagation; `p` memory channels, each holding the *whole* vertex
//! value set, its chunk of every partition, and an update set
//! (insights 8 and 9: `n*c + m + n*c` footprint).
//!
//! Per iteration, a **scatter-gather** phase runs for each partition
//! (prefetch the partition's destination values, read the chunk's
//! edges, load source values semi-sequentially through the duplicate-
//! filtering vertex value buffer, write the updated values back),
//! followed by an **apply** phase per partition (read all channels'
//! updates, combine, write the result to *all* channels).
//!
//! Optimization (§4.5): `Schd.` — greedy offline chunk-to-channel
//! scheduling by predicted execution time.

use super::config::{AcceleratorConfig, Optimization};
use super::stream::{Fanout, LineSource, LineStream, Merge, Phase, StreamClass};
use super::Accelerator;
use crate::algo::problem::GraphProblem;
use crate::dram::{MemKind, MemorySystem, CACHE_LINE};
use crate::graph::edgelist::Edge;
use crate::graph::EdgeList;
use crate::partition::vertical::VerticalPartitioning;
use crate::sim::driver::run_phase;
use crate::sim::metrics::{RunMetrics, SimReport};

/// ThunderGP simulator instance.
pub struct ThunderGp {
    part: VerticalPartitioning,
    /// chunk -> channel assignment per partition (`Schd.` reorders it).
    chunk_channel: Vec<Vec<usize>>,
    n: usize,
    m: usize,
    cfg: AcceleratorConfig,
    /// Channel-local bases: full value copy, per-partition chunk edges,
    /// per-partition update sets.
    val_base: u64,
    edge_base: Vec<Vec<u64>>, // [q][chunk]
    upd_base: Vec<u64>,       // [q]
    edge_bytes: u64,
}

impl ThunderGp {
    pub fn new(g: &EdgeList, cfg: &AcceleratorConfig) -> Self {
        let channels = cfg.channels.max(1);
        let part = VerticalPartitioning::new(g, cfg.bram_values, channels);
        let chunk_channel = if cfg.has(Optimization::ChunkScheduling) {
            part.schedule_chunks()
        } else {
            part.chunks
                .iter()
                .map(|cs| (0..cs.len()).collect())
                .collect()
        };
        let n = g.num_vertices;
        let edge_bytes = g.edge_bytes();
        // Channel-local layout (identical on every channel): value copy,
        // then chunk edge arrays, then update sets.
        let val_base = 0u64;
        let mut cursor = (n as u64 * 4 + CACHE_LINE - 1) / CACHE_LINE * CACHE_LINE;
        let mut edge_base = Vec::with_capacity(part.num_partitions());
        for q in 0..part.num_partitions() {
            let mut per_chunk = Vec::new();
            for c in 0..part.chunks[q].len() {
                per_chunk.push(cursor);
                let bytes = part.chunks[q][c].len() as u64 * edge_bytes;
                cursor += (bytes + CACHE_LINE - 1) / CACHE_LINE * CACHE_LINE;
            }
            edge_base.push(per_chunk);
        }
        let mut upd_base = Vec::with_capacity(part.num_partitions());
        for q in 0..part.num_partitions() {
            upd_base.push(cursor);
            let bytes = part.intervals[q].len() as u64 * 4;
            cursor += (bytes + CACHE_LINE - 1) / CACHE_LINE * CACHE_LINE;
        }
        ThunderGp {
            part,
            chunk_channel,
            n,
            m: g.num_edges(),
            cfg: cfg.clone(),
            val_base,
            edge_base,
            upd_base,
            edge_bytes,
        }
    }

    pub fn num_partitions(&self) -> usize {
        self.part.num_partitions()
    }
}

impl Accelerator for ThunderGp {
    fn name(&self) -> &'static str {
        "ThunderGP"
    }

    fn run(&mut self, p: &GraphProblem, mem: &mut MemorySystem) -> SimReport {
        let _n = self.n;
        let k = self.part.num_partitions();
        let channels = self.cfg.channels.max(1).min(mem.num_channels());
        let window = self.cfg.window;

        let mut values = p.init_values();
        let mut metrics = RunMetrics::default();
        let mut cursor = 0u64;
        let max_iters = p.kind.fixed_iterations().unwrap_or(u32::MAX);

        loop {
            metrics.iterations += 1;
            // Per-partition, per-channel partial accumulators (2-phase).
            // acc[q][c][local_dst]
            let mut acc: Vec<Vec<Vec<f32>>> = (0..k)
                .map(|q| {
                    vec![
                        vec![p.reduce_identity(); self.part.intervals[q].len()];
                        channels
                    ]
                })
                .collect();

            // -------- Scatter-gather, one phase per partition ---------
            for q in 0..k {
                metrics.processed += 1;
                let iv = self.part.intervals[q];
                let mut streams: Vec<LineStream> = Vec::new();
                let mut pe_trees: Vec<Merge> = Vec::new();
                for pe in 0..channels.min(self.part.chunks[q].len()) {
                    // chunk handled by channel `pe` under the schedule
                    let chunk_idx = self
                        .chunk_channel[q]
                        .iter()
                        .position(|&ch| ch == pe)
                        .unwrap_or(pe.min(self.part.chunks[q].len() - 1));
                    let chunk: &[Edge] = &self.part.chunks[q][chunk_idx];
                    let region = mem.region_base(pe);

                    // Algorithm: accumulate into this channel's partial.
                    for e in chunk {
                        let u = p.combine(e.src, values[e.src as usize], e.weight);
                        let loc = (e.dst - iv.start) as usize;
                        let a = &mut acc[q][pe][loc];
                        *a = p.reduce(*a, u);
                    }
                    metrics.edges_read += chunk.len() as u64;
                    metrics.values_read += iv.len() as u64; // dst prefetch

                    let base = streams.len();
                    // 1) prefetch destination interval values
                    let pre_src = LineSource::seq(
                        region + self.val_base + iv.start as u64 * 4,
                        iv.len() as u64 * 4,
                    );
                    let npre = pre_src.len();
                    streams.push(LineStream::independent(
                        StreamClass::Prefetch,
                        MemKind::Read,
                        pre_src,
                    ));
                    // 2) chunk edges, chained to the prefetch end
                    let edge_src = LineSource::seq(
                        region + self.edge_base[q][chunk_idx],
                        chunk.len() as u64 * self.edge_bytes,
                    );
                    let nedge = edge_src.len();
                    streams.push(if npre == 0 {
                        LineStream::independent(StreamClass::Edges, MemKind::Read, edge_src)
                    } else {
                        LineStream::chained(
                            StreamClass::Edges,
                            MemKind::Read,
                            edge_src,
                            base,
                            Fanout::AfterLast(nedge as u32),
                        )
                    });
                    // 3) source value loads: semi-sequential (sorted by
                    // src); the vertex value buffer filters duplicates.
                    let src_src = LineSource::gather(
                        region + self.val_base,
                        4,
                        chunk.iter().map(|e| e.src as u64),
                    );
                    metrics.values_read += src_src.len() as u64 * (CACHE_LINE / 4);
                    let nsrc = src_src.len();
                    // distribute src-line releases over edge lines
                    let mut efan = vec![0u32; nedge];
                    if nedge > 0 {
                        let edges_per_line = (CACHE_LINE / self.edge_bytes).max(1) as usize;
                        let mut prev = u64::MAX;
                        let mut li = 0usize;
                        for (ei, e) in chunk.iter().enumerate() {
                            let line = (region + self.val_base + e.src as u64 * 4) / CACHE_LINE
                                * CACHE_LINE;
                            if line != prev {
                                prev = line;
                                let el = ei / edges_per_line;
                                efan[el.min(nedge - 1)] += 1;
                                li += 1;
                            }
                        }
                        debug_assert_eq!(li, nsrc);
                    }
                    streams.push(if nedge == 0 {
                        LineStream::independent(StreamClass::Values, MemKind::Read, src_src)
                    } else {
                        LineStream::chained(
                            StreamClass::Values,
                            MemKind::Read,
                            src_src,
                            base + 1,
                            efan,
                        )
                    });
                    // 4) update write-back: n_q values sequential, after
                    // edge reading finishes — chain to last src load (or
                    // edge line when no src loads).
                    let upd_src = LineSource::seq(region + self.upd_base[q], iv.len() as u64 * 4);
                    let nupd = upd_src.len();
                    metrics.updates_rw += iv.len() as u64;
                    let (parent, plen) = if nsrc > 0 {
                        (base + 2, nsrc)
                    } else {
                        (base + 1, nedge)
                    };
                    if plen > 0 {
                        streams.push(LineStream::chained(
                            StreamClass::Updates,
                            MemKind::Write,
                            upd_src,
                            parent,
                            Fanout::AfterLast(nupd as u32),
                        ));
                        pe_trees.push(Merge::prio([base + 3, base + 2, base + 1, base]));
                    } else {
                        streams.push(LineStream::independent(
                            StreamClass::Updates,
                            MemKind::Write,
                            upd_src,
                        ));
                        pe_trees.push(Merge::prio([base + 3, base]));
                    }
                }
                let phase = Phase {
                    streams,
                    merge: Merge::RoundRobin(pe_trees),
                    window,
                };
                cursor = run_phase(mem, &phase, cursor).end_cycle;
            }

            // ----------------- Apply, one phase per partition ----------
            let mut changed_now = false;
            for q in 0..k {
                let iv = self.part.intervals[q];
                // combine all channels' partials, apply
                let mut writes = 0u64;
                for loc in 0..iv.len() {
                    let mut a = p.reduce_identity();
                    for pe in 0..channels {
                        a = p.reduce(a, acc[q][pe][loc]);
                    }
                    let v = iv.start as usize + loc;
                    let new = if p.kind.reduces_with_min() && a >= p.reduce_identity() {
                        values[v]
                    } else {
                        p.apply(values[v], a)
                    };
                    if p.changed(values[v], new) {
                        changed_now = true;
                        writes += 1;
                    }
                    values[v] = new;
                }
                metrics.values_written += writes * channels as u64;
                metrics.updates_rw += iv.len() as u64 * channels as u64;
                metrics.values_read += iv.len() as u64 * channels as u64;

                // Streams: read update sets from all channels, write the
                // combined value back to every channel's copy.
                let mut streams: Vec<LineStream> = Vec::new();
                let mut reads = Vec::new();
                for pe in 0..channels {
                    let region = mem.region_base(pe);
                    reads.push(streams.len());
                    streams.push(LineStream::independent(
                        StreamClass::Updates,
                        MemKind::Read,
                        LineSource::seq(region + self.upd_base[q], iv.len() as u64 * 4),
                    ));
                }
                let nread = LineSource::seq(self.upd_base[q], iv.len() as u64 * 4).len();
                let mut trees: Vec<Merge> = reads.iter().map(|&i| Merge::Leaf(i)).collect();
                for pe in 0..channels {
                    let region = mem.region_base(pe);
                    let wsrc = LineSource::seq(
                        region + self.val_base + iv.start as u64 * 4,
                        iv.len() as u64 * 4,
                    );
                    // barrier: writes released by the end of this
                    // channel's update read stream
                    if nread > 0 {
                        let nw = wsrc.len();
                        let idx = streams.len();
                        streams.push(LineStream::chained(
                            StreamClass::Writes,
                            MemKind::Write,
                            wsrc,
                            reads[pe],
                            Fanout::AfterLast(nw as u32),
                        ));
                        trees.push(Merge::Leaf(idx));
                    }
                }
                let phase = Phase {
                    streams,
                    merge: Merge::RoundRobin(trees),
                    window,
                };
                cursor = run_phase(mem, &phase, cursor).end_cycle;
            }

            if metrics.iterations >= max_iters {
                break;
            }
            if !changed_now {
                break;
            }
        }

        let dram = mem.stats();
        SimReport {
            accelerator: "ThunderGP",
            problem: p.kind.name(),
            graph_edges: self.m as u64,
            cycles: cursor,
            seconds: cursor as f64 * mem.spec().seconds_per_cycle(),
            bytes_total: dram.requests() * CACHE_LINE,
            bus_utilization: mem.utilization(),
            channels: mem.num_channels(),
            metrics,
            dram,
            // Filled in by SimSpec::run when pattern analysis is on.
            patterns: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::golden::{run_golden, Propagation};
    use crate::algo::problem::ProblemKind;
    use crate::dram::{ChannelMode, DramSpec};
    use crate::graph::synthetic::erdos_renyi;

    fn run_ch(g: &EdgeList, kind: ProblemKind, channels: usize, cfg: &AcceleratorConfig) -> SimReport {
        let p = GraphProblem::new(kind, g);
        let mut acc = ThunderGp::new(g, &cfg.clone().with_channels(channels));
        let mut mem =
            MemorySystem::with_mode(DramSpec::ddr4_2400(channels), ChannelMode::Region);
        acc.run(&p, &mut mem)
    }

    #[test]
    fn bfs_iterations_match_two_phase_golden() {
        let g = erdos_renyi(3000, 18000, 1);
        let p = GraphProblem::new(ProblemKind::Bfs, &g);
        let golden = run_golden(&p, &g, Propagation::TwoPhase);
        let r = run_ch(&g, ProblemKind::Bfs, 1, &AcceleratorConfig::default());
        assert_eq!(r.metrics.iterations, golden.iterations);
    }

    #[test]
    fn pr_single_iteration() {
        let g = erdos_renyi(2000, 16000, 2);
        let r = run_ch(&g, ProblemKind::PageRank, 1, &AcceleratorConfig::default());
        assert_eq!(r.metrics.iterations, 1);
        assert_eq!(r.metrics.edges_read, 16000);
    }

    #[test]
    fn multichannel_duplicates_value_traffic() {
        // insight 8/9: apply reads+writes scale with channel count
        let g = erdos_renyi(4000, 30000, 3);
        let r1 = run_ch(&g, ProblemKind::PageRank, 1, &AcceleratorConfig::default());
        let r4 = run_ch(&g, ProblemKind::PageRank, 4, &AcceleratorConfig::default());
        assert!(
            r4.metrics.updates_rw > 2 * r1.metrics.updates_rw,
            "{} !> 2x {}",
            r4.metrics.updates_rw,
            r1.metrics.updates_rw
        );
    }

    #[test]
    fn scaling_is_sublinear() {
        // insight 8: vertical partitioning scales sub-linearly
        let g = erdos_renyi(6000, 60000, 4);
        let r1 = run_ch(&g, ProblemKind::Bfs, 1, &AcceleratorConfig::default());
        let r4 = run_ch(&g, ProblemKind::Bfs, 4, &AcceleratorConfig::default());
        let speedup = r1.seconds / r4.seconds;
        assert!(speedup > 1.2, "4ch should help some: {speedup}");
        assert!(speedup < 4.0, "but sub-linearly: {speedup}");
    }

    #[test]
    fn chunk_scheduling_small_effect() {
        let g = erdos_renyi(4000, 30000, 5);
        let base = run_ch(&g, ProblemKind::Bfs, 4, &AcceleratorConfig::default());
        let sched = run_ch(
            &g,
            ProblemKind::Bfs,
            4,
            &AcceleratorConfig::default().with(Optimization::ChunkScheduling),
        );
        // Fig. 13: "does not make a big difference" — within 25%.
        let ratio = sched.seconds / base.seconds;
        assert!(ratio > 0.7 && ratio < 1.3, "ratio {ratio}");
    }

    #[test]
    fn weighted_sssp_runs() {
        let g = erdos_renyi(1500, 9000, 6).with_random_weights(7, 8.0);
        let p = GraphProblem::new(ProblemKind::Sssp, &g);
        let golden = run_golden(&p, &g, Propagation::TwoPhase);
        let r = run_ch(&g, ProblemKind::Sssp, 1, &AcceleratorConfig::default());
        assert_eq!(r.metrics.iterations, golden.iterations);
    }
}
