//! ThunderGP model (§3.2.4, Fig. 7): edge-centric over **vertical**
//! partitioning with a source-**sorted edge list**, **2-phase** update
//! propagation; `p` memory channels, each holding the *whole* vertex
//! value set, its chunk of every partition, and an update set
//! (insights 8 and 9: `n*c + m + n*c` footprint).
//!
//! Per iteration, a **scatter-gather** phase runs for each partition
//! (prefetch the partition's destination values, read the chunk's
//! edges, load source values semi-sequentially through the duplicate-
//! filtering vertex value buffer, write the updated values back),
//! followed by an **apply** phase per partition (read all channels'
//! updates, combine, write the result to *all* channels).
//!
//! Optimization (§4.5): `Schd.` — greedy offline chunk-to-channel
//! scheduling by predicted execution time.
//!
//! Split compile/execute (see [`crate::accel::program`]): ThunderGP's
//! request streams are *entirely* value-independent, so
//! [`ThunderGpProgram`] compiles the per-chunk source-value
//! [`LineSource::Gather`] descriptors and their edge-line release
//! fan-outs once (the seed rebuilt both — an O(|E|) pass with two
//! allocations per chunk — every iteration), in channel-relative
//! form; execution instantiates each partition's scatter and apply
//! phase once per run against the concrete memory system's region
//! bases and replays them by reference across iterations.

use super::config::{AcceleratorConfig, Optimization};
use super::stream::{Fanout, LineSource, LineStream, Merge, Phase, StreamClass};
use super::Accelerator;
use crate::algo::problem::GraphProblem;
use crate::dram::{MemKind, MemorySystem, CACHE_LINE};
use crate::graph::edgelist::Edge;
use crate::graph::EdgeList;
use crate::onchip::OnChipBuffer;
use crate::partition::vertical::VerticalPartitioning;
use crate::sim::driver::{run_phase_onchip, PhaseScratch};
use crate::sim::metrics::{RunMetrics, SimReport};

/// Compiled ThunderGP program (iteration- and memory-invariant
/// artifacts; addresses are channel-relative until execute adds the
/// region bases).
pub struct ThunderGpProgram {
    part: VerticalPartitioning,
    /// chunk -> channel assignment per partition (`Schd.` reorders it).
    chunk_channel: Vec<Vec<usize>>,
    m: usize,
    cfg: AcceleratorConfig,
    /// Channel-local bases: full value copy, per-partition chunk edges,
    /// per-partition update sets.
    val_base: u64,
    edge_base: Vec<Vec<u64>>, // [q][chunk]
    upd_base: Vec<u64>,       // [q]
    edge_bytes: u64,
    /// Per (partition, chunk): source-value gather descriptor
    /// (channel-relative; `rebase` relocates it) — the semi-sequential
    /// src loads through the duplicate-filtering value buffer.
    src_gather: Vec<Vec<LineSource>>,
    /// Per (partition, chunk): how many src-value lines each edge
    /// line's completion releases.
    src_fanout: Vec<Vec<Fanout>>,
}

impl ThunderGpProgram {
    pub fn compile(g: &EdgeList, cfg: &AcceleratorConfig) -> Self {
        let channels = cfg.channels.max(1);
        let part = VerticalPartitioning::new(g, cfg.bram_values, channels);
        let chunk_channel = if cfg.has(Optimization::ChunkScheduling) {
            part.schedule_chunks()
        } else {
            part.chunks
                .iter()
                .map(|cs| (0..cs.len()).collect())
                .collect()
        };
        let n = g.num_vertices;
        let edge_bytes = g.edge_bytes();
        // Channel-local layout (identical on every channel): value copy,
        // then chunk edge arrays, then update sets.
        let val_base = 0u64;
        let mut cursor = (n as u64 * 4 + CACHE_LINE - 1) / CACHE_LINE * CACHE_LINE;
        let mut edge_base = Vec::with_capacity(part.num_partitions());
        for q in 0..part.num_partitions() {
            let mut per_chunk = Vec::new();
            for c in 0..part.chunks[q].len() {
                per_chunk.push(cursor);
                let bytes = part.chunks[q][c].len() as u64 * edge_bytes;
                cursor += (bytes + CACHE_LINE - 1) / CACHE_LINE * CACHE_LINE;
            }
            edge_base.push(per_chunk);
        }
        let mut upd_base = Vec::with_capacity(part.num_partitions());
        for q in 0..part.num_partitions() {
            upd_base.push(cursor);
            let bytes = part.intervals[q].len() as u64 * 4;
            cursor += (bytes + CACHE_LINE - 1) / CACHE_LINE * CACHE_LINE;
        }

        // Per-chunk source gathers + edge-line release schedules. The
        // line-merge pattern is computed channel-relative; region
        // bases are cache-line aligned, so relocation preserves it.
        let mut src_gather = Vec::with_capacity(part.num_partitions());
        let mut src_fanout = Vec::with_capacity(part.num_partitions());
        for q in 0..part.num_partitions() {
            let mut gathers = Vec::with_capacity(part.chunks[q].len());
            let mut fanouts = Vec::with_capacity(part.chunks[q].len());
            for (c, chunk) in part.chunks[q].iter().enumerate() {
                let src = LineSource::gather(val_base, 4, chunk.iter().map(|e| e.src as u64));
                let nsrc = src.len();
                let nedge =
                    LineSource::seq(edge_base[q][c], chunk.len() as u64 * edge_bytes).len();
                let mut efan = vec![0u32; nedge];
                if nedge > 0 {
                    let edges_per_line = (CACHE_LINE / edge_bytes).max(1) as usize;
                    let mut prev = u64::MAX;
                    let mut li = 0usize;
                    for (ei, e) in chunk.iter().enumerate() {
                        let line = (val_base + e.src as u64 * 4) / CACHE_LINE * CACHE_LINE;
                        if line != prev {
                            prev = line;
                            let el = ei / edges_per_line;
                            efan[el.min(nedge - 1)] += 1;
                            li += 1;
                        }
                    }
                    debug_assert_eq!(li, nsrc);
                }
                gathers.push(src);
                fanouts.push(Fanout::PerParent(efan.into()));
            }
            src_gather.push(gathers);
            src_fanout.push(fanouts);
        }

        ThunderGpProgram {
            part,
            chunk_channel,
            m: g.num_edges(),
            cfg: cfg.clone(),
            val_base,
            edge_base,
            upd_base,
            edge_bytes,
            src_gather,
            src_fanout,
        }
    }

    pub fn num_partitions(&self) -> usize {
        self.part.num_partitions()
    }

    /// The checkable mirror of this program (see [`crate::verify`]).
    /// ThunderGP's request streams are entirely value-independent, so
    /// this is the exact structure [`ThunderGpProgram::execute_onchip`]
    /// instantiates — scatter and apply phases per partition — in the
    /// compiled channel-relative address space (owners replace region
    /// bases; the bounds check replays the rebase). Source-value
    /// gathers declare the vertex count as their index domain.
    pub(crate) fn facts(&self) -> crate::verify::ProgramFacts {
        use crate::dram::ChannelMode;
        use crate::verify::{PhaseFacts, ProgramFacts, StreamFacts};
        let k = self.part.num_partitions();
        let channels = self.cfg.channels.max(1);
        let window = self.cfg.window;
        let n = self.part.intervals.last().map_or(0, |iv| iv.end as usize);
        let mut phases = Vec::with_capacity(2 * k);
        for q in 0..k {
            let iv = self.part.intervals[q];
            let pe_chunks = self.pe_chunks(q, channels);

            // ---- Scatter-gather: prefetch -> edges -> src gather -> updates
            let mut streams: Vec<StreamFacts> = Vec::new();
            let mut pe_trees: Vec<Merge> = Vec::new();
            for (pe, &chunk_idx) in pe_chunks.iter().enumerate() {
                let chunk_len = self.part.chunks[q][chunk_idx].len();
                let base = streams.len();
                let pre_src =
                    LineSource::seq(self.val_base + iv.start as u64 * 4, iv.len() as u64 * 4);
                let npre = pre_src.len();
                streams.push(StreamFacts {
                    class: StreamClass::Prefetch,
                    source: pre_src,
                    chained_to: None,
                    fanout: Fanout::Uniform(0),
                    owner: Some(pe),
                    gather_domain: None,
                    dynamic: false,
                });
                let edge_src = LineSource::seq(
                    self.edge_base[q][chunk_idx],
                    chunk_len as u64 * self.edge_bytes,
                );
                let nedge = edge_src.len();
                streams.push(StreamFacts {
                    class: StreamClass::Edges,
                    source: edge_src,
                    chained_to: (npre > 0).then_some(base),
                    fanout: if npre > 0 {
                        Fanout::AfterLast(nedge as u32)
                    } else {
                        Fanout::Uniform(0)
                    },
                    owner: Some(pe),
                    gather_domain: None,
                    dynamic: false,
                });
                let src_src = self.src_gather[q][chunk_idx].clone();
                let nsrc = src_src.len();
                streams.push(StreamFacts {
                    class: StreamClass::Values,
                    source: src_src,
                    chained_to: (nedge > 0).then_some(base + 1),
                    fanout: if nedge > 0 {
                        self.src_fanout[q][chunk_idx].clone()
                    } else {
                        Fanout::Uniform(0)
                    },
                    owner: Some(pe),
                    gather_domain: Some(n as u64),
                    dynamic: false,
                });
                let upd_src = LineSource::seq(self.upd_base[q], iv.len() as u64 * 4);
                let nupd = upd_src.len();
                let (parent, plen) = if nsrc > 0 {
                    (base + 2, nsrc)
                } else {
                    (base + 1, nedge)
                };
                if plen > 0 {
                    streams.push(StreamFacts {
                        class: StreamClass::Updates,
                        source: upd_src,
                        chained_to: Some(parent),
                        fanout: Fanout::AfterLast(nupd as u32),
                        owner: Some(pe),
                        gather_domain: None,
                        dynamic: false,
                    });
                    pe_trees.push(Merge::prio([base + 3, base + 2, base + 1, base]));
                } else {
                    streams.push(StreamFacts {
                        class: StreamClass::Updates,
                        source: upd_src,
                        chained_to: None,
                        fanout: Fanout::Uniform(0),
                        owner: Some(pe),
                        gather_domain: None,
                        dynamic: false,
                    });
                    pe_trees.push(Merge::prio([base + 3, base]));
                }
            }
            phases.push(PhaseFacts {
                label: format!("scatter[{q}]"),
                streams,
                merge: Merge::RoundRobin(pe_trees).into(),
                window,
            });

            // ---- Apply: read all channels' update sets, write all copies
            let mut streams: Vec<StreamFacts> = Vec::new();
            let mut reads = Vec::new();
            for pe in 0..channels {
                reads.push(streams.len());
                streams.push(StreamFacts {
                    class: StreamClass::Updates,
                    source: LineSource::seq(self.upd_base[q], iv.len() as u64 * 4),
                    chained_to: None,
                    fanout: Fanout::Uniform(0),
                    owner: Some(pe),
                    gather_domain: None,
                    dynamic: false,
                });
            }
            let nread = LineSource::seq(self.upd_base[q], iv.len() as u64 * 4).len();
            let mut trees: Vec<Merge> = reads.iter().map(|&i| Merge::Leaf(i)).collect();
            if nread > 0 {
                for pe in 0..channels {
                    let wsrc =
                        LineSource::seq(self.val_base + iv.start as u64 * 4, iv.len() as u64 * 4);
                    let nw = wsrc.len();
                    let idx = streams.len();
                    streams.push(StreamFacts {
                        class: StreamClass::Writes,
                        source: wsrc,
                        chained_to: Some(reads[pe]),
                        fanout: Fanout::AfterLast(nw as u32),
                        owner: Some(pe),
                        gather_domain: None,
                        dynamic: false,
                    });
                    trees.push(Merge::Leaf(idx));
                }
            }
            phases.push(PhaseFacts {
                label: format!("apply[{q}]"),
                streams,
                merge: Merge::RoundRobin(trees).into(),
                window,
            });
        }
        ProgramFacts::assemble(
            super::AcceleratorKind::ThunderGp,
            n,
            self.m,
            channels,
            ChannelMode::Region,
            phases,
        )
    }

    /// The chunk each PE (= channel) of partition `q` processes under
    /// the (possibly `Schd.`-reordered) assignment.
    fn pe_chunks(&self, q: usize, channels: usize) -> Vec<usize> {
        (0..channels.min(self.part.chunks[q].len()))
            .map(|pe| {
                self.chunk_channel[q]
                    .iter()
                    .position(|&ch| ch == pe)
                    .unwrap_or(pe.min(self.part.chunks[q].len() - 1))
            })
            .collect()
    }

    /// Instantiate partition `q`'s scatter-gather phase against the
    /// concrete memory system (adds region bases to the compiled
    /// channel-relative descriptors). Iteration-invariant: built once
    /// per run, replayed every iteration.
    fn scatter_phase(&self, q: usize, pe_chunks: &[usize], mem: &MemorySystem) -> Phase {
        let iv = self.part.intervals[q];
        let window = self.cfg.window;
        let mut streams: Vec<LineStream> = Vec::new();
        let mut pe_trees: Vec<Merge> = Vec::new();
        for (pe, &chunk_idx) in pe_chunks.iter().enumerate() {
            let chunk: &[Edge] = &self.part.chunks[q][chunk_idx];
            let region = mem.region_base(pe);
            let base = streams.len();
            // 1) prefetch destination interval values
            let pre_src = LineSource::seq(
                region + self.val_base + iv.start as u64 * 4,
                iv.len() as u64 * 4,
            );
            let npre = pre_src.len();
            streams.push(LineStream::independent(
                StreamClass::Prefetch,
                MemKind::Read,
                pre_src,
            ));
            // 2) chunk edges, chained to the prefetch end
            let edge_src = LineSource::seq(
                region + self.edge_base[q][chunk_idx],
                chunk.len() as u64 * self.edge_bytes,
            );
            let nedge = edge_src.len();
            streams.push(if npre == 0 {
                LineStream::independent(StreamClass::Edges, MemKind::Read, edge_src)
            } else {
                LineStream::chained(
                    StreamClass::Edges,
                    MemKind::Read,
                    edge_src,
                    base,
                    Fanout::AfterLast(nedge as u32),
                )
            });
            // 3) source value loads: the compiled gather, relocated
            // onto this channel's region; released by edge lines.
            let src_src = self.src_gather[q][chunk_idx].rebase(region);
            let nsrc = src_src.len();
            streams.push(if nedge == 0 {
                LineStream::independent(StreamClass::Values, MemKind::Read, src_src)
            } else {
                LineStream::chained(
                    StreamClass::Values,
                    MemKind::Read,
                    src_src,
                    base + 1,
                    self.src_fanout[q][chunk_idx].clone(),
                )
            });
            // 4) update write-back: n_q values sequential, after
            // edge reading finishes — chain to last src load (or
            // edge line when no src loads).
            let upd_src = LineSource::seq(region + self.upd_base[q], iv.len() as u64 * 4);
            let nupd = upd_src.len();
            let (parent, plen) = if nsrc > 0 {
                (base + 2, nsrc)
            } else {
                (base + 1, nedge)
            };
            if plen > 0 {
                streams.push(LineStream::chained(
                    StreamClass::Updates,
                    MemKind::Write,
                    upd_src,
                    parent,
                    Fanout::AfterLast(nupd as u32),
                ));
                pe_trees.push(Merge::prio([base + 3, base + 2, base + 1, base]));
            } else {
                streams.push(LineStream::independent(
                    StreamClass::Updates,
                    MemKind::Write,
                    upd_src,
                ));
                pe_trees.push(Merge::prio([base + 3, base]));
            }
        }
        Phase {
            streams,
            merge: Merge::RoundRobin(pe_trees).into(),
            window,
        }
    }

    /// Instantiate partition `q`'s apply phase: read update sets from
    /// all channels, write the combined value back to every channel's
    /// copy. Also iteration-invariant.
    fn apply_phase(&self, q: usize, channels: usize, mem: &MemorySystem) -> Phase {
        let iv = self.part.intervals[q];
        let window = self.cfg.window;
        let mut streams: Vec<LineStream> = Vec::new();
        let mut reads = Vec::new();
        for pe in 0..channels {
            let region = mem.region_base(pe);
            reads.push(streams.len());
            streams.push(LineStream::independent(
                StreamClass::Updates,
                MemKind::Read,
                LineSource::seq(region + self.upd_base[q], iv.len() as u64 * 4),
            ));
        }
        let nread = LineSource::seq(self.upd_base[q], iv.len() as u64 * 4).len();
        let mut trees: Vec<Merge> = reads.iter().map(|&i| Merge::Leaf(i)).collect();
        for pe in 0..channels {
            let region = mem.region_base(pe);
            let wsrc = LineSource::seq(
                region + self.val_base + iv.start as u64 * 4,
                iv.len() as u64 * 4,
            );
            // barrier: writes released by the end of this
            // channel's update read stream
            if nread > 0 {
                let nw = wsrc.len();
                let idx = streams.len();
                streams.push(LineStream::chained(
                    StreamClass::Writes,
                    MemKind::Write,
                    wsrc,
                    reads[pe],
                    Fanout::AfterLast(nw as u32),
                ));
                trees.push(Merge::Leaf(idx));
            }
        }
        Phase {
            streams,
            merge: Merge::RoundRobin(trees).into(),
            window,
        }
    }

    pub fn execute(&self, p: &GraphProblem, mem: &mut MemorySystem) -> SimReport {
        self.execute_onchip(p, mem, None)
    }

    /// [`ThunderGpProgram::execute`] with an optional on-chip buffer
    /// (see [`crate::onchip`]). ThunderGP is a streaming design whose
    /// duplicate-filtering value buffer is already folded into the
    /// compiled gathers — its paper-faithful default is *no* buffer —
    /// but the hook makes BRAM what-ifs sweepable.
    pub fn execute_onchip(
        &self,
        p: &GraphProblem,
        mem: &mut MemorySystem,
        mut onchip: Option<&mut OnChipBuffer>,
    ) -> SimReport {
        let k = self.part.num_partitions();
        let channels = self.cfg.channels.max(1).min(mem.num_channels());
        let mut scratch = PhaseScratch::new();

        // Every request stream of this model is value-independent:
        // instantiate each partition's phases once, replay per
        // iteration.
        let pe_chunks: Vec<Vec<usize>> = (0..k).map(|q| self.pe_chunks(q, channels)).collect();
        let scatter_phases: Vec<Phase> = (0..k)
            .map(|q| self.scatter_phase(q, &pe_chunks[q], mem))
            .collect();
        let apply_phases: Vec<Phase> =
            (0..k).map(|q| self.apply_phase(q, channels, mem)).collect();

        let mut values = p.init_values();
        let mut metrics = RunMetrics::default();
        let mut cursor = 0u64;
        let max_iters = p.kind.fixed_iterations().unwrap_or(u32::MAX);

        loop {
            metrics.iterations += 1;
            // Per-partition, per-channel partial accumulators (2-phase).
            // acc[q][c][local_dst]
            let mut acc: Vec<Vec<Vec<f32>>> = (0..k)
                .map(|q| {
                    vec![
                        vec![p.reduce_identity(); self.part.intervals[q].len()];
                        channels
                    ]
                })
                .collect();

            // -------- Scatter-gather, one phase per partition ---------
            for q in 0..k {
                metrics.processed += 1;
                let iv = self.part.intervals[q];
                for (pe, &chunk_idx) in pe_chunks[q].iter().enumerate() {
                    let chunk: &[Edge] = &self.part.chunks[q][chunk_idx];
                    // Algorithm: accumulate into this channel's partial.
                    for e in chunk {
                        let u = p.combine(e.src, values[e.src as usize], e.weight);
                        let loc = (e.dst - iv.start) as usize;
                        let a = &mut acc[q][pe][loc];
                        *a = p.reduce(*a, u);
                    }
                    metrics.edges_read += chunk.len() as u64;
                    metrics.values_read += iv.len() as u64; // dst prefetch
                    metrics.values_read +=
                        self.src_gather[q][chunk_idx].len() as u64 * (CACHE_LINE / 4);
                    metrics.updates_rw += iv.len() as u64;
                }
                cursor = run_phase_onchip(
                    mem,
                    &scatter_phases[q],
                    cursor,
                    &mut scratch,
                    onchip.as_deref_mut(),
                )
                .end_cycle;
            }

            // ----------------- Apply, one phase per partition ----------
            let mut changed_now = false;
            for q in 0..k {
                let iv = self.part.intervals[q];
                // combine all channels' partials, apply
                let mut writes = 0u64;
                for loc in 0..iv.len() {
                    let mut a = p.reduce_identity();
                    for pe in 0..channels {
                        a = p.reduce(a, acc[q][pe][loc]);
                    }
                    let v = iv.start as usize + loc;
                    let new = if p.kind.reduces_with_min() && a >= p.reduce_identity() {
                        values[v]
                    } else {
                        p.apply(values[v], a)
                    };
                    if p.changed(values[v], new) {
                        changed_now = true;
                        writes += 1;
                    }
                    values[v] = new;
                }
                metrics.values_written += writes * channels as u64;
                metrics.updates_rw += iv.len() as u64 * channels as u64;
                metrics.values_read += iv.len() as u64 * channels as u64;

                cursor = run_phase_onchip(
                    mem,
                    &apply_phases[q],
                    cursor,
                    &mut scratch,
                    onchip.as_deref_mut(),
                )
                .end_cycle;
            }

            if metrics.iterations >= max_iters {
                break;
            }
            if !changed_now {
                break;
            }
        }

        let dram = mem.stats();
        SimReport {
            accelerator: "ThunderGP",
            problem: p.kind.name(),
            graph_edges: self.m as u64,
            cycles: cursor,
            seconds: cursor as f64 * mem.spec().seconds_per_cycle(),
            bytes_total: dram.requests() * CACHE_LINE,
            bus_utilization: mem.utilization(),
            channels: mem.num_channels(),
            metrics,
            dram,
            // Filled in by SimSpec::run when pattern analysis /
            // on-chip buffering is configured.
            patterns: None,
            onchip: None,
            // Stamped only by the advisor reporting paths.
            advisor: None,
        }
    }
}

/// ThunderGP simulator instance: a handle on a compiled
/// [`ThunderGpProgram`]. (Cross-thread program sharing happens one
/// level up, via `Arc<PhaseProgram>`.)
pub struct ThunderGp {
    program: ThunderGpProgram,
}

impl ThunderGp {
    pub fn new(g: &EdgeList, cfg: &AcceleratorConfig) -> Self {
        ThunderGp {
            program: ThunderGpProgram::compile(g, cfg),
        }
    }

    pub fn num_partitions(&self) -> usize {
        self.program.num_partitions()
    }
}

impl Accelerator for ThunderGp {
    fn name(&self) -> &'static str {
        "ThunderGP"
    }

    fn run(&mut self, p: &GraphProblem, mem: &mut MemorySystem) -> SimReport {
        self.program.execute(p, mem)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::golden::{run_golden, Propagation};
    use crate::algo::problem::ProblemKind;
    use crate::dram::{ChannelMode, DramSpec};
    use crate::graph::synthetic::erdos_renyi;

    fn run_ch(g: &EdgeList, kind: ProblemKind, channels: usize, cfg: &AcceleratorConfig) -> SimReport {
        let p = GraphProblem::new(kind, g);
        let mut acc = ThunderGp::new(g, &cfg.clone().with_channels(channels));
        let mut mem =
            MemorySystem::with_mode(DramSpec::ddr4_2400(channels), ChannelMode::Region);
        acc.run(&p, &mut mem)
    }

    #[test]
    fn bfs_iterations_match_two_phase_golden() {
        let g = erdos_renyi(3000, 18000, 1);
        let p = GraphProblem::new(ProblemKind::Bfs, &g);
        let golden = run_golden(&p, &g, Propagation::TwoPhase);
        let r = run_ch(&g, ProblemKind::Bfs, 1, &AcceleratorConfig::default());
        assert_eq!(r.metrics.iterations, golden.iterations);
    }

    #[test]
    fn pr_single_iteration() {
        let g = erdos_renyi(2000, 16000, 2);
        let r = run_ch(&g, ProblemKind::PageRank, 1, &AcceleratorConfig::default());
        assert_eq!(r.metrics.iterations, 1);
        assert_eq!(r.metrics.edges_read, 16000);
    }

    #[test]
    fn multichannel_duplicates_value_traffic() {
        // insight 8/9: apply reads+writes scale with channel count
        let g = erdos_renyi(4000, 30000, 3);
        let r1 = run_ch(&g, ProblemKind::PageRank, 1, &AcceleratorConfig::default());
        let r4 = run_ch(&g, ProblemKind::PageRank, 4, &AcceleratorConfig::default());
        assert!(
            r4.metrics.updates_rw > 2 * r1.metrics.updates_rw,
            "{} !> 2x {}",
            r4.metrics.updates_rw,
            r1.metrics.updates_rw
        );
    }

    #[test]
    fn scaling_is_sublinear() {
        // insight 8: vertical partitioning scales sub-linearly
        let g = erdos_renyi(6000, 60000, 4);
        let r1 = run_ch(&g, ProblemKind::Bfs, 1, &AcceleratorConfig::default());
        let r4 = run_ch(&g, ProblemKind::Bfs, 4, &AcceleratorConfig::default());
        let speedup = r1.seconds / r4.seconds;
        assert!(speedup > 1.2, "4ch should help some: {speedup}");
        assert!(speedup < 4.0, "but sub-linearly: {speedup}");
    }

    #[test]
    fn chunk_scheduling_small_effect() {
        let g = erdos_renyi(4000, 30000, 5);
        let base = run_ch(&g, ProblemKind::Bfs, 4, &AcceleratorConfig::default());
        let sched = run_ch(
            &g,
            ProblemKind::Bfs,
            4,
            &AcceleratorConfig::default().with(Optimization::ChunkScheduling),
        );
        // Fig. 13: "does not make a big difference" — within 25%.
        let ratio = sched.seconds / base.seconds;
        assert!(ratio > 0.7 && ratio < 1.3, "ratio {ratio}");
    }

    #[test]
    fn weighted_sssp_runs() {
        let g = erdos_renyi(1500, 9000, 6).with_random_weights(7, 8.0);
        let p = GraphProblem::new(ProblemKind::Sssp, &g);
        let golden = run_golden(&p, &g, Propagation::TwoPhase);
        let r = run_ch(&g, ProblemKind::Sssp, 1, &AcceleratorConfig::default());
        assert_eq!(r.metrics.iterations, golden.iterations);
    }

    #[test]
    fn compiled_gathers_match_inline_construction() {
        // The compile-time src gathers, relocated by the region base,
        // must reproduce exactly what building against the absolute
        // addresses would (the seed's per-iteration construction).
        let g = erdos_renyi(900, 5400, 8);
        let cfg = AcceleratorConfig::default().with_channels(2);
        let prog = ThunderGpProgram::compile(&g, &cfg);
        let mem = MemorySystem::with_mode(DramSpec::hbm_1000(2), ChannelMode::Region);
        for q in 0..prog.num_partitions() {
            for (c, chunk) in prog.part.chunks[q].iter().enumerate() {
                for pe in 0..2 {
                    let region = mem.region_base(pe);
                    let inline = LineSource::gather(
                        region + prog.val_base,
                        4,
                        chunk.iter().map(|e| e.src as u64),
                    );
                    let compiled = prog.src_gather[q][c].rebase(region);
                    assert_eq!(inline.materialize(), compiled.materialize());
                }
            }
        }
    }
}
