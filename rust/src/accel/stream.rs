//! The simulation vocabulary of the paper's environment (§2.2, §3.2):
//! request streams with callbacks, round-robin and priority mergers,
//! and the *cache line* and *filter* memory access abstractions.
//!
//! An accelerator phase is a set of [`LineStream`]s — precomputed
//! cache-line request sequences — wired together by chaining
//! (stream B's requests are released by completions of stream A:
//! the paper's "callbacks") and drained through a merge tree that
//! mirrors the accelerator's on-chip arbiters.

use crate::dram::{MemKind, CACHE_LINE};
use crate::trace::Region;

/// Identifies what a stream models. The phase driver maps it onto a
/// [`Region`] tag stamped on every issued request, which is how the
/// trace-analysis subsystem attributes traffic to data structures.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StreamClass {
    /// Vertex value prefetch.
    Prefetch,
    /// Vertex value reads.
    Values,
    /// CSR pointer reads.
    Pointers,
    /// Edge / neighbor reads.
    Edges,
    /// Update queue reads or writes.
    Updates,
    /// Vertex value write-backs.
    Writes,
}

impl StreamClass {
    /// The trace region a stream of this class belongs to: vertex
    /// value traffic (prefetches, random reads, write-backs), edge
    /// reads, update sets, or auxiliary payload (CSR pointers).
    pub fn region(self) -> Region {
        match self {
            StreamClass::Prefetch | StreamClass::Values | StreamClass::Writes => Region::Vertices,
            StreamClass::Edges => Region::Edges,
            StreamClass::Updates => Region::Updates,
            StreamClass::Pointers => Region::Payload,
        }
    }
}

/// A precomputed sequence of cache-line requests.
#[derive(Clone, Debug)]
pub struct LineStream {
    /// 64 B-aligned line addresses, in program order.
    pub lines: Vec<u64>,
    pub kind: MemKind,
    pub class: StreamClass,
    /// `Some(parent)`: requests are released by the parent stream's
    /// completions — `fanout[i]` requests become available when the
    /// parent's `i`-th request completes (the callback mechanism).
    /// `None`: all requests available at phase start.
    pub chained_to: Option<usize>,
    /// Only for chained streams; `fanout.len()` must equal the parent
    /// stream's `lines.len()` and `sum(fanout) == lines.len()`.
    pub fanout: Vec<u32>,
}

impl LineStream {
    /// Independent (unchained) stream.
    pub fn independent(class: StreamClass, kind: MemKind, lines: Vec<u64>) -> Self {
        LineStream {
            lines,
            kind,
            class,
            chained_to: None,
            fanout: Vec::new(),
        }
    }

    /// Stream whose requests are released by `parent`'s completions.
    pub fn chained(
        class: StreamClass,
        kind: MemKind,
        lines: Vec<u64>,
        parent: usize,
        fanout: Vec<u32>,
    ) -> Self {
        debug_assert_eq!(fanout.iter().map(|&f| f as usize).sum::<usize>(), lines.len());
        LineStream {
            lines,
            kind,
            class,
            chained_to: Some(parent),
            fanout,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.lines.is_empty()
    }
}

/// Merge tree: which stream may issue next. Mirrors the accelerators'
/// arbiters (AccuGraph: values/pointers round-robin under a priority
/// mux with writes highest; ForeGraph: PEs round-robin; …).
#[derive(Clone, Debug)]
pub enum Merge {
    Leaf(usize),
    /// Fair rotation among children that have an available request.
    RoundRobin(Vec<Merge>),
    /// First child (highest priority) with an available request wins.
    Priority(Vec<Merge>),
}

impl Merge {
    /// Round-robin over plain stream indices.
    pub fn rr(streams: impl IntoIterator<Item = usize>) -> Merge {
        Merge::RoundRobin(streams.into_iter().map(Merge::Leaf).collect())
    }

    /// Priority over plain stream indices (first = highest).
    pub fn prio(streams: impl IntoIterator<Item = usize>) -> Merge {
        Merge::Priority(streams.into_iter().map(Merge::Leaf).collect())
    }
}

/// One phase of accelerator execution: streams + merge tree + the
/// outstanding-request window of the PE's memory port.
#[derive(Clone, Debug)]
pub struct Phase {
    pub streams: Vec<LineStream>,
    pub merge: Merge,
    /// Maximum requests in flight.
    pub window: usize,
}

impl Phase {
    /// Single independent sequential stream — the most common phase
    /// shape (prefetches, write-backs).
    pub fn single(class: StreamClass, kind: MemKind, lines: Vec<u64>, window: usize) -> Phase {
        Phase {
            streams: vec![LineStream::independent(class, kind, lines)],
            merge: Merge::Leaf(0),
            window,
        }
    }

    pub fn total_requests(&self) -> usize {
        self.streams.iter().map(|s| s.lines.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.streams.iter().all(|s| s.is_empty())
    }
}

// ---------------------------------------------------------------------------
// Cache-line access abstraction (§3.2.1): merge adjacent requests to
// the same cache line into one.
// ---------------------------------------------------------------------------

/// Lines covering the byte range `[base, base + bytes)` — a sequential
/// array scan through the cache-line abstraction.
pub fn seq_lines(base: u64, bytes: u64) -> Vec<u64> {
    if bytes == 0 {
        return Vec::new();
    }
    let first = base / CACHE_LINE;
    let last = (base + bytes - 1) / CACHE_LINE;
    (first..=last).map(|l| l * CACHE_LINE).collect()
}

/// Lines for element-indexed accesses `base + idx * elem_bytes`,
/// merging *adjacent* requests to the same line (the abstraction
/// merges consecutive duplicates only — a repeated line after other
/// traffic is requested again).
pub fn element_lines(base: u64, elem_bytes: u64, indices: impl IntoIterator<Item = u64>) -> Vec<u64> {
    let mut out: Vec<u64> = Vec::new();
    for idx in indices {
        let line = (base + idx * elem_bytes) / CACHE_LINE * CACHE_LINE;
        if out.last() != Some(&line) {
            out.push(line);
        }
    }
    out
}

/// Number of lines a sequential scan of `bytes` bytes touches.
pub fn lines_for(bytes: u64) -> u64 {
    crate::util::ceil_div(bytes, CACHE_LINE)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seq_lines_cover_range() {
        assert_eq!(seq_lines(0, 64), vec![0]);
        assert_eq!(seq_lines(0, 65), vec![0, 64]);
        assert_eq!(seq_lines(60, 8), vec![0, 64]); // straddles boundary
        assert_eq!(seq_lines(128, 0), Vec::<u64>::new());
        assert_eq!(seq_lines(100, 1), vec![64]);
    }

    #[test]
    fn element_lines_merge_adjacent_only() {
        // 4-byte elements, indices 0,1,2 -> same line merged
        assert_eq!(element_lines(0, 4, [0, 1, 2]), vec![0]);
        // revisiting a line after other traffic re-requests it
        assert_eq!(element_lines(0, 4, [0, 16, 0]), vec![0, 64, 0]);
        // empty
        assert_eq!(element_lines(0, 4, []), Vec::<u64>::new());
    }

    #[test]
    fn chained_stream_fanout_invariant() {
        let parent_completions = 3;
        let s = LineStream::chained(
            StreamClass::Writes,
            MemKind::Write,
            vec![0, 64, 128, 192],
            0,
            vec![2, 0, 2],
        );
        assert_eq!(s.fanout.len(), parent_completions);
        assert_eq!(s.fanout.iter().sum::<u32>(), 4);
    }

    #[test]
    fn phase_helpers() {
        let p = Phase::single(StreamClass::Prefetch, MemKind::Read, seq_lines(0, 4096), 16);
        assert_eq!(p.total_requests(), 64);
        assert!(!p.is_empty());
        let empty = Phase::single(StreamClass::Prefetch, MemKind::Read, vec![], 16);
        assert!(empty.is_empty());
    }

    #[test]
    fn stream_classes_map_onto_regions() {
        assert_eq!(StreamClass::Prefetch.region(), Region::Vertices);
        assert_eq!(StreamClass::Values.region(), Region::Vertices);
        assert_eq!(StreamClass::Writes.region(), Region::Vertices);
        assert_eq!(StreamClass::Edges.region(), Region::Edges);
        assert_eq!(StreamClass::Updates.region(), Region::Updates);
        assert_eq!(StreamClass::Pointers.region(), Region::Payload);
    }

    #[test]
    fn lines_for_rounding() {
        assert_eq!(lines_for(0), 0);
        assert_eq!(lines_for(1), 1);
        assert_eq!(lines_for(64), 1);
        assert_eq!(lines_for(65), 2);
    }
}
