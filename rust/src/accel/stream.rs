//! The simulation vocabulary of the paper's environment (§2.2, §3.2):
//! request streams with callbacks, round-robin and priority mergers,
//! and the *cache line* and *filter* memory access abstractions.
//!
//! An accelerator phase is a set of [`LineStream`]s — cache-line
//! request sequences — wired together by chaining (stream B's requests
//! are released by completions of stream A: the paper's "callbacks")
//! and drained through a merge tree that mirrors the accelerator's
//! on-chip arbiters.
//!
//! # Zero-materialization sources
//!
//! A stream's addresses are described by a [`LineSource`], not stored:
//! the i-th line address is *computed* on demand. A sequential scan
//! over a gigabyte of edges is two `u64`s ([`LineSource::Seq`]), not a
//! 16-million-entry `Vec` — per-run stream memory is O(partitions +
//! irregular gathers) instead of O(|E|), and the simulator's working
//! set stays O(window) for the sequential traffic that dominates
//! graph accelerators (the whole point of the paper's cache-line
//! abstraction). Only genuinely irregular traffic pays for storage:
//! [`LineSource::Gather`] keeps one `u32` index per emitted line, and
//! [`LineSource::Explicit`] remains as the escape hatch (and as the
//! reference implementation the equivalence tests compare against —
//! see [`Phase::materialized`]).
//!
//! Chained-release fan-outs get the same treatment via [`Fanout`]:
//! the ubiquitous "everything releases when the parent finishes"
//! pattern is [`Fanout::AfterLast`] (one integer), uniform per-parent
//! releases are [`Fanout::Uniform`], and only irregular callbacks
//! store a per-parent vector.
//!
//! ```
//! use graphmem::accel::stream::{LineSource, LineStream, Merge, Phase, StreamClass};
//! use graphmem::dram::MemKind;
//!
//! // Gather: vertex-value lines for an irregular index set, merging
//! // *adjacent* same-line accesses exactly like the materialized
//! // `element_lines` helper (a line revisited later is re-requested).
//! let src = LineSource::gather(0, 4, [0u64, 1, 2, 100, 0]);
//! assert_eq!(src.len(), 3); // lines 0x0, 0x180, 0x0
//! assert_eq!(src.line(1), 0x180);
//! assert_eq!(src.heap_bytes(), 12); // three u32 indices
//!
//! // A sequential scan costs no heap at all, however large.
//! let seq = LineSource::seq(0, 1 << 30);
//! assert_eq!(seq.len(), (1 << 30) / 64);
//! assert_eq!(seq.heap_bytes(), 0);
//!
//! let phase = Phase {
//!     streams: vec![LineStream::independent(StreamClass::Values, MemKind::Read, src)],
//!     merge: Merge::Leaf(0).into(),
//!     window: 8,
//! };
//! assert_eq!(phase.total_requests(), 3);
//! assert_eq!(phase.stream_bytes(), 12);
//! ```

use crate::dram::{MemKind, CACHE_LINE};
use crate::trace::Region;
use std::sync::Arc;

/// Identifies what a stream models. The phase driver maps it onto a
/// [`Region`] tag stamped on every issued request, which is how the
/// trace-analysis subsystem attributes traffic to data structures.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StreamClass {
    /// Vertex value prefetch.
    Prefetch,
    /// Vertex value reads.
    Values,
    /// CSR pointer reads.
    Pointers,
    /// Edge / neighbor reads.
    Edges,
    /// Update queue reads or writes.
    Updates,
    /// Vertex value write-backs.
    Writes,
}

impl StreamClass {
    /// The trace region a stream of this class belongs to: vertex
    /// value traffic (prefetches, random reads, write-backs), edge
    /// reads, update sets, or auxiliary payload (CSR pointers).
    pub fn region(self) -> Region {
        match self {
            StreamClass::Prefetch | StreamClass::Values | StreamClass::Writes => Region::Vertices,
            StreamClass::Edges => Region::Edges,
            StreamClass::Updates => Region::Updates,
            StreamClass::Pointers => Region::Payload,
        }
    }
}

/// A cache-line address sequence in descriptor form: `line(i)` yields
/// the i-th 64 B-aligned address on demand, nothing is materialized.
///
/// All variants index in O(1); [`LineSource::heap_bytes`] is the
/// stream-memory accounting the perf benches report.
#[derive(Clone, Debug)]
pub enum LineSource {
    /// Lines covering the byte range `[base, base + bytes)` — a
    /// sequential array scan through the cache-line abstraction
    /// (the descriptor form of [`seq_lines`]).
    Seq { base: u64, bytes: u64 },
    /// `count` lines at `base + i * stride` (each mapped to its
    /// cache line). `Seq` with stride = [`CACHE_LINE`] is the common
    /// case; this generalizes to bank-walking and row-walking probes.
    Strided { base: u64, stride: u64, count: u64 },
    /// Element-indexed accesses `base + indices[i] * elem_bytes`, one
    /// kept index per emitted line (adjacent same-line accesses were
    /// merged at construction — the descriptor form of
    /// [`element_lines`]). `Arc` so cloning a phase never copies the
    /// index set.
    Gather {
        indices: Arc<[u32]>,
        elem_bytes: u64,
        base: u64,
    },
    /// Escape hatch: explicitly materialized line addresses. Used for
    /// genuinely irregular cross-structure traffic and by
    /// [`Phase::materialized`] as the reference path the equivalence
    /// suite compares descriptors against.
    Explicit(Vec<u64>),
}

impl LineSource {
    /// Sequential scan of `[base, base + bytes)`.
    pub fn seq(base: u64, bytes: u64) -> LineSource {
        LineSource::Seq { base, bytes }
    }

    /// `count` accesses at `base + i * stride`.
    pub fn strided(base: u64, stride: u64, count: u64) -> LineSource {
        LineSource::Strided { base, stride, count }
    }

    /// Element-indexed gather `base + idx * elem_bytes`, merging
    /// *adjacent* requests to the same line (the cache-line
    /// abstraction merges consecutive duplicates only — a repeated
    /// line after other traffic is requested again). Keeps the first
    /// index of every merged run, so `line(i)` reproduces exactly the
    /// sequence [`element_lines`] would materialize.
    pub fn gather(
        base: u64,
        elem_bytes: u64,
        indices: impl IntoIterator<Item = u64>,
    ) -> LineSource {
        let mut kept: Vec<u32> = Vec::new();
        let mut last_line = u64::MAX;
        for idx in indices {
            let line = (base + idx * elem_bytes) / CACHE_LINE * CACHE_LINE;
            if line != last_line {
                last_line = line;
                kept.push(u32::try_from(idx).expect("gather index exceeds u32"));
            }
        }
        LineSource::Gather {
            indices: kept.into(),
            elem_bytes,
            base,
        }
    }

    /// Number of line requests this source yields.
    pub fn len(&self) -> usize {
        match self {
            LineSource::Seq { base, bytes } => {
                if *bytes == 0 {
                    0
                } else {
                    let first = base / CACHE_LINE;
                    let last = (base + bytes - 1) / CACHE_LINE;
                    (last - first + 1) as usize
                }
            }
            LineSource::Strided { count, .. } => *count as usize,
            LineSource::Gather { indices, .. } => indices.len(),
            LineSource::Explicit(lines) => lines.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The i-th line address (64 B aligned). O(1) for every variant.
    #[inline]
    pub fn line(&self, i: usize) -> u64 {
        match self {
            LineSource::Seq { base, .. } => (base / CACHE_LINE + i as u64) * CACHE_LINE,
            LineSource::Strided { base, stride, .. } => {
                (base + i as u64 * stride) / CACHE_LINE * CACHE_LINE
            }
            LineSource::Gather {
                indices,
                elem_bytes,
                base,
            } => (base + indices[i] as u64 * elem_bytes) / CACHE_LINE * CACHE_LINE,
            LineSource::Explicit(lines) => lines[i],
        }
    }

    /// Heap bytes this descriptor holds onto (the stream-memory
    /// accounting): 0 for the closed-form variants, 4 B per kept
    /// gather index, 8 B per explicit line.
    pub fn heap_bytes(&self) -> u64 {
        match self {
            LineSource::Seq { .. } | LineSource::Strided { .. } => 0,
            LineSource::Gather { indices, .. } => indices.len() as u64 * 4,
            LineSource::Explicit(lines) => lines.len() as u64 * 8,
        }
    }

    /// The same source with every address shifted by `delta` bytes —
    /// how a compiled program's channel-relative descriptors are
    /// relocated onto a concrete memory system's region bases (see
    /// [`crate::accel::program`]). Cheap for every variant: `Gather`
    /// shares its index set through the `Arc`; only the `Explicit`
    /// escape hatch pays a copy.
    ///
    /// `delta` must be cache-line aligned, so that line boundaries —
    /// and therefore adjacent-line merging and line counts — are
    /// preserved: `rebased.line(i) == self.line(i) + delta` for all i.
    pub fn rebase(&self, delta: u64) -> LineSource {
        debug_assert_eq!(
            delta % CACHE_LINE,
            0,
            "rebase must preserve cache-line boundaries"
        );
        match self {
            LineSource::Seq { base, bytes } => LineSource::Seq {
                base: base + delta,
                bytes: *bytes,
            },
            LineSource::Strided { base, stride, count } => LineSource::Strided {
                base: base + delta,
                stride: *stride,
                count: *count,
            },
            LineSource::Gather {
                indices,
                elem_bytes,
                base,
            } => LineSource::Gather {
                indices: Arc::clone(indices),
                elem_bytes: *elem_bytes,
                base: base + delta,
            },
            LineSource::Explicit(lines) => {
                LineSource::Explicit(lines.iter().map(|a| a + delta).collect())
            }
        }
    }

    /// Materialize every line address (test/reference path).
    pub fn materialize(&self) -> Vec<u64> {
        (0..self.len()).map(|i| self.line(i)).collect()
    }
}

impl From<Vec<u64>> for LineSource {
    fn from(lines: Vec<u64>) -> LineSource {
        LineSource::Explicit(lines)
    }
}

/// Compressed chained-release fan-out: how many child requests each
/// parent completion releases.
#[derive(Clone, Debug)]
pub enum Fanout {
    /// Every parent completion releases `k` requests.
    Uniform(u32),
    /// The *last* parent completion releases all `n` requests — the
    /// barrier pattern ("after all requests are produced, the prefetch
    /// step triggers the edge reading step"). O(1) instead of a
    /// zeros-then-n vector.
    AfterLast(u32),
    /// Irregular: `v[i]` requests release on parent completion `i`;
    /// `v.len()` must equal the parent stream's length. `Arc` so a
    /// compiled program's release schedule is replayed by reference —
    /// cloning the fan-out never copies the vector.
    PerParent(Arc<[u32]>),
}

impl Fanout {
    /// Requests released by parent completion `i` (of `parent_len`).
    #[inline]
    pub fn released_by(&self, i: usize, parent_len: usize) -> u32 {
        match self {
            Fanout::Uniform(k) => *k,
            Fanout::AfterLast(n) => {
                if i + 1 == parent_len {
                    *n
                } else {
                    0
                }
            }
            Fanout::PerParent(v) => v[i],
        }
    }

    /// Total requests released across all `parent_len` completions.
    pub fn total(&self, parent_len: usize) -> u64 {
        match self {
            Fanout::Uniform(k) => *k as u64 * parent_len as u64,
            Fanout::AfterLast(n) => {
                if parent_len == 0 {
                    0
                } else {
                    *n as u64
                }
            }
            Fanout::PerParent(v) => v.iter().map(|&f| f as u64).sum(),
        }
    }

    /// Heap bytes held by this fan-out representation.
    pub fn heap_bytes(&self) -> u64 {
        match self {
            Fanout::Uniform(_) | Fanout::AfterLast(_) => 0,
            Fanout::PerParent(v) => v.len() as u64 * 4,
        }
    }
}

impl From<Vec<u32>> for Fanout {
    fn from(v: Vec<u32>) -> Fanout {
        Fanout::PerParent(v.into())
    }
}

/// A cache-line request stream in descriptor form.
#[derive(Clone, Debug)]
pub struct LineStream {
    /// Where the 64 B-aligned line addresses come from, in program
    /// order (computed on demand — see [`LineSource`]).
    pub source: LineSource,
    pub kind: MemKind,
    pub class: StreamClass,
    /// `Some(parent)`: requests are released by the parent stream's
    /// completions — [`Fanout::released_by`]`(i)` requests become
    /// available when the parent's `i`-th request completes (the
    /// callback mechanism). `None`: all requests available at phase
    /// start.
    pub chained_to: Option<usize>,
    /// Release schedule; only meaningful for chained streams, where
    /// its total over the parent's length must equal this stream's
    /// length.
    pub fanout: Fanout,
}

impl LineStream {
    /// Independent (unchained) stream.
    pub fn independent(
        class: StreamClass,
        kind: MemKind,
        source: impl Into<LineSource>,
    ) -> Self {
        LineStream {
            source: source.into(),
            kind,
            class,
            chained_to: None,
            fanout: Fanout::Uniform(0),
        }
    }

    /// Stream whose requests are released by `parent`'s completions.
    pub fn chained(
        class: StreamClass,
        kind: MemKind,
        source: impl Into<LineSource>,
        parent: usize,
        fanout: impl Into<Fanout>,
    ) -> Self {
        let source = source.into();
        let fanout = fanout.into();
        match &fanout {
            Fanout::PerParent(v) => debug_assert_eq!(
                v.iter().map(|&f| f as usize).sum::<usize>(),
                source.len(),
                "per-parent fanout must release exactly the stream"
            ),
            Fanout::AfterLast(n) => debug_assert_eq!(
                *n as usize,
                source.len(),
                "AfterLast fanout must release exactly the stream"
            ),
            // Uniform totals depend on the parent's length, which is
            // unknown here; `run_phase` debug-asserts every chained
            // stream's fanout total against its length at phase start.
            Fanout::Uniform(_) => {}
        }
        LineStream {
            source,
            kind,
            class,
            chained_to: Some(parent),
            fanout,
        }
    }

    /// Number of line requests in the stream.
    pub fn len(&self) -> usize {
        self.source.len()
    }

    pub fn is_empty(&self) -> bool {
        self.source.is_empty()
    }

    /// The i-th line address.
    #[inline]
    pub fn line(&self, i: usize) -> u64 {
        self.source.line(i)
    }

    /// Heap bytes held by this stream's descriptors (source + fanout).
    pub fn heap_bytes(&self) -> u64 {
        self.source.heap_bytes() + self.fanout.heap_bytes()
    }
}

/// Merge tree: which stream may issue next. Mirrors the accelerators'
/// arbiters (AccuGraph: values/pointers round-robin under a priority
/// mux with writes highest; ForeGraph: PEs round-robin; …).
#[derive(Clone, Debug)]
pub enum Merge {
    Leaf(usize),
    /// Fair rotation among children that have an available request.
    RoundRobin(Vec<Merge>),
    /// First child (highest priority) with an available request wins.
    Priority(Vec<Merge>),
}

impl Merge {
    /// Round-robin over plain stream indices.
    pub fn rr(streams: impl IntoIterator<Item = usize>) -> Merge {
        Merge::RoundRobin(streams.into_iter().map(Merge::Leaf).collect())
    }

    /// Priority over plain stream indices (first = highest).
    pub fn prio(streams: impl IntoIterator<Item = usize>) -> Merge {
        Merge::Priority(streams.into_iter().map(Merge::Leaf).collect())
    }
}

/// One phase of accelerator execution: streams + merge tree + the
/// outstanding-request window of the PE's memory port.
///
/// The merge tree is held by `Arc`: a compiled program (see
/// [`crate::accel::program`]) builds each arbiter tree once and every
/// per-iteration phase assembly replays it by reference.
#[derive(Clone, Debug)]
pub struct Phase {
    pub streams: Vec<LineStream>,
    pub merge: Arc<Merge>,
    /// Maximum requests in flight.
    pub window: usize,
}

impl Phase {
    /// Single independent sequential stream — the most common phase
    /// shape (prefetches, write-backs).
    pub fn single(
        class: StreamClass,
        kind: MemKind,
        source: impl Into<LineSource>,
        window: usize,
    ) -> Phase {
        Phase {
            streams: vec![LineStream::independent(class, kind, source)],
            merge: Arc::new(Merge::Leaf(0)),
            window,
        }
    }

    pub fn total_requests(&self) -> usize {
        self.streams.iter().map(|s| s.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.streams.iter().all(|s| s.is_empty())
    }

    /// Heap bytes held by all stream descriptors of this phase — the
    /// peak address-stream memory a run of this phase needs. Zero for
    /// purely sequential phases regardless of how many lines they
    /// touch.
    pub fn stream_bytes(&self) -> u64 {
        self.streams.iter().map(|s| s.heap_bytes()).sum()
    }

    /// The same phase with every source materialized to
    /// [`LineSource::Explicit`] and every fan-out expanded to
    /// [`Fanout::PerParent`] — the reference path the equivalence
    /// suite runs against descriptor execution (results must be
    /// bit-identical).
    pub fn materialized(&self) -> Phase {
        let streams = self
            .streams
            .iter()
            .map(|s| LineStream {
                source: LineSource::Explicit(s.source.materialize()),
                kind: s.kind,
                class: s.class,
                chained_to: s.chained_to,
                fanout: match s.chained_to {
                    None => Fanout::Uniform(0),
                    Some(p) => {
                        let plen = self.streams[p].len();
                        Fanout::PerParent(
                            (0..plen).map(|i| s.fanout.released_by(i, plen)).collect(),
                        )
                    }
                },
            })
            .collect();
        Phase {
            streams,
            merge: Arc::clone(&self.merge),
            window: self.window,
        }
    }
}

// ---------------------------------------------------------------------------
// Cache-line access abstraction (§3.2.1): merge adjacent requests to
// the same cache line into one. Materializing helpers — kept as the
// reference implementations of `LineSource::Seq` / `LineSource::Gather`
// and for tests that want literal address vectors.
// ---------------------------------------------------------------------------

/// Lines covering the byte range `[base, base + bytes)` — a sequential
/// array scan through the cache-line abstraction. Materialized form of
/// [`LineSource::seq`].
pub fn seq_lines(base: u64, bytes: u64) -> Vec<u64> {
    LineSource::seq(base, bytes).materialize()
}

/// Lines for element-indexed accesses `base + idx * elem_bytes`,
/// merging *adjacent* requests to the same line (the abstraction
/// merges consecutive duplicates only — a repeated line after other
/// traffic is requested again). Materialized form of
/// [`LineSource::gather`].
pub fn element_lines(base: u64, elem_bytes: u64, indices: impl IntoIterator<Item = u64>) -> Vec<u64> {
    LineSource::gather(base, elem_bytes, indices).materialize()
}

/// Number of lines a sequential scan of `bytes` bytes touches.
pub fn lines_for(bytes: u64) -> u64 {
    crate::util::ceil_div(bytes, CACHE_LINE)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seq_lines_cover_range() {
        assert_eq!(seq_lines(0, 64), vec![0]);
        assert_eq!(seq_lines(0, 65), vec![0, 64]);
        assert_eq!(seq_lines(60, 8), vec![0, 64]); // straddles boundary
        assert_eq!(seq_lines(128, 0), Vec::<u64>::new());
        assert_eq!(seq_lines(100, 1), vec![64]);
    }

    #[test]
    fn seq_source_indexes_like_materialized() {
        for (base, bytes) in [(0u64, 64u64), (0, 65), (60, 8), (128, 0), (100, 1), (4096, 777)] {
            let src = LineSource::seq(base, bytes);
            assert_eq!(src.materialize(), seq_lines(base, bytes), "{base}/{bytes}");
            assert_eq!(src.len(), seq_lines(base, bytes).len());
            assert_eq!(src.heap_bytes(), 0);
        }
    }

    #[test]
    fn element_lines_merge_adjacent_only() {
        // 4-byte elements, indices 0,1,2 -> same line merged
        assert_eq!(element_lines(0, 4, [0, 1, 2]), vec![0]);
        // revisiting a line after other traffic re-requests it
        assert_eq!(element_lines(0, 4, [0, 16, 0]), vec![0, 64, 0]);
        // empty
        assert_eq!(element_lines(0, 4, []), Vec::<u64>::new());
    }

    #[test]
    fn gather_source_indexes_like_materialized() {
        let idx = [0u64, 1, 2, 16, 0, 5, 1000, 1001];
        let src = LineSource::gather(128, 4, idx.iter().copied());
        assert_eq!(src.materialize(), element_lines(128, 4, idx.iter().copied()));
        assert_eq!(src.heap_bytes(), src.len() as u64 * 4);
    }

    #[test]
    fn strided_source_walks_stride() {
        let src = LineSource::strided(0, 8192, 4);
        assert_eq!(src.len(), 4);
        assert_eq!(src.materialize(), vec![0, 8192, 16384, 24576]);
        assert_eq!(src.heap_bytes(), 0);
        // unaligned strides map onto their cache line
        let off = LineSource::strided(32, 100, 3);
        assert_eq!(off.materialize(), vec![0, 128, 192]);
    }

    #[test]
    fn fanout_representations_agree() {
        let plen = 5;
        let uni = Fanout::Uniform(2);
        assert_eq!(uni.total(plen), 10);
        assert_eq!(uni.released_by(3, plen), 2);
        let last = Fanout::AfterLast(7);
        assert_eq!(last.total(plen), 7);
        assert_eq!(
            (0..plen).map(|i| last.released_by(i, plen)).collect::<Vec<_>>(),
            vec![0, 0, 0, 0, 7]
        );
        let per = Fanout::PerParent(vec![1, 0, 3].into());
        assert_eq!(per.total(3), 4);
        assert_eq!(per.released_by(2, 3), 3);
        assert_eq!(uni.heap_bytes() + last.heap_bytes(), 0);
        assert_eq!(per.heap_bytes(), 12);
    }

    #[test]
    fn chained_stream_fanout_invariant() {
        let parent_completions = 3;
        let s = LineStream::chained(
            StreamClass::Writes,
            MemKind::Write,
            vec![0, 64, 128, 192],
            0,
            vec![2, 0, 2],
        );
        match &s.fanout {
            Fanout::PerParent(v) => {
                assert_eq!(v.len(), parent_completions);
                assert_eq!(v.iter().sum::<u32>(), 4);
            }
            other => panic!("expected PerParent, got {other:?}"),
        }
    }

    #[test]
    fn phase_helpers() {
        let p = Phase::single(StreamClass::Prefetch, MemKind::Read, LineSource::seq(0, 4096), 16);
        assert_eq!(p.total_requests(), 64);
        assert!(!p.is_empty());
        assert_eq!(p.stream_bytes(), 0);
        let empty = Phase::single(StreamClass::Prefetch, MemKind::Read, Vec::<u64>::new(), 16);
        assert!(empty.is_empty());
    }

    #[test]
    fn stream_bytes_independent_of_sequential_length() {
        // The acceptance property: a sequential-only phase holds O(1)
        // descriptor memory no matter how many edges it scans.
        let small =
            Phase::single(StreamClass::Edges, MemKind::Read, LineSource::seq(0, 1 << 12), 32);
        let huge =
            Phase::single(StreamClass::Edges, MemKind::Read, LineSource::seq(0, 1 << 38), 32);
        assert_eq!(small.stream_bytes(), 0);
        assert_eq!(huge.stream_bytes(), 0);
        assert_eq!(huge.total_requests(), (1usize << 38) / 64);
    }

    #[test]
    fn materialized_phase_matches_descriptors() {
        let parent = LineStream::independent(
            StreamClass::Edges,
            MemKind::Read,
            LineSource::seq(0, 4 * 64),
        );
        let child = LineStream::chained(
            StreamClass::Writes,
            MemKind::Write,
            LineSource::gather(1 << 20, 4, [0u64, 16, 32, 48]),
            0,
            Fanout::AfterLast(4),
        );
        let phase = Phase {
            streams: vec![parent, child],
            merge: Merge::prio([1, 0]).into(),
            window: 8,
        };
        let m = phase.materialized();
        for (a, b) in phase.streams.iter().zip(&m.streams) {
            assert_eq!(a.source.materialize(), b.source.materialize());
            assert_eq!(a.len(), b.len());
            let plen = phase.streams[0].len();
            for i in 0..plen {
                assert_eq!(
                    a.fanout.released_by(i, plen),
                    b.fanout.released_by(i, plen)
                );
            }
        }
        assert!(m.stream_bytes() >= phase.stream_bytes());
    }

    #[test]
    fn stream_classes_map_onto_regions() {
        assert_eq!(StreamClass::Prefetch.region(), Region::Vertices);
        assert_eq!(StreamClass::Values.region(), Region::Vertices);
        assert_eq!(StreamClass::Writes.region(), Region::Vertices);
        assert_eq!(StreamClass::Edges.region(), Region::Edges);
        assert_eq!(StreamClass::Updates.region(), Region::Updates);
        assert_eq!(StreamClass::Pointers.region(), Region::Payload);
    }

    #[test]
    fn lines_for_rounding() {
        assert_eq!(lines_for(0), 0);
        assert_eq!(lines_for(1), 1);
        assert_eq!(lines_for(64), 1);
        assert_eq!(lines_for(65), 2);
    }
}
